//! Wire-protocol robustness (DESIGN.md §15): hostile or corrupt bytes
//! must come back as structured [`WireError`]s — never a panic, and
//! never an allocation driven by an unvalidated length prefix. The
//! fuzz loops are seeded xorshift, so a failure reproduces with
//! `cargo test --test net_wire` alone.

use std::sync::Arc;

use pemsvm::backend::{RngState, StepInput};
use pemsvm::net::frame::{
    crc32, encode_frame, read_frame, RecvError, WireError, HEADER_LEN, MAX_PAYLOAD, VERSION,
};
use pemsvm::net::wire::{msg, Enc, Reply, Request};
use pemsvm::solver::PartialStats;

/// All message-type bytes both decoders accept.
const REQUEST_TAGS: [u8; 7] = [
    msg::CONFIGURE,
    msg::CHUNK,
    msg::SEAL,
    msg::STEP,
    msg::GET_RNG,
    msg::SET_RNG,
    msg::SHUTDOWN,
];
const REPLY_TAGS: [u8; 5] = [msg::R_CONFIGURED, msg::R_OK, msg::R_STEPPED, msg::R_RNG, msg::R_ERROR];

/// A representative non-trivial request: a step frame exercises ranges,
/// length-prefixed float vectors, and the tagged input union.
fn sample_step() -> Request {
    Request::Step {
        round: 3,
        input: StepInput::Svr { w: Arc::new(vec![0.5, -1.25, 3.0]), eps_ins: 0.1 },
        extra: vec![10..20, 20..20],
    }
}

fn sample_frame() -> Vec<u8> {
    let (t, body) = sample_step().encode();
    encode_frame(t, &body)
}

struct XorShift(u64);

impl XorShift {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x
    }
}

#[test]
fn bad_magic_rejected() {
    let mut frame = sample_frame();
    frame[0] ^= 0xFF;
    match read_frame(&mut &frame[..]) {
        Err(RecvError::Protocol(WireError::BadMagic(_))) => {}
        other => panic!("expected BadMagic, got {other:?}"),
    }
}

#[test]
fn version_skew_rejected() {
    let mut frame = sample_frame();
    frame[4] = VERSION + 1;
    match read_frame(&mut &frame[..]) {
        Err(RecvError::Protocol(WireError::VersionSkew { got, want })) => {
            assert_eq!((got, want), (VERSION + 1, VERSION));
        }
        other => panic!("expected VersionSkew, got {other:?}"),
    }
}

#[test]
fn nonzero_reserved_rejected() {
    let mut frame = sample_frame();
    frame[6] = 0x01;
    assert!(matches!(
        read_frame(&mut &frame[..]),
        Err(RecvError::Protocol(WireError::BadReserved(1)))
    ));
}

/// A length prefix past `MAX_PAYLOAD` must fail at header validation —
/// *before* any payload read or allocation. The reader here holds only
/// the 16 header bytes, so an implementation that tried to allocate or
/// read the claimed 4 GiB would surface `Truncated`/`Io`, not
/// `Oversized`.
#[test]
fn oversized_length_prefix_fails_before_allocation() {
    let mut frame = sample_frame();
    frame[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
    frame.truncate(HEADER_LEN);
    match read_frame(&mut &frame[..]) {
        Err(RecvError::Protocol(WireError::Oversized { len, max })) => {
            assert_eq!(len, u32::MAX as u64);
            assert_eq!(max, MAX_PAYLOAD as u64);
        }
        other => panic!("expected Oversized, got {other:?}"),
    }
}

#[test]
fn crc_mismatch_detected_for_any_payload_corruption() {
    let clean = sample_frame();
    for i in HEADER_LEN..clean.len() {
        let mut frame = clean.clone();
        frame[i] ^= 0x10;
        match read_frame(&mut &frame[..]) {
            Err(RecvError::Protocol(WireError::CrcMismatch { .. })) => {}
            other => panic!("flipping payload byte {i}: expected CrcMismatch, got {other:?}"),
        }
    }
}

/// EOF on the frame boundary is a clean close; EOF anywhere inside a
/// frame is a structured truncation error. Every cut point is checked.
#[test]
fn truncation_at_every_byte_is_structured() {
    let frame = sample_frame();
    assert!(matches!(read_frame(&mut &frame[..0]), Err(RecvError::Closed)));
    for cut in 1..frame.len() {
        match read_frame(&mut &frame[..cut]) {
            Err(RecvError::Protocol(WireError::Truncated { .. })) => {}
            other => panic!("cut at {cut}: expected Truncated, got {other:?}"),
        }
    }
    assert!(read_frame(&mut &frame[..]).is_ok());
}

#[test]
fn unknown_message_types_rejected_by_both_decoders() {
    for t in [0x00, 0x08, 0x42, 0x80, 0x86, 0xFF] {
        assert!(
            matches!(Request::decode(t, &[]), Err(WireError::UnknownMsg(got)) if got == t),
            "request tag {t:#04x}"
        );
    }
    // a request tag handed to the reply decoder is just as unknown
    for t in REQUEST_TAGS {
        assert!(matches!(Reply::decode(t, &[]), Err(WireError::UnknownMsg(_))));
    }
    for t in REPLY_TAGS {
        assert!(matches!(Request::decode(t, &[]), Err(WireError::UnknownMsg(_))));
    }
}

#[test]
fn trailing_bytes_rejected() {
    for req in [sample_step(), Request::Seal, Request::GetRng] {
        let (t, mut body) = req.encode();
        body.push(0x00);
        assert!(
            matches!(Request::decode(t, &body), Err(WireError::BadValue(_))),
            "{req:?}: trailing byte accepted"
        );
    }
    let (t, mut body) = Reply::Stepped { round: 1, stats: PartialStats::zeros(4) }.encode();
    body.extend_from_slice(&[1, 2, 3]);
    assert!(matches!(Reply::decode(t, &body), Err(WireError::BadValue(_))));
}

/// Every strict prefix of every valid message body decodes to an error,
/// not a panic — the cursor checks remaining bytes before every read.
#[test]
fn truncated_message_bodies_never_panic() {
    let messages = [
        sample_step(),
        Request::SetRng(RngState { state: 7, inc: 11, spare: Some(0.25) }),
        Request::Step {
            round: 9,
            input: StepInput::Binary { w: Arc::new(vec![1.0; 8]) },
            extra: vec![],
        },
    ];
    for req in messages {
        let (t, body) = req.encode();
        for cut in 0..body.len() {
            let r = Request::decode(t, &body[..cut]);
            assert!(r.is_err(), "{req:?} cut at {cut}: decoded {r:?} from a prefix");
        }
        assert!(Request::decode(t, &body).is_ok());
    }
}

/// A hostile vector-length claim (here: 2^60 floats in a step input)
/// must be rejected against the bytes actually present, before any
/// `Vec` reservation.
#[test]
fn hostile_vector_length_rejected_without_allocation() {
    // Step body layout: round u64, extra count u64, input tag u8, then
    // Binary's weight vector length prefix
    let mut e = Enc::new();
    e.u64(1); // round
    e.u64(0); // no adoption ranges
    e.u8(0); // input tag: Binary
    e.u64(1 << 60); // claimed f32 count (would be 2^62 bytes)
    let body = e.into_bytes();
    match Request::decode(msg::STEP, &body) {
        Err(WireError::Truncated { need, have }) => {
            assert_eq!(need, (1usize << 60) * 4);
            assert_eq!(have, 0);
        }
        other => panic!("expected Truncated, got {other:?}"),
    }
    // and a count whose byte size overflows usize entirely
    let mut e = Enc::new();
    e.u64(1);
    e.u64(0);
    e.u8(0);
    e.u64(u64::MAX);
    assert!(matches!(Request::decode(msg::STEP, &e.into_bytes()), Err(WireError::BadValue(_))));
}

#[test]
fn inverted_adoption_range_rejected() {
    let mut e = Enc::new();
    e.u64(1); // round
    e.u64(1); // one adoption range
    e.u64(20); // start
    e.u64(10); // end < start
    assert!(matches!(Request::decode(msg::STEP, &e.into_bytes()), Err(WireError::BadValue(_))));
}

/// Seeded fuzz: random buffers and random mutations of valid bodies,
/// through both decoders under every known tag. The only contract is
/// totality — `Ok` or a structured `Err`, never a panic or abort.
#[test]
fn fuzz_decoders_are_total() {
    let mut rng = XorShift(0x9E37_79B9_7F4A_7C15);
    let mut buf = Vec::new();
    for round in 0..2000usize {
        let len = (rng.next() % 200) as usize;
        buf.clear();
        for _ in 0..len {
            buf.push(rng.next() as u8);
        }
        let tag_pool = [REQUEST_TAGS[round % 7], REPLY_TAGS[round % 5], rng.next() as u8];
        for t in tag_pool {
            let _ = Request::decode(t, &buf);
            let _ = Reply::decode(t, &buf);
        }
    }
    // mutate valid bodies: single byte flips at random offsets
    let valid: Vec<(u8, Vec<u8>)> = vec![
        sample_step().encode(),
        Request::SetRng(RngState { state: u128::MAX - 1, inc: 3, spare: None }).encode(),
        Reply::Stepped { round: 2, stats: PartialStats::zeros(6) }.encode(),
        Reply::Error { msg: "boom".into() }.encode(),
    ];
    for _ in 0..2000 {
        let (t, body) = &valid[(rng.next() % valid.len() as u64) as usize];
        let mut mutated = body.clone();
        if !mutated.is_empty() {
            let at = (rng.next() % mutated.len() as u64) as usize;
            mutated[at] ^= (rng.next() % 255 + 1) as u8;
        }
        let _ = Request::decode(*t, &mutated);
        let _ = Reply::decode(*t, &mutated);
    }
}

/// Same totality contract one layer down: random bytes through the
/// frame reader.
#[test]
fn fuzz_frame_reader_is_total() {
    let mut rng = XorShift(0xDEAD_BEEF_CAFE_F00D);
    let clean = sample_frame();
    for _ in 0..2000 {
        let len = (rng.next() % 64) as usize;
        let mut buf: Vec<u8> = (0..len).map(|_| rng.next() as u8).collect();
        let _ = read_frame(&mut &buf[..]);
        // and corrupted real frames
        buf = clean.clone();
        let at = (rng.next() % buf.len() as u64) as usize;
        buf[at] ^= (rng.next() % 255 + 1) as u8;
        let _ = read_frame(&mut &buf[..]);
    }
}

/// The CRC actually covers the payload bytes the header claims.
#[test]
fn crc_binds_header_to_payload() {
    let (t, body) = sample_step().encode();
    let frame = encode_frame(t, &body);
    let stored = u32::from_le_bytes([frame[12], frame[13], frame[14], frame[15]]);
    assert_eq!(stored, crc32(&body));
}
