//! The distributed backend end to end (DESIGN.md §15): coordinator and
//! `pemsvm worker` daemons in one process over loopback TCP, asserting
//! the tentpole guarantees —
//!
//! 1. **Bit-identity.** A `--hosts` run over real sockets produces
//!    bit-for-bit the weights and per-iteration history of the threaded
//!    pool, for every task and both algorithms, dense and sparse, eager
//!    and streamed: floats cross the wire as IEEE bit patterns, daemons
//!    run the same `NativeWorker` seeds, and the tree reduce still
//!    merges leader-side in the identical order.
//! 2. **A dead connection is an eviction, not a crash.** A worker that
//!    hangs up mid-step follows the retry→evict path; survivors adopt
//!    its rows and the run finishes finite.
//! 3. **Checkpoints cross process boundaries.** RNG streams captured
//!    from remote daemons resume bit-identically on a *fresh* set of
//!    daemons, and a `Remote` checkpoint refuses a `Threads` session.

use std::net::TcpListener;
use std::path::PathBuf;

use pemsvm::config::{Algo, TaskKind, Topology, TrainConfig};
use pemsvm::data::{libsvm, stream::StreamOpts, stream::StreamReader, synth, Dataset, Task};
use pemsvm::engine::{CheckpointCfg, Cluster, TrainOutput, WarmStart};
use pemsvm::model::Weights;
use pemsvm::net::frame::{read_frame, write_frame};
use pemsvm::net::wire::{Reply, Request};

/// Bind loopback listeners and serve each on its own daemon thread,
/// exactly what `pemsvm worker --listen 127.0.0.1:0` does. Binding
/// happens here, before the spawn, so a coordinator may connect before
/// the daemon thread reaches `accept`.
fn spawn_workers(n: usize) -> Vec<String> {
    let mut hosts = Vec::new();
    for _ in 0..n {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        hosts.push(listener.local_addr().unwrap().to_string());
        std::thread::spawn(move || {
            let _ = pemsvm::net::worker::run(listener, false);
        });
    }
    hosts
}

/// A daemon that answers the setup phase correctly and then hangs up on
/// the first step request — a deterministic stand-in for `kill -9` at
/// the worst moment (after it holds rows, before it contributed any
/// statistics).
fn spawn_saboteur() -> String {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let host = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        let (mut s, _) = match listener.accept() {
            Ok(c) => c,
            Err(_) => return,
        };
        loop {
            let Ok((t, payload, _)) = read_frame(&mut s) else { return };
            let Ok(req) = Request::decode(t, &payload) else { return };
            let reply = match req {
                Request::Configure(spec) => Reply::Configured { stat_dim: spec.k },
                Request::Chunk(_) | Request::Seal | Request::SetRng(_) => Reply::Ok,
                Request::GetRng => Reply::Rng { state: None },
                Request::Step { .. } => return, // the "crash"
                Request::Shutdown => {
                    let (t, b) = Reply::Ok.encode();
                    let _ = write_frame(&mut s, t, &b);
                    return;
                }
            };
            let (t, b) = reply.encode();
            if write_frame(&mut s, t, &b).is_err() {
                return;
            }
        }
    });
    host
}

/// Fixed-round config so both topologies execute the same schedule.
fn base_cfg() -> TrainConfig {
    let mut cfg = TrainConfig::default().with_options("LIN-EM-CLS").unwrap();
    cfg.workers = 2;
    cfg.max_iters = 5;
    cfg.tol = -1.0;
    cfg.num_classes = 3;
    cfg.burn_in = 1;
    cfg
}

fn dataset_for(task: TaskKind) -> Dataset {
    match task {
        TaskKind::Cls => synth::alpha_like(300, 8, 5),
        TaskKind::Svr => synth::year_like(300, 8, 5),
        TaskKind::Mlt => synth::mnist_like(300, 8, 3, 5),
    }
}

fn flat(w: &Weights) -> &[f32] {
    match w {
        Weights::Single(v) => v,
        Weights::PerClass(m) => &m.data,
    }
}

fn bits(w: &Weights) -> Vec<u32> {
    flat(w).iter().map(|x| x.to_bits()).collect()
}

fn history_bits(out: &TrainOutput) -> Vec<(usize, u64, u64)> {
    out.history
        .iter()
        .map(|h| (h.iter, h.objective.to_bits(), h.train_loss.to_bits()))
        .collect()
}

fn run(ds: &Dataset, cfg: &TrainConfig) -> TrainOutput {
    let mut cl = Cluster::new(ds, cfg).unwrap();
    cl.run_session(cfg, None, WarmStart::Cold).unwrap()
}

fn ckpt_path(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("pemsvm_distributed_test");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{}_{}.ckpt", tag, std::process::id()))
}

/// Guarantee 1, the full matrix: every task × both algorithms, a
/// 2-daemon `Remote` run against the `Threads` reference.
#[test]
fn remote_run_is_bit_identical_to_threads() {
    for task in [TaskKind::Cls, TaskKind::Svr, TaskKind::Mlt] {
        let ds = dataset_for(task);
        for algo in [Algo::Em, Algo::Mc] {
            let mut cfg = base_cfg();
            cfg.task = task;
            cfg.algo = algo;
            let want = run(&ds, &cfg);

            let mut rcfg = cfg.clone();
            rcfg.topology = Topology::Remote(spawn_workers(cfg.workers));
            let got = run(&ds, &rcfg);

            let tag = format!("{task:?}/{algo:?}");
            assert_eq!(bits(&got.weights), bits(&want.weights), "{tag}: weights drifted");
            assert_eq!(history_bits(&got), history_bits(&want), "{tag}: history drifted");
        }
    }
    // the run above moved real bytes through real sockets
    let m = pemsvm::net::net_metrics();
    assert!(m.bytes_tx.get() > 0, "no bytes counted as sent");
    assert!(m.bytes_rx.get() > 0, "no bytes counted as received");
}

/// Sparse features ship as CSR windows (never densified), so the sparse
/// compute path — whose f32 association order differs from the dense
/// one — still matches bit-for-bit.
#[test]
fn remote_sparse_dataset_is_bit_identical() {
    let ds = synth::dna_like(400, 40, 9);
    let cfg = base_cfg();
    let want = run(&ds, &cfg);

    let mut rcfg = cfg.clone();
    rcfg.topology = Topology::Remote(spawn_workers(cfg.workers));
    let got = run(&ds, &rcfg);
    assert_eq!(bits(&got.weights), bits(&want.weights));
    assert_eq!(history_bits(&got), history_bits(&want));
}

/// Streamed ingestion over the wire: chunks forward to the daemons as
/// they are parsed, no full dataset is ever shipped, and the result
/// still matches the threaded streamed run bit-for-bit.
#[test]
fn streamed_ingestion_over_the_wire_is_bit_identical() {
    let dir = std::env::temp_dir().join("pemsvm_distributed_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("stream_{}.svm", std::process::id()));
    libsvm::save(&synth::alpha_like(250, 6, 3), &path).unwrap();
    let opts = StreamOpts { chunk_rows: 32, dims: None, class_off: None };

    let cfg = base_cfg();
    let reader = StreamReader::open(&path, Task::Binary, &opts).unwrap();
    let mut cl = Cluster::from_stream(reader, &cfg).unwrap();
    let want = cl.run_session(&cfg, None, WarmStart::Cold).unwrap();

    let mut rcfg = cfg.clone();
    rcfg.topology = Topology::Remote(spawn_workers(cfg.workers));
    let reader = StreamReader::open(&path, Task::Binary, &opts).unwrap();
    let mut rcl = Cluster::from_stream(reader, &rcfg).unwrap();
    let got = rcl.run_session(&rcfg, None, WarmStart::Cold).unwrap();

    assert_eq!(bits(&got.weights), bits(&want.weights));
    assert_eq!(history_bits(&got), history_bits(&want));
    let _ = std::fs::remove_file(&path);
}

/// Guarantee 2: a connection that dies mid-step is retried (fail-fast on
/// the dead socket), evicted, and its rows adopted — the session
/// finishes every scheduled iteration with finite numbers, like the
/// in-process chaos tests' `PanicAt`.
#[test]
fn dead_connection_evicts_and_run_completes() {
    let ds = dataset_for(TaskKind::Cls);
    let mut cfg = base_cfg();
    cfg.workers = 3;
    cfg.step_timeout_ms = 2000;
    let mut hosts = spawn_workers(2);
    hosts.push(spawn_saboteur());
    cfg.topology = Topology::Remote(hosts);

    let mut cl = Cluster::new(&ds, &cfg).unwrap();
    let out = cl.run_session(&cfg, None, WarmStart::Cold).unwrap();
    assert_eq!(cl.fault_counters().evictions, 1);
    assert_eq!(cl.alive_workers(), 2);
    assert_eq!(out.iterations, cfg.max_iters, "run cut short");
    assert!(out.objective.is_finite());
    assert!(out.history.iter().all(|h| h.objective.is_finite()));
    assert!(flat(&out.weights).iter().all(|x| x.is_finite()));
}

/// Guarantee 3: the MC sampler's worker RNG streams round-trip through
/// `GetRng`/`SetRng` frames, so a run interrupted after a checkpoint
/// resumes on a *fresh* set of daemons bit-identically to the
/// uninterrupted remote run.
#[test]
fn checkpoint_resumes_on_fresh_daemons_bit_identically() {
    let ds = dataset_for(TaskKind::Cls);
    let mut cfg = base_cfg();
    cfg.algo = Algo::Mc;
    cfg.max_iters = 8;
    cfg.burn_in = 2;
    cfg.topology = Topology::Remote(spawn_workers(cfg.workers));

    let mut full = Cluster::new(&ds, &cfg).unwrap();
    let want = full.run_session(&cfg, None, WarmStart::Cold).unwrap();
    drop(full);

    let path = ckpt_path("remote_mc_cls");
    let mut half = cfg.clone();
    half.max_iters = 4;
    half.topology = Topology::Remote(spawn_workers(cfg.workers));
    let ck = CheckpointCfg { every: 4, path: path.clone(), resume: false };
    let mut interrupted = Cluster::new(&ds, &half).unwrap();
    interrupted.run_session_checkpointed(&half, None, WarmStart::Cold, None, Some(&ck)).unwrap();
    drop(interrupted);

    // fresh daemons, fresh coordinator: only the checkpoint file crosses
    let mut rcfg = cfg.clone();
    rcfg.topology = Topology::Remote(spawn_workers(cfg.workers));
    let ck = CheckpointCfg { every: 4, path: path.clone(), resume: true };
    let mut fresh = Cluster::new(&ds, &rcfg).unwrap();
    let got = fresh.run_session_checkpointed(&rcfg, None, WarmStart::Cold, None, Some(&ck)).unwrap();

    assert_eq!(got.history.first().map(|h| h.iter), Some(4), "resume did not start at iter 4");
    assert_eq!(history_bits(&got), history_bits(&want)[4..].to_vec(), "resumed tail diverged");
    assert_eq!(bits(&got.weights), bits(&want.weights), "final weights not bit-identical");

    // and the fingerprint pins the topology *kind*: a Remote checkpoint
    // refuses to continue on a Threads cluster
    let mut tcfg = cfg.clone();
    tcfg.topology = Topology::Threads;
    let ck = CheckpointCfg { every: 0, path: path.clone(), resume: true };
    let mut wrong = Cluster::new(&ds, &tcfg).unwrap();
    let err = wrong
        .run_session_checkpointed(&tcfg, None, WarmStart::Cold, None, Some(&ck))
        .unwrap_err();
    assert!(format!("{err:#}").contains("topology"), "{err:#}");
    let _ = std::fs::remove_file(&path);
}
