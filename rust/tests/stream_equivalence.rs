//! The streamed-ingestion equivalence guarantee (DESIGN.md §10): for a
//! fixed seed, `Cluster::from_stream` at ANY chunk size must reproduce
//! the eager path bit for bit — same shard contents in the workers,
//! same training trajectory, same final weights.

use std::path::PathBuf;

use pemsvm::config::{Topology, TrainConfig};
use pemsvm::data::stream::{StreamOpts, StreamReader};
use pemsvm::data::{libsvm, synth, Task};
use pemsvm::engine::{Cluster, WarmStart};
use pemsvm::model::Weights;

fn tmpfile(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("pemsvm_stream_equiv");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn base_cfg(options: &str, workers: usize) -> TrainConfig {
    let mut cfg = TrainConfig::default().with_options(options).unwrap();
    cfg.workers = workers;
    cfg.max_iters = 8;
    cfg.tol = 0.0; // run all 8 iterations in both paths
    cfg.seed = 7;
    cfg
}

fn weights_bits(w: &Weights) -> Vec<u32> {
    match w {
        Weights::Single(v) => v.iter().map(|x| x.to_bits()).collect(),
        Weights::PerClass(m) => m.data.iter().map(|x| x.to_bits()).collect(),
    }
}

/// Train eagerly and via the stream at several awkward chunk sizes; the
/// weights must agree to the bit.
#[test]
fn streamed_training_is_bit_identical_to_eager() {
    let p = tmpfile("cls.svm");
    let ds = synth::dna_like(3_000, 120, 11);
    libsvm::save(&ds, &p).unwrap();

    let cfg = base_cfg("LIN-EM-CLS", 4);
    let eager = libsvm::load(&p, Task::Binary, cfg.workers).unwrap();
    let mut cluster = Cluster::new(&eager, &cfg).unwrap();
    let want = cluster.run_session(&cfg, None, WarmStart::Cold).unwrap();

    // 257 does not divide shard boundaries, 3000 is one whole-file
    // chunk, 4096 exceeds the file
    for chunk_rows in [257usize, 1_000, 3_000, 4_096] {
        let opts = StreamOpts::rows(chunk_rows);
        let reader = StreamReader::open(&p, Task::Binary, &opts).unwrap();
        assert_eq!(reader.n(), eager.n);
        assert_eq!(reader.k(), eager.k);
        let gauge = reader.gauge();
        let mut streamed = Cluster::from_stream(reader, &cfg).unwrap();
        let got = streamed.run_session(&cfg, None, WarmStart::Cold).unwrap();
        assert!(
            gauge.peak() <= 2 * chunk_rows,
            "chunk {chunk_rows}: peak resident rows {} > 2 x chunk",
            gauge.peak()
        );
        assert_eq!(got.iterations, want.iterations, "chunk {chunk_rows}");
        assert_eq!(
            got.objective.to_bits(),
            want.objective.to_bits(),
            "chunk {chunk_rows}: objective diverged"
        );
        assert_eq!(
            weights_bits(&got.weights),
            weights_bits(&want.weights),
            "chunk {chunk_rows}: weights diverged"
        );
    }
}

/// The MC sampler draws per-worker RNG streams; streamed construction
/// must not perturb them.
#[test]
fn streamed_mc_matches_eager_mc() {
    let p = tmpfile("mc.svm");
    let ds = synth::dna_like(800, 60, 3);
    libsvm::save(&ds, &p).unwrap();

    let mut cfg = base_cfg("LIN-MC-CLS", 3);
    cfg.burn_in = 2;
    let eager = libsvm::load(&p, Task::Binary, cfg.workers).unwrap();
    let mut cluster = Cluster::new(&eager, &cfg).unwrap();
    let want = cluster.run_session(&cfg, None, WarmStart::Cold).unwrap();

    let opts = StreamOpts::rows(111);
    let reader = StreamReader::open(&p, Task::Binary, &opts).unwrap();
    let mut streamed = Cluster::from_stream(reader, &cfg).unwrap();
    let got = streamed.run_session(&cfg, None, WarmStart::Cold).unwrap();
    assert_eq!(weights_bits(&got.weights), weights_bits(&want.weights));
}

/// Simulated topology ingests serially on the leader; it must build the
/// same shards (and the declared --dims fast path must too).
#[test]
fn streamed_simulate_and_dims_match_eager() {
    let p = tmpfile("sim.svm");
    let ds = synth::dna_like(500, 40, 5);
    libsvm::save(&ds, &p).unwrap();

    let mut cfg = base_cfg("LIN-EM-CLS", 4);
    cfg.topology = Topology::Simulate;
    let eager = libsvm::load(&p, Task::Binary, cfg.workers).unwrap();
    let mut cluster = Cluster::new(&eager, &cfg).unwrap();
    let want = cluster.run_session(&cfg, None, WarmStart::Cold).unwrap();

    for dims in [None, Some((500usize, 40usize))] {
        let opts = StreamOpts { chunk_rows: 64, dims, class_off: None };
        let reader = StreamReader::open(&p, Task::Binary, &opts).unwrap();
        let mut streamed = Cluster::from_stream(reader, &cfg).unwrap();
        let got = streamed.run_session(&cfg, None, WarmStart::Cold).unwrap();
        assert_eq!(
            weights_bits(&got.weights),
            weights_bits(&want.weights),
            "dims {dims:?}"
        );
    }
}

/// Multiclass end to end: streamed MLT training through the
/// Crammer-Singer block driver (the scan pass also fixes the class-id
/// offset; the 1-based-ids case is pinned in `data::stream`'s unit
/// tests).
#[test]
fn streamed_multiclass_matches_eager() {
    let p = tmpfile("mlt.svm");
    let ds = synth::mnist_like(600, 24, 5, 2);
    libsvm::save(&ds, &p).unwrap();

    let mut cfg = base_cfg("LIN-EM-MLT", 3);
    cfg.num_classes = 5;
    cfg.max_iters = 4;
    let eager = libsvm::load(&p, Task::Multiclass(5), cfg.workers).unwrap();
    let mut cluster = Cluster::new(&eager, &cfg).unwrap();
    let want = cluster.run_session(&cfg, None, WarmStart::Cold).unwrap();

    let opts = StreamOpts::rows(97);
    let reader = StreamReader::open(&p, Task::Multiclass(5), &opts).unwrap();
    let mut streamed = Cluster::from_stream(reader, &cfg).unwrap();
    let got = streamed.run_session(&cfg, None, WarmStart::Cold).unwrap();
    assert_eq!(weights_bits(&got.weights), weights_bits(&want.weights));
}
