//! Property tests for the SIMD compute core: the dispatched rank-update
//! kernel, the scalar fallback, and a naive O(n k^2) reference must
//! agree within tight tolerance across awkward shapes (row counts that
//! miss every unroll width, tiny and odd k, zero-weight rows), and the
//! packed-triangular statistics must round-trip against full matrices.
//!
//! CI runs this file as the kernel-equivalence smoke step, so it must
//! pass on whatever ISA the runner dispatches to (the scalar fallback
//! makes it trivially true where no SIMD path exists).

use pemsvm::linalg::{
    active_isa, axpy, axpy_scalar, dot, dot_scalar, rank_update_dense, rank_update_dense_scalar,
    rank_update_sparse, Mat, SymPacked,
};
use pemsvm::rng::Pcg64;

/// Reference Sigma += sum_d a_d x_d x_d^T, computed naively in the
/// full matrix then packed.
fn naive(x: &[f32], n: usize, k: usize, a: &[f32]) -> SymPacked {
    let mut s = Mat::zeros(k, k);
    for d in 0..n {
        for i in 0..k {
            for j in 0..=i {
                s[(i, j)] += a[d] * x[d * k + i] * x[d * k + j];
            }
        }
    }
    SymPacked::from_mat_lower(&s)
}

fn random_problem(n: usize, k: usize, seed: u64, zero_rows: bool) -> (Vec<f32>, Vec<f32>) {
    let mut g = Pcg64::new(seed);
    let x: Vec<f32> = (0..n * k).map(|_| g.next_f32() * 2.0 - 1.0).collect();
    let a: Vec<f32> = (0..n)
        .map(|d| {
            if zero_rows && d % 3 == 0 {
                0.0
            } else {
                g.next_f32() * 3.0
            }
        })
        .collect();
    (x, a)
}

fn assert_close(got: &SymPacked, want: &SymPacked, label: &str) {
    let scale = want.data.iter().fold(1f32, |m, &v| m.max(v.abs()));
    let diff = got.max_abs_diff(want);
    assert!(
        diff <= 2e-4 * scale,
        "{label} (isa={}): max diff {diff} > 2e-4 * {scale}",
        active_isa().name()
    );
}

/// The three kernel paths agree on every awkward (n, k) combination:
/// n missing the rank-4 and rank-8 block widths, k missing every
/// vector width (1, 3, 17) plus aligned sizes (8, 64).
#[test]
fn simd_scalar_naive_agree_on_awkward_shapes() {
    let mut seed = 100;
    for &n in &[1usize, 2, 5, 7, 9, 15, 17, 33, 63] {
        for &k in &[1usize, 3, 8, 17, 64] {
            for zero_rows in [false, true] {
                seed += 1;
                let (x, a) = random_problem(n, k, seed, zero_rows);
                let want = naive(&x, n, k, &a);
                let mut fast = SymPacked::zeros(k);
                rank_update_dense(&mut fast, &x, n, k, &a);
                assert_close(&fast, &want, &format!("dispatched n={n} k={k} z={zero_rows}"));
                let mut slow = SymPacked::zeros(k);
                rank_update_dense_scalar(&mut slow, &x, n, k, &a);
                assert_close(&slow, &want, &format!("scalar n={n} k={k} z={zero_rows}"));
            }
        }
    }
}

/// All-zero weights leave the accumulator untouched on every path.
#[test]
fn zero_weights_are_exact_noops() {
    let (n, k) = (13usize, 17usize);
    let (x, _) = random_problem(n, k, 9, false);
    let a = vec![0f32; n];
    let mut s = SymPacked::zeros(k);
    rank_update_dense(&mut s, &x, n, k, &a);
    assert!(s.data.iter().all(|&v| v == 0.0));
    let mut s2 = SymPacked::zeros(k);
    rank_update_dense_scalar(&mut s2, &x, n, k, &a);
    assert!(s2.data.iter().all(|&v| v == 0.0));
}

/// The sparse kernel agrees with the dense path run on densified rows.
#[test]
fn sparse_matches_densified() {
    let k = 23usize;
    let mut g = Pcg64::new(42);
    let mut packed_sparse = SymPacked::zeros(k);
    let mut packed_dense = SymPacked::zeros(k);
    for d in 0..40 {
        // random sorted subset of 5 indices
        let mut idx: Vec<u32> = Vec::new();
        let mut j = (g.next_f32() * 3.0) as u32;
        while (j as usize) < k && idx.len() < 5 {
            idx.push(j);
            j += 1 + (g.next_f32() * 5.0) as u32;
        }
        let val: Vec<f32> = idx.iter().map(|_| g.next_f32() * 2.0 - 1.0).collect();
        let a_d = g.next_f32() * (if d % 4 == 0 { 0.0 } else { 1.0 });
        rank_update_sparse(&mut packed_sparse, &idx, &val, a_d);
        let mut row = vec![0f32; k];
        for (p, &i) in idx.iter().enumerate() {
            row[i as usize] = val[p];
        }
        rank_update_dense(&mut packed_dense, &row, 1, k, &[a_d]);
    }
    assert_close(&packed_sparse, &packed_dense, "sparse vs densified");
}

/// pack -> merge -> unpack == add_assign on full matrices, exactly.
#[test]
fn packed_merge_roundtrips_against_mat() {
    for &k in &[1usize, 3, 8, 17, 64] {
        let mut g = Pcg64::new(k as u64 + 500);
        let mut ma = Mat::zeros(k, k);
        let mut mb = Mat::zeros(k, k);
        for i in 0..k {
            for j in 0..=i {
                let (va, vb) = (g.next_f32() - 0.5, g.next_f32() - 0.5);
                ma[(i, j)] = va;
                ma[(j, i)] = va;
                mb[(i, j)] = vb;
                mb[(j, i)] = vb;
            }
        }
        let mut pa = SymPacked::from_mat_lower(&ma);
        let pb = SymPacked::from_mat_lower(&mb);
        pa.add_assign(&pb);
        let mut want = ma.clone();
        want.add_assign(&mb);
        let got = pa.unpack();
        assert_eq!(got.data, want.data, "k={k}");
        // and packing the unpacked sum is lossless
        assert_eq!(SymPacked::from_mat_lower(&got), pa, "k={k} repack");
    }
}

/// Dispatched dot agrees with the scalar dot under tolerance, and
/// dispatched axpy is bit-identical to the scalar axpy (the serving
/// layer's bit-identity contract rides on the latter).
#[test]
fn dot_and_axpy_paths_agree() {
    for &len in &[0usize, 1, 3, 7, 8, 9, 17, 31, 32, 33, 64, 127, 250] {
        let mut g = Pcg64::new(len as u64 + 77);
        let a: Vec<f32> = (0..len).map(|_| g.next_f32() - 0.5).collect();
        let b: Vec<f32> = (0..len).map(|_| g.next_f32() - 0.5).collect();
        let want = dot_scalar(&a, &b);
        let got = dot(&a, &b);
        assert!(
            (got - want).abs() <= 1e-4 * (1.0 + want.abs()),
            "dot len={len}: {got} vs {want}"
        );
        let mut y1 = a.clone();
        let mut y2 = a.clone();
        axpy(0.731, &b, &mut y1);
        axpy_scalar(0.731, &b, &mut y2);
        assert_eq!(y1, y2, "axpy len={len}");
    }
}
