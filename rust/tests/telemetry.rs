//! Tier-1 tests for the telemetry layer (DESIGN.md §12): exactness of
//! the lock-free counters under contention, histogram bucket/merge
//! semantics, and the Prometheus text exposition.

use std::sync::Arc;

use pemsvm::telemetry::{
    Counter, Histogram, HistogramSnapshot, MetricRegistry, HIST_BUCKETS,
};

#[test]
fn concurrent_counter_increments_sum_exactly() {
    const THREADS: usize = 8;
    const PER_THREAD: u64 = 100_000;
    let c = Arc::new(Counter::new());
    let handles: Vec<_> = (0..THREADS)
        .map(|t| {
            let c = c.clone();
            std::thread::spawn(move || {
                // mix inc() and add() so both paths are exercised
                for i in 0..PER_THREAD {
                    if (i + t as u64) % 2 == 0 {
                        c.inc();
                    } else {
                        c.add(1);
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(c.get(), THREADS as u64 * PER_THREAD);
}

#[test]
fn snapshot_during_increment_loses_nothing() {
    // Reads racing writes must be monotone (per-cell coherence) and the
    // final read after join must be exact.
    const WRITERS: usize = 4;
    const PER_WRITER: u64 = 200_000;
    let c = Arc::new(Counter::new());
    let writers: Vec<_> = (0..WRITERS)
        .map(|_| {
            let c = c.clone();
            std::thread::spawn(move || {
                for _ in 0..PER_WRITER {
                    c.inc();
                }
            })
        })
        .collect();
    let reader = {
        let c = c.clone();
        std::thread::spawn(move || {
            let mut prev = 0u64;
            let target = WRITERS as u64 * PER_WRITER;
            while prev < target {
                let now = c.get();
                assert!(now >= prev, "counter regressed: {now} < {prev}");
                prev = now;
            }
            prev
        })
    };
    for w in writers {
        w.join().unwrap();
    }
    assert_eq!(reader.join().unwrap(), WRITERS as u64 * PER_WRITER);
    assert_eq!(c.get(), WRITERS as u64 * PER_WRITER);
}

#[test]
fn histogram_bucket_boundaries() {
    let h = Histogram::new();
    // bucket 0: exact zeros; bucket i: bit length i
    h.observe(0); // bucket 0
    h.observe(1); // bucket 1
    h.observe(2); // bucket 2
    h.observe(3); // bucket 2
    h.observe(4); // bucket 3
    h.observe(255); // bucket 8 (2^7 ..= 2^8 - 1)
    h.observe(256); // bucket 9
    h.observe(u64::MAX); // overflow bucket
    let s = h.snapshot();
    assert_eq!(s.buckets[0], 1);
    assert_eq!(s.buckets[1], 1);
    assert_eq!(s.buckets[2], 2);
    assert_eq!(s.buckets[3], 1);
    assert_eq!(s.buckets[8], 1);
    assert_eq!(s.buckets[9], 1);
    assert_eq!(s.buckets[HIST_BUCKETS - 1], 1);
    assert_eq!(s.count(), 8);
    // the running sum is a plain atomic add, wrapping past u64::MAX
    assert_eq!(s.sum, (1u64 + 2 + 3 + 4 + 255 + 256).wrapping_add(u64::MAX));
}

#[test]
fn histogram_merge_is_associative() {
    fn filled(values: &[u64]) -> HistogramSnapshot {
        let h = Histogram::new();
        for &v in values {
            h.observe(v);
        }
        h.snapshot()
    }
    let a = filled(&[0, 5, 17, 900]);
    let b = filled(&[1, 1, 1, 1 << 20]);
    let c = filled(&[3, 1 << 30, 42]);

    let mut left = a;
    left.merge(&b);
    left.merge(&c); // (a + b) + c

    let mut bc = b;
    bc.merge(&c);
    let mut right = a;
    right.merge(&bc); // a + (b + c)

    assert_eq!(left, right);
    assert_eq!(left.count(), 11);
}

/// Every non-comment, non-blank exposition line must look like
/// `name{labels} value` (or `name value`) with a u64 value — the same
/// shape the CI smoke's awk check enforces on a live `#metrics` scrape.
fn assert_parses_as_exposition(text: &str) {
    for line in text.lines() {
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (series, value) = line.rsplit_once(' ').unwrap_or_else(|| {
            panic!("exposition line without value: `{line}`");
        });
        value.parse::<u64>().unwrap_or_else(|_| {
            panic!("non-numeric value `{value}` in line `{line}`");
        });
        let name = series.split('{').next().unwrap();
        assert!(!name.is_empty(), "empty series name in `{line}`");
        assert!(
            name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_'),
            "bad metric name `{name}` in `{line}`"
        );
        if let Some(rest) = series.strip_prefix(name) {
            if !rest.is_empty() {
                assert!(
                    rest.starts_with('{') && rest.ends_with('}'),
                    "malformed label block in `{line}`"
                );
            }
        }
    }
}

#[test]
fn registry_renders_prometheus_text() {
    // a local (non-global) registry keeps this test independent of
    // series other tests create
    let reg = MetricRegistry::new();
    reg.counter("requests_total", "Total requests.").add(3);
    reg.gauge_labeled("resident_rows", &pemsvm::telemetry::label("stage", "ingest"), "Rows.")
        .set(7);
    let h = reg.histogram("latency_nanos", "Latency.");
    h.observe(100);
    h.observe(2000);

    let text = reg.render();
    assert_parses_as_exposition(&text);
    assert!(text.contains("# TYPE requests_total counter"), "{text}");
    assert!(text.contains("requests_total 3"), "{text}");
    assert!(text.contains("# TYPE resident_rows gauge"), "{text}");
    assert!(text.contains("resident_rows{stage=\"ingest\"} 7"), "{text}");
    // gauges expose their high-water mark as a sibling family
    assert!(text.contains("resident_rows_peak{stage=\"ingest\"} 7"), "{text}");
    assert!(text.contains("# TYPE latency_nanos histogram"), "{text}");
    assert!(text.contains("latency_nanos_bucket{le=\"+Inf\"} 2"), "{text}");
    assert!(text.contains("latency_nanos_sum 2100"), "{text}");
    assert!(text.contains("latency_nanos_count 2"), "{text}");
}

#[test]
fn reregistration_returns_the_same_cells() {
    let reg = MetricRegistry::new();
    let a = reg.counter("shared_total", "First registration.");
    a.add(5);
    // same name => same underlying series (this is what keeps serving
    // stats continuous across model hot reloads)
    let b = reg.counter("shared_total", "Second registration.");
    b.add(2);
    assert_eq!(a.get(), 7);
    assert!(Arc::ptr_eq(&a, &b));
}

#[test]
fn label_escaping() {
    assert_eq!(pemsvm::telemetry::label("model", "plain"), "model=\"plain\"");
    assert_eq!(pemsvm::telemetry::label("model", "a\"b"), "model=\"a\\\"b\"");
    assert_eq!(pemsvm::telemetry::label("model", "a\\b"), "model=\"a\\\\b\"");
    assert_eq!(pemsvm::telemetry::label("model", "a\nb"), "model=\"a\\nb\"");
}
