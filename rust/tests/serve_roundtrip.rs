//! Serving-subsystem integration tests: model persistence round-trips
//! (save -> load -> identical predictions) for Single, PerClass and
//! kernel models including the awkward cases, registry hot-reload, and
//! the TCP protocol end to end against the batched scorer.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use pemsvm::config::{KernelCfg, TaskKind, TrainConfig};
use pemsvm::data::{synth, Dataset, Task};
use pemsvm::linalg::Mat;
use pemsvm::model::Weights;
use pemsvm::serve::{self, ModelBody, ModelMeta, Registry, SavedModel, ServeOpts, Scorer};

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("pemsvm_serve_roundtrip");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn linear_model(task: TaskKind, body: Weights, k: usize, m: usize) -> SavedModel {
    SavedModel::new(
        ModelMeta {
            task,
            k,
            m,
            lambda: 0.5,
            options: "LIN-EM-CLS".into(),
            verdict: None,
            legacy: false,
        },
        ModelBody::Linear(body),
    )
}

/// Scores from a one-shot scorer run.
fn scores_of(model: &Arc<SavedModel>, ds: &Arc<Dataset>, workers: usize) -> Vec<f32> {
    Scorer::new(workers).score_batch(model, ds).unwrap().scores
}

#[test]
fn single_roundtrip_identical_predictions() {
    // awkward case included: the dataset's row 0 is empty (K=0 row)
    let ds = Arc::new(Dataset::sparse(
        vec![0, 0, 2, 3],
        vec![0, 2, 1],
        vec![0.25, -1.5, 3.0],
        vec![1.0, -1.0, 1.0],
        4,
        Task::Binary,
    ));
    let w = vec![0.1f32, -0.7, 1.0 / 3.0, 2.5e-8];
    let model = Arc::new(linear_model(TaskKind::Cls, Weights::Single(w), 4, 1));
    let p = tmp("single.model");
    serve::save(&model, &p).unwrap();
    let back = Arc::new(serve::load(&p).unwrap());
    assert_eq!(back.meta.k, 4);
    assert!(!back.meta.legacy);
    assert_eq!(back.meta.options, "LIN-EM-CLS");
    assert_eq!(scores_of(&model, &ds, 3), scores_of(&back, &ds, 3));
    // empty row scores exactly zero
    assert_eq!(scores_of(&back, &ds, 1)[0], 0.0);
}

#[test]
fn perclass_roundtrip_including_empty_class_block() {
    let ds = Arc::new(synth::mnist_like(150, 9, 4, 7));
    let mut w = Mat::zeros(4, 9);
    let mut g = pemsvm::rng::Pcg64::new(21);
    for x in w.data.iter_mut() {
        *x = g.next_f32() - 0.5;
    }
    // awkward case: one class block entirely zero
    w.row_mut(2).fill(0.0);
    let weights = Weights::PerClass(w);
    let acc_ref = pemsvm::model::evaluate(&ds, &weights);
    let model = Arc::new(linear_model(TaskKind::Mlt, weights, 9, 4));
    let p = tmp("perclass.model");
    serve::save(&model, &p).unwrap();
    let back = Arc::new(serve::load(&p).unwrap());
    assert_eq!((back.meta.m, back.meta.k), (4, 9));
    let scores = scores_of(&back, &ds, 4);
    assert_eq!(scores, scores_of(&model, &ds, 4));
    assert_eq!(serve::metric_of(TaskKind::Mlt, &ds.labels, &scores), acc_ref);
}

#[test]
fn zero_width_perclass_roundtrips() {
    // degenerate shape: m classes over zero features
    let model = linear_model(TaskKind::Mlt, Weights::PerClass(Mat::zeros(3, 0)), 0, 3);
    let p = tmp("zero_width.model");
    serve::save(&model, &p).unwrap();
    let back = serve::load(&p).unwrap();
    match &back.body {
        ModelBody::Linear(Weights::PerClass(w)) => assert_eq!((w.rows, w.cols), (3, 0)),
        _ => panic!("wrong body"),
    }
}

/// Train a tiny KRN model end to end, save it, and check the loaded
/// model reproduces `KernelModel::accuracy` exactly through the scorer
/// (the acceptance criterion for `pemsvm predict`).
#[test]
fn kernel_roundtrip_reproduces_accuracy_exactly() {
    let full = synth::news20_like(240, 40, 5);
    let (train, test) = synth::split(&full, 4);
    let mut cfg = TrainConfig::default().with_options("KRN-EM-CLS").unwrap();
    cfg.lambda = 1e-2;
    cfg.kernel = KernelCfg::Gaussian { sigma: 1.0 };
    cfg.workers = 2;
    cfg.max_iters = 15;
    let out = pemsvm::coordinator::train_full(&train, None, &cfg).unwrap();
    let saved = SavedModel::from_training(&cfg, train.k, out);
    let p = tmp("kernel.model");
    serve::save(&saved, &p).unwrap();
    let back = Arc::new(serve::load(&p).unwrap());
    let km = match &saved.body {
        ModelBody::Kernel(km) => km,
        _ => panic!("expected kernel body"),
    };
    let acc_ref = km.accuracy(&test);
    let test = Arc::new(test);
    let scores = scores_of(&back, &test, 4);
    // per-row decisions are bit-identical, not merely close
    for (j, &s) in scores.iter().enumerate() {
        assert_eq!(s, km.decision(&test, j), "row {j}");
    }
    assert_eq!(serve::metric_of(TaskKind::Cls, &test.labels, &scores), acc_ref);
    // and the scorer is deterministic across worker counts
    assert_eq!(scores, scores_of(&back, &test, 1));
}

#[test]
fn legacy_model_txt_still_loads() {
    let p = tmp("legacy.model");
    std::fs::write(&p, "# pemsvm single 3\n0.5\n-1.25\n2\n").unwrap();
    let back = serve::load(&p).unwrap();
    assert!(back.meta.legacy);
    match &back.body {
        ModelBody::Linear(Weights::Single(v)) => assert_eq!(v, &vec![0.5, -1.25, 2.0]),
        _ => panic!("wrong body"),
    }
    // count mismatch now rejected for `single` too (the old loader
    // only validated `perclass`)
    std::fs::write(&p, "# pemsvm single 5\n0.5\n-1.25\n2\n").unwrap();
    assert!(serve::load(&p).is_err());
}

#[test]
fn nan_rejected_at_load_for_every_body() {
    let p = tmp("nan_single.model");
    std::fs::write(
        &p,
        concat!(
            "pemsvm-model v1\ntask cls\nk 2\nm 1\nlambda 1\n",
            "options LIN-EM-CLS\nweights single 2\n1.0\nNaN\nend\n"
        ),
    )
    .unwrap();
    assert!(serve::load(&p).is_err());
    let p = tmp("nan_legacy.model");
    std::fs::write(&p, "# pemsvm single 2\n1.0\nNaN\n").unwrap();
    assert!(serve::load(&p).is_err());
    let p = tmp("inf_omega.model");
    std::fs::write(
        &p,
        concat!(
            "pemsvm-model v1\ntask cls\nk 2\nm 1\nlambda 1\noptions KRN-EM-CLS\n",
            "kernel gaussian 1\nsupport 1 2\nomega 1\ninf\n1 1:1\nend\n"
        ),
    )
    .unwrap();
    assert!(serve::load(&p).is_err());
}

#[test]
fn registry_hot_reload_keeps_in_flight_snapshot() {
    let reg = Registry::new();
    let p = tmp("reload.model");
    serve::save(&linear_model(TaskKind::Cls, Weights::Single(vec![1.0, 0.0]), 2, 1), &p).unwrap();
    let entry = reg.load_file("m", &p).unwrap();
    let snapshot = entry.current();
    serve::save(&linear_model(TaskKind::Cls, Weights::Single(vec![0.0, 1.0]), 2, 1), &p).unwrap();
    reg.load_file("m", &p).unwrap();
    assert_eq!(entry.version(), 2);
    let ds = Arc::new(Dataset::sparse(
        vec![0, 1],
        vec![0],
        vec![2.0],
        vec![1.0],
        2,
        Task::Binary,
    ));
    // old snapshot still scores with the old weights; fresh lookups see v2
    assert_eq!(scores_of(&snapshot, &ds, 1), vec![2.0]);
    assert_eq!(scores_of(&entry.current(), &ds, 1), vec![0.0]);
}

/// End-to-end TCP smoke: serve a trained model on an ephemeral port,
/// push rows through the newline protocol, and require byte-equal
/// agreement with the batch scorer path (what `pemsvm predict` runs).
#[test]
fn tcp_protocol_matches_batch_scorer() {
    let ds = synth::alpha_like(300, 12, 2);
    let mut cfg = TrainConfig::default().with_options("LIN-EM-CLS").unwrap();
    cfg.workers = 2;
    cfg.max_iters = 20;
    let out = pemsvm::coordinator::train_full(&ds, None, &cfg).unwrap();
    let saved = SavedModel::from_training(&cfg, ds.k, out);

    let registry = Arc::new(Registry::new());
    let entry = registry.publish("m", saved);

    // the rows exactly as they will travel over the wire; the expected
    // predictions come from the batch scorer on the same libsvm
    // round-trip the server performs, so agreement is bit-exact even
    // for dense-stored synthetic data
    let mut block = String::new();
    for d in 0..ds.n {
        block.push('1');
        ds.for_nonzero(d, |j, v| {
            block.push_str(&format!(" {}:{v}", j + 1));
        });
        block.push('\n');
    }
    let rows_path = tmp("tcp_rows.svm");
    std::fs::write(&rows_path, &block).unwrap();
    let rows_ds = Arc::new(pemsvm::data::libsvm::load(&rows_path, Task::Binary, 2).unwrap());
    let batch_scores = scores_of(&entry.current(), &rows_ds, 2);
    let expected: Vec<String> = batch_scores
        .iter()
        .map(|&s| serve::format_prediction(TaskKind::Cls, s))
        .collect();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let reg = registry.clone();
    std::thread::spawn(move || {
        let opts = ServeOpts {
            max_batch: 64,
            max_wait: Duration::from_micros(500),
            workers: 2,
        };
        let _ = serve::serve(listener, reg, "m".into(), opts);
    });

    let stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    // a malformed row first: the connection must survive it
    writer.write_all(b"1 notafeature\n").unwrap();
    writer.flush().unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("error:"), "got `{line}`");

    // then every dataset row as a libsvm line
    writer.write_all(block.as_bytes()).unwrap();
    writer.flush().unwrap();
    let mut got = Vec::with_capacity(ds.n);
    for _ in 0..ds.n {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        got.push(line.trim().to_string());
    }
    assert_eq!(got, expected);

    // the stats verb reports the traffic we just pushed
    writer.write_all(b"#stats\n").unwrap();
    writer.flush().unwrap();
    let mut stats = String::new();
    reader.read_line(&mut stats).unwrap();
    assert!(stats.starts_with("stats m:"), "got `{stats}`");
    assert!(stats.contains(" rows=300 "), "got `{stats}`");

    // the metrics verb returns the Prometheus exposition, terminated by
    // `# EOF`, and its request counter agrees with #stats
    writer.write_all(b"#metrics\n").unwrap();
    writer.flush().unwrap();
    let mut exposition = String::new();
    loop {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        if line.trim_end() == "# EOF" {
            break;
        }
        exposition.push_str(&line);
    }
    assert!(
        exposition.contains("predict_requests_total{model=\"m\"} 300"),
        "got:\n{exposition}"
    );
    assert!(exposition.contains("# TYPE predict_requests_total counter"));
}

/// A model unloaded while a connection is mid-stream answers further
/// rows on that connection with a structured error line instead of
/// scoring against the withdrawn model — and the connection survives.
#[test]
fn unload_mid_stream_yields_structured_errors() {
    // unique model name: telemetry series are process-global
    let name = "unload-mid-batch";
    let registry = Arc::new(Registry::new());
    registry.publish(name, linear_model(TaskKind::Cls, Weights::Single(vec![1.0, 0.0]), 2, 1));

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let reg = registry.clone();
    std::thread::spawn(move || {
        let opts =
            ServeOpts { max_batch: 8, max_wait: Duration::from_micros(500), workers: 1 };
        let _ = serve::serve(listener, reg, "unload-mid-batch".into(), opts);
    });

    let stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    // healthy rows score normally
    writer.write_all(b"1 1:2\n").unwrap();
    writer.flush().unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(!line.starts_with("error:"), "got `{line}`");

    // operator withdraws the model while the connection still holds it
    assert!(registry.unload(name));
    for _ in 0..3 {
        writer.write_all(b"1 1:2\n").unwrap();
    }
    writer.write_all(b"#stats\n").unwrap();
    writer.flush().unwrap();
    // every queued row answers with the structured unload error, in
    // order, and #stats gets the same treatment
    for _ in 0..4 {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert_eq!(
            line.trim(),
            "error: model `unload-mid-batch` unloaded",
            "connection must get a structured error after unload"
        );
    }
    // the connection is still alive: switch to a republished model
    registry.publish(name, linear_model(TaskKind::Cls, Weights::Single(vec![0.0, 1.0]), 2, 1));
    writer.write_all(b"#model unload-mid-batch\n1 1:2\n").unwrap();
    writer.flush().unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(!line.starts_with("error:"), "fresh entry scores again, got `{line}`");
}

/// Serving counters are keyed by model *name* in the global telemetry
/// registry, so they stay monotone across a hot reload mid-stream AND
/// across a full unload + republish (which allocates a new entry).
#[test]
fn stats_stay_monotone_across_mid_stream_reload() {
    // unique model name: telemetry series are process-global, and other
    // tests in this binary pin exact counts for their own names
    let name = "hotswap";
    let registry = Arc::new(Registry::new());
    registry.publish(name, linear_model(TaskKind::Cls, Weights::Single(vec![1.0, 0.0]), 2, 1));

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let reg = registry.clone();
    std::thread::spawn(move || {
        let opts =
            ServeOpts { max_batch: 8, max_wait: Duration::from_micros(500), workers: 1 };
        let _ = serve::serve(listener, reg, "hotswap".into(), opts);
    });

    let send_rows = |writer: &mut TcpStream, reader: &mut BufReader<TcpStream>, n: usize| {
        for _ in 0..n {
            writer.write_all(b"1 1:2\n").unwrap();
        }
        writer.flush().unwrap();
        for _ in 0..n {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            assert!(!line.trim().is_empty());
        }
    };
    let read_rows_stat = |writer: &mut TcpStream, reader: &mut BufReader<TcpStream>| -> String {
        writer.write_all(b"#stats\n").unwrap();
        writer.flush().unwrap();
        let mut stats = String::new();
        reader.read_line(&mut stats).unwrap();
        stats
    };

    let stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    send_rows(&mut writer, &mut reader, 10);
    assert!(read_rows_stat(&mut writer, &mut reader).contains(" rows=10 "));

    // hot reload mid-stream: same entry, new model Arc
    registry.publish(name, linear_model(TaskKind::Cls, Weights::Single(vec![0.0, 1.0]), 2, 1));
    send_rows(&mut writer, &mut reader, 10);
    assert!(read_rows_stat(&mut writer, &mut reader).contains(" rows=20 "));

    // full unload + republish: a brand-new entry under the same name,
    // reached through a brand-new connection
    assert!(registry.unload(name));
    registry.publish(name, linear_model(TaskKind::Cls, Weights::Single(vec![1.0, 1.0]), 2, 1));
    let stream2 = TcpStream::connect(addr).unwrap();
    stream2.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut writer2 = stream2.try_clone().unwrap();
    let mut reader2 = BufReader::new(stream2);
    send_rows(&mut writer2, &mut reader2, 10);
    let stats = read_rows_stat(&mut writer2, &mut reader2);
    assert!(stats.contains(" rows=30 "), "counts reset across republish: `{stats}`");
}
