//! The deterministic chaos harness (DESIGN.md §13): seeded fault plans
//! driven through the live engine, asserting the three fault-tolerance
//! guarantees end to end —
//!
//! 1. **Recoverable faults are invisible.** A straggler, a dropped
//!    reply, or a corrupt partial costs retries, never numerics: the EM
//!    trajectory is bit-identical to the fault-free run, on both
//!    topologies, for every task.
//! 2. **A worker death degrades, it does not derail.** The dead
//!    worker's rows are re-sharded onto survivors mid-session; the run
//!    terminates with a finite objective close to the fault-free one
//!    (only the f32 association order changed — the statistics are
//!    exact sums either way).
//! 3. **Resume is exact.** A run killed after a checkpoint and resumed
//!    on a fresh cluster finishes bit-identical to one that was never
//!    interrupted — EM and MC, including the sampler's RNG streams.
//!
//! Everything here is seeded: a failure reproduces with `cargo test
//! --test chaos` alone, no flaky-retry loop required.

use std::path::PathBuf;

use pemsvm::config::{Algo, TaskKind, Topology, TrainConfig};
use pemsvm::data::{synth, Dataset};
use pemsvm::engine::{
    CheckpointCfg, Cluster, FaultKind, FaultPlan, FaultStats, TrainOutput, WarmStart,
};
use pemsvm::model::Weights;

/// Small-but-nondegenerate config: tol < 0 disarms the stopping rule so
/// every run executes exactly `max_iters` iterations (fixed round
/// schedule for the fault plans), and the tight timeout makes injected
/// stragglers trip the leader's deadline in milliseconds, not minutes.
fn chaos_cfg(options: &str) -> TrainConfig {
    let mut cfg = TrainConfig::default().with_options(options).unwrap();
    cfg.workers = 3;
    cfg.max_iters = 6;
    cfg.tol = -1.0;
    cfg.num_classes = 3;
    cfg.step_timeout_ms = 150;
    cfg.step_retries = 2;
    cfg
}

fn dataset_for(task: TaskKind) -> Dataset {
    match task {
        TaskKind::Cls => synth::alpha_like(600, 10, 7),
        TaskKind::Svr => synth::year_like(600, 10, 7),
        TaskKind::Mlt => synth::mnist_like(600, 10, 3, 7),
    }
}

/// Flat view over either weight shape, for bit comparisons.
fn flat(w: &Weights) -> &[f32] {
    match w {
        Weights::Single(v) => v,
        Weights::PerClass(m) => &m.data,
    }
}

fn bits(w: &Weights) -> Vec<u32> {
    flat(w).iter().map(|x| x.to_bits()).collect()
}

/// The per-iteration trajectory, bit-for-bit (f64 objectives included).
fn history_bits(out: &TrainOutput) -> Vec<(usize, u64, u64)> {
    out.history
        .iter()
        .map(|h| (h.iter, h.objective.to_bits(), h.train_loss.to_bits()))
        .collect()
}

fn run_with_plan(ds: &Dataset, cfg: &TrainConfig, plan: FaultPlan) -> (TrainOutput, FaultStats) {
    let mut cl = Cluster::new_with_faults(ds, cfg, plan).unwrap();
    let out = cl.run_session(cfg, None, WarmStart::Cold).unwrap();
    let stats = cl.fault_counters();
    assert_eq!(cl.alive_workers() + stats.evictions as usize, cfg.workers);
    (out, stats)
}

fn ckpt_path(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("pemsvm_chaos_test");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{}_{}.ckpt", tag, std::process::id()))
}

/// Guarantee 1: every recoverable fault kind, on every task and both
/// topologies, leaves the EM trajectory bit-identical to the fault-free
/// run. Round 2 is the second broadcast: mid-flight for CLS/SVR, the
/// second class block of iteration 0 for MLT.
#[test]
fn recoverable_faults_leave_em_trajectories_bit_identical() {
    for task in [TaskKind::Cls, TaskKind::Svr, TaskKind::Mlt] {
        let ds = dataset_for(task);
        for topology in [Topology::Threads, Topology::Simulate] {
            let mut cfg = chaos_cfg("LIN-EM-CLS");
            cfg.task = task;
            cfg.topology = topology.clone();
            let (clean, cstats) = run_with_plan(&ds, &cfg, FaultPlan::none());
            assert_eq!(cstats.retries, 0);
            assert_eq!(cstats.evictions, 0);
            for kind in [
                FaultKind::DelayStep { millis: 300 },
                FaultKind::DropReply,
                FaultKind::CorruptStats,
            ] {
                let plan = FaultPlan::none().with(1, 2, kind);
                let (out, stats) = run_with_plan(&ds, &cfg, plan);
                let tag = format!("{task:?}/{topology:?}/{kind:?}");
                assert_eq!(stats.evictions, 0, "{tag}: recoverable fault must not evict");
                // a delayed step never misses a deadline in the serial
                // simulator — there is no wire to time out on
                let silent =
                    topology == Topology::Simulate && matches!(kind, FaultKind::DelayStep { .. });
                if !silent {
                    assert!(stats.retries >= 1, "{tag}: fault should have cost a retry");
                }
                assert_eq!(bits(&out.weights), bits(&clean.weights), "{tag}: weights drifted");
                assert_eq!(history_bits(&out), history_bits(&clean), "{tag}: history drifted");
            }
        }
    }
}

/// Guarantee 2: a worker death mid-session is survived. The run
/// terminates (no deadlock on the dead channel), exactly one eviction is
/// counted, the survivors adopt the orphaned rows, and the objective
/// stays finite and close to the fault-free run — re-sharding changes
/// only the f32 summation order of exact statistics.
#[test]
fn worker_death_evicts_and_run_completes() {
    for topology in [Topology::Threads, Topology::Simulate] {
        let ds = dataset_for(TaskKind::Cls);
        let mut cfg = chaos_cfg("LIN-EM-CLS");
        cfg.topology = topology.clone();
        let (clean, _) = run_with_plan(&ds, &cfg, FaultPlan::none());
        let plan = FaultPlan::none().with(2, 2, FaultKind::PanicAt);
        let (out, stats) = run_with_plan(&ds, &cfg, plan);
        assert_eq!(stats.evictions, 1, "{topology:?}");
        assert_eq!(out.iterations, cfg.max_iters, "{topology:?}: run cut short");
        assert!(out.objective.is_finite(), "{topology:?}");
        assert!(out.history.iter().all(|h| h.objective.is_finite()), "{topology:?}");
        assert!(flat(&out.weights).iter().all(|x| x.is_finite()), "{topology:?}");
        let rel = (out.objective - clean.objective).abs() / clean.objective.abs().max(1.0);
        assert!(
            rel < 5e-2,
            "{topology:?}: degraded objective {} too far from fault-free {}",
            out.objective,
            clean.objective
        );
    }
}

/// A dead worker also cannot corrupt checkpoint capture: the RNG
/// snapshot leaves the evicted slot `None` instead of hanging on the
/// dead channel, and an EM resume from such a checkpoint still works
/// (onto a fresh full-strength cluster).
#[test]
fn checkpoint_after_eviction_resumes_on_a_fresh_cluster() {
    let ds = dataset_for(TaskKind::Cls);
    let cfg = chaos_cfg("LIN-EM-CLS");
    let path = ckpt_path("postkill_em_cls");
    let mut half = cfg.clone();
    half.max_iters = 4;
    let plan = FaultPlan::none().with(0, 2, FaultKind::PanicAt);
    let mut cl = Cluster::new_with_faults(&ds, &half, plan).unwrap();
    let ck = CheckpointCfg { every: 4, path: path.clone(), resume: false };
    cl.run_session_checkpointed(&half, None, WarmStart::Cold, None, Some(&ck)).unwrap();
    assert_eq!(cl.fault_counters().evictions, 1);
    drop(cl);

    // resume twice on fresh, fault-free clusters: both continuations
    // must agree bit-for-bit (EM resume is deterministic)
    let ck = CheckpointCfg { every: 0, path: path.clone(), resume: true };
    let mut outs = Vec::new();
    for _ in 0..2 {
        let mut fresh = Cluster::new(&ds, &cfg).unwrap();
        let out = fresh
            .run_session_checkpointed(&cfg, None, WarmStart::Cold, None, Some(&ck))
            .unwrap();
        assert_eq!(fresh.fault_counters().evictions, 0);
        assert!(out.objective.is_finite());
        assert_eq!(out.history.first().map(|h| h.iter), Some(4), "resumed at iteration 4");
        outs.push(out);
    }
    assert_eq!(bits(&outs[0].weights), bits(&outs[1].weights));
    assert_eq!(history_bits(&outs[0]), history_bits(&outs[1]));
    let _ = std::fs::remove_file(&path);
}

/// Guarantee 3, the headline: kill-and-resume is **bit-identical** to an
/// uninterrupted run — for EM (CLS), for the MC sampler (SVR, where the
/// master *and* every worker consume RNG streams), and for the
/// multi-weight MLT driver.
#[test]
fn resume_after_interrupt_is_bit_identical() {
    for (options, task, burn_in) in [
        ("LIN-EM-CLS", TaskKind::Cls, 0usize),
        ("LIN-MC-SVR", TaskKind::Svr, 2),
        ("LIN-EM-MLT", TaskKind::Mlt, 0),
    ] {
        let ds = dataset_for(task);
        let mut cfg = chaos_cfg(options);
        cfg.max_iters = 8;
        cfg.burn_in = burn_in;

        // the uninterrupted twin
        let mut full = Cluster::new(&ds, &cfg).unwrap();
        let want = full.run_session(&cfg, None, WarmStart::Cold).unwrap();
        drop(full);

        // the interrupted run: killed right after the iteration-4
        // checkpoint (max_iters = 4 plays the part of `kill -9`)
        let path = ckpt_path(&format!("resume_{options}"));
        let mut half = cfg.clone();
        half.max_iters = 4;
        let ck = CheckpointCfg { every: 4, path: path.clone(), resume: false };
        let mut interrupted = Cluster::new(&ds, &half).unwrap();
        interrupted
            .run_session_checkpointed(&half, None, WarmStart::Cold, None, Some(&ck))
            .unwrap();
        drop(interrupted);

        // a fresh process's cluster picks the checkpoint up
        let ck = CheckpointCfg { every: 4, path: path.clone(), resume: true };
        let mut fresh = Cluster::new(&ds, &cfg).unwrap();
        let got = fresh
            .run_session_checkpointed(&cfg, None, WarmStart::Cold, None, Some(&ck))
            .unwrap();

        assert_eq!(
            got.history.first().map(|h| h.iter),
            Some(4),
            "{options}: resume did not start at the checkpoint"
        );
        assert_eq!(
            history_bits(&got),
            history_bits(&want)[4..].to_vec(),
            "{options}: resumed tail diverged from the uninterrupted run"
        );
        assert_eq!(
            bits(&got.weights),
            bits(&want.weights),
            "{options}: final weights are not bit-identical"
        );
        let _ = std::fs::remove_file(&path);
    }
}

/// A resume must refuse a checkpoint from a different configuration —
/// silently continuing someone else's run is worse than failing.
#[test]
fn resume_rejects_mismatched_config() {
    let ds = dataset_for(TaskKind::Cls);
    let mut cfg = chaos_cfg("LIN-EM-CLS");
    cfg.max_iters = 4;
    let path = ckpt_path("mismatch_em_cls");
    let ck = CheckpointCfg { every: 4, path: path.clone(), resume: false };
    let mut cl = Cluster::new(&ds, &cfg).unwrap();
    cl.run_session_checkpointed(&cfg, None, WarmStart::Cold, None, Some(&ck)).unwrap();
    drop(cl);

    let mut other = cfg.clone();
    other.lambda = 2.0; // fingerprint drift: lambda is bit-compared
    let ck = CheckpointCfg { every: 0, path: path.clone(), resume: true };
    let mut fresh = Cluster::new(&ds, &other).unwrap();
    let err = fresh
        .run_session_checkpointed(&other, None, WarmStart::Cold, None, Some(&ck))
        .unwrap_err();
    assert!(format!("{err:#}").contains("lambda"), "{err:#}");
    let _ = std::fs::remove_file(&path);
}

/// The MC sampler under recoverable chaos: retries re-draw worker noise,
/// so the trajectory legitimately differs from the fault-free one — the
/// guarantee is termination, finite objectives, and a model that still
/// learns (same bound the coordinator tests use for clean MC runs).
#[test]
fn mc_chaos_run_terminates_and_stays_finite() {
    let ds = dataset_for(TaskKind::Cls);
    let mut cfg = chaos_cfg("LIN-MC-CLS");
    cfg.burn_in = 2;
    let plan = FaultPlan::none()
        .with(0, 2, FaultKind::DropReply)
        .with(1, 3, FaultKind::DelayStep { millis: 300 })
        .with(2, 5, FaultKind::CorruptStats);
    let (out, stats) = run_with_plan(&ds, &cfg, plan);
    assert!(stats.retries >= 2);
    assert_eq!(stats.evictions, 0);
    assert_eq!(out.iterations, cfg.max_iters);
    assert!(out.history.iter().all(|h| h.objective.is_finite()));
    // short run (4 averaged samples), so a loose learning floor: the
    // point is that chaos did not wreck the model, not peak accuracy
    assert!(pemsvm::model::accuracy_cls(&ds, out.weights.single()) > 0.6);
}

/// The seeded sweep: random-but-reproducible fault schedules, the whole
/// point of [`FaultPlan::seeded`]. Every seed must terminate within the
/// fixed iteration budget with finite objectives; at most one worker is
/// ever killed by construction, so at least one survivor always remains.
#[test]
fn seeded_fault_sweep_terminates_with_finite_objectives() {
    for algo in [Algo::Em, Algo::Mc] {
        for seed in 1u64..=5 {
            let ds = dataset_for(TaskKind::Cls);
            let mut cfg = chaos_cfg("LIN-EM-CLS");
            cfg.algo = algo;
            cfg.burn_in = 2;
            // 6 iterations of CLS = broadcast rounds 1..=6 (plus
            // restarts); schedule across 12 so some faults also land on
            // post-eviction rounds
            let plan = FaultPlan::seeded(seed, cfg.workers, 12, 4);
            let mut cl = Cluster::new_with_faults(&ds, &cfg, plan).unwrap();
            let out = cl.run_session(&cfg, None, WarmStart::Cold).unwrap();
            let stats = cl.fault_counters();
            let tag = format!("{algo:?}/seed {seed}");
            assert!(cl.alive_workers() >= 1, "{tag}");
            assert!(stats.evictions <= 2, "{tag}: {stats:?}");
            assert_eq!(out.iterations, cfg.max_iters, "{tag}: run cut short");
            assert!(out.history.iter().all(|h| h.objective.is_finite()), "{tag}");
            assert!(flat(&out.weights).iter().all(|x| x.is_finite()), "{tag}");

            // determinism of the harness itself: the same seed replays
            // the same retry/eviction schedule
            let plan = FaultPlan::seeded(seed, cfg.workers, 12, 4);
            let mut cl2 = Cluster::new_with_faults(&ds, &cfg, plan).unwrap();
            let out2 = cl2.run_session(&cfg, None, WarmStart::Cold).unwrap();
            assert_eq!(cl2.fault_counters().evictions, stats.evictions, "{tag}");
            if algo == Algo::Em && stats.evictions == 0 {
                // no eviction and deterministic steps: full bit-equality
                assert_eq!(bits(&out2.weights), bits(&out.weights), "{tag}");
                assert_eq!(history_bits(&out2), history_bits(&out), "{tag}");
            }
        }
    }
}
