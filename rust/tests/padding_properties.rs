//! Property tests for the XLA shape-padding contract (DESIGN.md §3):
//! feature padding to the artifact family and row padding to CHUNK must
//! be *exact* — identical statistics, identical solutions — for any
//! (N, K) that isn't already family-aligned.

// the whole file targets the PJRT backend
#![cfg(feature = "xla")]

use std::sync::Arc;

use pemsvm::backend::{MasterBackend, StepInput, WorkerBackend};
use pemsvm::config::{Algo, TrainConfig};
use pemsvm::data::synth;

fn have_artifacts() -> bool {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json")
        .exists()
}

fn cfg() -> TrainConfig {
    let mut c = TrainConfig::default();
    c.artifacts_dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    c
}

/// Sweep awkward (N, K): chunk-misaligned rows, family-misaligned
/// features; padded XLA stats must match native stats on the true
/// coordinates and be exactly zero on the padding.
#[test]
fn padded_stats_equal_native_for_awkward_shapes() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let cfg = cfg();
    for (n, k, seed) in [(513usize, 17usize, 1u64), (1000, 63, 2), (511, 65, 3), (77, 5, 4)] {
        let ds = Arc::new(synth::alpha_like(n, k, seed));
        let w = Arc::new(vec![0.03f32; k]);
        let mut xw =
            pemsvm::backend::xla::XlaWorker::new(&cfg, &ds, 0..n, 0).unwrap();
        let mut nw = pemsvm::backend::native::NativeWorker::new(
            ds.clone(),
            0..n,
            Algo::Em,
            cfg.eps_clamp,
            0,
            0,
        );
        let sx = xw.step(&StepInput::Binary { w: w.clone() }).unwrap();
        let sn = nw.step(&StepInput::Binary { w }).unwrap();
        // packed sigma indexes symmetrically; no mirroring needed
        let pk = xw.stat_dim();
        let scale = sn.sigma.data.iter().fold(1f32, |a, &b| a.max(b.abs()));
        for i in 0..pk {
            for j in 0..pk {
                let want = if i < k && j < k { sn.sigma[(i, j)] } else { 0.0 };
                let got = sx.sigma[(i, j)];
                assert!(
                    (got - want).abs() < 2e-4 * scale,
                    "(n={n},k={k}) sigma[{i},{j}] {got} vs {want}"
                );
            }
        }
        for j in k..pk {
            assert_eq!(sx.mu[j], 0.0, "mu padding dirty at {j}");
        }
        assert!((sx.obj - sn.obj).abs() < 1e-3 * sn.obj.abs().max(1.0));
        assert_eq!(sx.aux, sn.aux, "(n={n},k={k}) error counts differ");
    }
}

/// The padded solve returns w with exact zeros on padded coordinates
/// and the native solution on the rest.
#[test]
fn padded_solve_zero_on_padding() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let cfg = cfg();
    let (n, k) = (600usize, 40usize);
    let ds = Arc::new(synth::alpha_like(n, k, 9));
    let w0 = Arc::new(vec![0f32; k]);
    let mut xw = pemsvm::backend::xla::XlaWorker::new(&cfg, &ds, 0..n, 0).unwrap();
    let mut stats = xw.step(&StepInput::Binary { w: w0 }).unwrap();
    let mut stats_native = stats.clone();
    let pk = xw.stat_dim();

    let mut xm = pemsvm::backend::xla::XlaMaster::new(&cfg, pk, None).unwrap();
    let wx = xm.solve(&mut stats, None).unwrap();
    for j in k..pk {
        assert!(
            wx[j].abs() < 1e-6,
            "padded weight {j} = {} should be ~0",
            wx[j]
        );
    }
    let mut nm = pemsvm::backend::native::NativeMaster::new(cfg.lambda, None);
    let wn = nm.solve(&mut stats_native, None).unwrap();
    for j in 0..k {
        assert!(
            (wx[j] - wn[j]).abs() < 2e-3 * (1.0 + wn[j].abs()),
            "w[{j}] {} vs {}",
            wx[j],
            wn[j]
        );
    }
}

/// Shard/chunk boundaries must not change the statistics: one worker
/// over [0, n) equals the merge of three workers over a 3-way split,
/// on the XLA backend (each worker pads its own tail chunk).
#[test]
fn chunking_is_invisible_in_the_reduce() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let cfg = cfg();
    let (n, k) = (1100usize, 24usize);
    let ds = Arc::new(synth::alpha_like(n, k, 5));
    let w = Arc::new(vec![0.05f32; k]);
    let whole = pemsvm::backend::xla::XlaWorker::new(&cfg, &ds, 0..n, 0)
        .unwrap()
        .step(&StepInput::Binary { w: w.clone() })
        .unwrap();
    let cuts = [0usize, 400, 900, n];
    let mut merged: Option<pemsvm::solver::PartialStats> = None;
    for wdw in cuts.windows(2) {
        let part = pemsvm::backend::xla::XlaWorker::new(&cfg, &ds, wdw[0]..wdw[1], 0)
            .unwrap()
            .step(&StepInput::Binary { w: w.clone() })
            .unwrap();
        match &mut merged {
            None => merged = Some(part),
            Some(m) => m.merge(&part),
        }
    }
    let merged = merged.unwrap();
    let scale = whole.sigma.data.iter().fold(1f32, |a, &b| a.max(b.abs()));
    assert!(whole.sigma.max_abs_diff(&merged.sigma) < 2e-4 * scale);
    assert!((whole.obj - merged.obj).abs() < 1e-6 * whole.obj.abs().max(1.0));
}
