//! Engine-level tests: cross-topology determinism, cluster reuse across
//! sessions, and warm starts.

use pemsvm::config::{ReduceKind, Topology, TrainConfig};
use pemsvm::coordinator::{train, TrainOutput};
use pemsvm::data::synth;
use pemsvm::engine::{Cluster, WarmStart};

fn base_cfg(options: &str) -> TrainConfig {
    let mut cfg = TrainConfig::default().with_options(options).unwrap();
    cfg.workers = 4;
    cfg.max_iters = 30;
    cfg
}

/// The full per-iteration trajectory, bit-for-bit.
fn history_sig(out: &TrainOutput) -> Vec<(usize, f64, f64, f64)> {
    out.history
        .iter()
        .map(|h| (h.iter, h.objective, h.train_loss, h.train_err))
        .collect()
}

/// The threaded pool and the sequential cluster simulator must produce
/// identical iteration histories for a fixed seed — for the flat reduce
/// (same fold order) and for the tree reduce, whose in-pool pair merges
/// use the same pairing order as the simulator's serial tree.
#[test]
fn threaded_and_simulated_histories_identical() {
    let ds = synth::alpha_like(1500, 12, 3);
    for reduce in [ReduceKind::Flat, ReduceKind::Tree] {
        let mut cfg_thr = base_cfg("LIN-EM-CLS");
        cfg_thr.reduce = reduce;
        cfg_thr.topology = Topology::Threads;
        let mut cfg_sim = cfg_thr.clone();
        cfg_sim.topology = Topology::Simulate;
        let a = train(&ds, &cfg_thr).unwrap();
        let b = train(&ds, &cfg_sim).unwrap();
        assert_eq!(history_sig(&a), history_sig(&b), "reduce={reduce:?}");
        assert_eq!(a.weights.single(), b.weights.single(), "reduce={reduce:?}");
    }
}

/// In-pool tree reduce vs leader-side flat fold: same sums up to f32
/// association error.
#[test]
fn in_pool_tree_matches_flat() {
    let ds = synth::alpha_like(2000, 10, 4);
    let mut cfg_flat = base_cfg("LIN-EM-CLS");
    cfg_flat.max_iters = 8;
    cfg_flat.reduce = ReduceKind::Flat;
    let mut cfg_tree = cfg_flat.clone();
    cfg_tree.reduce = ReduceKind::Tree;
    let a = train(&ds, &cfg_flat).unwrap();
    let b = train(&ds, &cfg_tree).unwrap();
    for (x, y) in a.weights.single().iter().zip(b.weights.single()) {
        assert!((x - y).abs() < 1e-3 * (1.0 + x.abs()), "{x} vs {y}");
    }
}

/// Two sessions on one live cluster — second with a different lambda —
/// must match two fresh `train()` calls exactly: reuse may not leak any
/// state between EM sessions.
#[test]
fn cluster_sessions_match_fresh_trains() {
    let ds = synth::alpha_like(2000, 10, 5);
    let cfg = base_cfg("LIN-EM-CLS");
    let mut cfg2 = cfg.clone();
    cfg2.lambda = 0.25;

    let mut cluster = Cluster::new(&ds, &cfg).unwrap();
    let s1 = cluster.run_session(&cfg, None, WarmStart::Cold).unwrap();
    let s2 = cluster.run_session(&cfg2, None, WarmStart::Cold).unwrap();
    assert_eq!(cluster.sessions(), 2);

    let f1 = train(&ds, &cfg).unwrap();
    let f2 = train(&ds, &cfg2).unwrap();
    assert_eq!(history_sig(&s1), history_sig(&f1));
    assert_eq!(history_sig(&s2), history_sig(&f2));
    assert_eq!(s1.weights.single(), f1.weights.single());
    assert_eq!(s2.weights.single(), f2.weights.single());
    assert_eq!(s1.metrics.sessions, 1);
}

/// A warm-started session (from the previous solution, at the same
/// lambda) must converge in fewer iterations than the cold one and land
/// at (or below) the same objective.
#[test]
fn warm_start_converges_in_fewer_iterations() {
    let ds = synth::alpha_like(3000, 16, 7);
    let mut cfg = base_cfg("LIN-EM-CLS");
    cfg.max_iters = 60;
    cfg.tol = 1e-4;
    let mut cluster = Cluster::new(&ds, &cfg).unwrap();
    let cold = cluster.run_session(&cfg, None, WarmStart::Cold).unwrap();
    let warm = cluster.run_session(&cfg, None, WarmStart::Last).unwrap();
    assert!(cold.iterations >= 5, "cold run converged suspiciously fast: {}", cold.iterations);
    assert!(
        warm.iterations < cold.iterations,
        "warm {} vs cold {} iterations",
        warm.iterations,
        cold.iterations
    );
    assert!(
        warm.objective <= cold.objective * 1.001,
        "warm J {} vs cold J {}",
        warm.objective,
        cold.objective
    );
}

/// The Crammer-Singer driver through the engine: sessions on one
/// cluster are reproducible against a fresh train, and a warm start
/// does not take longer than the cold solve.
#[test]
fn mlt_sessions_and_warm_start() {
    let ds = synth::mnist_like(1200, 12, 4, 9);
    let mut cfg = base_cfg("LIN-EM-MLT");
    cfg.num_classes = 4;
    cfg.max_iters = 15;
    let mut cluster = Cluster::new(&ds, &cfg).unwrap();
    let cold = cluster.run_session(&cfg, None, WarmStart::Cold).unwrap();
    let warm = cluster.run_session(&cfg, None, WarmStart::Last).unwrap();
    assert!(warm.iterations <= cold.iterations);

    let fresh = train(&ds, &cfg).unwrap();
    assert_eq!(history_sig(&cold), history_sig(&fresh));
    assert_eq!(cold.weights.per_class().data, fresh.weights.per_class().data);
}

/// Session configs that contradict what the cluster baked in at
/// construction (worker count, algo) are rejected, not silently run.
#[test]
fn incompatible_session_rejected() {
    let ds = synth::alpha_like(300, 8, 1);
    let cfg = base_cfg("LIN-EM-CLS");
    let mut cluster = Cluster::new(&ds, &cfg).unwrap();

    let mut bad_workers = cfg.clone();
    bad_workers.workers = 2;
    assert!(cluster.run_session(&bad_workers, None, WarmStart::Cold).is_err());

    let mut bad_algo = cfg.clone();
    bad_algo.algo = pemsvm::config::Algo::Mc;
    assert!(cluster.run_session(&bad_algo, None, WarmStart::Cold).is_err());

    // the cluster itself is still usable afterwards
    assert!(cluster.run_session(&cfg, None, WarmStart::Cold).is_ok());
}

/// WarmStart::Weights with mismatched shape fails loudly.
#[test]
fn warm_start_shape_mismatch_rejected() {
    let ds = synth::mnist_like(400, 8, 3, 2);
    let mut cfg = base_cfg("LIN-EM-MLT");
    cfg.num_classes = 3;
    cfg.max_iters = 5;
    let mut cluster = Cluster::new(&ds, &cfg).unwrap();
    let single = pemsvm::model::Weights::Single(vec![0.0; 8]);
    assert!(cluster.run_session(&cfg, None, WarmStart::Weights(&single)).is_err());
}
