//! Integration tests: the full leader/worker coordinator across option
//! combinations, backends, worker counts, and reduce topologies.

use pemsvm::config::{Algo, BackendKind, ReduceKind, TrainConfig};
use pemsvm::coordinator::{train, train_full};
use pemsvm::data::synth;
use pemsvm::model::Weights;

fn base_cfg(options: &str) -> TrainConfig {
    let mut cfg = TrainConfig::default().with_options(options).unwrap();
    cfg.max_iters = 40;
    cfg.workers = 4;
    cfg.artifacts_dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    cfg
}

fn have_artifacts() -> bool {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json")
        .exists()
}

#[test]
fn lin_em_cls_trains() {
    let ds = synth::alpha_like(4000, 24, 1);
    let out = train(&ds, &base_cfg("LIN-EM-CLS")).unwrap();
    let acc = pemsvm::model::evaluate(&ds, &out.weights);
    assert!(acc > 0.82, "accuracy {acc}");
    assert!(out.iterations >= 3);
    // EM objective is non-increasing after the first couple of iterations
    let objs: Vec<f64> = out.history.iter().map(|h| h.objective).collect();
    for w in objs[1..].windows(2) {
        assert!(w[1] <= w[0] + 1e-2 * w[0].abs(), "objective rose: {w:?}");
    }
}

#[test]
fn lin_mc_cls_trains_and_averages() {
    let ds = synth::alpha_like(3000, 16, 2);
    let mut cfg = base_cfg("LIN-MC-CLS");
    cfg.burn_in = 5;
    cfg.max_iters = 40;
    let out = train(&ds, &cfg).unwrap();
    let acc = pemsvm::model::evaluate(&ds, &out.weights);
    assert!(acc > 0.82, "accuracy {acc}");
}

#[test]
fn deterministic_for_fixed_seed_any_workers() {
    let ds = synth::alpha_like(1000, 12, 3);
    // EM is deterministic: same trajectory regardless of seed / P
    let mut w_ref: Option<Vec<f32>> = None;
    for p in [1usize, 2, 5, 8] {
        let mut cfg = base_cfg("LIN-EM-CLS");
        cfg.workers = p;
        cfg.max_iters = 10;
        let out = train(&ds, &cfg).unwrap();
        let w = out.weights.single().to_vec();
        match &w_ref {
            None => w_ref = Some(w),
            Some(r) => {
                for (a, b) in r.iter().zip(&w) {
                    assert!((a - b).abs() < 2e-2 * (1.0 + a.abs()), "P={p}: {a} vs {b}");
                }
            }
        }
    }
    // MC with the same seed and same P is bit-reproducible
    let mut cfg = base_cfg("LIN-MC-CLS");
    cfg.max_iters = 12;
    let o1 = train(&ds, &cfg).unwrap();
    let o2 = train(&ds, &cfg).unwrap();
    assert_eq!(o1.weights.single(), o2.weights.single());
}

#[test]
fn tree_and_flat_reduce_agree() {
    let ds = synth::alpha_like(2000, 16, 4);
    let mut cfg_flat = base_cfg("LIN-EM-CLS");
    cfg_flat.max_iters = 8;
    let mut cfg_tree = cfg_flat.clone();
    cfg_flat.reduce = ReduceKind::Flat;
    cfg_tree.reduce = ReduceKind::Tree;
    let a = train(&ds, &cfg_flat).unwrap();
    let b = train(&ds, &cfg_tree).unwrap();
    for (x, y) in a.weights.single().iter().zip(b.weights.single()) {
        assert!((x - y).abs() < 1e-3 * (1.0 + x.abs()), "{x} vs {y}");
    }
}

#[test]
fn svr_trains() {
    let ds = synth::year_like(4000, 16, 5);
    let mut cfg = base_cfg("LIN-EM-SVR");
    cfg.lambda = 0.1;
    cfg.eps_insensitive = 0.1;
    let out = train(&ds, &cfg).unwrap();
    let rmse = pemsvm::model::evaluate(&ds, &out.weights);
    assert!(rmse < 0.8, "rmse {rmse}");
}

#[test]
fn mlt_trains() {
    let ds = synth::mnist_like(2000, 16, 5, 6);
    let mut cfg = base_cfg("LIN-EM-MLT");
    cfg.num_classes = 5;
    cfg.max_iters = 15;
    let out = train(&ds, &cfg).unwrap();
    let acc = pemsvm::model::evaluate(&ds, &out.weights);
    assert!(acc > 0.8, "accuracy {acc}");
    assert!(matches!(out.weights, Weights::PerClass(_)));
}

#[test]
fn krn_solves_nonlinear_problem() {
    // concentric-ish classes: inner radius positive, outer negative
    let n = 240;
    let mut g = pemsvm::rng::Pcg64::new(7);
    let mut data = Vec::with_capacity(n * 2);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let y: f32 = if g.next_f64() < 0.5 { 1.0 } else { -1.0 };
        let r = if y > 0.0 { 0.5 } else { 1.6 };
        let theta = g.next_f64() * std::f64::consts::TAU;
        data.push(r * theta.cos() as f32 + 0.05 * (g.next_f32() - 0.5));
        data.push(r * theta.sin() as f32 + 0.05 * (g.next_f32() - 0.5));
        labels.push(y);
    }
    let ds = pemsvm::data::Dataset::dense(data, labels, 2, pemsvm::data::Task::Binary);
    let mut cfg = base_cfg("KRN-EM-CLS");
    cfg.lambda = 1e-2;
    cfg.kernel = pemsvm::config::KernelCfg::Gaussian { sigma: 0.5 };
    cfg.max_iters = 30;
    let out = train(&ds, &cfg).unwrap();
    let km = out.kernel_model.as_ref().unwrap();
    let acc = km.accuracy(&ds);
    assert!(acc > 0.95, "kernel accuracy {acc}");
}

#[test]
fn history_records_test_metric() {
    let ds = synth::alpha_like(2000, 12, 8);
    let (tr, te) = synth::split(&ds, 5);
    let mut cfg = base_cfg("LIN-EM-CLS");
    cfg.max_iters = 6;
    let out = train_full(&tr, Some(&te), &cfg).unwrap();
    assert!(out.history.iter().all(|h| h.test_metric.is_some()));
    let last = out.history.last().unwrap().test_metric.unwrap();
    assert!(last > 0.8, "test accuracy {last}");
}

#[test]
fn stopping_rule_halts_early() {
    let ds = synth::gaussian_margin(1500, 8, 9, 3.0, 0.0);
    let mut cfg = base_cfg("LIN-EM-CLS");
    cfg.max_iters = 200;
    cfg.tol = 1e-3;
    let out = train(&ds, &cfg).unwrap();
    assert!(out.iterations < 100, "did not stop early: {}", out.iterations);
}

#[test]
fn task_mismatch_rejected() {
    let ds = synth::year_like(100, 4, 1);
    assert!(train(&ds, &base_cfg("LIN-EM-CLS")).is_err());
}

// ---- XLA backend end-to-end (needs artifacts) --------------------------

#[test]
fn xla_backend_matches_native_em() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let ds = synth::alpha_like(1500, 16, 10);
    let mut cfg_n = base_cfg("LIN-EM-CLS");
    cfg_n.max_iters = 8;
    cfg_n.workers = 2;
    let mut cfg_x = cfg_n.clone();
    cfg_n.backend = BackendKind::Native;
    cfg_x.backend = BackendKind::Xla;
    let a = train(&ds, &cfg_n).unwrap();
    let b = train(&ds, &cfg_x).unwrap();
    let acc_a = pemsvm::model::evaluate(&ds, &a.weights);
    let acc_b = pemsvm::model::evaluate(&ds, &b.weights);
    assert!((acc_a - acc_b).abs() < 0.02, "native {acc_a} vs xla {acc_b}");
    for (x, y) in a.weights.single().iter().zip(b.weights.single()) {
        assert!((x - y).abs() < 5e-2 * (1.0 + x.abs()), "{x} vs {y}");
    }
}

#[test]
fn xla_backend_mlt() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let ds = synth::mnist_like(1200, 24, 5, 11);
    let mut cfg = base_cfg("LIN-EM-MLT");
    cfg.backend = BackendKind::Xla;
    cfg.num_classes = 5;
    cfg.workers = 2;
    cfg.max_iters = 8;
    let out = train(&ds, &cfg).unwrap();
    let acc = pemsvm::model::evaluate(&ds, &out.weights);
    assert!(acc > 0.75, "accuracy {acc}");
}

#[test]
fn xla_backend_svr_and_mc() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let ds = synth::year_like(1500, 12, 12);
    let mut cfg = base_cfg("LIN-EM-SVR");
    cfg.backend = BackendKind::Xla;
    cfg.lambda = 0.1;
    cfg.workers = 2;
    cfg.max_iters = 10;
    let out = train(&ds, &cfg).unwrap();
    let rmse = pemsvm::model::evaluate(&ds, &out.weights);
    assert!(rmse < 0.9, "rmse {rmse}");

    let ds2 = synth::alpha_like(1200, 16, 13);
    let mut cfg2 = base_cfg("LIN-MC-CLS");
    cfg2.backend = BackendKind::Xla;
    cfg2.burn_in = 4;
    cfg2.workers = 2;
    cfg2.max_iters = 16;
    let out2 = train(&ds2, &cfg2).unwrap();
    let acc = pemsvm::model::evaluate(&ds2, &out2.weights);
    assert!(acc > 0.8, "MC/XLA accuracy {acc}");
}

/// EM across both algos: MC's averaged solution lands near EM's optimum.
#[test]
fn mc_approaches_em_solution() {
    let ds = synth::alpha_like(2500, 10, 14);
    let mut cfg_em = base_cfg("LIN-EM-CLS");
    cfg_em.max_iters = 30;
    let em = train(&ds, &cfg_em).unwrap();
    let mut cfg_mc = base_cfg("LIN-MC-CLS");
    cfg_mc.max_iters = 60;
    cfg_mc.burn_in = 10;
    let mc = train(&ds, &cfg_mc).unwrap();
    let j_em = pemsvm::model::objective_cls(&ds, em.weights.single(), cfg_em.lambda);
    let j_mc = pemsvm::model::objective_cls(&ds, mc.weights.single(), cfg_mc.lambda);
    assert!(j_mc < 1.1 * j_em, "J_mc={j_mc} J_em={j_em}");
}
