//! Golden tests for the convergence diagnostics (DESIGN.md §14):
//! streaming estimators vs the brute-force [`reference`] pass on
//! chains with known behavior (AR(1), iid, stuck, two-regime), the
//! [`ChainDiag`] verdict logic end to end, and the engine wiring
//! (`diag_every` producing a session verdict).

use pemsvm::rng::Pcg64;
use pemsvm::telemetry::diag::{reference, LAGS};
use pemsvm::telemetry::{ChainDiag, HealthVerdict, IterObs, ScalarChain};

/// Approximately-normal noise (Irwin–Hall of 4 uniforms, centered).
fn noise(g: &mut Pcg64) -> f64 {
    (0..4).map(|_| g.next_f32() as f64).sum::<f64>() - 2.0
}

/// A seeded AR(1) chain `x_{t+1} = phi * x_t + e_t`.
fn ar1(phi: f64, n: usize, seed: u64) -> Vec<f64> {
    let mut g = Pcg64::new(seed);
    let mut x = 0.0f64;
    (0..n)
        .map(|_| {
            x = phi * x + noise(&mut g);
            x
        })
        .collect()
}

/// Push a series through a fresh [`ScalarChain`].
fn chain_of(xs: &[f64]) -> ScalarChain {
    let mut c = ScalarChain::new();
    for &x in xs {
        c.push(x);
    }
    c
}

#[test]
fn streaming_equals_brute_force_on_ar1_chains() {
    for (phi, seed) in [(0.9, 11u64), (0.5, 12), (0.0, 13)] {
        let xs = ar1(phi, 2_000, seed);
        let c = chain_of(&xs);
        assert!((c.mean() - reference::mean(&xs)).abs() < 1e-9, "phi={phi}");
        assert!((c.variance() - reference::variance(&xs)).abs() < 1e-9, "phi={phi}");
        for (i, &lag) in LAGS.iter().enumerate() {
            let want = reference::autocorr(&xs, lag);
            assert!(
                (c.autocorr_at(i) - want).abs() < 1e-9,
                "phi={phi} lag={lag}: streaming {} vs reference {want}",
                c.autocorr_at(i)
            );
        }
        assert!((c.tau() - reference::tau(&xs)).abs() < 1e-9, "phi={phi}");
        assert!((c.ess() - reference::ess(&xs)).abs() < 1e-6, "phi={phi}");
        assert!((c.mcse() - reference::mcse(&xs)).abs() < 1e-9, "phi={phi}");
        assert!((c.split_rhat() - reference::split_rhat(&xs)).abs() < 1e-12, "phi={phi}");
    }
}

#[test]
fn ar1_ess_lands_in_the_theoretical_band() {
    // ESS/n -> (1-phi)/(1+phi) for AR(1); the truncated power-of-two
    // trapezoid is an approximation, so assert a generous band around
    // the theoretical value rather than a point.
    let n = 4_000;
    for (phi, lo, hi) in [(0.9f64, 0.02, 0.12), (0.5, 0.15, 0.55)] {
        let xs = ar1(phi, n, 21);
        let frac = reference::ess(&xs) / n as f64;
        let theory = (1.0 - phi) / (1.0 + phi);
        assert!(
            frac > lo && frac < hi,
            "phi={phi}: ESS fraction {frac:.4} outside [{lo}, {hi}] (theory {theory:.4})"
        );
    }
}

#[test]
fn iid_chain_has_near_full_ess_and_unit_rhat() {
    let xs = ar1(0.0, 3_000, 31); // pure noise
    let n = xs.len() as f64;
    let ess = reference::ess(&xs);
    assert!(ess > 0.5 * n, "iid ESS {ess:.0} should be close to n={n}");
    let rhat = reference::split_rhat(&xs);
    assert!((rhat - 1.0).abs() < 0.05, "iid split-rhat {rhat:.4} should be ~1");
    // MCSE is sd/sqrt(ESS) by definition
    let want = reference::sd(&xs) / ess.sqrt();
    assert!((reference::mcse(&xs) - want).abs() < 1e-12);
}

#[test]
fn stuck_chain_is_one_effective_sample() {
    let xs = vec![3.75f64; 500];
    assert_eq!(reference::ess(&xs), 1.0);
    assert_eq!(reference::tau(&xs), 500.0);
    assert_eq!(reference::split_rhat(&xs), 1.0);
    let c = chain_of(&xs);
    assert_eq!(c.ess(), 1.0);
}

#[test]
fn two_regime_chain_fails_split_rhat() {
    // first half near 0, second half near 10: the halves disagree, so
    // split-R-hat blows well past the 1.5 threshold
    let mut g = Pcg64::new(41);
    let xs: Vec<f64> = (0..400)
        .map(|i| if i < 200 { 0.0 } else { 10.0 } + 0.1 * noise(&mut g))
        .collect();
    let rhat = reference::split_rhat(&xs);
    assert!(rhat > 1.5, "two-regime split-rhat {rhat:.3} should exceed 1.5");
    let c = chain_of(&xs);
    assert!((c.split_rhat() - rhat).abs() < 1e-12);
}

/// Feed a [`ChainDiag`] `n` synthetic iterations through a closure
/// producing `(objective, weights, weight_delta, step_max, step_mean)`.
fn drive(
    diag: &mut ChainDiag,
    n: usize,
    mut f: impl FnMut(usize) -> (f64, Vec<f32>, f64, f64, f64),
) {
    for i in 0..n {
        let (objective, weights, weight_delta, step_max, step_mean) = f(i);
        diag.observe(&IterObs {
            iter: i,
            objective,
            weights: &weights,
            weight_delta,
            step_max,
            step_mean,
        });
    }
}

#[test]
fn well_mixed_mc_run_is_healthy() {
    let mut g = Pcg64::new(51);
    let mut diag = ChainDiag::new_detached(true, 4, 8, 7);
    drive(&mut diag, 100, |_| {
        let w: Vec<f32> = (0..8).map(|_| g.next_f32() - 0.5).collect();
        (100.0 + noise(&mut g), w, 0.3, 1.1e-3, 1.0e-3)
    });
    let s = diag.snapshot();
    assert_eq!(s.verdict, HealthVerdict::Healthy, "snapshot: {s:?}");
    assert_eq!(s.iters, 100);
    assert_eq!(s.samples, 96, "burn_in=4 observations drop out of the chains");
    assert!(s.objective.ess > 16.0, "iid-ish objective should mix: {:?}", s.objective);
    assert!(s.objective.rhat < 1.5);
    assert!(diag.max_coord_variance() > 0.0, "the sampler is actually moving");
}

#[test]
fn exploding_objective_is_diverged_and_sticky() {
    let mut diag = ChainDiag::new_detached(true, 0, 4, 7);
    // settle near 1.0, then explode past 10x the best smoothed J
    drive(&mut diag, 40, |i| {
        let j = if i < 20 { 1.0 + 0.01 * i as f64 } else { 1e6 };
        (j, vec![0.1, 0.2, 0.3, 0.4], 0.1, 1e-3, 1e-3)
    });
    assert_eq!(diag.summary().verdict, HealthVerdict::Diverged);
    // sticky: recovering afterwards does not clear the verdict
    drive(&mut diag, 30, |_| (1.0, vec![0.1, 0.2, 0.3, 0.4], 0.1, 1e-3, 1e-3));
    assert_eq!(diag.summary().verdict, HealthVerdict::Diverged);
}

#[test]
fn non_finite_objective_is_diverged() {
    let mut diag = ChainDiag::new_detached(true, 0, 2, 7);
    drive(&mut diag, 3, |i| {
        let j = if i == 2 { f64::NAN } else { 5.0 };
        (j, vec![0.1, 0.2], 0.1, 1e-3, 1e-3)
    });
    assert_eq!(diag.summary().verdict, HealthVerdict::Diverged);
}

#[test]
fn frozen_em_run_is_stalled() {
    // EM battery (mc=false): identical objective and weights for many
    // iterations with the stopping rule not firing => Stalled
    let mut diag = ChainDiag::new_detached(false, 0, 4, 7);
    drive(&mut diag, 12, |_| (42.0, vec![1.0, 2.0, 3.0, 4.0], 0.0, 1e-3, 1e-3));
    assert_eq!(diag.summary().verdict, HealthVerdict::Stalled);
}

#[test]
fn em_battery_skips_mixing_criteria() {
    // a slowly-drifting EM objective has lag-1 autocorrelation ~1, but
    // EM is a deterministic fixed point iteration, not a chain — the
    // mixing thresholds must not apply
    let mut diag = ChainDiag::new_detached(false, 0, 4, 7);
    drive(&mut diag, 100, |i| {
        (1000.0 - i as f64, vec![0.1 * i as f32, 1.0, 1.0, 1.0], 0.5, 1e-3, 1e-3)
    });
    assert_eq!(diag.summary().verdict, HealthVerdict::Healthy);
}

#[test]
fn high_autocorrelation_mc_chain_is_mixing_slow() {
    // the same drifting objective under the MC battery: lag-1 of a
    // 200-long ramp is ~0.99 > 0.98, and ESS collapses
    let mut diag = ChainDiag::new_detached(true, 0, 4, 7);
    drive(&mut diag, 200, |i| {
        (1000.0 - i as f64, vec![0.1 * i as f32, 1.0, 1.0, 1.0], 0.5, 1e-3, 1e-3)
    });
    assert_eq!(diag.summary().verdict, HealthVerdict::MixingSlow);
}

#[test]
fn straggler_skew_flags_mixing_slow() {
    let mut g = Pcg64::new(61);
    let mut diag = ChainDiag::new_detached(false, 0, 4, 7);
    // healthy objective, but one worker is 10x slower than the mean
    drive(&mut diag, 20, |_| {
        let w: Vec<f32> = (0..4).map(|_| g.next_f32()).collect();
        (50.0 + noise(&mut g), w, 0.3, 10.0e-3, 1.0e-3)
    });
    assert_eq!(diag.summary().verdict, HealthVerdict::MixingSlow);
}

#[test]
fn engine_session_produces_a_verdict_only_when_asked() {
    use pemsvm::config::TrainConfig;
    use pemsvm::data::synth;

    let ds = synth::alpha_like(400, 16, 0);
    let mut cfg = TrainConfig::default().with_options("LIN-MC-CLS").unwrap();
    cfg.workers = 2;
    cfg.max_iters = 40;
    cfg.burn_in = 5;
    cfg.seed = 3;

    // default: diagnostics off, no verdict, output unchanged
    let out = pemsvm::coordinator::train(&ds, &cfg).unwrap();
    assert!(out.verdict.is_none());

    // --diag-every 1: a session verdict appears, weights unchanged
    let mut dcfg = cfg.clone();
    dcfg.diag_every = 1;
    let dout = pemsvm::coordinator::train(&ds, &dcfg).unwrap();
    assert!(dout.verdict.is_some());
    assert_eq!(
        out.weights.single(),
        dout.weights.single(),
        "diagnostics are observer-only: the trained weights must be bit-identical"
    );
}
