//! SVR on a YearPredictionMSD-like regression problem — the paper's
//! §5.10 experiment: LIN-EM-SVR vs the liblinear-style SVR baseline.
//!
//!   cargo run --release --example svr_year

use pemsvm::baselines::svr_dcd;
use pemsvm::config::TrainConfig;
use pemsvm::data::synth;
use pemsvm::model::rmse;

fn main() -> anyhow::Result<()> {
    // year: N=250k higher for bench; example keeps it laptop-fast
    let ds = synth::year_like(50_000, 90, 0);
    let (tr, te) = synth::split(&ds, 5);
    println!("year-like: N={} K={} (paper: 250k x 90)", tr.n, tr.k);
    let eps = 0.3; // paper §5.10 sets epsilon = 0.3

    // LIN-EM-SVR, parallel
    let mut cfg = TrainConfig::default().with_options("LIN-EM-SVR")?;
    cfg.lambda = 0.01;
    cfg.eps_insensitive = eps;
    cfg.workers = 8;
    cfg.max_iters = 60;
    let t0 = std::time::Instant::now();
    let out = pemsvm::coordinator::train(&tr, &cfg)?;
    let t_pem = t0.elapsed().as_secs_f64();
    let rmse_pem = rmse(&te, out.weights.single());

    // LL-Dual-style SVR baseline (single thread)
    let t0 = std::time::Instant::now();
    let w_dcd = svr_dcd::train(
        &tr,
        &svr_dcd::SvrDcdCfg { lambda: 0.01, eps_insensitive: eps, ..Default::default() },
    );
    let t_dcd = t0.elapsed().as_secs_f64();
    let rmse_dcd = rmse(&te, &w_dcd);

    println!("solver         cores  train     test-RMSE");
    println!("LIN-EM-SVR     {:>5}  {:>7.2}s  {rmse_pem:.3}", cfg.workers, t_pem);
    println!("SVR-DCD (LL)   {:>5}  {:>7.2}s  {rmse_dcd:.3}", 1, t_dcd);
    Ok(())
}
