//! Kernel SVM on a news20-like subset — the paper's §5.11 experiment:
//! KRN-EM-CLS with a Gaussian kernel, training time independent of K.
//!
//!   cargo run --release --example kernel_news20

use pemsvm::baselines::dcd;
use pemsvm::config::{KernelCfg, TrainConfig};
use pemsvm::data::synth;

fn main() -> anyhow::Result<()> {
    // paper: N = 1800 subset of news20
    let ds = synth::news20_like(1800, 600, 0);
    let (tr, te) = synth::split(&ds, 5);
    println!("news20-like: N={} K={} density={:.3}", tr.n, tr.k, tr.density());

    let mut cfg = TrainConfig::default().with_options("KRN-EM-CLS")?;
    cfg.lambda = 1e-2;
    cfg.kernel = KernelCfg::Gaussian { sigma: 1.0 };
    cfg.workers = 8;
    cfg.max_iters = 40;
    let t0 = std::time::Instant::now();
    let out = pemsvm::coordinator::train_full(&tr, Some(&te), &cfg)?;
    let t_krn = t0.elapsed().as_secs_f64();
    let km = out.kernel_model.as_ref().unwrap();
    let acc_krn = km.accuracy(&te);

    // linear baseline for reference (LL-Dual)
    let t0 = std::time::Instant::now();
    let lin = dcd::train(&tr, &dcd::DcdCfg { lambda: 1e-2, ..Default::default() });
    let t_lin = t0.elapsed().as_secs_f64();
    let acc_lin = pemsvm::model::accuracy_cls(&te, &lin.w);

    println!("solver        cores  train     test-acc");
    println!("KRN-EM-CLS    {:>5}  {:>7.2}s  {:.4}", cfg.workers, t_krn, acc_krn);
    println!("LL-Dual(lin)  {:>5}  {:>7.2}s  {:.4}", 1, t_lin, acc_lin);
    Ok(())
}
