//! Quickstart: train the parallel sampling SVM on a synthetic binary
//! problem and compare EM vs MC and 1 vs P workers.
//!
//!   cargo run --release --example quickstart

use pemsvm::config::TrainConfig;
use pemsvm::data::synth;

fn main() -> anyhow::Result<()> {
    // an alpha-like dense binary problem (paper Table 3 signature)
    let ds = synth::alpha_like(20_000, 64, 0);
    let (train_set, test_set) = synth::split(&ds, 5);
    println!(
        "dataset: N={} K={} (train {}, test {})",
        ds.n, ds.k, train_set.n, test_set.n
    );

    for (options, workers) in [("LIN-EM-CLS", 1), ("LIN-EM-CLS", 8), ("LIN-MC-CLS", 8)] {
        let mut cfg = TrainConfig::default().with_options(options)?;
        cfg.workers = workers;
        cfg.lambda = 1.0;
        cfg.max_iters = 60;
        let t0 = std::time::Instant::now();
        let out = pemsvm::coordinator::train_full(&train_set, Some(&test_set), &cfg)?;
        let secs = t0.elapsed().as_secs_f64();
        let test_acc = pemsvm::model::evaluate(&test_set, &out.weights);
        println!(
            "{options} P={workers}: {:.2}s, {} iters, J={:.1}, test acc {:.4}",
            secs, out.iterations, out.objective, test_acc
        );
    }
    Ok(())
}
