//! End-to-end driver: proves all three layers compose on a real(istic)
//! workload, exercising the paper's headline claim (Table 5 shape):
//! the parallel sampling SVM beats single-thread state-of-the-art
//! solvers once cores are available, at equal accuracy.
//!
//! Pipeline: generate a dna-like corpus -> write it to a libsvm file ->
//! parallel-load (I/O parallelism, §5.6) -> train LIN-EM-CLS with
//! P = 1 and P = all-cores on the native backend *and* on the
//! XLA/PJRT backend (Pallas Sigma kernel inside the loaded HLO) ->
//! evaluate held-out accuracy -> compare against Pegasos / LL-Dual /
//! LL-Primal -> print the table and the objective curve.
//!
//!   cargo run --release --example end_to_end [N] [K]
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use std::path::Path;

use pemsvm::baselines::{dcd, pegasos, primal_newton};
use pemsvm::config::{BackendKind, Topology, TrainConfig};
use pemsvm::data::{libsvm, synth, Task};
use pemsvm::metrics::Stopwatch;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(100_000);
    let k: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(200);
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(4);
    let lambda = 1.0f32;

    // ---- stage 1: corpus on disk ---------------------------------------
    let dir = std::env::temp_dir().join("pemsvm_e2e");
    std::fs::create_dir_all(&dir)?;
    let train_path = dir.join("dna_train.svm");
    let test_path = dir.join("dna_test.svm");
    let sw = Stopwatch::start();
    let full = synth::dna_like(n + n / 5, k, 0);
    let (tr, te) = synth::split(&full, 6);
    libsvm::save(&tr, &train_path)?;
    libsvm::save(&te, &test_path)?;
    println!("[1] corpus: N={} K={} -> {} ({:.1}s)", tr.n, tr.k, train_path.display(), sw.secs());

    // ---- stage 2: parallel load (§5.6) ----------------------------------
    let sw = Stopwatch::start();
    let tr1 = libsvm::load(&train_path, Task::Binary, 1)?;
    let t_load1 = sw.secs();
    let sw = Stopwatch::start();
    let trp = libsvm::load(&train_path, Task::Binary, cores)?;
    let t_loadp = sw.secs();
    let te = libsvm::load(&test_path, Task::Binary, cores)?;
    println!("[2] load: 1 thread {t_load1:.2}s, {cores} threads {t_loadp:.2}s ({:.1}x)", t_load1 / t_loadp);
    drop(tr1);

    // ---- stage 3: train all solvers -------------------------------------
    println!("[3] training (lambda = {lambda}, C = {}):", 2.0 / lambda);
    println!("    solver          P      train      acc%");
    let mut rows: Vec<(String, usize, f64, f64)> = Vec::new();

    // single worker, real wall-clock
    let curve: Vec<(usize, f64)>;
    {
        let mut cfg = TrainConfig::default().with_options("LIN-EM-CLS")?;
        cfg.lambda = lambda;
        cfg.workers = 1;
        cfg.max_iters = 60;
        let sw = Stopwatch::start();
        let out = pemsvm::coordinator::train(&trp, &cfg)?;
        let secs = sw.secs();
        let acc = pemsvm::model::evaluate(&te, &out.weights) * 100.0;
        rows.push(("LIN-EM-CLS".into(), 1, secs, acc));
        println!("    LIN-EM-CLS      1   {secs:>7.2}s   {acc:.2}");
        curve = out.history.iter().map(|h| (h.iter, h.objective)).collect();
    }
    // P workers. With >= P physical cores this is real parallel wall
    // clock; on smaller boxes the engine's cluster cost model
    // (Topology::Simulate) reports max-worker time per iteration instead
    // (DESIGN.md §6 cluster substitution).
    let p_par = 8.max(cores);
    {
        let mut cfg = TrainConfig::default().with_options("LIN-EM-CLS")?;
        cfg.lambda = lambda;
        cfg.workers = p_par;
        cfg.topology =
            if cores < p_par { Topology::Simulate } else { Topology::Threads };
        cfg.max_iters = 60;
        let out = pemsvm::coordinator::train(&trp, &cfg)?;
        let secs = out.metrics.simulated_secs();
        let acc = pemsvm::model::evaluate(&te, &out.weights) * 100.0;
        rows.push(("LIN-EM-CLS".into(), p_par, secs, acc));
        println!(
            "    LIN-EM-CLS    {p_par:>3}   {secs:>7.2}s   {acc:.2}{}",
            if cfg.topology == Topology::Simulate { "  (cluster cost model)" } else { "" }
        );
    }

    // XLA backend (the paper's accelerator path) if artifacts are built
    if Path::new("artifacts/manifest.json").exists() {
        let mut cfg = TrainConfig::default().with_options("LIN-EM-CLS")?;
        cfg.lambda = lambda;
        cfg.workers = cores.min(4);
        cfg.backend = BackendKind::Xla;
        cfg.max_iters = 60;
        let sw = Stopwatch::start();
        let out = pemsvm::coordinator::train(&trp, &cfg)?;
        let secs = sw.secs();
        let acc = pemsvm::model::evaluate(&te, &out.weights) * 100.0;
        rows.push(("LIN-EM-CLS/XLA".into(), cfg.workers, secs, acc));
        println!("    LIN-EM-CLS/XLA{:>3}   {secs:>7.2}s   {acc:.2}  (Pallas Sigma kernel)", cfg.workers);
    } else {
        println!("    (artifacts/ missing -- run `make artifacts` for the XLA row)");
    }

    let sw = Stopwatch::start();
    let w = pegasos::train(&trp, &pegasos::PegasosCfg { lambda, epochs: 20, ..Default::default() });
    let (s, a) = (sw.secs(), pemsvm::model::accuracy_cls(&te, &w) * 100.0);
    rows.push(("Pegasos".into(), 1, s, a));
    println!("    Pegasos         1   {s:>7.2}s   {a:.2}");

    let sw = Stopwatch::start();
    let out = dcd::train(&trp, &dcd::DcdCfg { lambda, ..Default::default() });
    let (s, a) = (sw.secs(), pemsvm::model::accuracy_cls(&te, &out.w) * 100.0);
    rows.push(("LL-Dual".into(), 1, s, a));
    println!("    LL-Dual         1   {s:>7.2}s   {a:.2}");

    let sw = Stopwatch::start();
    let w = primal_newton::train(&trp, &primal_newton::PrimalNewtonCfg { lambda, ..Default::default() });
    let (s, a) = (sw.secs(), pemsvm::model::accuracy_cls(&te, &w) * 100.0);
    rows.push(("LL-Primal".into(), 1, s, a));
    println!("    LL-Primal       1   {s:>7.2}s   {a:.2}");

    // ---- stage 4: headline ----------------------------------------------
    let pem_par = rows.iter().find(|r| r.0 == "LIN-EM-CLS" && r.1 > 1).unwrap();
    let pem_one = rows.iter().find(|r| r.0 == "LIN-EM-CLS" && r.1 == 1).unwrap();
    let best_base = rows
        .iter()
        .filter(|r| !r.0.starts_with("LIN-EM"))
        .min_by(|a, b| a.2.total_cmp(&b.2))
        .unwrap();
    println!("\n[4] headline:");
    println!(
        "    self-speedup P={}: {:.1}x   vs best single-thread baseline ({}): {:.2}x",
        pem_par.1,
        pem_one.2 / pem_par.2,
        best_base.0,
        best_base.2 / pem_par.2
    );
    println!("    objective curve: first {:.1} -> last {:.1} over {} iters",
        curve.first().map(|c| c.1).unwrap_or(f64::NAN),
        curve.last().map(|c| c.1).unwrap_or(f64::NAN),
        curve.len()
    );
    for (it, j) in curve.iter().step_by(curve.len().div_ceil(12).max(1)) {
        println!("      iter {it:>3}  J = {j:.1}");
    }
    Ok(())
}
