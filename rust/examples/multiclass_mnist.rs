//! Crammer-Singer multiclass on an mnist8m-like problem — the paper's
//! §5.12 experiment: parallel LIN-MC-MLT vs the LL-CS baseline.
//!
//!   cargo run --release --example multiclass_mnist

use pemsvm::baselines::cs_dcd;
use pemsvm::config::TrainConfig;
use pemsvm::data::synth;

fn main() -> anyhow::Result<()> {
    let m = 10;
    let ds = synth::mnist_like(20_000, 96, m, 0);
    let (tr, te) = synth::split(&ds, 5);
    println!("mnist8m-like: N={} K={} M={m}", tr.n, tr.k);

    // parallel sampling solver (paper uses MC for Crammer-Singer, §5.13)
    let mut cfg = TrainConfig::default().with_options("LIN-MC-MLT")?;
    cfg.num_classes = m;
    cfg.lambda = 1.0;
    cfg.workers = 8;
    cfg.burn_in = 5;
    cfg.max_iters = 25;
    let t0 = std::time::Instant::now();
    let out = pemsvm::coordinator::train(&tr, &cfg)?;
    let t_pem = t0.elapsed().as_secs_f64();
    let acc_pem = pemsvm::model::evaluate(&te, &out.weights);

    // LL-CS baseline
    let t0 = std::time::Instant::now();
    let w_cs = cs_dcd::train(&tr, m, &cs_dcd::CsDcdCfg { lambda: 1.0, ..Default::default() });
    let t_cs = t0.elapsed().as_secs_f64();
    let acc_cs = pemsvm::model::accuracy_mlt(&te, &w_cs);

    println!("solver        cores  train     test-acc");
    println!("LIN-MC-MLT    {:>5}  {:>7.2}s  {:.4}", cfg.workers, t_pem, acc_pem);
    println!("LL-CS         {:>5}  {:>7.2}s  {:.4}", 1, t_cs, acc_cs);
    Ok(())
}
