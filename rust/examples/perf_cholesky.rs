//! §Perf microbench: the master-solve Cholesky factorization.
use pemsvm::linalg::{cholesky_in_place, Mat};
use pemsvm::rng::Pcg64;
fn main() {
    for k in [256usize, 512, 800, 1024] {
        let mut g = Pcg64::new(1);
        let mut b = Mat::zeros(k, 2 * k);
        for v in b.data.iter_mut() { *v = g.next_f32() - 0.5; }
        let mut a = Mat::zeros(k, k);
        for i in 0..k { for j in 0..=i { a[(i,j)] = pemsvm::linalg::dot(b.row(i), b.row(j)); a[(j,i)] = a[(i,j)]; } }
        a.add_scaled_eye(1.0);
        let reps = 3;
        let mut copies: Vec<Mat> = (0..reps).map(|_| a.clone()).collect();
        let t0 = std::time::Instant::now();
        for c in copies.iter_mut() { cholesky_in_place(c).unwrap(); }
        let t = t0.elapsed().as_secs_f64() / reps as f64;
        println!("K={k:<5} {:.4}s  {:.2} GFLOP/s", t, (k as f64).powi(3)/3.0 / t / 1e9);
    }
}
