//! PCG-XSL-RR 128/64: O'Neill's PCG64. 128-bit LCG state, 64-bit output
//! via xor-fold + random rotation. Small, fast, and good enough that the
//! MC sampler's mixing is limited by the chain, not the generator.

const MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

/// PCG64 generator. One instance per worker thread (not `Sync`; cheap to
/// clone for checkpointing).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128, // must be odd
}

impl Pcg64 {
    /// Default stream.
    pub fn new(seed: u64) -> Self {
        Self::new_stream(seed, 0)
    }

    /// Independent stream selected by `stream` (distinct increments give
    /// statistically independent sequences in the PCG family).
    pub fn new_stream(seed: u64, stream: u64) -> Self {
        let seq = ((stream as u128) << 64) | 0xda3e_39cb_94b9_5bdb;
        let mut g = Pcg64 { state: 0, inc: (seq << 1) | 1 };
        g.state = g.inc.wrapping_add(seed as u128);
        g.next_u64();
        // extra scramble so seed=0/stream=0 doesn't start near the fixed point
        g.state = g.state.wrapping_add((seed as u128) << 64);
        g.next_u64();
        g
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in the open interval (0, 1): never exactly 0 or 1, safe to
    /// feed to log/division in samplers.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (((self.next_u64() >> 11) as f64) + 0.5) * (1.0 / 9_007_199_254_740_992.0)
    }

    /// Uniform f32 in (0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (((self.next_u64() >> 40) as f32) + 0.5) * (1.0 / 16_777_216.0)
    }

    /// Uniform integer in [0, n) by Lemire reduction (unbiased enough for
    /// shuffles; n is tiny relative to 2^64).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Raw `(state, inc)` pair for checkpointing: restoring it via
    /// [`Pcg64::from_raw`] resumes the stream bit-exactly.
    pub fn to_raw(&self) -> (u128, u128) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from [`Pcg64::to_raw`] output. `inc` must be
    /// odd (every generator this module constructs satisfies that).
    pub fn from_raw(state: u128, inc: u128) -> Self {
        Pcg64 { state, inc: inc | 1 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_range_and_mean() {
        let mut g = Pcg64::new(1);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = g.next_f64();
            assert!(x > 0.0 && x < 1.0);
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.005, "mean {mean}");
    }

    #[test]
    fn next_below_is_in_range_and_covers() {
        let mut g = Pcg64::new(2);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = g.next_below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut g = Pcg64::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        g.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn equidistribution_chi2ish() {
        // 16 buckets over u64 high bits; crude chi^2 sanity bound
        let mut g = Pcg64::new(4);
        let mut counts = [0u32; 16];
        let n = 160_000;
        for _ in 0..n {
            counts[(g.next_u64() >> 60) as usize] += 1;
        }
        let exp = n as f64 / 16.0;
        let chi2: f64 = counts.iter().map(|&c| (c as f64 - exp).powi(2) / exp).sum();
        assert!(chi2 < 50.0, "chi2 {chi2}"); // df=15, p~1e-5 cut
    }
}
