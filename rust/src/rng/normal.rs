//! Standard normal variates via Marsaglia's polar method, with the spare
//! cached (the usual Box–Muller-family trick).

use super::Pcg64;

/// Wraps a [`Pcg64`] and produces N(0, 1) draws.
pub struct NormalSource {
    spare: Option<f64>,
}

impl Default for NormalSource {
    fn default() -> Self {
        Self::new()
    }
}

impl NormalSource {
    pub fn new() -> Self {
        NormalSource { spare: None }
    }

    /// Rebuild a source from a checkpointed spare (see [`Self::spare`]).
    pub fn with_spare(spare: Option<f64>) -> Self {
        NormalSource { spare }
    }

    /// The cached polar-method spare, if any — together with the raw
    /// [`Pcg64`] state this pins the draw sequence exactly, which is
    /// what makes `train --resume` bit-identical for the MC sampler.
    pub fn spare(&self) -> Option<f64> {
        self.spare
    }

    /// One N(0,1) draw, consuming entropy from `g`.
    #[inline]
    pub fn next(&mut self, g: &mut Pcg64) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        loop {
            let u = 2.0 * g.next_f64() - 1.0;
            let v = 2.0 * g.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let m = (-2.0 * s.ln() / s).sqrt();
                self.spare = Some(v * m);
                return u * m;
            }
        }
    }

    /// Fill `out` with N(0,1) f32 draws.
    pub fn fill_f32(&mut self, g: &mut Pcg64, out: &mut [f32]) {
        for o in out {
            *o = self.next(g) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moments() {
        let mut g = Pcg64::new(9);
        let mut ns = NormalSource::new();
        let n = 200_000;
        let (mut s1, mut s2, mut s3, mut s4) = (0.0, 0.0, 0.0, 0.0);
        for _ in 0..n {
            let x = ns.next(&mut g);
            s1 += x;
            s2 += x * x;
            s3 += x * x * x;
            s4 += x * x * x * x;
        }
        let nf = n as f64;
        assert!((s1 / nf).abs() < 0.01);
        assert!((s2 / nf - 1.0).abs() < 0.02);
        assert!((s3 / nf).abs() < 0.05);
        assert!((s4 / nf - 3.0).abs() < 0.15); // kurtosis of N(0,1)
    }

    #[test]
    fn tail_probability() {
        let mut g = Pcg64::new(10);
        let mut ns = NormalSource::new();
        let n = 100_000;
        let beyond2 = (0..n).filter(|_| ns.next(&mut g).abs() > 2.0).count();
        let frac = beyond2 as f64 / n as f64;
        assert!((frac - 0.0455).abs() < 0.005, "P(|Z|>2) = {frac}");
    }
}
