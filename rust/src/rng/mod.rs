//! Deterministic random-number substrate.
//!
//! The offline registry has no `rand` crate, and we want *identical*
//! randomness on the native and XLA backends anyway: every worker owns a
//! [`Pcg64`] stream seeded `(seed, worker_id)`, draws its uniforms /
//! normals in Rust, and (on the XLA backend) injects them into the
//! worker-step artifact. The inverse-Gaussian transform here is the same
//! Michael–Schucany–Haas math as `kernels/ref.py::inv_gauss_ref`.

mod invgauss;
mod normal;
mod pcg;

pub use invgauss::sample_inv_gauss;
pub use normal::NormalSource;
pub use pcg::Pcg64;

/// Convenience: a worker's private stream, decorrelated across workers.
pub fn worker_stream(seed: u64, worker_id: u64) -> Pcg64 {
    // stream selection via the PCG increment; golden-ratio spacing keeps
    // nearby worker ids far apart in sequence space.
    Pcg64::new_stream(seed, 0x9e37_79b9_7f4a_7c15u64.wrapping_mul(worker_id + 1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn streams_are_decorrelated() {
        let mut a = worker_stream(7, 0);
        let mut b = worker_stream(7, 1);
        let xa: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let xb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xa, xb);
    }

    #[test]
    fn same_seed_same_sequence() {
        let mut a = worker_stream(42, 3);
        let mut b = worker_stream(42, 3);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }
}
