//! Inverse-Gaussian sampler (Michael, Schucany & Haas 1976).
//!
//! Eq. (5) of the paper draws `gamma_d^{-1} ~ IG(|1 - y_d w.x_d|^{-1}, 1)`.
//! This is the transformation-with-rejection method: one chi-square(1)
//! variate gives the smaller root of the quadratic, a uniform picks
//! between the root and its reciprocal image.
//!
//! The arithmetic mirrors `kernels/ref.py::inv_gauss_ref` exactly (same
//! formula, same guards) so that a native-backend run and an XLA-backend
//! run with the same injected `(u, z)` agree to f32 rounding.

/// One IG(mu, lambda = 1) draw from pre-drawn `u ~ U(0,1)`, `z ~ N(0,1)`.
#[inline]
pub fn sample_inv_gauss(mu: f64, u: f64, z: f64) -> f64 {
    let y = z * z;
    let x = mu + 0.5 * mu * mu * y - 0.5 * mu * (4.0 * mu * y + (mu * y) * (mu * y)).sqrt();
    let x = x.max(1e-30); // fp cancellation guard for tiny mu*y
    if u <= mu / (mu + x) {
        x
    } else {
        mu * mu / x
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{NormalSource, Pcg64};

    fn sample_many(mu: f64, n: usize, seed: u64) -> Vec<f64> {
        let mut g = Pcg64::new(seed);
        let mut ns = NormalSource::new();
        (0..n)
            .map(|_| sample_inv_gauss(mu, g.next_f64(), ns.next(&mut g)))
            .collect()
    }

    #[test]
    fn moments_match_ig() {
        // IG(mu, 1): mean = mu, var = mu^3
        for &mu in &[0.2, 0.7, 1.5] {
            let n = 200_000;
            let s = sample_many(mu, n, 11);
            let mean: f64 = s.iter().sum::<f64>() / n as f64;
            let var: f64 = s.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
            let se = (mu.powi(3) / n as f64).sqrt();
            assert!((mean - mu).abs() < 6.0 * se + 1e-3, "mu={mu} mean={mean}");
            assert!((var - mu.powi(3)).abs() / mu.powi(3) < 0.25, "mu={mu} var={var}");
        }
    }

    #[test]
    fn positive_and_finite_extremes() {
        for &mu in &[1e-8, 1e-3, 1.0, 1e3, 1e8] {
            for s in sample_many(mu, 1_000, 13) {
                assert!(s.is_finite() && s > 0.0, "mu={mu} s={s}");
            }
        }
    }

    #[test]
    fn matches_python_reference_values() {
        // Spot values computed with kernels/ref.py::inv_gauss_ref
        // (mu, u, z) -> sample; keeps the two implementations honest.
        let cases = [
            (1.0, 0.3, 0.5, 0.6096117967977924),
            (0.5, 0.9, -1.2, 1.1408687448721169),
            (2.0, 0.5, 0.1, 1.7364510624248435),
        ];
        for (mu, u, z, want) in cases {
            let got = sample_inv_gauss(mu, u, z);
            assert!(
                (got - want).abs() < 1e-9,
                "IG({mu}; u={u}, z={z}) = {got}, want {want}"
            );
        }
    }
}
