//! The unified telemetry layer (DESIGN.md §12): lock-free metric
//! primitives, a global exposition registry, per-iteration span
//! tracing, and leveled logging — all dependency-free.
//!
//! Four parts:
//!
//! * [`metrics`] — the lock-free core: [`Counter`] (sharded atomic
//!   cells, one relaxed add on the hot path), [`Gauge`] (current value
//!   plus high-water mark) and [`Histogram`] (power-of-two latency
//!   buckets, exact u64 merges). All are safe to hammer from worker
//!   threads while another thread snapshots them.
//! * [`registry`] — [`MetricRegistry`]: named metric families with
//!   optional label sets, rendered in Prometheus text exposition
//!   format. [`global()`] is the process-wide registry that the
//!   engine, solver, stream loader and serve front-end all register
//!   into; `pemsvm serve` exposes it as the in-band `#metrics` verb
//!   and `pemsvm train --metrics-out <path>` writes an end-of-run
//!   snapshot.
//! * [`span`] — [`TraceWriter`]: per-iteration [`IterSpan`] records
//!   (phase wall-clock, objective, weight-delta norm) emitted as one
//!   JSONL line each via `pemsvm train/sweep --trace <path>` — the
//!   data behind the paper's Figures 2/5/6 as a byproduct of any run.
//! * [`log`] — `log_info!` / `log_debug!` macros gated by the
//!   process verbosity (`--verbosity`); default output is unchanged.
//! * [`diag`] — online sampler convergence diagnostics
//!   (DESIGN.md §14): the streaming [`ChainDiag`] accumulator (ESS,
//!   split-R̂, MCSE, straggler skew) folding into a [`HealthVerdict`],
//!   fed per-iteration when `--diag-every N` is set.
//!
//! Everything here is `std`-only and allocation-free on the hot paths:
//! recording into a counter or histogram is a handful of relaxed
//! atomic operations, and registration (the only locking path) happens
//! once per metric at first use.

pub mod diag;
pub mod log;
pub mod metrics;
pub mod registry;
pub mod span;

pub use diag::{ChainDiag, DiagSnapshot, DiagSummary, HealthVerdict, IterObs, ScalarChain};
pub use metrics::{Counter, Gauge, Histogram, HistogramSnapshot, HIST_BUCKETS};
pub use registry::{global, label, MetricRegistry};
pub use span::{IterSpan, TraceWriter};
