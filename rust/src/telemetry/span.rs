//! Iteration span tracing: one JSONL record per training iteration.
//!
//! The engine session loop ([`crate::engine::Cluster::run_session_traced`])
//! fills an [`IterSpan`] per iteration — phase wall-clock deltas in
//! the order of [`crate::metrics::PHASES`], the primal objective, and
//! the weight-delta norm `||w_t - w_{t-1}||` — and hands it to a
//! [`TraceWriter`], which appends one JSON line to the `--trace` file.
//! The record is flushed per iteration, so a killed run keeps every
//! completed iteration. The format is flat enough to load with any
//! JSON-lines reader and plot the paper's Figures 2/5/6 directly.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::diag::DiagSummary;
use crate::metrics::{NPHASES, PHASES};

/// Everything one training iteration reports into the trace.
#[derive(Clone, Debug)]
pub struct IterSpan {
    /// 0-based iteration index within the session
    pub iter: usize,
    /// primal objective J at the pre-update weights
    pub objective: f64,
    /// training loss sum at the pre-update weights
    pub train_loss: f64,
    /// training error fraction (CLS/MLT) or mean squared residual (SVR)
    pub train_err: f64,
    /// `||w_t - w_{t-1}||_2` over the flat weight view
    pub weight_delta: f64,
    /// held-out metric if the session has a test set
    pub test_metric: Option<f64>,
    /// this iteration's wall-clock per phase, [`PHASES`] order, seconds
    pub phase_secs: [f64; NPHASES],
    /// convergence diagnostics as of this iteration, when the run was
    /// started with `--diag-every N` (self-describing traces)
    pub diag: Option<DiagSummary>,
}

/// Appends [`IterSpan`]s as JSONL. Records carry a session id so a
/// sweep's per-lambda sessions stay distinguishable in one file.
pub struct TraceWriter {
    out: BufWriter<File>,
    path: PathBuf,
    session: usize,
}

impl TraceWriter {
    /// Create (truncate) the trace file.
    pub fn create(path: &Path) -> Result<TraceWriter> {
        let file = File::create(path)
            .with_context(|| format!("creating trace file {}", path.display()))?;
        Ok(TraceWriter { out: BufWriter::new(file), path: path.to_path_buf(), session: 0 })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Tag subsequent records with a session id (sweeps bump this once
    /// per lambda; plain `train` leaves it at 0).
    pub fn set_session(&mut self, session: usize) {
        self.session = session;
    }

    /// Append one iteration record and flush it to disk.
    pub fn record(&mut self, span: &IterSpan) -> Result<()> {
        let mut line = String::with_capacity(256);
        line.push_str(&format!(
            "{{\"session\":{},\"iter\":{},\"objective\":{},\"train_loss\":{},\"train_err\":{},\
             \"weight_delta\":{}",
            self.session,
            span.iter,
            json_f64(span.objective),
            json_f64(span.train_loss),
            json_f64(span.train_err),
            json_f64(span.weight_delta),
        ));
        match span.test_metric {
            Some(m) => line.push_str(&format!(",\"test_metric\":{}", json_f64(m))),
            None => line.push_str(",\"test_metric\":null"),
        }
        line.push_str(",\"phases\":{");
        for (i, p) in PHASES.iter().enumerate() {
            if i > 0 {
                line.push(',');
            }
            line.push_str(&format!("\"{}\":{}", p.name(), json_f64(span.phase_secs[i])));
        }
        line.push('}');
        if let Some(d) = &span.diag {
            line.push_str(&format!(
                ",\"diag\":{{\"ess\":{},\"tau\":{},\"lag1\":{},\"rhat\":{},\"mcse\":{},\
                 \"skew\":{},\"verdict\":\"{}\"}}",
                json_f64(d.ess),
                json_f64(d.tau),
                json_f64(d.lag1),
                json_f64(d.rhat),
                json_f64(d.mcse),
                json_f64(d.skew),
                d.verdict.name(),
            ));
        }
        line.push_str("}\n");
        self.out
            .write_all(line.as_bytes())
            .and_then(|()| self.out.flush())
            .with_context(|| format!("writing trace record to {}", self.path.display()))
    }

    /// Flush any buffered bytes and surface the error. [`Drop`] does
    /// the same best-effort, so an early-exiting or panicking run still
    /// leaves a parseable file; call this on the happy path to turn a
    /// silent flush failure into a hard error.
    pub fn finish(mut self) -> Result<()> {
        self.out
            .flush()
            .with_context(|| format!("flushing trace file {}", self.path.display()))
    }
}

impl Drop for TraceWriter {
    fn drop(&mut self) {
        // best-effort: every record() already flushed, so this only
        // matters if a future write path buffers without flushing
        let _ = self.out.flush();
    }
}

/// f64 as a JSON value: `Display` for finite numbers (round-trips in
/// any JSON parser), `null` for NaN/inf (which JSON cannot carry).
fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_are_one_json_line_each() {
        let dir = std::env::temp_dir().join("pemsvm_telemetry_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.jsonl");
        let mut tw = TraceWriter::create(&path).unwrap();
        let mut phase_secs = [0f64; NPHASES];
        phase_secs[0] = 1.5e-3;
        tw.record(&IterSpan {
            iter: 0,
            objective: 12.5,
            train_loss: 3.25,
            train_err: 0.125,
            weight_delta: 0.5,
            test_metric: None,
            phase_secs,
            diag: None,
        })
        .unwrap();
        tw.set_session(1);
        tw.record(&IterSpan {
            iter: 0,
            objective: f64::INFINITY,
            train_loss: 0.0,
            train_err: 0.0,
            weight_delta: 0.0,
            test_metric: Some(0.75),
            phase_secs: [0.0; NPHASES],
            diag: None,
        })
        .unwrap();
        drop(tw);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with("{\"session\":0,\"iter\":0,\"objective\":12.5,"));
        assert!(lines[0].contains("\"draw_gamma\":0.0015"));
        assert!(lines[0].contains("\"test_metric\":null"));
        assert!(lines[1].starts_with("{\"session\":1,"));
        assert!(lines[1].contains("\"objective\":null")); // inf -> null
        assert!(lines[1].contains("\"test_metric\":0.75"));
        // braces balance on every line (cheap well-formedness check)
        for l in &lines {
            let open = l.matches('{').count();
            assert_eq!(open, l.matches('}').count());
            assert_eq!(open, 2); // the record object + its phases object
        }
    }

    #[test]
    fn diag_object_is_embedded_when_present() {
        use crate::telemetry::diag::{DiagSummary, HealthVerdict};
        let dir = std::env::temp_dir().join("pemsvm_telemetry_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace_diag.jsonl");
        let mut tw = TraceWriter::create(&path).unwrap();
        tw.record(&IterSpan {
            iter: 3,
            objective: 1.0,
            train_loss: 1.0,
            train_err: 0.0,
            weight_delta: 0.1,
            test_metric: None,
            phase_secs: [0.0; NPHASES],
            diag: Some(DiagSummary {
                ess: 12.5,
                tau: 2.0,
                lag1: 0.25,
                rhat: 1.01,
                mcse: 0.125,
                skew: 1.5,
                verdict: HealthVerdict::Healthy,
            }),
        })
        .unwrap();
        tw.finish().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let line = text.lines().next().unwrap();
        assert!(line.contains(
            "\"diag\":{\"ess\":12.5,\"tau\":2,\"lag1\":0.25,\"rhat\":1.01,\
             \"mcse\":0.125,\"skew\":1.5,\"verdict\":\"healthy\"}"
        ));
        let open = line.matches('{').count();
        assert_eq!(open, line.matches('}').count());
        assert_eq!(open, 3); // record + phases + diag objects
    }

    #[test]
    fn dropped_writer_leaves_a_parseable_file() {
        let dir = std::env::temp_dir().join("pemsvm_telemetry_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace_dropped.jsonl");
        {
            let mut tw = TraceWriter::create(&path).unwrap();
            for i in 0..5 {
                tw.record(&IterSpan {
                    iter: i,
                    objective: i as f64,
                    train_loss: 0.0,
                    train_err: 0.0,
                    weight_delta: 0.0,
                    test_metric: None,
                    phase_secs: [0.0; NPHASES],
                    diag: None,
                })
                .unwrap();
            }
            // dropped without finish(): simulates an early bail-out
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.ends_with('\n'), "last record must be newline-terminated");
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 5);
        for l in &lines {
            assert!(l.starts_with('{') && l.ends_with('}'));
            assert_eq!(l.matches('{').count(), l.matches('}').count());
        }
    }
}
