//! Online sampler convergence diagnostics (DESIGN.md §14).
//!
//! The paper's central claim is that the data-augmentation Gibbs
//! sampler *mixes fast* (Figures 5/6); this module is how the repo
//! measures that claim instead of assuming it. A [`ChainDiag`] is fed
//! once per (diagnosed) iteration by the engine session loop and
//! maintains, allocation-light and in O(k) per observation:
//!
//! * per-coordinate running mean/variance of the weight trajectory
//!   (Welford);
//! * lag-{1,2,4,...,64} autocorrelation of three projected scalar
//!   summaries — the objective J, `||w||`, and a fixed seeded random
//!   projection of w — via ring-buffer cross-product accumulators;
//! * integrated autocorrelation time τ, effective sample size
//!   ESS = n/τ, and the Monte-Carlo standard error of the running
//!   average, MCSE = sd/√ESS;
//! * split-R̂ over the two halves of the post-burn-in chain;
//! * cross-worker straggler skew (EWMA of max/mean step time) and
//!   objective plateau/divergence detectors.
//!
//! Everything folds into one [`HealthVerdict`]
//! (Healthy / Mixing-Slow / Stalled / Diverged). The MC sampler gets
//! the full battery; EM — a deterministic fixed-point iteration, not a
//! chain — is judged only on plateau/divergence and straggler skew.
//!
//! The streaming estimators are *defined* to compute exactly what a
//! brute-force pass over the stored series computes (same moments, same
//! lag pairs), so `pemsvm diagnose` — which re-derives everything from
//! a trace file via the [`reference`] implementations — agrees with the
//! live values to floating-point rounding (`tests/diagnostics.rs`).

use std::sync::{Arc, OnceLock};

use super::metrics::Gauge;

/// Tracked autocorrelation lags (powers of two up to [`MAX_LAG`]).
pub const LAGS: [usize; 7] = [1, 2, 4, 8, 16, 32, 64];

/// Largest tracked lag; also the scalar ring-buffer capacity.
pub const MAX_LAG: usize = 64;

/// Autocorrelation below this is treated as noise: the τ integration
/// truncates at the first tracked lag under it (Geyer-style cutoff).
pub const RHO_CUTOFF: f64 = 0.05;

/// The folded health state of a training run, in increasing severity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum HealthVerdict {
    /// chain moves, mixes acceptably, objective finite and non-exploding
    Healthy,
    /// the sampler is moving but autocorrelation/ESS/R̂ or worker skew
    /// says the iterations buy little independent information
    MixingSlow,
    /// objective and weights have been frozen for many iterations while
    /// the stopping rule has not fired
    Stalled,
    /// non-finite objective, or the smoothed objective exploded past
    /// 10x its best value
    Diverged,
}

impl HealthVerdict {
    /// Stable lower-case name (model header, JSON, gauges).
    pub fn name(self) -> &'static str {
        match self {
            HealthVerdict::Healthy => "healthy",
            HealthVerdict::MixingSlow => "mixing-slow",
            HealthVerdict::Stalled => "stalled",
            HealthVerdict::Diverged => "diverged",
        }
    }

    /// Human display name (`pemsvm diagnose` report).
    pub fn display(self) -> &'static str {
        match self {
            HealthVerdict::Healthy => "Healthy",
            HealthVerdict::MixingSlow => "Mixing-Slow",
            HealthVerdict::Stalled => "Stalled",
            HealthVerdict::Diverged => "Diverged",
        }
    }

    /// Parse [`name`](HealthVerdict::name) back (model header read-path).
    pub fn parse(s: &str) -> Option<HealthVerdict> {
        Some(match s {
            "healthy" => HealthVerdict::Healthy,
            "mixing-slow" => HealthVerdict::MixingSlow,
            "stalled" => HealthVerdict::Stalled,
            "diverged" => HealthVerdict::Diverged,
            _ => None?,
        })
    }

    /// Numeric severity for the `diag_verdict` gauge (0..=3).
    pub fn severity(self) -> usize {
        match self {
            HealthVerdict::Healthy => 0,
            HealthVerdict::MixingSlow => 1,
            HealthVerdict::Stalled => 2,
            HealthVerdict::Diverged => 3,
        }
    }
}

/// One scalar summary chain with streaming moment + lag accumulators.
///
/// Per push: a Welford mean/variance update, one multiply-add per
/// tracked lag against the ring buffer, and an append to the stored
/// series (used only for split-R̂, which needs the halves, and for the
/// diagnose-time cross-check). Nothing else allocates after the first
/// [`MAX_LAG`] pushes.
#[derive(Clone, Debug)]
pub struct ScalarChain {
    n: usize,
    mean: f64,
    m2: f64,
    ring: [f64; MAX_LAG],
    /// `Σ x_t * x_{t-L}` over all pairs seen, per tracked lag
    cross: [f64; LAGS.len()],
    cross_n: [u64; LAGS.len()],
    series: Vec<f64>,
}

impl Default for ScalarChain {
    fn default() -> Self {
        ScalarChain {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            ring: [0.0; MAX_LAG],
            cross: [0.0; LAGS.len()],
            cross_n: [0; LAGS.len()],
            series: Vec::new(),
        }
    }
}

impl ScalarChain {
    pub fn new() -> ScalarChain {
        ScalarChain::default()
    }

    /// Observations so far.
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// The stored series (for split-R̂ and offline cross-checks).
    pub fn series(&self) -> &[f64] {
        &self.series
    }

    /// Feed one observation.
    pub fn push(&mut self, x: f64) {
        // lag pairs first: slot (n - L) % MAX_LAG still holds x_{n-L}
        // for every tracked L <= MAX_LAG, including L == MAX_LAG (that
        // is exactly the slot this push will overwrite)
        for (i, &lag) in LAGS.iter().enumerate() {
            if self.n >= lag {
                self.cross[i] += x * self.ring[(self.n - lag) % MAX_LAG];
                self.cross_n[i] += 1;
            }
        }
        self.ring[self.n % MAX_LAG] = x;
        self.series.push(x);
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance `m2 / n` (the normalization the ρ̂ estimator
    /// uses, so streaming and brute-force agree exactly).
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Sample standard deviation (`m2 / (n-1)`).
    pub fn sd(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    /// `ρ̂_L = ((1/(n-L)) Σ x_t·x_{t-L} − μ²) / σ²` at tracked lag
    /// index `i` — identical, term for term, to
    /// [`reference::autocorr`] over the stored series.
    pub fn autocorr_at(&self, i: usize) -> f64 {
        let var = self.variance();
        if self.cross_n[i] == 0 || var <= 0.0 {
            return 0.0;
        }
        (self.cross[i] / self.cross_n[i] as f64 - self.mean * self.mean) / var
    }

    /// `(lag, ρ̂)` for every tracked lag the chain is long enough for.
    pub fn autocorrs(&self) -> Vec<(usize, f64)> {
        LAGS.iter()
            .enumerate()
            .filter(|&(_, &lag)| self.n > lag)
            .map(|(i, &lag)| (lag, self.autocorr_at(i)))
            .collect()
    }

    /// Integrated autocorrelation time τ from the tracked lags.
    pub fn tau(&self) -> f64 {
        if self.variance() <= 0.0 {
            // a frozen chain carries no information at all
            return self.n.max(1) as f64;
        }
        tau_from_lags(&self.autocorrs())
    }

    /// Effective sample size `n / τ`, clamped to `[1, n]`.
    pub fn ess(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        if self.variance() <= 0.0 {
            return 1.0; // stuck chain: one effective sample
        }
        (self.n as f64 / self.tau()).clamp(1.0, self.n as f64)
    }

    /// Monte-Carlo standard error of the running mean: `sd / √ESS`.
    pub fn mcse(&self) -> f64 {
        if self.n < 2 {
            return f64::INFINITY;
        }
        self.sd() / self.ess().sqrt()
    }

    /// Split-R̂ over the two halves of the stored series (brute-force
    /// by construction: the halves' midpoint moves every push).
    pub fn split_rhat(&self) -> f64 {
        reference::split_rhat(&self.series)
    }

    /// Full derived statistics for this chain.
    pub fn stats(&self) -> ChainStats {
        ChainStats {
            n: self.n,
            mean: self.mean(),
            sd: self.sd(),
            lag1: if self.n > 1 { self.autocorr_at(0) } else { 0.0 },
            tau: if self.n > 1 { self.tau() } else { 1.0 },
            ess: self.ess(),
            mcse: self.mcse(),
            rhat: self.split_rhat(),
        }
    }
}

/// τ = 2 · ∫₀^cut ρ̃(x) dx with ρ̃ the piecewise-linear interpolation
/// through `(0, 1)` and the tracked `(lag, ρ̂)` points, truncated at
/// the first lag whose ρ̂ drops under [`RHO_CUTOFF`] (the trapezoid
/// into that lag decays to 0). The identity `τ = 1 + 2·Σ_{L≥1} ρ_L ≈
/// 2·∫₀ ρ̃` absorbs the half-weight of ρ₀ = 1 exactly.
fn tau_from_lags(rhos: &[(usize, f64)]) -> f64 {
    let mut s = 0.0f64;
    let (mut prev_lag, mut prev_rho) = (0usize, 1.0f64);
    for &(lag, rho) in rhos {
        let r = if rho.is_finite() { rho } else { 0.0 };
        if r < RHO_CUTOFF {
            // decay to zero across this interval, then truncate
            s += 0.5 * prev_rho * (lag - prev_lag) as f64;
            break;
        }
        s += 0.5 * (prev_rho + r) * (lag - prev_lag) as f64;
        prev_lag = lag;
        prev_rho = r;
    }
    (2.0 * s).max(1.0)
}

/// Derived statistics of one scalar summary chain.
#[derive(Clone, Copy, Debug)]
pub struct ChainStats {
    pub n: usize,
    pub mean: f64,
    pub sd: f64,
    pub lag1: f64,
    pub tau: f64,
    pub ess: f64,
    pub mcse: f64,
    pub rhat: f64,
}

/// The compact per-iteration diagnostics embedded in trace records
/// (the span's optional `diag` object): the **objective chain**'s
/// mixing numbers, the worst split-R̂ across the three summary chains,
/// the straggler-skew EWMA, and the folded verdict.
#[derive(Clone, Copy, Debug)]
pub struct DiagSummary {
    pub ess: f64,
    pub tau: f64,
    pub lag1: f64,
    pub rhat: f64,
    pub mcse: f64,
    pub skew: f64,
    pub verdict: HealthVerdict,
}

/// A full point-in-time read of a [`ChainDiag`].
#[derive(Clone, Debug)]
pub struct DiagSnapshot {
    /// iterations observed (including burn-in)
    pub iters: usize,
    /// post-burn-in observations feeding the chains
    pub samples: usize,
    pub objective: ChainStats,
    pub wnorm: ChainStats,
    pub wproj: ChainStats,
    pub skew: f64,
    pub verdict: HealthVerdict,
}

/// What the engine hands the accumulator each diagnosed iteration.
#[derive(Clone, Copy, Debug)]
pub struct IterObs<'a> {
    pub iter: usize,
    /// primal objective J at the pre-update weights
    pub objective: f64,
    /// the driver's current flat weight view
    pub weights: &'a [f32],
    /// `||w_t - w_{t-1}||` as already computed by the session loop
    pub weight_delta: f64,
    /// slowest worker step since the previous observation, seconds
    pub step_max: f64,
    /// mean worker step since the previous observation, seconds
    pub step_mean: f64,
}

/// Verdict thresholds (DESIGN.md §14 documents the rationale).
mod thresholds {
    /// smoothed J above `DIVERGE_FACTOR ×` its best smoothed value
    pub const DIVERGE_FACTOR: f64 = 10.0;
    /// objective moving by less than this relative amount...
    pub const PLATEAU_REL: f64 = 1e-8;
    /// ...with a weight delta under `PLATEAU_W_REL × (1 + ||w||)`...
    pub const PLATEAU_W_REL: f64 = 1e-8;
    /// ...for this many consecutive observations => Stalled
    pub const PLATEAU_RUN: usize = 8;
    /// MC lag-1 autocorrelation above this => Mixing-Slow
    pub const LAG1_MAX: f64 = 0.98;
    /// MC ESS under this fraction of the post-burn-in samples
    pub const ESS_FRACTION: f64 = 0.02;
    /// split-R̂ above this (checked at snapshot time) => Mixing-Slow
    pub const RHAT_MAX: f64 = 1.5;
    /// straggler-skew EWMA (max/mean step time) above this
    pub const SKEW_MAX: f64 = 4.0;
    /// minimum post-burn-in samples before mixing criteria apply
    pub const MIN_SAMPLES: usize = 16;
    /// minimum observations before the skew EWMA is trusted
    pub const MIN_SKEW_OBS: usize = 8;
    /// EWMA smoothing factor for the straggler skew
    pub const SKEW_ALPHA: f64 = 0.2;
}

/// `diag_*` gauges in the global telemetry registry, registered once
/// per process (DESIGN.md §12): ESS and τ/R̂/skew in milli-units
/// (gauges are integers), plus the verdict severity.
struct DiagGauges {
    ess: Arc<Gauge>,
    rhat_milli: Arc<Gauge>,
    tau_milli: Arc<Gauge>,
    skew_milli: Arc<Gauge>,
    verdict: Arc<Gauge>,
}

fn diag_gauges() -> &'static DiagGauges {
    static G: OnceLock<DiagGauges> = OnceLock::new();
    G.get_or_init(|| {
        let reg = super::global();
        DiagGauges {
            ess: reg.gauge("diag_ess", "Effective sample size of the objective chain."),
            rhat_milli: reg
                .gauge("diag_split_rhat_milli", "Worst split R-hat across summary chains, x1000."),
            tau_milli: reg.gauge(
                "diag_tau_milli",
                "Integrated autocorrelation time of the objective chain, x1000.",
            ),
            skew_milli: reg
                .gauge("diag_straggler_skew_milli", "EWMA of max/mean worker step time, x1000."),
            verdict: reg.gauge(
                "diag_verdict",
                "Health verdict severity: 0 healthy, 1 mixing-slow, 2 stalled, 3 diverged.",
            ),
        }
    })
}

/// The streaming convergence-diagnostics accumulator the engine feeds
/// once per diagnosed iteration (`--diag-every N`).
pub struct ChainDiag {
    mc: bool,
    burn_in: usize,
    k: usize,
    iters: usize,
    /// per-coordinate Welford over the weight trajectory
    w_n: usize,
    w_mean: Vec<f64>,
    w_m2: Vec<f64>,
    /// fixed random ±1/√k projection (seeded, so runs are reproducible)
    proj: Vec<f32>,
    obj: ScalarChain,
    wnorm: ScalarChain,
    wproj: ScalarChain,
    // plateau / divergence detectors (these see burn-in iterations too)
    smooth: [f64; 5],
    smooth_n: usize,
    best_smooth: f64,
    last_obj: f64,
    plateau_run: usize,
    diverged: bool,
    // straggler skew
    skew_ewma: f64,
    skew_n: usize,
    /// worst verdict from cheap per-observe signals (R̂ folds in at
    /// snapshot time; see [`ChainDiag::snapshot`])
    inline_verdict: HealthVerdict,
    /// last snapshot-time R̂ (cached for the gauges)
    last_rhat: f64,
    export_gauges: bool,
}

impl ChainDiag {
    /// `mc` selects the full battery (vs the EM plateau/divergence
    /// subset), `burn_in` is the iteration the summary chains start at
    /// (0 for EM), `k` the flat weight length, `seed` fixes the random
    /// projection.
    pub fn new(mc: bool, burn_in: usize, k: usize, seed: u64) -> ChainDiag {
        let mut rng = crate::rng::Pcg64::new_stream(seed, 0xd1a6);
        let scale = 1.0 / (k.max(1) as f32).sqrt();
        let proj = (0..k)
            .map(|_| if rng.next_f32() < 0.5 { -scale } else { scale })
            .collect();
        ChainDiag {
            mc,
            burn_in: if mc { burn_in } else { 0 },
            k,
            iters: 0,
            w_n: 0,
            w_mean: vec![0.0; k],
            w_m2: vec![0.0; k],
            proj,
            obj: ScalarChain::new(),
            wnorm: ScalarChain::new(),
            wproj: ScalarChain::new(),
            smooth: [0.0; 5],
            smooth_n: 0,
            best_smooth: f64::INFINITY,
            last_obj: f64::INFINITY,
            plateau_run: 0,
            diverged: false,
            skew_ewma: 1.0,
            skew_n: 0,
            inline_verdict: HealthVerdict::Healthy,
            last_rhat: 1.0,
            export_gauges: true,
        }
    }

    /// A [`new`](ChainDiag::new) that never touches the global metric
    /// registry (benches measuring the bundle in isolation).
    pub fn new_detached(mc: bool, burn_in: usize, k: usize, seed: u64) -> ChainDiag {
        let mut d = ChainDiag::new(mc, burn_in, k, seed);
        d.export_gauges = false;
        d
    }

    /// Observations so far (including burn-in ones).
    pub fn iters(&self) -> usize {
        self.iters
    }

    /// Post-burn-in observations feeding the summary chains.
    pub fn samples(&self) -> usize {
        self.obj.len()
    }

    /// The objective summary chain (read-only).
    pub fn objective_chain(&self) -> &ScalarChain {
        &self.obj
    }

    /// Feed one iteration. O(k) plus a handful of scalar updates; the
    /// only allocation is the amortized series append inside each
    /// [`ScalarChain`].
    pub fn observe(&mut self, obs: &IterObs<'_>) {
        self.iters += 1;

        // --- divergence: non-finite, or smoothed J exploding ---
        let finite = obs.objective.is_finite();
        if !finite {
            self.diverged = true;
        } else {
            self.smooth[self.smooth_n % 5] = obs.objective;
            self.smooth_n += 1;
            let m = self.smooth_n.min(5);
            let j_s = self.smooth[..m].iter().sum::<f64>() / m as f64;
            if self.smooth_n >= 5 {
                if j_s > thresholds::DIVERGE_FACTOR * self.best_smooth + 1e-12
                    && self.best_smooth.is_finite()
                {
                    self.diverged = true;
                }
                self.best_smooth = self.best_smooth.min(j_s);
            }
        }

        // --- plateau: frozen objective AND frozen weights ---
        let mut wnorm_sq = 0.0f64;
        for &w in obs.weights {
            wnorm_sq += w as f64 * w as f64;
        }
        let wnorm = wnorm_sq.sqrt();
        let d_obj = (obs.objective - self.last_obj).abs();
        let frozen = finite
            && d_obj <= thresholds::PLATEAU_REL * obs.objective.abs().max(1.0)
            && obs.weight_delta <= thresholds::PLATEAU_W_REL * (1.0 + wnorm);
        self.plateau_run = if frozen { self.plateau_run + 1 } else { 0 };
        self.last_obj = obs.objective;

        // --- straggler skew EWMA ---
        if obs.step_mean > 0.0 && obs.step_max.is_finite() {
            let skew = (obs.step_max / obs.step_mean).max(1.0);
            self.skew_ewma += thresholds::SKEW_ALPHA * (skew - self.skew_ewma);
            self.skew_n += 1;
        }

        // --- per-coordinate Welford + summary chains (post-burn-in) ---
        if obs.iter >= self.burn_in {
            self.w_n += 1;
            let inv_n = 1.0 / self.w_n as f64;
            let mut p = 0.0f64;
            for (i, &w) in obs.weights.iter().enumerate().take(self.k) {
                let w = w as f64;
                let d = w - self.w_mean[i];
                self.w_mean[i] += d * inv_n;
                self.w_m2[i] += d * (w - self.w_mean[i]);
                p += w * self.proj[i] as f64;
            }
            if finite {
                self.obj.push(obs.objective);
            }
            self.wnorm.push(wnorm);
            self.wproj.push(p);
        }

        self.inline_verdict = self.inline_verdict.max(self.verdict_inline());
        if self.export_gauges {
            let g = diag_gauges();
            g.ess.set(self.obj.ess().round() as usize);
            g.tau_milli.set((self.obj.tau() * 1e3).round() as usize);
            g.rhat_milli
                .set((self.last_rhat.min(1e6) * 1e3).round() as usize);
            g.skew_milli.set((self.skew_ewma * 1e3).round() as usize);
            g.verdict.set(self.inline_verdict.severity());
        }
    }

    /// The verdict from streaming-only signals (O(1)): everything
    /// except split-R̂, which needs the chain halves and is folded in
    /// by [`snapshot`](ChainDiag::snapshot).
    fn verdict_inline(&self) -> HealthVerdict {
        if self.diverged {
            return HealthVerdict::Diverged;
        }
        if self.plateau_run >= thresholds::PLATEAU_RUN {
            return HealthVerdict::Stalled;
        }
        if self.skew_n >= thresholds::MIN_SKEW_OBS && self.skew_ewma > thresholds::SKEW_MAX {
            return HealthVerdict::MixingSlow;
        }
        if self.mc && self.samples() >= thresholds::MIN_SAMPLES {
            let n = self.samples() as f64;
            let lag1 = self.obj.autocorr_at(0).max(self.wproj.autocorr_at(0));
            let ess = self.obj.ess().min(self.wproj.ess());
            if lag1 > thresholds::LAG1_MAX || ess < thresholds::ESS_FRACTION * n {
                return HealthVerdict::MixingSlow;
            }
        }
        HealthVerdict::Healthy
    }

    /// Worst per-coordinate weight variance seen so far (a zero here
    /// with MC means the sampler is not actually sampling).
    pub fn max_coord_variance(&self) -> f64 {
        if self.w_n < 2 {
            return 0.0;
        }
        self.w_m2.iter().fold(0.0f64, |a, &m| a.max(m)) / (self.w_n - 1) as f64
    }

    /// Full snapshot: chain statistics (including the O(n) split-R̂)
    /// plus the final verdict with the R̂ criterion folded in.
    pub fn snapshot(&mut self) -> DiagSnapshot {
        let objective = self.obj.stats();
        let wnorm = self.wnorm.stats();
        let wproj = self.wproj.stats();
        let rhat = objective.rhat.max(wnorm.rhat).max(wproj.rhat);
        self.last_rhat = if rhat.is_finite() { rhat } else { 1e6 };
        let mut verdict = self.inline_verdict.max(self.verdict_inline());
        if verdict == HealthVerdict::Healthy
            && self.mc
            && self.samples() >= thresholds::MIN_SAMPLES
            && rhat > thresholds::RHAT_MAX
        {
            verdict = HealthVerdict::MixingSlow;
        }
        if self.export_gauges {
            let g = diag_gauges();
            g.rhat_milli.set((self.last_rhat.min(1e6) * 1e3).round() as usize);
            g.verdict.set(verdict.severity());
        }
        DiagSnapshot {
            iters: self.iters,
            samples: self.samples(),
            objective,
            wnorm,
            wproj,
            skew: self.skew_ewma,
            verdict,
        }
    }

    /// The compact per-span summary (computes a [`snapshot`](ChainDiag::snapshot)).
    pub fn summary(&mut self) -> DiagSummary {
        let s = self.snapshot();
        DiagSummary {
            ess: s.objective.ess,
            tau: s.objective.tau,
            lag1: s.objective.lag1,
            rhat: s.objective.rhat.max(s.wnorm.rhat).max(s.wproj.rhat),
            mcse: s.objective.mcse,
            skew: s.skew,
            verdict: s.verdict,
        }
    }
}

/// Brute-force reference implementations over a full series — the
/// golden standard the streaming accumulators are tested against
/// (`tests/diagnostics.rs`) and the estimators `pemsvm diagnose` runs
/// over trace files. Definitions are identical to the streaming ones,
/// so agreement is exact up to floating-point rounding.
pub mod reference {
    use super::{tau_from_lags, LAGS};

    pub fn mean(xs: &[f64]) -> f64 {
        if xs.is_empty() {
            return 0.0;
        }
        xs.iter().sum::<f64>() / xs.len() as f64
    }

    /// Population variance.
    pub fn variance(xs: &[f64]) -> f64 {
        let m = mean(xs);
        if xs.is_empty() {
            return 0.0;
        }
        xs.iter().map(|&x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
    }

    /// Sample standard deviation.
    pub fn sd(xs: &[f64]) -> f64 {
        if xs.len() < 2 {
            return 0.0;
        }
        let m = mean(xs);
        (xs.iter().map(|&x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
    }

    /// `ρ̂_L = ((1/(n-L)) Σ_{t=L}^{n-1} x_t·x_{t-L} − μ²) / σ²`, with
    /// μ and σ² taken over the **full** series.
    pub fn autocorr(xs: &[f64], lag: usize) -> f64 {
        let n = xs.len();
        if n <= lag {
            return 0.0;
        }
        let var = variance(xs);
        if var <= 0.0 {
            return 0.0;
        }
        let m = mean(xs);
        let cross =
            (lag..n).map(|t| xs[t] * xs[t - lag]).sum::<f64>() / (n - lag) as f64;
        (cross - m * m) / var
    }

    /// Integrated autocorrelation time over the same tracked
    /// power-of-two lags and trapezoid rule as the streaming estimator.
    pub fn tau(xs: &[f64]) -> f64 {
        if variance(xs) <= 0.0 {
            return xs.len().max(1) as f64;
        }
        let rhos: Vec<(usize, f64)> = LAGS
            .iter()
            .filter(|&&lag| xs.len() > lag)
            .map(|&lag| (lag, autocorr(xs, lag)))
            .collect();
        tau_from_lags(&rhos)
    }

    /// Effective sample size `n / τ`, clamped to `[1, n]`.
    pub fn ess(xs: &[f64]) -> f64 {
        if xs.is_empty() {
            return 0.0;
        }
        if variance(xs) <= 0.0 {
            return 1.0;
        }
        (xs.len() as f64 / tau(xs)).clamp(1.0, xs.len() as f64)
    }

    /// Monte-Carlo standard error `sd / √ESS`.
    pub fn mcse(xs: &[f64]) -> f64 {
        if xs.len() < 2 {
            return f64::INFINITY;
        }
        sd(xs) / ess(xs).sqrt()
    }

    /// Split-R̂ (Gelman et al.): the series is split into two halves of
    /// `m = n/2` (the first element is dropped when `n` is odd), and
    /// `R̂ = √(var⁺ / W)` with `W` the mean within-half variance,
    /// `B/m` the between-half variance of the half means, and
    /// `var⁺ = (m−1)/m · W + B/m`. A constant series reports 1.
    pub fn split_rhat(xs: &[f64]) -> f64 {
        let m = xs.len() / 2;
        if m < 2 {
            return 1.0;
        }
        let xs = &xs[xs.len() - 2 * m..];
        let (a, b) = (&xs[..m], &xs[m..]);
        let (ma, mb) = (mean(a), mean(b));
        let sample_var = |h: &[f64], mh: f64| {
            h.iter().map(|&x| (x - mh) * (x - mh)).sum::<f64>() / (m - 1) as f64
        };
        let w = 0.5 * (sample_var(a, ma) + sample_var(b, mb));
        let g = 0.5 * (ma + mb);
        let b_var = m as f64 * ((ma - g) * (ma - g) + (mb - g) * (mb - g));
        if w <= 0.0 {
            return if b_var <= 0.0 { 1.0 } else { f64::INFINITY };
        }
        let var_plus = (m - 1) as f64 / m as f64 * w + b_var / m as f64;
        (var_plus / w).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn verdict_roundtrip_and_order() {
        for v in [
            HealthVerdict::Healthy,
            HealthVerdict::MixingSlow,
            HealthVerdict::Stalled,
            HealthVerdict::Diverged,
        ] {
            assert_eq!(HealthVerdict::parse(v.name()), Some(v));
        }
        assert!(HealthVerdict::Diverged > HealthVerdict::Stalled);
        assert!(HealthVerdict::Stalled > HealthVerdict::MixingSlow);
        assert!(HealthVerdict::MixingSlow > HealthVerdict::Healthy);
        assert_eq!(HealthVerdict::parse("nonsense"), None);
    }

    #[test]
    fn streaming_matches_reference_on_short_series() {
        let xs: Vec<f64> = (0..200).map(|i| ((i * 37 + 11) % 101) as f64 / 101.0).collect();
        let mut c = ScalarChain::new();
        for &x in &xs {
            c.push(x);
        }
        assert!((c.mean() - reference::mean(&xs)).abs() < 1e-12);
        assert!((c.variance() - reference::variance(&xs)).abs() < 1e-12);
        for (i, &lag) in LAGS.iter().enumerate() {
            let want = reference::autocorr(&xs, lag);
            assert!(
                (c.autocorr_at(i) - want).abs() < 1e-10,
                "lag {lag}: streaming {} vs reference {want}",
                c.autocorr_at(i)
            );
        }
        assert!((c.ess() - reference::ess(&xs)).abs() < 1e-8);
        assert!((c.mcse() - reference::mcse(&xs)).abs() < 1e-10);
    }

    #[test]
    fn stuck_chain_is_one_effective_sample() {
        let mut c = ScalarChain::new();
        for _ in 0..100 {
            c.push(4.25);
        }
        assert_eq!(c.ess(), 1.0);
        assert_eq!(c.split_rhat(), 1.0);
    }
}
