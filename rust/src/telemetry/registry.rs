//! [`MetricRegistry`]: named metric families, optionally labeled,
//! rendered in Prometheus text exposition format.
//!
//! Registration is get-or-create: calling
//! [`counter_labeled`](MetricRegistry::counter_labeled) twice with the
//! same name and label set returns the *same* underlying cells, which
//! is what makes per-model serving counters survive hot reloads — a
//! re-published model re-registers and lands on its existing series
//! (`serve::registry::ModelStats`). The registry lock is only taken at
//! registration time; recording goes straight to the lock-free
//! primitives.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::{Arc, OnceLock, RwLock};

use super::metrics::{bucket_upper_bound, Counter, Gauge, Histogram, HIST_BUCKETS};

#[derive(Clone)]
enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

impl Metric {
    fn kind(&self) -> &'static str {
        match self {
            Metric::Counter(_) => "counter",
            Metric::Gauge(_) => "gauge",
            Metric::Histogram(_) => "histogram",
        }
    }
}

/// One metric family: a help string plus its series keyed by rendered
/// label set (`""` for the unlabeled series).
struct Family {
    help: String,
    series: BTreeMap<String, Metric>,
}

/// Named metric families behind one lock (held for registration and
/// rendering only — never on the record path).
#[derive(Default)]
pub struct MetricRegistry {
    families: RwLock<BTreeMap<String, Family>>,
}

impl MetricRegistry {
    pub fn new() -> MetricRegistry {
        MetricRegistry::default()
    }

    fn register(&self, name: &str, labels: &str, help: &str, make: fn() -> Metric) -> Metric {
        let mut fams = self.families.write().expect("telemetry registry poisoned");
        let fam = fams
            .entry(name.to_string())
            .or_insert_with(|| Family { help: help.to_string(), series: BTreeMap::new() });
        let metric = fam.series.entry(labels.to_string()).or_insert_with(make).clone();
        let want = make().kind();
        assert_eq!(
            metric.kind(),
            want,
            "metric `{name}` already registered as a {}",
            metric.kind()
        );
        metric
    }

    /// Get-or-register an unlabeled counter.
    pub fn counter(&self, name: &str, help: &str) -> Arc<Counter> {
        self.counter_labeled(name, "", help)
    }

    /// Get-or-register a counter series under `labels` (a rendered
    /// label set from [`label`], e.g. `model="smoke"`).
    pub fn counter_labeled(&self, name: &str, labels: &str, help: &str) -> Arc<Counter> {
        match self.register(name, labels, help, || Metric::Counter(Arc::new(Counter::new()))) {
            Metric::Counter(c) => c,
            _ => unreachable!("kind checked in register"),
        }
    }

    pub fn gauge(&self, name: &str, help: &str) -> Arc<Gauge> {
        self.gauge_labeled(name, "", help)
    }

    pub fn gauge_labeled(&self, name: &str, labels: &str, help: &str) -> Arc<Gauge> {
        match self.register(name, labels, help, || Metric::Gauge(Arc::new(Gauge::new()))) {
            Metric::Gauge(g) => g,
            _ => unreachable!("kind checked in register"),
        }
    }

    pub fn histogram(&self, name: &str, help: &str) -> Arc<Histogram> {
        self.histogram_labeled(name, "", help)
    }

    pub fn histogram_labeled(&self, name: &str, labels: &str, help: &str) -> Arc<Histogram> {
        match self.register(name, labels, help, || Metric::Histogram(Arc::new(Histogram::new()))) {
            Metric::Histogram(h) => h,
            _ => unreachable!("kind checked in register"),
        }
    }

    /// Render every family in Prometheus text exposition format:
    /// `# HELP` / `# TYPE` headers, then one `name{labels} value` line
    /// per series (histograms expand to cumulative `_bucket` lines plus
    /// `_sum` / `_count`; gauges also emit a `<name>_peak` family for
    /// their high-water mark).
    pub fn render(&self) -> String {
        let fams = self.families.read().expect("telemetry registry poisoned");
        let mut out = String::new();
        for (name, fam) in fams.iter() {
            let Some(kind) = fam.series.values().next().map(Metric::kind) else { continue };
            if !fam.help.is_empty() {
                let _ = writeln!(out, "# HELP {name} {}", fam.help);
            }
            let _ = writeln!(out, "# TYPE {name} {kind}");
            for (labels, metric) in &fam.series {
                match metric {
                    Metric::Counter(c) => series_line(&mut out, name, labels, "", c.get()),
                    Metric::Gauge(g) => {
                        series_line(&mut out, name, labels, "", g.value() as u64)
                    }
                    Metric::Histogram(h) => {
                        let snap = h.snapshot();
                        let mut cum = 0u64;
                        for (i, &b) in snap.buckets.iter().enumerate() {
                            cum += b;
                            if b == 0 && i + 1 < HIST_BUCKETS {
                                continue; // only boundaries that move, plus +Inf
                            }
                            let le = match bucket_upper_bound(i) {
                                Some(hi) => hi.to_string(),
                                None => "+Inf".to_string(),
                            };
                            let le = label("le", &le);
                            series_line(&mut out, &format!("{name}_bucket"), labels, &le, cum);
                        }
                        series_line(&mut out, &format!("{name}_sum"), labels, "", snap.sum);
                        series_line(&mut out, &format!("{name}_count"), labels, "", snap.count());
                    }
                }
            }
            if kind == "gauge" {
                let _ = writeln!(out, "# TYPE {name}_peak gauge");
                for (labels, metric) in &fam.series {
                    if let Metric::Gauge(g) = metric {
                        series_line(&mut out, &format!("{name}_peak"), labels, "", g.peak() as u64);
                    }
                }
            }
        }
        out
    }
}

/// Append one `name{labels} value` exposition line; `extra` is an
/// additional label pair (the histogram `le`).
fn series_line(out: &mut String, name: &str, labels: &str, extra: &str, value: u64) {
    let _ = match (labels.is_empty(), extra.is_empty()) {
        (true, true) => writeln!(out, "{name} {value}"),
        (true, false) => writeln!(out, "{name}{{{extra}}} {value}"),
        (false, true) => writeln!(out, "{name}{{{labels}}} {value}"),
        (false, false) => writeln!(out, "{name}{{{labels},{extra}}} {value}"),
    };
}

/// Render one `key="value"` label pair, escaping the value per the
/// exposition format (`\\`, `\"`, `\n`).
pub fn label(key: &str, value: &str) -> String {
    let mut v = String::with_capacity(value.len());
    for ch in value.chars() {
        match ch {
            '\\' => v.push_str("\\\\"),
            '"' => v.push_str("\\\""),
            '\n' => v.push_str("\\n"),
            c => v.push(c),
        }
    }
    format!("{key}=\"{v}\"")
}

/// The process-wide registry every subsystem records into.
pub fn global() -> &'static MetricRegistry {
    static GLOBAL: OnceLock<MetricRegistry> = OnceLock::new();
    GLOBAL.get_or_init(MetricRegistry::default)
}
