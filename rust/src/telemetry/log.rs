//! Leveled stderr logging: [`crate::log_info!`] / [`crate::log_debug!`]
//! gated by a process-wide verbosity level.
//!
//! Levels: 0 = quiet (suppress info), 1 = info (default), 2 = debug.
//! The CLI sets the level from `--verbosity N` before dispatching a
//! subcommand. Both macros write to **stderr**, so protocol/stdout
//! output (predictions, `# listening on ...`) stays byte-identical at
//! any verbosity and the CI smoke diffs keep passing.

use std::sync::atomic::{AtomicU8, Ordering};

/// Suppress `log_info!`.
pub const QUIET: u8 = 0;
/// The default: `log_info!` prints, `log_debug!` does not.
pub const INFO: u8 = 1;
/// Everything prints.
pub const DEBUG: u8 = 2;

static VERBOSITY: AtomicU8 = AtomicU8::new(INFO);

/// Set the process verbosity (clamped to [`DEBUG`]).
pub fn set_verbosity(level: u8) {
    VERBOSITY.store(level.min(DEBUG), Ordering::Relaxed);
}

/// Current process verbosity.
pub fn verbosity() -> u8 {
    VERBOSITY.load(Ordering::Relaxed)
}

/// `eprintln!` that always prints: degraded-mode events (worker
/// evictions, retries exhausted) the operator should see even at
/// `--verbosity 0`.
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        eprintln!($($arg)*);
    };
}

/// `eprintln!` at info level (suppressed by `--verbosity 0`).
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        if $crate::telemetry::log::verbosity() >= $crate::telemetry::log::INFO {
            eprintln!($($arg)*);
        }
    };
}

/// `eprintln!` at debug level (enabled by `--verbosity 2`).
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        if $crate::telemetry::log::verbosity() >= $crate::telemetry::log::DEBUG {
            eprintln!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::{set_verbosity, verbosity, DEBUG, INFO};

    #[test]
    fn verbosity_clamps_and_macros_expand() {
        // process-global state: restore the default before returning
        set_verbosity(9);
        assert_eq!(verbosity(), DEBUG);
        crate::log_info!("log test: info at debug verbosity");
        crate::log_debug!("log test: debug at debug verbosity (n = {})", 1 + 1);
        set_verbosity(INFO);
        assert_eq!(verbosity(), INFO);
    }
}
