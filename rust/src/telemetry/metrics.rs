//! Lock-free metric primitives: sharded [`Counter`], watermark
//! [`Gauge`], power-of-two-bucket [`Histogram`].
//!
//! Design constraints (DESIGN.md §12): recording must be safe from any
//! thread, allocation-free, and cheap enough to sit inside the solver
//! hot path — `benches/solver_hotpath.rs` asserts the whole
//! per-iteration instrumentation bundle costs < 1% of one worker step.
//! Counters stripe their adds over cache-line-sized cells indexed by a
//! per-thread shard id, so concurrent workers never contend on one
//! atomic; a snapshot sums the cells. Histograms bucket by the value's
//! bit length (bucket `i` covers `2^(i-1) ..= 2^i - 1` nanoseconds),
//! which makes merges exact u64 adds and therefore associative.

use std::cell::Cell as TlCell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::Duration;

/// Number of independent cells a [`Counter`] stripes its adds over.
/// Threads hash to a cell once (round-robin at first use) and stick to
/// it, so any worker count up to `SHARDS` is entirely contention-free.
pub const SHARDS: usize = 16;

/// One cache line per cell so writers on different shards never
/// false-share.
#[repr(align(64))]
struct Cell(AtomicU64);

/// This thread's shard index: assigned round-robin on first use.
fn shard() -> usize {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static SHARD: TlCell<usize> = const { TlCell::new(usize::MAX) };
    }
    SHARD.with(|s| {
        let mut v = s.get();
        if v == usize::MAX {
            v = NEXT.fetch_add(1, Ordering::Relaxed) % SHARDS;
            s.set(v);
        }
        v
    })
}

/// Monotone counter. [`add`](Counter::add) is one relaxed atomic add on
/// a thread-affine cell; [`get`](Counter::get) sums the cells. A `get`
/// racing concurrent adds sees every add that completed before the
/// last cell load (per-cell reads are coherent, so repeated `get`s are
/// monotone).
pub struct Counter {
    cells: [Cell; SHARDS],
}

impl Counter {
    pub fn new() -> Counter {
        Counter { cells: std::array::from_fn(|_| Cell(AtomicU64::new(0))) }
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.cells[shard()].0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current total across all shards.
    pub fn get(&self) -> u64 {
        self.cells.iter().map(|c| c.0.load(Ordering::Relaxed)).sum()
    }
}

impl Default for Counter {
    fn default() -> Self {
        Counter::new()
    }
}

/// A level with a high-water mark: `value()` is the current level,
/// `peak()` the largest level ever seen. Used both as an up/down
/// resource gauge (resident streamed rows: [`add`](Gauge::add) /
/// [`sub`](Gauge::sub)) and as a last-sample-plus-max recorder
/// ([`set`](Gauge::set), e.g. per-batch latency where the peak is the
/// worst batch).
#[derive(Debug, Default)]
pub struct Gauge {
    cur: AtomicUsize,
    peak: AtomicUsize,
}

impl Gauge {
    pub fn new() -> Gauge {
        Gauge::default()
    }

    pub fn add(&self, n: usize) {
        let now = self.cur.fetch_add(n, Ordering::SeqCst) + n;
        self.peak.fetch_max(now, Ordering::SeqCst);
    }

    pub fn sub(&self, n: usize) {
        self.cur.fetch_sub(n, Ordering::SeqCst);
    }

    /// Overwrite the level (the peak still ratchets up).
    pub fn set(&self, v: usize) {
        self.cur.store(v, Ordering::SeqCst);
        self.peak.fetch_max(v, Ordering::SeqCst);
    }

    /// Current level.
    pub fn value(&self) -> usize {
        self.cur.load(Ordering::SeqCst)
    }

    /// High-water mark.
    pub fn peak(&self) -> usize {
        self.peak.load(Ordering::SeqCst)
    }
}

/// Bucket count of a [`Histogram`]: bucket 0 holds exact zeros, bucket
/// `i` (1..=42) holds values of bit length `i` (`2^(i-1) ..= 2^i - 1`),
/// and the last bucket is the overflow (`>= 2^42` ns ≈ 73 minutes —
/// far beyond any per-iteration latency this crate records).
pub const HIST_BUCKETS: usize = 44;

/// Upper bound (inclusive) of bucket `i`, or `None` for the overflow
/// bucket (rendered as `+Inf`).
pub fn bucket_upper_bound(i: usize) -> Option<u64> {
    if i + 1 >= HIST_BUCKETS {
        None
    } else {
        Some((1u64 << i) - 1) // i = 0 gives 0: the exact-zero bucket
    }
}

/// Lock-free latency histogram: one relaxed add into the value's
/// bit-length bucket plus one into the running sum. Bucket counts are
/// exact, so snapshots merge associatively (`tests/telemetry.rs`).
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    sum: AtomicU64,
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram { buckets: std::array::from_fn(|_| AtomicU64::new(0)), sum: AtomicU64::new(0) }
    }

    fn bucket_of(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            (64 - v.leading_zeros() as usize).min(HIST_BUCKETS - 1)
        }
    }

    #[inline]
    pub fn observe(&self, v: u64) {
        self.buckets[Self::bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn observe_duration(&self, d: Duration) {
        self.observe(d.as_nanos() as u64);
    }

    /// Point-in-time copy. Racing observers may land between the
    /// bucket loads and the sum load, so `sum` can momentarily run
    /// ahead of the bucketed values — counts themselves never regress
    /// and never lose a completed observe.
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
            sum: self.sum.load(Ordering::Relaxed),
        }
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

/// A point-in-time read of a [`Histogram`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HistogramSnapshot {
    /// per-bucket observation counts (see [`bucket_upper_bound`])
    pub buckets: [u64; HIST_BUCKETS],
    /// sum of all observed values
    pub sum: u64,
}

impl HistogramSnapshot {
    /// Total observations.
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Fold another snapshot in. Exact u64 adds bucket by bucket, so
    /// `(a + b) + c == a + (b + c)` — worker-local histograms can be
    /// reduced in any tree order.
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += *b;
        }
        self.sum += other.sum;
    }

    /// Upper bound of the bucket the `q`-quantile observation falls in
    /// (`q` in `[0, 1]`): the resolution is the bucket width, which is
    /// plenty for the order-of-magnitude latency reporting `#health`
    /// does. Returns 0 for an empty histogram and `u64::MAX` when the
    /// quantile lands in the overflow bucket.
    pub fn quantile(&self, q: f64) -> u64 {
        let count = self.count();
        if count == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_upper_bound(i).unwrap_or(u64::MAX);
            }
        }
        u64::MAX
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_basics() {
        let c = Counter::new();
        assert_eq!(c.get(), 0);
        c.inc();
        c.add(41);
        assert_eq!(c.get(), 42);
    }

    #[test]
    fn gauge_tracks_level_and_peak() {
        let g = Gauge::new();
        g.add(5);
        g.add(3);
        g.sub(6);
        assert_eq!(g.value(), 2);
        assert_eq!(g.peak(), 8);
        g.set(4);
        assert_eq!(g.value(), 4);
        assert_eq!(g.peak(), 8); // set below the peak does not lower it
        g.set(20);
        assert_eq!(g.peak(), 20);
    }

    #[test]
    fn quantile_walks_cumulative_buckets() {
        let h = Histogram::new();
        assert_eq!(h.snapshot().quantile(0.5), 0);
        for v in [10u64, 20, 100, 1000, 5000] {
            h.observe(v);
        }
        let s = h.snapshot();
        // 5 observations: p50 is the 3rd (value 100, bucket bound 127)
        assert_eq!(s.quantile(0.5), 127);
        assert_eq!(s.quantile(0.0), 15); // first observation's bucket
        assert_eq!(s.quantile(1.0), 8191); // last observation's bucket
    }

    #[test]
    fn bucket_bounds_are_powers_of_two_minus_one() {
        assert_eq!(bucket_upper_bound(0), Some(0));
        assert_eq!(bucket_upper_bound(1), Some(1));
        assert_eq!(bucket_upper_bound(10), Some(1023));
        assert_eq!(bucket_upper_bound(HIST_BUCKETS - 1), None);
        // every value lands in the bucket whose bound first covers it
        for v in [0u64, 1, 2, 3, 4, 1023, 1024, u64::MAX] {
            let b = Histogram::bucket_of(v);
            if let Some(hi) = bucket_upper_bound(b) {
                assert!(v <= hi, "v={v} bucket={b}");
            }
            if b > 0 {
                let below = bucket_upper_bound(b - 1).unwrap();
                assert!(v > below, "v={v} bucket={b}");
            }
        }
    }
}
