//! The online serving subsystem (DESIGN.md §9) — the inference side of
//! the crate, the layer the ROADMAP's "serve heavy traffic" north star
//! plugs into.
//!
//! Three parts:
//!
//! * [`format`] — the versioned on-disk model format (`pemsvm-model
//!   v1`): typed header, linear *and* kernel bodies, validated counts,
//!   plus the legacy `model.txt` read-path.
//! * [`registry`] — named models in memory behind an `Arc` swap:
//!   publish/hot-reload without dropping in-flight requests, with
//!   per-model [`registry::ModelStats`] counters that live in the
//!   global telemetry registry (so `#metrics` exposes them and they
//!   survive unload/republish cycles; DESIGN.md §12).
//! * [`scorer`] — the persistent batched scoring pool (patterned on
//!   `engine::pool::Pool`): shards a batch of rows across worker
//!   threads and scores CLS margins, SVR values, MLT argmaxes
//!   (blockwise, against transposed weights) and kernel decisions.
//!
//! [`server`] wires them to a TCP front-end speaking newline-delimited
//! libsvm rows with micro-batching; `main.rs` adds the `predict` batch
//! subcommand on the same scorer.

pub mod format;
pub mod registry;
pub mod scorer;
pub mod server;

pub use format::{load, save, ModelBody, ModelMeta, SavedModel};
pub use registry::{ModelEntry, ModelStats, Registry, ServeSnapshot};
pub use scorer::{format_prediction, metric_of, predicted_value, ScoredBatch, Scorer};
pub use server::{serve, ServeOpts};
