//! The `pemsvm serve` TCP front-end: a newline-delimited libsvm-row
//! protocol over `std::net::TcpListener` (no external deps, offline-
//! friendly).
//!
//! Protocol, one line per message:
//!
//! * `<label> idx:val idx:val ...` — a libsvm row; the label field is
//!   required by the format but ignored for scoring. The server replies
//!   with one line holding the prediction (`1`/`-1` for CLS/KRN, class
//!   index for MLT, value for SVR), in row order per connection.
//! * `#model <name>` — switch this connection to another registry model.
//! * `#stats` — reply with the current model's serving counters.
//! * `#metrics` — reply with the full Prometheus text exposition of
//!   the process telemetry registry (DESIGN.md §12), terminated by a
//!   `# EOF` line so in-band scrapers know where the block ends.
//! * `#health` — reply with the current model's training convergence
//!   verdict (stamped into the model header by `train --diag-every`,
//!   DESIGN.md §14) plus live scorer-latency percentiles.
//! * blank lines / other `#...` lines — ignored, no reply.
//! * a malformed row — replies `error: <why>`, the connection stays up.
//!
//! Malformed-row errors and `#stats` / `#metrics` replies travel
//! through the same dispatcher queue as predictions, so the
//! one-reply-per-line ordering holds even for pipelined clients — a
//! `#metrics` scrape sent after N rows reports counters that include
//! all N. Only errors with no model context (unknown `#model`) are
//! answered immediately, as is `#metrics` on a connection with no
//! model selected (the exposition needs no model).
//!
//! Micro-batching: connection readers feed one dispatcher channel; the
//! dispatcher coalesces up to `max_batch` rows or `max_wait` (whichever
//! first) before handing the block to the [`Scorer`], so concurrent
//! clients share batched row-major multiplies instead of per-row calls.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::data::{libsvm, Dataset};
use crate::telemetry::{self, Counter};

use super::registry::{ModelEntry, Registry};
use super::scorer::{format_prediction, Scorer};

/// Front-end counters (global: one TCP server per process in practice).
struct ServerMetrics {
    connections: Arc<Counter>,
    protocol_errors: Arc<Counter>,
}

fn server_metrics() -> &'static ServerMetrics {
    static M: OnceLock<ServerMetrics> = OnceLock::new();
    M.get_or_init(|| ServerMetrics {
        connections: telemetry::global()
            .counter("serve_connections_total", "Accepted TCP serving connections."),
        protocol_errors: telemetry::global().counter(
            "serve_protocol_errors_total",
            "Error replies sent on the TCP protocol (bad rows, unknown models).",
        ),
    })
}

/// The `#metrics` reply body: the whole exposition plus the in-band
/// terminator line (the connection writer appends the final newline).
fn render_exposition() -> String {
    format!("{}# EOF", telemetry::global().render())
}

/// Serving knobs (see `pemsvm serve --help` text in `main.rs`).
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// dispatch a batch once this many rows are pending
    pub max_batch: usize,
    /// ... or once the oldest pending row has waited this long
    pub max_wait: Duration,
    /// scoring threads
    pub workers: usize,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts { max_batch: 256, max_wait: Duration::from_micros(1000), workers: 4 }
    }
}

/// What a protocol line asks for, queued in arrival order.
enum Payload {
    /// a parsed libsvm row to score
    Row(Vec<(u32, f32)>),
    /// a parse failure whose error reply must keep its queue position
    BadRow(String),
    /// the `#stats` verb, answered in order against the row stream
    Stats,
    /// the `#metrics` verb: the full exposition, ordered like `#stats`
    /// so the counters cover every row queued before it
    Metrics,
    /// the `#health` verb: training verdict + latency percentiles,
    /// ordered like `#stats`
    Health,
}

/// One protocol message en route to the dispatcher.
struct RowMsg {
    payload: Payload,
    entry: Arc<ModelEntry>,
    reply: Sender<String>,
}

/// Serve forever on `listener`. `default_model` names the registry
/// entry connections start on. Blocks the calling thread; tests run it
/// on a spawned thread and connect via `TcpStream`.
pub fn serve(
    listener: TcpListener,
    registry: Arc<Registry>,
    default_model: String,
    opts: ServeOpts,
) -> Result<()> {
    let (row_tx, row_rx) = mpsc::channel::<RowMsg>();
    let dispatcher_opts = opts.clone();
    let dispatcher = std::thread::spawn(move || dispatch_loop(row_rx, dispatcher_opts));

    // the accept loop is shared with the `pemsvm worker` daemon
    // (net::tcp); serving handles connections concurrently, so each one
    // moves to its own thread and the loop continues immediately
    crate::net::tcp::accept_loop(&listener, |stream, peer| {
        let registry = registry.clone();
        let default_model = default_model.clone();
        let row_tx = row_tx.clone();
        std::thread::spawn(move || {
            let _ = handle_conn(stream, &peer, &registry, &default_model, &row_tx);
        });
        crate::net::tcp::After::Continue
    });
    drop(row_tx);
    let _ = dispatcher.join();
    Ok(())
}

/// Read rows off one connection, forwarding them to the dispatcher and
/// pumping replies back through a per-connection writer thread (so slow
/// clients don't stall scoring).
fn handle_conn(
    stream: TcpStream,
    peer: &str,
    registry: &Registry,
    default_model: &str,
    row_tx: &Sender<RowMsg>,
) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    let (reply_tx, reply_rx) = mpsc::channel::<String>();
    let writer_thread = std::thread::spawn(move || {
        while let Ok(line) = reply_rx.recv() {
            if writer.write_all(line.as_bytes()).is_err() || writer.write_all(b"\n").is_err() {
                break;
            }
            let _ = writer.flush();
        }
    });

    server_metrics().connections.inc();
    crate::log_debug!("serve: connection from {peer} (default model `{default_model}`)");
    let mut entry = registry.get(default_model);
    for (lineno, line) in reader.lines().enumerate() {
        let Ok(line) = line else { break };
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        if let Some(ctl) = trimmed.strip_prefix('#') {
            let mut it = ctl.split_whitespace();
            match it.next() {
                Some("model") => match it.next().and_then(|n| registry.get(n)) {
                    Some(e) => entry = Some(e),
                    None => {
                        server_metrics().protocol_errors.inc();
                        let _ = reply_tx.send("error: unknown model".into());
                    }
                },
                Some("stats") => match entry.clone() {
                    // ordered behind any rows already queued, so the
                    // counters reflect everything sent before the verb
                    Some(entry) => {
                        let msg =
                            RowMsg { payload: Payload::Stats, entry, reply: reply_tx.clone() };
                        if row_tx.send(msg).is_err() {
                            break;
                        }
                    }
                    None => {
                        server_metrics().protocol_errors.inc();
                        let _ = reply_tx.send("error: no model selected".into());
                    }
                },
                Some("health") => match entry.clone() {
                    // ordered behind queued rows, like #stats, so the
                    // latency percentiles cover them
                    Some(entry) => {
                        let msg =
                            RowMsg { payload: Payload::Health, entry, reply: reply_tx.clone() };
                        if row_tx.send(msg).is_err() {
                            break;
                        }
                    }
                    None => {
                        server_metrics().protocol_errors.inc();
                        let _ = reply_tx.send("error: no model selected".into());
                    }
                },
                Some("metrics") => match entry.clone() {
                    // queued like #stats so the exposition covers every
                    // row this connection sent before the verb
                    Some(entry) => {
                        let msg =
                            RowMsg { payload: Payload::Metrics, entry, reply: reply_tx.clone() };
                        if row_tx.send(msg).is_err() {
                            break;
                        }
                    }
                    // the exposition needs no model: answer immediately
                    None => {
                        let _ = reply_tx.send(render_exposition());
                    }
                },
                _ => {} // comment; ignore
            }
            continue;
        }
        let Some(entry) = entry.clone() else {
            server_metrics().protocol_errors.inc();
            let _ = reply_tx.send("error: no model selected".into());
            continue;
        };
        let payload = match libsvm::parse_row(trimmed, lineno + 1) {
            Ok(Some((_label, pairs))) => Payload::Row(pairs),
            Ok(None) => continue,
            Err(e) => {
                server_metrics().protocol_errors.inc();
                Payload::BadRow(format!("error: {e:#}"))
            }
        };
        if row_tx.send(RowMsg { payload, entry, reply: reply_tx.clone() }).is_err() {
            break; // dispatcher gone: server shutting down
        }
    }
    drop(reply_tx);
    let _ = writer_thread.join();
    Ok(())
}

/// The micro-batching loop: block for the first row, then drain until
/// `max_batch` rows or `max_wait` elapsed, score, reply, repeat.
fn dispatch_loop(rx: Receiver<RowMsg>, opts: ServeOpts) {
    let mut scorer = Scorer::new(opts.workers);
    while let Ok(first) = rx.recv() {
        let deadline = Instant::now() + opts.max_wait;
        let mut rows = vec![first];
        while rows.len() < opts.max_batch {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                break;
            }
            match rx.recv_timeout(left) {
                Ok(msg) => rows.push(msg),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        score_and_reply(&mut scorer, rows);
    }
}

/// Score one drained block: group rows by target model entry, score
/// each group as one batch, then emit every reply in the block's
/// arrival order — so a connection that interleaves `#model` switches
/// within one micro-batch still gets its replies line-for-line.
fn score_and_reply(scorer: &mut Scorer, rows: Vec<RowMsg>) {
    let mut groups: Vec<(Arc<ModelEntry>, Vec<(usize, RowMsg)>)> = Vec::new();
    for (pos, row) in rows.into_iter().enumerate() {
        let idx = groups.iter().position(|(e, _)| Arc::ptr_eq(e, &row.entry));
        match idx {
            Some(i) => groups[i].1.push((pos, row)),
            None => {
                let entry = row.entry.clone();
                groups.push((entry, vec![(pos, row)]));
            }
        }
    }
    let mut replies: Vec<(usize, String, Sender<String>)> = Vec::new();
    for (entry, group) in groups {
        // the model was unloaded after these rows were queued (or after
        // the connection selected it): answer with a structured error
        // per row rather than scoring against the withdrawn model
        if entry.is_retired() {
            for (pos, row) in group {
                let msg = match &row.payload {
                    Payload::BadRow(e) => e.clone(),
                    // the exposition needs no model; still answerable
                    Payload::Metrics => render_exposition(),
                    Payload::Row(_) | Payload::Stats | Payload::Health => {
                        server_metrics().protocol_errors.inc();
                        format!("error: model `{}` unloaded", entry.name())
                    }
                };
                replies.push((pos, msg, row.reply));
            }
            continue;
        }
        let model = entry.current();
        // assemble the scorable rows into one CSR batch, wide enough
        // for the model and for any stray larger feature index
        let mut kmax = model.meta.k;
        let mut indptr = vec![0usize];
        let (mut indices, mut values) = (Vec::new(), Vec::new());
        let mut n_rows = 0usize;
        for (_, row) in &group {
            let Payload::Row(pairs) = &row.payload else { continue };
            for &(j, v) in pairs {
                kmax = kmax.max(j as usize + 1);
                indices.push(j);
                values.push(v);
            }
            indptr.push(indices.len());
            n_rows += 1;
        }
        let labels = vec![0f32; n_rows];
        let batch =
            Arc::new(Dataset::sparse(indptr, indices, values, labels, kmax, model.data_task()));
        let scored = scorer.score_batch(&model, &batch);
        if let Ok(out) = &scored {
            if batch.n > 0 {
                entry.stats.record(batch.n, out.wall);
            }
        }
        let empty: [f32; 0] = [];
        let mut scores = match &scored {
            Ok(out) => out.scores.iter(),
            Err(_) => empty.iter(),
        };
        for (pos, row) in group {
            let msg = match (&row.payload, &scored) {
                (Payload::Row(_), Ok(_)) => {
                    let &s = scores.next().expect("one score per scored row");
                    format_prediction(model.meta.task, s)
                }
                (Payload::Row(_), Err(e)) => format!("error: {e:#}"),
                (Payload::BadRow(e), _) => e.clone(),
                (Payload::Stats, _) => {
                    format!("stats {}: {}", entry.name(), entry.stats.snapshot().report())
                }
                (Payload::Health, _) => {
                    // training verdict from the model header plus live
                    // scorer-latency percentiles (bucket upper bounds of
                    // the batch-latency histogram, DESIGN.md §14)
                    let verdict = model.meta.verdict.map_or("unknown", |v| v.name());
                    let lat = entry.stats.latency_snapshot();
                    format!(
                        "health {}: verdict={verdict} batches={} p50={}us p90={}us p99={}us",
                        entry.name(),
                        lat.count(),
                        lat.quantile(0.5) / 1_000,
                        lat.quantile(0.9) / 1_000,
                        lat.quantile(0.99) / 1_000,
                    )
                }
                // multi-line reply: the per-connection writer sends the
                // whole block plus the trailing newline in one message
                (Payload::Metrics, _) => render_exposition(),
            };
            replies.push((pos, msg, row.reply));
        }
    }
    // predictions, queued parse errors, and stats snapshots interleave
    // exactly as the clients sent them
    replies.sort_unstable_by_key(|(pos, ..)| *pos);
    for (_, msg, reply) in replies {
        let _ = reply.send(msg);
    }
}
