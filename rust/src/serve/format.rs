//! The versioned on-disk model format.
//!
//! `pemsvm-model v1` is a line-oriented text format with a typed header
//! (task, K, M, lambda, the training options string) followed by one
//! body block — linear weights or a kernel model (kernel config, dual
//! coefficients, support vectors as libsvm rows). It replaces the
//! untyped `model.txt` dump: every count in the header is validated on
//! load, non-finite values are rejected, and a trailing `end` sentinel
//! guards against truncated files. The pre-v1 headers
//! (`# pemsvm single N` / `# pemsvm perclass R C`) keep a read-path so
//! existing model files still load.
//!
//! f32 values are written with Rust's shortest-roundtrip `Display`, so
//! save -> load -> predict is bit-identical to the in-memory model.

use std::path::Path;
use std::sync::OnceLock;

use anyhow::{bail, Context, Result};

use crate::config::{KernelCfg, TaskKind, TrainConfig};
use crate::data::{libsvm, Dataset, Task};
use crate::linalg::Mat;
use crate::model::Weights;
use crate::solver::KernelModel;
use crate::telemetry::HealthVerdict;

/// Format version written by [`save`].
pub const FORMAT_VERSION: u32 = 1;

/// Typed header: everything the serving path needs to interpret the
/// body without side-channel flags.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub task: TaskKind,
    /// feature dimension the model was trained on
    pub k: usize,
    /// number of classes (1 for CLS/SVR)
    pub m: usize,
    pub lambda: f32,
    /// the paper's option string, e.g. "LIN-EM-CLS"
    pub options: String,
    /// training convergence verdict (DESIGN.md §14), stamped when the
    /// run used `--diag-every`; the serve `#health` verb reports it
    pub verdict: Option<HealthVerdict>,
    /// true when loaded through the pre-v1 `model.txt` read-path (the
    /// old header carries no task, so callers may override it)
    pub legacy: bool,
}

/// The learned parameters behind the header.
#[derive(Debug)]
pub enum ModelBody {
    Linear(Weights),
    Kernel(KernelModel),
}

/// A model as it exists on disk / in the registry.
#[derive(Debug)]
pub struct SavedModel {
    pub meta: ModelMeta,
    pub body: ModelBody,
    /// per-class weights transposed to `[k, m]`, built lazily once per
    /// model (the scorer's hot path; the model is immutable behind its
    /// registry `Arc`, so per-batch recomputation would be pure waste)
    wt: OnceLock<Mat>,
}

impl SavedModel {
    pub fn new(meta: ModelMeta, body: ModelBody) -> SavedModel {
        SavedModel { meta, body, wt: OnceLock::new() }
    }

    /// The transposed `[k, m]` Crammer-Singer weights for blockwise
    /// scoring, or `None` for single-vector and kernel bodies.
    pub fn transposed_weights(&self) -> Option<&Mat> {
        match &self.body {
            ModelBody::Linear(Weights::PerClass(w)) => {
                Some(self.wt.get_or_init(|| w.transpose()))
            }
            _ => None,
        }
    }

    /// Wrap a training output for saving: the kernel model when the run
    /// produced one, the linear weights otherwise.
    pub fn from_training(
        cfg: &TrainConfig,
        k: usize,
        out: crate::engine::TrainOutput,
    ) -> SavedModel {
        let m = match cfg.task {
            TaskKind::Mlt => cfg.num_classes,
            _ => 1,
        };
        let meta = ModelMeta {
            task: cfg.task,
            k,
            m,
            lambda: cfg.lambda,
            options: cfg.options_string(),
            verdict: out.verdict,
            legacy: false,
        };
        let body = match out.kernel_model {
            Some(km) => ModelBody::Kernel(km),
            None => ModelBody::Linear(out.weights),
        };
        SavedModel::new(meta, body)
    }

    /// The dataset task this model predicts for.
    pub fn data_task(&self) -> Task {
        match self.meta.task {
            TaskKind::Cls => Task::Binary,
            TaskKind::Svr => Task::Regression,
            TaskKind::Mlt => Task::Multiclass(self.meta.m),
        }
    }
}

fn task_name(t: TaskKind) -> &'static str {
    match t {
        TaskKind::Cls => "cls",
        TaskKind::Svr => "svr",
        TaskKind::Mlt => "mlt",
    }
}

fn parse_task(s: &str) -> Result<TaskKind> {
    Ok(match s {
        "cls" => TaskKind::Cls,
        "svr" => TaskKind::Svr,
        "mlt" => TaskKind::Mlt,
        other => bail!("bad task `{other}` in model header"),
    })
}

/// Write `model` to `path` in the v1 format.
pub fn save(model: &SavedModel, path: &Path) -> Result<()> {
    use std::io::Write;
    let f = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = std::io::BufWriter::new(f);
    let meta = &model.meta;
    writeln!(w, "pemsvm-model v{FORMAT_VERSION}")?;
    writeln!(w, "task {}", task_name(meta.task))?;
    writeln!(w, "k {}", meta.k)?;
    writeln!(w, "m {}", meta.m)?;
    writeln!(w, "lambda {}", meta.lambda)?;
    writeln!(w, "options {}", meta.options)?;
    // optional: only runs trained with --diag-every carry a verdict, so
    // default-trained model files stay byte-identical to pre-diag ones
    if let Some(v) = meta.verdict {
        writeln!(w, "verdict {}", v.name())?;
    }
    match &model.body {
        ModelBody::Linear(Weights::Single(v)) => {
            writeln!(w, "weights single {}", v.len())?;
            for x in v {
                writeln!(w, "{x}")?;
            }
        }
        ModelBody::Linear(Weights::PerClass(mat)) => {
            writeln!(w, "weights perclass {} {}", mat.rows, mat.cols)?;
            for x in &mat.data {
                writeln!(w, "{x}")?;
            }
        }
        ModelBody::Kernel(km) => {
            match km.cfg {
                KernelCfg::Gaussian { sigma } => writeln!(w, "kernel gaussian {sigma}")?,
                KernelCfg::LinearK => writeln!(w, "kernel linear")?,
            }
            // only rows with nonzero dual coefficient are support
            // vectors; decision() skips the rest, so pruning them is
            // prediction-identical and shrinks the file
            let sv: Vec<usize> = (0..km.train.n).filter(|&d| km.omega[d] != 0.0).collect();
            writeln!(w, "support {} {}", sv.len(), km.train.k)?;
            writeln!(w, "omega {}", sv.len())?;
            for &d in &sv {
                writeln!(w, "{}", km.omega[d])?;
            }
            let mut io_err = None;
            for &d in &sv {
                write!(w, "{}", km.train.labels[d])?;
                km.train.for_nonzero(d, |j, v| {
                    if let Err(e) = write!(w, " {}:{v}", j + 1) {
                        io_err = Some(e);
                    }
                });
                if let Some(e) = io_err.take() {
                    return Err(e.into());
                }
                writeln!(w)?;
            }
        }
    }
    writeln!(w, "end")?;
    Ok(())
}

/// Line cursor over the model file.
struct Lines<'a> {
    it: std::str::Lines<'a>,
    lineno: usize,
}

impl<'a> Lines<'a> {
    fn line(&mut self, what: &str) -> Result<&'a str> {
        self.lineno += 1;
        self.it.next().with_context(|| format!("model file truncated: expected {what}"))
    }

    /// Read `n` finite f32 values, one per line. The capacity hint is
    /// capped: `n` comes from an untrusted header, and a corrupt count
    /// should surface as a truncation error, not an allocation abort.
    fn values(&mut self, n: usize, what: &str) -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(n.min(1 << 16));
        for i in 0..n {
            let line = self.line(what)?;
            let x: f32 = line
                .trim()
                .parse()
                .with_context(|| format!("line {}: bad {what} value `{line}`", self.lineno))?;
            if !x.is_finite() {
                bail!("line {}: non-finite {what} value `{x}` (index {i})", self.lineno);
            }
            out.push(x);
        }
        Ok(out)
    }
}

/// Load a model in either the v1 format or the legacy `model.txt`
/// format (auto-detected from the first line).
pub fn load(path: &Path) -> Result<SavedModel> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read model {}", path.display()))?;
    let first = text.lines().next().unwrap_or("");
    if first.starts_with("# pemsvm ") {
        return load_legacy(&text);
    }
    if !first.starts_with("pemsvm-model ") {
        bail!("not a pemsvm model file (header `{first}`)");
    }
    let version: u32 = first
        .trim_start_matches("pemsvm-model ")
        .trim_start_matches('v')
        .trim()
        .parse()
        .with_context(|| format!("bad model version in `{first}`"))?;
    if version > FORMAT_VERSION {
        bail!("model format v{version} is newer than this binary (max v{FORMAT_VERSION})");
    }

    let mut ls = Lines { it: text.lines(), lineno: 0 };
    ls.line("header")?; // skip the version line we already parsed

    // fixed header fields, in order
    let mut field = |name: &str| -> Result<String> {
        let line = ls.line(name)?;
        let (key, val) = line
            .split_once(' ')
            .with_context(|| format!("line {}: expected `{name} <value>`", ls.lineno))?;
        if key != name {
            bail!("line {}: expected `{name}`, found `{key}`", ls.lineno);
        }
        Ok(val.trim().to_string())
    };
    let task = parse_task(&field("task")?)?;
    let k: usize = field("k")?.parse().context("bad k")?;
    let m: usize = field("m")?.parse().context("bad m")?;
    let lambda: f32 = field("lambda")?.parse().context("bad lambda")?;
    let options = field("options")?;

    // the optional `verdict` header line sits between the fixed fields
    // and the body block
    let mut body_line = ls.line("weights/kernel block")?;
    let verdict = match body_line.strip_prefix("verdict ") {
        Some(rest) => {
            let v = HealthVerdict::parse(rest.trim())
                .with_context(|| format!("line {}: bad verdict `{rest}`", ls.lineno))?;
            body_line = ls.line("weights/kernel block")?;
            Some(v)
        }
        None => None,
    };
    let meta = ModelMeta { task, k, m, lambda, options, verdict, legacy: false };
    let parts: Vec<&str> = body_line.split_whitespace().collect();
    let body = match parts.as_slice() {
        ["weights", "single", n] => {
            let n: usize = n.parse().context("bad single length")?;
            let vals = ls.values(n, "weight")?;
            ModelBody::Linear(Weights::Single(vals))
        }
        ["weights", "perclass", r, c] => {
            let rows: usize = r.parse().context("bad perclass rows")?;
            let cols: usize = c.parse().context("bad perclass cols")?;
            let count = rows
                .checked_mul(cols)
                .with_context(|| format!("perclass shape {rows}x{cols} overflows"))?;
            let vals = ls.values(count, "weight")?;
            let mut mat = Mat::zeros(rows, cols);
            mat.data.copy_from_slice(&vals);
            ModelBody::Linear(Weights::PerClass(mat))
        }
        ["kernel", rest @ ..] => {
            let cfg = match rest {
                ["gaussian", s] => {
                    let sigma: f32 = s.parse().context("bad kernel sigma")?;
                    if !(sigma.is_finite() && sigma > 0.0) {
                        bail!("bad kernel sigma {sigma}");
                    }
                    KernelCfg::Gaussian { sigma }
                }
                ["linear"] => KernelCfg::LinearK,
                other => bail!("bad kernel line `kernel {}`", other.join(" ")),
            };
            let sup = ls.line("support header")?;
            let (n_sv, sv_k) = match sup.split_whitespace().collect::<Vec<_>>().as_slice() {
                ["support", n, kk] => (
                    n.parse::<usize>().context("bad support count")?,
                    kk.parse::<usize>().context("bad support k")?,
                ),
                _ => bail!("line {}: expected `support <n> <k>`", ls.lineno),
            };
            let om = ls.line("omega header")?;
            match om.split_whitespace().collect::<Vec<_>>().as_slice() {
                ["omega", n] if n.parse::<usize>().ok() == Some(n_sv) => {}
                _ => bail!("line {}: expected `omega {n_sv}`", ls.lineno),
            }
            let omega = ls.values(n_sv, "omega")?;
            let mut indptr = vec![0usize];
            let (mut indices, mut values, mut labels) = (Vec::new(), Vec::new(), Vec::new());
            for _ in 0..n_sv {
                let line = ls.line("support vector row")?;
                let (label, pairs) = libsvm::parse_row(line, ls.lineno)?
                    .with_context(|| format!("line {}: empty support vector row", ls.lineno))?;
                labels.push(label);
                for (j, v) in pairs {
                    if j as usize >= sv_k {
                        bail!(
                            "line {}: support vector index {} out of range (k={sv_k})",
                            ls.lineno,
                            j + 1
                        );
                    }
                    if !v.is_finite() {
                        bail!("line {}: non-finite support vector value", ls.lineno);
                    }
                    indices.push(j);
                    values.push(v);
                }
                indptr.push(indices.len());
            }
            let train = Dataset::sparse(indptr, indices, values, labels, sv_k, Task::Binary);
            ModelBody::Kernel(KernelModel { train, omega, cfg })
        }
        _ => bail!("bad body header `{body_line}`"),
    };
    let tail = ls.line("`end` sentinel")?;
    if tail.trim() != "end" {
        bail!("line {}: expected `end`, found `{tail}` (corrupt model?)", ls.lineno);
    }

    // cross-check the body against the header
    match &body {
        ModelBody::Linear(Weights::Single(v)) => {
            if v.len() != meta.k {
                bail!("header says k={}, single weights have {} values", meta.k, v.len());
            }
        }
        ModelBody::Linear(Weights::PerClass(w)) => {
            if w.rows != meta.m || w.cols != meta.k {
                bail!(
                    "header says m={} k={}, perclass weights are {}x{}",
                    meta.m,
                    meta.k,
                    w.rows,
                    w.cols
                );
            }
        }
        ModelBody::Kernel(km) => {
            if km.train.k != meta.k {
                bail!("header says k={}, support vectors have k={}", meta.k, km.train.k);
            }
        }
    }
    Ok(SavedModel::new(meta, body))
}

/// The pre-v1 `model.txt` read-path: `# pemsvm single N` /
/// `# pemsvm perclass R C`, values one per line. Unlike the old
/// `load_weights` in `main.rs`, the declared count is validated for
/// *both* layouts (the old code only checked `perclass`).
fn load_legacy(text: &str) -> Result<SavedModel> {
    let mut lines = text.lines();
    let header = lines.next().context("empty model file")?;
    let parts: Vec<&str> = header.split_whitespace().collect();
    let mut vals = Vec::new();
    for (off, line) in lines.enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let x: f32 = line
            .parse()
            .with_context(|| format!("line {}: bad weight `{line}`", off + 2))?;
        if !x.is_finite() {
            bail!("line {}: non-finite weight `{x}`", off + 2);
        }
        vals.push(x);
    }
    let (weights, k, m) = match parts.get(2) {
        Some(&"single") => {
            let n: usize = parts
                .get(3)
                .context("legacy single header missing length")?
                .parse()
                .context("bad length in legacy header")?;
            if vals.len() != n {
                bail!("model file: header declares {n} values, got {}", vals.len());
            }
            (Weights::Single(vals), n, 1)
        }
        Some(&"perclass") => {
            let rows: usize = parts.get(3).context("legacy perclass header missing rows")?.parse()?;
            let cols: usize = parts.get(4).context("legacy perclass header missing cols")?.parse()?;
            if vals.len() != rows * cols {
                bail!("model file: expected {} values, got {}", rows * cols, vals.len());
            }
            let mut mat = Mat::zeros(rows, cols);
            mat.data.copy_from_slice(&vals);
            (Weights::PerClass(mat), cols, rows)
        }
        _ => bail!("bad model header `{header}`"),
    };
    let task = if m > 1 { TaskKind::Mlt } else { TaskKind::Cls };
    Ok(SavedModel::new(
        ModelMeta {
            task,
            k,
            m,
            lambda: f32::NAN,
            options: String::new(),
            verdict: None,
            legacy: true,
        },
        ModelBody::Linear(weights),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("pemsvm_format_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn legacy_single_count_validated() {
        let p = tmp("legacy_bad.txt");
        std::fs::write(&p, "# pemsvm single 3\n1.0\n2.0\n").unwrap();
        let err = load(&p).unwrap_err().to_string();
        assert!(err.contains("declares 3"), "{err}");
        std::fs::write(&p, "# pemsvm single 2\n1.0\n2.0\n").unwrap();
        let m = load(&p).unwrap();
        assert!(m.meta.legacy);
        match m.body {
            ModelBody::Linear(Weights::Single(v)) => assert_eq!(v, vec![1.0, 2.0]),
            _ => panic!("wrong body"),
        }
    }

    #[test]
    fn legacy_perclass_count_validated() {
        let p = tmp("legacy_pc.txt");
        std::fs::write(&p, "# pemsvm perclass 2 2\n1\n2\n3\n").unwrap();
        assert!(load(&p).is_err());
        std::fs::write(&p, "# pemsvm perclass 2 2\n1\n2\n3\n4\n").unwrap();
        let m = load(&p).unwrap();
        assert_eq!(m.meta.m, 2);
        assert_eq!(m.meta.k, 2);
    }

    #[test]
    fn rejects_foreign_and_truncated_files() {
        let p = tmp("foreign.txt");
        std::fs::write(&p, "hello world\n").unwrap();
        assert!(load(&p).is_err());
        std::fs::write(
            &p,
            concat!(
                "pemsvm-model v1\ntask cls\nk 2\nm 1\nlambda 1\n",
                "options LIN-EM-CLS\nweights single 2\n0.5\n"
            ),
        )
        .unwrap();
        let err = load(&p).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
    }

    #[test]
    fn rejects_nan_weight() {
        let p = tmp("nan.txt");
        std::fs::write(
            &p,
            concat!(
                "pemsvm-model v1\ntask cls\nk 2\nm 1\nlambda 1\n",
                "options LIN-EM-CLS\nweights single 2\nNaN\n1.0\nend\n"
            ),
        )
        .unwrap();
        let err = load(&p).unwrap_err().to_string();
        assert!(err.contains("non-finite"), "{err}");
    }

    #[test]
    fn verdict_header_roundtrips_and_stays_optional() {
        let p = tmp("verdict.txt");
        let meta = ModelMeta {
            task: TaskKind::Cls,
            k: 2,
            m: 1,
            lambda: 1.0,
            options: "LIN-MC-CLS".into(),
            verdict: Some(HealthVerdict::Healthy),
            legacy: false,
        };
        let model = SavedModel::new(meta, ModelBody::Linear(Weights::Single(vec![0.5, -0.25])));
        save(&model, &p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.contains("\nverdict healthy\n"));
        let loaded = load(&p).unwrap();
        assert_eq!(loaded.meta.verdict, Some(HealthVerdict::Healthy));

        // without a verdict the header line is absent entirely
        let q = tmp("no_verdict.txt");
        let mut meta2 = loaded.meta.clone();
        meta2.verdict = None;
        let model2 =
            SavedModel::new(meta2, ModelBody::Linear(Weights::Single(vec![0.5, -0.25])));
        save(&model2, &q).unwrap();
        let text2 = std::fs::read_to_string(&q).unwrap();
        assert!(!text2.contains("verdict"));
        assert_eq!(load(&q).unwrap().meta.verdict, None);

        // a corrupt verdict value is rejected, not ignored
        std::fs::write(&p, text.replace("verdict healthy", "verdict sideways")).unwrap();
        assert!(load(&p).unwrap_err().to_string().contains("bad verdict"));
    }

    #[test]
    fn rejects_newer_version() {
        let p = tmp("v99.txt");
        std::fs::write(&p, "pemsvm-model v99\n").unwrap();
        assert!(load(&p).unwrap_err().to_string().contains("newer"));
    }
}
