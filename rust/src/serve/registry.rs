//! The model registry: many named models in memory, hot-reloadable.
//!
//! Each name maps to a long-lived [`ModelEntry`]; the entry holds the
//! current [`SavedModel`] behind an `Arc` that is *swapped*, never
//! mutated. A scoring request clones the `Arc` once at dispatch time
//! ([`ModelEntry::current`]) and keeps scoring against that snapshot
//! even if [`Registry::publish`] replaces the model mid-flight — the
//! old version is freed when the last in-flight request drops its
//! clone. Per-model serving counters ([`ModelStats`]) live in the
//! global telemetry registry keyed by model name, so they survive hot
//! reloads — including a full unload + republish cycle.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::telemetry::{self, Counter, Gauge, Histogram};

use super::format::{self, SavedModel};

/// Per-model serving counters, backed by the global telemetry registry
/// (DESIGN.md §12) and keyed by model **name**, not registry slot: a
/// model that is unloaded and re-published — even through a different
/// [`Registry`] in the same process — re-registers onto the same
/// monotone series, so `#stats` / `#metrics` counts never reset across
/// hot reloads (`tests/serve_roundtrip.rs` pins this).
pub struct ModelStats {
    rows: Arc<Counter>,
    batches: Arc<Counter>,
    busy_nanos: Arc<Counter>,
    /// value = last batch latency (ns); peak = worst batch
    batch_nanos: Arc<Gauge>,
    latency: Arc<Histogram>,
}

impl ModelStats {
    /// Get-or-register the serving series for `model` in the global
    /// telemetry registry.
    pub fn for_model(model: &str) -> ModelStats {
        let reg = telemetry::global();
        let l = telemetry::label("model", model);
        ModelStats {
            rows: reg.counter_labeled(
                "predict_requests_total",
                &l,
                "Rows scored through the serve and predict paths.",
            ),
            batches: reg.counter_labeled(
                "predict_batches_total",
                &l,
                "Micro-batches handed to the scorer.",
            ),
            busy_nanos: reg.counter_labeled(
                "predict_busy_nanos_total",
                &l,
                "Wall-clock nanoseconds spent inside the scorer.",
            ),
            batch_nanos: reg.gauge_labeled(
                "predict_batch_nanos",
                &l,
                "Latency of the most recent scored batch in nanoseconds (peak = worst batch).",
            ),
            latency: reg.histogram_labeled(
                "predict_batch_latency_nanos",
                &l,
                "Scored-batch latency distribution in nanoseconds.",
            ),
        }
    }

    /// Record one scored batch of `rows` rows that took `elapsed`.
    pub fn record(&self, rows: usize, elapsed: Duration) {
        let nanos = elapsed.as_nanos() as u64;
        self.batches.inc();
        self.rows.add(rows as u64);
        self.busy_nanos.add(nanos);
        self.batch_nanos.set(nanos as usize);
        self.latency.observe(nanos);
    }

    pub fn snapshot(&self) -> ServeSnapshot {
        ServeSnapshot {
            batches: self.batches.get(),
            rows: self.rows.get(),
            busy: Duration::from_nanos(self.busy_nanos.get()),
            max_batch: Duration::from_nanos(self.batch_nanos.peak() as u64),
        }
    }

    /// The batch-latency distribution (the `#health` verb derives its
    /// p50/p90/p99 from this).
    pub fn latency_snapshot(&self) -> crate::telemetry::HistogramSnapshot {
        self.latency.snapshot()
    }
}

/// A point-in-time read of [`ModelStats`].
#[derive(Clone, Copy, Debug)]
pub struct ServeSnapshot {
    pub batches: u64,
    pub rows: u64,
    /// total wall-clock spent inside the scorer
    pub busy: Duration,
    /// worst single-batch latency
    pub max_batch: Duration,
}

impl ServeSnapshot {
    /// Rows per second of scorer busy time (0 when idle).
    pub fn rows_per_sec(&self) -> f64 {
        let secs = self.busy.as_secs_f64();
        if secs > 0.0 {
            self.rows as f64 / secs
        } else {
            0.0
        }
    }

    /// One-line report for the `#stats` protocol verb and CLI prints.
    pub fn report(&self) -> String {
        let mean_us = if self.batches > 0 {
            self.busy.as_secs_f64() * 1e6 / self.batches as f64
        } else {
            0.0
        };
        format!(
            "batches={} rows={} busy={:.1}ms mean_batch={:.0}us max_batch={:.0}us \
             rows_per_sec={:.0}",
            self.batches,
            self.rows,
            self.busy.as_secs_f64() * 1e3,
            mean_us,
            self.max_batch.as_secs_f64() * 1e6,
            self.rows_per_sec()
        )
    }
}

/// A named registry slot: the swappable model + its lifetime counters.
pub struct ModelEntry {
    name: String,
    model: RwLock<Arc<SavedModel>>,
    /// requests/rows/latency counters, accumulated across reloads
    pub stats: ModelStats,
    /// how many times this slot has been (re)published
    versions: AtomicU64,
    /// set by [`Registry::unload`]: connections still holding this entry
    /// get a structured error instead of scores from a ghost model
    retired: AtomicBool,
}

impl ModelEntry {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Snapshot the current model. The returned `Arc` stays valid (and
    /// unchanged) for as long as the caller holds it, regardless of
    /// concurrent publishes.
    pub fn current(&self) -> Arc<SavedModel> {
        self.model.read().expect("model lock poisoned").clone()
    }

    /// Number of publishes into this slot (1 for a freshly loaded model).
    pub fn version(&self) -> u64 {
        self.versions.load(Ordering::Acquire)
    }

    /// Has this slot been removed from its registry? A connection (or a
    /// queued micro-batch) holding the entry across an unload should
    /// answer with an error, not score against the ghost model.
    pub fn is_retired(&self) -> bool {
        self.retired.load(Ordering::Acquire)
    }

    fn swap(&self, next: Arc<SavedModel>) {
        *self.model.write().expect("model lock poisoned") = next;
        self.versions.fetch_add(1, Ordering::AcqRel);
    }
}

/// Named model slots behind one lock. The map lock is held only for
/// lookup/insert; scoring holds no registry lock at all.
#[derive(Default)]
pub struct Registry {
    inner: RwLock<BTreeMap<String, Arc<ModelEntry>>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Publish `model` under `name`: a new slot if the name is unknown,
    /// an `Arc` swap on the existing slot (hot reload) otherwise.
    pub fn publish(&self, name: &str, model: SavedModel) -> Arc<ModelEntry> {
        let model = Arc::new(model);
        let mut map = self.inner.write().expect("registry lock poisoned");
        if let Some(entry) = map.get(name) {
            entry.swap(model);
            return entry.clone();
        }
        let entry = Arc::new(ModelEntry {
            name: name.to_string(),
            model: RwLock::new(model),
            stats: ModelStats::for_model(name),
            versions: AtomicU64::new(1),
            retired: AtomicBool::new(false),
        });
        map.insert(name.to_string(), entry.clone());
        entry
    }

    /// Load a model file and publish it under `name`.
    pub fn load_file(&self, name: &str, path: &Path) -> Result<Arc<ModelEntry>> {
        let model = format::load(path)
            .with_context(|| format!("loading model `{name}` from {}", path.display()))?;
        Ok(self.publish(name, model))
    }

    pub fn get(&self, name: &str) -> Option<Arc<ModelEntry>> {
        self.inner.read().expect("registry lock poisoned").get(name).cloned()
    }

    /// Remove a slot and mark its entry retired: requests still holding
    /// the entry (a connection that selected it, a micro-batch already
    /// queued) get a structured `error: model ... unloaded` reply
    /// instead of scores from a model the operator withdrew.
    pub fn unload(&self, name: &str) -> bool {
        match self.inner.write().expect("registry lock poisoned").remove(name) {
            Some(entry) => {
                entry.retired.store(true, Ordering::Release);
                true
            }
            None => false,
        }
    }

    pub fn names(&self) -> Vec<String> {
        self.inner.read().expect("registry lock poisoned").keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.inner.read().expect("registry lock poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TaskKind;
    use crate::model::Weights;
    use crate::serve::format::{ModelBody, ModelMeta};

    fn linear(w: Vec<f32>) -> SavedModel {
        SavedModel::new(
            ModelMeta {
                task: TaskKind::Cls,
                k: w.len(),
                m: 1,
                lambda: 1.0,
                options: "LIN-EM-CLS".into(),
                verdict: None,
                legacy: false,
            },
            ModelBody::Linear(Weights::Single(w)),
        )
    }

    #[test]
    fn publish_get_unload() {
        let reg = Registry::new();
        assert!(reg.is_empty());
        reg.publish("a", linear(vec![1.0]));
        reg.publish("b", linear(vec![2.0]));
        assert_eq!(reg.names(), vec!["a".to_string(), "b".to_string()]);
        assert!(reg.get("a").is_some());
        assert!(reg.get("c").is_none());
        assert!(reg.unload("a"));
        assert!(!reg.unload("a"));
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn hot_swap_preserves_in_flight_snapshot() {
        let reg = Registry::new();
        let entry = reg.publish("m", linear(vec![1.0, 2.0]));
        assert_eq!(entry.version(), 1);
        let in_flight = entry.current();
        // hot reload under the same name: same entry, new model Arc
        let entry2 = reg.publish("m", linear(vec![9.0, 9.0]));
        assert!(Arc::ptr_eq(&entry, &entry2));
        assert_eq!(entry.version(), 2);
        // the in-flight snapshot is untouched; new requests see v2
        match (&in_flight.body, &entry.current().body) {
            (ModelBody::Linear(Weights::Single(old)), ModelBody::Linear(Weights::Single(new))) => {
                assert_eq!(old, &vec![1.0, 2.0]);
                assert_eq!(new, &vec![9.0, 9.0]);
            }
            _ => panic!("wrong bodies"),
        }
    }

    #[test]
    fn unload_retires_held_entries() {
        let reg = Registry::new();
        let held = reg.publish("retire-me", linear(vec![1.0]));
        assert!(!held.is_retired());
        assert!(reg.unload("retire-me"));
        // the Arc we held across the unload is flagged...
        assert!(held.is_retired());
        // ...but a republish under the same name starts a fresh entry
        let fresh = reg.publish("retire-me", linear(vec![2.0]));
        assert!(!fresh.is_retired());
        assert!(held.is_retired(), "old entry stays retired");
    }

    #[test]
    fn stats_survive_unload_and_republish() {
        // the series is keyed by model name in the global telemetry
        // registry, so unload + republish (which allocates a brand-new
        // entry) keeps counting where the old entry left off
        let reg = Registry::new();
        let e1 = reg.publish("registry-continuity", linear(vec![1.0]));
        e1.stats.record(5, std::time::Duration::from_micros(10));
        assert!(reg.unload("registry-continuity"));
        let e2 = reg.publish("registry-continuity", linear(vec![2.0]));
        assert!(!Arc::ptr_eq(&e1, &e2));
        e2.stats.record(3, std::time::Duration::from_micros(10));
        let snap = e2.stats.snapshot();
        assert_eq!(snap.rows, 8);
        assert_eq!(snap.batches, 2);
        // the stale entry reads the same series
        assert_eq!(e1.stats.snapshot().rows, 8);
    }
}
