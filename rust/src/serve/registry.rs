//! The model registry: many named models in memory, hot-reloadable.
//!
//! Each name maps to a long-lived [`ModelEntry`]; the entry holds the
//! current [`SavedModel`] behind an `Arc` that is *swapped*, never
//! mutated. A scoring request clones the `Arc` once at dispatch time
//! ([`ModelEntry::current`]) and keeps scoring against that snapshot
//! even if [`Registry::publish`] replaces the model mid-flight — the
//! old version is freed when the last in-flight request drops its
//! clone. Per-model serving counters live on the entry (not the model)
//! so they survive hot reloads.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use anyhow::{Context, Result};

use crate::metrics::ServeStats;

use super::format::{self, SavedModel};

/// A named registry slot: the swappable model + its lifetime counters.
pub struct ModelEntry {
    name: String,
    model: RwLock<Arc<SavedModel>>,
    /// requests/rows/latency counters, accumulated across reloads
    pub stats: ServeStats,
    /// how many times this slot has been (re)published
    versions: AtomicU64,
}

impl ModelEntry {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Snapshot the current model. The returned `Arc` stays valid (and
    /// unchanged) for as long as the caller holds it, regardless of
    /// concurrent publishes.
    pub fn current(&self) -> Arc<SavedModel> {
        self.model.read().expect("model lock poisoned").clone()
    }

    /// Number of publishes into this slot (1 for a freshly loaded model).
    pub fn version(&self) -> u64 {
        self.versions.load(Ordering::Acquire)
    }

    fn swap(&self, next: Arc<SavedModel>) {
        *self.model.write().expect("model lock poisoned") = next;
        self.versions.fetch_add(1, Ordering::AcqRel);
    }
}

/// Named model slots behind one lock. The map lock is held only for
/// lookup/insert; scoring holds no registry lock at all.
#[derive(Default)]
pub struct Registry {
    inner: RwLock<BTreeMap<String, Arc<ModelEntry>>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Publish `model` under `name`: a new slot if the name is unknown,
    /// an `Arc` swap on the existing slot (hot reload) otherwise.
    pub fn publish(&self, name: &str, model: SavedModel) -> Arc<ModelEntry> {
        let model = Arc::new(model);
        let mut map = self.inner.write().expect("registry lock poisoned");
        if let Some(entry) = map.get(name) {
            entry.swap(model);
            return entry.clone();
        }
        let entry = Arc::new(ModelEntry {
            name: name.to_string(),
            model: RwLock::new(model),
            stats: ServeStats::default(),
            versions: AtomicU64::new(1),
        });
        map.insert(name.to_string(), entry.clone());
        entry
    }

    /// Load a model file and publish it under `name`.
    pub fn load_file(&self, name: &str, path: &Path) -> Result<Arc<ModelEntry>> {
        let model = format::load(path)
            .with_context(|| format!("loading model `{name}` from {}", path.display()))?;
        Ok(self.publish(name, model))
    }

    pub fn get(&self, name: &str) -> Option<Arc<ModelEntry>> {
        self.inner.read().expect("registry lock poisoned").get(name).cloned()
    }

    /// Remove a slot; in-flight requests holding the entry finish
    /// against their snapshot.
    pub fn unload(&self, name: &str) -> bool {
        self.inner.write().expect("registry lock poisoned").remove(name).is_some()
    }

    pub fn names(&self) -> Vec<String> {
        self.inner.read().expect("registry lock poisoned").keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.inner.read().expect("registry lock poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TaskKind;
    use crate::model::Weights;
    use crate::serve::format::{ModelBody, ModelMeta};

    fn linear(w: Vec<f32>) -> SavedModel {
        SavedModel::new(
            ModelMeta {
                task: TaskKind::Cls,
                k: w.len(),
                m: 1,
                lambda: 1.0,
                options: "LIN-EM-CLS".into(),
                legacy: false,
            },
            ModelBody::Linear(Weights::Single(w)),
        )
    }

    #[test]
    fn publish_get_unload() {
        let reg = Registry::new();
        assert!(reg.is_empty());
        reg.publish("a", linear(vec![1.0]));
        reg.publish("b", linear(vec![2.0]));
        assert_eq!(reg.names(), vec!["a".to_string(), "b".to_string()]);
        assert!(reg.get("a").is_some());
        assert!(reg.get("c").is_none());
        assert!(reg.unload("a"));
        assert!(!reg.unload("a"));
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn hot_swap_preserves_in_flight_snapshot() {
        let reg = Registry::new();
        let entry = reg.publish("m", linear(vec![1.0, 2.0]));
        assert_eq!(entry.version(), 1);
        let in_flight = entry.current();
        // hot reload under the same name: same entry, new model Arc
        let entry2 = reg.publish("m", linear(vec![9.0, 9.0]));
        assert!(Arc::ptr_eq(&entry, &entry2));
        assert_eq!(entry.version(), 2);
        // the in-flight snapshot is untouched; new requests see v2
        match (&in_flight.body, &entry.current().body) {
            (ModelBody::Linear(Weights::Single(old)), ModelBody::Linear(Weights::Single(new))) => {
                assert_eq!(old, &vec![1.0, 2.0]);
                assert_eq!(new, &vec![9.0, 9.0]);
            }
            _ => panic!("wrong bodies"),
        }
    }
}
