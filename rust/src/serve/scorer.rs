//! The batched scoring pool: persistent worker threads that shard an
//! incoming batch of rows and score them against a [`SavedModel`].
//!
//! Patterned on `engine::pool::Pool` (persistent threads, `Arc`-shared
//! request blocks, no per-call spawn): `score_batch` wraps the batch in
//! one `Arc<ScoreReq>`, sends each worker a row range, and splices the
//! per-range score vectors back in order. For Crammer-Singer models the
//! `[m, k]` weights are transposed **once per model** to `[k, m]`
//! (cached on the immutable [`SavedModel`]) and the workers run
//! [`crate::model::class_scores_block`] — a `[rows x K]`
//! block of contiguous row-major multiplies instead of the per-row
//! per-class scalar loop of `model::class_scores`.
//!
//! Every scoring path reproduces its one-shot twin bit-for-bit:
//! CLS/SVR margins match `Dataset::dot_row`, MLT scores match
//! `class_scores`, kernel decisions match `KernelModel::decision` —
//! the serve round-trip tests pin this down.

use std::ops::Range;
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::config::TaskKind;
use crate::data::{shard_ranges, Dataset};
use crate::linalg::Mat;
use crate::model::{self, Weights};

use super::format::{ModelBody, SavedModel};

/// One in-flight batch, shared by all workers through a single `Arc`.
struct ScoreReq {
    model: Arc<SavedModel>,
    batch: Arc<Dataset>,
}

enum Cmd {
    Score { req: Arc<ScoreReq>, range: Range<usize>, slot: usize },
    Stop,
}

struct Reply {
    slot: usize,
    scores: Result<Vec<f32>>,
    elapsed: Duration,
}

/// Raw scores for one batch, plus timing for the serving counters.
pub struct ScoredBatch {
    /// one score per row: signed margin (CLS), predicted value (SVR),
    /// argmax class index (MLT), kernel decision value (KRN)
    pub scores: Vec<f32>,
    /// wall-clock of the whole dispatch
    pub wall: Duration,
    /// max per-worker compute time (the §4.1-style parallel cost)
    pub compute_max: Duration,
}

/// A persistent pool of scoring threads.
pub struct Scorer {
    cmd_txs: Vec<Sender<Cmd>>,
    res_rx: Receiver<Reply>,
    handles: Vec<JoinHandle<()>>,
}

impl Scorer {
    /// Spawn `workers` scoring threads (at least one).
    pub fn new(workers: usize) -> Scorer {
        let p = workers.max(1);
        let (res_tx, res_rx) = mpsc::channel::<Reply>();
        let mut cmd_txs = Vec::with_capacity(p);
        let mut handles = Vec::with_capacity(p);
        for _ in 0..p {
            let (tx, rx) = mpsc::channel::<Cmd>();
            cmd_txs.push(tx);
            let res_tx = res_tx.clone();
            handles.push(std::thread::spawn(move || {
                while let Ok(cmd) = rx.recv() {
                    match cmd {
                        Cmd::Stop => break,
                        Cmd::Score { req, range, slot } => {
                            let t0 = Instant::now();
                            let mut out = vec![0f32; range.len()];
                            let scores = score_range(&req, range, &mut out).map(|()| out);
                            let elapsed = t0.elapsed();
                            drop(req);
                            if res_tx.send(Reply { slot, scores, elapsed }).is_err() {
                                break;
                            }
                        }
                    }
                }
            }));
        }
        Scorer { cmd_txs, res_rx, handles }
    }

    pub fn workers(&self) -> usize {
        self.cmd_txs.len()
    }

    /// Score every row of `batch` against `model`. Rows are sharded
    /// contiguously across the pool; the result is ordered like the
    /// batch.
    pub fn score_batch(
        &mut self,
        model: &Arc<SavedModel>,
        batch: &Arc<Dataset>,
    ) -> Result<ScoredBatch> {
        let t0 = Instant::now();
        let n = batch.n;
        // materialize the model's cached [k, m] transpose before the
        // fan-out so the workers don't race to build it
        let _ = model.transposed_weights();
        let req = Arc::new(ScoreReq { model: model.clone(), batch: batch.clone() });
        let shards: Vec<Range<usize>> = shard_ranges(n, self.workers())
            .into_iter()
            .map(|s| s.range)
            .filter(|r| !r.is_empty())
            .collect();
        for (slot, range) in shards.iter().enumerate() {
            self.cmd_txs[slot % self.cmd_txs.len()]
                .send(Cmd::Score { req: req.clone(), range: range.clone(), slot })
                .map_err(|_| anyhow!("scorer worker hung up"))?;
        }
        drop(req);
        let mut parts: Vec<Option<Vec<f32>>> = (0..shards.len()).map(|_| None).collect();
        let mut compute_max = Duration::ZERO;
        let mut first_err: Option<anyhow::Error> = None;
        // drain every reply even on error: a queued reply would leak
        // into the next batch on this persistent pool
        for _ in 0..shards.len() {
            let reply = self.res_rx.recv().context("scorer worker died")?;
            compute_max = compute_max.max(reply.elapsed);
            match reply.scores {
                Ok(s) => parts[reply.slot] = Some(s),
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        let mut scores = Vec::with_capacity(n);
        for p in parts {
            scores.extend(p.expect("scorer slot not filled"));
        }
        Ok(ScoredBatch { scores, wall: t0.elapsed(), compute_max })
    }
}

impl Drop for Scorer {
    fn drop(&mut self) {
        for tx in &self.cmd_txs {
            let _ = tx.send(Cmd::Stop);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Score `range` of the request's batch into `out` (len == range.len()).
fn score_range(req: &ScoreReq, range: Range<usize>, out: &mut [f32]) -> Result<()> {
    let ds = &*req.batch;
    match &req.model.body {
        ModelBody::Linear(Weights::Single(w)) => {
            if ds.k <= w.len() {
                // same code path as evaluate/dot_row: bit-identical sums
                for (o, d) in out.iter_mut().zip(range) {
                    *o = ds.dot_row(d, w);
                }
            } else {
                // rows wider than the model: extra features carry zero weight
                for (o, d) in out.iter_mut().zip(range) {
                    let mut s = 0f32;
                    ds.for_nonzero(d, |j, v| {
                        if (j as usize) < w.len() {
                            s += v * w[j as usize];
                        }
                    });
                    *o = s;
                }
            }
        }
        ModelBody::Linear(Weights::PerClass(_)) => {
            let wt = req
                .model
                .transposed_weights()
                .context("per-class model missing transposed weights")?;
            const BLOCK: usize = 128;
            let mut block = Mat::zeros(BLOCK.min(range.len().max(1)), wt.cols);
            let mut start = range.start;
            while start < range.end {
                let end = (start + BLOCK).min(range.end);
                let b = end - start;
                if block.rows != b {
                    block = Mat::zeros(b, wt.cols);
                }
                model::class_scores_block(ds, start..end, wt, &mut block);
                for r in 0..b {
                    out[start - range.start + r] = model::argmax(block.row(r)) as f32;
                }
                start = end;
            }
        }
        ModelBody::Kernel(km) => {
            let (mut bi, mut bj) = km.scratch(ds.k);
            for (o, d) in out.iter_mut().zip(range) {
                *o = km.decision_with(ds, d, &mut bi, &mut bj);
            }
        }
    }
    Ok(())
}

/// Map a raw score to the predicted label value for `task`:
/// CLS/KRN margin -> ±1, MLT argmax index, SVR value unchanged.
pub fn predicted_value(task: TaskKind, score: f32) -> f32 {
    match task {
        TaskKind::Cls => {
            if score > 0.0 {
                1.0
            } else {
                -1.0
            }
        }
        TaskKind::Svr | TaskKind::Mlt => score,
    }
}

/// Format one prediction for the `predict` output file and the TCP
/// protocol (integers print without a trailing `.0`).
pub fn format_prediction(task: TaskKind, score: f32) -> String {
    let v = predicted_value(task, score);
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// The evaluation metric of raw scores against ground-truth labels:
/// accuracy for CLS/MLT (the CLS rule `label * margin > 0` matches
/// `accuracy_cls` and `KernelModel::accuracy` exactly), RMSE for SVR
/// (same residual order as `model::rmse`).
pub fn metric_of(task: TaskKind, labels: &[f32], scores: &[f32]) -> f64 {
    debug_assert_eq!(labels.len(), scores.len());
    let n = labels.len().max(1) as f64;
    match task {
        TaskKind::Cls => {
            labels.iter().zip(scores).filter(|(&y, &s)| y * s > 0.0).count() as f64 / n
        }
        TaskKind::Mlt => {
            labels.iter().zip(scores).filter(|(&y, &s)| s == y).count() as f64 / n
        }
        TaskKind::Svr => {
            let mut acc = 0f64;
            for (&y, &s) in labels.iter().zip(scores) {
                let r = (y - s) as f64;
                acc += r * r;
            }
            (acc / n).sqrt()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TaskKind;
    use crate::data::synth;
    use crate::serve::format::{ModelBody, ModelMeta};

    fn linear_model(task: TaskKind, w: Weights, k: usize, m: usize) -> Arc<SavedModel> {
        Arc::new(SavedModel::new(
            ModelMeta {
                task,
                k,
                m,
                lambda: 1.0,
                options: String::new(),
                verdict: None,
                legacy: false,
            },
            ModelBody::Linear(w),
        ))
    }

    #[test]
    fn cls_scores_match_dot_row_for_any_worker_count() {
        let ds = Arc::new(synth::alpha_like(503, 12, 5));
        let mut g = crate::rng::Pcg64::new(3);
        let w: Vec<f32> = (0..12).map(|_| g.next_f32() - 0.5).collect();
        let model = linear_model(TaskKind::Cls, Weights::Single(w.clone()), 12, 1);
        for workers in [1usize, 3, 8] {
            let mut sc = Scorer::new(workers);
            let out = sc.score_batch(&model, &ds).unwrap();
            assert_eq!(out.scores.len(), ds.n);
            for d in 0..ds.n {
                assert_eq!(out.scores[d], ds.dot_row(d, &w), "worker={workers} row {d}");
            }
        }
    }

    #[test]
    fn mlt_argmax_matches_evaluate() {
        let ds = Arc::new(synth::mnist_like(400, 20, 6, 2));
        let mut g = crate::rng::Pcg64::new(4);
        let mut w = Mat::zeros(6, 20);
        for x in w.data.iter_mut() {
            *x = g.next_f32() - 0.5;
        }
        let weights = Weights::PerClass(w);
        let acc_ref = crate::model::evaluate(&ds, &weights);
        let model = linear_model(TaskKind::Mlt, weights, 20, 6);
        let mut sc = Scorer::new(4);
        let out = sc.score_batch(&model, &ds).unwrap();
        assert_eq!(metric_of(TaskKind::Mlt, &ds.labels, &out.scores), acc_ref);
    }

    #[test]
    fn empty_batch_and_wide_rows() {
        let empty = Arc::new(Dataset::sparse(
            vec![0],
            vec![],
            vec![],
            vec![],
            4,
            crate::data::Task::Binary,
        ));
        let model = linear_model(TaskKind::Cls, Weights::Single(vec![1.0, -1.0]), 2, 1);
        let mut sc = Scorer::new(2);
        assert!(sc.score_batch(&model, &empty).unwrap().scores.is_empty());
        // a batch wider than the model: extra features score zero
        let wide = Arc::new(Dataset::sparse(
            vec![0, 2],
            vec![0, 3],
            vec![2.0, 5.0],
            vec![1.0],
            4,
            crate::data::Task::Binary,
        ));
        let out = sc.score_batch(&model, &wide).unwrap();
        assert_eq!(out.scores, vec![2.0]);
    }

    #[test]
    fn prediction_formatting() {
        assert_eq!(format_prediction(TaskKind::Cls, 0.37), "1");
        assert_eq!(format_prediction(TaskKind::Cls, -2.0), "-1");
        assert_eq!(format_prediction(TaskKind::Cls, 0.0), "-1");
        assert_eq!(format_prediction(TaskKind::Mlt, 7.0), "7");
        assert_eq!(format_prediction(TaskKind::Svr, 1.5), "1.5");
        assert_eq!(format_prediction(TaskKind::Svr, 2.0), "2");
    }
}
