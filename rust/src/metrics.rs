//! Phase timers + counters. The phase set mirrors the rows of the
//! paper's Table 1 (Draw gamma / Calculate mu_p, Sigma_p / Reduce /
//! Draw mu / Broadcast mu) so the itertime bench can print an empirical
//! version of the asymptotic table.
//!
//! [`Metrics`] is the per-session training record (phase wall-clock,
//! iteration/reduce counts — accumulated by the engine, merged across
//! sessions for cluster-lifetime reports); span tracing diffs two
//! [`Metrics::phase_totals`] snapshots to attribute one iteration's
//! wall-clock (see [`crate::telemetry::span`]). [`Stopwatch`] is the
//! shared bench timer. The lock-free serving counters that used to
//! live here moved onto the telemetry registry
//! (`serve::registry::ModelStats`).

use std::time::{Duration, Instant};

/// Per-iteration phases, in Table-1 order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// gamma draw/update (EM Eq. 9 / MC Eq. 5)
    DrawGamma,
    /// local mu^p and Sigma^p accumulation (Eq. 40)
    LocalStats,
    /// partial-sum reduction to the leader
    Reduce,
    /// master solve / posterior draw (Eq. 6)
    DrawMu,
    /// w broadcast back to workers
    Broadcast,
    /// objective bookkeeping, stopping checks
    Other,
}

/// Number of [`Phase`]s (the width of [`Metrics::phase_totals`] and of
/// [`crate::telemetry::IterSpan::phase_secs`]).
pub const NPHASES: usize = 6;

pub const PHASES: [Phase; NPHASES] = [
    Phase::DrawGamma,
    Phase::LocalStats,
    Phase::Reduce,
    Phase::DrawMu,
    Phase::Broadcast,
    Phase::Other,
];

impl Phase {
    pub fn name(self) -> &'static str {
        match self {
            Phase::DrawGamma => "draw_gamma",
            Phase::LocalStats => "local_stats",
            Phase::Reduce => "reduce",
            Phase::DrawMu => "draw_mu",
            Phase::Broadcast => "broadcast",
            Phase::Other => "other",
        }
    }

    fn idx(self) -> usize {
        match self {
            Phase::DrawGamma => 0,
            Phase::LocalStats => 1,
            Phase::Reduce => 2,
            Phase::DrawMu => 3,
            Phase::Broadcast => 4,
            Phase::Other => 5,
        }
    }
}

/// Accumulated wall-clock per phase + iteration count.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    totals: [Duration; NPHASES],
    pub iterations: usize,
    /// number of reduce rounds (== collects; > iterations for MLT)
    pub reduces: usize,
    /// training sessions folded into this record (1 per `run_session`;
    /// grows under `merge` when aggregating a cluster's lifetime)
    pub sessions: usize,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, phase: Phase, d: Duration) {
        self.totals[phase.idx()] += d;
    }

    /// Time a closure into `phase`.
    pub fn time<T>(&mut self, phase: Phase, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(phase, t0.elapsed());
        out
    }

    pub fn total(&self, phase: Phase) -> Duration {
        self.totals[phase.idx()]
    }

    /// Point-in-time copy of the per-phase totals ([`PHASES`] order).
    /// Span tracing diffs two of these around an iteration to get that
    /// iteration's per-phase wall-clock.
    pub fn phase_totals(&self) -> [Duration; NPHASES] {
        self.totals
    }

    pub fn grand_total(&self) -> Duration {
        self.totals.iter().sum()
    }

    /// Merge another worker's metrics (phases accumulate; iterations max).
    pub fn merge(&mut self, other: &Metrics) {
        for (a, b) in self.totals.iter_mut().zip(&other.totals) {
            *a += *b;
        }
        self.iterations = self.iterations.max(other.iterations);
        self.reduces += other.reduces;
        self.sessions += other.sessions;
    }

    /// Simulated parallel wall-clock (seconds): per-iteration
    /// max-worker step time plus the serial reduce/solve/broadcast
    /// phases. Equals real wall-clock shape when workers run threaded on
    /// enough cores; under `Topology::Simulate` it is the cluster cost
    /// model's prediction.
    pub fn simulated_secs(&self) -> f64 {
        self.grand_total().as_secs_f64()
    }

    /// One-line report, Table-1 style.
    pub fn report(&self) -> String {
        let mut s = String::new();
        if self.sessions > 1 {
            s.push_str(&format!("sessions={} ", self.sessions));
        }
        s.push_str(&format!("iters={} ", self.iterations));
        for p in PHASES {
            let t = self.total(p);
            if !t.is_zero() {
                s.push_str(&format!("{}={:.1}ms ", p.name(), t.as_secs_f64() * 1e3));
            }
        }
        s.trim_end().to_string()
    }
}

/// Simple stopwatch for benches.
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }

    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_merges() {
        let mut m = Metrics::new();
        m.add(Phase::Reduce, Duration::from_millis(5));
        m.add(Phase::Reduce, Duration::from_millis(7));
        m.iterations = 3;
        let mut o = Metrics::new();
        o.add(Phase::Reduce, Duration::from_millis(1));
        o.iterations = 2;
        m.merge(&o);
        assert_eq!(m.total(Phase::Reduce), Duration::from_millis(13));
        assert_eq!(m.iterations, 3);
    }

    #[test]
    fn phase_totals_snapshot_diffs() {
        let mut m = Metrics::new();
        m.add(Phase::LocalStats, Duration::from_millis(4));
        let before = m.phase_totals();
        m.add(Phase::LocalStats, Duration::from_millis(6));
        m.add(Phase::Reduce, Duration::from_millis(1));
        let after = m.phase_totals();
        let delta: Vec<Duration> =
            after.iter().zip(before).map(|(a, b)| *a - b).collect();
        assert_eq!(delta[Phase::LocalStats.idx()], Duration::from_millis(6));
        assert_eq!(delta[Phase::Reduce.idx()], Duration::from_millis(1));
        assert_eq!(delta[Phase::DrawMu.idx()], Duration::ZERO);
    }

    #[test]
    fn time_closure() {
        let mut m = Metrics::new();
        let v = m.time(Phase::DrawMu, || 42);
        assert_eq!(v, 42);
        assert!(m.total(Phase::DrawMu) > Duration::ZERO);
        assert!(m.report().contains("draw_mu"));
    }
}
