//! Phase timers + counters. The phase set mirrors the rows of the
//! paper's Table 1 (Draw gamma / Calculate mu_p, Sigma_p / Reduce /
//! Draw mu / Broadcast mu) so the itertime bench can print an empirical
//! version of the asymptotic table.
//!
//! Two families live here: [`Metrics`] is the per-session training
//! record (phase wall-clock, iteration/reduce counts — accumulated by
//! the engine, merged across sessions for cluster-lifetime reports),
//! and [`ServeStats`]/[`ServeSnapshot`] are the lock-free monotonic
//! counters the serving registry hangs off every model entry
//! (DESIGN.md §9). [`Stopwatch`] is the shared bench timer.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Per-iteration phases, in Table-1 order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// gamma draw/update (EM Eq. 9 / MC Eq. 5)
    DrawGamma,
    /// local mu^p and Sigma^p accumulation (Eq. 40)
    LocalStats,
    /// partial-sum reduction to the leader
    Reduce,
    /// master solve / posterior draw (Eq. 6)
    DrawMu,
    /// w broadcast back to workers
    Broadcast,
    /// objective bookkeeping, stopping checks
    Other,
}

pub const PHASES: [Phase; 6] = [
    Phase::DrawGamma,
    Phase::LocalStats,
    Phase::Reduce,
    Phase::DrawMu,
    Phase::Broadcast,
    Phase::Other,
];

impl Phase {
    pub fn name(self) -> &'static str {
        match self {
            Phase::DrawGamma => "draw_gamma",
            Phase::LocalStats => "local_stats",
            Phase::Reduce => "reduce",
            Phase::DrawMu => "draw_mu",
            Phase::Broadcast => "broadcast",
            Phase::Other => "other",
        }
    }

    fn idx(self) -> usize {
        match self {
            Phase::DrawGamma => 0,
            Phase::LocalStats => 1,
            Phase::Reduce => 2,
            Phase::DrawMu => 3,
            Phase::Broadcast => 4,
            Phase::Other => 5,
        }
    }
}

/// Accumulated wall-clock per phase + iteration count.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    totals: [Duration; 6],
    pub iterations: usize,
    /// number of reduce rounds (== collects; > iterations for MLT)
    pub reduces: usize,
    /// training sessions folded into this record (1 per `run_session`;
    /// grows under `merge` when aggregating a cluster's lifetime)
    pub sessions: usize,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, phase: Phase, d: Duration) {
        self.totals[phase.idx()] += d;
    }

    /// Time a closure into `phase`.
    pub fn time<T>(&mut self, phase: Phase, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(phase, t0.elapsed());
        out
    }

    pub fn total(&self, phase: Phase) -> Duration {
        self.totals[phase.idx()]
    }

    pub fn grand_total(&self) -> Duration {
        self.totals.iter().sum()
    }

    /// Merge another worker's metrics (phases accumulate; iterations max).
    pub fn merge(&mut self, other: &Metrics) {
        for (a, b) in self.totals.iter_mut().zip(&other.totals) {
            *a += *b;
        }
        self.iterations = self.iterations.max(other.iterations);
        self.reduces += other.reduces;
        self.sessions += other.sessions;
    }

    /// Simulated parallel wall-clock (seconds): per-iteration
    /// max-worker step time plus the serial reduce/solve/broadcast
    /// phases. Equals real wall-clock shape when workers run threaded on
    /// enough cores; under `Topology::Simulate` it is the cluster cost
    /// model's prediction.
    pub fn simulated_secs(&self) -> f64 {
        self.grand_total().as_secs_f64()
    }

    /// One-line report, Table-1 style.
    pub fn report(&self) -> String {
        let mut s = String::new();
        if self.sessions > 1 {
            s.push_str(&format!("sessions={} ", self.sessions));
        }
        s.push_str(&format!("iters={} ", self.iterations));
        for p in PHASES {
            let t = self.total(p);
            if !t.is_zero() {
                s.push_str(&format!("{}={:.1}ms ", p.name(), t.as_secs_f64() * 1e3));
            }
        }
        s.trim_end().to_string()
    }
}

/// Lock-free serving counters: one per registry entry, shared by every
/// thread that scores against that model. All counters are monotonic;
/// a [`ServeSnapshot`] reads them at one instant for reporting.
#[derive(Debug, Default)]
pub struct ServeStats {
    batches: AtomicU64,
    rows: AtomicU64,
    busy_nanos: AtomicU64,
    max_batch_nanos: AtomicU64,
}

impl ServeStats {
    /// Record one scored batch of `rows` rows that took `elapsed`.
    pub fn record(&self, rows: usize, elapsed: Duration) {
        let nanos = elapsed.as_nanos() as u64;
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.rows.fetch_add(rows as u64, Ordering::Relaxed);
        self.busy_nanos.fetch_add(nanos, Ordering::Relaxed);
        self.max_batch_nanos.fetch_max(nanos, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> ServeSnapshot {
        ServeSnapshot {
            batches: self.batches.load(Ordering::Relaxed),
            rows: self.rows.load(Ordering::Relaxed),
            busy: Duration::from_nanos(self.busy_nanos.load(Ordering::Relaxed)),
            max_batch: Duration::from_nanos(self.max_batch_nanos.load(Ordering::Relaxed)),
        }
    }
}

/// A point-in-time read of [`ServeStats`].
#[derive(Clone, Copy, Debug)]
pub struct ServeSnapshot {
    pub batches: u64,
    pub rows: u64,
    /// total wall-clock spent inside the scorer
    pub busy: Duration,
    /// worst single-batch latency
    pub max_batch: Duration,
}

impl ServeSnapshot {
    /// Rows per second of scorer busy time (0 when idle).
    pub fn rows_per_sec(&self) -> f64 {
        let secs = self.busy.as_secs_f64();
        if secs > 0.0 {
            self.rows as f64 / secs
        } else {
            0.0
        }
    }

    /// One-line report for the `#stats` protocol verb and CLI prints.
    pub fn report(&self) -> String {
        let mean_us = if self.batches > 0 {
            self.busy.as_secs_f64() * 1e6 / self.batches as f64
        } else {
            0.0
        };
        format!(
            "batches={} rows={} busy={:.1}ms mean_batch={:.0}us max_batch={:.0}us \
             rows_per_sec={:.0}",
            self.batches,
            self.rows,
            self.busy.as_secs_f64() * 1e3,
            mean_us,
            self.max_batch.as_secs_f64() * 1e6,
            self.rows_per_sec()
        )
    }
}

/// Simple stopwatch for benches.
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }

    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_merges() {
        let mut m = Metrics::new();
        m.add(Phase::Reduce, Duration::from_millis(5));
        m.add(Phase::Reduce, Duration::from_millis(7));
        m.iterations = 3;
        let mut o = Metrics::new();
        o.add(Phase::Reduce, Duration::from_millis(1));
        o.iterations = 2;
        m.merge(&o);
        assert_eq!(m.total(Phase::Reduce), Duration::from_millis(13));
        assert_eq!(m.iterations, 3);
    }

    #[test]
    fn serve_stats_accumulate() {
        let s = ServeStats::default();
        s.record(10, Duration::from_micros(100));
        s.record(30, Duration::from_micros(300));
        let snap = s.snapshot();
        assert_eq!(snap.batches, 2);
        assert_eq!(snap.rows, 40);
        assert_eq!(snap.busy, Duration::from_micros(400));
        assert_eq!(snap.max_batch, Duration::from_micros(300));
        assert!((snap.rows_per_sec() - 100_000.0).abs() < 1.0);
        assert!(snap.report().contains("rows=40"));
    }

    #[test]
    fn time_closure() {
        let mut m = Metrics::new();
        let v = m.time(Phase::DrawMu, || 42);
        assert_eq!(v, 42);
        assert!(m.total(Phase::DrawMu) > Duration::ZERO);
        assert!(m.report().contains("draw_mu"));
    }
}
