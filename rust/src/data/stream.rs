//! Out-of-core libsvm ingestion (DESIGN.md §10).
//!
//! [`StreamReader`] walks a libsvm file in line-aligned windows of
//! `chunk_rows` data rows. A prefetch thread reads **and parses** chunk
//! `i + 1` while chunk `i` is consumed; the two sides meet on a
//! rendezvous channel, so at most two chunks of parsed rows are ever
//! resident (the double-buffering contract — [`Gauge`] tracks the
//! high-water mark and the equivalence tests pin the `2 x chunk`
//! bound). Row and feature counts are fixed **up front**, either by a
//! cheap counting pass over the file or by an explicit
//! [`StreamOpts::dims`] declaration, so shard boundaries can be
//! computed before the first row arrives.
//!
//! [`ShardBuilder`] is the receiving side: one per worker, each owning
//! a contiguous global row window. Feeding every chunk to every
//! builder in file order reassembles exactly the shards the eager
//! loader + [`shard_ranges`] would produce — same rows, same order,
//! same f32 values — which is why a streamed
//! [`Cluster::from_stream`](crate::engine::Cluster::from_stream) trains
//! bit-identically to an eager [`Cluster::new`](crate::engine::Cluster::new)
//! for a fixed seed (`tests/stream_equivalence.rs`).
//!
//! [`shard_ranges`]: super::shard_ranges

use std::fs::File;
use std::io::{BufRead, BufReader};
use std::ops::Range;
use std::path::Path;
use std::sync::mpsc::{self, Receiver, SyncSender};
use std::sync::{Arc, OnceLock};
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, Context, Result};

use super::{libsvm, Dataset, Task};
use crate::linalg::Mat;
use crate::model::Weights;
use crate::telemetry::{self, Counter};

/// Resident-row gauge shared by every [`ParsedChunk`] of one stream:
/// rows are counted in as they are parsed and counted out when the
/// chunk drops. `peak()` is the bench's peak-RSS proxy and the
/// equivalence test's `<= 2 x chunk` bound. (The type itself now lives
/// in [`crate::telemetry`]; re-exported here for the streaming API.)
pub use crate::telemetry::Gauge;

/// Stream-wide ingestion counters in the global telemetry registry.
struct StreamMetrics {
    chunks: Arc<Counter>,
    rows: Arc<Counter>,
}

fn stream_metrics() -> &'static StreamMetrics {
    static M: OnceLock<StreamMetrics> = OnceLock::new();
    M.get_or_init(|| StreamMetrics {
        chunks: telemetry::global()
            .counter("ingest_chunks_total", "Parsed chunks emitted by stream readers."),
        rows: telemetry::global()
            .counter("ingest_rows_total", "Data rows parsed by stream readers."),
    })
}

/// Streaming-ingestion knobs.
#[derive(Clone, Copy, Debug)]
pub struct StreamOpts {
    /// Data rows per chunk (the unit of prefetch; resident parsed rows
    /// are bounded by `2 * chunk_rows`).
    pub chunk_rows: usize,
    /// Declared `(rows, features)`. When given, the counting pass is
    /// skipped and the stream is validated against the declaration
    /// instead (more rows, fewer rows, or a feature index `>=
    /// features` all fail). Multiclass files are still scanned unless
    /// [`class_off`](StreamOpts::class_off) is also declared: the
    /// 0-based/1-based class-id offset needs the label minimum.
    pub dims: Option<(usize, usize)>,
    /// Known multiclass class-id offset (1.0 for 1-based files, 0.0
    /// for 0-based). Together with `dims` this skips the counting pass
    /// for MLT too — callers re-streaming a file they already scanned
    /// (metric passes, sweeps) carry it from
    /// [`StreamReader::class_off`]. Ignored for CLS/SVR.
    pub class_off: Option<f32>,
}

impl StreamOpts {
    /// Options with nothing declared: one counting pass fixes the dims.
    pub fn rows(chunk_rows: usize) -> Self {
        StreamOpts { chunk_rows, dims: None, class_off: None }
    }
}

/// One parsed window of the file: a CSR block of `len()` rows starting
/// at global row `start()`, labels already task-mapped (the same
/// mapping `libsvm::load` applies). Dropping the chunk releases its
/// rows from the stream's [`Gauge`].
pub struct ParsedChunk {
    start: usize,
    labels: Vec<f32>,
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f32>,
    gauge: Arc<Gauge>,
}

impl ParsedChunk {
    fn new(start: usize, gauge: Arc<Gauge>) -> Self {
        ParsedChunk {
            start,
            labels: Vec::new(),
            indptr: vec![0],
            indices: Vec::new(),
            values: Vec::new(),
            gauge,
        }
    }

    fn push_row(&mut self, label: f32, pairs: &[(u32, f32)]) {
        self.labels.push(label);
        for &(i, v) in pairs {
            self.indices.push(i);
            self.values.push(v);
        }
        self.indptr.push(self.indices.len());
        self.gauge.add(1);
    }

    /// Global row index of the first row in this chunk.
    pub fn start(&self) -> usize {
        self.start
    }

    /// Number of data rows in this chunk.
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Label of chunk-local row `r` (already task-mapped).
    pub fn label(&self, r: usize) -> f32 {
        self.labels[r]
    }

    /// `x_r . w` over the chunk-local CSR row `r` — the same
    /// accumulation order as [`Dataset::dot_row`]'s sparse arm, so
    /// streamed scores match eager ones bit for bit.
    pub fn dot_row(&self, r: usize, w: &[f32]) -> f32 {
        let mut s = 0.0;
        for p in self.indptr[r]..self.indptr[r + 1] {
            s += self.values[p] * w[self.indices[p] as usize];
        }
        s
    }

    /// `scores[c] = w_c . x_r`, mirroring [`crate::model::class_scores`]
    /// nonzero by nonzero (bit-identical scores).
    pub fn class_scores(&self, r: usize, w: &Mat, out: &mut [f32]) {
        out.fill(0.0);
        for p in self.indptr[r]..self.indptr[r + 1] {
            let (j, v) = (self.indices[p] as usize, self.values[p]);
            for (c, o) in out.iter_mut().enumerate() {
                *o += v * w[(c, j)];
            }
        }
    }

    /// Reassemble a chunk from its raw CSR parts — the receiving side of
    /// the wire protocol (`net::wire`, DESIGN.md §15): a `pemsvm worker`
    /// daemon decodes an `Ingest` frame back into the exact chunk the
    /// coordinator's reader produced, so streamed-over-TCP shards hold
    /// the same rows in the same order as in-process ones. The chunk
    /// gets its own resident-rows gauge (the stream's gauge lives in the
    /// sending process).
    pub fn from_parts(
        start: usize,
        labels: Vec<f32>,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f32>,
    ) -> Result<ParsedChunk> {
        if indptr.len() != labels.len() + 1 {
            bail!("chunk indptr length {} != rows + 1 ({})", indptr.len(), labels.len() + 1);
        }
        if indptr.first() != Some(&0) || indptr.windows(2).any(|w| w[0] > w[1]) {
            bail!("chunk indptr is not monotone from 0");
        }
        if *indptr.last().unwrap() != values.len() || indices.len() != values.len() {
            bail!(
                "chunk nnz mismatch: indptr ends at {}, {} indices, {} values",
                indptr.last().unwrap(),
                indices.len(),
                values.len()
            );
        }
        let gauge = Arc::new(Gauge::new());
        gauge.add(labels.len());
        Ok(ParsedChunk { start, labels, indptr, indices, values, gauge })
    }

    /// Raw CSR views for the wire encoder ([`from_parts`]'s inverse).
    ///
    /// [`from_parts`]: ParsedChunk::from_parts
    pub fn raw_parts(&self) -> (&[f32], &[usize], &[u32], &[f32]) {
        (&self.labels, &self.indptr, &self.indices, &self.values)
    }
}

impl Drop for ParsedChunk {
    fn drop(&mut self) {
        self.gauge.sub(self.labels.len());
    }
}

/// Accumulates one worker's shard from the chunk stream: the rows of
/// each arriving chunk that fall inside `window` are appended in file
/// order. [`build`](ShardBuilder::build) seals the shard into a
/// [`Dataset`] once every window row has arrived.
pub struct ShardBuilder {
    window: Range<usize>,
    k: usize,
    task: Task,
    labels: Vec<f32>,
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f32>,
}

impl ShardBuilder {
    /// A builder for the global row window `window` of an `N x k`
    /// corpus.
    pub fn new(window: Range<usize>, k: usize, task: Task) -> Self {
        ShardBuilder {
            window,
            k,
            task,
            labels: Vec::new(),
            indptr: vec![0],
            indices: Vec::new(),
            values: Vec::new(),
        }
    }

    /// Rows ingested so far.
    pub fn rows(&self) -> usize {
        self.labels.len()
    }

    /// Append the intersection of `chunk` with this builder's window.
    /// Chunks must arrive in file order (the reader emits them so).
    pub fn ingest(&mut self, chunk: &ParsedChunk) -> Result<()> {
        let lo = self.window.start.max(chunk.start);
        let hi = self.window.end.min(chunk.start + chunk.len());
        if lo >= hi {
            return Ok(());
        }
        let expected = self.window.start + self.labels.len();
        if lo != expected {
            bail!(
                "stream chunk out of order: shard {:?} expected global row {expected}, \
                 chunk covers {}..{}",
                self.window,
                chunk.start,
                chunk.start + chunk.len()
            );
        }
        for r in (lo - chunk.start)..(hi - chunk.start) {
            self.labels.push(chunk.labels[r]);
            let (a, b) = (chunk.indptr[r], chunk.indptr[r + 1]);
            self.indices.extend_from_slice(&chunk.indices[a..b]);
            self.values.extend_from_slice(&chunk.values[a..b]);
            self.indptr.push(self.indices.len());
        }
        Ok(())
    }

    /// Seal the shard. Fails if any window row never arrived.
    pub fn build(self) -> Result<Dataset> {
        if self.labels.len() != self.window.len() {
            bail!(
                "shard {:?} incomplete: ingested {} of {} rows",
                self.window,
                self.labels.len(),
                self.window.len()
            );
        }
        Ok(Dataset::sparse(self.indptr, self.indices, self.values, self.labels, self.k, self.task))
    }
}

/// Dimensions discovered by the counting pass.
struct ScanDims {
    rows: usize,
    k: usize,
    /// 1.0 when a multiclass file uses 1-based class ids (same
    /// detection rule as `libsvm::load`), else 0.0.
    class_off: f32,
}

/// Cheap first pass: count data rows, track the max feature index and
/// the label minimum. Parses index substrings only — no values, no
/// per-row allocation.
fn scan_dims(path: &Path, task: Task) -> Result<ScanDims> {
    let file = File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut rd = BufReader::with_capacity(1 << 20, file);
    let mut line = String::new();
    let (mut rows, mut kmax) = (0usize, 0u32);
    let mut min_label = f32::INFINITY;
    let mut lineno = 0usize;
    loop {
        line.clear();
        if rd.read_line(&mut line)? == 0 {
            break;
        }
        lineno += 1;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let mut it = t.split_ascii_whitespace();
        let label: f32 = it
            .next()
            .unwrap()
            .parse()
            .with_context(|| format!("line {lineno}: bad label"))?;
        min_label = min_label.min(label);
        for tok in it {
            let Some((i, _)) = tok.split_once(':') else {
                bail!("line {lineno}: token `{tok}` is not idx:val");
            };
            let i: u32 = i.parse().with_context(|| format!("line {lineno}: bad index"))?;
            if i == 0 {
                bail!("line {lineno}: libsvm indices are 1-based, got 0");
            }
            kmax = kmax.max(i);
        }
        rows += 1;
    }
    let class_off = match task {
        Task::Multiclass(_) if min_label >= 1.0 => 1.0,
        _ => 0.0,
    };
    Ok(ScanDims { rows, k: kmax as usize, class_off })
}

/// Chunked, double-buffered libsvm reader. Iterating yields
/// [`ParsedChunk`]s in file order; the prefetch thread keeps exactly one
/// chunk ahead of the consumer.
pub struct StreamReader {
    rx: Option<Receiver<Result<ParsedChunk>>>,
    handle: Option<JoinHandle<()>>,
    n: usize,
    k: usize,
    task: Task,
    class_off: f32,
    chunk_rows: usize,
    gauge: Arc<Gauge>,
    done: bool,
}

impl StreamReader {
    /// Fix `(n, k)` (counting pass or declared dims), then spawn the
    /// prefetch thread. Errors in the file surface through the chunk
    /// iterator as they are reached.
    pub fn open(path: &Path, task: Task, opts: &StreamOpts) -> Result<StreamReader> {
        if opts.chunk_rows == 0 {
            bail!("stream chunk size must be at least 1 row");
        }
        let (n, k, off) = match (opts.dims, opts.class_off, task) {
            (Some((n, k)), _, Task::Binary | Task::Regression) => (n, k, 0.0f32),
            (Some((n, k)), Some(off), Task::Multiclass(_)) => (n, k, off),
            (dims, _, _) => {
                // without a declared offset, multiclass must scan (the
                // class-id offset needs the label minimum); declared
                // dims then become a cross-check
                let scan = scan_dims(path, task)?;
                if let Some((dn, dk)) = dims {
                    if dn != scan.rows {
                        bail!("--dims declares {dn} rows but the file has {}", scan.rows);
                    }
                    if dk < scan.k {
                        bail!("--dims declares {dk} features but the file uses index {}", scan.k);
                    }
                    (dn, dk, scan.class_off)
                } else {
                    (scan.rows, scan.k, scan.class_off)
                }
            }
        };
        let file = File::open(path).with_context(|| format!("open {}", path.display()))?;
        let gauge = Arc::new(Gauge::default());
        // rendezvous channel: the producer finishes chunk i+1 and then
        // blocks until the consumer asks for it, so live parsed rows
        // never exceed (chunk being consumed) + (chunk handed over)
        let (tx, rx) = mpsc::sync_channel::<Result<ParsedChunk>>(0);
        let chunk_rows = opts.chunk_rows;
        let g = gauge.clone();
        let handle =
            std::thread::spawn(move || producer(file, task, n, k, off, chunk_rows, g, tx));
        Ok(StreamReader {
            rx: Some(rx),
            handle: Some(handle),
            n,
            k,
            task,
            class_off: off,
            chunk_rows,
            gauge,
            done: false,
        })
    }

    /// Total data rows (fixed before streaming starts).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Feature count (max index seen by the scan, or the declared K).
    pub fn k(&self) -> usize {
        self.k
    }

    pub fn task(&self) -> Task {
        self.task
    }

    /// Multiclass class-id offset in effect (1.0 for 1-based files).
    /// Carry it into [`StreamOpts::class_off`] when re-streaming the
    /// same file, so the second pass skips the counting scan.
    pub fn class_off(&self) -> f32 {
        self.class_off
    }

    pub fn chunk_rows(&self) -> usize {
        self.chunk_rows
    }

    /// The stream's resident-row gauge (survives the reader: clone it
    /// before handing the reader to `Cluster::from_stream`).
    pub fn gauge(&self) -> Arc<Gauge> {
        self.gauge.clone()
    }
}

impl Iterator for StreamReader {
    type Item = Result<ParsedChunk>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.done {
            return None;
        }
        match self.rx.as_ref()?.recv() {
            Ok(Ok(chunk)) => Some(Ok(chunk)),
            Ok(Err(e)) => {
                self.done = true;
                Some(Err(e))
            }
            // producer dropped its sender: end of stream
            Err(_) => {
                self.done = true;
                None
            }
        }
    }
}

impl Drop for StreamReader {
    fn drop(&mut self) {
        // unblock a producer parked on send, then reap the thread
        drop(self.rx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// The prefetch thread: read + parse the next window while the consumer
/// works on the previous one. All errors are sent down the channel.
#[allow(clippy::too_many_arguments)]
fn producer(
    file: File,
    task: Task,
    n: usize,
    k: usize,
    off: f32,
    chunk_rows: usize,
    gauge: Arc<Gauge>,
    tx: SyncSender<Result<ParsedChunk>>,
) {
    let mut rd = BufReader::with_capacity(1 << 20, file);
    let mut line = String::new();
    let mut lineno = 0usize;
    let mut start = 0usize;
    loop {
        let mut chunk = ParsedChunk::new(start, gauge.clone());
        let mut eof = false;
        while chunk.len() < chunk_rows {
            line.clear();
            match rd.read_line(&mut line) {
                Ok(0) => {
                    eof = true;
                    break;
                }
                Ok(_) => {}
                Err(e) => {
                    let _ = tx.send(Err(e.into()));
                    return;
                }
            }
            lineno += 1;
            let parsed = match libsvm::parse_row(&line, lineno) {
                Ok(p) => p,
                Err(e) => {
                    let _ = tx.send(Err(e));
                    return;
                }
            };
            let Some((label, pairs)) = parsed else { continue };
            if start + chunk.len() >= n {
                let _ = tx.send(Err(anyhow!(
                    "line {lineno}: more than the declared {n} data rows"
                )));
                return;
            }
            // parse_row sorts pairs, so the last index is the max
            if let Some(&(i, _)) = pairs.last() {
                if i as usize >= k {
                    let _ = tx.send(Err(anyhow!(
                        "line {lineno}: feature index {} exceeds the declared K={k}",
                        i + 1
                    )));
                    return;
                }
            }
            let label = match libsvm::map_label(label, task, off) {
                Ok(l) => l,
                Err(e) => {
                    let _ = tx.send(Err(e.context(format!("line {lineno}"))));
                    return;
                }
            };
            chunk.push_row(label, &pairs);
        }
        let end = start + chunk.len();
        if !chunk.is_empty() {
            stream_metrics().chunks.inc();
            stream_metrics().rows.add(chunk.len() as u64);
            crate::log_debug!("stream: parsed chunk {start}..{end} ({} rows)", chunk.len());
            if tx.send(Ok(chunk)).is_err() {
                return;
            }
        }
        if eof {
            if end != n {
                let _ = tx.send(Err(anyhow!("file has {end} data rows, expected {n}")));
            }
            return;
        }
        start = end;
    }
}

/// Out-of-core evaluation: stream the file a second time and score it
/// chunk by chunk — accuracy for CLS/MLT, RMSE for SVR. Accumulation
/// runs in file order with one f64 accumulator, so the result equals
/// [`crate::model::evaluate`] on the eagerly loaded dataset.
pub fn evaluate_streamed(path: &Path, task: Task, opts: &StreamOpts, w: &Weights) -> Result<f64> {
    let reader = StreamReader::open(path, task, opts)?;
    let task = reader.task();
    let mut acc = 0f64; // correct count (CLS/MLT) or squared-residual sum (SVR)
    let mut rows = 0usize;
    for chunk in reader {
        let chunk = chunk?;
        rows += chunk.len();
        match (task, w) {
            (Task::Binary, Weights::Single(wv)) => {
                for r in 0..chunk.len() {
                    if chunk.label(r) * chunk.dot_row(r, wv) > 0.0 {
                        acc += 1.0;
                    }
                }
            }
            (Task::Regression, Weights::Single(wv)) => {
                for r in 0..chunk.len() {
                    let d = (chunk.label(r) - chunk.dot_row(r, wv)) as f64;
                    acc += d * d;
                }
            }
            (Task::Multiclass(_), Weights::PerClass(m)) => {
                let mut scores = vec![0f32; m.rows];
                for r in 0..chunk.len() {
                    chunk.class_scores(r, m, &mut scores);
                    let pred = scores
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.total_cmp(b.1))
                        .map(|(c, _)| c)
                        .unwrap();
                    if pred == chunk.label(r) as usize {
                        acc += 1.0;
                    }
                }
            }
            _ => bail!("weights/task mismatch"),
        }
    }
    Ok(match task {
        Task::Regression => (acc / rows.max(1) as f64).sqrt(),
        _ => acc / rows.max(1) as f64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{shard_ranges, synth};

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("pemsvm_stream_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn scan_counts_rows_and_features() {
        let p = tmpfile("scan.svm");
        std::fs::write(&p, "# header\n1 3:1.5\n\n-1 1:2.0 7:0.5\n1\n").unwrap();
        let s = scan_dims(&p, Task::Binary).unwrap();
        assert_eq!(s.rows, 3);
        assert_eq!(s.k, 7);
        assert_eq!(s.class_off, 0.0);
    }

    #[test]
    fn chunks_cover_file_in_order() {
        let p = tmpfile("chunks.svm");
        let ds = synth::dna_like(100, 50, 3);
        libsvm::save(&ds, &p).unwrap();
        let opts = StreamOpts::rows(7);
        let reader = StreamReader::open(&p, Task::Binary, &opts).unwrap();
        assert_eq!(reader.n(), 100);
        let mut next = 0usize;
        let mut rows = 0usize;
        for chunk in reader {
            let c = chunk.unwrap();
            assert_eq!(c.start(), next);
            assert!(c.len() <= 7);
            next = c.start() + c.len();
            rows += c.len();
        }
        assert_eq!(rows, 100);
    }

    #[test]
    fn resident_rows_bounded_by_two_chunks() {
        let p = tmpfile("bound.svm");
        let ds = synth::dna_like(400, 40, 5);
        libsvm::save(&ds, &p).unwrap();
        let opts = StreamOpts::rows(32);
        let reader = StreamReader::open(&p, Task::Binary, &opts).unwrap();
        let gauge = reader.gauge();
        for chunk in reader {
            chunk.unwrap();
        }
        assert!(gauge.peak() <= 64, "peak {} > 2 x chunk", gauge.peak());
        assert_eq!(gauge.value(), 0);
    }

    #[test]
    fn shard_builders_reassemble_the_eager_shards() {
        let p = tmpfile("shards.svm");
        let ds = synth::dna_like(91, 30, 9);
        libsvm::save(&ds, &p).unwrap();
        let eager = libsvm::load(&p, Task::Binary, 3).unwrap();

        let opts = StreamOpts::rows(8);
        let reader = StreamReader::open(&p, Task::Binary, &opts).unwrap();
        let k = reader.k();
        assert_eq!(k, eager.k);
        let mut builders: Vec<ShardBuilder> = shard_ranges(91, 4)
            .into_iter()
            .map(|s| ShardBuilder::new(s.range, k, Task::Binary))
            .collect();
        for chunk in reader {
            let c = chunk.unwrap();
            for b in builders.iter_mut() {
                b.ingest(&c).unwrap();
            }
        }
        for (shard, b) in shard_ranges(91, 4).into_iter().zip(builders) {
            let got = b.build().unwrap();
            assert_eq!(got.n, shard.range.len());
            for (local, global) in shard.range.enumerate() {
                assert_eq!(got.labels[local], eager.labels[global]);
                assert_eq!(got.sparse_row(local), eager.sparse_row(global));
            }
        }
    }

    #[test]
    fn dims_declaration_is_validated() {
        let p = tmpfile("dims.svm");
        std::fs::write(&p, "1 2:1.0\n-1 5:1.0\n").unwrap();
        // too few declared rows: third row never comes, stream errors
        let opts = StreamOpts { chunk_rows: 4, dims: Some((3, 5)), class_off: None };
        let reader = StreamReader::open(&p, Task::Binary, &opts).unwrap();
        assert!(reader.map(|c| c.map(|_| ())).collect::<Result<Vec<_>>>().is_err());
        // feature index beyond declared K
        let opts = StreamOpts { chunk_rows: 4, dims: Some((2, 4)), class_off: None };
        let reader = StreamReader::open(&p, Task::Binary, &opts).unwrap();
        assert!(reader.map(|c| c.map(|_| ())).collect::<Result<Vec<_>>>().is_err());
        // exact declaration passes
        let opts = StreamOpts { chunk_rows: 4, dims: Some((2, 5)), class_off: None };
        let reader = StreamReader::open(&p, Task::Binary, &opts).unwrap();
        assert!(reader.map(|c| c.map(|_| ())).collect::<Result<Vec<_>>>().is_ok());
    }

    #[test]
    fn malformed_rows_surface_as_errors_not_panics() {
        // parse failures mid-file must come back as Err from open (the
        // scan touches every line) or from chunk iteration — a streamed
        // reader that panics would take the ingestion thread with it
        for (name, body) in [
            ("bad_tok.svm", "1 3:1.5\n1 x:y\n-1 2:0.5\n"),
            ("zero_idx.svm", "1 0:2.0\n"),
            ("overflow.svm", "1 4294967296:1.0\n"),
            ("bad_label.svm", "spam 2:1.0\n"),
        ] {
            let p = tmpfile(name);
            std::fs::write(&p, body).unwrap();
            // auto-scan path: the dim scan itself hits the bad row
            let got = StreamReader::open(&p, Task::Binary, &StreamOpts::rows(4))
                .and_then(|r| r.map(|c| c.map(|_| ())).collect::<Result<Vec<_>>>());
            assert!(got.is_err(), "{name}: expected Err, got {got:?}");
            // declared-dims path skips the scan, so the error must
            // surface from the chunk iterator instead
            let opts = StreamOpts { chunk_rows: 4, dims: Some((3, 8)), class_off: None };
            let got = StreamReader::open(&p, Task::Binary, &opts)
                .and_then(|r| r.map(|c| c.map(|_| ())).collect::<Result<Vec<_>>>());
            assert!(got.is_err(), "{name} (declared dims): expected Err, got {got:?}");
        }
    }

    #[test]
    fn multiclass_one_based_matches_eager() {
        let p = tmpfile("mc.svm");
        std::fs::write(&p, "1 1:1\n2 1:1\n3 1:1\n").unwrap();
        let eager = libsvm::load(&p, Task::Multiclass(3), 1).unwrap();
        let opts = StreamOpts::rows(2);
        let reader = StreamReader::open(&p, Task::Multiclass(3), &opts).unwrap();
        assert_eq!(reader.class_off(), 1.0);
        let mut labels = Vec::new();
        for chunk in reader {
            let c = chunk.unwrap();
            labels.extend_from_slice(&c.labels);
        }
        assert_eq!(labels, eager.labels);

        // declared dims + offset skip the scan entirely and must agree
        let opts = StreamOpts { chunk_rows: 2, dims: Some((3, 1)), class_off: Some(1.0) };
        let reader = StreamReader::open(&p, Task::Multiclass(3), &opts).unwrap();
        let mut declared = Vec::new();
        for chunk in reader {
            declared.extend_from_slice(&chunk.unwrap().labels);
        }
        assert_eq!(declared, eager.labels);
    }

    #[test]
    fn evaluate_streamed_matches_eager_evaluate() {
        let p = tmpfile("eval.svm");
        let ds = synth::dna_like(200, 40, 1);
        libsvm::save(&ds, &p).unwrap();
        let w = Weights::Single((0..40).map(|j| (j as f32 * 0.37).sin()).collect());
        let eager = libsvm::load(&p, Task::Binary, 2).unwrap();
        let want = crate::model::evaluate(&eager, &w);
        let opts = StreamOpts::rows(33);
        let got = evaluate_streamed(&p, Task::Binary, &opts, &w).unwrap();
        assert_eq!(got, want);
    }
}
