//! Seeded synthetic generators standing in for the paper's corpora.
//!
//! We cannot ship Pascal-LSL `alpha`/`dna`, `YearPredictionMSD`,
//! `mnist8m`, or `news20` in this offline image, so each generator
//! reproduces the *signature that drives solver behaviour*: N, K, M,
//! density, margin structure, and label noise (DESIGN.md §6). All
//! generators are deterministic in (shape, seed).

use super::{Dataset, Task};
use crate::rng::{NormalSource, Pcg64};

/// Dense binary classification in the mold of Pascal `alpha`
/// (N=250k, K=500, dense, moderately separable).
///
/// x | y ~ N(y * margin * u, I) with u a random unit direction, plus
/// `flip` label noise so accuracies land in the paper's 75-90% band.
pub fn alpha_like(n: usize, k: usize, seed: u64) -> Dataset {
    gaussian_margin(n, k, seed, 1.8, 0.12)
}

/// The same family with explicit margin/noise knobs (used by the
/// scaling benches that only care about N/K shapes).
pub fn gaussian_margin(n: usize, k: usize, seed: u64, margin: f32, flip: f64) -> Dataset {
    let mut g = Pcg64::new_stream(seed, 0xa1fa);
    let mut ns = NormalSource::new();
    // random unit direction
    let mut u: Vec<f32> = (0..k).map(|_| ns.next(&mut g) as f32).collect();
    let norm = crate::linalg::norm2_sq(&u).sqrt().max(1e-12);
    u.iter_mut().for_each(|v| *v /= norm);

    let mut data = vec![0f32; n * k];
    let mut labels = vec![0f32; n];
    for d in 0..n {
        let y: f32 = if g.next_f64() < 0.5 { -1.0 } else { 1.0 };
        let row = &mut data[d * k..(d + 1) * k];
        for (j, r) in row.iter_mut().enumerate() {
            *r = ns.next(&mut g) as f32 + y * margin * u[j];
        }
        labels[d] = if g.next_f64() < flip { -y } else { y };
    }
    Dataset::dense(data, labels, k, Task::Binary)
}

/// Sparse binary classification in the mold of Pascal `dna`
/// (K=800, ~25 nonzeros/row, huge N). Class-dependent Bernoulli rates
/// on a planted subset of "motif" features.
pub fn dna_like(n: usize, k: usize, seed: u64) -> Dataset {
    let mut g = Pcg64::new_stream(seed, 0xd4a);
    let nnz_per_row = 25.min(k);
    let n_motif = (k / 10).max(1);
    let mut indptr = vec![0usize];
    let mut indices: Vec<u32> = Vec::with_capacity(n * nnz_per_row);
    let mut values: Vec<f32> = Vec::with_capacity(n * nnz_per_row);
    let mut labels = vec![0f32; n];
    let mut scratch: Vec<u32> = Vec::with_capacity(nnz_per_row);
    for d in 0..n {
        let y: f32 = if g.next_f64() < 0.5 { -1.0 } else { 1.0 };
        labels[d] = if g.next_f64() < 0.08 { -y } else { y };
        scratch.clear();
        // positive class draws ~60% of its nonzeros from the motif block
        for _ in 0..nnz_per_row {
            let in_motif = g.next_f64() < if y > 0.0 { 0.6 } else { 0.25 };
            let j = if in_motif {
                g.next_below(n_motif as u64) as u32
            } else {
                n_motif as u32 + g.next_below((k - n_motif) as u64) as u32
            };
            scratch.push(j);
        }
        scratch.sort_unstable();
        scratch.dedup();
        for &j in &scratch {
            indices.push(j);
            values.push(1.0);
        }
        indptr.push(indices.len());
    }
    Dataset::sparse(indptr, indices, values, labels, k, Task::Binary)
}

/// Dense regression in the mold of YearPredictionMSD (K=90), already
/// normalized to zero mean / unit variance like the paper's §5.10 setup.
pub fn year_like(n: usize, k: usize, seed: u64) -> Dataset {
    let mut g = Pcg64::new_stream(seed, 0x9ea2);
    let mut ns = NormalSource::new();
    let w_true: Vec<f32> = (0..k).map(|_| ns.next(&mut g) as f32 / (k as f32).sqrt()).collect();
    let mut data = vec![0f32; n * k];
    let mut labels = vec![0f32; n];
    for d in 0..n {
        let row = &mut data[d * k..(d + 1) * k];
        for r in row.iter_mut() {
            *r = ns.next(&mut g) as f32;
        }
        labels[d] = crate::linalg::dot(row, &w_true) + 0.6 * ns.next(&mut g) as f32;
    }
    // normalize labels to unit variance (paper normalized the data)
    let mean = labels.iter().sum::<f32>() / n as f32;
    let var = labels.iter().map(|l| (l - mean) * (l - mean)).sum::<f32>() / n as f32;
    let sd = var.sqrt().max(1e-12);
    labels.iter_mut().for_each(|l| *l = (*l - mean) / sd);
    Dataset::dense(data, labels, k, Task::Regression)
}

/// Dense multiclass in the mold of mnist8m (K=784, M=10): class
/// prototypes with within-class Gaussian scatter and overlap noise.
pub fn mnist_like(n: usize, k: usize, m: usize, seed: u64) -> Dataset {
    let mut g = Pcg64::new_stream(seed, 0x3357);
    let mut ns = NormalSource::new();
    // prototypes: random vectors with K-independent pairwise distance
    // (~5.7), so class overlap (and hence achievable accuracy ~85-95%,
    // like mnist8m in the paper) does not collapse as K grows
    let proto_scale = 4.0 / (k as f32).sqrt();
    let mut protos = vec![0f32; m * k];
    for c in 0..m {
        for j in 0..k {
            protos[c * k + j] = proto_scale * ns.next(&mut g) as f32;
        }
    }
    let mut data = vec![0f32; n * k];
    let mut labels = vec![0f32; n];
    for d in 0..n {
        let c = g.next_below(m as u64) as usize;
        labels[d] = c as f32;
        let row = &mut data[d * k..(d + 1) * k];
        let proto = &protos[c * k..(c + 1) * k];
        for (r, p) in row.iter_mut().zip(proto) {
            *r = p + 1.25 * ns.next(&mut g) as f32;
        }
    }
    Dataset::dense(data, labels, k, Task::Multiclass(m))
}

/// Small sparse binary text-like set in the mold of news20 (for the
/// kernel experiments, N ~ 1800).
pub fn news20_like(n: usize, k: usize, seed: u64) -> Dataset {
    let mut g = Pcg64::new_stream(seed, 0x2e52);
    let nnz = 40.min(k);
    let mut indptr = vec![0usize];
    let (mut indices, mut values) = (Vec::new(), Vec::new());
    let mut labels = vec![0f32; n];
    let mut scratch = Vec::with_capacity(nnz);
    for d in 0..n {
        let y: f32 = if g.next_f64() < 0.5 { -1.0 } else { 1.0 };
        labels[d] = if g.next_f64() < 0.05 { -y } else { y };
        scratch.clear();
        for _ in 0..nnz {
            // class-biased topic blocks in the first 30% of the vocab
            let topical = g.next_f64() < 0.5;
            let block = (k * 3) / 10;
            let j = if topical {
                let half = (block / 2).max(1);
                if y > 0.0 {
                    g.next_below(half as u64) as u32
                } else {
                    half as u32 + g.next_below(half as u64) as u32
                }
            } else {
                block as u32 + g.next_below((k - block) as u64) as u32
            };
            scratch.push(j);
        }
        scratch.sort_unstable();
        scratch.dedup();
        let inv = 1.0 / (scratch.len() as f32).sqrt(); // l2-ish tf norm
        for &j in &scratch {
            indices.push(j);
            values.push(inv);
        }
        indptr.push(indices.len());
    }
    Dataset::sparse(indptr, indices, values, labels, k, Task::Binary)
}

/// Stream a seeded sparse binary corpus straight to `path` in libsvm
/// format, row by row — the whole corpus never exists in memory, which
/// is what lets `benches/ingest.rs` generate a file larger than any
/// ingestion chunk without cheating on its own memory bound.
/// Deterministic in `(n, k, seed)`; ~20 nonzeros per row with
/// class-separated values so short training runs are meaningful.
pub fn write_libsvm_streaming(
    path: &std::path::Path,
    n: usize,
    k: usize,
    seed: u64,
) -> anyhow::Result<()> {
    use std::io::Write;
    let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
    let mut g = Pcg64::new_stream(seed, 0x57e3);
    let nnz = 20.min(k.max(1));
    let mut scratch: Vec<u32> = Vec::with_capacity(nnz);
    for _ in 0..n {
        let y: i32 = if g.next_f64() < 0.5 { -1 } else { 1 };
        write!(w, "{y}")?;
        scratch.clear();
        for _ in 0..nnz {
            scratch.push(g.next_below(k as u64) as u32);
        }
        scratch.sort_unstable();
        scratch.dedup();
        for &j in &scratch {
            let v = if y > 0 { 0.5 + g.next_f32() } else { -0.5 - g.next_f32() };
            write!(w, " {}:{v:.3}", j + 1)?;
        }
        writeln!(w)?;
    }
    w.flush()?;
    Ok(())
}

/// Deterministic train/test split: every `holdout`-th row goes to test.
/// Storage kind (dense/CSR) is preserved.
pub fn split(ds: &Dataset, holdout: usize) -> (Dataset, Dataset) {
    assert!(holdout >= 2);
    match &ds.features {
        super::Features::Dense { data } => {
            let (mut tr_x, mut te_x) = (Vec::new(), Vec::new());
            let (mut tr_y, mut te_y) = (Vec::new(), Vec::new());
            for d in 0..ds.n {
                let row = &data[d * ds.k..(d + 1) * ds.k];
                if d % holdout == 0 {
                    te_x.extend_from_slice(row);
                    te_y.push(ds.labels[d]);
                } else {
                    tr_x.extend_from_slice(row);
                    tr_y.push(ds.labels[d]);
                }
            }
            (
                Dataset::dense(tr_x, tr_y, ds.k, ds.task),
                Dataset::dense(te_x, te_y, ds.k, ds.task),
            )
        }
        super::Features::Sparse { .. } => {
            let mut parts = [
                (vec![0usize], Vec::new(), Vec::new(), Vec::new()), // train
                (vec![0usize], Vec::new(), Vec::new(), Vec::new()), // test
            ];
            for d in 0..ds.n {
                let which = usize::from(d % holdout == 0);
                let (indptr, idx, val, labels) = &mut parts[which];
                ds.for_nonzero(d, |j, v| {
                    idx.push(j);
                    val.push(v);
                });
                indptr.push(idx.len());
                labels.push(ds.labels[d]);
            }
            let [tr, te] = parts;
            (
                Dataset::sparse(tr.0, tr.1, tr.2, tr.3, ds.k, ds.task),
                Dataset::sparse(te.0, te.1, te.2, te.3, ds.k, ds.task),
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_in_seed() {
        let a = alpha_like(100, 20, 7);
        let b = alpha_like(100, 20, 7);
        let c = alpha_like(100, 20, 8);
        match (&a.features, &b.features, &c.features) {
            (
                super::super::Features::Dense { data: da },
                super::super::Features::Dense { data: db },
                super::super::Features::Dense { data: dc },
            ) => {
                assert_eq!(da, db);
                assert_ne!(da, dc);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn alpha_is_roughly_balanced_and_separable() {
        let ds = alpha_like(2000, 10, 1);
        let pos = ds.labels.iter().filter(|&&y| y > 0.0).count();
        assert!(pos > 700 && pos < 1300, "balance {pos}");
    }

    #[test]
    fn dna_is_sparse_binary() {
        let ds = dna_like(500, 800, 3);
        assert!(ds.is_sparse());
        assert!(ds.density() < 0.05, "density {}", ds.density());
        assert!(ds.labels.iter().all(|&y| y == 1.0 || y == -1.0));
    }

    #[test]
    fn year_labels_normalized() {
        let ds = year_like(5000, 30, 5);
        let mean = ds.labels.iter().sum::<f32>() / ds.n as f32;
        let var = ds.labels.iter().map(|l| (l - mean) * (l - mean)).sum::<f32>() / ds.n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn mnist_covers_classes() {
        let ds = mnist_like(1000, 16, 10, 2);
        let mut seen = [false; 10];
        for &l in &ds.labels {
            seen[l as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn write_libsvm_streaming_is_deterministic_and_loadable() {
        let dir = std::env::temp_dir().join("pemsvm_synth_stream");
        std::fs::create_dir_all(&dir).unwrap();
        let (p1, p2) = (dir.join("a.svm"), dir.join("b.svm"));
        write_libsvm_streaming(&p1, 50, 30, 4).unwrap();
        write_libsvm_streaming(&p2, 50, 30, 4).unwrap();
        assert_eq!(std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap());
        let ds = super::super::libsvm::load(&p1, Task::Binary, 2).unwrap();
        assert_eq!(ds.n, 50);
        assert!(ds.k <= 30);
        assert!(ds.is_sparse());
        assert!(ds.labels.iter().all(|&y| y == 1.0 || y == -1.0));
    }

    #[test]
    fn split_partitions() {
        let ds = alpha_like(100, 4, 9);
        let (tr, te) = split(&ds, 5);
        assert_eq!(tr.n + te.n, 100);
        assert_eq!(te.n, 20);
    }
}
