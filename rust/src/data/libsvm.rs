//! libsvm/svmlight-format I/O.
//!
//! `label idx:val idx:val ...` with 1-based feature indices. The reader
//! supports the paper's parallel-I/O point (§5.6): the file is split
//! into P byte ranges aligned to line boundaries and parsed by P
//! threads, so load time scales with cores like the MPI implementation.

use std::fs::File;
use std::io::{BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{Dataset, Task};

/// Parse one libsvm line into (label, pairs). Returns None for blank /
/// comment lines. Public: the serve protocol and the model format both
/// speak libsvm rows.
pub fn parse_row(line: &str, lineno: usize) -> Result<Option<(f32, Vec<(u32, f32)>)>> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(None);
    }
    let mut it = line.split_ascii_whitespace();
    let label: f32 = it
        .next()
        .unwrap()
        .parse()
        .with_context(|| format!("line {lineno}: bad label"))?;
    let mut pairs = Vec::new();
    for tok in it {
        let (i, v) = tok
            .split_once(':')
            .with_context(|| format!("line {lineno}: token `{tok}` is not idx:val"))?;
        let i: u32 = i.parse().with_context(|| format!("line {lineno}: bad index"))?;
        if i == 0 {
            bail!("line {lineno}: libsvm indices are 1-based, got 0");
        }
        let v: f32 = v.parse().with_context(|| format!("line {lineno}: bad value"))?;
        pairs.push((i - 1, v));
    }
    pairs.sort_unstable_by_key(|p| p.0);
    Ok(Some((label, pairs)))
}

/// Per-row label mapping shared by the eager loader and the streaming
/// reader (`data::stream`) — one definition, so the two paths cannot
/// drift: Binary maps {0,1}/{-1,+1} to ±1, Regression keeps values,
/// Multiclass subtracts the 1-based-id offset `class_off` and
/// range-checks the result.
pub(crate) fn map_label(label: f32, task: Task, class_off: f32) -> Result<f32> {
    Ok(match task {
        Task::Binary => {
            if label > 0.0 {
                1.0
            } else {
                -1.0
            }
        }
        Task::Regression => label,
        Task::Multiclass(m) => {
            let l = label - class_off;
            if l < 0.0 || l >= m as f32 {
                bail!("class id {l} out of range 0..{m}");
            }
            l
        }
    })
}

fn parse_block(text: &str, first_lineno: usize) -> Result<Vec<(f32, Vec<(u32, f32)>)>> {
    let mut rows = Vec::new();
    for (off, line) in text.lines().enumerate() {
        if let Some(r) = parse_row(line, first_lineno + off)? {
            rows.push(r);
        }
    }
    Ok(rows)
}

/// Load a libsvm file with `threads` parallel parsers.
///
/// `task` decides label handling: Binary maps {0,1}/{-1,+1} to ±1,
/// Multiclass expects 0..m or 1..=m class ids, Regression keeps values.
pub fn load(path: &Path, task: Task, threads: usize) -> Result<Dataset> {
    let mut text = String::new();
    File::open(path)
        .with_context(|| format!("open {}", path.display()))?
        .read_to_string(&mut text)?;
    let threads = threads.max(1);

    // Split into line-aligned byte ranges.
    let bytes = text.as_bytes();
    let mut cuts = vec![0usize];
    for t in 1..threads {
        let mut pos = bytes.len() * t / threads;
        while pos < bytes.len() && bytes[pos] != b'\n' {
            pos += 1;
        }
        cuts.push((pos + 1).min(bytes.len()));
    }
    cuts.push(bytes.len());
    cuts.dedup();

    let blocks: Vec<Vec<(f32, Vec<(u32, f32)>)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = cuts
            .windows(2)
            .map(|w| {
                let chunk = &text[w[0]..w[1]];
                scope.spawn(move || parse_block(chunk, 0))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect::<Result<Vec<_>>>()
    })?;

    let mut indptr = vec![0usize];
    let (mut indices, mut values, mut labels) = (Vec::new(), Vec::new(), Vec::new());
    let mut kmax = 0u32;
    for block in blocks {
        for (label, pairs) in block {
            labels.push(label);
            for (i, v) in pairs {
                kmax = kmax.max(i + 1);
                indices.push(i);
                values.push(v);
            }
            indptr.push(indices.len());
        }
    }

    // accept 1-based multiclass ids: the offset follows the label minimum
    let class_off = match task {
        Task::Multiclass(_) => {
            let min = labels.iter().cloned().fold(f32::INFINITY, f32::min);
            if min >= 1.0 {
                1.0
            } else {
                0.0
            }
        }
        _ => 0.0,
    };
    let labels = labels
        .into_iter()
        .map(|l| map_label(l, task, class_off))
        .collect::<Result<Vec<f32>>>()?;
    Ok(Dataset::sparse(indptr, indices, values, labels, kmax as usize, task))
}

/// Write a dataset in libsvm format.
pub fn save(ds: &Dataset, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    for d in 0..ds.n {
        let label = ds.labels[d];
        if label == label.trunc() {
            write!(w, "{}", label as i64)?;
        } else {
            write!(w, "{label}")?;
        }
        let mut err = None;
        ds.for_nonzero(d, |j, v| {
            if let Err(e) = write!(w, " {}:{}", j + 1, v) {
                err = Some(e);
            }
        });
        if let Some(e) = err {
            return Err(e.into());
        }
        writeln!(w)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("pemsvm_libsvm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.svm");
        let ds = Dataset::sparse(
            vec![0, 2, 3, 3],
            vec![0, 4, 2],
            vec![1.5, -2.0, 3.0],
            vec![1.0, -1.0, 1.0],
            5,
            Task::Binary,
        );
        save(&ds, &p).unwrap();
        let back = load(&p, Task::Binary, 2).unwrap();
        assert_eq!(back.n, 3);
        assert_eq!(back.k, 5);
        assert_eq!(back.labels, ds.labels);
        assert_eq!(back.sparse_row(0).unwrap().0, &[0u32, 4]);
        assert_eq!(back.sparse_row(1).unwrap().1, &[3.0f32]);
        assert_eq!(back.sparse_row(2).unwrap().0, &[] as &[u32]);
    }

    #[test]
    fn parallel_load_equals_serial() {
        let dir = std::env::temp_dir().join("pemsvm_libsvm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("par.svm");
        let mut g = crate::rng::Pcg64::new(17);
        let mut text = String::new();
        for d in 0..500 {
            text.push_str(if d % 2 == 0 { "1" } else { "-1" });
            for j in 0..10u32 {
                if g.next_f32() < 0.3 {
                    text.push_str(&format!(" {}:{:.3}", j + 1, g.next_f32()));
                }
            }
            text.push('\n');
        }
        std::fs::write(&p, &text).unwrap();
        let a = load(&p, Task::Binary, 1).unwrap();
        let b = load(&p, Task::Binary, 7).unwrap();
        assert_eq!(a.n, b.n);
        assert_eq!(a.labels, b.labels);
        for d in 0..a.n {
            assert_eq!(a.sparse_row(d), b.sparse_row(d), "row {d}");
        }
    }

    #[test]
    fn parse_row_skips_comments_and_blanks() {
        assert!(parse_row("", 1).unwrap().is_none());
        assert!(parse_row("   \t  ", 2).unwrap().is_none());
        assert!(parse_row("# a comment", 3).unwrap().is_none());
        assert!(parse_row("  # indented comment", 4).unwrap().is_none());
    }

    #[test]
    fn parse_row_sorts_out_of_order_indices() {
        let (label, pairs) = parse_row("1 7:0.5 2:1.0 5:-3.0", 1).unwrap().unwrap();
        assert_eq!(label, 1.0);
        // 1-based in the file, 0-based sorted in memory
        assert_eq!(pairs, vec![(1, 1.0), (4, -3.0), (6, 0.5)]);
    }

    #[test]
    fn parse_row_label_only_row_is_empty() {
        let (label, pairs) = parse_row("-1", 1).unwrap().unwrap();
        assert_eq!(label, -1.0);
        assert!(pairs.is_empty());
    }

    #[test]
    fn parse_row_rejects_malformed_tokens() {
        // 0-based index
        assert!(parse_row("1 0:3.0", 1).is_err());
        // missing colon
        assert!(parse_row("1 5", 1).is_err());
        // non-numeric index / value / label
        assert!(parse_row("1 x:1.0", 1).is_err());
        assert!(parse_row("1 2:abc", 1).is_err());
        assert!(parse_row("spam 2:1.0", 1).is_err());
        // error message carries the line number
        let err = parse_row("1 5", 41).unwrap_err();
        assert!(format!("{err:#}").contains("line 41"), "{err:#}");
    }

    #[test]
    fn parse_row_feature_index_overflow_is_an_error_not_a_wrap() {
        // u32::MAX + 1: must fail parse, never wrap around to index 0
        assert!(parse_row("1 4294967296:1.0", 1).is_err());
        assert!(parse_row("1 99999999999999999999:1.0", 1).is_err());
        // u32::MAX itself is representable (0-based u32::MAX - 1)
        let (_, pairs) = parse_row("1 4294967295:2.0", 1).unwrap().unwrap();
        assert_eq!(pairs, vec![(u32::MAX - 1, 2.0)]);
    }

    #[test]
    fn parse_row_tolerates_trailing_whitespace_and_cr() {
        // trailing spaces/tabs and a Windows \r must not become tokens
        let (label, pairs) = parse_row("1 3:1.5 \t ", 1).unwrap().unwrap();
        assert_eq!((label, pairs), (1.0, vec![(2, 1.5)]));
        let (label, pairs) = parse_row("-1 2:0.5\r", 1).unwrap().unwrap();
        assert_eq!((label, pairs), (-1.0, vec![(1, 0.5)]));
        // a trailing comment marker mid-line is NOT a comment: `#` only
        // introduces comments at line start, so this token must error
        assert!(parse_row("1 2:0.5 # trailing", 1).is_err());
    }

    #[test]
    fn parse_row_never_panics_on_adversarial_input() {
        // property-style sweep: every line must return Ok(Some)/Ok(None)/
        // Err — a panic in the parser would take down a serve connection
        // reader thread (`serve::server` feeds client bytes in here)
        let corpus = [
            ":",
            "1 :",
            "1 :5",
            "1 5:",
            "1 ::",
            "1 1:2:3",
            "1 -3:1.0",
            "1 3:-inf",
            "1 3:NaN",
            "nan 1:1",
            "1 18446744073709551616:1",
            "\u{0}",
            "1 \u{0}:1",
            "+ 1:1",
            "1e999 1:1",
            "1 1:1e999",
            "  -1   7:0.5    2:1.0  ",
        ];
        for (i, line) in corpus.iter().enumerate() {
            let _ = parse_row(line, i + 1); // must return, not panic
        }
        // seeded fuzz over the format's alphabet
        let mut g = crate::rng::Pcg64::new(99);
        let alphabet: &[u8] = b"0123456789:. -+eE#\t\rinfa";
        for round in 0..1000 {
            let len = g.next_below(48) as usize;
            let line: String = (0..len)
                .map(|_| alphabet[g.next_below(alphabet.len() as u64) as usize] as char)
                .collect();
            if let Ok(Some((label, pairs))) = parse_row(&line, round) {
                // whatever parses obeys the parsed-row invariants
                assert!(!label.is_nan() || line.to_ascii_lowercase().contains("nan"));
                assert!(pairs.windows(2).all(|w| w[0].0 <= w[1].0), "sorted: `{line}`");
            }
        }
    }

    #[test]
    fn rejects_zero_index() {
        let dir = std::env::temp_dir().join("pemsvm_libsvm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.svm");
        std::fs::write(&p, "1 0:3.0\n").unwrap();
        assert!(load(&p, Task::Binary, 1).is_err());
    }

    #[test]
    fn multiclass_one_based() {
        let dir = std::env::temp_dir().join("pemsvm_libsvm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("mc.svm");
        std::fs::write(&p, "1 1:1\n2 1:1\n3 1:1\n").unwrap();
        let ds = load(&p, Task::Multiclass(3), 1).unwrap();
        assert_eq!(ds.labels, vec![0.0, 1.0, 2.0]);
    }
}
