//! Datasets: dense + CSR storage, libsvm-format I/O (eager and
//! out-of-core streaming), sharding, and the seeded synthetic
//! generators that stand in for the paper's corpora (DESIGN.md §6
//! substitutions).

pub mod libsvm;
pub mod shard;
pub mod stream;
pub mod synth;

pub use shard::{shard_ranges, Shard};

/// Feature storage. The paper's MPI implementation is sparse (§5.7.1)
/// and its GPU implementation dense (§5.7.2); we keep both and the
/// backends accept either (densifying per chunk where needed).
#[derive(Clone, Debug)]
pub enum Features {
    Dense {
        /// row-major [n, k]
        data: Vec<f32>,
    },
    Sparse {
        /// CSR: row d occupies `indices/values[indptr[d]..indptr[d+1]]`
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f32>,
    },
}

/// Learning task, mirroring the paper's CLS / SVR / MLT options.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    /// binary classification, labels in {-1, +1}
    Binary,
    /// regression, real labels
    Regression,
    /// multiclass, labels in 0..m
    Multiclass(usize),
}

/// An in-memory dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub features: Features,
    /// Binary: ±1; regression: real; multiclass: class index as f32.
    pub labels: Vec<f32>,
    pub n: usize,
    pub k: usize,
    pub task: Task,
}

impl Dataset {
    pub fn dense(data: Vec<f32>, labels: Vec<f32>, k: usize, task: Task) -> Self {
        let n = labels.len();
        assert_eq!(data.len(), n * k);
        Dataset { features: Features::Dense { data }, labels, n, k, task }
    }

    pub fn sparse(
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f32>,
        labels: Vec<f32>,
        k: usize,
        task: Task,
    ) -> Self {
        let n = labels.len();
        assert_eq!(indptr.len(), n + 1);
        assert_eq!(indices.len(), values.len());
        debug_assert!(indices.iter().all(|&i| (i as usize) < k));
        Dataset { features: Features::Sparse { indptr, indices, values }, labels, n, k, task }
    }

    pub fn is_sparse(&self) -> bool {
        matches!(self.features, Features::Sparse { .. })
    }

    /// Fraction of stored nonzeros (1.0 for dense).
    pub fn density(&self) -> f64 {
        match &self.features {
            Features::Dense { .. } => 1.0,
            Features::Sparse { values, .. } => values.len() as f64 / (self.n * self.k) as f64,
        }
    }

    /// Copy row `d` into the (zeroed by us) dense buffer `out` (len k).
    pub fn densify_row(&self, d: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.k);
        match &self.features {
            Features::Dense { data } => out.copy_from_slice(&data[d * self.k..(d + 1) * self.k]),
            Features::Sparse { indptr, indices, values } => {
                out.fill(0.0);
                for p in indptr[d]..indptr[d + 1] {
                    out[indices[p] as usize] = values[p];
                }
            }
        }
    }

    /// Visit nonzeros of row `d` as (index, value).
    #[inline]
    pub fn for_nonzero<F: FnMut(u32, f32)>(&self, d: usize, mut f: F) {
        match &self.features {
            Features::Dense { data } => {
                for (j, &v) in data[d * self.k..(d + 1) * self.k].iter().enumerate() {
                    if v != 0.0 {
                        f(j as u32, v);
                    }
                }
            }
            Features::Sparse { indptr, indices, values } => {
                for p in indptr[d]..indptr[d + 1] {
                    f(indices[p], values[p]);
                }
            }
        }
    }

    /// Sparse row view (indices, values) if sparse.
    pub fn sparse_row(&self, d: usize) -> Option<(&[u32], &[f32])> {
        match &self.features {
            Features::Sparse { indptr, indices, values } => {
                Some((&indices[indptr[d]..indptr[d + 1]], &values[indptr[d]..indptr[d + 1]]))
            }
            _ => None,
        }
    }

    /// x_d . w
    pub fn dot_row(&self, d: usize, w: &[f32]) -> f32 {
        match &self.features {
            Features::Dense { data } => crate::linalg::dot(&data[d * self.k..(d + 1) * self.k], w),
            Features::Sparse { indptr, indices, values } => {
                let mut s = 0.0;
                for p in indptr[d]..indptr[d + 1] {
                    s += values[p] * w[indices[p] as usize];
                }
                s
            }
        }
    }

    /// Squared norm of row d.
    pub fn row_norm_sq(&self, d: usize) -> f32 {
        let mut s = 0.0;
        self.for_nonzero(d, |_, v| s += v * v);
        s
    }

    /// Restrict to the first `n0` rows (paper §5.3's "N = N0 subset").
    pub fn subset_rows(&self, n0: usize) -> Dataset {
        let n0 = n0.min(self.n);
        let labels = self.labels[..n0].to_vec();
        match &self.features {
            Features::Dense { data } => {
                Dataset::dense(data[..n0 * self.k].to_vec(), labels, self.k, self.task)
            }
            Features::Sparse { indptr, indices, values } => {
                let end = indptr[n0];
                Dataset::sparse(
                    indptr[..=n0].to_vec(),
                    indices[..end].to_vec(),
                    values[..end].to_vec(),
                    labels,
                    self.k,
                    self.task,
                )
            }
        }
    }

    /// Drop the first `n0` rows — the complement of [`subset_rows`]
    /// (`ds.subset_rows(hi).subset_rows_from(lo)` is the row window
    /// `lo..hi`, which the out-of-core baseline streams block by block).
    ///
    /// [`subset_rows`]: Dataset::subset_rows
    pub fn subset_rows_from(&self, n0: usize) -> Dataset {
        let n0 = n0.min(self.n);
        let labels = self.labels[n0..].to_vec();
        match &self.features {
            Features::Dense { data } => {
                Dataset::dense(data[n0 * self.k..].to_vec(), labels, self.k, self.task)
            }
            Features::Sparse { indptr, indices, values } => {
                let start = indptr[n0];
                let ip: Vec<usize> = indptr[n0..].iter().map(|&p| p - start).collect();
                Dataset::sparse(
                    ip,
                    indices[start..].to_vec(),
                    values[start..].to_vec(),
                    labels,
                    self.k,
                    self.task,
                )
            }
        }
    }

    /// Keep only features with index < k0 (paper §5.3's "K = K0 subset").
    pub fn subset_features(&self, k0: usize) -> Dataset {
        let k0 = k0.min(self.k);
        match &self.features {
            Features::Dense { data } => {
                let mut out = Vec::with_capacity(self.n * k0);
                for d in 0..self.n {
                    out.extend_from_slice(&data[d * self.k..d * self.k + k0]);
                }
                Dataset::dense(out, self.labels.clone(), k0, self.task)
            }
            Features::Sparse { indptr, indices, values } => {
                let (mut ip, mut ix, mut vs) = (vec![0usize], Vec::new(), Vec::new());
                for d in 0..self.n {
                    for p in indptr[d]..indptr[d + 1] {
                        if (indices[p] as usize) < k0 {
                            ix.push(indices[p]);
                            vs.push(values[p]);
                        }
                    }
                    ip.push(ix.len());
                }
                Dataset::sparse(ip, ix, vs, self.labels.clone(), k0, self.task)
            }
        }
    }

    /// Densify the whole dataset (for the XLA backend's chunk uploads).
    pub fn to_dense(&self) -> Dataset {
        match &self.features {
            Features::Dense { .. } => self.clone(),
            Features::Sparse { .. } => {
                let mut data = vec![0.0f32; self.n * self.k];
                for d in 0..self.n {
                    let row = &mut data[d * self.k..(d + 1) * self.k];
                    self.for_nonzero(d, |j, v| row[j as usize] = v);
                }
                Dataset::dense(data, self.labels.clone(), self.k, self.task)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_sparse() -> Dataset {
        // rows: [0: (1, 2.0)], [1: (0, 1.0), (2, -1.0)], [2: empty]
        Dataset::sparse(
            vec![0, 1, 3, 3],
            vec![1, 0, 2],
            vec![2.0, 1.0, -1.0],
            vec![1.0, -1.0, 1.0],
            3,
            Task::Binary,
        )
    }

    #[test]
    fn densify_and_dot_agree() {
        let ds = tiny_sparse();
        let w = [0.5f32, 1.5, 2.0];
        let mut buf = vec![0.0f32; 3];
        for d in 0..3 {
            ds.densify_row(d, &mut buf);
            let dense_dot: f32 = buf.iter().zip(&w).map(|(a, b)| a * b).sum();
            assert!((ds.dot_row(d, &w) - dense_dot).abs() < 1e-6);
        }
    }

    #[test]
    fn to_dense_roundtrip() {
        let ds = tiny_sparse();
        let dd = ds.to_dense();
        let mut b1 = vec![0.0f32; 3];
        let mut b2 = vec![0.0f32; 3];
        for d in 0..3 {
            ds.densify_row(d, &mut b1);
            dd.densify_row(d, &mut b2);
            assert_eq!(b1, b2);
        }
    }

    #[test]
    fn subsets() {
        let ds = tiny_sparse();
        let s = ds.subset_rows(2);
        assert_eq!(s.n, 2);
        let f = ds.subset_features(2);
        assert_eq!(f.k, 2);
        // feature index 2 dropped from row 1
        assert_eq!(f.sparse_row(1).unwrap().0, &[0u32]);
    }

    #[test]
    fn subset_rows_from_is_a_row_window() {
        let ds = tiny_sparse();
        let w = ds.subset_rows(3).subset_rows_from(1);
        assert_eq!(w.n, 2);
        assert_eq!(w.labels, vec![-1.0, 1.0]);
        assert_eq!(w.sparse_row(0).unwrap().0, &[0u32, 2]);
        assert!(w.sparse_row(1).unwrap().0.is_empty());
    }

    #[test]
    fn density_math() {
        let ds = tiny_sparse();
        assert!((ds.density() - 3.0 / 9.0).abs() < 1e-12);
    }
}
