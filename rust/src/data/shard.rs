//! Data sharding for the worker topology (§4.1: "equally partition the
//! large data set").

use std::ops::Range;

/// A worker's contiguous slice of the dataset.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Shard {
    pub worker: usize,
    pub range: Range<usize>,
}

impl Shard {
    pub fn len(&self) -> usize {
        self.range.len()
    }

    pub fn is_empty(&self) -> bool {
        self.range.is_empty()
    }
}

/// Balanced contiguous partition of `n` rows over `p` workers: the first
/// `n % p` shards get one extra row. Every row lands in exactly one shard.
pub fn shard_ranges(n: usize, p: usize) -> Vec<Shard> {
    assert!(p > 0);
    let base = n / p;
    let extra = n % p;
    let mut out = Vec::with_capacity(p);
    let mut start = 0;
    for w in 0..p {
        let len = base + usize::from(w < extra);
        out.push(Shard { worker: w, range: start..start + len });
        start += len;
    }
    debug_assert_eq!(start, n);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Property: for any (n, p), shards form a partition — cover all of
    /// 0..n, are disjoint, contiguous, and balanced within 1.
    #[test]
    fn partition_property_sweep() {
        for n in [0usize, 1, 2, 7, 64, 511, 512, 513, 100_003] {
            for p in [1usize, 2, 3, 5, 8, 13, 48, 480] {
                let shards = shard_ranges(n, p);
                assert_eq!(shards.len(), p);
                let mut covered = 0usize;
                let mut prev_end = 0usize;
                let (mut min_len, mut max_len) = (usize::MAX, 0usize);
                for s in &shards {
                    assert_eq!(s.range.start, prev_end, "contiguous");
                    prev_end = s.range.end;
                    covered += s.len();
                    min_len = min_len.min(s.len());
                    max_len = max_len.max(s.len());
                }
                assert_eq!(prev_end, n);
                assert_eq!(covered, n);
                assert!(max_len - min_len <= 1, "balanced n={n} p={p}");
            }
        }
    }
}
