//! PEMSVM — Fast Parallel SVM using Data Augmentation.
//!
//! Reproduction of Perkins, Xu, Zhu & Zhang (2015) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the parallel coordinator: leader/worker
//!   map-reduce over data shards, EM / Gibbs-MC iteration loop, stopping
//!   rule, baselines, datasets, benchmarks.
//! * **L2 (`python/compile/model.py`)** — the per-iteration compute graph
//!   (worker statistics + master solve) written in JAX and AOT-lowered to
//!   HLO text artifacts.
//! * **L1 (`python/compile/kernels/`)** — the `Sigma^p = X^T diag(1/gamma) X`
//!   hot-spot as a Pallas kernel (the paper's GPU kernel, re-thought for
//!   the MXU).
//!
//! Python never runs at training time: with the `xla` cargo feature the
//! Rust binary loads the pre-compiled artifacts through PJRT and drives
//! everything (the default build is the pure-native backend and
//! compiles fully offline). See the repo-level `README.md` for a CLI
//! tour, `DESIGN.md` for the system inventory and architecture, and
//! `EXPERIMENTS.md` for the paper-vs-measured index.
//!
//! Large corpora stream into the engine chunk by chunk instead of
//! being materialized several times over (no file-sized text buffer,
//! no duplicate dataset copy — just the sharded training data): see
//! [`data::stream`] and [`engine::Cluster::from_stream`]
//! (DESIGN.md §10).
//!
//! Quick start:
//!
//! ```no_run
//! use pemsvm::config::TrainConfig;
//! use pemsvm::data::synth;
//!
//! let ds = synth::alpha_like(10_000, 64, 0);
//! let cfg = TrainConfig::default().with_options("LIN-EM-CLS").unwrap();
//! let out = pemsvm::coordinator::train(&ds, &cfg).unwrap();
//! println!("objective {} after {} iters", out.objective, out.iterations);
//! ```
//!
//! For repeated solves (sweeps, warm starts, serving), build a
//! persistent [`engine::Cluster`] once and run many sessions on it:
//!
//! ```no_run
//! use pemsvm::config::TrainConfig;
//! use pemsvm::data::synth;
//! use pemsvm::engine::{Cluster, WarmStart};
//!
//! let ds = synth::alpha_like(10_000, 64, 0);
//! let cfg = TrainConfig::default().with_options("LIN-EM-CLS").unwrap();
//! let mut cluster = Cluster::new(&ds, &cfg).unwrap();
//! for lambda in [1.0f32, 0.1, 0.01] {
//!     let mut scfg = cfg.clone();
//!     scfg.lambda = lambda;
//!     let out = cluster.run_session(&scfg, None, WarmStart::Last).unwrap();
//!     println!("lambda={lambda}: J={} in {} iters", out.objective, out.iterations);
//! }
//! ```

pub mod backend;
pub mod baselines;
pub mod benchutil;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod diag_report;
pub mod engine;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod net;
pub mod rng;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod serve;
pub mod solver;
pub mod telemetry;
