//! PEMSVM — Fast Parallel SVM using Data Augmentation.
//!
//! Reproduction of Perkins, Xu, Zhu & Zhang (2015) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the parallel coordinator: leader/worker
//!   map-reduce over data shards, EM / Gibbs-MC iteration loop, stopping
//!   rule, baselines, datasets, benchmarks.
//! * **L2 (`python/compile/model.py`)** — the per-iteration compute graph
//!   (worker statistics + master solve) written in JAX and AOT-lowered to
//!   HLO text artifacts.
//! * **L1 (`python/compile/kernels/`)** — the `Sigma^p = X^T diag(1/gamma) X`
//!   hot-spot as a Pallas kernel (the paper's GPU kernel, re-thought for
//!   the MXU).
//!
//! Python never runs at training time: the Rust binary loads the
//! pre-compiled artifacts through PJRT (`xla` crate) and drives
//! everything. See `DESIGN.md` for the system inventory and the
//! experiment index, `EXPERIMENTS.md` for paper-vs-measured results.
//!
//! Quick start:
//!
//! ```no_run
//! use pemsvm::config::TrainConfig;
//! use pemsvm::data::synth;
//!
//! let ds = synth::alpha_like(10_000, 64, 0);
//! let cfg = TrainConfig::default().with_options("LIN-EM-CLS").unwrap();
//! let out = pemsvm::coordinator::train(&ds, &cfg).unwrap();
//! println!("objective {} after {} iters", out.objective, out.iterations);
//! ```

pub mod backend;
pub mod baselines;
pub mod benchutil;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod rng;
pub mod runtime;
pub mod solver;
