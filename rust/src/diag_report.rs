//! `pemsvm diagnose` — render a convergence report from a trace file.
//!
//! Input is the JSONL emitted by `train/sweep --trace` (one
//! [`crate::telemetry::IterSpan`] per line). The report pipeline
//! re-derives every estimator offline with the brute-force
//! [`crate::telemetry::diag::reference`] implementations — the same
//! definitions the streaming accumulator uses — so a report over a
//! `--diag-every 1` trace reproduces the live values, and the embedded
//! per-iteration `diag` objects (when the run recorded them) are
//! surfaced alongside for cross-checking.
//!
//! No serde: trace records are flat, so a small recursive-descent JSON
//! parser ([`json`]) covers the grammar the tracer emits (and any
//! well-formed JSON, for robustness against hand-edited files).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::telemetry::diag::{reference, HealthVerdict, LAGS};

/// A parsed JSON value — just enough structure for trace records.
#[derive(Clone, Debug, PartialEq)]
pub enum Jv {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Jv>),
    Obj(Vec<(String, Jv)>),
}

impl Jv {
    /// Object field lookup (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Jv> {
        match self {
            Jv::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Jv::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Jv::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// Minimal recursive-descent JSON parser for trace lines.
pub mod json {
    use super::Jv;
    use anyhow::{bail, Result};

    pub fn parse(text: &str) -> Result<Jv> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing bytes after JSON value at offset {}", p.i);
        }
        Ok(v)
    }

    struct Parser<'a> {
        b: &'a [u8],
        i: usize,
    }

    impl Parser<'_> {
        fn ws(&mut self) {
            while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
                self.i += 1;
            }
        }

        fn peek(&self) -> Option<u8> {
            self.b.get(self.i).copied()
        }

        fn eat(&mut self, c: u8) -> Result<()> {
            if self.peek() == Some(c) {
                self.i += 1;
                Ok(())
            } else {
                bail!("expected `{}` at offset {}", c as char, self.i)
            }
        }

        fn lit(&mut self, s: &str, v: Jv) -> Result<Jv> {
            if self.b[self.i..].starts_with(s.as_bytes()) {
                self.i += s.len();
                Ok(v)
            } else {
                bail!("bad literal at offset {}", self.i)
            }
        }

        fn value(&mut self) -> Result<Jv> {
            match self.peek() {
                Some(b'{') => self.object(),
                Some(b'[') => self.array(),
                Some(b'"') => Ok(Jv::Str(self.string()?)),
                Some(b'n') => self.lit("null", Jv::Null),
                Some(b't') => self.lit("true", Jv::Bool(true)),
                Some(b'f') => self.lit("false", Jv::Bool(false)),
                Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
                _ => bail!("unexpected byte at offset {}", self.i),
            }
        }

        fn object(&mut self) -> Result<Jv> {
            self.eat(b'{')?;
            let mut fields = Vec::new();
            self.ws();
            if self.peek() == Some(b'}') {
                self.i += 1;
                return Ok(Jv::Obj(fields));
            }
            loop {
                self.ws();
                let key = self.string()?;
                self.ws();
                self.eat(b':')?;
                self.ws();
                let val = self.value()?;
                fields.push((key, val));
                self.ws();
                match self.peek() {
                    Some(b',') => self.i += 1,
                    Some(b'}') => {
                        self.i += 1;
                        return Ok(Jv::Obj(fields));
                    }
                    _ => bail!("expected `,` or `}}` at offset {}", self.i),
                }
            }
        }

        fn array(&mut self) -> Result<Jv> {
            self.eat(b'[')?;
            let mut items = Vec::new();
            self.ws();
            if self.peek() == Some(b']') {
                self.i += 1;
                return Ok(Jv::Arr(items));
            }
            loop {
                self.ws();
                items.push(self.value()?);
                self.ws();
                match self.peek() {
                    Some(b',') => self.i += 1,
                    Some(b']') => {
                        self.i += 1;
                        return Ok(Jv::Arr(items));
                    }
                    _ => bail!("expected `,` or `]` at offset {}", self.i),
                }
            }
        }

        fn string(&mut self) -> Result<String> {
            self.eat(b'"')?;
            let mut out = String::new();
            loop {
                match self.peek() {
                    None => bail!("unterminated string"),
                    Some(b'"') => {
                        self.i += 1;
                        return Ok(out);
                    }
                    Some(b'\\') => {
                        self.i += 1;
                        match self.peek() {
                            Some(b'"') => out.push('"'),
                            Some(b'\\') => out.push('\\'),
                            Some(b'/') => out.push('/'),
                            Some(b'n') => out.push('\n'),
                            Some(b't') => out.push('\t'),
                            Some(b'r') => out.push('\r'),
                            Some(b'b') => out.push('\u{8}'),
                            Some(b'f') => out.push('\u{c}'),
                            Some(b'u') => {
                                if self.i + 4 >= self.b.len() {
                                    bail!("truncated \\u escape");
                                }
                                let hex =
                                    std::str::from_utf8(&self.b[self.i + 1..self.i + 5])?;
                                let cp = u32::from_str_radix(hex, 16)?;
                                out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                                self.i += 4;
                            }
                            _ => bail!("bad escape at offset {}", self.i),
                        }
                        self.i += 1;
                    }
                    Some(_) => {
                        // copy the full UTF-8 character, not just a byte
                        let rest = std::str::from_utf8(&self.b[self.i..])?;
                        let c = rest.chars().next().unwrap();
                        out.push(c);
                        self.i += c.len_utf8();
                    }
                }
            }
        }

        fn number(&mut self) -> Result<Jv> {
            let start = self.i;
            while let Some(c) = self.peek() {
                if c.is_ascii_digit()
                    || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E')
                {
                    self.i += 1;
                } else {
                    break;
                }
            }
            let s = std::str::from_utf8(&self.b[start..self.i])?;
            Ok(Jv::Num(s.parse()?))
        }
    }
}

/// One trace record, reduced to what the report needs.
struct Rec {
    iter: usize,
    objective: Option<f64>,
    weight_delta: Option<f64>,
    /// the embedded `diag` object's (ess, rhat, verdict), when present
    diag: Option<(f64, f64, HealthVerdict)>,
}

/// Parse the trace file into per-session record lists.
fn load_sessions(path: &Path) -> Result<BTreeMap<usize, Vec<Rec>>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace {}", path.display()))?;
    let mut sessions: BTreeMap<usize, Vec<Rec>> = BTreeMap::new();
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = json::parse(line)
            .with_context(|| format!("{}:{}: bad trace line", path.display(), lineno + 1))?;
        let session = v.get("session").and_then(Jv::as_f64).unwrap_or(0.0) as usize;
        let iter = v
            .get("iter")
            .and_then(Jv::as_f64)
            .with_context(|| format!("{}:{}: record has no iter", path.display(), lineno + 1))?
            as usize;
        let diag = v.get("diag").and_then(|d| {
            let verdict = HealthVerdict::parse(d.get("verdict")?.as_str()?)?;
            Some((
                d.get("ess").and_then(Jv::as_f64).unwrap_or(f64::NAN),
                d.get("rhat").and_then(Jv::as_f64).unwrap_or(f64::NAN),
                verdict,
            ))
        });
        sessions.entry(session).or_default().push(Rec {
            iter,
            objective: v.get("objective").and_then(Jv::as_f64),
            weight_delta: v.get("weight_delta").and_then(Jv::as_f64),
            diag,
        });
    }
    if sessions.is_empty() {
        bail!("{}: no trace records", path.display());
    }
    Ok(sessions)
}

/// Unicode block sparkline of `xs`, downsampled to at most `width`
/// buckets (bucket mean). Constant series render as a flat low line.
pub fn sparkline(xs: &[f64], width: usize) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let finite: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
    if finite.is_empty() {
        return String::new();
    }
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for &x in &finite {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    let buckets = finite.len().min(width.max(1));
    let mut out = String::with_capacity(buckets * 3);
    for b in 0..buckets {
        let s = b * finite.len() / buckets;
        let e = ((b + 1) * finite.len() / buckets).max(s + 1);
        let mean = finite[s..e].iter().sum::<f64>() / (e - s) as f64;
        let level = if hi > lo {
            (((mean - lo) / (hi - lo)) * 7.0).round() as usize
        } else {
            0
        };
        out.push(BARS[level.min(7)]);
    }
    out
}

/// Derive a verdict offline from the post-burn-in objective chain —
/// the subset of the live thresholds (DESIGN.md §14) computable from a
/// trace alone (no step timings, no weight vectors).
fn derive_verdict(xs: &[f64], any_nonfinite: bool) -> HealthVerdict {
    if any_nonfinite {
        return HealthVerdict::Diverged;
    }
    let n = xs.len();
    if n >= 5 {
        // smoothed-objective explosion, mirroring the live detector
        let smooth: Vec<f64> =
            xs.windows(5).map(|w| w.iter().sum::<f64>() / 5.0).collect();
        let best = smooth.iter().cloned().fold(f64::INFINITY, f64::min);
        if smooth.iter().any(|&j| j > 10.0 * best + 1e-12) && best.is_finite() {
            return HealthVerdict::Diverged;
        }
    }
    if n >= 16 {
        if reference::sd(xs) == 0.0 {
            return HealthVerdict::Stalled;
        }
        let lag1 = reference::autocorr(xs, 1);
        let ess = reference::ess(xs);
        let rhat = reference::split_rhat(xs);
        if lag1 > 0.98 || ess < 0.02 * n as f64 || rhat > 1.5 {
            return HealthVerdict::MixingSlow;
        }
    }
    HealthVerdict::Healthy
}

/// Render the full diagnose report for a trace file. `burn_in` drops
/// the first iterations of each session from the chains (traces do not
/// carry the training burn-in, so the CLI takes it as a flag).
pub fn report(path: &Path, burn_in: usize) -> Result<String> {
    use std::fmt::Write;
    let sessions = load_sessions(path)?;
    let total: usize = sessions.values().map(Vec::len).sum();
    let mut out = String::new();
    writeln!(out, "pemsvm diagnose — {}", path.display())?;
    writeln!(
        out,
        "{} session(s), {} record(s), burn-in {} (post-burn-in chains)",
        sessions.len(),
        total,
        burn_in
    )?;
    writeln!(out)?;
    writeln!(
        out,
        "{:>7}  {:>6}  {:>8}  {:>6}  {:>6}  {:>10}  {:>9}  verdict",
        "session", "iters", "ess", "tau", "lag1", "split-rhat", "mcse"
    )?;
    let mut details = String::new();
    for (sid, recs) in &sessions {
        let xs: Vec<f64> = recs
            .iter()
            .filter(|r| r.iter >= burn_in)
            .filter_map(|r| r.objective)
            .filter(|x| x.is_finite())
            .collect();
        let any_nonfinite = recs
            .iter()
            .filter(|r| r.iter >= burn_in)
            .any(|r| r.objective.is_none());
        let n = xs.len();
        let (ess, tau, lag1, rhat, mcse) = if n >= 2 {
            (
                reference::ess(&xs),
                reference::tau(&xs),
                reference::autocorr(&xs, 1),
                reference::split_rhat(&xs),
                reference::mcse(&xs),
            )
        } else {
            (n as f64, 1.0, 0.0, 1.0, f64::NAN)
        };
        // the run's own verdict (last embedded diag object) wins; a
        // plain trace gets the offline derivation
        let embedded = recs.iter().rev().find_map(|r| r.diag);
        let verdict = embedded
            .map(|(_, _, v)| v)
            .unwrap_or_else(|| derive_verdict(&xs, any_nonfinite));
        writeln!(
            out,
            "{:>7}  {:>6}  {:>8.1}  {:>6.2}  {:>6.3}  {:>10.4}  {:>9.3e}  {}",
            sid,
            recs.len(),
            ess,
            tau,
            lag1,
            rhat,
            mcse,
            verdict.display()
        )?;

        writeln!(details, "session {sid}: {} iters, {} post-burn-in samples", recs.len(), n)?;
        writeln!(
            details,
            "  objective  mean={:.6}  sd={:.3e}  mcse={:.3e}  ess={:.1}",
            reference::mean(&xs),
            reference::sd(&xs),
            mcse,
            ess
        )?;
        let rho: Vec<String> = LAGS
            .iter()
            .filter(|&&l| n > l)
            .map(|&l| format!("{l}:{:+.3}", reference::autocorr(&xs, l)))
            .collect();
        writeln!(details, "  autocorr   {}", rho.join("  "))?;
        match embedded {
            Some((e_ess, e_rhat, v)) => writeln!(
                details,
                "  verdict    {} (recorded in trace; live ess={e_ess:.1} rhat={e_rhat:.3})",
                v.display()
            )?,
            None => writeln!(details, "  verdict    {} (derived offline)", verdict.display())?,
        }
        writeln!(details, "  J          {}", sparkline(&xs, 60))?;
        let wd: Vec<f64> = recs
            .iter()
            .filter(|r| r.iter >= burn_in)
            .filter_map(|r| r.weight_delta)
            .collect();
        if !wd.is_empty() {
            writeln!(details, "  |dw|       {}", sparkline(&wd, 60))?;
        }
        writeln!(details)?;
    }
    writeln!(out)?;
    out.push_str(&details);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_parser_handles_trace_shapes() {
        let v = json::parse(
            r#"{"session":0,"iter":3,"objective":12.5,"test_metric":null,
                "phases":{"draw_gamma":0.001},"arr":[1,-2.5e3,true,false,"x\n"]}"#,
        )
        .unwrap();
        assert_eq!(v.get("session").and_then(Jv::as_f64), Some(0.0));
        assert_eq!(v.get("objective").and_then(Jv::as_f64), Some(12.5));
        assert_eq!(v.get("test_metric"), Some(&Jv::Null));
        assert_eq!(
            v.get("phases").and_then(|p| p.get("draw_gamma")).and_then(Jv::as_f64),
            Some(0.001)
        );
        match v.get("arr") {
            Some(Jv::Arr(items)) => {
                assert_eq!(items[1], Jv::Num(-2500.0));
                assert_eq!(items[4], Jv::Str("x\n".into()));
            }
            other => panic!("bad arr: {other:?}"),
        }
        assert!(json::parse("{\"a\":1,}").is_err());
        assert!(json::parse("{\"a\"").is_err());
        assert!(json::parse("12 34").is_err());
    }

    #[test]
    fn sparkline_spans_levels() {
        let xs: Vec<f64> = (0..8).map(|i| i as f64).collect();
        let s = sparkline(&xs, 8);
        assert_eq!(s.chars().count(), 8);
        assert!(s.starts_with('▁') && s.ends_with('█'));
        assert_eq!(sparkline(&[5.0; 10], 4).chars().count(), 4);
        assert_eq!(sparkline(&[], 10), "");
    }

    #[test]
    fn report_on_synthetic_trace_matches_reference() {
        let dir = std::env::temp_dir().join("pemsvm_diag_report_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.jsonl");
        // a well-mixing pseudo-chain: deterministic LCG noise
        let mut text = String::new();
        let mut x = 7u64;
        let mut xs = Vec::new();
        for i in 0..64 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let obj = 100.0 + (x >> 40) as f64 / 1e6;
            xs.push(obj);
            text.push_str(&format!(
                "{{\"session\":0,\"iter\":{i},\"objective\":{obj},\"weight_delta\":0.1}}\n"
            ));
        }
        std::fs::write(&path, text).unwrap();
        let rep = report(&path, 0).unwrap();
        let want_ess = reference::ess(&xs);
        assert!(
            rep.contains(&format!("ess={want_ess:.1}")),
            "report should carry the reference ESS {want_ess:.1}:\n{rep}"
        );
        assert!(rep.contains("Healthy"), "{rep}");
        // burn-in drops leading iterations from the chain
        let rep2 = report(&path, 32).unwrap();
        let want2 = reference::ess(&xs[32..]);
        assert!(rep2.contains(&format!("ess={want2:.1}")), "{rep2}");
    }

    #[test]
    fn stuck_and_exploding_traces_get_flagged() {
        let dir = std::env::temp_dir().join("pemsvm_diag_report_test");
        std::fs::create_dir_all(&dir).unwrap();
        let stuck = dir.join("stuck.jsonl");
        let mut text = String::new();
        for i in 0..32 {
            text.push_str(&format!("{{\"session\":0,\"iter\":{i},\"objective\":5.0}}\n"));
        }
        std::fs::write(&stuck, text).unwrap();
        assert!(report(&stuck, 0).unwrap().contains("Stalled"));

        let bad = dir.join("diverged.jsonl");
        let mut text = String::new();
        for i in 0..12 {
            let obj = if i < 10 { "2.0".into() } else { "null".to_string() };
            text.push_str(&format!("{{\"session\":0,\"iter\":{i},\"objective\":{obj}}}\n"));
        }
        std::fs::write(&bad, text).unwrap();
        assert!(report(&bad, 0).unwrap().contains("Diverged"));
    }

    #[test]
    fn embedded_verdict_wins_over_derivation() {
        let dir = std::env::temp_dir().join("pemsvm_diag_report_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("embedded.jsonl");
        let mut text = String::new();
        for i in 0..20 {
            text.push_str(&format!(
                "{{\"session\":0,\"iter\":{i},\"objective\":5.0,\"diag\":{{\"ess\":3.5,\
                 \"tau\":2,\"lag1\":0.9,\"rhat\":1.2,\"mcse\":0.1,\"skew\":1.0,\
                 \"verdict\":\"mixing-slow\"}}}}\n"
            ));
        }
        std::fs::write(&path, text).unwrap();
        let rep = report(&path, 0).unwrap();
        // a constant chain would derive Stalled; the recorded verdict wins
        assert!(rep.contains("Mixing-Slow"), "{rep}");
        assert!(rep.contains("recorded in trace"), "{rep}");
    }
}
