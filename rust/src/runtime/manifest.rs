//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! and the Rust runtime.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::config::Json;

/// One artifact's metadata.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    /// "lin_step" | "svr_step" | "mlt_step" | "solve" | "predict" | "predict_mlt"
    pub kind: String,
    /// "em" | "mc"
    pub variant: String,
    pub k: usize,
    pub chunk: usize,
    pub m: usize,
    pub num_inputs: usize,
    pub num_outputs: usize,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub chunk: usize,
    pub k_family: Vec<usize>,
    pub m_classes: usize,
    by_name: HashMap<String, ArtifactMeta>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let need = |v: Option<usize>, what: &str| v.ok_or_else(|| anyhow!("manifest: missing {what}"));
        let chunk = need(j.get("chunk").and_then(Json::as_usize), "chunk")?;
        let m_classes = need(j.get("m_classes").and_then(Json::as_usize), "m_classes")?;
        let mut k_family: Vec<usize> = j
            .get("k_family")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest: missing k_family"))?
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        k_family.sort_unstable();

        let mut by_name = HashMap::new();
        for a in j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest: missing artifacts"))?
        {
            let s = |key: &str| -> Result<String> {
                a.get(key)
                    .and_then(Json::as_str)
                    .map(str::to_string)
                    .ok_or_else(|| anyhow!("artifact missing `{key}`"))
            };
            let u = |key: &str| -> Result<usize> {
                a.get(key)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("artifact missing `{key}`"))
            };
            let meta = ArtifactMeta {
                name: s("name")?,
                file: s("file")?,
                kind: s("kind")?,
                variant: s("variant")?,
                k: u("k")?,
                chunk: u("chunk")?,
                m: u("m")?,
                num_inputs: a
                    .get("inputs")
                    .and_then(Json::as_arr)
                    .map(|x| x.len())
                    .ok_or_else(|| anyhow!("artifact missing `inputs`"))?,
                num_outputs: u("num_outputs")?,
            };
            by_name.insert(meta.name.clone(), meta);
        }
        Ok(Manifest { chunk, k_family, m_classes, by_name })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.by_name.get(name)
    }

    pub fn len(&self) -> usize {
        self.by_name.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_name.is_empty()
    }

    /// Artifact name for a worker step.
    pub fn step_name(kind: &str, variant: &str, k: usize, m: usize) -> String {
        match kind {
            "mlt_step" => format!("mlt_{variant}_step_k{k}_m{m}"),
            "lin_step" => format!("lin_{variant}_step_k{k}"),
            "lin_step_jnp" => format!("lin_{variant}_step_jnp_k{k}"),
            "svr_step" => format!("svr_{variant}_step_k{k}"),
            _ => unreachable!("not a step kind: {kind}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "chunk": 512, "k_family": [64, 16], "m_classes": 10,
        "artifacts": [
            {"name": "lin_em_step_k16", "file": "lin_em_step_k16.hlo.txt",
             "kind": "lin_step", "variant": "em", "k": 16, "chunk": 512, "m": 0,
             "num_outputs": 4, "sha256": "ab",
             "inputs": [{"shape": [512,16], "dtype": "float32"},
                        {"shape": [512], "dtype": "float32"},
                        {"shape": [512], "dtype": "float32"},
                        {"shape": [16], "dtype": "float32"},
                        {"shape": [1], "dtype": "float32"}]}
        ]}"#;

    #[test]
    fn parses_and_sorts_family() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.chunk, 512);
        assert_eq!(m.k_family, vec![16, 64]);
        let a = m.get("lin_em_step_k16").unwrap();
        assert_eq!(a.num_inputs, 5);
        assert_eq!(a.num_outputs, 4);
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn step_names() {
        assert_eq!(Manifest::step_name("lin_step", "em", 16, 0), "lin_em_step_k16");
        assert_eq!(Manifest::step_name("mlt_step", "mc", 64, 10), "mlt_mc_step_k64_m10");
    }

    #[test]
    fn missing_fields_rejected() {
        assert!(Manifest::parse(r#"{"chunk": 1}"#).is_err());
    }
}
