//! PJRT runtime: load the AOT HLO-text artifacts and execute them.
//!
//! One shared CPU [`xla::PjRtClient`] per process; executables are
//! compiled lazily per artifact and cached. The `xla` crate's handles
//! wrap raw pointers without `Send`/`Sync`, so the runtime serializes
//! device access behind a mutex — which is also the honest model of the
//! paper's GPU backend (§5.7.2): one accelerator shared by all workers,
//! partitions processed as a queue. (PJRT CPU parallelizes *inside* an
//! execution with its own thread pool.)

mod manifest;

pub use manifest::{ArtifactMeta, Manifest};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Mutex, OnceLock};

use anyhow::{anyhow, bail, Context, Result};

struct Device {
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

/// Handle to the artifacts + compiled-executable cache.
///
/// Typically wrapped in `Arc` (or obtained via [`global`]) and shared by
/// every worker thread.
pub struct Runtime {
    dir: PathBuf,
    pub manifest: Manifest,
    device: Mutex<Device>,
}

// SAFETY: all access to the client / executables goes through the
// `device` mutex; the raw PJRT handles never escape it. PJRT itself is
// thread-safe, the mutex is belt-and-braces for the wrapper types.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Load `manifest.json` from `dir` and create the CPU client.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime {
            dir: dir.to_path_buf(),
            manifest,
            device: Mutex::new(Device { client, cache: HashMap::new() }),
        })
    }

    /// Rows per worker-step execution.
    pub fn chunk(&self) -> usize {
        self.manifest.chunk
    }

    /// Smallest artifact K that fits `k` features. Feature padding is
    /// exact: zero columns contribute nothing to the statistics and the
    /// lam*I block keeps the padded solve well-posed with w_pad = 0.
    pub fn pad_k(&self, k: usize) -> Result<usize> {
        self.manifest
            .k_family
            .iter()
            .copied()
            .find(|&fk| fk >= k)
            .ok_or_else(|| {
                anyhow!(
                    "K={k} exceeds the largest artifact K={} (regenerate artifacts)",
                    self.manifest.k_family.last().copied().unwrap_or(0)
                )
            })
    }

    /// Execute artifact `name` on `args`, returning the untupled outputs.
    /// Accepts owned literals or references (`Borrow<Literal>`).
    pub fn execute<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        name: &str,
        args: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let meta = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact `{name}`"))?;
        if meta.num_inputs != args.len() {
            bail!("artifact `{name}` wants {} inputs, got {}", meta.num_inputs, args.len());
        }
        let mut dev = self.device.lock().unwrap();
        if !dev.cache.contains_key(name) {
            let path = self.dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = dev
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
            dev.cache.insert(name.to_string(), exe);
        }
        let exe = dev.cache.get(name).unwrap();
        let borrowed: Vec<&xla::Literal> = args.iter().map(|a| a.borrow()).collect();
        let result = exe
            .execute::<&xla::Literal>(&borrowed)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
        tuple.to_tuple().map_err(|e| anyhow!("untuple {name}: {e:?}"))
    }

    /// Number of artifacts compiled so far (for tests/metrics).
    pub fn compiled_count(&self) -> usize {
        self.device.lock().unwrap().cache.len()
    }
}

/// Process-wide runtime singleton keyed by artifacts dir — PJRT CPU
/// clients are expensive (each owns a thread pool), so examples, tests
/// and benches share one.
pub fn global(dir: &Path) -> Result<&'static Runtime> {
    static CELL: OnceLock<Mutex<HashMap<PathBuf, &'static Runtime>>> = OnceLock::new();
    let map = CELL.get_or_init(|| Mutex::new(HashMap::new()));
    let mut map = map.lock().unwrap();
    if let Some(rt) = map.get(dir) {
        return Ok(rt);
    }
    let rt: &'static Runtime = Box::leak(Box::new(Runtime::load(dir)?));
    map.insert(dir.to_path_buf(), rt);
    Ok(rt)
}

/// Build an f32 literal of the given logical shape from a flat slice.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    if dims.len() == 1 {
        debug_assert_eq!(dims[0] as usize, data.len());
        return Ok(lit);
    }
    lit.reshape(dims).map_err(|e| anyhow!("reshape: {e:?}"))
}

/// Fetch an f32 output.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn load_and_execute_predict() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let rt = global(&dir).unwrap();
        let chunk = rt.chunk();
        let k = 16usize;
        // x = row of ones for d=0, zeros elsewhere; w = [0..k)
        let mut x = vec![0f32; chunk * k];
        for j in 0..k {
            x[j] = 1.0;
        }
        let w: Vec<f32> = (0..k).map(|j| j as f32).collect();
        let out = rt
            .execute(
                "predict_k16",
                &[
                    literal_f32(&x, &[chunk as i64, k as i64]).unwrap(),
                    literal_f32(&w, &[k as i64]).unwrap(),
                ],
            )
            .unwrap();
        let scores = to_vec_f32(&out[0]).unwrap();
        assert_eq!(scores.len(), chunk);
        let want: f32 = (0..k).map(|j| j as f32).sum();
        assert!((scores[0] - want).abs() < 1e-4);
        assert_eq!(scores[1], 0.0);
        assert!(rt.compiled_count() >= 1);
    }

    #[test]
    fn pad_k_picks_smallest_fit() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let rt = global(&dir).unwrap();
        assert_eq!(rt.pad_k(1).unwrap(), 16);
        assert_eq!(rt.pad_k(16).unwrap(), 16);
        assert_eq!(rt.pad_k(17).unwrap(), 64);
        assert_eq!(rt.pad_k(500).unwrap(), 1024);
        assert!(rt.pad_k(5000).is_err());
    }
}
