//! The persistent training engine — the architectural seam between "run
//! one `train()`" and "serve sustained training traffic".
//!
//! A [`Cluster`] is built **once** from a dataset + topology config: it
//! shards the data, constructs one worker backend per shard (uploading
//! chunk literals on the XLA backend — the expensive part), and, in the
//! threaded topology, spawns the worker threads. It then runs any number
//! of **sessions** — repeated solves, lambda/config sweeps, warm starts
//! from a previous solution — without re-spawning threads or re-sharding
//! data. The paper's iteration is an embarrassingly parallel
//! `worker step -> reduce -> master solve` round (§4.1); amortizing the
//! cluster setup across solves is where sustained-traffic throughput
//! comes from (cf. arXiv:1406.5161, arXiv:2207.01016).
//!
//! Three pieces (see DESIGN.md §2):
//!
//! * [`pool::Pool`] — the worker runtime behind a
//!   [`Topology`](crate::config::Topology): real
//!   threads or the sequential cluster cost model, plus the in-pool
//!   tree reduce (pair merges on worker threads).
//! * [`driver::IterDriver`] — per-task iteration logic:
//!   [`driver::BinaryDriver`], [`driver::SvrDriver`],
//!   [`driver::CsBlockDriver`].
//! * [`Cluster::run_session`] — the shared session scaffolding:
//!   stopping rule (§5.5), MC burn-in averaging (§5.13), history,
//!   metrics.
//!
//! `coordinator::train` / `train_full` remain as thin one-shot wrappers.

pub mod checkpoint;
pub mod driver;
pub mod fault;
pub mod pool;

use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

pub use checkpoint::{Checkpoint, CheckpointCfg};
pub use driver::{BinaryDriver, CsBlockDriver, IterDriver, IterStats, SvrDriver};
pub use fault::{FaultKind, FaultPlan};
pub use pool::{FaultStats, Pool, PoolOpts, StepTiming};

use crate::backend::{self, MasterBackend, RngState, StepInput, WorkerBackend};
use crate::config::{Algo, BackendKind, ModelKind, TaskKind, TrainConfig};
use crate::data::stream::StreamReader;
use crate::data::{shard_ranges, Dataset, Task};
use crate::net::remote::RemoteWorker;
use crate::net::wire::{remote_hosts, WorkerSpec};
use crate::linalg::Mat;
use crate::metrics::{Metrics, Phase, NPHASES, PHASES};
use crate::model::Weights;
use crate::rng::{NormalSource, Pcg64};
use crate::solver::{KernelModel, PartialStats};
use crate::telemetry::diag::{ChainDiag, HealthVerdict, IterObs};
use crate::telemetry::{self, Counter, Histogram, IterSpan, TraceWriter};

/// Per-iteration record (drives Figures 5 and 6).
#[derive(Clone, Debug)]
pub struct IterRecord {
    pub iter: usize,
    /// primal objective J at the weights the step was computed from
    pub objective: f64,
    /// training loss sum (hinge / eps-insensitive / CS)
    pub train_loss: f64,
    /// `err_sum / N`: the training **error fraction** for CLS/MLT (aux
    /// counts misclassifications) and the **mean squared residual** for
    /// SVR (aux sums squared residuals) — same ratio, different statistic
    pub train_err: f64,
    /// held-out metric (accuracy or RMSE) if a test set was supplied
    pub test_metric: Option<f64>,
    /// this iteration's wall-clock per phase
    /// ([`crate::metrics::PHASES`] order, seconds)
    pub phase_secs: [f64; NPHASES],
    /// `||w_t - w_{t-1}||_2` over the flat weight view (for KRN this is
    /// the dual omega) — the convergence quantity behind Figure 5
    pub weight_delta: f64,
}

/// Session-lifetime training counters in the global telemetry registry
/// (DESIGN.md §12). Registered once per process; the session loop adds
/// into them so `--metrics-out` and `#metrics` see training activity.
struct EngineMetrics {
    sessions: Arc<Counter>,
    iterations: Arc<Counter>,
    iteration_nanos: Arc<Histogram>,
    /// one `train_phase_nanos_total{phase=...}` series per [`PHASES`] entry
    phase_nanos: [Arc<Counter>; NPHASES],
}

fn engine_metrics() -> &'static EngineMetrics {
    static M: OnceLock<EngineMetrics> = OnceLock::new();
    M.get_or_init(|| {
        let reg = telemetry::global();
        EngineMetrics {
            sessions: reg.counter("train_sessions_total", "Completed training sessions."),
            iterations: reg.counter("train_iterations_total", "Completed training iterations."),
            iteration_nanos: reg.histogram(
                "train_iteration_nanos",
                "Full-iteration wall-clock in nanoseconds.",
            ),
            phase_nanos: std::array::from_fn(|i| {
                reg.counter_labeled(
                    "train_phase_nanos_total",
                    &telemetry::label("phase", PHASES[i].name()),
                    "Training wall-clock per Table-1 phase in nanoseconds.",
                )
            }),
        }
    })
}

/// Everything a training session returns.
pub struct TrainOutput {
    pub weights: Weights,
    pub objective: f64,
    pub iterations: usize,
    pub metrics: Metrics,
    pub history: Vec<IterRecord>,
    /// populated for KRN runs: the dual model for prediction
    pub kernel_model: Option<KernelModel>,
    /// final convergence-health verdict (DESIGN.md §14) when the
    /// session ran with `diag_every > 0`; stamped into saved models
    pub verdict: Option<HealthVerdict>,
}

/// How a session initializes its weights.
#[derive(Clone, Copy, Debug, Default)]
pub enum WarmStart<'a> {
    /// start from zero
    #[default]
    Cold,
    /// start from the cluster's previous session's solution (cold if
    /// no session has run yet)
    Last,
    /// start from explicit weights
    Weights(&'a Weights),
}

/// What the drivers see each iteration: the pool, the master backend,
/// and the session's config/RNG/metrics, behind two composite
/// operations (`collect`, `solve`).
pub struct EngineCtx<'a> {
    pool: &'a mut Pool,
    master: &'a mut dyn MasterBackend,
    metrics: &'a mut Metrics,
    pub(crate) cfg: &'a TrainConfig,
    gram: Option<&'a Arc<Mat>>,
    rng: &'a mut Pcg64,
    normals: &'a mut NormalSource,
    dim: usize,
}

impl EngineCtx<'_> {
    /// One broadcast + collect + reduce round.
    pub fn collect(&mut self, input: StepInput) -> Result<PartialStats> {
        let partials = self.pool.step_all(input, self.metrics)?;
        self.pool.reduce(self.cfg.reduce, partials, self.metrics)
    }

    /// The master solve (Eq. 6), drawing MC posterior noise when the
    /// session runs the sampler.
    pub fn solve(&mut self, stats: &mut PartialStats) -> Result<Vec<f32>> {
        let noise = (self.cfg.algo == Algo::Mc).then(|| {
            let mut z = vec![0f32; self.dim];
            self.normals.fill_f32(self.rng, &mut z);
            z
        });
        let master = &mut *self.master;
        self.metrics.time(Phase::DrawMu, || master.solve(stats, noise.as_deref()))
    }

    /// `lam/2 w^T R w` — R = I for LIN, the Gram matrix for KRN (§3.1).
    pub fn reg_quad(&self, w: &[f32]) -> f64 {
        match self.gram {
            None => 0.5 * self.cfg.lambda as f64 * crate::linalg::norm2_sq(w) as f64,
            Some(g) => {
                let k = g.rows.min(w.len());
                let mut q = 0f64;
                for i in 0..k {
                    q += w[i] as f64 * crate::linalg::dot(&g.row(i)[..k], &w[..k]) as f64;
                }
                0.5 * self.cfg.lambda as f64 * q
            }
        }
    }
}

/// The stopping rule (§5.5): `|J_m - J_{m-1}| <= tol * N`, on a
/// 5-iteration moving average of J for the MC sampler.
struct StopRule {
    j_prev: f64,
    smooth: Vec<f64>,
    mc: bool,
    min_iters: usize,
    tol_n: f64,
}

impl StopRule {
    fn new(cfg: &TrainConfig, n: usize) -> Self {
        let mc = cfg.algo == Algo::Mc;
        StopRule {
            j_prev: f64::INFINITY,
            smooth: Vec::new(),
            mc,
            min_iters: if mc { cfg.burn_in + 5 } else { 2 },
            tol_n: cfg.tol as f64 * n as f64,
        }
    }

    fn converged(&mut self, iter: usize, j: f64) -> bool {
        let j_s = if self.mc {
            self.smooth.push(j);
            let lo = self.smooth.len().saturating_sub(5);
            self.smooth[lo..].iter().sum::<f64>() / (self.smooth.len() - lo) as f64
        } else {
            j
        };
        let stop = iter >= self.min_iters && (self.j_prev - j_s).abs() <= self.tol_n;
        self.j_prev = j_s;
        stop
    }
}

/// Build one [`RemoteWorker`] proxy per host for a
/// [`Topology::Remote`](crate::config::Topology::Remote) cluster
/// (DESIGN.md §15): connect, configure with the *same* seed / worker id
/// / shard range the in-process pool would use, and — in eager mode
/// (`ds` given) — ship the full dataset so every daemon can adopt an
/// evicted peer's global row ranges later. With `ds` absent the workers
/// are streamed: chunks arrive over the wire through the pool's normal
/// ingest broadcast.
fn make_remote_workers(
    cfg: &TrainConfig,
    hosts: &[String],
    shards: &[std::ops::Range<usize>],
    k: usize,
    n: usize,
    task: Task,
    ds: Option<&Dataset>,
) -> Result<Vec<Box<dyn WorkerBackend>>> {
    if cfg.backend != BackendKind::Native {
        bail!("--hosts drives the native backend; the XLA backend is in-process only");
    }
    if hosts.len() != shards.len() {
        bail!(
            "{} worker hosts given for {} workers (pass one host:port per worker)",
            hosts.len(),
            shards.len()
        );
    }
    let timeout = Duration::from_millis(cfg.step_timeout_ms);
    let mut out: Vec<Box<dyn WorkerBackend>> = Vec::with_capacity(hosts.len());
    for (wid, (host, r)) in hosts.iter().zip(shards).enumerate() {
        let spec = WorkerSpec {
            wid: wid as u64,
            seed: cfg.seed,
            algo: cfg.algo,
            task,
            eps_clamp: cfg.eps_clamp,
            k,
            n,
            range: r.clone(),
            streamed: ds.is_none(),
        };
        let rw = RemoteWorker::connect(host, spec, timeout)?;
        if let Some(ds) = ds {
            rw.ship_dataset(ds)?;
        }
        out.push(Box::new(rw));
    }
    Ok(out)
}

/// A persistent worker-pool cluster bound to one dataset.
///
/// Construction pays the full setup cost (clone + shard the dataset,
/// build one backend per shard, spawn threads); every subsequent
/// [`run_session`](Cluster::run_session) reuses all of it.
pub struct Cluster {
    cfg: TrainConfig,
    /// dataset shape; the rows themselves live only in the workers'
    /// shards (which is what lets `from_stream` ingest out-of-core)
    n: usize,
    k: usize,
    gram: Option<Arc<Mat>>,
    pool: Pool,
    /// statistics width: `k`, or the padded width on the XLA backend
    dim: usize,
    m_classes: usize,
    sessions: usize,
    last: Option<Weights>,
}

impl Cluster {
    /// Build a cluster over `ds` with `cfg`'s topology (workers,
    /// backend, algo, seed and topology are fixed for the cluster's
    /// lifetime; per-session knobs like lambda/tol/max_iters may vary).
    pub fn new(ds: &Dataset, cfg: &TrainConfig) -> Result<Cluster> {
        Self::with_gram(ds, cfg, None)
    }

    /// [`new`](Cluster::new) with a deterministic [`FaultPlan`] compiled
    /// into the pool — the chaos harness's entry point (DESIGN.md §13).
    pub fn new_with_faults(ds: &Dataset, cfg: &TrainConfig, plan: FaultPlan) -> Result<Cluster> {
        Self::with_gram_faults(ds, cfg, None, plan)
    }

    /// KRN variant: `ds` is the Gram-row dataset and `gram` the Gram
    /// regularizer (§3.1).
    pub fn with_gram(
        ds: &Dataset,
        cfg: &TrainConfig,
        gram: Option<Arc<Mat>>,
    ) -> Result<Cluster> {
        Self::with_gram_faults(ds, cfg, gram, FaultPlan::none())
    }

    /// [`with_gram`](Cluster::with_gram) with a [`FaultPlan`].
    pub fn with_gram_faults(
        ds: &Dataset,
        cfg: &TrainConfig,
        gram: Option<Arc<Mat>>,
        plan: FaultPlan,
    ) -> Result<Cluster> {
        match (cfg.task, ds.task) {
            (TaskKind::Cls, Task::Binary)
            | (TaskKind::Svr, Task::Regression)
            | (TaskKind::Mlt, Task::Multiclass(_)) => {}
            (t, d) => bail!("config task {t:?} does not match dataset task {d:?}"),
        }
        let p = cfg.workers.max(1);
        let ds_arc = Arc::new(ds.clone());
        let shards: Vec<_> = shard_ranges(ds.n, p).into_iter().map(|s| s.range).collect();
        let workers = match remote_hosts(&cfg.topology) {
            Some(hosts) => make_remote_workers(cfg, hosts, &shards, ds.k, ds.n, ds.task, Some(ds))?,
            None => backend::make_workers(cfg, &ds_arc, &shards)?,
        };
        let dim = workers.iter().map(|w| w.stat_dim()).max().unwrap_or(ds.k);
        // eager workers view the full dataset, so the pool can re-shard
        // an evicted worker's global row ranges onto survivors
        let pool = Pool::spawn_with(
            workers,
            cfg.topology.clone(),
            PoolOpts {
                shards: Some(shards.clone()),
                plan,
                step_timeout: Duration::from_millis(cfg.step_timeout_ms),
                step_retries: cfg.step_retries,
            },
        );
        let m_classes = match ds.task {
            Task::Multiclass(m) => m,
            _ => 1,
        };
        Ok(Cluster {
            cfg: cfg.clone(),
            n: ds.n,
            k: ds.k,
            gram,
            pool,
            dim,
            m_classes,
            sessions: 0,
            last: None,
        })
    }

    /// Build a cluster by **streaming** the corpus through a
    /// [`StreamReader`] instead of pinning a materialized dataset
    /// (DESIGN.md §10): shard windows are computed from the reader's
    /// fixed row count, each arriving chunk is broadcast to the pool and
    /// appended into the owning workers' shard buffers (the append runs
    /// on the worker threads, overlapping the prefetch thread's
    /// read+parse of the next chunk), and at end of stream every shard
    /// is validated and sealed. The resulting cluster holds exactly the
    /// shards [`Cluster::new`] would have built from the eager loader —
    /// same rows, same order, same f32 values — so training trajectories
    /// are bit-identical for a fixed seed (`tests/stream_equivalence.rs`).
    pub fn from_stream(reader: StreamReader, cfg: &TrainConfig) -> Result<Cluster> {
        let task = reader.task();
        match (cfg.task, task) {
            (TaskKind::Cls, Task::Binary)
            | (TaskKind::Svr, Task::Regression)
            | (TaskKind::Mlt, Task::Multiclass(_)) => {}
            (t, d) => bail!("config task {t:?} does not match stream task {d:?}"),
        }
        if cfg.model == ModelKind::Kernel {
            bail!(
                "streamed construction supports linear models; KRN materializes the Gram \
                 dataset (use Cluster::with_gram on the eager loader)"
            );
        }
        let p = cfg.workers.max(1);
        let (n, k) = (reader.n(), reader.k());
        let shards: Vec<_> = shard_ranges(n, p).into_iter().map(|s| s.range).collect();
        let workers = match remote_hosts(&cfg.topology) {
            Some(hosts) => make_remote_workers(cfg, hosts, &shards, k, n, task, None)?,
            None => backend::make_stream_workers(cfg, k, task, &shards)?,
        };
        let dim = workers.iter().map(|w| w.stat_dim()).max().unwrap_or(k);
        // streamed workers hold only their own shard, so the pool cannot
        // re-shard on eviction (`shards: None`); a worker death here is
        // fatal and the run must restart from ingestion
        let mut pool = Pool::spawn_with(
            workers,
            cfg.topology.clone(),
            PoolOpts {
                shards: None,
                plan: FaultPlan::none(),
                step_timeout: Duration::from_millis(cfg.step_timeout_ms),
                step_retries: cfg.step_retries,
            },
        );
        for chunk in reader {
            pool.ingest_all(chunk?)?;
        }
        pool.seal_all()?;
        let m_classes = match task {
            Task::Multiclass(m) => m,
            _ => 1,
        };
        Ok(Cluster {
            cfg: cfg.clone(),
            n,
            k,
            gram: None,
            pool,
            dim,
            m_classes,
            sessions: 0,
            last: None,
        })
    }

    pub fn workers(&self) -> usize {
        self.pool.len()
    }

    /// Workers still trusted with step commands (== [`workers`](Cluster::workers)
    /// unless some were evicted mid-session).
    pub fn alive_workers(&self) -> usize {
        self.pool.alive()
    }

    /// This cluster's pool-local retry/eviction counters — the
    /// per-instance twin of `worker_retries_total` /
    /// `worker_evictions_total`.
    pub fn fault_counters(&self) -> FaultStats {
        self.pool.fault_counters()
    }

    /// Sessions completed on this cluster so far.
    pub fn sessions(&self) -> usize {
        self.sessions
    }

    /// The previous session's solution, if any.
    pub fn last_weights(&self) -> Option<&Weights> {
        self.last.as_ref()
    }

    /// A session config must agree with the cluster on everything baked
    /// into the workers at construction.
    fn check_compat(&self, cfg: &TrainConfig) -> Result<()> {
        let base = &self.cfg;
        if cfg.workers.max(1) != self.pool.len() {
            bail!(
                "session wants {} workers, cluster was built with {}",
                cfg.workers.max(1),
                self.pool.len()
            );
        }
        if cfg.backend != base.backend {
            bail!("session backend {:?} != cluster backend {:?}", cfg.backend, base.backend);
        }
        if cfg.algo != base.algo {
            bail!(
                "session algo {:?} != cluster algo {:?} (worker gamma mode is fixed at \
                 construction)",
                cfg.algo,
                base.algo
            );
        }
        if cfg.task != base.task {
            bail!("session task {:?} != cluster task {:?}", cfg.task, base.task);
        }
        if cfg.seed != base.seed {
            bail!("session seed {} != cluster seed {} (worker RNG streams)", cfg.seed, base.seed);
        }
        if cfg.eps_clamp != base.eps_clamp {
            bail!("session eps_clamp differs from the cluster's");
        }
        if cfg.topology != base.topology {
            bail!(
                "session topology {:?} != cluster topology {:?}",
                cfg.topology,
                base.topology
            );
        }
        Ok(())
    }

    /// Convenience: one session under the cluster's own config.
    pub fn train(&mut self, test: Option<&Dataset>) -> Result<TrainOutput> {
        let cfg = self.cfg.clone();
        self.run_session(&cfg, test, WarmStart::Cold)
    }

    /// Run one training session on the live cluster. Threads stay up and
    /// shards stay resident across calls; only the master backend and
    /// the driver's weight state are per-session.
    pub fn run_session(
        &mut self,
        cfg: &TrainConfig,
        test: Option<&Dataset>,
        warm: WarmStart<'_>,
    ) -> Result<TrainOutput> {
        self.run_session_traced(cfg, test, warm, None)
    }

    /// [`run_session`](Cluster::run_session) with iteration span tracing
    /// (DESIGN.md §12): when `trace` is given, one JSONL record per
    /// iteration — phase timings, objective, loss, weight-delta norm —
    /// is written through the [`TraceWriter`]. Either way each iteration
    /// is folded into the global telemetry registry, so `--metrics-out`
    /// and the serve `#metrics` verb see training activity.
    pub fn run_session_traced(
        &mut self,
        cfg: &TrainConfig,
        test: Option<&Dataset>,
        warm: WarmStart<'_>,
        trace: Option<&mut TraceWriter>,
    ) -> Result<TrainOutput> {
        self.run_session_checkpointed(cfg, test, warm, trace, None)
    }

    /// [`run_session_traced`](Cluster::run_session_traced) with
    /// checkpointing (DESIGN.md §13): with `ck`, the full session state
    /// — driver weights, MC running average, stopping-rule tail, master
    /// and worker RNG streams — is written atomically every
    /// [`CheckpointCfg::every`] iterations, and `resume` restores all of
    /// it so the continued run is **bit-identical** to one that was
    /// never interrupted (`tests/chaos.rs`). A checkpoint written after
    /// an eviction still resumes exactly — onto a fresh full-strength
    /// pool — for EM; an MC resume requires every worker's sampler
    /// state, so it refuses a checkpoint with gaps.
    pub fn run_session_checkpointed(
        &mut self,
        cfg: &TrainConfig,
        test: Option<&Dataset>,
        warm: WarmStart<'_>,
        mut trace: Option<&mut TraceWriter>,
        ck: Option<&CheckpointCfg>,
    ) -> Result<TrainOutput> {
        self.check_compat(cfg)?;
        let mut master = backend::make_master(cfg, self.dim, self.gram.clone())?;
        let mut metrics = Metrics::new();
        let mut history: Vec<IterRecord> = Vec::new();
        let mut rng = Pcg64::new_stream(cfg.seed, 0x1ead);
        let mut normals = NormalSource::new();

        let mut drv: Box<dyn IterDriver> = match cfg.task {
            TaskKind::Cls => Box::new(BinaryDriver::new(self.dim)),
            TaskKind::Svr => Box::new(SvrDriver::new(self.dim)),
            TaskKind::Mlt => Box::new(CsBlockDriver::new(self.m_classes, self.dim)),
        };
        match warm {
            WarmStart::Cold => {}
            WarmStart::Weights(w) => drv.warm_start(w)?,
            WarmStart::Last => {
                if let Some(w) = self.last.clone() {
                    drv.warm_start(&w)?;
                }
            }
        }

        // MC running average over post-burn-in samples (§5.13)
        let mut avg: Option<Vec<f32>> = None;
        let mut avg_count = 0usize;

        // convergence diagnostics (DESIGN.md §14): observer-only — not
        // part of the checkpoint fingerprint or payload, so resumed
        // weights stay bit-identical whatever the cadence
        let mut diag = (cfg.diag_every > 0).then(|| {
            // drain step timing left over from a previous session on
            // this cluster so the first skew sample is this session's
            self.pool.take_step_timing();
            ChainDiag::new(
                cfg.algo == Algo::Mc,
                cfg.burn_in,
                drv.current().len(),
                cfg.seed,
            )
        });
        let mut last_verdict = HealthVerdict::Healthy;

        let n = self.n;
        let mut stop = StopRule::new(cfg, n);
        let mut start_iter = 0usize;
        if let Some(c) = ck.filter(|c| c.resume) {
            let loaded = Checkpoint::load(&c.path)?;
            loaded.check_compat(cfg)?;
            if loaded.dim != self.dim || loaded.m != self.m_classes {
                bail!(
                    "checkpoint shape {}x{} does not match this cluster ({}x{})",
                    loaded.m,
                    loaded.dim,
                    self.m_classes,
                    self.dim
                );
            }
            if cfg.algo == Algo::Mc && loaded.worker_rng.iter().any(|s| s.is_none()) {
                bail!(
                    "checkpoint lacks sampler RNG state for some workers; an MC run \
                     cannot resume bit-exactly without it"
                );
            }
            let w = if self.m_classes > 1 {
                Weights::PerClass(Mat {
                    rows: loaded.m,
                    cols: loaded.dim,
                    data: loaded.weights.clone(),
                })
            } else {
                Weights::Single(loaded.weights.clone())
            };
            drv.warm_start(&w)?;
            avg = loaded.avg.clone();
            avg_count = loaded.avg_count;
            stop.j_prev = loaded.stop_jprev;
            stop.smooth = loaded.stop_smooth.clone();
            rng = Pcg64::from_raw(loaded.master_rng.state, loaded.master_rng.inc);
            normals = NormalSource::with_spare(loaded.master_rng.spare);
            self.pool.set_rng_states(&loaded.worker_rng)?;
            start_iter = loaded.next_iter;
            crate::log_info!(
                "engine: resumed from {} at iteration {start_iter}",
                c.path.display()
            );
        }
        // reused across iterations: previous weights for the delta norm
        let mut w_prev: Vec<f32> = Vec::new();
        for iter in start_iter..cfg.max_iters {
            let iter_start = Instant::now();
            let phase_before = metrics.phase_totals();
            w_prev.clear();
            w_prev.extend_from_slice(drv.current());
            let mut cx = EngineCtx {
                pool: &mut self.pool,
                master: &mut *master,
                metrics: &mut metrics,
                cfg,
                gram: self.gram.as_ref(),
                rng: &mut rng,
                normals: &mut normals,
                dim: self.dim,
            };
            let st = drv.iterate(&mut cx)?;
            drop(cx);

            if cfg.algo == Algo::Mc && iter >= cfg.burn_in {
                let cur = drv.current();
                match &mut avg {
                    None => {
                        avg = Some(cur.to_vec());
                        avg_count = 1;
                    }
                    Some(a) => {
                        avg_count += 1;
                        let alpha = 1.0 / avg_count as f32;
                        for (ai, ci) in a.iter_mut().zip(cur) {
                            *ai += alpha * (ci - *ai);
                        }
                    }
                }
            }

            // held-out metric for the history (Figure 6)
            let k = self.k;
            let test_metric = metrics.time(Phase::Other, || {
                test.filter(|_| cfg.model == ModelKind::Linear).map(|te| {
                    let weights = drv.snapshot(k, avg.as_deref());
                    crate::model::evaluate(te, &weights)
                })
            });

            // per-iteration phase deltas: the difference between two
            // cumulative phase_totals snapshots bracketing this round
            let phase_after = metrics.phase_totals();
            let mut phase_secs = [0f64; NPHASES];
            for (i, s) in phase_secs.iter_mut().enumerate() {
                *s = phase_after[i].saturating_sub(phase_before[i]).as_secs_f64();
            }
            let weight_delta = {
                let cur = drv.current();
                let mut acc = 0f64;
                for (i, &c) in cur.iter().enumerate() {
                    let p = w_prev.get(i).copied().unwrap_or(0.0);
                    let d = (c - p) as f64;
                    acc += d * d;
                }
                acc.sqrt()
            };

            let em = engine_metrics();
            em.iterations.inc();
            em.iteration_nanos.observe_duration(iter_start.elapsed());
            for (i, c) in em.phase_nanos.iter().enumerate() {
                let delta = phase_after[i].saturating_sub(phase_before[i]);
                c.add(delta.as_nanos() as u64);
            }

            // diagnostics cadence: iterations at the --diag-every
            // stride feed the accumulator; step timing accrued since
            // the last observation folds into the straggler skew
            let mut diag_span = None;
            if let Some(d) = diag.as_mut() {
                if iter % cfg.diag_every == 0 {
                    let t = self.pool.take_step_timing();
                    d.observe(&IterObs {
                        iter,
                        objective: st.objective,
                        weights: drv.current(),
                        weight_delta,
                        step_max: t.max.as_secs_f64(),
                        step_mean: t.mean_secs(),
                    });
                    let s = d.summary();
                    if s.verdict != last_verdict {
                        crate::log_info!(
                            "diag: verdict {} -> {} at iteration {iter} (ess {:.1}, \
                             rhat {:.3})",
                            last_verdict.name(),
                            s.verdict.name(),
                            s.ess,
                            s.rhat
                        );
                        last_verdict = s.verdict;
                    }
                    diag_span = Some(s);
                }
            }

            let rec = IterRecord {
                iter,
                objective: st.objective,
                train_loss: st.loss_sum,
                train_err: st.err_sum / n as f64,
                test_metric,
                phase_secs,
                weight_delta,
            };
            if let Some(tw) = trace.as_deref_mut() {
                tw.record(&IterSpan {
                    iter: rec.iter,
                    objective: rec.objective,
                    train_loss: rec.train_loss,
                    train_err: rec.train_err,
                    weight_delta: rec.weight_delta,
                    test_metric: rec.test_metric,
                    phase_secs: rec.phase_secs,
                    diag: diag_span,
                })?;
            }
            history.push(rec);
            metrics.iterations = iter + 1;
            // evaluate the stopping rule *before* writing a checkpoint:
            // its mutated state (j_prev, smoothing tail) is part of the
            // resume payload, so a resumed run decides iteration
            // `next_iter` exactly as the uninterrupted run would
            let stopped = stop.converged(iter, st.objective);
            if let Some(c) = ck {
                if c.every > 0 && (iter + 1) % c.every == 0 {
                    let (state, inc) = rng.to_raw();
                    let (task, algo, topology, reduce) = Checkpoint::fingerprint(cfg);
                    let snap = Checkpoint {
                        task,
                        algo,
                        topology,
                        reduce,
                        seed: cfg.seed,
                        workers: self.pool.len(),
                        burn_in: cfg.burn_in,
                        lambda_bits: cfg.lambda.to_bits(),
                        eps_clamp_bits: cfg.eps_clamp.to_bits(),
                        eps_ins_bits: cfg.eps_insensitive.to_bits(),
                        next_iter: iter + 1,
                        dim: self.dim,
                        m: self.m_classes,
                        weights: drv.current().to_vec(),
                        avg: avg.clone(),
                        avg_count,
                        stop_jprev: stop.j_prev,
                        stop_smooth: stop.smooth.clone(),
                        master_rng: RngState { state, inc, spare: normals.spare() },
                        worker_rng: self.pool.rng_states()?,
                    };
                    snap.save(&c.path)?;
                    crate::log_debug!(
                        "engine: checkpoint written to {} after iteration {}",
                        c.path.display(),
                        iter + 1
                    );
                }
            }
            if stopped {
                break;
            }
        }
        engine_metrics().sessions.inc();

        let verdict = diag.as_mut().map(|d| {
            let s = d.snapshot();
            crate::log_info!(
                "diag: session verdict {} ({} samples, ess {:.1}, rhat {:.3}, \
                 mcse {:.3e}, skew {:.2})",
                s.verdict.name(),
                s.samples,
                s.objective.ess,
                s.objective.rhat.max(s.wnorm.rhat).max(s.wproj.rhat),
                s.objective.mcse,
                s.skew
            );
            s.verdict
        });
        let weights = drv.snapshot(self.k, avg.as_deref());
        let objective = history.last().map(|h| h.objective).unwrap_or(f64::INFINITY);
        let iterations = history.len();
        crate::log_debug!(
            "engine: session {} finished after {iterations} iterations (J = {objective:.4})",
            self.sessions
        );
        metrics.sessions = 1;
        self.sessions += 1;
        self.last = Some(weights.clone());
        Ok(TrainOutput {
            weights,
            objective,
            iterations,
            metrics,
            history,
            kernel_model: None,
            verdict,
        })
    }
}
