//! Per-task iteration drivers: each task owns its step input, objective
//! assembly and weight state, while [`super::Cluster::run_session`] owns
//! the shared session scaffolding (stopping rule, MC averaging,
//! history). This replaces the pre-engine `train_inner`, which
//! interleaved all three tasks in one 200-line loop.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::backend::StepInput;
use crate::linalg::Mat;
use crate::metrics::Phase;
use crate::model::Weights;

use super::EngineCtx;

/// What one driver iteration reports back to the session loop.
pub struct IterStats {
    /// training loss sum (hinge / eps-insensitive / CS) at the
    /// pre-update weights
    pub loss_sum: f64,
    /// task-dependent second statistic summed over data: error count
    /// (CLS/MLT) or squared residuals (SVR)
    pub err_sum: f64,
    /// primal objective J at the pre-update weights
    pub objective: f64,
}

/// One task's `worker step -> reduce -> master solve` round.
pub trait IterDriver {
    /// Run one full iteration, updating the internal weights.
    fn iterate(&mut self, cx: &mut EngineCtx<'_>) -> Result<IterStats>;

    /// Flat view of the current weights (for the MC running average).
    fn current(&self) -> &[f32];

    /// Seed the weights from a previous session's solution.
    fn warm_start(&mut self, w: &Weights) -> Result<()>;

    /// Model snapshot truncated to the dataset's true feature width
    /// `k` (the XLA backend pads); `avg` substitutes the MC
    /// post-burn-in average when present.
    fn snapshot(&self, k: usize, avg: Option<&[f32]>) -> Weights;
}

/// Shared state/logic of the single-weight-vector tasks (CLS, SVR):
/// the two drivers differ only in the `StepInput` they broadcast.
struct SingleWeight {
    w: Arc<Vec<f32>>,
}

impl SingleWeight {
    fn new(dim: usize) -> Self {
        SingleWeight { w: Arc::new(vec![0.0; dim]) }
    }

    fn iterate_with(
        &mut self,
        cx: &mut EngineCtx<'_>,
        input: StepInput,
    ) -> Result<IterStats> {
        let mut stats = cx.collect(input)?;
        let loss_sum = stats.obj;
        let err_sum = stats.aux;
        let t0 = Instant::now();
        let objective = cx.reg_quad(&self.w) + 2.0 * loss_sum;
        cx.metrics.add(Phase::Other, t0.elapsed());
        self.w = Arc::new(cx.solve(&mut stats)?);
        Ok(IterStats { loss_sum, err_sum, objective })
    }

    fn warm_start(&mut self, w: &Weights) -> Result<()> {
        let Weights::Single(src) = w else {
            bail!("warm start: CLS/SVR session expects a single weight vector");
        };
        self.w = Arc::new(pad_to(src, self.w.len()));
        Ok(())
    }

    fn snapshot(&self, k: usize, avg: Option<&[f32]>) -> Weights {
        let src: &[f32] = avg.unwrap_or(&self.w);
        Weights::Single(src[..k.min(src.len())].to_vec())
    }
}

/// Binary hinge classification (Eqs. 5/9 + 40); also drives KRN, where
/// `w` is the dual vector omega over Gram-row features.
pub struct BinaryDriver(SingleWeight);

impl BinaryDriver {
    pub fn new(dim: usize) -> Self {
        BinaryDriver(SingleWeight::new(dim))
    }
}

impl IterDriver for BinaryDriver {
    fn iterate(&mut self, cx: &mut EngineCtx<'_>) -> Result<IterStats> {
        let input = StepInput::Binary { w: self.0.w.clone() };
        self.0.iterate_with(cx, input)
    }

    fn current(&self) -> &[f32] {
        &self.0.w
    }

    fn warm_start(&mut self, w: &Weights) -> Result<()> {
        self.0.warm_start(w)
    }

    fn snapshot(&self, k: usize, avg: Option<&[f32]>) -> Weights {
        self.0.snapshot(k, avg)
    }
}

/// Epsilon-insensitive regression (Lemma 3 + Eqs. 25-28).
pub struct SvrDriver(SingleWeight);

impl SvrDriver {
    pub fn new(dim: usize) -> Self {
        SvrDriver(SingleWeight::new(dim))
    }
}

impl IterDriver for SvrDriver {
    fn iterate(&mut self, cx: &mut EngineCtx<'_>) -> Result<IterStats> {
        let input =
            StepInput::Svr { w: self.0.w.clone(), eps_ins: cx.cfg.eps_insensitive };
        self.0.iterate_with(cx, input)
    }

    fn current(&self) -> &[f32] {
        &self.0.w
    }

    fn warm_start(&mut self, w: &Weights) -> Result<()> {
        self.0.warm_start(w)
    }

    fn snapshot(&self, k: usize, avg: Option<&[f32]>) -> Weights {
        self.0.snapshot(k, avg)
    }
}

/// Crammer-Singer multiclass: one Gauss-Seidel sweep over the M class
/// blocks per iteration (§3.3) — each class sees the already-updated
/// weights of earlier classes.
pub struct CsBlockDriver {
    w_all: Arc<Mat>,
    m: usize,
}

impl CsBlockDriver {
    pub fn new(m: usize, dim: usize) -> Self {
        let m = m.max(1);
        CsBlockDriver { w_all: Arc::new(Mat::zeros(m, dim)), m }
    }
}

impl IterDriver for CsBlockDriver {
    fn iterate(&mut self, cx: &mut EngineCtx<'_>) -> Result<IterStats> {
        let mut loss_sum = 0f64;
        let mut err_sum = 0f64;
        for y in 0..self.m {
            let input = StepInput::Mlt { w_all: self.w_all.clone(), yidx: y };
            let mut stats = cx.collect(input)?;
            // the CS loss / error count cover all classes at once, so
            // they are only meaningful from the first class's pass
            if y == 0 {
                loss_sum = stats.obj;
                err_sum = stats.aux;
            }
            let wy = cx.solve(&mut stats)?;
            // every worker has dropped its share of the broadcast Arc by
            // now, so this updates the block in place instead of cloning
            // the whole [m, dim] matrix per class
            Arc::make_mut(&mut self.w_all).row_mut(y).copy_from_slice(&wy);
        }
        let t0 = Instant::now();
        let objective = 0.5 * cx.cfg.lambda as f64
            * crate::linalg::norm2_sq(&self.w_all.data) as f64
            + 2.0 * loss_sum;
        cx.metrics.add(Phase::Other, t0.elapsed());
        Ok(IterStats { loss_sum, err_sum, objective })
    }

    fn current(&self) -> &[f32] {
        &self.w_all.data
    }

    fn warm_start(&mut self, w: &Weights) -> Result<()> {
        let Weights::PerClass(src) = w else {
            bail!("warm start: MLT session expects per-class weights");
        };
        if src.rows != self.m {
            bail!("warm start: {} classes, cluster has {}", src.rows, self.m);
        }
        let dst = Arc::make_mut(&mut self.w_all);
        let n = src.cols.min(dst.cols);
        for c in 0..src.rows {
            dst.row_mut(c)[..n].copy_from_slice(&src.row(c)[..n]);
        }
        Ok(())
    }

    fn snapshot(&self, k: usize, avg: Option<&[f32]>) -> Weights {
        let dim = self.w_all.cols;
        let src: &[f32] = avg.unwrap_or(&self.w_all.data);
        let kk = k.min(dim);
        let mut out = Mat::zeros(self.m, kk);
        for c in 0..self.m {
            out.row_mut(c).copy_from_slice(&src[c * dim..c * dim + kk]);
        }
        Weights::PerClass(out)
    }
}

/// Copy `src` into a zero vector of width `dim` (truncating or
/// zero-extending: sessions may warm-start across backends whose
/// padded stat widths differ).
fn pad_to(src: &[f32], dim: usize) -> Vec<f32> {
    let mut v = vec![0f32; dim];
    let n = src.len().min(dim);
    v[..n].copy_from_slice(&src[..n]);
    v
}
