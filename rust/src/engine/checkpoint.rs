//! Iteration checkpointing (DESIGN.md §13): everything a training
//! session needs to continue **bit-identically** after a kill, in a
//! versioned plain-text format next of kin to `pemsvm-model v1`.
//!
//! Bit-exact resume is stricter than "load the weights": the session's
//! state also includes the MC running average, the stopping rule's
//! smoothed-objective tail, and three RNG streams (the master's
//! posterior-noise stream plus one sampler stream per worker). All of
//! them are captured, and every float is serialized as its IEEE-754 bit
//! pattern in hex — a round-trip through decimal formatting would
//! perturb the trajectory.
//!
//! Layout (`pemsvm-ckpt v1`): a header of `key value` lines carrying the
//! config fingerprint (task/algo/seed/worker count/λ/ε — resume refuses
//! a checkpoint written under a different fingerprint), then the state
//! vectors as `name <len> <hex>...` lines, then the RNG block, then an
//! `end` sentinel so a truncated file is detected rather than resumed.

use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::backend::RngState;
use crate::config::TrainConfig;

/// Checkpointing knobs for a session: write every `every` iterations to
/// `path`; `resume` starts the session from the file instead of fresh.
#[derive(Clone, Debug)]
pub struct CheckpointCfg {
    pub every: usize,
    pub path: PathBuf,
    pub resume: bool,
}

/// One captured session state — the full resume payload.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    // config fingerprint: resume refuses a mismatch
    pub task: String,
    pub algo: String,
    pub topology: String,
    pub reduce: String,
    pub seed: u64,
    pub workers: usize,
    pub burn_in: usize,
    pub lambda_bits: u32,
    pub eps_clamp_bits: u32,
    pub eps_ins_bits: u32,
    // session state
    /// the iteration the resumed loop starts at
    pub next_iter: usize,
    /// statistics width (`k`, or the XLA-padded width)
    pub dim: usize,
    /// class count (1 for CLS/SVR)
    pub m: usize,
    /// driver weights, flat `[m * dim]`
    pub weights: Vec<f32>,
    /// MC running average over post-burn-in samples, if any yet
    pub avg: Option<Vec<f32>>,
    pub avg_count: usize,
    /// stopping rule: previous (smoothed) objective
    pub stop_jprev: f64,
    /// stopping rule: the MC smoothing window tail (empty for EM)
    pub stop_smooth: Vec<f64>,
    /// the master's posterior-noise RNG stream
    pub master_rng: RngState,
    /// per-worker sampler streams; `None` for evicted workers or
    /// backends without a restorable RNG
    pub worker_rng: Vec<Option<RngState>>,
}

impl Checkpoint {
    /// The config fingerprint of this checkpoint, from the session
    /// config it was written under.
    pub fn fingerprint(cfg: &TrainConfig) -> (String, String, String, String) {
        (
            format!("{:?}", cfg.task),
            format!("{:?}", cfg.algo),
            // host-independent tag: a Remote checkpoint may resume onto
            // a Remote cluster with a different (e.g. replacement) host
            // list — worker identity is the id, not the address
            cfg.topology.name().to_string(),
            format!("{:?}", cfg.reduce),
        )
    }

    /// Refuse to resume under a config that would diverge from the
    /// trajectory this checkpoint was written on.
    pub fn check_compat(&self, cfg: &TrainConfig) -> Result<()> {
        let (task, algo, topology, reduce) = Self::fingerprint(cfg);
        if self.task != task {
            bail!("checkpoint task {} != session task {task}", self.task);
        }
        if self.algo != algo {
            bail!("checkpoint algo {} != session algo {algo}", self.algo);
        }
        if self.topology != topology {
            bail!("checkpoint topology {} != session topology {topology}", self.topology);
        }
        if self.reduce != reduce {
            bail!(
                "checkpoint reduce {} != session reduce {reduce} (association order \
                 changes the f32 sums)",
                self.reduce
            );
        }
        if self.seed != cfg.seed {
            bail!("checkpoint seed {} != session seed {}", self.seed, cfg.seed);
        }
        if self.workers != cfg.workers.max(1) {
            bail!(
                "checkpoint was written with {} workers, session has {}",
                self.workers,
                cfg.workers.max(1)
            );
        }
        if self.burn_in != cfg.burn_in {
            bail!("checkpoint burn_in {} != session burn_in {}", self.burn_in, cfg.burn_in);
        }
        if self.lambda_bits != cfg.lambda.to_bits() {
            bail!("checkpoint lambda differs from the session's (bit-exact compare)");
        }
        if self.eps_clamp_bits != cfg.eps_clamp.to_bits() {
            bail!("checkpoint eps_clamp differs from the session's (bit-exact compare)");
        }
        if self.eps_ins_bits != cfg.eps_insensitive.to_bits() {
            bail!("checkpoint eps_insensitive differs from the session's (bit-exact compare)");
        }
        Ok(())
    }

    /// Serialize to the `pemsvm-ckpt v1` text format.
    pub fn to_text(&self) -> String {
        let mut s = String::new();
        s.push_str("pemsvm-ckpt v1\n");
        let _ = writeln!(s, "task {}", self.task);
        let _ = writeln!(s, "algo {}", self.algo);
        let _ = writeln!(s, "topology {}", self.topology);
        let _ = writeln!(s, "reduce {}", self.reduce);
        let _ = writeln!(s, "seed {}", self.seed);
        let _ = writeln!(s, "workers {}", self.workers);
        let _ = writeln!(s, "burn_in {}", self.burn_in);
        let _ = writeln!(s, "lambda {:08x}", self.lambda_bits);
        let _ = writeln!(s, "eps_clamp {:08x}", self.eps_clamp_bits);
        let _ = writeln!(s, "eps_insensitive {:08x}", self.eps_ins_bits);
        let _ = writeln!(s, "next_iter {}", self.next_iter);
        let _ = writeln!(s, "dim {}", self.dim);
        let _ = writeln!(s, "classes {}", self.m);
        write_f32s(&mut s, "weights", &self.weights);
        match &self.avg {
            Some(a) => write_f32s(&mut s, "avg", a),
            None => s.push_str("avg none\n"),
        }
        let _ = writeln!(s, "avg_count {}", self.avg_count);
        let _ = writeln!(s, "stop_jprev {:016x}", self.stop_jprev.to_bits());
        let _ = write!(s, "stop_smooth {}", self.stop_smooth.len());
        for v in &self.stop_smooth {
            let _ = write!(s, " {:016x}", v.to_bits());
        }
        s.push('\n');
        let _ = writeln!(s, "master_rng {}", rng_text(&self.master_rng));
        let _ = writeln!(s, "worker_rng {}", self.worker_rng.len());
        for (wid, st) in self.worker_rng.iter().enumerate() {
            match st {
                Some(st) => {
                    let _ = writeln!(s, "worker {wid} {}", rng_text(st));
                }
                None => {
                    let _ = writeln!(s, "worker {wid} none");
                }
            }
        }
        s.push_str("end pemsvm-ckpt\n");
        s
    }

    /// Parse the `pemsvm-ckpt v1` text format.
    pub fn from_text(text: &str) -> Result<Checkpoint> {
        let mut c = Cursor { it: text.lines(), lineno: 0 };
        if c.next()? != "pemsvm-ckpt v1" {
            bail!("not a pemsvm-ckpt v1 file");
        }
        let task = c.kv("task")?.to_string();
        let algo = c.kv("algo")?.to_string();
        let topology = c.kv("topology")?.to_string();
        let reduce = c.kv("reduce")?.to_string();
        let seed = c.kv("seed")?.parse().context("seed")?;
        let workers = c.kv("workers")?.parse().context("workers")?;
        let burn_in = c.kv("burn_in")?.parse().context("burn_in")?;
        let lambda_bits = u32::from_str_radix(c.kv("lambda")?, 16).context("lambda")?;
        let eps_clamp_bits = u32::from_str_radix(c.kv("eps_clamp")?, 16).context("eps_clamp")?;
        let eps_ins_bits =
            u32::from_str_radix(c.kv("eps_insensitive")?, 16).context("eps_insensitive")?;
        let next_iter = c.kv("next_iter")?.parse().context("next_iter")?;
        let dim: usize = c.kv("dim")?.parse().context("dim")?;
        let m: usize = c.kv("classes")?.parse().context("classes")?;
        let weights = read_f32s(c.kv("weights")?).context("weights")?;
        if weights.len() != m * dim {
            bail!("checkpoint weights length {} != classes*dim {}", weights.len(), m * dim);
        }
        let avg_line = c.kv("avg")?;
        let avg = if avg_line == "none" { None } else { Some(read_f32s(avg_line).context("avg")?) };
        let avg_count = c.kv("avg_count")?.parse().context("avg_count")?;
        let stop_jprev =
            f64::from_bits(u64::from_str_radix(c.kv("stop_jprev")?, 16).context("stop_jprev")?);
        let stop_smooth = read_f64s(c.kv("stop_smooth")?).context("stop_smooth")?;
        let master_rng = rng_parse(c.kv("master_rng")?).context("master_rng")?;
        let nw: usize = c.kv("worker_rng")?.parse().context("worker_rng")?;
        if nw > 1 << 20 {
            bail!("unreasonable worker count {nw} in checkpoint");
        }
        let mut worker_rng = Vec::with_capacity(nw);
        for wid in 0..nw {
            let rest = c.kv("worker")?;
            let (id, st) = rest.split_once(' ').ok_or_else(|| anyhow!("bad worker line"))?;
            if id.parse::<usize>().ok() != Some(wid) {
                bail!("worker RNG lines out of order (expected {wid}, got {id})");
            }
            worker_rng.push(if st == "none" { None } else { Some(rng_parse(st)?) });
        }
        if c.next()? != "end pemsvm-ckpt" {
            bail!("checkpoint truncated: missing end sentinel");
        }
        Ok(Checkpoint {
            task,
            algo,
            topology,
            reduce,
            seed,
            workers,
            burn_in,
            lambda_bits,
            eps_clamp_bits,
            eps_ins_bits,
            next_iter,
            dim,
            m,
            weights,
            avg,
            avg_count,
            stop_jprev,
            stop_smooth,
            master_rng,
            worker_rng,
        })
    }

    /// Write atomically: serialize to `<path>.tmp`, then rename over
    /// `path` — a kill mid-write leaves the previous checkpoint intact.
    pub fn save(&self, path: &Path) -> Result<()> {
        let tmp = path.with_extension("ckpt.tmp");
        std::fs::write(&tmp, self.to_text())
            .with_context(|| format!("writing checkpoint {}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("publishing checkpoint {}", path.display()))?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        Self::from_text(&text).with_context(|| format!("parsing checkpoint {}", path.display()))
    }
}

struct Cursor<'a> {
    it: std::str::Lines<'a>,
    lineno: usize,
}

impl<'a> Cursor<'a> {
    fn next(&mut self) -> Result<&'a str> {
        self.lineno += 1;
        self.it.next().ok_or_else(|| anyhow!("checkpoint truncated at line {}", self.lineno))
    }

    /// Read one `key rest...` line, checking the key.
    fn kv(&mut self, key: &str) -> Result<&'a str> {
        let line = self.next()?;
        let (k, v) = line.split_once(' ').unwrap_or((line, ""));
        if k != key {
            bail!("checkpoint line {}: expected `{key}`, found `{k}`", self.lineno);
        }
        Ok(v)
    }
}

fn write_f32s(s: &mut String, name: &str, vals: &[f32]) {
    let _ = write!(s, "{name} {}", vals.len());
    for v in vals {
        let _ = write!(s, " {:08x}", v.to_bits());
    }
    s.push('\n');
}

fn read_f32s(line: &str) -> Result<Vec<f32>> {
    let mut parts = line.split_ascii_whitespace();
    let len: usize = parts.next().ok_or_else(|| anyhow!("missing length"))?.parse()?;
    let mut out = Vec::with_capacity(len.min(1 << 24));
    for p in parts {
        out.push(f32::from_bits(u32::from_str_radix(p, 16)?));
    }
    if out.len() != len {
        bail!("vector length mismatch: header says {len}, found {}", out.len());
    }
    Ok(out)
}

fn read_f64s(line: &str) -> Result<Vec<f64>> {
    let mut parts = line.split_ascii_whitespace();
    let len: usize = parts.next().ok_or_else(|| anyhow!("missing length"))?.parse()?;
    let mut out = Vec::with_capacity(len.min(1 << 24));
    for p in parts {
        out.push(f64::from_bits(u64::from_str_radix(p, 16)?));
    }
    if out.len() != len {
        bail!("vector length mismatch: header says {len}, found {}", out.len());
    }
    Ok(out)
}

fn rng_text(s: &RngState) -> String {
    let spare = match s.spare {
        Some(v) => format!("{:016x}", v.to_bits()),
        None => "none".to_string(),
    };
    format!("{:032x} {:032x} {spare}", s.state, s.inc)
}

fn rng_parse(s: &str) -> Result<RngState> {
    let mut p = s.split_ascii_whitespace();
    let state = u128::from_str_radix(p.next().ok_or_else(|| anyhow!("missing rng state"))?, 16)?;
    let inc = u128::from_str_radix(p.next().ok_or_else(|| anyhow!("missing rng inc"))?, 16)?;
    let spare = match p.next().ok_or_else(|| anyhow!("missing rng spare"))? {
        "none" => None,
        hex => Some(f64::from_bits(u64::from_str_radix(hex, 16)?)),
    };
    Ok(RngState { state, inc, spare })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            task: "Cls".into(),
            algo: "Mc".into(),
            topology: "Threads".into(),
            reduce: "Tree".into(),
            seed: 42,
            workers: 3,
            burn_in: 2,
            lambda_bits: 1.5f32.to_bits(),
            eps_clamp_bits: 1e-5f32.to_bits(),
            eps_ins_bits: 0.1f32.to_bits(),
            next_iter: 7,
            dim: 4,
            m: 1,
            weights: vec![0.25, -1.5, f32::MIN_POSITIVE, 3.75],
            avg: Some(vec![0.5, 0.5, -0.125, 0.0]),
            avg_count: 5,
            stop_jprev: 123.456789,
            stop_smooth: vec![130.0, 128.5, 123.456789],
            master_rng: RngState { state: u128::MAX - 17, inc: 12345, spare: Some(-0.7071) },
            worker_rng: vec![
                Some(RngState { state: 1, inc: 3, spare: None }),
                None,
                Some(RngState { state: 9, inc: 11, spare: Some(2.25) }),
            ],
        }
    }

    #[test]
    fn text_roundtrip_is_bit_exact() {
        let ck = sample();
        let parsed = Checkpoint::from_text(&ck.to_text()).unwrap();
        assert_eq!(parsed, ck);
        // the floats survive via bit patterns, not decimal formatting
        assert_eq!(parsed.stop_jprev.to_bits(), ck.stop_jprev.to_bits());
    }

    #[test]
    fn truncated_and_corrupt_files_are_rejected() {
        let text = sample().to_text();
        // drop the end sentinel
        let cut = text.rsplit_once("end pemsvm-ckpt").unwrap().0;
        assert!(Checkpoint::from_text(cut).is_err());
        // wrong magic
        assert!(Checkpoint::from_text("pemsvm-model v1\n").is_err());
        // weights length lies
        let lied = text.replace("weights 4 ", "weights 5 ");
        assert!(Checkpoint::from_text(&lied).is_err());
    }

    #[test]
    fn compat_check_catches_fingerprint_drift() {
        let ck = sample();
        let mut cfg = TrainConfig {
            task: crate::config::TaskKind::Cls,
            algo: crate::config::Algo::Mc,
            topology: crate::config::Topology::Threads,
            reduce: crate::config::ReduceKind::Tree,
            seed: 42,
            workers: 3,
            burn_in: 2,
            lambda: 1.5,
            eps_clamp: 1e-5,
            eps_insensitive: 0.1,
            ..TrainConfig::default()
        };
        ck.check_compat(&cfg).unwrap();
        cfg.seed = 43;
        assert!(ck.check_compat(&cfg).is_err());
        cfg.seed = 42;
        cfg.lambda = 1.5000001;
        assert!(ck.check_compat(&cfg).is_err());
    }

    #[test]
    fn save_load_via_disk() {
        let dir = std::env::temp_dir()
            .join(format!("pemsvm-ckpt-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.ckpt");
        let ck = sample();
        ck.save(&path).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap(), ck);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
