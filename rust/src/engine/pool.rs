//! The persistent worker pool: the threads, channels and pinned shards
//! behind [`super::Cluster`].
//!
//! In the [`Topology::Threads`] mode each worker backend lives on its
//! own OS thread for the lifetime of the pool, serving both `Step`
//! commands (the per-iteration shard pass) and `Merge` commands (the
//! in-pool tree reduce — pair merges of partial statistics execute on
//! the worker threads themselves, instead of the leader spawning fresh
//! OS threads per reduce round as the pre-engine `reduce.rs` did).
//!
//! In the [`Topology::Simulate`] mode the same backends run serially on
//! the leader thread and the metrics record `max(worker durations)` per
//! iteration — the homogeneous-cluster cost model of the paper's §4.1.
//! The two modes are numerically identical for a fixed seed: steps see
//! the same shard/weights, and the tree reduce uses the same pairing
//! order (so the f32 sums associate identically).

use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, OnceLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::backend::{StepInput, WorkerBackend};
use crate::config::{ReduceKind, Topology};
use crate::coordinator::reduce;
use crate::data::stream::ParsedChunk;
use crate::metrics::{Metrics, Phase};
use crate::solver::PartialStats;
use crate::telemetry::{self, Histogram};

/// Pool-level latency distributions in the global telemetry registry:
/// the slowest worker's step per round, and the whole reduce.
struct PoolMetrics {
    step_nanos: Arc<Histogram>,
    reduce_nanos: Arc<Histogram>,
}

fn pool_metrics() -> &'static PoolMetrics {
    static M: OnceLock<PoolMetrics> = OnceLock::new();
    M.get_or_init(|| PoolMetrics {
        step_nanos: telemetry::global().histogram(
            "worker_step_nanos",
            "Slowest worker step per broadcast round in nanoseconds.",
        ),
        reduce_nanos: telemetry::global()
            .histogram("reduce_nanos", "Full reduce round wall-clock in nanoseconds."),
    })
}

enum Cmd {
    /// One shard pass at the broadcast weights. The `Arc` is the whole
    /// broadcast: P workers share one `StepInput` instead of receiving
    /// P deep copies (the `rebind_weights` optimization — for MLT this
    /// saves P clones of the full `[m, k]` weight block per class).
    Step(Arc<StepInput>),
    /// Merge `src` into the partial at tree slot `.0` and hand it back.
    Merge(usize, Box<PartialStats>, Box<PartialStats>),
    /// Streaming ingestion (DESIGN.md §10): every worker appends its
    /// slice of the shared parsed chunk to its shard buffer. Like
    /// `Step`, the `Arc` is the broadcast — the chunk's memory is
    /// released once the last worker drops its share.
    Ingest(Arc<ParsedChunk>),
    /// End of the chunk stream: each worker validates + seals its shard.
    Seal,
    Stop,
}

enum Reply {
    Stepped { wid: usize, stats: Result<PartialStats>, step_time: Duration },
    Merged { slot: usize, stats: Box<PartialStats> },
    Ingested { wid: usize, res: Result<()> },
}

enum Mode {
    Threads {
        cmd_txs: Vec<Sender<Cmd>>,
        res_rx: Receiver<Reply>,
        handles: Vec<JoinHandle<()>>,
    },
    Simulate {
        workers: Vec<Box<dyn WorkerBackend>>,
    },
}

/// A set of worker backends bound to their shards, alive across many
/// training sessions.
pub struct Pool {
    mode: Mode,
}

impl Pool {
    /// Take ownership of the (already shard-bound) worker backends and,
    /// in the threaded topology, spawn their threads.
    pub fn spawn(workers: Vec<Box<dyn WorkerBackend>>, topology: Topology) -> Pool {
        match topology {
            Topology::Simulate => Pool { mode: Mode::Simulate { workers } },
            Topology::Threads => {
                let (res_tx, res_rx) = mpsc::channel::<Reply>();
                let mut cmd_txs = Vec::with_capacity(workers.len());
                let mut handles = Vec::with_capacity(workers.len());
                for (wid, mut wk) in workers.into_iter().enumerate() {
                    let (tx, rx) = mpsc::channel::<Cmd>();
                    cmd_txs.push(tx);
                    let res_tx = res_tx.clone();
                    handles.push(std::thread::spawn(move || {
                        while let Ok(cmd) = rx.recv() {
                            match cmd {
                                Cmd::Stop => break,
                                Cmd::Step(input) => {
                                    let t0 = Instant::now();
                                    let stats = wk.step(&input);
                                    let step_time = t0.elapsed();
                                    // drop our share of the broadcast
                                    // *before* replying, so once the
                                    // leader holds all P replies its Arc
                                    // is unique again (MLT mutates the
                                    // weight block in place via make_mut)
                                    drop(input);
                                    if res_tx
                                        .send(Reply::Stepped { wid, stats, step_time })
                                        .is_err()
                                    {
                                        break;
                                    }
                                }
                                Cmd::Merge(slot, mut dst, src) => {
                                    dst.merge(&src);
                                    if res_tx.send(Reply::Merged { slot, stats: dst }).is_err() {
                                        break;
                                    }
                                }
                                Cmd::Ingest(chunk) => {
                                    let res = wk.ingest(&chunk);
                                    // release our share before replying so
                                    // the chunk frees as soon as the last
                                    // worker is done with it
                                    drop(chunk);
                                    if res_tx.send(Reply::Ingested { wid, res }).is_err() {
                                        break;
                                    }
                                }
                                Cmd::Seal => {
                                    let res = wk.seal();
                                    if res_tx.send(Reply::Ingested { wid, res }).is_err() {
                                        break;
                                    }
                                }
                            }
                        }
                    }));
                }
                Pool { mode: Mode::Threads { cmd_txs, res_rx, handles } }
            }
        }
    }

    /// Number of workers.
    pub fn len(&self) -> usize {
        match &self.mode {
            Mode::Threads { cmd_txs, .. } => cmd_txs.len(),
            Mode::Simulate { workers } => workers.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// One broadcast + collect round: every worker steps on `input`;
    /// partials come back ordered by worker id. Timing goes to the
    /// `Broadcast` / `LocalStats` phases (max over workers, per §4.1).
    pub fn step_all(
        &mut self,
        input: StepInput,
        metrics: &mut Metrics,
    ) -> Result<Vec<PartialStats>> {
        match &mut self.mode {
            Mode::Simulate { workers } => {
                let mut max_step = Duration::ZERO;
                let mut out = Vec::with_capacity(workers.len());
                for wk in workers.iter_mut() {
                    let t0 = Instant::now();
                    out.push(wk.step(&input)?);
                    max_step = max_step.max(t0.elapsed());
                }
                metrics.add(Phase::LocalStats, max_step);
                pool_metrics().step_nanos.observe_duration(max_step);
                Ok(out)
            }
            Mode::Threads { cmd_txs, res_rx, .. } => {
                let p = cmd_txs.len();
                let input = Arc::new(input);
                let t0 = Instant::now();
                for tx in cmd_txs.iter() {
                    tx.send(Cmd::Step(input.clone()))
                        .map_err(|_| anyhow!("worker hung up"))?;
                }
                drop(input);
                metrics.add(Phase::Broadcast, t0.elapsed());
                let mut slots: Vec<Option<PartialStats>> = (0..p).map(|_| None).collect();
                let mut max_step = Duration::ZERO;
                // Consume all P replies even if one step failed: a reply
                // left queued in the shared channel would be read by the
                // *next* session on this persistent pool as if current.
                let mut first_err: Option<anyhow::Error> = None;
                for _ in 0..p {
                    match res_rx.recv().context("worker died")? {
                        Reply::Stepped { wid, stats, step_time } => match stats {
                            Ok(s) => {
                                slots[wid] = Some(s);
                                max_step = max_step.max(step_time);
                            }
                            Err(e) => {
                                if first_err.is_none() {
                                    first_err = Some(e);
                                }
                            }
                        },
                        _ => return Err(anyhow!("protocol error: unexpected reply during step")),
                    }
                }
                if let Some(e) = first_err {
                    return Err(e);
                }
                metrics.add(Phase::LocalStats, max_step);
                pool_metrics().step_nanos.observe_duration(max_step);
                Ok(slots.into_iter().map(Option::unwrap).collect())
            }
        }
    }

    /// Broadcast one parsed chunk to every worker: each appends its
    /// slice to its shard buffer (DESIGN.md §10). In the threaded
    /// topology the append runs on the worker threads, overlapping with
    /// the stream reader's parse of the next chunk; waiting for all P
    /// replies before the next chunk keeps per-worker ingestion in file
    /// order. All replies are consumed even on error (a queued reply
    /// would otherwise leak into the next command round).
    pub fn ingest_all(&mut self, chunk: ParsedChunk) -> Result<()> {
        match &mut self.mode {
            Mode::Simulate { workers } => {
                for wk in workers.iter_mut() {
                    wk.ingest(&chunk)?;
                }
                Ok(())
            }
            Mode::Threads { cmd_txs, res_rx, .. } => {
                let chunk = Arc::new(chunk);
                for tx in cmd_txs.iter() {
                    tx.send(Cmd::Ingest(chunk.clone()))
                        .map_err(|_| anyhow!("worker hung up during ingest"))?;
                }
                drop(chunk);
                collect_ingest_replies(cmd_txs.len(), res_rx, "ingest")
            }
        }
    }

    /// End of stream: every worker validates and seals its shard, making
    /// the pool steppable.
    pub fn seal_all(&mut self) -> Result<()> {
        match &mut self.mode {
            Mode::Simulate { workers } => {
                for wk in workers.iter_mut() {
                    wk.seal()?;
                }
                Ok(())
            }
            Mode::Threads { cmd_txs, res_rx, .. } => {
                for tx in cmd_txs.iter() {
                    tx.send(Cmd::Seal).map_err(|_| anyhow!("worker hung up during seal"))?;
                }
                collect_ingest_replies(cmd_txs.len(), res_rx, "seal")
            }
        }
    }

    /// Reduce the P partials to one. `Flat` folds at the leader; `Tree`
    /// merges pairs — dispatched to the pool's worker threads in the
    /// threaded topology, serially (identical pairing order, hence
    /// bit-identical sums) in the simulated one.
    pub fn reduce(
        &mut self,
        kind: ReduceKind,
        partials: Vec<PartialStats>,
        metrics: &mut Metrics,
    ) -> Result<PartialStats> {
        metrics.reduces += 1;
        let t0 = Instant::now();
        let out = match (&mut self.mode, kind) {
            (Mode::Threads { cmd_txs, res_rx, .. }, ReduceKind::Tree) if partials.len() > 1 => {
                in_pool_tree(cmd_txs, res_rx, partials)?
            }
            (_, kind) => reduce::reduce(kind, partials),
        };
        let elapsed = t0.elapsed();
        metrics.add(Phase::Reduce, elapsed);
        pool_metrics().reduce_nanos.observe_duration(elapsed);
        Ok(out)
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        if let Mode::Threads { cmd_txs, handles, .. } = &mut self.mode {
            for tx in cmd_txs.iter() {
                let _ = tx.send(Cmd::Stop);
            }
            for h in handles.drain(..) {
                let _ = h.join();
            }
        }
    }
}

/// Collect the P `Ingested` replies of one ingest/seal round,
/// propagating the first worker error after draining all replies.
fn collect_ingest_replies(p: usize, res_rx: &Receiver<Reply>, what: &str) -> Result<()> {
    let mut first_err: Option<anyhow::Error> = None;
    for _ in 0..p {
        match res_rx.recv().with_context(|| format!("worker died during {what}"))? {
            Reply::Ingested { wid, res } => {
                if let Err(e) = res {
                    if first_err.is_none() {
                        first_err = Some(e.context(format!("worker {wid} {what}")));
                    }
                }
            }
            _ => return Err(anyhow!("protocol error: unexpected reply during {what}")),
        }
    }
    match first_err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// Binary-tree reduce whose pair merges run on the pool's worker
/// threads: each round's merges are dispatched round-robin and collected
/// before the stride doubles (the merges of one round overlap, matching
/// the simultaneous pairwise exchanges of the paper's Table 1).
///
/// Pairing is identical to [`reduce::reduce`]'s serial tree — slot `i`
/// absorbs slot `i + stride` — so both produce the same f32 sums.
fn in_pool_tree(
    cmd_txs: &[Sender<Cmd>],
    res_rx: &Receiver<Reply>,
    partials: Vec<PartialStats>,
) -> Result<PartialStats> {
    let mut slots: Vec<Option<Box<PartialStats>>> =
        partials.into_iter().map(|p| Some(Box::new(p))).collect();
    let n = slots.len();
    let mut stride = 1usize;
    while stride < n {
        let mut inflight = 0usize;
        let mut i = 0usize;
        while i + stride < n {
            let dst = slots[i].take().expect("tree slot vacated twice");
            let src = slots[i + stride].take().expect("tree slot vacated twice");
            cmd_txs[inflight % cmd_txs.len()]
                .send(Cmd::Merge(i, dst, src))
                .map_err(|_| anyhow!("worker hung up during reduce"))?;
            inflight += 1;
            i += 2 * stride;
        }
        for _ in 0..inflight {
            match res_rx.recv().context("worker died during reduce")? {
                Reply::Merged { slot, stats } => slots[slot] = Some(stats),
                _ => return Err(anyhow!("protocol error: unexpected reply during reduce")),
            }
        }
        stride *= 2;
    }
    Ok(*slots.swap_remove(0).expect("tree root"))
}
