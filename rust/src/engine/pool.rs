//! The persistent worker pool: the threads, channels and pinned shards
//! behind [`super::Cluster`].
//!
//! In the [`Topology::Threads`] mode each worker backend lives on its
//! own OS thread for the lifetime of the pool, serving both `Step`
//! commands (the per-iteration shard pass) and `Merge` commands (the
//! in-pool tree reduce — pair merges of partial statistics execute on
//! the worker threads themselves, instead of the leader spawning fresh
//! OS threads per reduce round as the pre-engine `reduce.rs` did).
//!
//! In the [`Topology::Simulate`] mode the same backends run serially on
//! the leader thread and the metrics record `max(worker durations)` per
//! iteration — the homogeneous-cluster cost model of the paper's §4.1.
//! The two modes are numerically identical for a fixed seed: steps see
//! the same shard/weights, and the tree reduce uses the same pairing
//! order (so the f32 sums associate identically).
//!
//! # Fault tolerance (DESIGN.md §13)
//!
//! Every broadcast round is tagged with a monotone round id; the leader
//! collects replies under a bounded timeout and ignores stale or
//! duplicate replies (an earlier round's straggler answering late). A
//! worker that misses its deadline or returns non-finite statistics is
//! retried up to [`PoolOpts::step_retries`] times with a doubling
//! timeout; a worker that exhausts its retries — or whose channel is
//! gone because its thread died — is **evicted**: its shard rows are
//! re-split across the survivors, which adopt them as extra global
//! ranges on every subsequent step. Statistics stay exact because the
//! partial-merge operator is additive over rows; only the f32
//! association order changes. Seeded [`FaultPlan`]s (compiled in, inert
//! when empty) make every one of these paths deterministic under test
//! (`tests/chaos.rs`).

use std::ops::Range;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, OnceLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::backend::{RngState, StepInput, WorkerBackend};
use crate::config::{ReduceKind, Topology};
use crate::coordinator::reduce;
use crate::data::stream::ParsedChunk;
use crate::metrics::{Metrics, Phase};
use crate::solver::PartialStats;
use crate::telemetry::{self, Counter, Histogram};

use super::fault::{FaultKind, FaultPlan, WorkerFaults};

/// Pool-level latency distributions in the global telemetry registry:
/// the slowest worker's step per round, and the whole reduce.
struct PoolMetrics {
    step_nanos: Arc<Histogram>,
    reduce_nanos: Arc<Histogram>,
}

fn pool_metrics() -> &'static PoolMetrics {
    static M: OnceLock<PoolMetrics> = OnceLock::new();
    M.get_or_init(|| PoolMetrics {
        step_nanos: telemetry::global().histogram(
            "worker_step_nanos",
            "Slowest worker step per broadcast round in nanoseconds.",
        ),
        reduce_nanos: telemetry::global()
            .histogram("reduce_nanos", "Full reduce round wall-clock in nanoseconds."),
    })
}

/// Fault-tolerance counters in the global telemetry registry
/// (DESIGN.md §13): step retries after timeouts/corruption, and workers
/// evicted with their rows re-sharded onto survivors.
struct FaultMetrics {
    retries: Arc<Counter>,
    evictions: Arc<Counter>,
}

fn fault_metrics() -> &'static FaultMetrics {
    static M: OnceLock<FaultMetrics> = OnceLock::new();
    M.get_or_init(|| FaultMetrics {
        retries: telemetry::global().counter(
            "worker_retries_total",
            "Worker step commands re-sent after a timeout or a corrupt reply.",
        ),
        evictions: telemetry::global().counter(
            "worker_evictions_total",
            "Workers evicted from the pool; their rows re-sharded onto survivors.",
        ),
    })
}

/// Pool-local fault counters — the per-instance twin of the global
/// telemetry series, so tests can assert on one pool's behaviour even
/// when other pools run concurrently in the same process.
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultStats {
    pub retries: u64,
    pub evictions: u64,
}

/// Pool construction knobs. [`Default`] is the production setting:
/// no fault plan, generous timeout, eviction only as a last resort.
#[derive(Clone, Debug)]
pub struct PoolOpts {
    /// each worker's global row range; `None` for pools whose workers
    /// hold only their own shard (streamed ingestion), which therefore
    /// cannot re-shard a dead worker's rows
    pub shards: Option<Vec<Range<usize>>>,
    /// deterministic fault injection schedule (inert when empty)
    pub plan: FaultPlan,
    /// how long the leader waits on a step reply before retrying
    pub step_timeout: Duration,
    /// retries per worker per round before eviction
    pub step_retries: usize,
}

impl Default for PoolOpts {
    fn default() -> Self {
        PoolOpts {
            shards: None,
            plan: FaultPlan::none(),
            step_timeout: Duration::from_secs(30),
            step_retries: 2,
        }
    }
}

enum Cmd {
    /// One shard pass at the broadcast weights, tagged with the leader's
    /// round id (stale-reply detection) and the adopted global row
    /// ranges this worker covers for evicted peers. The `Arc` is the
    /// whole broadcast: P workers share one `StepInput` instead of
    /// receiving P deep copies (the `rebind_weights` optimization — for
    /// MLT this saves P clones of the full `[m, k]` weight block per
    /// class).
    Step { input: Arc<StepInput>, round: u64, extra: Vec<Range<usize>> },
    /// Merge `src` into the partial at tree slot `.0` and hand it back.
    Merge(usize, Box<PartialStats>, Box<PartialStats>),
    /// Streaming ingestion (DESIGN.md §10): every worker appends its
    /// slice of the shared parsed chunk to its shard buffer. Like
    /// `Step`, the `Arc` is the broadcast — the chunk's memory is
    /// released once the last worker drops its share.
    Ingest(Arc<ParsedChunk>),
    /// End of the chunk stream: each worker validates + seals its shard.
    Seal,
    /// Capture / restore the worker's sampler-RNG state (checkpointing).
    GetRng,
    SetRng(RngState),
    Stop,
}

enum Reply {
    Stepped { wid: usize, round: u64, stats: Result<PartialStats>, step_time: Duration },
    Merged { slot: usize, stats: Box<PartialStats> },
    Ingested { wid: usize, res: Result<()> },
    Rng { wid: usize, state: Option<RngState> },
    RngSet { wid: usize, res: Result<()> },
}

enum Mode {
    Threads {
        cmd_txs: Vec<Sender<Cmd>>,
        res_rx: Receiver<Reply>,
        handles: Vec<JoinHandle<()>>,
    },
    Simulate {
        workers: Vec<Box<dyn WorkerBackend>>,
        faults: Vec<WorkerFaults>,
    },
}

/// A set of worker backends bound to their shards, alive across many
/// training sessions.
pub struct Pool {
    mode: Mode,
    /// original global shard per worker id (`None`: cannot re-shard)
    shards: Option<Vec<Range<usize>>>,
    /// worker id -> still trusted? Evicted workers are never sent
    /// another step and their late replies are discarded.
    alive: Vec<bool>,
    /// worker id -> adopted global row ranges from evicted peers
    adopted: Vec<Vec<Range<usize>>>,
    /// monotone broadcast-round id (also the fault plan's clock)
    round: u64,
    step_timeout: Duration,
    step_retries: usize,
    fault_stats: FaultStats,
    /// a non-empty fault plan was compiled in: reduces run leader-side
    faulty: bool,
    /// per-worker step wall-clock accumulated since the last
    /// [`take_step_timing`](Pool::take_step_timing) (straggler-skew
    /// diagnostics, DESIGN.md §14)
    timing: StepTiming,
}

/// Worker step wall-clock accumulated across step rounds: the slowest
/// single step, the sum over all (worker, round) steps, and their
/// count. `max / (sum / n)` is the straggler skew the diagnostics
/// EWMA tracks. Drained by [`Pool::take_step_timing`]; when nothing
/// drains it the accumulation is a few scalar adds per round and never
/// grows.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepTiming {
    /// slowest single worker step
    pub max: Duration,
    /// sum of per-worker step durations
    pub sum: Duration,
    /// number of (worker, round) steps folded into `sum`
    pub n: u64,
}

impl StepTiming {
    /// Mean per-worker step duration in seconds (0 when empty).
    pub fn mean_secs(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum.as_secs_f64() / self.n as f64
        }
    }
}

impl Pool {
    /// Take ownership of the (already shard-bound) worker backends and,
    /// in the threaded topology, spawn their threads.
    pub fn spawn(workers: Vec<Box<dyn WorkerBackend>>, topology: Topology) -> Pool {
        Self::spawn_with(workers, topology, PoolOpts::default())
    }

    /// [`spawn`](Pool::spawn) with fault-tolerance options: shard map
    /// for re-sharding, timeout/retry budget, and an optional
    /// deterministic [`FaultPlan`].
    pub fn spawn_with(
        workers: Vec<Box<dyn WorkerBackend>>,
        topology: Topology,
        opts: PoolOpts,
    ) -> Pool {
        let p = workers.len();
        let faulty = !opts.plan.is_empty();
        let mut per_worker = opts.plan.split(p);
        let mode = match topology {
            Topology::Simulate => Mode::Simulate { workers, faults: per_worker },
            // Remote: the backends are `net::remote::RemoteWorker`
            // proxies, each driven by a leader-side forwarding thread —
            // the threaded machinery (round tags, timeouts, retry,
            // eviction, tree-merge dispatch) applies unchanged, and the
            // Merge command never touches a backend, so the tree reduce
            // still runs leader-side with the identical pairing order.
            Topology::Threads | Topology::Remote(_) => {
                let (res_tx, res_rx) = mpsc::channel::<Reply>();
                let mut cmd_txs = Vec::with_capacity(p);
                let mut handles = Vec::with_capacity(p);
                for (wid, mut wk) in workers.into_iter().enumerate() {
                    let (tx, rx) = mpsc::channel::<Cmd>();
                    cmd_txs.push(tx);
                    let res_tx = res_tx.clone();
                    let mut faults = std::mem::take(&mut per_worker[wid]);
                    handles.push(std::thread::spawn(move || {
                        worker_loop(wid, &mut *wk, &rx, &res_tx, &mut faults)
                    }));
                }
                Mode::Threads { cmd_txs, res_rx, handles }
            }
        };
        Pool {
            mode,
            shards: opts.shards,
            alive: vec![true; p],
            adopted: (0..p).map(|_| Vec::new()).collect(),
            round: 0,
            step_timeout: opts.step_timeout.max(Duration::from_millis(1)),
            step_retries: opts.step_retries,
            fault_stats: FaultStats::default(),
            faulty,
            timing: StepTiming::default(),
        }
    }

    /// Number of workers (the worker-id space; includes evicted ones).
    pub fn len(&self) -> usize {
        match &self.mode {
            Mode::Threads { cmd_txs, .. } => cmd_txs.len(),
            Mode::Simulate { workers, .. } => workers.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Workers still trusted with step commands.
    pub fn alive(&self) -> usize {
        self.alive.iter().filter(|&&a| a).count()
    }

    /// This pool's retry/eviction counters (the pool-local twin of the
    /// `worker_retries_total` / `worker_evictions_total` series).
    pub fn fault_counters(&self) -> FaultStats {
        self.fault_stats
    }

    /// Drain the per-worker step-timing accumulator: returns everything
    /// folded in since the previous call and resets it. The engine's
    /// diagnostics cadence calls this once per diagnosed iteration, so
    /// MLT's per-class collects aggregate naturally.
    pub fn take_step_timing(&mut self) -> StepTiming {
        std::mem::take(&mut self.timing)
    }

    /// Running degraded: a fault plan is armed or a worker has been
    /// evicted. Reduces then run leader-side (same pairing order, so
    /// still bit-identical to the in-pool tree) — the merge dispatch is
    /// the one pool path with no retry story, so it is bypassed rather
    /// than hardened.
    pub fn degraded(&self) -> bool {
        self.faulty || self.fault_stats.evictions > 0
    }

    /// One broadcast + collect round: every live worker steps on `input`
    /// (plus its adopted ranges); partials come back ordered by worker
    /// id, one per live worker. Timing goes to the `Broadcast` /
    /// `LocalStats` phases (max over workers, per §4.1).
    pub fn step_all(
        &mut self,
        input: StepInput,
        metrics: &mut Metrics,
    ) -> Result<Vec<PartialStats>> {
        let ctx = StepCtx {
            alive: &mut self.alive,
            adopted: &mut self.adopted,
            shards: &self.shards,
            round: &mut self.round,
            timeout: self.step_timeout,
            retries: self.step_retries,
            fstats: &mut self.fault_stats,
            timing: &mut self.timing,
        };
        match &mut self.mode {
            Mode::Simulate { workers, faults } => {
                step_all_simulate(workers, faults, ctx, &input, metrics)
            }
            Mode::Threads { cmd_txs, res_rx, .. } => {
                step_all_threads(cmd_txs, res_rx, ctx, input, metrics)
            }
        }
    }

    /// Broadcast one parsed chunk to every worker: each appends its
    /// slice to its shard buffer (DESIGN.md §10). In the threaded
    /// topology the append runs on the worker threads, overlapping with
    /// the stream reader's parse of the next chunk; waiting for all P
    /// replies before the next chunk keeps per-worker ingestion in file
    /// order. All replies are consumed even on error (a queued reply
    /// would otherwise leak into the next command round).
    pub fn ingest_all(&mut self, chunk: ParsedChunk) -> Result<()> {
        match &mut self.mode {
            Mode::Simulate { workers, .. } => {
                for wk in workers.iter_mut() {
                    wk.ingest(&chunk)?;
                }
                Ok(())
            }
            Mode::Threads { cmd_txs, res_rx, .. } => {
                let chunk = Arc::new(chunk);
                for tx in cmd_txs.iter() {
                    tx.send(Cmd::Ingest(chunk.clone()))
                        .map_err(|_| anyhow!("worker hung up during ingest"))?;
                }
                drop(chunk);
                collect_ingest_replies(cmd_txs.len(), res_rx, "ingest")
            }
        }
    }

    /// End of stream: every worker validates and seals its shard, making
    /// the pool steppable.
    pub fn seal_all(&mut self) -> Result<()> {
        match &mut self.mode {
            Mode::Simulate { workers, .. } => {
                for wk in workers.iter_mut() {
                    wk.seal()?;
                }
                Ok(())
            }
            Mode::Threads { cmd_txs, res_rx, .. } => {
                for tx in cmd_txs.iter() {
                    tx.send(Cmd::Seal).map_err(|_| anyhow!("worker hung up during seal"))?;
                }
                collect_ingest_replies(cmd_txs.len(), res_rx, "seal")
            }
        }
    }

    /// Reduce the partials to one. `Flat` folds at the leader; `Tree`
    /// merges pairs — dispatched to the pool's worker threads in the
    /// threaded topology, serially (identical pairing order, hence
    /// bit-identical sums) in the simulated one or when the pool is
    /// [`degraded`](Pool::degraded).
    pub fn reduce(
        &mut self,
        kind: ReduceKind,
        partials: Vec<PartialStats>,
        metrics: &mut Metrics,
    ) -> Result<PartialStats> {
        metrics.reduces += 1;
        let degraded = self.degraded();
        let t0 = Instant::now();
        let out = match (&mut self.mode, kind) {
            (Mode::Threads { cmd_txs, res_rx, .. }, ReduceKind::Tree)
                if partials.len() > 1 && !degraded =>
            {
                in_pool_tree(cmd_txs, res_rx, partials)?
            }
            (_, kind) => reduce::reduce(kind, partials),
        };
        let elapsed = t0.elapsed();
        metrics.add(Phase::Reduce, elapsed);
        pool_metrics().reduce_nanos.observe_duration(elapsed);
        Ok(out)
    }

    /// Capture every live worker's sampler-RNG state (checkpointing).
    /// Entries are `None` for evicted workers, backends without a
    /// restorable RNG, or (defensively) workers that fail to answer
    /// within the step timeout.
    pub fn rng_states(&mut self) -> Result<Vec<Option<RngState>>> {
        let timeout = self.step_timeout;
        match &mut self.mode {
            Mode::Simulate { workers, .. } => Ok(workers
                .iter()
                .zip(&self.alive)
                .map(|(w, &a)| if a { w.rng_state() } else { None })
                .collect()),
            Mode::Threads { cmd_txs, res_rx, .. } => {
                let p = cmd_txs.len();
                let mut out: Vec<Option<RngState>> = vec![None; p];
                let mut expect = 0usize;
                for (wid, tx) in cmd_txs.iter().enumerate() {
                    if self.alive[wid] && tx.send(Cmd::GetRng).is_ok() {
                        expect += 1;
                    }
                }
                let mut got = 0usize;
                while got < expect {
                    match res_rx.recv_timeout(timeout) {
                        Ok(Reply::Rng { wid, state }) => {
                            out[wid] = state;
                            got += 1;
                        }
                        // a straggler's stale step reply from an aborted
                        // round; harmless here
                        Ok(Reply::Stepped { .. }) => {}
                        Ok(_) => bail!("protocol error: unexpected reply during rng capture"),
                        Err(_) => break, // dead worker: leave its slot None
                    }
                }
                Ok(out)
            }
        }
    }

    /// Restore states captured by [`rng_states`](Pool::rng_states);
    /// `None` entries are skipped. Errors if any worker rejects or
    /// fails to acknowledge the restore — a checkpoint resumed onto a
    /// half-restored pool would silently diverge.
    pub fn set_rng_states(&mut self, states: &[Option<RngState>]) -> Result<()> {
        let timeout = self.step_timeout;
        match &mut self.mode {
            Mode::Simulate { workers, .. } => {
                for (wid, wk) in workers.iter_mut().enumerate() {
                    if let Some(s) = states.get(wid).copied().flatten() {
                        if self.alive[wid] {
                            wk.set_rng_state(s)
                                .with_context(|| format!("restoring RNG of worker {wid}"))?;
                        }
                    }
                }
                Ok(())
            }
            Mode::Threads { cmd_txs, res_rx, .. } => {
                let mut expect = 0usize;
                for (wid, tx) in cmd_txs.iter().enumerate() {
                    if let Some(s) = states.get(wid).copied().flatten() {
                        if self.alive[wid] {
                            tx.send(Cmd::SetRng(s))
                                .map_err(|_| anyhow!("worker {wid} hung up during restore"))?;
                            expect += 1;
                        }
                    }
                }
                let mut got = 0usize;
                while got < expect {
                    match res_rx.recv_timeout(timeout) {
                        Ok(Reply::RngSet { wid, res }) => {
                            res.with_context(|| format!("restoring RNG of worker {wid}"))?;
                            got += 1;
                        }
                        Ok(Reply::Stepped { .. }) => {} // stale straggler reply
                        Ok(_) => bail!("protocol error: unexpected reply during rng restore"),
                        Err(_) => bail!("worker did not acknowledge RNG restore"),
                    }
                }
                Ok(())
            }
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        if let Mode::Threads { cmd_txs, handles, .. } = &mut self.mode {
            for tx in cmd_txs.iter() {
                let _ = tx.send(Cmd::Stop);
            }
            for h in handles.drain(..) {
                let _ = h.join();
            }
        }
    }
}

/// The worker-thread command loop, with the fault injector inline: a
/// production pool carries an empty [`WorkerFaults`], so the injection
/// seam costs one `Vec::is_empty`-grade scan per step command.
fn worker_loop(
    wid: usize,
    wk: &mut dyn WorkerBackend,
    rx: &Receiver<Cmd>,
    res_tx: &Sender<Reply>,
    faults: &mut WorkerFaults,
) {
    while let Ok(cmd) = rx.recv() {
        match cmd {
            Cmd::Stop => break,
            Cmd::Step { input, round, extra } => {
                let fault = faults.fire(round);
                match fault {
                    // the worker "panics": leave the loop for good; the
                    // leader observes the dead channel and evicts
                    Some(FaultKind::PanicAt) => break,
                    // lost message: never reply, let the timeout fire
                    Some(FaultKind::DropReply) => continue,
                    _ => {}
                }
                if let Some(FaultKind::DelayStep { millis }) = fault {
                    std::thread::sleep(Duration::from_millis(millis));
                }
                let t0 = Instant::now();
                let mut stats = wk.step_ranges(&input, &extra);
                let step_time = t0.elapsed();
                if matches!(fault, Some(FaultKind::CorruptStats)) {
                    if let Ok(s) = stats.as_mut() {
                        s.obj = f64::NAN;
                        if let Some(m) = s.mu.first_mut() {
                            *m = f32::NAN;
                        }
                    }
                }
                // drop our share of the broadcast *before* replying, so
                // once the leader holds all replies its Arc is unique
                // again (MLT mutates the weight block in place)
                drop(input);
                if res_tx.send(Reply::Stepped { wid, round, stats, step_time }).is_err() {
                    break;
                }
            }
            Cmd::Merge(slot, mut dst, src) => {
                dst.merge(&src);
                if res_tx.send(Reply::Merged { slot, stats: dst }).is_err() {
                    break;
                }
            }
            Cmd::Ingest(chunk) => {
                let res = wk.ingest(&chunk);
                // release our share before replying so the chunk frees
                // as soon as the last worker is done with it
                drop(chunk);
                if res_tx.send(Reply::Ingested { wid, res }).is_err() {
                    break;
                }
            }
            Cmd::Seal => {
                let res = wk.seal();
                if res_tx.send(Reply::Ingested { wid, res }).is_err() {
                    break;
                }
            }
            Cmd::GetRng => {
                if res_tx.send(Reply::Rng { wid, state: wk.rng_state() }).is_err() {
                    break;
                }
            }
            Cmd::SetRng(s) => {
                if res_tx.send(Reply::RngSet { wid, res: wk.set_rng_state(s) }).is_err() {
                    break;
                }
            }
        }
    }
}

/// The mutable pool state one step round threads through its helpers —
/// split out of [`Pool`] so the borrow of `Pool::mode` stays disjoint.
struct StepCtx<'a> {
    alive: &'a mut Vec<bool>,
    adopted: &'a mut Vec<Vec<Range<usize>>>,
    shards: &'a Option<Vec<Range<usize>>>,
    round: &'a mut u64,
    timeout: Duration,
    retries: usize,
    fstats: &'a mut FaultStats,
    timing: &'a mut StepTiming,
}

impl StepCtx<'_> {
    fn note_retry(&mut self) {
        self.fstats.retries += 1;
        fault_metrics().retries.inc();
    }

    /// Evict `wid`: stop trusting it and re-split its rows (own shard +
    /// anything it had already adopted) across the survivors. Errors if
    /// no survivor remains or the pool has no shard map (streamed pools,
    /// whose workers hold only their own rows).
    fn evict(&mut self, wid: usize) -> Result<()> {
        if !self.alive[wid] {
            return Ok(());
        }
        self.alive[wid] = false;
        self.fstats.evictions += 1;
        fault_metrics().evictions.inc();
        let survivors: Vec<usize> =
            self.alive.iter().enumerate().filter(|&(_, &a)| a).map(|(i, _)| i).collect();
        if survivors.is_empty() {
            bail!("worker {wid} failed and no worker survives it");
        }
        let Some(shards) = self.shards else {
            bail!(
                "worker {wid} failed and this pool cannot re-shard its rows (streamed \
                 shards live only in their worker; restart ingestion)"
            );
        };
        crate::log_warn!(
            "pool: evicting worker {wid}; re-sharding {} rows across {} survivors",
            shards[wid].len(),
            survivors.len()
        );
        let mut orphaned = vec![shards[wid].clone()];
        orphaned.append(&mut self.adopted[wid]);
        for r in orphaned {
            if r.is_empty() {
                continue;
            }
            // same balanced split the initial sharding used, offset into
            // the orphaned range; survivor j adopts piece j
            let pieces = crate::data::shard_ranges(r.len(), survivors.len());
            for (j, s) in pieces.into_iter().enumerate() {
                let piece = r.start + s.range.start..r.start + s.range.end;
                if !piece.is_empty() {
                    self.adopted[survivors[j]].push(piece);
                }
            }
        }
        Ok(())
    }
}

/// Threaded step round: broadcast with round tags, collect under a
/// bounded (doubling) timeout, retry stragglers/corruption, evict and
/// re-shard on exhaustion, and restart the round whenever membership
/// changed so every partial reflects the final assignment.
fn step_all_threads(
    cmd_txs: &[Sender<Cmd>],
    res_rx: &Receiver<Reply>,
    mut ctx: StepCtx<'_>,
    input: StepInput,
    metrics: &mut Metrics,
) -> Result<Vec<PartialStats>> {
    let p = cmd_txs.len();
    let input = Arc::new(input);
    'round: loop {
        *ctx.round += 1;
        let round = *ctx.round;
        let t0 = Instant::now();
        let mut send_failed: Vec<usize> = Vec::new();
        for wid in 0..p {
            if !ctx.alive[wid] {
                continue;
            }
            let cmd =
                Cmd::Step { input: input.clone(), round, extra: ctx.adopted[wid].clone() };
            if cmd_txs[wid].send(cmd).is_err() {
                send_failed.push(wid);
            }
        }
        metrics.add(Phase::Broadcast, t0.elapsed());
        if !send_failed.is_empty() {
            for wid in send_failed {
                ctx.evict(wid)?;
            }
            continue 'round; // assignment changed: re-broadcast
        }

        let mut slots: Vec<Option<PartialStats>> = (0..p).map(|_| None).collect();
        let mut errored: Vec<bool> = vec![false; p];
        let mut attempts: Vec<usize> = vec![1; p];
        let mut first_err: Option<anyhow::Error> = None;
        let mut max_step = Duration::ZERO;
        let mut sum_step = Duration::ZERO;
        let mut n_step = 0u64;
        let mut timeout = ctx.timeout;
        loop {
            let missing = (0..p)
                .filter(|&w| ctx.alive[w] && slots[w].is_none() && !errored[w])
                .count();
            if missing == 0 {
                break;
            }
            match res_rx.recv_timeout(timeout) {
                Ok(Reply::Stepped { wid, round: r, stats, step_time }) => {
                    if r != round || !ctx.alive[wid] || slots[wid].is_some() || errored[wid] {
                        continue; // stale round, evicted sender, or duplicate
                    }
                    match stats {
                        Ok(s) if s.is_finite() => {
                            slots[wid] = Some(s);
                            max_step = max_step.max(step_time);
                            sum_step += step_time;
                            n_step += 1;
                        }
                        Ok(_corrupt) => {
                            // NaN/inf partial: retry, then evict
                            attempts[wid] += 1;
                            if attempts[wid] > ctx.retries + 1 {
                                ctx.evict(wid)?;
                                continue 'round;
                            }
                            ctx.note_retry();
                            let cmd = Cmd::Step {
                                input: input.clone(),
                                round,
                                extra: ctx.adopted[wid].clone(),
                            };
                            if cmd_txs[wid].send(cmd).is_err() {
                                ctx.evict(wid)?;
                                continue 'round;
                            }
                        }
                        Err(e) if e.downcast_ref::<crate::net::NetDown>().is_some() => {
                            // the worker's *connection* failed, not its
                            // math: same treatment as a missed deadline.
                            // A dead connection fails fast on the
                            // retries, so this converges to eviction
                            // without ever re-stepping the daemon.
                            attempts[wid] += 1;
                            if attempts[wid] > ctx.retries + 1 {
                                ctx.evict(wid)?;
                                continue 'round;
                            }
                            ctx.note_retry();
                            let cmd = Cmd::Step {
                                input: input.clone(),
                                round,
                                extra: ctx.adopted[wid].clone(),
                            };
                            if cmd_txs[wid].send(cmd).is_err() {
                                ctx.evict(wid)?;
                                continue 'round;
                            }
                        }
                        Err(e) => {
                            // a deterministic backend error (not injected
                            // noise): retrying cannot heal it — surface it
                            errored[wid] = true;
                            if first_err.is_none() {
                                first_err = Some(e);
                            }
                        }
                    }
                }
                // replies of other kinds can only be stragglers from an
                // aborted earlier round; skip them
                Ok(_) => continue,
                Err(RecvTimeoutError::Timeout) => {
                    let mut evicted = false;
                    for wid in 0..p {
                        if !ctx.alive[wid] || slots[wid].is_some() || errored[wid] {
                            continue;
                        }
                        attempts[wid] += 1;
                        if attempts[wid] > ctx.retries + 1 {
                            ctx.evict(wid)?;
                            evicted = true;
                            continue;
                        }
                        ctx.note_retry();
                        let cmd = Cmd::Step {
                            input: input.clone(),
                            round,
                            extra: ctx.adopted[wid].clone(),
                        };
                        if cmd_txs[wid].send(cmd).is_err() {
                            ctx.evict(wid)?;
                            evicted = true;
                        }
                    }
                    if evicted {
                        continue 'round; // assignment changed: re-broadcast
                    }
                    timeout = timeout.saturating_mul(2); // backoff
                }
                Err(RecvTimeoutError::Disconnected) => {
                    bail!("all worker threads hung up mid-round")
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        metrics.add(Phase::LocalStats, max_step);
        pool_metrics().step_nanos.observe_duration(max_step);
        ctx.timing.max = ctx.timing.max.max(max_step);
        ctx.timing.sum += sum_step;
        ctx.timing.n += n_step;
        return Ok((0..p).filter(|&w| ctx.alive[w]).map(|w| slots[w].take().unwrap()).collect());
    }
}

/// Simulated step round: the same fault semantics run serially — a
/// dropped reply or corrupt partial costs a retry (immediate, there is
/// no wire to wait on), a "panicked" worker is evicted and the round
/// restarts with its rows re-sharded.
fn step_all_simulate(
    workers: &mut [Box<dyn WorkerBackend>],
    faults: &mut [WorkerFaults],
    mut ctx: StepCtx<'_>,
    input: &StepInput,
    metrics: &mut Metrics,
) -> Result<Vec<PartialStats>> {
    'round: loop {
        *ctx.round += 1;
        let round = *ctx.round;
        let mut out = Vec::with_capacity(workers.len());
        let mut max_step = Duration::ZERO;
        let mut sum_step = Duration::ZERO;
        let mut n_step = 0u64;
        for wid in 0..workers.len() {
            if !ctx.alive[wid] {
                continue;
            }
            let mut attempts = 0usize;
            loop {
                attempts += 1;
                if attempts > ctx.retries + 1 {
                    ctx.evict(wid)?;
                    continue 'round;
                }
                let fault = faults[wid].fire(round);
                match fault {
                    Some(FaultKind::PanicAt) => {
                        ctx.evict(wid)?;
                        continue 'round;
                    }
                    Some(FaultKind::DropReply) => {
                        ctx.note_retry();
                        continue;
                    }
                    Some(FaultKind::DelayStep { millis }) => {
                        std::thread::sleep(Duration::from_millis(millis));
                    }
                    _ => {}
                }
                let t0 = Instant::now();
                // a hard backend error is deterministic: propagate, as
                // the threaded path does
                let mut stats = workers[wid].step_ranges(input, &ctx.adopted[wid])?;
                if matches!(fault, Some(FaultKind::CorruptStats)) {
                    stats.obj = f64::NAN;
                }
                if !stats.is_finite() {
                    ctx.note_retry();
                    continue;
                }
                let step_time = t0.elapsed();
                max_step = max_step.max(step_time);
                sum_step += step_time;
                n_step += 1;
                out.push(stats);
                break;
            }
        }
        metrics.add(Phase::LocalStats, max_step);
        pool_metrics().step_nanos.observe_duration(max_step);
        ctx.timing.max = ctx.timing.max.max(max_step);
        ctx.timing.sum += sum_step;
        ctx.timing.n += n_step;
        return Ok(out);
    }
}

/// Collect the P `Ingested` replies of one ingest/seal round,
/// propagating the first worker error after draining all replies.
fn collect_ingest_replies(p: usize, res_rx: &Receiver<Reply>, what: &str) -> Result<()> {
    let mut first_err: Option<anyhow::Error> = None;
    let mut got = 0usize;
    while got < p {
        match res_rx.recv().with_context(|| format!("worker died during {what}"))? {
            Reply::Ingested { wid, res } => {
                got += 1;
                if let Err(e) = res {
                    if first_err.is_none() {
                        first_err = Some(e.context(format!("worker {wid} {what}")));
                    }
                }
            }
            Reply::Stepped { .. } => {} // straggler from an aborted round
            _ => return Err(anyhow!("protocol error: unexpected reply during {what}")),
        }
    }
    match first_err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// Binary-tree reduce whose pair merges run on the pool's worker
/// threads: each round's merges are dispatched round-robin and collected
/// before the stride doubles (the merges of one round overlap, matching
/// the simultaneous pairwise exchanges of the paper's Table 1).
///
/// Pairing is identical to [`reduce::reduce`]'s serial tree — slot `i`
/// absorbs slot `i + stride` — so both produce the same f32 sums.
fn in_pool_tree(
    cmd_txs: &[Sender<Cmd>],
    res_rx: &Receiver<Reply>,
    partials: Vec<PartialStats>,
) -> Result<PartialStats> {
    let mut slots: Vec<Option<Box<PartialStats>>> =
        partials.into_iter().map(|p| Some(Box::new(p))).collect();
    let n = slots.len();
    let mut stride = 1usize;
    while stride < n {
        let mut inflight = 0usize;
        let mut i = 0usize;
        while i + stride < n {
            let dst = slots[i].take().expect("tree slot vacated twice");
            let src = slots[i + stride].take().expect("tree slot vacated twice");
            cmd_txs[inflight % cmd_txs.len()]
                .send(Cmd::Merge(i, dst, src))
                .map_err(|_| anyhow!("worker hung up during reduce"))?;
            inflight += 1;
            i += 2 * stride;
        }
        let mut got = 0usize;
        while got < inflight {
            match res_rx.recv().context("worker died during reduce")? {
                Reply::Merged { slot, stats } => {
                    slots[slot] = Some(stats);
                    got += 1;
                }
                Reply::Stepped { .. } => {} // straggler from an aborted round
                _ => return Err(anyhow!("protocol error: unexpected reply during reduce")),
            }
        }
        stride *= 2;
    }
    Ok(*slots.swap_remove(0).expect("tree root"))
}
