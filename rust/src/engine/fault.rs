//! Deterministic fault injection for the worker pool (DESIGN.md §13).
//!
//! A [`FaultPlan`] is a list of `(worker, round, kind)` triples compiled
//! into the pool at spawn time. The plan is **inert when empty** — the
//! production path carries a zero-length vector and one integer compare
//! per step command — and fully deterministic otherwise: a fault fires
//! exactly once, when the named worker receives the step command of the
//! named round. Rounds count broadcast rounds as issued by the leader
//! (so an MLT iteration consumes `m` rounds, and a round restarted after
//! an eviction gets a fresh number).
//!
//! Four fault kinds cover the failure modes a distributed reduce must
//! survive:
//!
//! * [`FaultKind::DelayStep`] — a straggler: the worker sleeps before
//!   stepping, long enough to trip the leader's bounded timeout.
//! * [`FaultKind::DropReply`] — a lost message: the step command is
//!   swallowed, no reply is ever sent.
//! * [`FaultKind::PanicAt`] — a crash: the worker thread exits its
//!   command loop (observably identical to an unwound panic — the
//!   channels drop — without the stderr noise of a real `panic!`).
//! * [`FaultKind::CorruptStats`] — a poisoned message: the step runs
//!   but its statistics come back with NaNs.

use crate::rng::Pcg64;

/// One injectable failure mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// sleep this long before computing the step (straggler)
    DelayStep { millis: u64 },
    /// swallow the step command; never reply (lost message)
    DropReply,
    /// the worker dies: its thread leaves the command loop for good
    PanicAt,
    /// reply with NaN-poisoned statistics (corrupt message)
    CorruptStats,
}

/// A fault pinned to one worker and one broadcast round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultSpec {
    pub worker: usize,
    pub round: u64,
    pub kind: FaultKind,
}

/// A deterministic schedule of faults, split per worker at pool spawn.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    specs: Vec<FaultSpec>,
}

impl FaultPlan {
    /// The inert (production) plan.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    pub fn len(&self) -> usize {
        self.specs.len()
    }

    /// Add one fault; builder-style for test matrices.
    pub fn with(mut self, worker: usize, round: u64, kind: FaultKind) -> FaultPlan {
        self.specs.push(FaultSpec { worker, round, kind });
        self
    }

    pub fn push(&mut self, spec: FaultSpec) {
        self.specs.push(spec);
    }

    /// A seeded random plan of `n_faults` faults over `workers` workers
    /// and broadcast rounds `1..=rounds`: the chaos harness sweeps seeds
    /// instead of hand-writing matrices. At most one worker is ever
    /// killed (a plan that kills all workers cannot terminate), and
    /// delays are kept short enough for tests.
    pub fn seeded(seed: u64, workers: usize, rounds: u64, n_faults: usize) -> FaultPlan {
        let mut rng = Pcg64::new_stream(seed, 0xfau64);
        let mut plan = FaultPlan::default();
        let mut killed = false;
        for _ in 0..n_faults {
            let worker = rng.next_below(workers.max(1) as u64) as usize;
            let round = 1 + rng.next_below(rounds.max(1));
            let kind = match rng.next_below(4) {
                0 => FaultKind::DelayStep { millis: 20 + rng.next_below(60) },
                1 => FaultKind::DropReply,
                2 if !killed => {
                    killed = true;
                    FaultKind::PanicAt
                }
                _ => FaultKind::CorruptStats,
            };
            plan.push(FaultSpec { worker, round, kind });
        }
        plan
    }

    /// Split the plan into per-worker injectors (what each worker thread
    /// carries). Specs naming workers `>= workers` are dropped.
    pub fn split(&self, workers: usize) -> Vec<WorkerFaults> {
        let mut out: Vec<WorkerFaults> = (0..workers).map(|_| WorkerFaults::default()).collect();
        for s in &self.specs {
            if s.worker < workers {
                out[s.worker].specs.push(*s);
            }
        }
        out
    }
}

/// One worker's slice of the plan. Each spec fires at most once — a
/// retried or restarted round re-delivers the same round number, but the
/// fault has already been consumed, so retries observe a healthy worker.
#[derive(Clone, Debug, Default)]
pub struct WorkerFaults {
    specs: Vec<FaultSpec>,
}

impl WorkerFaults {
    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    /// Consume and return the fault scheduled for `round`, if any.
    pub fn fire(&mut self, round: u64) -> Option<FaultKind> {
        let i = self.specs.iter().position(|s| s.round == round)?;
        Some(self.specs.swap_remove(i).kind)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_is_inert() {
        let plan = FaultPlan::none();
        assert!(plan.is_empty());
        let mut per = plan.split(4);
        assert_eq!(per.len(), 4);
        for w in per.iter_mut() {
            assert!(w.fire(1).is_none());
        }
    }

    #[test]
    fn faults_fire_once_at_their_round() {
        let plan = FaultPlan::none()
            .with(1, 3, FaultKind::DropReply)
            .with(1, 5, FaultKind::CorruptStats)
            .with(0, 3, FaultKind::PanicAt);
        let mut per = plan.split(2);
        assert_eq!(per[0].fire(3), Some(FaultKind::PanicAt));
        assert_eq!(per[0].fire(3), None, "consumed on first delivery");
        assert_eq!(per[1].fire(1), None);
        assert_eq!(per[1].fire(3), Some(FaultKind::DropReply));
        assert_eq!(per[1].fire(5), Some(FaultKind::CorruptStats));
        assert!(per[1].is_empty());
    }

    #[test]
    fn seeded_plans_are_reproducible_and_bounded() {
        let a = FaultPlan::seeded(42, 4, 10, 6);
        let b = FaultPlan::seeded(42, 4, 10, 6);
        assert_eq!(a.specs, b.specs);
        assert_eq!(a.len(), 6);
        let kills =
            a.specs.iter().filter(|s| s.kind == FaultKind::PanicAt).count();
        assert!(kills <= 1, "a survivable plan kills at most one worker");
        for s in &a.specs {
            assert!(s.worker < 4);
            assert!(s.round >= 1 && s.round <= 10);
        }
        // different seed -> different schedule (overwhelmingly likely)
        let c = FaultPlan::seeded(43, 4, 10, 6);
        assert_ne!(a.specs, c.specs);
    }
}
