//! `pemsvm` — CLI for the parallel data-augmentation SVM.
//!
//! Subcommands:
//!   train <data.svm>  --options LIN-EM-CLS --workers 8 --lambda 1.0 ...
//!   sweep <data.svm>  --lambdas 10,1,0.1,0.01 [--warm-start] ...
//!   datagen <out.svm> --dataset alpha --n 10000 --k 64 --seed 0
//!   eval <data.svm> <model.txt>
//!   info
//!
//! `train` writes the learned weights to `--model-out` (default
//! `model.txt`, one weight per line; M blocks for multiclass). `sweep`
//! builds one persistent `engine::Cluster` and runs one training
//! session per lambda on it — threads stay up and shards stay resident
//! across solves, optionally warm-starting each session from the
//! previous solution.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use pemsvm::cli::Args;
use pemsvm::config::{TaskKind, TrainConfig};
use pemsvm::data::{libsvm, synth, Dataset, Task};
use pemsvm::model::Weights;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: Vec<String>) -> Result<()> {
    if argv.is_empty() {
        print_usage();
        return Ok(());
    }
    let args = Args::parse(argv)?;
    match args.subcommand.as_str() {
        "train" => cmd_train(&args),
        "sweep" => cmd_sweep(&args),
        "datagen" => cmd_datagen(&args),
        "eval" => cmd_eval(&args),
        "info" => cmd_info(&args),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown subcommand `{other}` (try `pemsvm help`)"),
    }
}

fn print_usage() {
    println!(
        "pemsvm — Fast Parallel SVM using Data Augmentation (Perkins et al. 2015)

USAGE:
  pemsvm train <data.svm> [--options LIN-EM-CLS] [--workers P] [--lambda L]
               [--backend native|xla] [--reduce flat|tree] [--max-iters I]
               [--tol T] [--seed S] [--num-classes M] [--model-out model.txt]
               [--config file.toml] [--test test.svm] [--verbose]
               [--topology threads|simulate]
  pemsvm sweep <data.svm> [--lambdas 10,1,0.1,0.01] [--warm-start]
               [--test test.svm] [train flags...]
  pemsvm datagen <out.svm> --dataset alpha|dna|year|mnist|news20
               [--n N] [--k K] [--m M] [--seed S]
  pemsvm eval <data.svm> <model.txt> [--task cls|svr|mlt] [--num-classes M]
  pemsvm info [--artifacts-dir artifacts]"
    );
}

fn build_config(args: &Args) -> Result<TrainConfig> {
    let mut cfg = TrainConfig::default();
    if let Some(path) = args.get("config") {
        let doc = pemsvm::config::TomlDoc::load(Path::new(path))?;
        cfg.apply_toml(&doc)?;
    }
    for (key, val) in &args.flags {
        let k = key.replace('-', "_");
        match k.as_str() {
            "config" | "model_out" | "test" | "lambdas" => continue,
            "max_iters" | "options" | "lambda" | "workers" | "seed" | "tol" | "backend"
            | "reduce" | "burn_in" | "num_classes" | "eps_clamp" | "eps_insensitive"
            | "artifacts_dir" | "verbose" | "kernel" | "kernel_sigma" | "algo" | "task"
            | "model" | "topology" | "simulate_cluster" | "warm_start" => cfg.set(&k, val)?,
            other => bail!("unknown flag --{other}"),
        }
    }
    Ok(cfg)
}

fn task_of(cfg: &TrainConfig) -> Task {
    match cfg.task {
        TaskKind::Cls => Task::Binary,
        TaskKind::Svr => Task::Regression,
        TaskKind::Mlt => Task::Multiclass(cfg.num_classes),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let Some(data_path) = args.positional.first() else {
        bail!("train: missing <data.svm>");
    };
    let cfg = build_config(args)?;
    let t_load = std::time::Instant::now();
    let ds = libsvm::load(Path::new(data_path), task_of(&cfg), cfg.workers)
        .with_context(|| format!("loading {data_path}"))?;
    let load_secs = t_load.elapsed().as_secs_f64();
    let test = args
        .get("test")
        .map(|p| libsvm::load(Path::new(p), task_of(&cfg), cfg.workers))
        .transpose()?;

    println!(
        "# {} on {} (N={} K={} density={:.3}) workers={} backend={:?}",
        cfg.options_string(),
        data_path,
        ds.n,
        ds.k,
        ds.density(),
        cfg.workers,
        cfg.backend
    );
    let t_train = std::time::Instant::now();
    let out = pemsvm::coordinator::train_full(&ds, test.as_ref(), &cfg)?;
    let train_secs = t_train.elapsed().as_secs_f64();

    if cfg.verbose {
        for h in &out.history {
            println!(
                "iter {:>4}  J = {:<14.4} loss = {:<12.4} err = {:.4}{}",
                h.iter,
                h.objective,
                h.train_loss,
                h.train_err,
                h.test_metric.map(|m| format!("  test = {m:.4}")).unwrap_or_default()
            );
        }
    }
    println!("# load {load_secs:.2}s  train {train_secs:.2}s  iters {}", out.iterations);
    println!("# phases: {}", out.metrics.report());
    println!("# final objective {:.4}", out.objective);
    let train_metric = pemsvm::model::evaluate(&ds, &out.weights);
    println!(
        "# train {} = {:.4}",
        if cfg.task == TaskKind::Svr { "rmse" } else { "accuracy" },
        train_metric
    );
    if let Some(te) = &test {
        let m = match (&out.kernel_model, cfg.model) {
            (Some(km), pemsvm::config::ModelKind::Kernel) => km.accuracy(te),
            _ => pemsvm::model::evaluate(te, &out.weights),
        };
        println!(
            "# test {} = {m:.4}",
            if cfg.task == TaskKind::Svr { "rmse" } else { "accuracy" }
        );
    }

    let model_out = PathBuf::from(args.get("model-out").unwrap_or("model.txt"));
    save_weights(&out.weights, &model_out)?;
    println!("# model written to {}", model_out.display());
    Ok(())
}

/// Lambda sweep on one persistent cluster: the `engine::Cluster` is
/// built once (threads spawned, shards pinned) and then runs one
/// session per lambda — with `--warm-start`, each session starts from
/// the previous session's weights.
fn cmd_sweep(args: &Args) -> Result<()> {
    let Some(data_path) = args.positional.first() else {
        bail!("sweep: missing <data.svm>");
    };
    let cfg = build_config(args)?;
    let lambdas: Vec<f32> = match args.get("lambdas") {
        Some(list) => {
            let mut out = Vec::new();
            for part in list.split(',') {
                out.push(
                    part.trim()
                        .parse()
                        .with_context(|| format!("bad lambda `{part}` in --lambdas"))?,
                );
            }
            out
        }
        None => vec![10.0, 1.0, 0.1, 0.01],
    };
    if lambdas.is_empty() {
        bail!("sweep: --lambdas is empty");
    }

    let ds = libsvm::load(Path::new(data_path), task_of(&cfg), cfg.workers)
        .with_context(|| format!("loading {data_path}"))?;
    let test = args
        .get("test")
        .map(|p| libsvm::load(Path::new(p), task_of(&cfg), cfg.workers))
        .transpose()?;

    let t_setup = std::time::Instant::now();
    let mut cluster = pemsvm::engine::Cluster::new(&ds, &cfg)?;
    println!(
        "# sweep: {} lambdas on one cluster (N={} K={} P={} {:?}/{:?}), setup {:.2}s{}",
        lambdas.len(),
        ds.n,
        ds.k,
        cluster.workers(),
        cfg.backend,
        cfg.topology,
        t_setup.elapsed().as_secs_f64(),
        if cfg.warm_start { ", warm-started sessions" } else { "" }
    );
    let metric_name = if cfg.task == TaskKind::Svr { "rmse" } else { "acc" };
    println!(
        "# {:>10} {:>6} {:>14} {:>10} {:>10} {:>8}",
        "lambda", "iters", "objective", format!("train_{metric_name}"),
        format!("test_{metric_name}"), "secs"
    );
    for (i, &lambda) in lambdas.iter().enumerate() {
        let mut scfg = cfg.clone();
        scfg.lambda = lambda;
        let warm = if cfg.warm_start && i > 0 {
            pemsvm::engine::WarmStart::Last
        } else {
            pemsvm::engine::WarmStart::Cold
        };
        let t0 = std::time::Instant::now();
        // test set stays out of the session: the per-iteration held-out
        // history would be discarded here; one final evaluate suffices
        let out = cluster.run_session(&scfg, None, warm)?;
        let train_metric = pemsvm::model::evaluate(&ds, &out.weights);
        let test_metric = test.as_ref().map(|te| pemsvm::model::evaluate(te, &out.weights));
        println!(
            "  {:>10} {:>6} {:>14.4} {:>10.4} {:>10} {:>7.2}s",
            lambda,
            out.iterations,
            out.objective,
            train_metric,
            test_metric.map(|m| format!("{m:.4}")).unwrap_or_else(|| "-".into()),
            t0.elapsed().as_secs_f64()
        );
    }
    println!(
        "# cluster reused across {} sessions: threads and shards were built once",
        cluster.sessions()
    );
    Ok(())
}

fn save_weights(w: &Weights, path: &Path) -> Result<()> {
    use std::io::Write;
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    match w {
        Weights::Single(v) => {
            writeln!(f, "# pemsvm single {}", v.len())?;
            for x in v {
                writeln!(f, "{x}")?;
            }
        }
        Weights::PerClass(m) => {
            writeln!(f, "# pemsvm perclass {} {}", m.rows, m.cols)?;
            for c in 0..m.rows {
                for x in m.row(c) {
                    writeln!(f, "{x}")?;
                }
            }
        }
    }
    Ok(())
}

fn load_weights(path: &Path) -> Result<Weights> {
    let text = std::fs::read_to_string(path)?;
    let mut lines = text.lines();
    let header = lines.next().context("empty model file")?;
    let parts: Vec<&str> = header.split_whitespace().collect();
    let vals: Vec<f32> = lines.filter_map(|l| l.trim().parse().ok()).collect();
    match parts.get(2) {
        Some(&"single") => Ok(Weights::Single(vals)),
        Some(&"perclass") => {
            let rows: usize = parts[3].parse()?;
            let cols: usize = parts[4].parse()?;
            if vals.len() != rows * cols {
                bail!("model file: expected {} values, got {}", rows * cols, vals.len());
            }
            let mut m = pemsvm::linalg::Mat::zeros(rows, cols);
            m.data.copy_from_slice(&vals);
            Ok(Weights::PerClass(m))
        }
        _ => bail!("bad model header `{header}`"),
    }
}

fn cmd_eval(args: &Args) -> Result<()> {
    let (Some(data_path), Some(model_path)) =
        (args.positional.first(), args.positional.get(1))
    else {
        bail!("eval: need <data.svm> <model.txt>");
    };
    let m: usize = args.get_usize("num-classes", 10)?;
    let task = match args.get("task").unwrap_or("cls") {
        "cls" => Task::Binary,
        "svr" => Task::Regression,
        "mlt" => Task::Multiclass(m),
        t => bail!("bad task {t}"),
    };
    let ds = libsvm::load(Path::new(data_path), task, 4)?;
    let w = load_weights(Path::new(model_path))?;
    let metric = pemsvm::model::evaluate(&ds, &w);
    println!(
        "{} = {metric:.4}",
        if task == Task::Regression { "rmse" } else { "accuracy" }
    );
    Ok(())
}

fn cmd_datagen(args: &Args) -> Result<()> {
    let Some(out_path) = args.positional.first() else {
        bail!("datagen: missing <out.svm>");
    };
    let n = args.get_usize("n", 10_000)?;
    let k = args.get_usize("k", 64)?;
    let m = args.get_usize("m", 10)?;
    let seed = args.get_u64("seed", 0)?;
    let ds: Dataset = match args.get("dataset").unwrap_or("alpha") {
        "alpha" => synth::alpha_like(n, k, seed),
        "dna" => synth::dna_like(n, k, seed),
        "year" => synth::year_like(n, k, seed),
        "mnist" => synth::mnist_like(n, k, m, seed),
        "news20" => synth::news20_like(n, k, seed),
        other => bail!("unknown dataset `{other}`"),
    };
    libsvm::save(&ds, Path::new(out_path))?;
    println!("wrote {} rows x {} features to {out_path}", ds.n, ds.k);
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    #[cfg(feature = "xla")]
    {
        let dir = args.get("artifacts-dir").unwrap_or("artifacts");
        match pemsvm::runtime::Runtime::load(Path::new(dir)) {
            Ok(rt) => {
                println!(
                    "artifacts: {} graphs, chunk={}, K family {:?}, M={}",
                    rt.manifest.len(),
                    rt.chunk(),
                    rt.manifest.k_family,
                    rt.manifest.m_classes
                );
            }
            Err(e) => println!("artifacts not available at `{dir}`: {e:#}"),
        }
    }
    #[cfg(not(feature = "xla"))]
    {
        let _ = args;
        println!("artifacts runtime: built without the `xla` feature");
    }
    println!("cores: {}", std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1));
    Ok(())
}
