//! `pemsvm` — CLI for the parallel data-augmentation SVM.
//!
//! Subcommands:
//!
//! ```text
//! train <data.svm>  --options LIN-EM-CLS --workers 8 --lambda 1.0 ...
//!                   [--stream-chunk-rows R] out-of-core ingestion
//! sweep <data.svm>  --lambdas 10,1,0.1,0.01 [--warm-start] ...
//! datagen <out.svm> --dataset alpha --n 10000 --k 64 --seed 0
//! predict <data.svm> <model>  batch scoring via the serve scorer
//! serve <model...> --port N   TCP serving with micro-batching
//! eval <data.svm> <model>
//! diagnose <spans.jsonl>      convergence report from a --trace file
//! info
//! ```
//!
//! `train` writes the learned model to `--model-out` (default
//! `model.txt`) in the versioned `pemsvm-model v1` format
//! (`serve::format`) — linear weights or, for KRN runs, the kernel
//! dual model with its support vectors. `sweep` builds one persistent
//! `engine::Cluster` and runs one training session per lambda on it —
//! threads stay up and shards stay resident across solves, optionally
//! warm-starting each session from the previous solution. `predict`
//! and `serve` are the inference side (DESIGN.md §9): both load models
//! through `serve::Registry` and score through the batched
//! `serve::Scorer` pool.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use pemsvm::cli::Args;
use pemsvm::config::{ModelKind, TaskKind, TrainConfig};
use pemsvm::data::stream::{self, StreamOpts, StreamReader};
use pemsvm::data::{libsvm, synth, Dataset, Task};
use pemsvm::engine::{CheckpointCfg, Cluster, WarmStart};
use pemsvm::serve::{self, ModelBody, SavedModel, Scorer};
use pemsvm::telemetry::{self, TraceWriter};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: Vec<String>) -> Result<()> {
    if argv.is_empty() {
        print_usage();
        return Ok(());
    }
    let args = Args::parse(argv)?;
    // applies to every subcommand; the default (1 = info) keeps output
    // byte-identical to builds before the telemetry layer existed
    telemetry::log::set_verbosity(args.get_usize("verbosity", 1)? as u8);
    match args.subcommand.as_str() {
        "train" => cmd_train(&args),
        "sweep" => cmd_sweep(&args),
        "datagen" => cmd_datagen(&args),
        "predict" => cmd_predict(&args),
        "serve" => cmd_serve(&args),
        "worker" => cmd_worker(&args),
        "eval" => cmd_eval(&args),
        "diagnose" => cmd_diagnose(&args),
        "info" => cmd_info(&args),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown subcommand `{other}` (try `pemsvm help`)"),
    }
}

fn print_usage() {
    // lives in cli.rs next to the flag tables, with a drift test
    println!("{}", pemsvm::cli::USAGE);
}

fn build_config(args: &Args) -> Result<TrainConfig> {
    let mut cfg = TrainConfig::default();
    if let Some(path) = args.get("config") {
        let doc = pemsvm::config::TomlDoc::load(Path::new(path))?;
        cfg.apply_toml(&doc)?;
    }
    for (key, val) in &args.flags {
        let k = key.replace('-', "_");
        match k.as_str() {
            "simulate_cluster" => {
                bail!("--simulate-cluster was removed; use --topology threads|simulate")
            }
            k if pemsvm::cli::LOCAL_FLAGS.contains(&k) => continue,
            k if pemsvm::cli::FORWARDED_FLAGS.contains(&k) => cfg.set(k, val)?,
            other => bail!("unknown flag --{other}"),
        }
    }
    Ok(cfg)
}

fn task_of(cfg: &TrainConfig) -> Task {
    match cfg.task {
        TaskKind::Cls => Task::Binary,
        TaskKind::Svr => Task::Regression,
        TaskKind::Mlt => Task::Multiclass(cfg.num_classes),
    }
}

/// `--stream-chunk-rows R` (+ optional `--dims N,K`) parsed into the
/// streaming-ingestion options; `None` when the eager loader should run.
fn stream_opts_of(args: &Args) -> Result<Option<StreamOpts>> {
    let chunk_rows = args.get_usize("stream-chunk-rows", 0)?;
    let dims: Option<(usize, usize)> = match args.get("dims") {
        None => None,
        Some(s) => {
            let Some((n, k)) = s.split_once(',') else {
                bail!("--dims expects N,K (rows,features)");
            };
            Some((n.trim().parse()?, k.trim().parse()?))
        }
    };
    if chunk_rows == 0 {
        if dims.is_some() {
            bail!("--dims only applies with --stream-chunk-rows");
        }
        return Ok(None);
    }
    Ok(Some(StreamOpts { chunk_rows, dims, class_off: None }))
}

/// `--trace <path>`: open the iteration-span JSONL writer (DESIGN.md
/// §12); `None` when tracing is off.
fn trace_writer_of(args: &Args) -> Result<Option<TraceWriter>> {
    args.get("trace").map(|p| TraceWriter::create(Path::new(p))).transpose()
}

/// `--checkpoint every-N` / `--checkpoint-path <p>` / `--resume` parsed
/// into the session checkpoint options (DESIGN.md §13); `None` when
/// checkpointing is off. The path defaults to `<model-out>.ckpt`.
fn checkpoint_cfg_of(args: &Args) -> Result<Option<CheckpointCfg>> {
    let every_s = args.get("checkpoint");
    let resume = args.get("resume").map(|v| v != "false").unwrap_or(false);
    if every_s.is_none() && !resume {
        if args.get("checkpoint-path").is_some() {
            bail!("--checkpoint-path needs --checkpoint every-N and/or --resume");
        }
        return Ok(None);
    }
    let every = match every_s {
        None => 0, // --resume alone: continue the run, write no new checkpoints
        Some(s) => {
            let num = s.strip_prefix("every-").unwrap_or(s);
            let v: usize = num
                .parse()
                .with_context(|| format!("bad --checkpoint `{s}` (want every-N)"))?;
            if v == 0 {
                bail!("--checkpoint every-N needs N >= 1");
            }
            v
        }
    };
    let path = match args.get("checkpoint-path") {
        Some(p) => PathBuf::from(p),
        None => PathBuf::from(format!("{}.ckpt", args.get("model-out").unwrap_or("model.txt"))),
    };
    Ok(Some(CheckpointCfg { every, path, resume }))
}

/// `--metrics-out <path>`: dump the full Prometheus exposition of the
/// global telemetry registry. Prints a `#` line only when the flag is
/// present, so default CLI output stays byte-identical.
fn write_metrics_out(args: &Args) -> Result<()> {
    if let Some(p) = args.get("metrics-out") {
        std::fs::write(p, telemetry::global().render())
            .with_context(|| format!("writing {p}"))?;
        println!("# metrics written to {p}");
    }
    Ok(())
}

/// The closing `#` line for `--trace` runs (again: silent without the
/// flag).
fn report_trace(trace: &Option<TraceWriter>) {
    if let Some(tw) = trace {
        println!("# trace written to {}", tw.path().display());
    }
}

fn reject_kernel_streaming(cfg: &TrainConfig) -> Result<()> {
    if cfg.model == ModelKind::Kernel {
        bail!("--stream-chunk-rows supports LIN models (KRN materializes the Gram matrix)");
    }
    Ok(())
}

/// Per-iteration history lines shared by the eager and streamed train
/// paths.
fn print_history(out: &pemsvm::engine::TrainOutput, verbose: bool) {
    if !verbose {
        return;
    }
    for h in &out.history {
        println!(
            "iter {:>4}  J = {:<14.4} loss = {:<12.4} err = {:.4}{}",
            h.iter,
            h.objective,
            h.train_loss,
            h.train_err,
            h.test_metric.map(|m| format!("  test = {m:.4}")).unwrap_or_default()
        );
    }
}

/// Write the trained model to `--model-out` and report what was written
/// (shared tail of the eager and streamed train paths).
fn save_trained_model(
    args: &Args,
    cfg: &TrainConfig,
    k: usize,
    out: pemsvm::engine::TrainOutput,
) -> Result<()> {
    let model_out = PathBuf::from(args.get("model-out").unwrap_or("model.txt"));
    let saved = SavedModel::from_training(cfg, k, out);
    serve::save(&saved, &model_out)?;
    println!(
        "# model written to {} ({})",
        model_out.display(),
        match &saved.body {
            ModelBody::Kernel(km) => format!("kernel, {} support vectors", {
                km.omega.iter().filter(|&&o| o != 0.0).count()
            }),
            ModelBody::Linear(_) => "linear".to_string(),
        }
    );
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let Some(data_path) = args.positional.first() else {
        bail!("train: missing <data.svm>");
    };
    let cfg = build_config(args)?;
    if let Some(opts) = stream_opts_of(args)? {
        return cmd_train_streamed(args, &cfg, data_path, &opts);
    }
    let t_load = std::time::Instant::now();
    let ds = libsvm::load(Path::new(data_path), task_of(&cfg), cfg.workers)
        .with_context(|| format!("loading {data_path}"))?;
    let load_secs = t_load.elapsed().as_secs_f64();
    let test = args
        .get("test")
        .map(|p| libsvm::load(Path::new(p), task_of(&cfg), cfg.workers))
        .transpose()?;

    println!(
        "# {} on {} (N={} K={} density={:.3}) workers={} backend={:?}",
        cfg.options_string(),
        data_path,
        ds.n,
        ds.k,
        ds.density(),
        cfg.workers,
        cfg.backend
    );
    let mut trace = trace_writer_of(args)?;
    let ck = checkpoint_cfg_of(args)?;
    if let Some(c) = &ck {
        println!(
            "# checkpoint: {}{}{}",
            if c.resume { "resuming from " } else { "" },
            c.path.display(),
            if c.every > 0 { format!(", writing every {} iters", c.every) } else { String::new() }
        );
    }
    let t_train = std::time::Instant::now();
    let out = pemsvm::coordinator::train_full_checkpointed(
        &ds,
        test.as_ref(),
        &cfg,
        trace.as_mut(),
        ck.as_ref(),
    )?;
    let train_secs = t_train.elapsed().as_secs_f64();

    print_history(&out, cfg.verbose);
    println!("# load {load_secs:.2}s  train {train_secs:.2}s  iters {}", out.iterations);
    println!("# phases: {}", out.metrics.report());
    println!("# final objective {:.4}", out.objective);
    // for KRN, out.weights holds the dual omega (length N, not K) —
    // the training metric must go through the kernel model
    let train_metric = match (&out.kernel_model, cfg.model) {
        (Some(km), pemsvm::config::ModelKind::Kernel) => km.accuracy(&ds),
        _ => pemsvm::model::evaluate(&ds, &out.weights),
    };
    println!(
        "# train {} = {:.4}",
        if cfg.task == TaskKind::Svr { "rmse" } else { "accuracy" },
        train_metric
    );
    if let Some(te) = &test {
        let m = match (&out.kernel_model, cfg.model) {
            (Some(km), pemsvm::config::ModelKind::Kernel) => km.accuracy(te),
            _ => pemsvm::model::evaluate(te, &out.weights),
        };
        println!(
            "# test {} = {m:.4}",
            if cfg.task == TaskKind::Svr { "rmse" } else { "accuracy" }
        );
    }

    save_trained_model(args, &cfg, ds.k, out)?;
    report_trace(&trace);
    write_metrics_out(args)
}

/// `train --stream-chunk-rows`: out-of-core ingestion through
/// `Cluster::from_stream` (DESIGN.md §10). Parsed rows in flight are
/// bounded by two chunks, the trained weights are bit-identical to the
/// eager path for a fixed seed, and the training-set metric runs as a
/// second streamed pass so the corpus is never materialized.
fn cmd_train_streamed(
    args: &Args,
    cfg: &TrainConfig,
    data_path: &str,
    opts: &StreamOpts,
) -> Result<()> {
    reject_kernel_streaming(cfg)?;
    let test = args
        .get("test")
        .map(|p| libsvm::load(Path::new(p), task_of(cfg), cfg.workers))
        .transpose()?;
    let t_ingest = std::time::Instant::now();
    let reader = StreamReader::open(Path::new(data_path), task_of(cfg), opts)
        .with_context(|| format!("streaming {data_path}"))?;
    let (n, k, class_off) = (reader.n(), reader.k(), reader.class_off());
    println!(
        "# {} on {} (streamed: N={} K={} chunk={} rows) workers={} backend={:?}",
        cfg.options_string(),
        data_path,
        n,
        k,
        opts.chunk_rows,
        cfg.workers,
        cfg.backend
    );
    let mut cluster = Cluster::from_stream(reader, cfg)?;
    let ingest_secs = t_ingest.elapsed().as_secs_f64();
    let mut trace = trace_writer_of(args)?;
    let ck = checkpoint_cfg_of(args)?;
    let t_train = std::time::Instant::now();
    let out = cluster.run_session_checkpointed(
        cfg,
        test.as_ref(),
        WarmStart::Cold,
        trace.as_mut(),
        ck.as_ref(),
    )?;
    let train_secs = t_train.elapsed().as_secs_f64();

    print_history(&out, cfg.verbose);
    println!("# ingest {ingest_secs:.2}s  train {train_secs:.2}s  iters {}", out.iterations);
    println!("# phases: {}", out.metrics.report());
    println!("# final objective {:.4}", out.objective);
    // the metric pass reuses the known dims + offset: no second count scan
    let eval_opts =
        StreamOpts { chunk_rows: opts.chunk_rows, dims: Some((n, k)), class_off: Some(class_off) };
    let train_metric =
        stream::evaluate_streamed(Path::new(data_path), task_of(cfg), &eval_opts, &out.weights)?;
    println!("# train {} = {train_metric:.4} (second streamed pass)", metric_name(cfg.task));
    if let Some(te) = &test {
        println!(
            "# test {} = {:.4}",
            metric_name(cfg.task),
            pemsvm::model::evaluate(te, &out.weights)
        );
    }

    save_trained_model(args, cfg, k, out)?;
    report_trace(&trace);
    write_metrics_out(args)
}

/// Lambda sweep on one persistent cluster: the `engine::Cluster` is
/// built once (threads spawned, shards pinned) and then runs one
/// session per lambda — with `--warm-start`, each session starts from
/// the previous session's weights.
fn cmd_sweep(args: &Args) -> Result<()> {
    let Some(data_path) = args.positional.first() else {
        bail!("sweep: missing <data.svm>");
    };
    let cfg = build_config(args)?;
    let lambdas: Vec<f32> = match args.get("lambdas") {
        Some(list) => {
            let mut out = Vec::new();
            for part in list.split(',') {
                out.push(
                    part.trim()
                        .parse()
                        .with_context(|| format!("bad lambda `{part}` in --lambdas"))?,
                );
            }
            out
        }
        None => vec![10.0, 1.0, 0.1, 0.01],
    };
    if lambdas.is_empty() {
        bail!("sweep: --lambdas is empty");
    }
    let stream_opts = stream_opts_of(args)?;
    if stream_opts.is_some() {
        reject_kernel_streaming(&cfg)?;
    }

    let test = args
        .get("test")
        .map(|p| libsvm::load(Path::new(p), task_of(&cfg), cfg.workers))
        .transpose()?;

    let t_setup = std::time::Instant::now();
    // eager_ds is None in streaming mode: per-lambda train metrics then
    // run as streamed passes instead of over a materialized dataset
    let (mut cluster, n, k, class_off, eager_ds) = match &stream_opts {
        Some(opts) => {
            let reader = StreamReader::open(Path::new(data_path), task_of(&cfg), opts)
                .with_context(|| format!("streaming {data_path}"))?;
            let (n, k, off) = (reader.n(), reader.k(), reader.class_off());
            (Cluster::from_stream(reader, &cfg)?, n, k, off, None)
        }
        None => {
            let ds = libsvm::load(Path::new(data_path), task_of(&cfg), cfg.workers)
                .with_context(|| format!("loading {data_path}"))?;
            let (n, k) = (ds.n, ds.k);
            (Cluster::new(&ds, &cfg)?, n, k, 0.0, Some(ds))
        }
    };
    println!(
        "# sweep: {} lambdas on one cluster (N={n} K={k} P={} {:?}/{:?}), setup {:.2}s{}{}",
        lambdas.len(),
        cluster.workers(),
        cfg.backend,
        cfg.topology,
        t_setup.elapsed().as_secs_f64(),
        if cfg.warm_start { ", warm-started sessions" } else { "" },
        match &stream_opts {
            Some(o) => format!(", streamed ingest ({} rows/chunk)", o.chunk_rows),
            None => String::new(),
        }
    );
    // per-lambda streamed metric passes reuse the known dims + offset
    // (no rescans of the corpus)
    let eval_opts = stream_opts.as_ref().map(|o| StreamOpts {
        chunk_rows: o.chunk_rows,
        dims: Some((n, k)),
        class_off: Some(class_off),
    });
    let metric_name = if cfg.task == TaskKind::Svr { "rmse" } else { "acc" };
    println!(
        "# {:>10} {:>6} {:>14} {:>10} {:>10} {:>8}",
        "lambda", "iters", "objective", format!("train_{metric_name}"),
        format!("test_{metric_name}"), "secs"
    );
    let mut trace = trace_writer_of(args)?;
    for (i, &lambda) in lambdas.iter().enumerate() {
        let mut scfg = cfg.clone();
        scfg.lambda = lambda;
        let warm = if cfg.warm_start && i > 0 {
            pemsvm::engine::WarmStart::Last
        } else {
            pemsvm::engine::WarmStart::Cold
        };
        // one session per lambda in the trace stream, distinguished by
        // the record's `session` field
        if let Some(tw) = trace.as_mut() {
            tw.set_session(i);
        }
        let t0 = std::time::Instant::now();
        // test set stays out of the session: the per-iteration held-out
        // history would be discarded here; one final evaluate suffices
        let out = cluster.run_session_traced(&scfg, None, warm, trace.as_mut())?;
        let train_metric = match &eager_ds {
            Some(ds) => pemsvm::model::evaluate(ds, &out.weights),
            None => stream::evaluate_streamed(
                Path::new(data_path),
                task_of(&cfg),
                eval_opts.as_ref().unwrap(),
                &out.weights,
            )?,
        };
        let test_metric = test.as_ref().map(|te| pemsvm::model::evaluate(te, &out.weights));
        println!(
            "  {:>10} {:>6} {:>14.4} {:>10.4} {:>10} {:>7.2}s",
            lambda,
            out.iterations,
            out.objective,
            train_metric,
            test_metric.map(|m| format!("{m:.4}")).unwrap_or_else(|| "-".into()),
            t0.elapsed().as_secs_f64()
        );
    }
    println!(
        "# cluster reused across {} sessions: threads and shards were built once",
        cluster.sessions()
    );
    report_trace(&trace);
    write_metrics_out(args)
}

/// Load a model for the inference subcommands, letting `--task` /
/// `--num-classes` override the header of a legacy `model.txt` (the
/// old format carried neither).
fn load_model_for(args: &Args) -> Result<SavedModel> {
    let Some(model_path) = args.positional.get(1) else {
        bail!("need <data.svm> <model>");
    };
    let mut model = serve::load(Path::new(model_path))?;
    if model.meta.legacy {
        if let Some(t) = args.get("task") {
            model.meta.task = match t {
                "cls" => TaskKind::Cls,
                "svr" => TaskKind::Svr,
                "mlt" => TaskKind::Mlt,
                t => bail!("bad task {t}"),
            };
        }
        if model.meta.task == TaskKind::Mlt {
            model.meta.m = args.get_usize("num-classes", model.meta.m)?;
        }
    }
    Ok(model)
}

fn metric_name(task: TaskKind) -> &'static str {
    if task == TaskKind::Svr {
        "rmse"
    } else {
        "accuracy"
    }
}

/// Batch scoring through the serve scorer: predictions one per line
/// (stdout or --out), metric + throughput as trailing `#` lines.
fn cmd_predict(args: &Args) -> Result<()> {
    let Some(data_path) = args.positional.first() else {
        bail!("predict: need <data.svm> <model>");
    };
    let model = Arc::new(load_model_for(args)?);
    let workers = args.get_usize("workers", 4)?;
    let ds = Arc::new(
        libsvm::load(Path::new(data_path), model.data_task(), workers)
            .with_context(|| format!("loading {data_path}"))?,
    );
    let mut scorer = Scorer::new(workers);
    let out = scorer.score_batch(&model, &ds)?;
    let task = model.meta.task;

    let mut text = String::new();
    for &s in &out.scores {
        text.push_str(&serve::format_prediction(task, s));
        text.push('\n');
    }
    let metric = serve::metric_of(task, &ds.labels, &out.scores);
    let secs = out.wall.as_secs_f64();
    let summary = format!(
        "# {} = {metric:.4}\n# rows {} in {:.3}s ({:.0} rows/s, {} workers, compute max {:.3}s)\n",
        metric_name(task),
        ds.n,
        secs,
        ds.n as f64 / secs.max(1e-12),
        workers,
        out.compute_max.as_secs_f64(),
    );
    match args.get("out") {
        Some(p) => {
            std::fs::write(p, &text).with_context(|| format!("writing {p}"))?;
            print!("{summary}");
            println!("# predictions written to {p}");
        }
        None => {
            print!("{text}{summary}");
        }
    }
    Ok(())
}

/// TCP serving front-end over the registry + scorer.
fn cmd_serve(args: &Args) -> Result<()> {
    if args.positional.is_empty() {
        bail!("serve: need at least one <model> path");
    }
    let registry = Arc::new(pemsvm::serve::Registry::new());
    let mut default_model = String::new();
    for p in &args.positional {
        let path = Path::new(p);
        let name = path
            .file_stem()
            .and_then(|s| s.to_str())
            .with_context(|| format!("bad model path {p}"))?
            .to_string();
        if registry.get(&name).is_some() {
            bail!(
                "duplicate model name `{name}` (from {p}); registry names come from file \
                 stems, so serve files with distinct stems"
            );
        }
        registry.load_file(&name, path)?;
        if default_model.is_empty() {
            default_model = name;
        }
    }
    let opts = pemsvm::serve::ServeOpts {
        max_batch: args.get_usize("max-batch", 256)?,
        max_wait: std::time::Duration::from_micros(args.get_u64("max-wait-us", 1000)?),
        workers: args.get_usize("workers", 4)?,
    };
    let port = args.get_u16("port", 7878)?;
    let listener = std::net::TcpListener::bind(("127.0.0.1", port))
        .with_context(|| format!("binding 127.0.0.1:{port}"))?;
    let addr = listener.local_addr()?;
    println!(
        "# serving {:?} (default `{default_model}`), workers={} max_batch={} max_wait_us={}",
        registry.names(),
        opts.workers,
        opts.max_batch,
        opts.max_wait.as_micros()
    );
    // scripts parse this line for the ephemeral port (--port 0)
    println!("# listening on {addr}");
    pemsvm::serve::serve(listener, registry, default_model, opts)
}

/// `pemsvm worker --listen host:port`: one training-worker daemon for a
/// `--hosts` coordinator (DESIGN.md §15). Serves one coordinator
/// session at a time; all shard data and config arrive over the wire.
fn cmd_worker(args: &Args) -> Result<()> {
    let Some(listen) = args.get("listen") else {
        bail!("worker: missing --listen host:port (e.g. --listen 127.0.0.1:7001)");
    };
    let once = args.get("once").map(|v| v != "false").unwrap_or(false);
    let listener = std::net::TcpListener::bind(listen)
        .with_context(|| format!("binding {listen}"))?;
    // scripts parse this line for the ephemeral port (--listen host:0),
    // mirroring serve's `# listening on ...`
    println!("# worker listening on {}", listener.local_addr()?);
    pemsvm::net::worker::run(listener, once)
}

fn cmd_eval(args: &Args) -> Result<()> {
    let Some(data_path) = args.positional.first() else {
        bail!("eval: need <data.svm> <model>");
    };
    let model = Arc::new(load_model_for(args)?);
    let workers = args.get_usize("workers", 4)?;
    let ds = Arc::new(libsvm::load(Path::new(data_path), model.data_task(), workers)?);
    let mut scorer = Scorer::new(workers);
    let out = scorer.score_batch(&model, &ds)?;
    let metric = serve::metric_of(model.meta.task, &ds.labels, &out.scores);
    println!("{} = {metric:.4}", metric_name(model.meta.task));
    Ok(())
}

/// `pemsvm diagnose <spans.jsonl>`: offline convergence report over a
/// `--trace` file (DESIGN.md §14). Estimators are recomputed with the
/// brute-force reference implementations; embedded per-iteration `diag`
/// objects (from `--diag-every` runs) are surfaced for cross-checking.
fn cmd_diagnose(args: &Args) -> Result<()> {
    let Some(trace_path) = args.positional.first() else {
        bail!("diagnose: missing <spans.jsonl> (produced by train/sweep --trace)");
    };
    let burn_in = args.get_usize("burn-in", 0)?;
    print!("{}", pemsvm::diag_report::report(Path::new(trace_path), burn_in)?);
    Ok(())
}

fn cmd_datagen(args: &Args) -> Result<()> {
    let Some(out_path) = args.positional.first() else {
        bail!("datagen: missing <out.svm>");
    };
    let n = args.get_usize("n", 10_000)?;
    let k = args.get_usize("k", 64)?;
    let m = args.get_usize("m", 10)?;
    let seed = args.get_u64("seed", 0)?;
    let ds: Dataset = match args.get("dataset").unwrap_or("alpha") {
        "alpha" => synth::alpha_like(n, k, seed),
        "dna" => synth::dna_like(n, k, seed),
        "year" => synth::year_like(n, k, seed),
        "mnist" => synth::mnist_like(n, k, m, seed),
        "news20" => synth::news20_like(n, k, seed),
        other => bail!("unknown dataset `{other}`"),
    };
    libsvm::save(&ds, Path::new(out_path))?;
    println!("wrote {} rows x {} features to {out_path}", ds.n, ds.k);
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    #[cfg(feature = "xla")]
    {
        let dir = args.get("artifacts-dir").unwrap_or("artifacts");
        match pemsvm::runtime::Runtime::load(Path::new(dir)) {
            Ok(rt) => {
                println!(
                    "artifacts: {} graphs, chunk={}, K family {:?}, M={}",
                    rt.manifest.len(),
                    rt.chunk(),
                    rt.manifest.k_family,
                    rt.manifest.m_classes
                );
            }
            Err(e) => println!("artifacts not available at `{dir}`: {e:#}"),
        }
    }
    #[cfg(not(feature = "xla"))]
    {
        let _ = args;
        println!("artifacts runtime: built without the `xla` feature");
    }
    println!("cores: {}", std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1));
    Ok(())
}
