//! `pemsvm` — CLI for the parallel data-augmentation SVM.
//!
//! Subcommands:
//!   train <data.svm>  --options LIN-EM-CLS --workers 8 --lambda 1.0 ...
//!   datagen <out.svm> --dataset alpha --n 10000 --k 64 --seed 0
//!   eval <data.svm> <model.txt>
//!   info
//!
//! `train` writes the learned weights to `--model-out` (default
//! `model.txt`, one weight per line; M blocks for multiclass).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use pemsvm::cli::Args;
use pemsvm::config::{TaskKind, TrainConfig};
use pemsvm::data::{libsvm, synth, Dataset, Task};
use pemsvm::model::Weights;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: Vec<String>) -> Result<()> {
    if argv.is_empty() {
        print_usage();
        return Ok(());
    }
    let args = Args::parse(argv)?;
    match args.subcommand.as_str() {
        "train" => cmd_train(&args),
        "datagen" => cmd_datagen(&args),
        "eval" => cmd_eval(&args),
        "info" => cmd_info(&args),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown subcommand `{other}` (try `pemsvm help`)"),
    }
}

fn print_usage() {
    println!(
        "pemsvm — Fast Parallel SVM using Data Augmentation (Perkins et al. 2015)

USAGE:
  pemsvm train <data.svm> [--options LIN-EM-CLS] [--workers P] [--lambda L]
               [--backend native|xla] [--reduce flat|tree] [--max-iters I]
               [--tol T] [--seed S] [--num-classes M] [--model-out model.txt]
               [--config file.toml] [--test test.svm] [--verbose]
  pemsvm datagen <out.svm> --dataset alpha|dna|year|mnist|news20
               [--n N] [--k K] [--m M] [--seed S]
  pemsvm eval <data.svm> <model.txt> [--task cls|svr|mlt] [--num-classes M]
  pemsvm info [--artifacts-dir artifacts]"
    );
}

fn build_config(args: &Args) -> Result<TrainConfig> {
    let mut cfg = TrainConfig::default();
    if let Some(path) = args.get("config") {
        let doc = pemsvm::config::TomlDoc::load(Path::new(path))?;
        cfg.apply_toml(&doc)?;
    }
    for (key, val) in &args.flags {
        let k = key.replace('-', "_");
        match k.as_str() {
            "config" | "model_out" | "test" => continue,
            "max_iters" | "options" | "lambda" | "workers" | "seed" | "tol" | "backend"
            | "reduce" | "burn_in" | "num_classes" | "eps_clamp" | "eps_insensitive"
            | "artifacts_dir" | "verbose" | "kernel" | "kernel_sigma" | "algo" | "task"
            | "model" => cfg.set(&k, val)?,
            other => bail!("unknown flag --{other}"),
        }
    }
    Ok(cfg)
}

fn task_of(cfg: &TrainConfig) -> Task {
    match cfg.task {
        TaskKind::Cls => Task::Binary,
        TaskKind::Svr => Task::Regression,
        TaskKind::Mlt => Task::Multiclass(cfg.num_classes),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let Some(data_path) = args.positional.first() else {
        bail!("train: missing <data.svm>");
    };
    let cfg = build_config(args)?;
    let t_load = std::time::Instant::now();
    let ds = libsvm::load(Path::new(data_path), task_of(&cfg), cfg.workers)
        .with_context(|| format!("loading {data_path}"))?;
    let load_secs = t_load.elapsed().as_secs_f64();
    let test = args
        .get("test")
        .map(|p| libsvm::load(Path::new(p), task_of(&cfg), cfg.workers))
        .transpose()?;

    println!(
        "# {} on {} (N={} K={} density={:.3}) workers={} backend={:?}",
        cfg.options_string(),
        data_path,
        ds.n,
        ds.k,
        ds.density(),
        cfg.workers,
        cfg.backend
    );
    let t_train = std::time::Instant::now();
    let out = pemsvm::coordinator::train_full(&ds, test.as_ref(), &cfg)?;
    let train_secs = t_train.elapsed().as_secs_f64();

    if cfg.verbose {
        for h in &out.history {
            println!(
                "iter {:>4}  J = {:<14.4} loss = {:<12.4} err = {:.4}{}",
                h.iter,
                h.objective,
                h.train_loss,
                h.train_err,
                h.test_metric.map(|m| format!("  test = {m:.4}")).unwrap_or_default()
            );
        }
    }
    println!("# load {load_secs:.2}s  train {train_secs:.2}s  iters {}", out.iterations);
    println!("# phases: {}", out.metrics.report());
    println!("# final objective {:.4}", out.objective);
    let train_metric = pemsvm::model::evaluate(&ds, &out.weights);
    println!(
        "# train {} = {:.4}",
        if cfg.task == TaskKind::Svr { "rmse" } else { "accuracy" },
        train_metric
    );
    if let Some(te) = &test {
        let m = match (&out.kernel_model, cfg.model) {
            (Some(km), pemsvm::config::ModelKind::Kernel) => km.accuracy(te),
            _ => pemsvm::model::evaluate(te, &out.weights),
        };
        println!(
            "# test {} = {m:.4}",
            if cfg.task == TaskKind::Svr { "rmse" } else { "accuracy" }
        );
    }

    let model_out = PathBuf::from(args.get("model-out").unwrap_or("model.txt"));
    save_weights(&out.weights, &model_out)?;
    println!("# model written to {}", model_out.display());
    Ok(())
}

fn save_weights(w: &Weights, path: &Path) -> Result<()> {
    use std::io::Write;
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    match w {
        Weights::Single(v) => {
            writeln!(f, "# pemsvm single {}", v.len())?;
            for x in v {
                writeln!(f, "{x}")?;
            }
        }
        Weights::PerClass(m) => {
            writeln!(f, "# pemsvm perclass {} {}", m.rows, m.cols)?;
            for c in 0..m.rows {
                for x in m.row(c) {
                    writeln!(f, "{x}")?;
                }
            }
        }
    }
    Ok(())
}

fn load_weights(path: &Path) -> Result<Weights> {
    let text = std::fs::read_to_string(path)?;
    let mut lines = text.lines();
    let header = lines.next().context("empty model file")?;
    let parts: Vec<&str> = header.split_whitespace().collect();
    let vals: Vec<f32> = lines.filter_map(|l| l.trim().parse().ok()).collect();
    match parts.get(2) {
        Some(&"single") => Ok(Weights::Single(vals)),
        Some(&"perclass") => {
            let rows: usize = parts[3].parse()?;
            let cols: usize = parts[4].parse()?;
            if vals.len() != rows * cols {
                bail!("model file: expected {} values, got {}", rows * cols, vals.len());
            }
            let mut m = pemsvm::linalg::Mat::zeros(rows, cols);
            m.data.copy_from_slice(&vals);
            Ok(Weights::PerClass(m))
        }
        _ => bail!("bad model header `{header}`"),
    }
}

fn cmd_eval(args: &Args) -> Result<()> {
    let (Some(data_path), Some(model_path)) =
        (args.positional.first(), args.positional.get(1))
    else {
        bail!("eval: need <data.svm> <model.txt>");
    };
    let m: usize = args.get_usize("num-classes", 10)?;
    let task = match args.get("task").unwrap_or("cls") {
        "cls" => Task::Binary,
        "svr" => Task::Regression,
        "mlt" => Task::Multiclass(m),
        t => bail!("bad task {t}"),
    };
    let ds = libsvm::load(Path::new(data_path), task, 4)?;
    let w = load_weights(Path::new(model_path))?;
    let metric = pemsvm::model::evaluate(&ds, &w);
    println!(
        "{} = {metric:.4}",
        if task == Task::Regression { "rmse" } else { "accuracy" }
    );
    Ok(())
}

fn cmd_datagen(args: &Args) -> Result<()> {
    let Some(out_path) = args.positional.first() else {
        bail!("datagen: missing <out.svm>");
    };
    let n = args.get_usize("n", 10_000)?;
    let k = args.get_usize("k", 64)?;
    let m = args.get_usize("m", 10)?;
    let seed = args.get_u64("seed", 0)?;
    let ds: Dataset = match args.get("dataset").unwrap_or("alpha") {
        "alpha" => synth::alpha_like(n, k, seed),
        "dna" => synth::dna_like(n, k, seed),
        "year" => synth::year_like(n, k, seed),
        "mnist" => synth::mnist_like(n, k, m, seed),
        "news20" => synth::news20_like(n, k, seed),
        other => bail!("unknown dataset `{other}`"),
    };
    libsvm::save(&ds, Path::new(out_path))?;
    println!("wrote {} rows x {} features to {out_path}", ds.n, ds.k);
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args.get("artifacts-dir").unwrap_or("artifacts");
    match pemsvm::runtime::Runtime::load(Path::new(dir)) {
        Ok(rt) => {
            println!(
                "artifacts: {} graphs, chunk={}, K family {:?}, M={}",
                rt.manifest.len(),
                rt.chunk(),
                rt.manifest.k_family,
                rt.manifest.m_classes
            );
        }
        Err(e) => println!("artifacts not available at `{dir}`: {e:#}"),
    }
    println!("cores: {}", std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1));
    Ok(())
}
