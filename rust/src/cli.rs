//! Hand-rolled CLI argument parsing (no `clap` in the offline registry).
//!
//! Grammar: `pemsvm <subcommand> [positional ...] [--key value | --key=value | --flag]`.
//!
//! [`Args`] only tokenizes: subcommand, positionals, and a flat
//! `--key value` map (a flag followed by another `--flag` or by
//! nothing parses as boolean `"true"`). Interpretation — which keys
//! exist, their types and defaults — lives with each subcommand in
//! `main.rs`, and training-relevant keys are forwarded to
//! [`TrainConfig::set`](crate::config::TrainConfig::set) so the CLI,
//! TOML config files, and programmatic use all share one
//! string-keyed surface.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        let Some(sub) = it.next() else {
            bail!("missing subcommand");
        };
        out.subcommand = sub;
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.flags.insert(name.to_string(), it.next().unwrap());
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn get_f32(&self, key: &str, default: f32) -> Result<f32> {
        match self.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    /// Port-sized flag (`--port 0` means "pick an ephemeral port").
    pub fn get_u16(&self, key: &str, default: u16) -> Result<u16> {
        match self.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_positional_flags() {
        let a = parse("train data.svm --workers 8 --lambda=0.5 --verbose");
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.positional, vec!["data.svm"]);
        assert_eq!(a.get("workers"), Some("8"));
        assert_eq!(a.get("lambda"), Some("0.5"));
        assert_eq!(a.get("verbose"), Some("true"));
        assert_eq!(a.get_usize("workers", 1).unwrap(), 8);
        assert_eq!(a.get_f32("lambda", 1.0).unwrap(), 0.5);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        let s = parse("serve m.txt --port 0");
        assert_eq!(s.get_u16("port", 7878).unwrap(), 0);
        assert_eq!(s.get_u16("missing", 7878).unwrap(), 7878);
        assert!(parse("serve --port 70000").get_u16("port", 0).is_err());
    }

    #[test]
    fn missing_subcommand_rejected() {
        assert!(Args::parse(std::iter::empty()).is_err());
    }

    #[test]
    fn negative_number_as_value() {
        let a = parse("train --lambda -0.5");
        // "-0.5" doesn't start with -- so it's consumed as the value
        assert_eq!(a.get("lambda"), Some("-0.5"));
    }
}
