//! Hand-rolled CLI argument parsing (no `clap` in the offline registry).
//!
//! Grammar: `pemsvm <subcommand> [positional ...] [--key value | --key=value | --flag]`.
//!
//! [`Args`] only tokenizes: subcommand, positionals, and a flat
//! `--key value` map (a flag followed by another `--flag` or by
//! nothing parses as boolean `"true"`). Interpretation — which keys
//! exist, their types and defaults — lives with each subcommand in
//! `main.rs`, and training-relevant keys are forwarded to
//! [`TrainConfig::set`](crate::config::TrainConfig::set) so the CLI,
//! TOML config files, and programmatic use all share one
//! string-keyed surface.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        let Some(sub) = it.next() else {
            bail!("missing subcommand");
        };
        out.subcommand = sub;
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.flags.insert(name.to_string(), it.next().unwrap());
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn get_f32(&self, key: &str, default: f32) -> Result<f32> {
        match self.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    /// Port-sized flag (`--port 0` means "pick an ephemeral port").
    pub fn get_u16(&self, key: &str, default: u16) -> Result<u16> {
        match self.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }
}

/// Every dispatched subcommand, in `main.rs` dispatch order. The usage
/// test below holds [`USAGE`] to this list, so adding a subcommand
/// without documenting it fails `cargo test`.
pub const SUBCOMMANDS: &[&str] = &[
    "train", "sweep", "datagen", "predict", "serve", "worker", "eval", "diagnose", "info", "help",
];

/// Flags `build_config` forwards to
/// [`TrainConfig::set`](crate::config::TrainConfig::set), underscored
/// the way `set` expects its keys.
pub const FORWARDED_FLAGS: &[&str] = &[
    "algo",
    "artifacts_dir",
    "backend",
    "burn_in",
    "diag_every",
    "eps_clamp",
    "eps_insensitive",
    "hosts",
    "kernel",
    "kernel_sigma",
    "lambda",
    "max_iters",
    "model",
    "num_classes",
    "options",
    "reduce",
    "seed",
    "step_retries",
    "step_timeout_ms",
    "task",
    "tol",
    "topology",
    "verbose",
    "warm_start",
    "workers",
];

/// Flags the train/sweep front-end interprets itself rather than
/// forwarding to `TrainConfig` (underscored like [`FORWARDED_FLAGS`],
/// so `build_config` can use one membership test for both).
pub const LOCAL_FLAGS: &[&str] = &[
    "checkpoint",
    "checkpoint_path",
    "config",
    "dims",
    "lambdas",
    "metrics_out",
    "model_out",
    "resume",
    "stream_chunk_rows",
    "test",
    "trace",
    "verbosity",
];

/// Subcommand-local flags that never reach `TrainConfig` (datagen,
/// predict, serve, worker extras), kebab-case as typed.
pub const EXTRA_FLAGS: &[&str] =
    &["dataset", "k", "listen", "m", "max-batch", "max-wait-us", "n", "once", "out", "port"];

/// The `pemsvm help` text. Kept here, next to the flag tables above,
/// with a test asserting every registered subcommand and flag appears —
/// usage text drifts otherwise (it did: `--kernel` advertised an `rbf`
/// value the parser never accepted).
pub const USAGE: &str = "\
pemsvm — Fast Parallel SVM using Data Augmentation (Perkins et al. 2015)

USAGE:
  pemsvm train <data.svm> [--options LIN-EM-CLS] [--workers P] [--lambda L]
               [--backend native|xla] [--reduce flat|tree] [--max-iters I]
               [--tol T] [--seed S] [--num-classes M] [--model-out model.txt]
               [--config file.toml] [--test test.svm] [--verbose]
               [--topology threads|simulate] [--hosts h1:p,h2:p]
               [--stream-chunk-rows R] [--dims N,K]
               [--trace spans.jsonl] [--metrics-out metrics.prom]
               [--verbosity 0|1|2] [--diag-every N]
               [--checkpoint every-N] [--checkpoint-path run.ckpt] [--resume]
               [--step-timeout-ms T] [--step-retries R]
               [--algo em|mc] [--task cls|svr|mlt] [--model lin|krn]
               [--burn-in B] [--kernel gaussian|linear] [--kernel-sigma S]
               [--eps-clamp E] [--eps-insensitive E]
               [--artifacts-dir artifacts]
               --options bundles --model/--algo/--task (LIN-EM-CLS);
               the split flags override individual parts. --burn-in
               discards the first B MC iterations from the running
               average (and from the diagnostics chains)
               --hosts a:port,b:port trains over TCP against that many
               `pemsvm worker` daemons (one host:port per worker,
               DESIGN.md §15) — bit-identical to --topology threads;
               --step-timeout-ms doubles as the socket read timeout, and
               a dead connection follows the same retry→evict path as a
               local straggler
               --checkpoint every-N writes the full session state
               (weights, sampler RNG streams, stopping rule) atomically
               every N iterations to --checkpoint-path (default
               <model-out>.ckpt); --resume continues a killed run from
               it **bit-identically**. --step-timeout-ms/--step-retries
               bound the per-round wait on a worker before it is retried
               and then evicted (its rows re-shard onto survivors)
               --trace writes one JSON line per training iteration
               (phase timings, objective, weight-delta norm);
               --metrics-out dumps the Prometheus exposition of the
               process telemetry registry after training;
               --verbosity gates diagnostic stderr (0 quiet, 1 default,
               2 debug)
               --diag-every N feeds the online convergence diagnostics
               (ESS, split-Rhat, MCSE, health verdict — DESIGN.md §14)
               every N iterations; with --trace, each observed record
               carries a `diag` object, and the model header records
               the final session verdict. 0 (default) disables
               --stream-chunk-rows streams ingestion in R-row chunks:
               no file-sized text buffer or duplicate dataset copy,
               loader buffers bounded at 2R parsed rows, and trained
               weights bit-identical to the eager path. --dims declares
               rows,features up front, skipping the counting pass for
               CLS/SVR (MLT still scans once to detect 0/1-based class
               ids). LIN models, native backend
               --artifacts-dir points the xla backend at its compiled
               artifact directory (default `artifacts`)
  pemsvm sweep <data.svm> [--lambdas 10,1,0.1,0.01] [--warm-start]
               [--test test.svm] [--stream-chunk-rows R] [--dims N,K]
               [--trace spans.jsonl] [--metrics-out metrics.prom]
               [train flags...]
               --trace tags each lambda's records with its session index
  pemsvm datagen <out.svm> --dataset alpha|dna|year|mnist|news20
               [--n N] [--k K] [--m M] [--seed S]
  pemsvm predict <data.svm> <model> [--workers P] [--out preds.txt]
               predictions one per line (stdout unless --out); `#` lines
               carry the metric and throughput
  pemsvm serve <model...> [--port N] [--workers P] [--max-batch B]
               [--max-wait-us U]
               newline-delimited libsvm rows over TCP; --port 0 picks an
               ephemeral port (printed on stdout). `#model <name>`,
               `#stats`, `#health` (training verdict + live latency
               p50/p90/p99) and `#metrics` (Prometheus exposition, ends
               at `# EOF`) are in-band control lines
  pemsvm worker --listen host:port [--once]
               host one training worker for a --hosts coordinator: the
               daemon receives its shard and config over the wire
               protocol, executes solver steps remotely, and serves one
               coordinator session at a time. --listen host:0 picks an
               ephemeral port (printed as `# worker listening on ...`);
               --once exits after the first session ends (tests, CI)
  pemsvm eval <data.svm> <model> [--task cls|svr|mlt] [--num-classes M]
               [--workers P]
  pemsvm diagnose <spans.jsonl> [--burn-in B]
               convergence report from a --trace file: per-session ESS,
               integrated autocorrelation time, split-Rhat, MCSE,
               objective sparklines and a health verdict. --burn-in
               drops the first B iterations of each session (traces do
               not record the training burn-in)
  pemsvm info [--artifacts-dir artifacts]
  pemsvm help";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_positional_flags() {
        let a = parse("train data.svm --workers 8 --lambda=0.5 --verbose");
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.positional, vec!["data.svm"]);
        assert_eq!(a.get("workers"), Some("8"));
        assert_eq!(a.get("lambda"), Some("0.5"));
        assert_eq!(a.get("verbose"), Some("true"));
        assert_eq!(a.get_usize("workers", 1).unwrap(), 8);
        assert_eq!(a.get_f32("lambda", 1.0).unwrap(), 0.5);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        let s = parse("serve m.txt --port 0");
        assert_eq!(s.get_u16("port", 7878).unwrap(), 0);
        assert_eq!(s.get_u16("missing", 7878).unwrap(), 7878);
        assert!(parse("serve --port 70000").get_u16("port", 0).is_err());
    }

    #[test]
    fn missing_subcommand_rejected() {
        assert!(Args::parse(std::iter::empty()).is_err());
    }

    #[test]
    fn negative_number_as_value() {
        let a = parse("train --lambda -0.5");
        // "-0.5" doesn't start with -- so it's consumed as the value
        assert_eq!(a.get("lambda"), Some("-0.5"));
    }

    /// The drift guard: every registered subcommand and every flag the
    /// binary accepts must appear in the help text.
    #[test]
    fn usage_lists_every_subcommand_and_flag() {
        for sub in SUBCOMMANDS {
            assert!(
                USAGE.contains(&format!("pemsvm {sub}")),
                "usage drift: subcommand `{sub}` missing from USAGE"
            );
        }
        for key in FORWARDED_FLAGS.iter().chain(LOCAL_FLAGS) {
            let flag = format!("--{}", key.replace('_', "-"));
            assert!(USAGE.contains(&flag), "usage drift: {flag} missing from USAGE");
        }
        for key in EXTRA_FLAGS {
            assert!(USAGE.contains(&format!("--{key}")), "usage drift: --{key} missing");
        }
        // the lists themselves stay sorted so membership diffs are easy
        // to read in review
        for list in [FORWARDED_FLAGS, LOCAL_FLAGS, EXTRA_FLAGS] {
            let mut sorted = list.to_vec();
            sorted.sort_unstable();
            assert_eq!(sorted, list, "flag table out of order");
        }
    }
}
