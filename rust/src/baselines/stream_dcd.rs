//! StreamSVM / SDB-lite: out-of-core blocked dual coordinate descent.
//!
//! StreamSVM (Matsushima et al. 2012) keeps a small in-memory working
//! block and streams the rest from disk through a reader thread; SDB
//! (Chang & Roth 2011) selects blocks by violation. We model both:
//! the dataset is split into `blocks`; each outer pass loads one block
//! (optionally *re-reading it from a libsvm file* to pay real I/O like
//! the original) and runs `inner_epochs` of DCD on it while the dual
//! state persists across blocks. `selective` biases block order by the
//! violation observed last pass (the SDB heuristic).

use std::path::PathBuf;

use anyhow::Result;

use crate::data::{libsvm, Dataset, Task};
use crate::rng::Pcg64;

pub struct StreamDcdCfg {
    /// PEMSVM-scale lambda; C = 2/lambda
    pub lambda: f32,
    pub blocks: usize,
    pub passes: usize,
    pub inner_epochs: usize,
    /// SDB mode: order blocks by last-seen violation
    pub selective: bool,
    /// when set, stream blocks from this libsvm file instead of RAM
    /// (pays parse cost per visit, like the real systems pay disk I/O)
    pub stream_from: Option<PathBuf>,
    pub seed: u64,
}

impl Default for StreamDcdCfg {
    fn default() -> Self {
        StreamDcdCfg {
            lambda: 1.0,
            blocks: 8,
            passes: 6,
            inner_epochs: 3,
            selective: false,
            stream_from: None,
            seed: 0,
        }
    }
}

pub fn train(ds: &Dataset, cfg: &StreamDcdCfg) -> Result<Vec<f32>> {
    let n = ds.n;
    let c = 2.0 / cfg.lambda;
    let nb = cfg.blocks.max(1).min(n.max(1));
    let bounds: Vec<(usize, usize)> = (0..nb)
        .map(|b| (n * b / nb, n * (b + 1) / nb))
        .collect();
    let mut w = vec![0f32; ds.k];
    let mut alpha = vec![0f32; n];
    let mut block_viol = vec![f32::INFINITY; nb];
    let mut g = Pcg64::new_stream(cfg.seed, 0x57e);

    for _ in 0..cfg.passes {
        // block visit order
        let mut order: Vec<usize> = (0..nb).collect();
        if cfg.selective {
            order.sort_by(|&a, &b| block_viol[b].total_cmp(&block_viol[a]));
        } else {
            g.shuffle(&mut order);
        }
        for &b in &order {
            let (lo, hi) = bounds[b];
            // "load" the block: either slice RAM or re-parse from disk
            let owned_block;
            let block: &Dataset = match &cfg.stream_from {
                Some(path) => {
                    let full = libsvm::load(path, Task::Binary, 1)?;
                    owned_block = full.subset_rows(hi).subset_rows_from(lo);
                    &owned_block
                }
                None => ds,
            };
            let (blo, bhi) = if cfg.stream_from.is_some() { (0, hi - lo) } else { (lo, hi) };
            let mut viol = 0f32;
            for _ in 0..cfg.inner_epochs {
                for d_local in blo..bhi {
                    let d_global = if cfg.stream_from.is_some() { lo + d_local } else { d_local };
                    let q = block.row_norm_sq(d_local);
                    if q == 0.0 {
                        continue;
                    }
                    let y = block.labels[d_local];
                    let grad = y * block.dot_row(d_local, &w) - 1.0;
                    let a_old = alpha[d_global];
                    let pg = if a_old <= 0.0 {
                        grad.min(0.0)
                    } else if a_old >= c {
                        grad.max(0.0)
                    } else {
                        grad
                    };
                    viol = viol.max(pg.abs());
                    let a_new = (a_old - grad / q).clamp(0.0, c);
                    let delta = (a_new - a_old) * y;
                    if delta != 0.0 {
                        alpha[d_global] = a_new;
                        block.for_nonzero(d_local, |j, v| w[j as usize] += delta * v);
                    }
                }
            }
            block_viol[b] = viol;
        }
    }
    Ok(w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn blocked_matches_plain_dcd_quality() {
        let ds = synth::alpha_like(1200, 10, 1);
        let w = train(&ds, &StreamDcdCfg { passes: 20, ..Default::default() }).unwrap();
        let plain = crate::baselines::dcd::train(&ds, &Default::default());
        let j_blocked = crate::model::objective_cls(&ds, &w, 1.0);
        let j_plain = crate::model::objective_cls(&ds, &plain.w, 1.0);
        assert!(j_blocked < 1.15 * j_plain, "{j_blocked} vs {j_plain}");
    }

    #[test]
    fn selective_mode_also_converges() {
        let ds = synth::alpha_like(600, 8, 2);
        let w = train(&ds, &StreamDcdCfg { selective: true, ..Default::default() }).unwrap();
        assert!(crate::model::accuracy_cls(&ds, &w) > 0.8);
    }

    #[test]
    fn streaming_from_file_matches_ram() {
        let ds = synth::alpha_like(300, 6, 3);
        let dir = std::env::temp_dir().join("pemsvm_stream_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("stream.svm");
        crate::data::libsvm::save(&ds, &path).unwrap();
        let cfg_ram = StreamDcdCfg { selective: false, seed: 9, ..Default::default() };
        let cfg_file = StreamDcdCfg { stream_from: Some(path), seed: 9, ..cfg_ram };
        let w_ram = train(&ds, &StreamDcdCfg { seed: 9, ..Default::default() }).unwrap();
        let w_file = train(&ds, &cfg_file).unwrap();
        // same visit order (same seed) => identical trajectories up to
        // the f32 parse/print roundtrip of the libsvm file
        for (a, b) in w_ram.iter().zip(&w_file) {
            assert!((a - b).abs() < 5e-2, "{a} vs {b}");
        }
        let _ = cfg_ram;
    }
}
