//! LL-Primal: truncated Newton-CG on the L2-loss (squared-hinge) primal
//! — the algorithm family behind liblinear's `-s 2` trust-region
//! Newton. Squared hinge is what liblinear's primal solver actually
//! minimizes, matching the paper's "L2-regularization L2-loss" note in
//! Table 4.
//!
//!   f(w) = lam/2 ||w||^2 + 2 sum_i max(0, 1 - y_i w.x_i)^2
//!   grad = lam w - 4 sum_{i in I} (1 - y_i w.x_i) y_i x_i
//!   Hess = lam I + 4 X_I^T X_I    (I = active set)
//!
//! Hessian-vector products stream over the active rows, so memory is
//! O(K) and each Newton step is a few CG iterations.

use crate::data::Dataset;

pub struct PrimalNewtonCfg {
    pub lambda: f32,
    pub max_newton: usize,
    pub cg_iters: usize,
    pub tol: f32,
}

impl Default for PrimalNewtonCfg {
    fn default() -> Self {
        PrimalNewtonCfg { lambda: 1.0, max_newton: 30, cg_iters: 25, tol: 1e-4 }
    }
}

fn objective(ds: &Dataset, w: &[f32], lam: f32) -> f64 {
    let mut loss = 0f64;
    for d in 0..ds.n {
        let m = 1.0 - ds.labels[d] * ds.dot_row(d, w);
        if m > 0.0 {
            loss += (m * m) as f64;
        }
    }
    0.5 * lam as f64 * crate::linalg::norm2_sq(w) as f64 + 2.0 * loss
}

/// grad and the active set at w.
fn gradient(ds: &Dataset, w: &[f32], lam: f32, active: &mut Vec<u32>) -> Vec<f32> {
    let mut grad: Vec<f32> = w.iter().map(|&v| lam * v).collect();
    active.clear();
    for d in 0..ds.n {
        let y = ds.labels[d];
        let m = 1.0 - y * ds.dot_row(d, w);
        if m > 0.0 {
            active.push(d as u32);
            let coef = -4.0 * m * y;
            ds.for_nonzero(d, |j, v| grad[j as usize] += coef * v);
        }
    }
    grad
}

/// Hv = lam v + 4 X_I^T (X_I v)
fn hess_vec(ds: &Dataset, active: &[u32], v: &[f32], lam: f32, out: &mut [f32]) {
    for (o, &vi) in out.iter_mut().zip(v) {
        *o = lam * vi;
    }
    for &du in active {
        let d = du as usize;
        let xv = ds.dot_row(d, v);
        let coef = 4.0 * xv;
        ds.for_nonzero(d, |j, val| out[j as usize] += coef * val);
    }
}

pub fn train(ds: &Dataset, cfg: &PrimalNewtonCfg) -> Vec<f32> {
    let k = ds.k;
    let lam = cfg.lambda;
    let mut w = vec![0f32; k];
    let mut active: Vec<u32> = Vec::new();
    let mut f_prev = objective(ds, &w, lam);
    for _ in 0..cfg.max_newton {
        let grad = gradient(ds, &w, lam, &mut active);
        let gnorm = crate::linalg::norm2_sq(&grad).sqrt();
        if gnorm < cfg.tol * (1.0 + f_prev as f32) {
            break;
        }
        // CG solve H s = -grad
        let mut s = vec![0f32; k];
        let mut r: Vec<f32> = grad.iter().map(|g| -g).collect();
        let mut p = r.clone();
        let mut rs_old = crate::linalg::norm2_sq(&r);
        let mut hp = vec![0f32; k];
        for _ in 0..cfg.cg_iters {
            hess_vec(ds, &active, &p, lam, &mut hp);
            let php = crate::linalg::dot(&p, &hp);
            if php <= 0.0 {
                break;
            }
            let a = rs_old / php;
            crate::linalg::axpy(a, &p, &mut s);
            crate::linalg::axpy(-a, &hp, &mut r);
            let rs_new = crate::linalg::norm2_sq(&r);
            if rs_new.sqrt() < 0.1 * gnorm {
                break;
            }
            let beta = rs_new / rs_old;
            for (pi, ri) in p.iter_mut().zip(&r) {
                *pi = ri + beta * *pi;
            }
            rs_old = rs_new;
        }
        // backtracking line search
        let mut step = 1.0f32;
        let g_dot_s = crate::linalg::dot(&grad, &s);
        let mut improved = false;
        for _ in 0..20 {
            let wt: Vec<f32> = w.iter().zip(&s).map(|(wi, si)| wi + step * si).collect();
            let ft = objective(ds, &wt, lam);
            if ft <= f_prev + 1e-4 * (step * g_dot_s) as f64 {
                w = wt;
                f_prev = ft;
                improved = true;
                break;
            }
            step *= 0.5;
        }
        if !improved {
            break;
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn learns_and_monotone() {
        let ds = synth::alpha_like(800, 10, 1);
        let w = train(&ds, &PrimalNewtonCfg::default());
        assert!(crate::model::accuracy_cls(&ds, &w) > 0.82);
        // optimality: gradient near zero
        let mut active = Vec::new();
        let g = gradient(&ds, &w, 1.0, &mut active);
        assert!(crate::linalg::norm2_sq(&g).sqrt() < 1.0, "grad norm");
    }

    #[test]
    fn hessian_vec_is_symmetric_psd() {
        let ds = synth::alpha_like(100, 6, 2);
        let w = vec![0.01f32; 6];
        let mut active = Vec::new();
        let _ = gradient(&ds, &w, 1.0, &mut active);
        let mut hu = vec![0f32; 6];
        let mut hv = vec![0f32; 6];
        let u: Vec<f32> = (0..6).map(|i| (i as f32).sin()).collect();
        let v: Vec<f32> = (0..6).map(|i| (i as f32).cos()).collect();
        hess_vec(&ds, &active, &u, 1.0, &mut hu);
        hess_vec(&ds, &active, &v, 1.0, &mut hv);
        // symmetry: u^T H v == v^T H u
        let a = crate::linalg::dot(&v, &hu);
        let b = crate::linalg::dot(&u, &hv);
        assert!((a - b).abs() < 1e-2 * a.abs().max(1.0));
        // PSD: u^T H u > 0
        assert!(crate::linalg::dot(&u, &hu) > 0.0);
    }
}
