//! PSVM-lite (Chang et al. 2007): the paper's PSVM baseline
//! approximates the N x N kernel matrix by incomplete Cholesky
//! factorization to rank r ~ sqrt(N) and solves the resulting QP.
//! We reproduce the same complexity signature — O(N r^2) factorization
//! plus O(N r) per dual sweep — with ICF + projected-gradient dual
//! ascent on the factored problem.
//!
//! This is what makes PSVM scale well in K but poorly in N
//! (r = sqrt(N) => factorization cost ~ N^2), the shape Figure 3/4
//! report.

use crate::data::Dataset;

pub struct PsvmLiteCfg {
    /// PEMSVM-scale lambda; C = 2/lambda
    pub lambda: f32,
    /// rank ratio: r = ceil(ratio * N). The paper used 1/sqrt(N), i.e.
    /// r = sqrt(N); pass `None` for that default.
    pub rank: Option<usize>,
    pub pg_iters: usize,
}

impl Default for PsvmLiteCfg {
    fn default() -> Self {
        PsvmLiteCfg { lambda: 1.0, rank: None, pg_iters: 200 }
    }
}

/// Incomplete Cholesky of the (linear-kernel) Gram matrix with pivoting:
/// returns H [n, r] with K ~= H H^T, touching only O(n r) kernel entries
/// per column.
pub fn icf(ds: &Dataset, r: usize) -> Vec<f32> {
    let n = ds.n;
    let mut h = vec![0f32; n * r];
    let mut diag: Vec<f32> = (0..n).map(|d| ds.row_norm_sq(d)).collect();
    let mut perm_used = vec![false; n];
    let mut xi = vec![0f32; ds.k];
    let mut xp = vec![0f32; ds.k];
    for col in 0..r {
        // pivot: largest remaining diagonal
        let (piv, &dmax) = diag
            .iter()
            .enumerate()
            .filter(|(i, _)| !perm_used[*i])
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap();
        if dmax <= 1e-9 {
            break;
        }
        perm_used[piv] = true;
        let droot = dmax.sqrt();
        h[piv * r + col] = droot;
        ds.densify_row(piv, &mut xp);
        for i in 0..n {
            if perm_used[i] || diag[i] <= 0.0 {
                continue;
            }
            ds.densify_row(i, &mut xi);
            let kip = crate::linalg::dot(&xi, &xp);
            let mut proj = 0f32;
            for c in 0..col {
                proj += h[i * r + c] * h[piv * r + c];
            }
            let v = (kip - proj) / droot;
            h[i * r + col] = v;
            diag[i] -= v * v;
        }
    }
    h
}

/// Train a binary SVM through the low-rank dual. Returns the primal w
/// reconstructed from alpha (linear kernel).
pub fn train(ds: &Dataset, cfg: &PsvmLiteCfg) -> Vec<f32> {
    let n = ds.n;
    let r = cfg.rank.unwrap_or_else(|| (n as f64).sqrt().ceil() as usize).clamp(1, n);
    let c = 2.0 / cfg.lambda;
    let h = icf(ds, r);
    // dual: max e^T a - 1/2 a^T Y H H^T Y a, 0 <= a <= C
    // projected gradient with v = H^T (y .* a) kept incrementally
    let mut alpha = vec![0f32; n];
    let mut v = vec![0f32; r];
    // Lipschitz-ish step: 1 / max_i ||h_i||^2
    let hmax = (0..n)
        .map(|i| crate::linalg::norm2_sq(&h[i * r..(i + 1) * r]))
        .fold(0f32, f32::max)
        .max(1e-9);
    let step = 1.0 / hmax;
    for _ in 0..cfg.pg_iters {
        let mut changed = false;
        for i in 0..n {
            let hi = &h[i * r..(i + 1) * r];
            let grad = 1.0 - ds.labels[i] * crate::linalg::dot(hi, &v);
            let a_new = (alpha[i] + step * grad).clamp(0.0, c);
            let da = a_new - alpha[i];
            if da != 0.0 {
                alpha[i] = a_new;
                crate::linalg::axpy(da * ds.labels[i], hi, &mut v);
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }
    // primal reconstruction: w = sum a_i y_i x_i (exact in the linear case)
    let mut w = vec![0f32; ds.k];
    for i in 0..n {
        if alpha[i] != 0.0 {
            let coef = alpha[i] * ds.labels[i];
            ds.for_nonzero(i, |j, val| w[j as usize] += coef * val);
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn icf_reconstructs_lowrank_gram() {
        // data of intrinsic rank 3 => rank-3 ICF is near-exact
        let mut data = vec![0f32; 40 * 6];
        let mut g = crate::rng::Pcg64::new(1);
        let basis: Vec<f32> = (0..3 * 6).map(|_| g.next_f32() - 0.5).collect();
        for d in 0..40 {
            let coef: Vec<f32> = (0..3).map(|_| g.next_f32() - 0.5).collect();
            for j in 0..6 {
                for (c, b) in coef.iter().zip(basis.chunks(6)) {
                    data[d * 6 + j] += c * b[j];
                }
            }
        }
        let ds = crate::data::Dataset::dense(data, vec![1.0; 40], 6, crate::data::Task::Binary);
        let h = icf(&ds, 3);
        let mut bi = vec![0f32; 6];
        let mut bj = vec![0f32; 6];
        for i in 0..40 {
            for j in 0..40 {
                ds.densify_row(i, &mut bi);
                ds.densify_row(j, &mut bj);
                let kij = crate::linalg::dot(&bi, &bj);
                let approx = crate::linalg::dot(&h[i * 3..i * 3 + 3], &h[j * 3..j * 3 + 3]);
                assert!((kij - approx).abs() < 1e-2, "({i},{j}): {kij} vs {approx}");
            }
        }
    }

    #[test]
    fn learns_with_sqrt_n_rank() {
        let ds = synth::alpha_like(900, 10, 2);
        let w = train(&ds, &PsvmLiteCfg::default());
        let acc = crate::model::accuracy_cls(&ds, &w);
        assert!(acc > 0.8, "acc {acc}");
    }
}
