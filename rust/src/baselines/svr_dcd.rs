//! SVR baseline: dual coordinate descent for L1-loss epsilon-SVR
//! (Ho & Lin 2012, liblinear `-s 13`).
//!
//! Dual over beta_i in [-C, C]:
//!   min ½ beta^T Q beta - y^T beta + eps ||beta||_1,  w = sum beta_i x_i
//! Coordinate step minimizes ½ Q_ii d² + g d + eps |b + d| with
//! g = w.x_i - y_i, giving the three-case soft-threshold update.

use crate::data::Dataset;
use crate::rng::Pcg64;

pub struct SvrDcdCfg {
    /// PEMSVM-scale lambda; C = 2/lambda
    pub lambda: f32,
    pub eps_insensitive: f32,
    pub max_epochs: usize,
    pub tol: f32,
    pub seed: u64,
}

impl Default for SvrDcdCfg {
    fn default() -> Self {
        SvrDcdCfg { lambda: 1.0, eps_insensitive: 0.1, max_epochs: 100, tol: 1e-3, seed: 0 }
    }
}

pub fn train(ds: &Dataset, cfg: &SvrDcdCfg) -> Vec<f32> {
    let n = ds.n;
    let c = 2.0 / cfg.lambda;
    let eps = cfg.eps_insensitive;
    let qii: Vec<f32> = (0..n).map(|d| ds.row_norm_sq(d)).collect();
    let mut w = vec![0f32; ds.k];
    let mut beta = vec![0f32; n];
    let mut order: Vec<u32> = (0..n as u32).collect();
    let mut g = Pcg64::new_stream(cfg.seed, 0x54b);
    for _ in 0..cfg.max_epochs {
        g.shuffle(&mut order);
        let mut max_change = 0f32;
        for &du in &order {
            let d = du as usize;
            if qii[d] == 0.0 {
                continue;
            }
            let grad = ds.dot_row(d, &w) - ds.labels[d];
            let b = beta[d];
            // minimize ½ q d² + (grad) d + eps |b + d|
            let d1 = -(grad + eps) / qii[d]; // assumes b + d > 0
            let d2 = -(grad - eps) / qii[d]; // assumes b + d < 0
            let step = if b + d1 > 0.0 {
                d1
            } else if b + d2 < 0.0 {
                d2
            } else {
                -b
            };
            let b_new = (b + step).clamp(-c, c);
            let delta = b_new - b;
            if delta != 0.0 {
                beta[d] = b_new;
                ds.for_nonzero(d, |j, v| w[j as usize] += delta * v);
                max_change = max_change.max(delta.abs() * qii[d].sqrt());
            }
        }
        if max_change < cfg.tol {
            break;
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn fits_linear_data() {
        let ds = synth::year_like(3000, 12, 1);
        let w = train(&ds, &SvrDcdCfg { lambda: 0.1, eps_insensitive: 0.1, ..Default::default() });
        let r = crate::model::rmse(&ds, &w);
        assert!(r < 0.75, "rmse {r}"); // noise floor ~0.6/σ_y
        assert!(r < crate::model::rmse(&ds, &vec![0.0; 12]));
    }

    #[test]
    fn eps_wider_than_signal_gives_zero() {
        let ds = synth::year_like(500, 6, 2);
        // eps = 10 >> |y|: no residual exceeds the tube, w stays 0
        let w = train(&ds, &SvrDcdCfg { eps_insensitive: 10.0, ..Default::default() });
        assert!(crate::linalg::norm2_sq(&w) < 1e-8);
    }
}
