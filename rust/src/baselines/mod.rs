//! Baseline solvers — from-scratch implementations of every comparator
//! in the paper's Table 4, sharing this crate's data structures so the
//! constant factors are comparable (DESIGN.md §6):
//!
//! | paper          | here                                   |
//! |----------------|----------------------------------------|
//! | LL-Dual [5]    | [`dcd`] dual coordinate descent        |
//! | LL-Primal [5]  | [`primal_newton`] truncated Newton-CG  |
//! | LL-CS [5]      | [`cs_dcd`] Crammer-Singer sequential dual |
//! | Pegasos [14]   | [`pegasos`] primal sub-gradient        |
//! | SVMPerf [8]    | [`cutting_plane`] primal bundle method |
//! | SVMMult [9]    | [`cutting_plane`] (CS loss variant via cs_dcd fallback) |
//! | PSVM [2]       | [`psvm_lite`] low-rank ICF dual        |
//! | StreamSVM [10] | [`stream_dcd`] blocked out-of-core DCD |
//! | SDB [3]        | [`stream_dcd`] (selective-block mode)  |

pub mod cs_dcd;
pub mod cutting_plane;
pub mod dcd;
pub mod pegasos;
pub mod primal_newton;
pub mod psvm_lite;
pub mod stream_dcd;
pub mod svr_dcd;
