//! SVMPerf-lite (Joachims 2006): cutting-plane / bundle method on the
//! primal. Each iteration linearizes the (scaled) hinge-loss sum at the
//! current w into a plane `l(w) >= b_t + a_t . w`, then solves the
//! master problem `min lam/2 ||w||^2 + max_t (b_t + a_t . w)` in its
//! dual (a simplex QP over plane weights, Frank-Wolfe inner loop).
//! Terminates when the primal-dual-ish gap between the true loss and
//! the bundle lower bound closes.

use crate::data::Dataset;

pub struct CuttingPlaneCfg {
    pub lambda: f32,
    pub max_planes: usize,
    /// relative gap tolerance (SVMPerf's epsilon)
    pub gap_tol: f64,
    pub fw_iters: usize,
}

impl Default for CuttingPlaneCfg {
    fn default() -> Self {
        CuttingPlaneCfg { lambda: 1.0, max_planes: 100, gap_tol: 1e-3, fw_iters: 200 }
    }
}

/// loss(w) = 2 sum hinge, plus its subgradient plane at w.
fn plane_at(ds: &Dataset, w: &[f32]) -> (f64, Vec<f32>, f64) {
    let mut a = vec![0f32; ds.k];
    let mut cnt = 0f64;
    let mut loss = 0f64;
    for d in 0..ds.n {
        let y = ds.labels[d];
        let margin = y * ds.dot_row(d, w);
        if margin < 1.0 {
            loss += 2.0 * (1.0 - margin) as f64;
            cnt += 2.0;
            ds.for_nonzero(d, |j, v| a[j as usize] -= 2.0 * y * v);
        }
    }
    // loss(w') >= cnt + a . w' (exact at w)
    (loss, a, cnt)
}

pub fn train(ds: &Dataset, cfg: &CuttingPlaneCfg) -> Vec<f32> {
    let k = ds.k;
    let lam = cfg.lambda as f64;
    let mut w = vec![0f32; k];
    let mut planes_a: Vec<Vec<f32>> = Vec::new();
    let mut planes_b: Vec<f64> = Vec::new();
    // theta: simplex weights over planes; w = -(1/lam) sum theta_t a_t
    let mut theta: Vec<f64> = Vec::new();

    for _ in 0..cfg.max_planes {
        let (loss, a, b) = plane_at(ds, &w);
        let primal = 0.5 * lam * crate::linalg::norm2_sq(&w) as f64 + loss;
        // bundle value at w
        let bundle = planes_a
            .iter()
            .zip(&planes_b)
            .map(|(at, bt)| bt + crate::linalg::dot(at, &w) as f64)
            .fold(0.0f64, f64::max); // max(0, .) since loss >= 0
        let lower = 0.5 * lam * crate::linalg::norm2_sq(&w) as f64 + bundle;
        if primal - lower <= cfg.gap_tol * primal.abs().max(1.0) && !planes_a.is_empty() {
            break;
        }
        planes_a.push(a);
        planes_b.push(b);
        theta.push(0.0);
        if theta.len() == 1 {
            theta[0] = 1.0;
        }

        // master dual: max_theta sum theta_t b_t - 1/(2 lam) ||sum theta a||^2
        // over the simplex, by Frank-Wolfe with exact line search.
        let t = planes_a.len();
        let mut v = vec![0f32; k]; // sum theta_t a_t
        for (th, at) in theta.iter().zip(&planes_a) {
            crate::linalg::axpy(*th as f32, at, &mut v);
        }
        for _ in 0..cfg.fw_iters {
            // gradient over theta: g_t = b_t - (1/lam) a_t . v
            let mut best_t = 0usize;
            let mut best_g = f64::NEG_INFINITY;
            for i in 0..t {
                let gi = planes_b[i] - crate::linalg::dot(&planes_a[i], &v) as f64 / lam;
                if gi > best_g {
                    best_g = gi;
                    best_t = i;
                }
            }
            // direction: e_{best} - theta ; line search over step in [0,1]
            let mut d_v = planes_a[best_t].clone(); // a_best - v_theta-combo
            for (dv, vv) in d_v.iter_mut().zip(&v) {
                *dv -= vv;
            }
            let cur_obj_grad = best_g
                - theta
                    .iter()
                    .enumerate()
                    .map(|(i, th)| {
                        th * (planes_b[i] - crate::linalg::dot(&planes_a[i], &v) as f64 / lam)
                    })
                    .sum::<f64>();
            if cur_obj_grad <= 1e-12 {
                break;
            }
            // quadratic in step: f(step) = f0 + step * cur_obj_grad - step^2/(2 lam) ||d_v||^2
            let dnorm = crate::linalg::norm2_sq(&d_v) as f64;
            let step = if dnorm > 0.0 {
                (lam * cur_obj_grad / dnorm).clamp(0.0, 1.0)
            } else {
                1.0
            };
            for th in theta.iter_mut() {
                *th *= 1.0 - step;
            }
            theta[best_t] += step;
            for (vv, dv) in v.iter_mut().zip(&d_v) {
                *vv += step as f32 * dv;
            }
        }
        // primal from dual: w = -(1/lam) v
        for (wi, vi) in w.iter_mut().zip(&v) {
            *wi = -vi / lam as f32;
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn converges_to_dcd_objective() {
        let ds = synth::alpha_like(800, 10, 1);
        let w = train(&ds, &CuttingPlaneCfg::default());
        let out = crate::baselines::dcd::train(&ds, &Default::default());
        let j_cp = crate::model::objective_cls(&ds, &w, 1.0);
        let j_dcd = crate::model::objective_cls(&ds, &out.w, 1.0);
        assert!(
            (j_cp - j_dcd).abs() / j_dcd < 0.05,
            "J_cp={j_cp} J_dcd={j_dcd}"
        );
    }

    #[test]
    fn few_planes_for_easy_data() {
        let ds = synth::gaussian_margin(500, 6, 2, 3.0, 0.0);
        let w = train(&ds, &CuttingPlaneCfg { max_planes: 50, ..Default::default() });
        assert!(crate::model::accuracy_cls(&ds, &w) > 0.95);
    }
}
