//! LL-Dual: dual coordinate descent for linear SVM (Hsieh et al. 2008,
//! the algorithm behind liblinear's `-s 3` / `-s 1`).
//!
//! Dual: min ½ a^T Q a - e^T a,  0 <= a_i <= U, Q_ij = y_i y_j x_i.x_j
//! (+ 1/(2C) on the diagonal for L2 loss). `U = C` for L1 (hinge) loss,
//! `U = inf` for L2 (squared hinge). `w = sum_i a_i y_i x_i` maintained
//! incrementally — O(nnz) per coordinate.
//!
//! PEMSVM's Eq. (1) scaling `lam/2 ||w||^2 + 2 sum hinge` maps to the
//! liblinear form `1/2 ||w||^2 + C sum hinge` with `C = 2/lam`.

use crate::data::Dataset;
use crate::rng::Pcg64;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Loss {
    /// L1 (hinge); bounded dual
    Hinge,
    /// L2 (squared hinge); diagonal-shifted dual
    SquaredHinge,
}

pub struct DcdCfg {
    pub lambda: f32,
    pub loss: Loss,
    pub max_epochs: usize,
    /// stop when the max projected-gradient violation in an epoch drops
    /// below this
    pub tol: f32,
    pub seed: u64,
}

impl Default for DcdCfg {
    fn default() -> Self {
        DcdCfg { lambda: 1.0, loss: Loss::Hinge, max_epochs: 100, tol: 1e-3, seed: 0 }
    }
}

pub struct DcdOutput {
    pub w: Vec<f32>,
    pub alpha: Vec<f32>,
    pub epochs: usize,
}

pub fn train(ds: &Dataset, cfg: &DcdCfg) -> DcdOutput {
    let n = ds.n;
    let c = 2.0 / cfg.lambda;
    let (upper, diag_shift) = match cfg.loss {
        Loss::Hinge => (c, 0.0),
        Loss::SquaredHinge => (f32::INFINITY, 1.0 / (2.0 * c)),
    };
    let qii: Vec<f32> = (0..n).map(|d| ds.row_norm_sq(d) + diag_shift).collect();
    let mut w = vec![0f32; ds.k];
    let mut alpha = vec![0f32; n];
    let mut order: Vec<u32> = (0..n as u32).collect();
    let mut g = Pcg64::new_stream(cfg.seed, 0xdcd);
    let mut epochs = 0;
    for ep in 0..cfg.max_epochs {
        epochs = ep + 1;
        g.shuffle(&mut order);
        let mut max_viol = 0f32;
        for &du in &order {
            let d = du as usize;
            if qii[d] <= diag_shift {
                continue; // zero row
            }
            let y = ds.labels[d];
            // G = y w.x - 1 + diag_shift * a
            let grad = y * ds.dot_row(d, &w) - 1.0 + diag_shift * alpha[d];
            // projected gradient
            let pg = if alpha[d] <= 0.0 {
                grad.min(0.0)
            } else if alpha[d] >= upper {
                grad.max(0.0)
            } else {
                grad
            };
            max_viol = max_viol.max(pg.abs());
            if pg.abs() > 1e-12 {
                let a_old = alpha[d];
                let a_new = (a_old - grad / qii[d]).clamp(0.0, upper);
                alpha[d] = a_new;
                let delta = (a_new - a_old) * y;
                if delta != 0.0 {
                    ds.for_nonzero(d, |j, v| w[j as usize] += delta * v);
                }
            }
        }
        if max_viol < cfg.tol {
            break;
        }
    }
    DcdOutput { w, alpha, epochs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::model::objective_cls;

    #[test]
    fn reaches_good_objective_hinge() {
        let ds = synth::alpha_like(1000, 12, 1);
        let lambda = 1.0;
        let out = train(&ds, &DcdCfg { lambda, ..DcdCfg::default() });
        // compare against the EM solver's optimum on the same problem
        let mut w_em = vec![0f32; 12];
        let mut ws = crate::solver::local::StepWorkspace::new();
        for _ in 0..40 {
            let mut st = crate::solver::PartialStats::zeros(12);
            crate::solver::local::lin_step(
                &ds,
                0..ds.n,
                &w_em,
                1e-5,
                &mut crate::solver::GammaMode::Em,
                &mut ws,
                &mut st,
            );
            w_em = crate::solver::master::solve_native(
                &mut st,
                &crate::solver::master::Regularizer::Eye(lambda),
                None,
            )
            .unwrap();
        }
        let j_dcd = objective_cls(&ds, &out.w, lambda);
        let j_em = objective_cls(&ds, &w_em, lambda);
        // the two optimize the same objective; within a few percent
        assert!(
            (j_dcd - j_em).abs() / j_em < 0.05,
            "J_dcd={j_dcd} J_em={j_em}"
        );
        assert!(crate::model::accuracy_cls(&ds, &out.w) > 0.82);
    }

    #[test]
    fn alpha_within_box() {
        let ds = synth::alpha_like(300, 6, 3);
        let out = train(&ds, &DcdCfg { lambda: 0.5, ..DcdCfg::default() });
        let c = 2.0 / 0.5;
        assert!(out.alpha.iter().all(|&a| (0.0..=c).contains(&a)));
    }

    #[test]
    fn squared_hinge_also_learns() {
        let ds = synth::alpha_like(500, 8, 4);
        let out = train(
            &ds,
            &DcdCfg { lambda: 1.0, loss: Loss::SquaredHinge, ..DcdCfg::default() },
        );
        assert!(crate::model::accuracy_cls(&ds, &out.w) > 0.82);
    }

    /// KKT spot check: interior alphas should have ~zero gradient.
    #[test]
    fn kkt_interior() {
        let ds = synth::alpha_like(400, 5, 5);
        let out = train(
            &ds,
            &DcdCfg { lambda: 1.0, tol: 1e-4, max_epochs: 300, ..DcdCfg::default() },
        );
        let c = 2.0f32;
        for d in 0..ds.n {
            let a = out.alpha[d];
            if a > 0.01 * c && a < 0.99 * c {
                let gkkt = ds.labels[d] * ds.dot_row(d, &out.w) - 1.0;
                assert!(gkkt.abs() < 0.05, "interior KKT violated: {gkkt}");
            }
        }
    }
}
