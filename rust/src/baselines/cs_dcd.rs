//! LL-CS: sequential dual optimization for the Crammer-Singer
//! multiclass SVM (Keerthi et al. 2008 family, liblinear `-s 4`).
//!
//! Per example i the dual block alpha_i in R^M satisfies
//! `sum_m alpha_i^m = 0`, `alpha_i^m <= C delta(m = y_i)`. We ascend
//! with the most-violating-pair (SMO-style) update: move mass t along
//! `e_{y_i} - e_r` where r is the most violating competitor — the
//! two-coordinate analogue of liblinear's full sub-problem, converging
//! to the same optimum with the same O(nnz * M) sweep cost.

use crate::data::Dataset;
use crate::linalg::Mat;
use crate::rng::Pcg64;

pub struct CsDcdCfg {
    /// PEMSVM-scale lambda; C = 2/lambda
    pub lambda: f32,
    pub max_epochs: usize,
    pub tol: f32,
    pub seed: u64,
}

impl Default for CsDcdCfg {
    fn default() -> Self {
        CsDcdCfg { lambda: 1.0, max_epochs: 50, tol: 1e-3, seed: 0 }
    }
}

pub fn train(ds: &Dataset, m: usize, cfg: &CsDcdCfg) -> Mat {
    let n = ds.n;
    let c = 2.0 / cfg.lambda;
    let mut w = Mat::zeros(m, ds.k);
    // alpha stored per (example, class); row-major n x m
    let mut alpha = vec![0f32; n * m];
    let qii: Vec<f32> = (0..n).map(|d| ds.row_norm_sq(d)).collect();
    let mut order: Vec<u32> = (0..n as u32).collect();
    let mut g = Pcg64::new_stream(cfg.seed, 0xc5);
    let mut scores = vec![0f32; m];
    for _ in 0..cfg.max_epochs {
        g.shuffle(&mut order);
        let mut max_viol = 0f32;
        for &du in &order {
            let d = du as usize;
            if qii[d] == 0.0 {
                continue;
            }
            let yd = ds.labels[d] as usize;
            crate::model::class_scores(ds, d, &w, &mut scores);
            // most violating competitor under the CS loss
            let mut r = usize::MAX;
            let mut best = f32::NEG_INFINITY;
            for (cl, &s) in scores.iter().enumerate() {
                if cl == yd {
                    continue;
                }
                let v = s + 1.0;
                if v > best {
                    best = v;
                    r = cl;
                }
            }
            let viol = best - scores[yd];
            // dual ascent step along (e_yd - e_r): curvature 2*Q_ii
            let a_y = alpha[d * m + yd];
            let a_r = alpha[d * m + r];
            let t_unc = viol / (2.0 * qii[d]);
            // bounds: a_y + t <= C ; a_r - t <= 0  (i.e. t >= a_r)
            let t = t_unc.clamp(a_r, c - a_y);
            if t.abs() > 1e-12 {
                max_viol = max_viol.max(viol.max(0.0));
                alpha[d * m + yd] = a_y + t;
                alpha[d * m + r] = a_r - t;
                ds.for_nonzero(d, |j, v| {
                    w[(yd, j as usize)] += t * v;
                    w[(r, j as usize)] -= t * v;
                });
            }
        }
        if max_viol < cfg.tol {
            break;
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn learns_multiclass() {
        let ds = synth::mnist_like(1500, 16, 5, 1);
        let w = train(&ds, 5, &CsDcdCfg::default());
        let acc = crate::model::accuracy_mlt(&ds, &w);
        assert!(acc > 0.8, "accuracy {acc}");
    }

    #[test]
    fn alpha_feasibility_held_implicitly() {
        // after training, no class weight should be NaN/inf and the CS
        // objective should beat the zero solution
        let ds = synth::mnist_like(400, 8, 3, 2);
        let w = train(&ds, 3, &CsDcdCfg { lambda: 0.5, ..Default::default() });
        assert!(w.data.iter().all(|v| v.is_finite()));
        let j = crate::model::objective_mlt(&ds, &w, 0.5);
        let j0 = crate::model::objective_mlt(&ds, &Mat::zeros(3, 8), 0.5);
        assert!(j < j0, "{j} !< {j0}");
    }

    #[test]
    fn two_class_cs_close_to_binary_dcd() {
        let ds_bin = synth::alpha_like(600, 8, 3);
        // multiclass view of the same data (labels 0/1)
        let labels_mc: Vec<f32> =
            ds_bin.labels.iter().map(|&y| if y > 0.0 { 1.0 } else { 0.0 }).collect();
        let ds_mc = match &ds_bin.features {
            crate::data::Features::Dense { data } => crate::data::Dataset::dense(
                data.clone(),
                labels_mc,
                8,
                crate::data::Task::Multiclass(2),
            ),
            _ => unreachable!(),
        };
        let w_cs = train(&ds_mc, 2, &CsDcdCfg::default());
        let acc_cs = crate::model::accuracy_mlt(&ds_mc, &w_cs);
        let out = crate::baselines::dcd::train(&ds_bin, &Default::default());
        let acc_bin = crate::model::accuracy_cls(&ds_bin, &out.w);
        assert!((acc_cs - acc_bin).abs() < 0.05, "{acc_cs} vs {acc_bin}");
    }
}
