//! Pegasos (Shalev-Shwartz et al. 2007): primal stochastic sub-gradient
//! with the 1/(lam t) step schedule and optional ball projection.
//!
//! Scaling note: Pegasos minimizes `lam_p/2 ||w||^2 + (1/N) sum hinge`;
//! with `lam_p = lambda / (2N)` this is exactly PEMSVM's Eq. (1)
//! objective divided by 2N, so the two solvers optimize the same w.

use crate::data::Dataset;
use crate::rng::Pcg64;

pub struct PegasosCfg {
    /// PEMSVM-scale lambda (Eq. 1); internally mapped to lam/(2N)
    pub lambda: f32,
    pub epochs: usize,
    pub seed: u64,
    /// project onto the 1/sqrt(lam_p) ball each step (the paper's
    /// optional step; helps early iterations)
    pub project: bool,
}

impl Default for PegasosCfg {
    fn default() -> Self {
        PegasosCfg { lambda: 1.0, epochs: 20, seed: 0, project: true }
    }
}

/// Train on a binary dataset; returns w.
pub fn train(ds: &Dataset, cfg: &PegasosCfg) -> Vec<f32> {
    let n = ds.n;
    let lam = (cfg.lambda / (2.0 * n as f32)).max(1e-12);
    let mut w = vec![0f32; ds.k];
    let mut g = Pcg64::new_stream(cfg.seed, 0x9e9a);
    let mut t = 1u64;
    let radius = 1.0 / lam.sqrt();
    for _ in 0..cfg.epochs {
        for _ in 0..n {
            let d = g.next_below(n as u64) as usize;
            let y = ds.labels[d];
            let margin = y * ds.dot_row(d, &w);
            let eta = 1.0 / (lam * t as f32);
            // w <- (1 - eta lam) w  [+ eta y x if margin < 1]
            let shrink = 1.0 - eta * lam;
            for v in w.iter_mut() {
                *v *= shrink;
            }
            if margin < 1.0 {
                ds.for_nonzero(d, |j, v| w[j as usize] += eta * y * v);
            }
            if cfg.project {
                let norm = crate::linalg::norm2_sq(&w).sqrt();
                if norm > radius {
                    let s = radius / norm;
                    for v in w.iter_mut() {
                        *v *= s;
                    }
                }
            }
            t += 1;
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn learns_separable_data() {
        let ds = synth::gaussian_margin(2000, 10, 1, 2.5, 0.02);
        let w = train(&ds, &PegasosCfg { lambda: 1.0, epochs: 10, seed: 0, project: true });
        assert!(crate::model::accuracy_cls(&ds, &w) > 0.9);
    }

    #[test]
    fn deterministic() {
        let ds = synth::alpha_like(500, 8, 2);
        let cfg = PegasosCfg::default();
        assert_eq!(train(&ds, &cfg), train(&ds, &cfg));
    }
}
