//! The transport unit of the cluster wire protocol (DESIGN.md §15).
//!
//! Every message travels as one frame:
//!
//! ```text
//! offset  size  field
//!      0     4  magic      "PSVM" (little-endian u32 0x4d565350)
//!      4     1  version    protocol version (currently 1)
//!      5     1  msg type   wire::Request / wire::Reply tag
//!      6     2  reserved   must be zero
//!      8     4  len        payload length in bytes (LE u32)
//!     12     4  crc32      CRC-32/IEEE of the payload (LE u32)
//!     16   len  payload    message body (wire.rs encoding)
//! ```
//!
//! Decoding is **total**: a truncated stream, wrong magic, version
//! skew, an oversized length prefix or a checksum mismatch all return a
//! structured [`WireError`] — no panics, and no allocation before the
//! length has been validated against [`MAX_PAYLOAD`], so a hostile
//! 4 GiB length prefix cannot balloon the receiver.

use std::io::{Read, Write};
use std::sync::OnceLock;

/// `"PSVM"` little-endian.
pub const MAGIC: u32 = u32::from_le_bytes(*b"PSVM");
/// Protocol version this build speaks.
pub const VERSION: u8 = 1;
/// Header bytes before the payload.
pub const HEADER_LEN: usize = 16;
/// Upper bound on one payload. Generous — a shipped dataset is chunked
/// into many frames well below this — but small enough that a corrupt
/// or hostile length prefix cannot drive an allocation anywhere near
/// address-space scale.
pub const MAX_PAYLOAD: usize = 256 << 20;

/// Structured decode failure. Every variant is a protocol-level fact
/// about the bytes, not an I/O condition (those stay `std::io::Error`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// first four bytes were not `"PSVM"`
    BadMagic(u32),
    /// peer speaks a different protocol version
    VersionSkew { got: u8, want: u8 },
    /// length prefix exceeds [`MAX_PAYLOAD`]
    Oversized { len: u64, max: u64 },
    /// payload checksum mismatch
    CrcMismatch { got: u32, want: u32 },
    /// payload ended before a field finished decoding
    Truncated { need: usize, have: usize },
    /// reserved header bytes were non-zero
    BadReserved(u16),
    /// unknown message-type byte
    UnknownMsg(u8),
    /// a decoded field had an impossible value (bad tag, count
    /// mismatch, non-UTF-8 string)
    BadValue(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::BadMagic(m) => write!(f, "bad frame magic {m:#010x}"),
            WireError::VersionSkew { got, want } => {
                write!(f, "protocol version skew: peer speaks v{got}, this build v{want}")
            }
            WireError::Oversized { len, max } => {
                write!(f, "frame payload length {len} exceeds the {max}-byte cap")
            }
            WireError::CrcMismatch { got, want } => {
                write!(f, "payload CRC mismatch: computed {got:#010x}, header says {want:#010x}")
            }
            WireError::Truncated { need, have } => {
                write!(f, "payload truncated: field needs {need} bytes, {have} remain")
            }
            WireError::BadReserved(r) => write!(f, "reserved header bytes non-zero ({r:#06x})"),
            WireError::UnknownMsg(t) => write!(f, "unknown message type {t:#04x}"),
            WireError::BadValue(why) => write!(f, "bad field value: {why}"),
        }
    }
}

impl std::error::Error for WireError {}

/// Why a frame read ended: cleanly closed peer, transport error, or a
/// protocol violation in the bytes themselves.
#[derive(Debug)]
pub enum RecvError {
    /// EOF on the frame boundary — the peer closed the conversation
    Closed,
    /// transport failure (includes read timeouts)
    Io(std::io::Error),
    /// the bytes violate the protocol
    Protocol(WireError),
}

impl std::fmt::Display for RecvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RecvError::Closed => write!(f, "peer closed the connection"),
            RecvError::Io(e) => write!(f, "transport error: {e}"),
            RecvError::Protocol(e) => write!(f, "protocol error: {e}"),
        }
    }
}

impl std::error::Error for RecvError {}

/// CRC-32/IEEE (the zlib polynomial), table-driven, dependency-free.
pub fn crc32(bytes: &[u8]) -> u32 {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut c = !0u32;
    for &b in bytes {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

/// Encode one frame (header + payload) into a fresh buffer.
pub fn encode_frame(msg_type: u8, payload: &[u8]) -> Vec<u8> {
    assert!(payload.len() <= MAX_PAYLOAD, "frame payload exceeds MAX_PAYLOAD");
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(VERSION);
    out.push(msg_type);
    out.extend_from_slice(&0u16.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc32(payload).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Write one frame; returns the bytes put on the wire (for the
/// `net_bytes_tx_total` counter).
pub fn write_frame<W: Write>(w: &mut W, msg_type: u8, payload: &[u8]) -> std::io::Result<usize> {
    let buf = encode_frame(msg_type, payload);
    w.write_all(&buf)?;
    w.flush()?;
    Ok(buf.len())
}

/// Parse and validate a 16-byte header. Returns `(msg_type, payload_len)`.
pub fn decode_header(h: &[u8; HEADER_LEN]) -> Result<(u8, usize), WireError> {
    let magic = u32::from_le_bytes([h[0], h[1], h[2], h[3]]);
    if magic != MAGIC {
        return Err(WireError::BadMagic(magic));
    }
    if h[4] != VERSION {
        return Err(WireError::VersionSkew { got: h[4], want: VERSION });
    }
    let reserved = u16::from_le_bytes([h[6], h[7]]);
    if reserved != 0 {
        return Err(WireError::BadReserved(reserved));
    }
    let len = u32::from_le_bytes([h[8], h[9], h[10], h[11]]) as usize;
    if len > MAX_PAYLOAD {
        return Err(WireError::Oversized { len: len as u64, max: MAX_PAYLOAD as u64 });
    }
    Ok((h[5], len))
}

/// Read one frame off `r`. Returns `(msg_type, payload, wire_bytes)`
/// with the payload CRC already verified; `wire_bytes` feeds the
/// `net_bytes_rx_total` counter. An EOF *on the frame boundary* is the
/// peer's clean close ([`RecvError::Closed`]); anywhere else it is a
/// truncated frame.
pub fn read_frame<R: Read>(r: &mut R) -> Result<(u8, Vec<u8>, usize), RecvError> {
    let mut header = [0u8; HEADER_LEN];
    let mut filled = 0usize;
    while filled < HEADER_LEN {
        match r.read(&mut header[filled..]) {
            Ok(0) if filled == 0 => return Err(RecvError::Closed),
            Ok(0) => {
                return Err(RecvError::Protocol(WireError::Truncated {
                    need: HEADER_LEN,
                    have: filled,
                }))
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(RecvError::Io(e)),
        }
    }
    let (msg_type, len) = decode_header(&header).map_err(RecvError::Protocol)?;
    let want_crc = u32::from_le_bytes([header[12], header[13], header[14], header[15]]);
    // len is validated against MAX_PAYLOAD above, so this allocation is
    // bounded no matter what the peer claims
    let mut payload = vec![0u8; len];
    if let Err(e) = r.read_exact(&mut payload) {
        return Err(match e.kind() {
            std::io::ErrorKind::UnexpectedEof => {
                RecvError::Protocol(WireError::Truncated { need: len, have: 0 })
            }
            _ => RecvError::Io(e),
        });
    }
    let got_crc = crc32(&payload);
    if got_crc != want_crc {
        return Err(RecvError::Protocol(WireError::CrcMismatch {
            got: got_crc,
            want: want_crc,
        }));
    }
    Ok((msg_type, payload, HEADER_LEN + len))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vectors() {
        // the classic check value for CRC-32/IEEE
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn frame_roundtrip() {
        let buf = encode_frame(0x42, b"hello");
        let mut cur = &buf[..];
        let (t, p, n) = read_frame(&mut cur).unwrap();
        assert_eq!((t, p.as_slice(), n), (0x42, &b"hello"[..], buf.len()));
        // and a clean EOF right after
        assert!(matches!(read_frame(&mut cur), Err(RecvError::Closed)));
    }
}
