//! The distributed cluster backend (DESIGN.md §15): a length-prefixed,
//! CRC-checked binary wire protocol plus the two endpoints that speak
//! it — the [`worker`] daemon (`pemsvm worker --listen ADDR`) hosting
//! shard state in its own process, and the [`remote::RemoteWorker`]
//! proxy the engine drives through the ordinary
//! [`WorkerBackend`](crate::backend::WorkerBackend) trait.
//!
//! Layering, bottom up:
//!
//! * [`frame`] — the transport unit: a 16-byte header (magic, version,
//!   message type, payload length, CRC-32) followed by the payload.
//!   Decoding is total: truncation, bad magic, version skew, oversized
//!   lengths and checksum mismatches all come back as structured
//!   [`frame::WireError`]s, never panics or unbounded allocations.
//! * [`wire`] — the messages: `Request` (configure / ship chunks /
//!   step / RNG capture+restore / shutdown) and `Reply` (stats, RNG,
//!   errors), encoded field by field with every float as its IEEE bit
//!   pattern, so a statistic crosses the wire bit-exactly.
//! * [`tcp`] — the small bind/accept plumbing shared with
//!   `serve::server` (satellite of the same PR).
//! * [`worker`] / [`remote`] — daemon and proxy. The proxy maps socket
//!   failures to [`NetDown`], which the pool treats like a timeout:
//!   retry, then evict and re-shard (DESIGN.md §13).
//!
//! Determinism: a remote daemon runs the *same* `NativeWorker` with the
//! same seed, worker id and shard rows as the in-process pool would,
//! the encoder preserves Dense/Sparse feature layout (the two compute
//! paths associate differently), and the tree reduce still merges
//! leader-side in the identical pairing order — so a `Remote` run is
//! bit-identical to `Threads` for a fixed seed (`tests/distributed.rs`).

pub mod frame;
pub mod remote;
pub mod tcp;
pub mod wire;
pub mod worker;

use std::sync::{Arc, OnceLock};

use crate::telemetry::{self, Counter, Histogram};

/// A connection-level failure: the remote worker timed out, hung up or
/// desynchronized. The pool downcasts to this to route the failure into
/// the retry→evict path instead of treating it as a deterministic
/// backend error (which would abort the session).
#[derive(Debug, Clone)]
pub struct NetDown {
    pub peer: String,
    pub what: String,
}

impl std::fmt::Display for NetDown {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "connection to worker {} is down: {}", self.peer, self.what)
    }
}

impl std::error::Error for NetDown {}

/// Wire-traffic series in the global telemetry registry. Both endpoints
/// count through the same cells, so an in-process loopback test sees
/// tx + rx covering both directions of the conversation.
pub struct NetMetrics {
    /// payload + header bytes written to sockets
    pub bytes_tx: Arc<Counter>,
    /// payload + header bytes read off sockets
    pub bytes_rx: Arc<Counter>,
    /// full request→reply round-trip as seen by the coordinator
    pub rtt_nanos: Arc<Histogram>,
}

pub fn net_metrics() -> &'static NetMetrics {
    static M: OnceLock<NetMetrics> = OnceLock::new();
    M.get_or_init(|| NetMetrics {
        bytes_tx: telemetry::global()
            .counter("net_bytes_tx_total", "Bytes written to cluster wire-protocol sockets."),
        bytes_rx: telemetry::global()
            .counter("net_bytes_rx_total", "Bytes read from cluster wire-protocol sockets."),
        rtt_nanos: telemetry::global().histogram(
            "net_rtt_nanos",
            "Coordinator-side request/reply round-trip in nanoseconds.",
        ),
    })
}

/// Per-worker connection gauge: 1 while the coordinator holds a live
/// connection to worker `wid`, 0 once it is closed or declared dead.
pub fn conn_gauge(wid: usize) -> Arc<telemetry::Gauge> {
    telemetry::global().gauge_labeled(
        "net_worker_connected",
        &telemetry::label("worker", &wid.to_string()),
        "Live coordinator connections per remote worker id.",
    )
}
