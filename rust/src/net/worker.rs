//! The `pemsvm worker --listen ADDR` daemon: hosts one shard's state in
//! its own process and executes solver steps on behalf of a remote
//! coordinator (DESIGN.md §15).
//!
//! One connection = one session. The coordinator drives the state
//! machine Configure → \[Chunk…\] → Seal → {Step | GetRng | SetRng}* →
//! Shutdown; the daemon replies to every request in order. Inside the
//! session the daemon runs the *same* [`NativeWorker`] the threaded
//! pool would build — same seed, worker id and shard rows — which is
//! what makes a distributed run bit-identical to a local one.
//!
//! Failure semantics: a handler error (bad step, out-of-order chunk) is
//! a *deterministic* fault and travels back as [`Reply::Error`] with the
//! connection intact; a protocol violation (bad magic, CRC mismatch,
//! truncation) means the stream can no longer be trusted, so the
//! connection drops and all session state is discarded. The coordinator
//! sees the drop as [`NetDown`](super::NetDown) and evicts the worker.

use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::backend::native::NativeWorker;
use crate::backend::WorkerBackend;
use crate::data::stream::ParsedChunk;
use crate::data::Dataset;

use super::frame::{read_frame, write_frame, RecvError};
use super::net_metrics;
use super::tcp::{self, After};
use super::wire::{ChunkData, Reply, Request, WorkerSpec};

/// Serve worker sessions on `listener`. Serial: one session at a time —
/// a daemon embodies one worker, and the coordinator holds one
/// connection to it for the whole run. With `once` the daemon exits
/// after its first session ends (tests and one-shot benches).
pub fn run(listener: TcpListener, once: bool) -> Result<()> {
    tcp::accept_loop(&listener, |stream, peer| {
        crate::log_debug!("worker: session opened by {peer}");
        match session(stream) {
            Ok(()) => crate::log_debug!("worker: session with {peer} closed"),
            Err(e) => crate::log_debug!("worker: session with {peer} aborted: {e:#}"),
        }
        if once {
            After::Stop
        } else {
            After::Continue
        }
    });
    Ok(())
}

/// Rebuilds an eagerly shipped dataset from its layout-preserving
/// chunks, validating contiguity as they arrive.
struct DatasetAssembler {
    spec: WorkerSpec,
    labels: Vec<f32>,
    feats: Option<AsmFeatures>,
}

enum AsmFeatures {
    Dense(Vec<f32>),
    Sparse { indptr: Vec<usize>, indices: Vec<u32>, values: Vec<f32> },
}

impl DatasetAssembler {
    fn new(spec: WorkerSpec) -> DatasetAssembler {
        DatasetAssembler { spec, labels: Vec::new(), feats: None }
    }

    fn push(&mut self, chunk: ChunkData) -> Result<()> {
        if chunk.start() != self.labels.len() {
            bail!(
                "dataset chunk out of order: starts at row {}, expected {}",
                chunk.start(),
                self.labels.len()
            );
        }
        if self.labels.len() + chunk.rows() > self.spec.n {
            bail!("dataset chunks overflow the configured {} rows", self.spec.n);
        }
        match chunk {
            ChunkData::Dense { k, labels, data, .. } => {
                if k != self.spec.k {
                    bail!("dense chunk width {k} != configured k {}", self.spec.k);
                }
                let dst = match self.feats.get_or_insert_with(|| AsmFeatures::Dense(Vec::new())) {
                    AsmFeatures::Dense(d) => d,
                    AsmFeatures::Sparse { .. } => bail!("dense chunk after sparse chunks"),
                };
                dst.extend_from_slice(&data);
                self.labels.extend_from_slice(&labels);
            }
            ChunkData::Sparse { labels, indptr, indices, values, .. } => {
                let dst = self.feats.get_or_insert_with(|| AsmFeatures::Sparse {
                    indptr: vec![0],
                    indices: Vec::new(),
                    values: Vec::new(),
                });
                let (dst_indptr, dst_indices, dst_values) = match dst {
                    AsmFeatures::Sparse { indptr, indices, values } => (indptr, indices, values),
                    AsmFeatures::Dense(_) => bail!("sparse chunk after dense chunks"),
                };
                // the chunk's indptr is chunk-local (starts at 0);
                // rebase onto the rows already assembled
                let base = dst_values.len();
                if indptr.first() != Some(&0) || indptr.len() != labels.len() + 1 {
                    bail!("sparse chunk indptr is malformed");
                }
                if indptr.last() != Some(&values.len()) {
                    bail!("sparse chunk indptr does not cover its values");
                }
                dst_indptr.extend(indptr[1..].iter().map(|&p| p + base));
                dst_indices.extend_from_slice(&indices);
                dst_values.extend_from_slice(&values);
                self.labels.extend_from_slice(&labels);
            }
        }
        Ok(())
    }

    fn finish(self) -> Result<Dataset> {
        if self.labels.len() != self.spec.n {
            bail!("dataset sealed at {} rows, configured {}", self.labels.len(), self.spec.n);
        }
        let task = self.spec.task;
        let k = self.spec.k;
        Ok(match self.feats {
            None | Some(AsmFeatures::Dense(_)) if self.spec.n == 0 => {
                Dataset::dense(Vec::new(), Vec::new(), k, task)
            }
            Some(AsmFeatures::Dense(data)) => Dataset::dense(data, self.labels, k, task),
            Some(AsmFeatures::Sparse { indptr, indices, values }) => {
                Dataset::sparse(indptr, indices, values, self.labels, k, task)
            }
            None => bail!("dataset sealed without any chunks"),
        })
    }
}

/// Per-connection session state.
struct Session {
    spec: Option<WorkerSpec>,
    /// eager mode: accumulates shipped chunks until Seal
    asm: Option<DatasetAssembler>,
    /// streamed mode: live from Configure; eager mode: live after Seal
    worker: Option<NativeWorker>,
}

impl Session {
    fn worker(&mut self) -> Result<&mut NativeWorker> {
        self.worker.as_mut().context("worker not sealed yet")
    }

    fn handle(&mut self, req: Request) -> Result<Reply> {
        match req {
            Request::Configure(spec) => {
                if self.spec.is_some() {
                    bail!("session already configured");
                }
                let stat_dim = spec.k;
                if spec.streamed {
                    self.worker = Some(NativeWorker::new_streaming(
                        spec.range.clone(),
                        spec.k,
                        spec.task,
                        spec.algo,
                        spec.eps_clamp,
                        spec.seed,
                        spec.wid,
                    ));
                } else {
                    if spec.range.end > spec.n {
                        bail!("shard range {:?} exceeds corpus rows {}", spec.range, spec.n);
                    }
                    self.asm = Some(DatasetAssembler::new(spec.clone()));
                }
                self.spec = Some(spec);
                Ok(Reply::Configured { stat_dim })
            }
            Request::Chunk(chunk) => {
                match (&mut self.asm, &mut self.worker) {
                    (Some(asm), _) => asm.push(chunk)?,
                    (None, Some(worker)) => {
                        let ChunkData::Sparse { start, labels, indptr, indices, values } = chunk
                        else {
                            bail!("streamed chunks are CSR; got a dense chunk");
                        };
                        let parsed = ParsedChunk::from_parts(start, labels, indptr, indices, values)?;
                        worker.ingest(&parsed)?;
                    }
                    (None, None) => bail!("chunk before configure"),
                }
                Ok(Reply::Ok)
            }
            Request::Seal => {
                match (self.asm.take(), &mut self.worker) {
                    (Some(asm), _) => {
                        let spec = self.spec.as_ref().expect("asm implies spec");
                        let ds = Arc::new(asm.finish()?);
                        self.worker = Some(NativeWorker::new(
                            ds,
                            spec.range.clone(),
                            spec.algo,
                            spec.eps_clamp,
                            spec.seed,
                            spec.wid,
                        ));
                    }
                    (None, Some(worker)) => worker.seal()?,
                    (None, None) => bail!("seal before configure"),
                }
                Ok(Reply::Ok)
            }
            Request::Step { round, input, extra } => {
                let stats = self.worker()?.step_ranges(&input, &extra)?;
                Ok(Reply::Stepped { round, stats })
            }
            Request::GetRng => Ok(Reply::Rng { state: self.worker()?.rng_state() }),
            Request::SetRng(state) => {
                self.worker()?.set_rng_state(state)?;
                Ok(Reply::Ok)
            }
            Request::Shutdown => Ok(Reply::Ok),
        }
    }
}

/// Run one coordinator session to completion. `Ok(())` covers both the
/// explicit Shutdown and the peer simply closing; `Err` is a transport
/// or protocol failure (the coordinator-side eviction path).
fn session(mut stream: TcpStream) -> Result<()> {
    tcp::configure(&stream, None)?;
    let m = net_metrics();
    let mut sess = Session { spec: None, asm: None, worker: None };
    loop {
        let (msg_type, payload, rx_bytes) = match read_frame(&mut stream) {
            Ok(f) => f,
            Err(RecvError::Closed) => return Ok(()),
            Err(e) => return Err(e.into()),
        };
        m.bytes_rx.add(rx_bytes as u64);
        // a decode failure is a protocol violation: the stream cannot be
        // trusted past it, so the session drops rather than replying
        let req = Request::decode(msg_type, &payload)?;
        let shutdown = matches!(req, Request::Shutdown);
        let reply = match sess.handle(req) {
            Ok(r) => r,
            Err(e) => Reply::Error { msg: format!("{e:#}") },
        };
        let (t, body) = reply.encode();
        let tx = write_frame(&mut stream, t, &body)?;
        m.bytes_tx.add(tx as u64);
        if shutdown {
            return Ok(());
        }
    }
}
