//! The coordinator-side proxy: a [`RemoteWorker`] implements
//! [`WorkerBackend`] over one TCP connection to a `pemsvm worker`
//! daemon, so the threaded pool drives a remote process exactly as it
//! drives an in-process `NativeWorker` (DESIGN.md §15).
//!
//! Failure mapping: any transport failure — connect refused mid-run,
//! read timeout (the socket read timeout *is* `--step-timeout-ms`),
//! hangup, CRC mismatch, desynchronized reply — marks the connection
//! dead and surfaces as [`NetDown`], which the pool routes into its
//! retry→evict path. A dead connection then fails fast on every later
//! call: the daemon is never re-stepped, so an evicted worker's RNG
//! cannot silently double-advance and survivors stay bit-identical. A
//! daemon-side [`Reply::Error`] is the opposite case — a deterministic
//! worker failure — and propagates as a plain error, aborting the
//! session just as a local backend error would.

use std::net::{TcpStream, ToSocketAddrs};
use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::backend::{RngState, StepInput, WorkerBackend};
use crate::data::stream::ParsedChunk;
use crate::data::Dataset;
use crate::solver::PartialStats;
use crate::telemetry::Gauge;

use super::frame::{read_frame, write_frame};
use super::wire::{chunk_from_parsed, dataset_chunks, Reply, Request, WorkerSpec};
use super::{conn_gauge, net_metrics, tcp, NetDown};

struct Conn {
    stream: TcpStream,
    /// once set, every call fails fast with [`NetDown`] (why it died)
    dead: Option<String>,
}

/// One remote worker as seen by the pool.
pub struct RemoteWorker {
    conn: Mutex<Conn>,
    /// the configured `host:port`, used in errors and logs
    peer: String,
    stat_dim: usize,
    /// request/reply pairing tag for step calls (desync detection)
    round: AtomicU64,
    gauge: Arc<Gauge>,
}

impl RemoteWorker {
    /// Connect to `host` (a `host:port`), configure the session, and
    /// return the proxy. `step_timeout` becomes the socket read
    /// timeout, so a remote step that outlives `--step-timeout-ms`
    /// surfaces exactly like a local straggler's missed deadline.
    pub fn connect(host: &str, spec: WorkerSpec, step_timeout: Duration) -> Result<RemoteWorker> {
        let timeout = step_timeout.max(Duration::from_millis(1));
        let addrs: Vec<_> = host
            .to_socket_addrs()
            .with_context(|| format!("resolving worker host `{host}`"))?
            .collect();
        let mut stream = None;
        let mut last_err = None;
        for a in &addrs {
            // connects get a floor: a tight step timeout is about slow
            // *steps*, not the TCP handshake
            match TcpStream::connect_timeout(a, timeout.max(Duration::from_secs(2))) {
                Ok(s) => {
                    stream = Some(s);
                    break;
                }
                Err(e) => last_err = Some(e),
            }
        }
        let stream = stream.ok_or_else(|| match last_err {
            Some(e) => anyhow!("connecting to worker `{host}`: {e}"),
            None => anyhow!("worker host `{host}` resolves to no addresses"),
        })?;
        tcp::configure(&stream, Some(timeout))
            .with_context(|| format!("configuring socket to worker `{host}`"))?;
        let gauge = conn_gauge(spec.wid as usize);
        gauge.set(1);
        let rw = RemoteWorker {
            conn: Mutex::new(Conn { stream, dead: None }),
            peer: host.to_string(),
            stat_dim: spec.k,
            round: AtomicU64::new(0),
            gauge,
        };
        match rw.rpc(Request::Configure(spec))? {
            Reply::Configured { stat_dim } if stat_dim == rw.stat_dim => Ok(rw),
            Reply::Configured { stat_dim } => {
                bail!("worker `{host}` reports stat_dim {stat_dim}, expected {}", rw.stat_dim)
            }
            _ => Err(rw.desync("unexpected reply to configure")),
        }
    }

    /// Eager mode: ship the **full** dataset, layout-preserving, chunk
    /// by chunk, then seal. Every remote worker holds all rows so it
    /// can adopt an evicted peer's global ranges later (the same
    /// reason the threaded pool's workers share one `Arc<Dataset>`).
    pub fn ship_dataset(&self, ds: &Dataset) -> Result<()> {
        for chunk in dataset_chunks(ds) {
            match self.rpc(Request::Chunk(chunk))? {
                Reply::Ok => {}
                _ => return Err(self.desync("unexpected reply to dataset chunk")),
            }
        }
        match self.rpc(Request::Seal)? {
            Reply::Ok => Ok(()),
            _ => Err(self.desync("unexpected reply to seal")),
        }
    }

    /// One request/reply exchange. Transport and protocol failures mark
    /// the connection dead and come back as [`NetDown`]; a daemon-side
    /// [`Reply::Error`] becomes a plain (deterministic) error.
    fn rpc(&self, req: Request) -> Result<Reply> {
        let mut c = self.conn.lock().expect("remote conn lock");
        if let Some(why) = &c.dead {
            let what = why.clone();
            return Err(anyhow::Error::new(NetDown { peer: self.peer.clone(), what }));
        }
        let m = net_metrics();
        let (t, body) = req.encode();
        let t0 = Instant::now();
        let sent = match write_frame(&mut c.stream, t, &body) {
            Ok(n) => n,
            Err(e) => return Err(self.die(&mut c, format!("send failed: {e}"))),
        };
        m.bytes_tx.add(sent as u64);
        let (mt, payload, recvd) = match read_frame(&mut c.stream) {
            Ok(f) => f,
            Err(e) => return Err(self.die(&mut c, format!("receive failed: {e}"))),
        };
        m.bytes_rx.add(recvd as u64);
        m.rtt_nanos.observe_duration(t0.elapsed());
        match Reply::decode(mt, &payload) {
            Ok(Reply::Error { msg }) => bail!("remote worker `{}`: {msg}", self.peer),
            Ok(reply) => Ok(reply),
            Err(e) => Err(self.die(&mut c, format!("bad reply: {e}"))),
        }
    }

    fn die(&self, c: &mut Conn, what: String) -> anyhow::Error {
        crate::log_warn!("net: connection to worker `{}` is down: {what}", self.peer);
        self.gauge.set(0);
        c.dead = Some(what.clone());
        anyhow::Error::new(NetDown { peer: self.peer.clone(), what })
    }

    /// A well-formed frame of the wrong kind: the two sides no longer
    /// agree where they are in the conversation, so the connection
    /// cannot be trusted either.
    fn desync(&self, what: &str) -> anyhow::Error {
        let mut c = self.conn.lock().expect("remote conn lock");
        self.die(&mut c, what.to_string())
    }
}

impl WorkerBackend for RemoteWorker {
    fn step(&mut self, input: &StepInput) -> Result<PartialStats> {
        self.step_ranges(input, &[])
    }

    fn step_ranges(&mut self, input: &StepInput, extra: &[Range<usize>]) -> Result<PartialStats> {
        let round = self.round.fetch_add(1, Ordering::Relaxed) + 1;
        let req = Request::Step { round, input: input.clone(), extra: extra.to_vec() };
        match self.rpc(req)? {
            Reply::Stepped { round: r, stats } if r == round => Ok(stats),
            Reply::Stepped { round: r, .. } => {
                Err(self.desync(&format!("step reply for round {r}, expected {round}")))
            }
            _ => Err(self.desync("unexpected reply to step")),
        }
    }

    fn stat_dim(&self) -> usize {
        self.stat_dim
    }

    fn rng_state(&self) -> Option<RngState> {
        match self.rpc(Request::GetRng) {
            Ok(Reply::Rng { state }) => state,
            // the checkpoint layer treats an unanswerable worker like a
            // backend without a restorable RNG: the gap is recorded and
            // `--resume` rejects the file
            Ok(_) => {
                let _ = self.desync("unexpected reply to rng capture");
                None
            }
            Err(_) => None,
        }
    }

    fn set_rng_state(&mut self, state: RngState) -> Result<()> {
        match self.rpc(Request::SetRng(state))? {
            Reply::Ok => Ok(()),
            _ => Err(self.desync("unexpected reply to rng restore")),
        }
    }

    fn ingest(&mut self, chunk: &ParsedChunk) -> Result<()> {
        match self.rpc(Request::Chunk(chunk_from_parsed(chunk)))? {
            Reply::Ok => Ok(()),
            _ => Err(self.desync("unexpected reply to streamed chunk")),
        }
    }

    fn seal(&mut self) -> Result<()> {
        match self.rpc(Request::Seal)? {
            Reply::Ok => Ok(()),
            _ => Err(self.desync("unexpected reply to seal")),
        }
    }
}

impl Drop for RemoteWorker {
    fn drop(&mut self) {
        if let Ok(c) = self.conn.get_mut() {
            if c.dead.is_none() {
                // best effort: let the daemon end its session cleanly
                let (t, body) = Request::Shutdown.encode();
                if write_frame(&mut c.stream, t, &body).is_ok() {
                    let _ = read_frame(&mut c.stream);
                }
            }
        }
        self.gauge.set(0);
    }
}
