//! Message bodies of the cluster wire protocol (DESIGN.md §15).
//!
//! A [`Request`] flows coordinator → daemon, a [`Reply`] flows back;
//! each variant owns a frame message-type byte (requests `0x01..`,
//! replies `0x81..`). Encoding is explicit, field by field, little-
//! endian, with **every float written as its IEEE bit pattern**
//! (`f32::to_bits` / `f64::to_bits`) — a statistic or weight crosses
//! the wire bit-exactly, which is what lets a `Remote` run reproduce a
//! `Threads` run to the last bit.
//!
//! Decoding mirrors [`frame`](super::frame)'s discipline: every vector
//! read validates its length prefix against the bytes actually
//! remaining *before* allocating, so a corrupt count cannot
//! over-allocate; all failures are structured
//! [`WireError`](super::frame::WireError)s.

use std::ops::Range;
use std::sync::Arc;

use crate::backend::{RngState, StepInput};
use crate::config::{Algo, Topology};
use crate::data::stream::ParsedChunk;
use crate::data::{Dataset, Features, Task};
use crate::linalg::packed::SymPacked;
use crate::linalg::Mat;
use crate::solver::PartialStats;

use super::frame::WireError;

// ---------------------------------------------------------------- codec

/// Append-only encoder over a byte buffer.
pub struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    pub fn new() -> Enc {
        Enc { buf: Vec::new() }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32_bits(&mut self, v: f32) {
        self.u32(v.to_bits());
    }

    pub fn f64_bits(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    pub fn bool(&mut self, v: bool) {
        self.u8(v as u8);
    }

    pub fn range(&mut self, r: &Range<usize>) {
        self.u64(r.start as u64);
        self.u64(r.end as u64);
    }

    pub fn vec_f32(&mut self, v: &[f32]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.f32_bits(x);
        }
    }

    pub fn vec_u32(&mut self, v: &[u32]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.u32(x);
        }
    }

    pub fn vec_usize(&mut self, v: &[usize]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.u64(x as u64);
        }
    }

    pub fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }
}

impl Default for Enc {
    fn default() -> Self {
        Self::new()
    }
}

/// Cursor decoder over a received payload. Every read checks the bytes
/// remaining first; length-prefixed reads validate the prefix against
/// the remainder **before** allocating.
pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    pub fn new(buf: &'a [u8]) -> Dec<'a> {
        Dec { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(WireError::Truncated { need: n, have: self.remaining() });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32, WireError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64, WireError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    pub fn f32_bits(&mut self) -> Result<f32, WireError> {
        Ok(f32::from_bits(self.u32()?))
    }

    pub fn f64_bits(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub fn bool(&mut self) -> Result<bool, WireError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            b => Err(WireError::BadValue(format!("bool byte {b}"))),
        }
    }

    pub fn usize(&mut self) -> Result<usize, WireError> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| WireError::BadValue(format!("{v} exceeds usize")))
    }

    pub fn range(&mut self) -> Result<Range<usize>, WireError> {
        let (start, end) = (self.usize()?, self.usize()?);
        if start > end {
            return Err(WireError::BadValue(format!("range {start}..{end} is inverted")));
        }
        Ok(start..end)
    }

    /// Validated length prefix for elements of `elem_size` bytes.
    fn len_prefix(&mut self, elem_size: usize) -> Result<usize, WireError> {
        let len = self.usize()?;
        let need = len.checked_mul(elem_size).ok_or_else(|| {
            WireError::BadValue(format!("vector length {len} overflows the payload"))
        })?;
        if need > self.remaining() {
            return Err(WireError::Truncated { need, have: self.remaining() });
        }
        Ok(len)
    }

    pub fn vec_f32(&mut self) -> Result<Vec<f32>, WireError> {
        let len = self.len_prefix(4)?;
        let mut v = Vec::with_capacity(len);
        for _ in 0..len {
            v.push(self.f32_bits()?);
        }
        Ok(v)
    }

    pub fn vec_u32(&mut self) -> Result<Vec<u32>, WireError> {
        let len = self.len_prefix(4)?;
        let mut v = Vec::with_capacity(len);
        for _ in 0..len {
            v.push(self.u32()?);
        }
        Ok(v)
    }

    pub fn vec_usize(&mut self) -> Result<Vec<usize>, WireError> {
        let len = self.len_prefix(8)?;
        let mut v = Vec::with_capacity(len);
        for _ in 0..len {
            v.push(self.usize()?);
        }
        Ok(v)
    }

    pub fn str(&mut self) -> Result<String, WireError> {
        let len = self.len_prefix(1)?;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| WireError::BadValue("non-UTF-8 string".into()))
    }

    /// The payload must be fully consumed — trailing garbage means the
    /// two sides disagree about the message layout.
    pub fn finish(self) -> Result<(), WireError> {
        if self.remaining() != 0 {
            return Err(WireError::BadValue(format!(
                "{} trailing bytes after the message",
                self.remaining()
            )));
        }
        Ok(())
    }
}

// ------------------------------------------------------------- messages

/// Frame message-type bytes, requests.
pub mod msg {
    pub const CONFIGURE: u8 = 0x01;
    pub const CHUNK: u8 = 0x02;
    pub const SEAL: u8 = 0x03;
    pub const STEP: u8 = 0x04;
    pub const GET_RNG: u8 = 0x05;
    pub const SET_RNG: u8 = 0x06;
    pub const SHUTDOWN: u8 = 0x07;
    pub const R_CONFIGURED: u8 = 0x81;
    pub const R_OK: u8 = 0x82;
    pub const R_STEPPED: u8 = 0x83;
    pub const R_RNG: u8 = 0x84;
    pub const R_ERROR: u8 = 0x85;
}

/// Everything a daemon needs to build its `NativeWorker` — the same
/// arguments `backend::make_workers` / `make_stream_workers` pass
/// in-process, so the remote worker's RNG stream and shard rows are
/// identical to the threaded pool's.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkerSpec {
    pub wid: u64,
    pub seed: u64,
    pub algo: Algo,
    pub task: Task,
    pub eps_clamp: f32,
    /// feature dimensionality
    pub k: usize,
    /// total corpus rows (eager mode: the daemon receives all of them)
    pub n: usize,
    /// this worker's own global row range (eager) or shard window
    /// (streamed)
    pub range: Range<usize>,
    /// streamed mode: only the window's rows arrive, and the worker
    /// cannot adopt ranges after an eviction
    pub streamed: bool,
}

/// One shipped block of rows, **layout-preserving**: a Dense dataset
/// ships dense and a Sparse one ships CSR, because the two compute
/// paths accumulate in different orders and only the original layout
/// reproduces the in-process bits.
#[derive(Clone, Debug, PartialEq)]
pub enum ChunkData {
    Sparse {
        start: usize,
        labels: Vec<f32>,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f32>,
    },
    Dense {
        start: usize,
        k: usize,
        labels: Vec<f32>,
        /// row-major `[labels.len(), k]`
        data: Vec<f32>,
    },
}

impl ChunkData {
    pub fn rows(&self) -> usize {
        match self {
            ChunkData::Sparse { labels, .. } | ChunkData::Dense { labels, .. } => labels.len(),
        }
    }

    pub fn start(&self) -> usize {
        match self {
            ChunkData::Sparse { start, .. } | ChunkData::Dense { start, .. } => *start,
        }
    }
}

/// Coordinator → daemon.
#[derive(Debug)]
pub enum Request {
    Configure(WorkerSpec),
    Chunk(ChunkData),
    Seal,
    Step { round: u64, input: StepInput, extra: Vec<Range<usize>> },
    GetRng,
    SetRng(RngState),
    Shutdown,
}

/// Daemon → coordinator.
#[derive(Debug)]
pub enum Reply {
    /// Configure accepted; echoes the statistics width for validation.
    Configured { stat_dim: usize },
    /// Chunk / Seal / SetRng / Shutdown accepted.
    Ok,
    /// A step's partial statistics, tagged with the request's round id.
    Stepped { round: u64, stats: PartialStats },
    /// The worker's sampler-RNG state (`None`: not restorable).
    Rng { state: Option<RngState> },
    /// A deterministic worker-side failure, surfaced as a normal error
    /// (distinct from the connection dying, which is an eviction).
    Error { msg: String },
}

fn enc_algo(e: &mut Enc, a: Algo) {
    e.u8(match a {
        Algo::Em => 0,
        Algo::Mc => 1,
    });
}

fn dec_algo(d: &mut Dec) -> Result<Algo, WireError> {
    match d.u8()? {
        0 => Ok(Algo::Em),
        1 => Ok(Algo::Mc),
        t => Err(WireError::BadValue(format!("algo tag {t}"))),
    }
}

fn enc_task(e: &mut Enc, t: Task) {
    match t {
        Task::Binary => e.u8(0),
        Task::Regression => e.u8(1),
        Task::Multiclass(m) => {
            e.u8(2);
            e.u64(m as u64);
        }
    }
}

fn dec_task(d: &mut Dec) -> Result<Task, WireError> {
    match d.u8()? {
        0 => Ok(Task::Binary),
        1 => Ok(Task::Regression),
        2 => Ok(Task::Multiclass(d.usize()?)),
        t => Err(WireError::BadValue(format!("task tag {t}"))),
    }
}

fn enc_rng(e: &mut Enc, s: &RngState) {
    e.u64(s.state as u64);
    e.u64((s.state >> 64) as u64);
    e.u64(s.inc as u64);
    e.u64((s.inc >> 64) as u64);
    match s.spare {
        None => e.u8(0),
        Some(v) => {
            e.u8(1);
            e.f64_bits(v);
        }
    }
}

fn dec_rng(d: &mut Dec) -> Result<RngState, WireError> {
    let state = (d.u64()? as u128) | ((d.u64()? as u128) << 64);
    let inc = (d.u64()? as u128) | ((d.u64()? as u128) << 64);
    let spare = match d.u8()? {
        0 => None,
        1 => Some(d.f64_bits()?),
        t => Err(WireError::BadValue(format!("rng spare tag {t}")))?,
    };
    Ok(RngState { state, inc, spare })
}

fn enc_input(e: &mut Enc, input: &StepInput) {
    match input {
        StepInput::Binary { w } => {
            e.u8(0);
            e.vec_f32(w);
        }
        StepInput::Svr { w, eps_ins } => {
            e.u8(1);
            e.f32_bits(*eps_ins);
            e.vec_f32(w);
        }
        StepInput::Mlt { w_all, yidx } => {
            e.u8(2);
            e.u64(*yidx as u64);
            e.u64(w_all.rows as u64);
            e.u64(w_all.cols as u64);
            e.vec_f32(&w_all.data);
        }
    }
}

fn dec_input(d: &mut Dec) -> Result<StepInput, WireError> {
    match d.u8()? {
        0 => Ok(StepInput::Binary { w: Arc::new(d.vec_f32()?) }),
        1 => {
            let eps_ins = d.f32_bits()?;
            Ok(StepInput::Svr { w: Arc::new(d.vec_f32()?), eps_ins })
        }
        2 => {
            let yidx = d.usize()?;
            let (rows, cols) = (d.usize()?, d.usize()?);
            let data = d.vec_f32()?;
            if data.len() != rows.checked_mul(cols).unwrap_or(usize::MAX) {
                return Err(WireError::BadValue(format!(
                    "MLT weight block {}x{} carries {} floats",
                    rows,
                    cols,
                    data.len()
                )));
            }
            if yidx >= rows {
                return Err(WireError::BadValue(format!("class index {yidx} >= {rows}")));
            }
            Ok(StepInput::Mlt { w_all: Arc::new(Mat { rows, cols, data }), yidx })
        }
        t => Err(WireError::BadValue(format!("step input tag {t}"))),
    }
}

fn enc_stats(e: &mut Enc, s: &PartialStats) {
    e.u64(s.sigma.dim() as u64);
    e.vec_f32(&s.sigma.data);
    e.vec_f32(&s.mu);
    e.f64_bits(s.obj);
    e.f64_bits(s.aux);
}

fn dec_stats(d: &mut Dec) -> Result<PartialStats, WireError> {
    let k = d.usize()?;
    let data = d.vec_f32()?;
    if data.len() != SymPacked::packed_len(k) {
        return Err(WireError::BadValue(format!(
            "packed sigma for k={k} needs {} floats, got {}",
            SymPacked::packed_len(k),
            data.len()
        )));
    }
    let mu = d.vec_f32()?;
    if mu.len() != k {
        return Err(WireError::BadValue(format!("mu length {} != k {k}", mu.len())));
    }
    let mut sigma = SymPacked::zeros(k);
    sigma.data = data;
    Ok(PartialStats { sigma, mu, obj: d.f64_bits()?, aux: d.f64_bits()? })
}

fn enc_chunk(e: &mut Enc, c: &ChunkData) {
    match c {
        ChunkData::Sparse { start, labels, indptr, indices, values } => {
            e.u8(0);
            e.u64(*start as u64);
            e.vec_f32(labels);
            e.vec_usize(indptr);
            e.vec_u32(indices);
            e.vec_f32(values);
        }
        ChunkData::Dense { start, k, labels, data } => {
            e.u8(1);
            e.u64(*start as u64);
            e.u64(*k as u64);
            e.vec_f32(labels);
            e.vec_f32(data);
        }
    }
}

fn dec_chunk(d: &mut Dec) -> Result<ChunkData, WireError> {
    match d.u8()? {
        0 => {
            let start = d.usize()?;
            let labels = d.vec_f32()?;
            let indptr = d.vec_usize()?;
            let indices = d.vec_u32()?;
            let values = d.vec_f32()?;
            if indptr.len() != labels.len() + 1 {
                return Err(WireError::BadValue(format!(
                    "chunk indptr length {} != rows + 1 ({})",
                    indptr.len(),
                    labels.len() + 1
                )));
            }
            if indices.len() != values.len() {
                return Err(WireError::BadValue("chunk indices/values length skew".into()));
            }
            Ok(ChunkData::Sparse { start, labels, indptr, indices, values })
        }
        1 => {
            let start = d.usize()?;
            let k = d.usize()?;
            let labels = d.vec_f32()?;
            let data = d.vec_f32()?;
            if data.len() != labels.len().checked_mul(k).unwrap_or(usize::MAX) {
                return Err(WireError::BadValue(format!(
                    "dense chunk of {} rows x {k} carries {} floats",
                    labels.len(),
                    data.len()
                )));
            }
            Ok(ChunkData::Dense { start, k, labels, data })
        }
        t => Err(WireError::BadValue(format!("chunk layout tag {t}"))),
    }
}

impl Request {
    /// `(frame msg type, payload bytes)`.
    pub fn encode(&self) -> (u8, Vec<u8>) {
        let mut e = Enc::new();
        let t = match self {
            Request::Configure(spec) => {
                e.u64(spec.wid);
                e.u64(spec.seed);
                enc_algo(&mut e, spec.algo);
                enc_task(&mut e, spec.task);
                e.f32_bits(spec.eps_clamp);
                e.u64(spec.k as u64);
                e.u64(spec.n as u64);
                e.range(&spec.range);
                e.bool(spec.streamed);
                msg::CONFIGURE
            }
            Request::Chunk(c) => {
                enc_chunk(&mut e, c);
                msg::CHUNK
            }
            Request::Seal => msg::SEAL,
            Request::Step { round, input, extra } => {
                e.u64(*round);
                e.u64(extra.len() as u64);
                for r in extra {
                    e.range(r);
                }
                enc_input(&mut e, input);
                msg::STEP
            }
            Request::GetRng => msg::GET_RNG,
            Request::SetRng(s) => {
                enc_rng(&mut e, s);
                msg::SET_RNG
            }
            Request::Shutdown => msg::SHUTDOWN,
        };
        (t, e.into_bytes())
    }

    pub fn decode(msg_type: u8, payload: &[u8]) -> Result<Request, WireError> {
        let mut d = Dec::new(payload);
        let req = match msg_type {
            msg::CONFIGURE => {
                let wid = d.u64()?;
                let seed = d.u64()?;
                let algo = dec_algo(&mut d)?;
                let task = dec_task(&mut d)?;
                let eps_clamp = d.f32_bits()?;
                let k = d.usize()?;
                let n = d.usize()?;
                let range = d.range()?;
                let streamed = d.bool()?;
                Request::Configure(WorkerSpec {
                    wid,
                    seed,
                    algo,
                    task,
                    eps_clamp,
                    k,
                    n,
                    range,
                    streamed,
                })
            }
            msg::CHUNK => Request::Chunk(dec_chunk(&mut d)?),
            msg::SEAL => Request::Seal,
            msg::STEP => {
                let round = d.u64()?;
                let n_extra = d.len_prefix(16)?;
                let mut extra = Vec::with_capacity(n_extra);
                for _ in 0..n_extra {
                    extra.push(d.range()?);
                }
                let input = dec_input(&mut d)?;
                Request::Step { round, input, extra }
            }
            msg::GET_RNG => Request::GetRng,
            msg::SET_RNG => Request::SetRng(dec_rng(&mut d)?),
            msg::SHUTDOWN => Request::Shutdown,
            t => return Err(WireError::UnknownMsg(t)),
        };
        d.finish()?;
        Ok(req)
    }
}

impl Reply {
    /// `(frame msg type, payload bytes)`.
    pub fn encode(&self) -> (u8, Vec<u8>) {
        let mut e = Enc::new();
        let t = match self {
            Reply::Configured { stat_dim } => {
                e.u64(*stat_dim as u64);
                msg::R_CONFIGURED
            }
            Reply::Ok => msg::R_OK,
            Reply::Stepped { round, stats } => {
                e.u64(*round);
                enc_stats(&mut e, stats);
                msg::R_STEPPED
            }
            Reply::Rng { state } => {
                match state {
                    None => e.u8(0),
                    Some(s) => {
                        e.u8(1);
                        enc_rng(&mut e, s);
                    }
                }
                msg::R_RNG
            }
            Reply::Error { msg: m } => {
                e.str(m);
                msg::R_ERROR
            }
        };
        (t, e.into_bytes())
    }

    pub fn decode(msg_type: u8, payload: &[u8]) -> Result<Reply, WireError> {
        let mut d = Dec::new(payload);
        let reply = match msg_type {
            msg::R_CONFIGURED => Reply::Configured { stat_dim: d.usize()? },
            msg::R_OK => Reply::Ok,
            msg::R_STEPPED => {
                let round = d.u64()?;
                Reply::Stepped { round, stats: dec_stats(&mut d)? }
            }
            msg::R_RNG => match d.u8()? {
                0 => Reply::Rng { state: None },
                1 => Reply::Rng { state: Some(dec_rng(&mut d)?) },
                t => return Err(WireError::BadValue(format!("rng presence tag {t}"))),
            },
            msg::R_ERROR => Reply::Error { msg: d.str()? },
            t => return Err(WireError::UnknownMsg(t)),
        };
        d.finish()?;
        Ok(reply)
    }
}

// --------------------------------------------------- dataset chunking

/// Rows per shipped chunk when a full eager dataset crosses the wire.
/// Small enough to keep frames a few MB at bench-scale k, large enough
/// that per-frame overhead is noise.
pub const SHIP_ROWS: usize = 8192;

/// Slice `ds` into layout-preserving [`ChunkData`] blocks of at most
/// [`SHIP_ROWS`] rows.
pub fn dataset_chunks(ds: &Dataset) -> Vec<ChunkData> {
    let mut out = Vec::new();
    let mut start = 0usize;
    while start < ds.n {
        let end = (start + SHIP_ROWS).min(ds.n);
        let labels = ds.labels[start..end].to_vec();
        out.push(match &ds.features {
            Features::Dense { data } => ChunkData::Dense {
                start,
                k: ds.k,
                labels,
                data: data[start * ds.k..end * ds.k].to_vec(),
            },
            Features::Sparse { indptr, indices, values } => {
                let (a, b) = (indptr[start], indptr[end]);
                ChunkData::Sparse {
                    start,
                    labels,
                    indptr: indptr[start..=end].iter().map(|&p| p - a).collect(),
                    indices: indices[a..b].to_vec(),
                    values: values[a..b].to_vec(),
                }
            }
        });
        start = end;
    }
    out
}

/// The streamed path's bridge: a [`ParsedChunk`] (always CSR) as wire
/// data.
pub fn chunk_from_parsed(chunk: &ParsedChunk) -> ChunkData {
    let (labels, indptr, indices, values) = chunk.raw_parts();
    ChunkData::Sparse {
        start: chunk.start(),
        labels: labels.to_vec(),
        indptr: indptr.to_vec(),
        indices: indices.to_vec(),
        values: values.to_vec(),
    }
}

/// Host list of a [`Topology::Remote`] config, or `None`.
pub fn remote_hosts(t: &Topology) -> Option<&[String]> {
    match t {
        Topology::Remote(hosts) => Some(hosts),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_req(req: &Request) -> Request {
        let (t, p) = req.encode();
        Request::decode(t, &p).unwrap()
    }

    #[test]
    fn request_roundtrips() {
        let spec = WorkerSpec {
            wid: 3,
            seed: 42,
            algo: Algo::Mc,
            task: Task::Multiclass(7),
            eps_clamp: 1e-5,
            k: 64,
            n: 1000,
            range: 250..500,
            streamed: false,
        };
        match roundtrip_req(&Request::Configure(spec.clone())) {
            Request::Configure(s) => assert_eq!(s, spec),
            other => panic!("bad decode: {other:?}"),
        }
        let input = StepInput::Svr { w: Arc::new(vec![1.5, -2.25, f32::MIN_POSITIVE]), eps_ins: 0.1 };
        match roundtrip_req(&Request::Step { round: 9, input, extra: vec![10..20, 30..40] }) {
            Request::Step { round, input: StepInput::Svr { w, eps_ins }, extra } => {
                assert_eq!(round, 9);
                assert_eq!(*w, vec![1.5, -2.25, f32::MIN_POSITIVE]);
                assert_eq!(eps_ins, 0.1);
                assert_eq!(extra, vec![10..20, 30..40]);
            }
            other => panic!("bad decode: {other:?}"),
        }
    }

    #[test]
    fn stats_bits_survive() {
        let mut s = PartialStats::zeros(3);
        s.sigma.data.copy_from_slice(&[1.0, -0.5, 2.5, 1e-30, f32::MAX, -0.0]);
        s.mu = vec![0.1, 0.2, 0.3];
        s.obj = std::f64::consts::PI;
        s.aux = -7.25;
        let (t, p) = Reply::Stepped { round: 4, stats: s.clone() }.encode();
        match Reply::decode(t, &p).unwrap() {
            Reply::Stepped { round, stats } => {
                assert_eq!(round, 4);
                let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&stats.sigma.data), bits(&s.sigma.data));
                assert_eq!(bits(&stats.mu), bits(&s.mu));
                assert_eq!(stats.obj.to_bits(), s.obj.to_bits());
                assert_eq!(stats.aux.to_bits(), s.aux.to_bits());
            }
            other => panic!("bad decode: {other:?}"),
        }
    }

    #[test]
    fn rng_state_roundtrips() {
        let s = RngState { state: u128::MAX - 7, inc: 12345, spare: Some(-0.75) };
        let (t, p) = Request::SetRng(s).encode();
        match Request::decode(t, &p).unwrap() {
            Request::SetRng(got) => assert_eq!(got, s),
            other => panic!("bad decode: {other:?}"),
        }
    }

    #[test]
    fn dataset_chunks_preserve_layout_and_rows() {
        let ds = crate::data::synth::alpha_like(100, 8, 1);
        let chunks = dataset_chunks(&ds);
        assert_eq!(chunks.iter().map(ChunkData::rows).sum::<usize>(), ds.n);
        // alpha_like is dense: the layout must survive the wire
        assert!(chunks.iter().all(|c| matches!(c, ChunkData::Dense { .. })));
    }
}
