//! Small TCP plumbing shared by the two listeners in this crate — the
//! `pemsvm serve` prediction front-end (`serve::server`) and the
//! `pemsvm worker` cluster daemon ([`super::worker`]): the accept loop
//! with peer-address tagging, and per-stream socket configuration.

use std::net::{TcpListener, TcpStream};
use std::time::Duration;

/// What the connection handler wants the accept loop to do next.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum After {
    /// keep accepting
    Continue,
    /// leave the loop (e.g. a `--once` daemon after its session ends)
    Stop,
}

/// The peer address as a log/metric tag; `"unknown"` if the socket
/// cannot say (already reset, etc.).
pub fn peer_tag(stream: &TcpStream) -> String {
    stream.peer_addr().map(|a| a.to_string()).unwrap_or_else(|_| "unknown".into())
}

/// Configure one protocol stream: Nagle off (the wire protocol is
/// request/reply, latency-bound) and an optional read timeout.
pub fn configure(stream: &TcpStream, read_timeout: Option<Duration>) -> std::io::Result<()> {
    stream.set_nodelay(true)?;
    stream.set_read_timeout(read_timeout)
}

/// Run the accept loop: hand each connection (plus its peer tag) to
/// `handle`, skip failed accepts, stop when the handler says
/// [`After::Stop`]. The handler decides its own concurrency — `serve`
/// spawns a thread per connection and returns [`After::Continue`]
/// immediately, the worker daemon runs its single session inline.
pub fn accept_loop<F>(listener: &TcpListener, mut handle: F)
where
    F: FnMut(TcpStream, String) -> After,
{
    for stream in listener.incoming() {
        let Ok(stream) = stream else { continue };
        let peer = peer_tag(&stream);
        if handle(stream, peer) == After::Stop {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};

    #[test]
    fn accept_loop_stops_on_request() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let mut seen = Vec::new();
            accept_loop(&listener, |mut stream, peer| {
                assert!(peer.starts_with("127.0.0.1:"), "peer tag: {peer}");
                let mut byte = [0u8; 1];
                stream.read_exact(&mut byte).unwrap();
                seen.push(byte[0]);
                if byte[0] == b'q' {
                    After::Stop
                } else {
                    After::Continue
                }
            });
            seen
        });
        for b in [b'a', b'q'] {
            let mut c = TcpStream::connect(addr).unwrap();
            c.write_all(&[b]).unwrap();
        }
        assert_eq!(server.join().unwrap(), vec![b'a', b'q']);
    }
}
