//! Lower-packed symmetric matrix storage.
//!
//! `Sigma^p` is symmetric and the workers only ever fill its lower
//! triangle (paper §4.1: one triangle is all a worker needs to submit).
//! Storing the `k(k+1)/2` packed floats instead of a full `k x k`
//! matrix halves merge bandwidth in the tree reduce, halves the
//! reduce-buffer memory, and halves the `reset` traffic per iteration;
//! the master unpacks exactly once per solve.

use std::ops::{Index, IndexMut};

use super::Mat;

/// Symmetric `k x k` matrix stored as its lower triangle, row-packed:
/// row `i` occupies `data[i(i+1)/2 .. i(i+1)/2 + i + 1]`, holding the
/// entries `(i, 0..=i)`.
#[derive(Clone, Debug, PartialEq)]
pub struct SymPacked {
    k: usize,
    pub data: Vec<f32>,
}

impl SymPacked {
    /// Packed length for dimension `k`.
    #[inline]
    pub fn packed_len(k: usize) -> usize {
        k * (k + 1) / 2
    }

    /// Offset of packed row `i` (its entries are `(i, 0..=i)`).
    #[inline]
    pub fn row_offset(i: usize) -> usize {
        i * (i + 1) / 2
    }

    pub fn zeros(k: usize) -> Self {
        SymPacked { k, data: vec![0.0; Self::packed_len(k)] }
    }

    /// Matrix dimension (the `k` of `k x k`).
    #[inline]
    pub fn dim(&self) -> usize {
        self.k
    }

    /// Packed row `i`: the `i + 1` entries `(i, 0..=i)`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        let off = Self::row_offset(i);
        &self.data[off..off + i + 1]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let off = Self::row_offset(i);
        &mut self.data[off..off + i + 1]
    }

    pub fn fill(&mut self, v: f32) {
        self.data.fill(v);
    }

    /// self += other (the reduce/merge operator); dims must match.
    pub fn add_assign(&mut self, other: &SymPacked) {
        assert_eq!(self.k, other.k);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// Pack the lower triangle of a square `Mat` (the upper triangle is
    /// ignored, matching how the rank-update kernels fill a `Mat`).
    pub fn from_mat_lower(m: &Mat) -> SymPacked {
        assert_eq!(m.rows, m.cols);
        let k = m.rows;
        let mut data = Vec::with_capacity(Self::packed_len(k));
        for i in 0..k {
            data.extend_from_slice(&m.row(i)[..i + 1]);
        }
        SymPacked { k, data }
    }

    /// Unpack into a full symmetric `Mat` (both triangles mirrored).
    /// The master solve calls this exactly once per iteration.
    pub fn unpack(&self) -> Mat {
        let k = self.k;
        let mut m = Mat::zeros(k, k);
        for i in 0..k {
            let row = self.row(i);
            for (j, &v) in row.iter().enumerate() {
                m.data[i * k + j] = v;
                m.data[j * k + i] = v;
            }
        }
        m
    }

    /// Max |a_ij - b_ij| over the packed entries.
    pub fn max_abs_diff(&self, other: &SymPacked) -> f32 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// Symmetric indexing: `(i, j)` and `(j, i)` address the same entry.
impl Index<(usize, usize)> for SymPacked {
    type Output = f32;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        let (hi, lo) = if i >= j { (i, j) } else { (j, i) };
        &self.data[Self::row_offset(hi) + lo]
    }
}

impl IndexMut<(usize, usize)> for SymPacked {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        let (hi, lo) = if i >= j { (i, j) } else { (j, i) };
        &mut self.data[Self::row_offset(hi) + lo]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packed_layout_and_indexing() {
        let mut s = SymPacked::zeros(3);
        assert_eq!(s.data.len(), 6);
        s[(1, 0)] = 2.0;
        s[(2, 2)] = 5.0;
        // symmetric addressing
        assert_eq!(s[(0, 1)], 2.0);
        assert_eq!(s.row(1), &[2.0, 0.0]);
        assert_eq!(s.row(2), &[0.0, 0.0, 5.0]);
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let mut m = Mat::zeros(4, 4);
        let mut v = 1.0f32;
        for i in 0..4 {
            for j in 0..=i {
                m[(i, j)] = v;
                v += 1.0;
            }
        }
        // garbage in the upper triangle must be ignored
        m[(0, 3)] = 99.0;
        let p = SymPacked::from_mat_lower(&m);
        let full = p.unpack();
        for i in 0..4 {
            for j in 0..4 {
                let want = if i >= j { m[(i, j)] } else { m[(j, i)] };
                assert_eq!(full[(i, j)], want, "({i},{j})");
            }
        }
        assert_eq!(SymPacked::from_mat_lower(&full), p);
    }

    #[test]
    fn add_assign_matches_mat_add() {
        let mut a = SymPacked::zeros(3);
        let mut b = SymPacked::zeros(3);
        a[(2, 1)] = 1.5;
        b[(2, 1)] = 2.0;
        b[(0, 0)] = -1.0;
        let want = {
            let mut m = a.unpack();
            m.add_assign(&b.unpack());
            m
        };
        a.add_assign(&b);
        assert_eq!(a.unpack(), want);
    }
}
