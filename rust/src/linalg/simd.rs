//! Runtime ISA dispatch for the hot kernels.
//!
//! The native backend picks one of three code paths once per process
//! (first use, cached in a `OnceLock`): AVX2+FMA on x86_64 when the CPU
//! reports both features, NEON on aarch64 (baseline there), or the
//! portable scalar path everywhere else. The scalar implementations are
//! the pre-SIMD kernels, kept callable so benches and property tests
//! can compare paths on the same machine.
//!
//! Numerical contract: `axpy` vectorizes element-wise multiply-then-add
//! (no FMA contraction, no reassociation), so it stays bit-identical to
//! the scalar loop — the serving layer relies on that (`model::
//! class_scores_block` must equal `class_scores` exactly). `dot` and the
//! rank-update kernels may reassociate the sum, so callers compare them
//! under tolerance, never bit-equality.

use std::sync::OnceLock;

/// Which micro-kernel family `active_isa` selected for this process.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelIsa {
    /// x86_64 with AVX2 and FMA (256-bit, fused multiply-add).
    Avx2Fma,
    /// aarch64 NEON (128-bit; baseline on that architecture).
    Neon,
    /// Portable fallback: the pre-SIMD unrolled scalar kernels.
    Scalar,
}

impl KernelIsa {
    /// Short stable name for logs and bench output.
    pub fn name(self) -> &'static str {
        match self {
            KernelIsa::Avx2Fma => "avx2+fma",
            KernelIsa::Neon => "neon",
            KernelIsa::Scalar => "scalar",
        }
    }
}

static ISA: OnceLock<KernelIsa> = OnceLock::new();

/// The ISA path the kernels will use, detected once per process.
pub fn active_isa() -> KernelIsa {
    *ISA.get_or_init(detect)
}

fn detect() -> KernelIsa {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            return KernelIsa::Avx2Fma;
        }
    }
    if cfg!(target_arch = "aarch64") {
        KernelIsa::Neon
    } else {
        KernelIsa::Scalar
    }
}

/// Dot product, dispatched to the active ISA. The vector paths use
/// multiple accumulators, so the f32 sum order differs from
/// [`dot_scalar`]; agreement is tolerance-level, not bit-level.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    {
        if active_isa() == KernelIsa::Avx2Fma {
            // SAFETY: active_isa verified avx2+fma on this CPU.
            return unsafe { dot_avx2(a, b) };
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if active_isa() == KernelIsa::Neon {
            return dot_neon(a, b);
        }
    }
    dot_scalar(a, b)
}

/// Scalar dot product with 4-way unrolling (the pre-SIMD kernel; the
/// compiler autovectorizes this shape reliably).
#[inline]
pub fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    let chunks = n / 4;
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for j in chunks * 4..n {
        s += a[j] * b[j];
    }
    s
}

/// a += alpha * b (axpy), dispatched to the active ISA. Every path
/// computes `a[i] + (alpha * b[i])` element-wise with both operations
/// rounded separately (multiply then add, never fused), so the result
/// is bit-identical across ISAs and to [`axpy_scalar`].
#[inline]
pub fn axpy(alpha: f32, b: &[f32], a: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    {
        if active_isa() == KernelIsa::Avx2Fma {
            // SAFETY: active_isa verified avx2 on this CPU.
            unsafe { axpy_avx2(alpha, b, a) };
            return;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if active_isa() == KernelIsa::Neon {
            axpy_neon(alpha, b, a);
            return;
        }
    }
    axpy_scalar(alpha, b, a);
}

/// Scalar axpy: `a[i] += alpha * b[i]`.
#[inline]
pub fn axpy_scalar(alpha: f32, b: &[f32], a: &mut [f32]) {
    for (ai, bi) in a.iter_mut().zip(b) {
        *ai += alpha * bi;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
    // SAFETY (caller): requires avx2+fma. Pointer reads stay inside the
    // first min(a.len(), b.len()) elements of both slices.
    use std::arch::x86_64::*;
    let n = a.len().min(b.len());
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut acc2 = _mm256_setzero_ps();
    let mut acc3 = _mm256_setzero_ps();
    let mut j = 0usize;
    while j + 32 <= n {
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(j)), _mm256_loadu_ps(bp.add(j)), acc0);
        acc1 = _mm256_fmadd_ps(
            _mm256_loadu_ps(ap.add(j + 8)),
            _mm256_loadu_ps(bp.add(j + 8)),
            acc1,
        );
        acc2 = _mm256_fmadd_ps(
            _mm256_loadu_ps(ap.add(j + 16)),
            _mm256_loadu_ps(bp.add(j + 16)),
            acc2,
        );
        acc3 = _mm256_fmadd_ps(
            _mm256_loadu_ps(ap.add(j + 24)),
            _mm256_loadu_ps(bp.add(j + 24)),
            acc3,
        );
        j += 32;
    }
    while j + 8 <= n {
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(j)), _mm256_loadu_ps(bp.add(j)), acc0);
        j += 8;
    }
    let acc = _mm256_add_ps(_mm256_add_ps(acc0, acc1), _mm256_add_ps(acc2, acc3));
    let mut lanes = [0f32; 8];
    _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
    let mut s = ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
        + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]));
    while j < n {
        s += *ap.add(j) * *bp.add(j);
        j += 1;
    }
    s
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy_avx2(alpha: f32, b: &[f32], a: &mut [f32]) {
    // SAFETY (caller): requires avx2. Pointer accesses stay inside the
    // first min(a.len(), b.len()) elements of both slices. Uses
    // mul-then-add (NOT fmadd) to keep bit-identity with axpy_scalar.
    use std::arch::x86_64::*;
    let n = a.len().min(b.len());
    let al = _mm256_set1_ps(alpha);
    let ap = a.as_mut_ptr();
    let bp = b.as_ptr();
    let mut j = 0usize;
    while j + 8 <= n {
        let v = _mm256_add_ps(
            _mm256_loadu_ps(ap.add(j)),
            _mm256_mul_ps(al, _mm256_loadu_ps(bp.add(j))),
        );
        _mm256_storeu_ps(ap.add(j), v);
        j += 8;
    }
    while j < n {
        *ap.add(j) += alpha * *bp.add(j);
        j += 1;
    }
}

#[cfg(target_arch = "aarch64")]
fn dot_neon(a: &[f32], b: &[f32]) -> f32 {
    use std::arch::aarch64::*;
    let n = a.len().min(b.len());
    // SAFETY: NEON is baseline on aarch64; reads stay inside the first
    // n elements of both slices.
    unsafe {
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let mut j = 0usize;
        while j + 8 <= n {
            acc0 = vfmaq_f32(acc0, vld1q_f32(ap.add(j)), vld1q_f32(bp.add(j)));
            acc1 = vfmaq_f32(acc1, vld1q_f32(ap.add(j + 4)), vld1q_f32(bp.add(j + 4)));
            j += 8;
        }
        let mut s = vaddvq_f32(vaddq_f32(acc0, acc1));
        while j < n {
            s += *ap.add(j) * *bp.add(j);
            j += 1;
        }
        s
    }
}

#[cfg(target_arch = "aarch64")]
fn axpy_neon(alpha: f32, b: &[f32], a: &mut [f32]) {
    use std::arch::aarch64::*;
    let n = a.len().min(b.len());
    // SAFETY: NEON is baseline on aarch64; accesses stay inside the
    // first n elements of both slices. vmulq + vaddq (not vfmaq) keeps
    // bit-identity with axpy_scalar.
    unsafe {
        let ap = a.as_mut_ptr();
        let bp = b.as_ptr();
        let al = vdupq_n_f32(alpha);
        let mut j = 0usize;
        while j + 4 <= n {
            let v = vaddq_f32(vld1q_f32(ap.add(j)), vmulq_f32(al, vld1q_f32(bp.add(j))));
            vst1q_f32(ap.add(j), v);
            j += 4;
        }
        while j < n {
            *ap.add(j) += alpha * *bp.add(j);
            j += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(n: usize, scale: f32, off: f32) -> Vec<f32> {
        (0..n).map(|i| off + (i as f32) * scale).collect()
    }

    #[test]
    fn detect_is_stable() {
        assert_eq!(active_isa(), active_isa());
    }

    #[test]
    fn dot_dispatched_matches_scalar_under_tolerance() {
        // lengths straddling every unroll boundary, incl. 0 and tails
        for n in [0usize, 1, 3, 4, 7, 8, 9, 31, 32, 33, 100, 257] {
            let a = seq(n, 0.013, -0.7);
            let b = seq(n, -0.029, 1.1);
            let want = dot_scalar(&a, &b);
            let got = dot(&a, &b);
            let tol = 1e-4 * (1.0 + want.abs());
            assert!((got - want).abs() <= tol, "n={n}: {got} vs {want}");
        }
    }

    #[test]
    fn axpy_dispatched_is_bit_identical_to_scalar() {
        for n in [0usize, 1, 3, 4, 5, 8, 9, 17, 64, 131] {
            let b = seq(n, 0.37, -2.0);
            let mut a1 = seq(n, -0.11, 0.5);
            let mut a2 = a1.clone();
            axpy(1.7, &b, &mut a1);
            axpy_scalar(1.7, &b, &mut a2);
            assert_eq!(a1, a2, "n={n}");
        }
    }
}
