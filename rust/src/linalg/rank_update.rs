//! The native-backend hot spot: `S += sum_d a_d x_d x_d^T` (Eq. 40).
//!
//! Dense and CSR-sparse variants accumulating into lower-packed
//! [`SymPacked`] storage — the paper notes (§4.1) that workers need only
//! submit one triangle, so nothing above the diagonal is ever written
//! or shipped. The dense kernel is runtime-dispatched (see
//! [`active_isa`](super::active_isa)): a rank-8 AVX2+FMA micro-kernel
//! with an L2-blocked loop over the output rows on x86_64, a rank-4
//! NEON kernel on aarch64, and the portable rank-4 scalar kernel
//! elsewhere. `symmetrize_from_lower` still mirrors a full `Mat` for
//! the (rare) callers that build one directly.

use super::simd::{active_isa, KernelIsa};
use super::{Mat, SymPacked};

/// Dense rank-1 updates over a row-block: `s += sum_d a[d] * x_d x_d^T`,
/// lower triangle only. `x` is row-major [n, k]; `s` is `k x k` packed.
///
/// Dispatches once per process to the widest kernel the CPU supports.
/// All paths produce the same result up to f32 accumulation order
/// (rank-8 FMA vs rank-4 separate multiply-add); within one process the
/// path is fixed, so repeated calls are bit-reproducible.
pub fn rank_update_dense(s: &mut SymPacked, x: &[f32], n: usize, k: usize, a: &[f32]) {
    debug_assert_eq!(s.dim(), k);
    debug_assert_eq!(x.len(), n * k);
    debug_assert_eq!(a.len(), n);
    #[cfg(target_arch = "x86_64")]
    {
        if active_isa() == KernelIsa::Avx2Fma {
            // SAFETY: active_isa verified avx2+fma; slice lengths are
            // checked by the debug asserts above and rechecked inside.
            unsafe { rank_update_dense_avx2(&mut s.data, x, n, k, a) };
            return;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if active_isa() == KernelIsa::Neon {
            rank_update_dense_neon(&mut s.data, x, n, k, a);
            return;
        }
    }
    rank_update_dense_scalar(s, x, n, k, a);
}

/// The portable scalar path: rows are processed four at a time (a
/// rank-4 SYRK micro-kernel), so the inner j-loop performs 4 fused
/// multiply-adds per store to `s`, quartering the dominant write
/// traffic — see EXPERIMENTS.md §Perf. Public so benches and property
/// tests can compare it against the dispatched path on any machine.
pub fn rank_update_dense_scalar(s: &mut SymPacked, x: &[f32], n: usize, k: usize, a: &[f32]) {
    debug_assert_eq!(s.dim(), k);
    debug_assert_eq!(x.len(), n * k);
    debug_assert_eq!(a.len(), n);
    let sd = &mut s.data;
    let blocks = n / 4;
    for blk in 0..blocks {
        let d = blk * 4;
        let (a0, a1, a2, a3) = (a[d], a[d + 1], a[d + 2], a[d + 3]);
        if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
            continue;
        }
        let r0 = &x[d * k..(d + 1) * k];
        let r1 = &x[(d + 1) * k..(d + 2) * k];
        let r2 = &x[(d + 2) * k..(d + 3) * k];
        let r3 = &x[(d + 3) * k..(d + 4) * k];
        for i in 0..k {
            let w0 = a0 * r0[i];
            let w1 = a1 * r1[i];
            let w2 = a2 * r2[i];
            let w3 = a3 * r3[i];
            let off = SymPacked::row_offset(i);
            let dst = &mut sd[off..off + i + 1];
            let (s0, s1, s2, s3) = (&r0[..=i], &r1[..=i], &r2[..=i], &r3[..=i]);
            // zip chain keeps bounds checks out of the loop body so the
            // compiler emits one fused SIMD stream
            for ((((d_, v0), v1), v2), v3) in
                dst.iter_mut().zip(s0).zip(s1).zip(s2).zip(s3)
            {
                *d_ += w0 * v0 + w1 * v1 + w2 * v2 + w3 * v3;
            }
        }
    }
    for d in blocks * 4..n {
        let ad = a[d];
        if ad == 0.0 {
            continue;
        }
        let row = &x[d * k..(d + 1) * k];
        for i in 0..k {
            let w = ad * row[i];
            if w == 0.0 {
                continue;
            }
            let off = SymPacked::row_offset(i);
            let dst = &mut sd[off..off + i + 1];
            let src = &row[..i + 1];
            for (d_, s_) in dst.iter_mut().zip(src) {
                *d_ += w * s_;
            }
        }
    }
}

/// AVX2+FMA rank-8 kernel. The output rows are walked in L2-sized
/// tiles (`TILE_FLOATS` packed floats ≈ 192 KB) so each tile of `s`
/// stays cache-resident across the whole pass over the data block —
/// for large k the packed matrix no longer fits L2 and an untiled loop
/// would stream it from L3 once per 8 rows of data.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn rank_update_dense_avx2(sd: &mut [f32], x: &[f32], n: usize, k: usize, a: &[f32]) {
    // SAFETY (caller): requires avx2+fma; sd.len() == k(k+1)/2,
    // x.len() == n*k, a.len() == n. All pointer arithmetic below stays
    // inside those bounds: row pointers r0..r7 index < k, dst indexes
    // < off(i) + i + 1 <= sd.len().
    use std::arch::x86_64::*;
    const TILE_FLOATS: usize = 48 * 1024; // 192 KB of packed dst per tile
    let xp = x.as_ptr();
    let sp = sd.as_mut_ptr();
    let mut i0 = 0usize;
    while i0 < k {
        // grow the tile [i0, i1) until it holds ~TILE_FLOATS packed floats
        let mut i1 = i0;
        let mut fl = 0usize;
        while i1 < k {
            let rowlen = i1 + 1;
            if fl + rowlen > TILE_FLOATS && i1 > i0 {
                break;
            }
            fl += rowlen;
            i1 += 1;
        }
        let blocks = n / 8;
        for blk in 0..blocks {
            let d = blk * 8;
            if a[d] == 0.0
                && a[d + 1] == 0.0
                && a[d + 2] == 0.0
                && a[d + 3] == 0.0
                && a[d + 4] == 0.0
                && a[d + 5] == 0.0
                && a[d + 6] == 0.0
                && a[d + 7] == 0.0
            {
                continue;
            }
            let r0 = xp.add(d * k);
            let r1 = xp.add((d + 1) * k);
            let r2 = xp.add((d + 2) * k);
            let r3 = xp.add((d + 3) * k);
            let r4 = xp.add((d + 4) * k);
            let r5 = xp.add((d + 5) * k);
            let r6 = xp.add((d + 6) * k);
            let r7 = xp.add((d + 7) * k);
            for i in i0..i1 {
                let w0 = a[d] * *r0.add(i);
                let w1 = a[d + 1] * *r1.add(i);
                let w2 = a[d + 2] * *r2.add(i);
                let w3 = a[d + 3] * *r3.add(i);
                let w4 = a[d + 4] * *r4.add(i);
                let w5 = a[d + 5] * *r5.add(i);
                let w6 = a[d + 6] * *r6.add(i);
                let w7 = a[d + 7] * *r7.add(i);
                let wv0 = _mm256_set1_ps(w0);
                let wv1 = _mm256_set1_ps(w1);
                let wv2 = _mm256_set1_ps(w2);
                let wv3 = _mm256_set1_ps(w3);
                let wv4 = _mm256_set1_ps(w4);
                let wv5 = _mm256_set1_ps(w5);
                let wv6 = _mm256_set1_ps(w6);
                let wv7 = _mm256_set1_ps(w7);
                let dst = sp.add(SymPacked::row_offset(i));
                let len = i + 1;
                let mut j = 0usize;
                while j + 8 <= len {
                    let mut acc = _mm256_loadu_ps(dst.add(j));
                    acc = _mm256_fmadd_ps(wv0, _mm256_loadu_ps(r0.add(j)), acc);
                    acc = _mm256_fmadd_ps(wv1, _mm256_loadu_ps(r1.add(j)), acc);
                    acc = _mm256_fmadd_ps(wv2, _mm256_loadu_ps(r2.add(j)), acc);
                    acc = _mm256_fmadd_ps(wv3, _mm256_loadu_ps(r3.add(j)), acc);
                    acc = _mm256_fmadd_ps(wv4, _mm256_loadu_ps(r4.add(j)), acc);
                    acc = _mm256_fmadd_ps(wv5, _mm256_loadu_ps(r5.add(j)), acc);
                    acc = _mm256_fmadd_ps(wv6, _mm256_loadu_ps(r6.add(j)), acc);
                    acc = _mm256_fmadd_ps(wv7, _mm256_loadu_ps(r7.add(j)), acc);
                    _mm256_storeu_ps(dst.add(j), acc);
                    j += 8;
                }
                while j < len {
                    *dst.add(j) += w0 * *r0.add(j)
                        + w1 * *r1.add(j)
                        + w2 * *r2.add(j)
                        + w3 * *r3.add(j)
                        + w4 * *r4.add(j)
                        + w5 * *r5.add(j)
                        + w6 * *r6.add(j)
                        + w7 * *r7.add(j);
                    j += 1;
                }
            }
        }
        // remainder rows of the data block: rank-1 updates
        for d in blocks * 8..n {
            let ad = a[d];
            if ad == 0.0 {
                continue;
            }
            let row = xp.add(d * k);
            for i in i0..i1 {
                let w = ad * *row.add(i);
                if w == 0.0 {
                    continue;
                }
                let wv = _mm256_set1_ps(w);
                let dst = sp.add(SymPacked::row_offset(i));
                let len = i + 1;
                let mut j = 0usize;
                while j + 8 <= len {
                    let acc = _mm256_fmadd_ps(
                        wv,
                        _mm256_loadu_ps(row.add(j)),
                        _mm256_loadu_ps(dst.add(j)),
                    );
                    _mm256_storeu_ps(dst.add(j), acc);
                    j += 8;
                }
                while j < len {
                    *dst.add(j) += w * *row.add(j);
                    j += 1;
                }
            }
        }
        i0 = i1;
    }
}

/// NEON rank-4 kernel (128-bit lanes; NEON is baseline on aarch64).
#[cfg(target_arch = "aarch64")]
fn rank_update_dense_neon(sd: &mut [f32], x: &[f32], n: usize, k: usize, a: &[f32]) {
    use std::arch::aarch64::*;
    let xp = x.as_ptr();
    let sp = sd.as_mut_ptr();
    let blocks = n / 4;
    // SAFETY: NEON is baseline on aarch64; pointer arithmetic mirrors
    // the scalar kernel's slice bounds (sd.len() == k(k+1)/2,
    // x.len() == n*k, a.len() == n).
    unsafe {
        for blk in 0..blocks {
            let d = blk * 4;
            if a[d] == 0.0 && a[d + 1] == 0.0 && a[d + 2] == 0.0 && a[d + 3] == 0.0 {
                continue;
            }
            let r0 = xp.add(d * k);
            let r1 = xp.add((d + 1) * k);
            let r2 = xp.add((d + 2) * k);
            let r3 = xp.add((d + 3) * k);
            for i in 0..k {
                let w0 = a[d] * *r0.add(i);
                let w1 = a[d + 1] * *r1.add(i);
                let w2 = a[d + 2] * *r2.add(i);
                let w3 = a[d + 3] * *r3.add(i);
                let wv0 = vdupq_n_f32(w0);
                let wv1 = vdupq_n_f32(w1);
                let wv2 = vdupq_n_f32(w2);
                let wv3 = vdupq_n_f32(w3);
                let dst = sp.add(SymPacked::row_offset(i));
                let len = i + 1;
                let mut j = 0usize;
                while j + 4 <= len {
                    let mut acc = vld1q_f32(dst.add(j));
                    acc = vfmaq_f32(acc, wv0, vld1q_f32(r0.add(j)));
                    acc = vfmaq_f32(acc, wv1, vld1q_f32(r1.add(j)));
                    acc = vfmaq_f32(acc, wv2, vld1q_f32(r2.add(j)));
                    acc = vfmaq_f32(acc, wv3, vld1q_f32(r3.add(j)));
                    vst1q_f32(dst.add(j), acc);
                    j += 4;
                }
                while j < len {
                    *dst.add(j) +=
                        w0 * *r0.add(j) + w1 * *r1.add(j) + w2 * *r2.add(j) + w3 * *r3.add(j);
                    j += 1;
                }
            }
        }
        for d in blocks * 4..n {
            let ad = a[d];
            if ad == 0.0 {
                continue;
            }
            let row = xp.add(d * k);
            for i in 0..k {
                let w = ad * *row.add(i);
                if w == 0.0 {
                    continue;
                }
                let wv = vdupq_n_f32(w);
                let dst = sp.add(SymPacked::row_offset(i));
                let len = i + 1;
                let mut j = 0usize;
                while j + 4 <= len {
                    let acc = vfmaq_f32(vld1q_f32(dst.add(j)), wv, vld1q_f32(row.add(j)));
                    vst1q_f32(dst.add(j), acc);
                    j += 4;
                }
                while j < len {
                    *dst.add(j) += w * *row.add(j);
                    j += 1;
                }
            }
        }
    }
}

/// Sparse rank-1 updates: rows given as (indices, values) pairs.
/// `S[i, j] += a_d v_i v_j` for every nonzero pair with `j <= i`.
/// Gather/scatter-shaped, so it stays scalar on every ISA; the f32
/// order is unchanged from the pre-packed kernel.
pub fn rank_update_sparse(s: &mut SymPacked, idx: &[u32], val: &[f32], a_d: f32) {
    debug_assert_eq!(idx.len(), val.len());
    if a_d == 0.0 {
        return;
    }
    let sd = &mut s.data;
    for (p, &ip) in idx.iter().enumerate() {
        let w = a_d * val[p];
        let base = SymPacked::row_offset(ip as usize);
        // CSR indices are sorted, so idx[..=p] are all <= ip
        for q in 0..=p {
            sd[base + idx[q] as usize] += w * val[q];
        }
    }
}

/// Mirror the lower triangle of a full `Mat` into the upper.
pub fn symmetrize_from_lower(s: &mut Mat) {
    assert_eq!(s.rows, s.cols);
    let k = s.rows;
    for i in 0..k {
        for j in (i + 1)..k {
            s.data[i * k + j] = s.data[j * k + i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn naive(x: &[f32], n: usize, k: usize, a: &[f32]) -> Mat {
        let mut s = Mat::zeros(k, k);
        for d in 0..n {
            for i in 0..k {
                for j in 0..k {
                    s[(i, j)] += a[d] * x[d * k + i] * x[d * k + j];
                }
            }
        }
        s
    }

    #[test]
    fn dense_matches_naive() {
        let (n, k) = (37, 13);
        let mut g = Pcg64::new(5);
        let x: Vec<f32> = (0..n * k).map(|_| g.next_f32() - 0.5).collect();
        let a: Vec<f32> = (0..n).map(|_| g.next_f32() * 3.0).collect();
        let mut s = SymPacked::zeros(k);
        rank_update_dense(&mut s, &x, n, k, &a);
        let full = s.unpack();
        let want = naive(&x, n, k, &a);
        assert!(full.max_abs_diff(&want) < 1e-4, "{}", full.max_abs_diff(&want));
    }

    #[test]
    fn dispatched_matches_scalar_under_tolerance() {
        // the accumulation order differs (rank-8 FMA vs rank-4), so
        // compare under a relative bound, not bit-equality
        let (n, k) = (53, 17);
        let mut g = Pcg64::new(11);
        let x: Vec<f32> = (0..n * k).map(|_| g.next_f32() * 2.0 - 1.0).collect();
        let a: Vec<f32> = (0..n).map(|_| g.next_f32()).collect();
        let mut fast = SymPacked::zeros(k);
        rank_update_dense(&mut fast, &x, n, k, &a);
        let mut slow = SymPacked::zeros(k);
        rank_update_dense_scalar(&mut slow, &x, n, k, &a);
        let scale = slow.data.iter().fold(1f32, |m, &v| m.max(v.abs()));
        assert!(
            fast.max_abs_diff(&slow) < 1e-4 * scale,
            "isa={} diff={}",
            active_isa().name(),
            fast.max_abs_diff(&slow)
        );
    }

    #[test]
    fn sparse_matches_dense() {
        let k = 10;
        // one sparse row: indices sorted
        let idx = [1u32, 4, 7];
        let val = [0.5f32, -2.0, 1.5];
        let a_d = 0.7;
        let mut dense_row = vec![0.0f32; k];
        for (i, v) in idx.iter().zip(&val) {
            dense_row[*i as usize] = *v;
        }
        let mut s1 = SymPacked::zeros(k);
        rank_update_sparse(&mut s1, &idx, &val, a_d);
        let mut s2 = SymPacked::zeros(k);
        rank_update_dense(&mut s2, &dense_row, 1, k, &[a_d]);
        assert!(s1.max_abs_diff(&s2) < 1e-6);
    }

    #[test]
    fn zero_weight_rows_skipped() {
        let k = 4;
        let x = vec![1.0f32; 2 * k];
        let mut s = SymPacked::zeros(k);
        rank_update_dense(&mut s, &x, 2, k, &[0.0, 0.0]);
        assert!(s.data.iter().all(|&v| v == 0.0));
    }
}
