//! The native-backend hot spot: `S += sum_d a_d x_d x_d^T` (Eq. 40).
//!
//! Dense and CSR-sparse variants, accumulating only the lower triangle —
//! the paper notes (§4.1) that workers need only submit one triangle.
//! `symmetrize_from_lower` mirrors it before the master solve.

use super::Mat;

/// Dense rank-1 updates over a row-block: `s += sum_d a[d] * x_d x_d^T`,
/// lower triangle only. `x` is row-major [n, k]; `s` is [k, k].
///
/// Rows are processed four at a time (a rank-4 SYRK micro-kernel): the
/// inner j-loop then performs 4 fused multiply-adds per store to `s`,
/// quartering the dominant write traffic — see EXPERIMENTS.md §Perf for
/// the measured before/after (~7 -> ~17 GFLOP/s on this box).
pub fn rank_update_dense(s: &mut Mat, x: &[f32], n: usize, k: usize, a: &[f32]) {
    debug_assert_eq!(s.rows, k);
    debug_assert_eq!(x.len(), n * k);
    debug_assert_eq!(a.len(), n);
    let sd = &mut s.data;
    let blocks = n / 4;
    for blk in 0..blocks {
        let d = blk * 4;
        let (a0, a1, a2, a3) = (a[d], a[d + 1], a[d + 2], a[d + 3]);
        if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
            continue;
        }
        let r0 = &x[d * k..(d + 1) * k];
        let r1 = &x[(d + 1) * k..(d + 2) * k];
        let r2 = &x[(d + 2) * k..(d + 3) * k];
        let r3 = &x[(d + 3) * k..(d + 4) * k];
        for i in 0..k {
            let w0 = a0 * r0[i];
            let w1 = a1 * r1[i];
            let w2 = a2 * r2[i];
            let w3 = a3 * r3[i];
            let dst = &mut sd[i * k..i * k + i + 1];
            let (s0, s1, s2, s3) = (&r0[..=i], &r1[..=i], &r2[..=i], &r3[..=i]);
            // zip chain keeps bounds checks out of the loop body so the
            // compiler emits one fused SIMD stream
            for ((((d_, v0), v1), v2), v3) in
                dst.iter_mut().zip(s0).zip(s1).zip(s2).zip(s3)
            {
                *d_ += w0 * v0 + w1 * v1 + w2 * v2 + w3 * v3;
            }
        }
    }
    for d in blocks * 4..n {
        let ad = a[d];
        if ad == 0.0 {
            continue;
        }
        let row = &x[d * k..(d + 1) * k];
        for i in 0..k {
            let w = ad * row[i];
            if w == 0.0 {
                continue;
            }
            let dst = &mut sd[i * k..i * k + i + 1];
            let src = &row[..i + 1];
            for (d_, s_) in dst.iter_mut().zip(src) {
                *d_ += w * s_;
            }
        }
    }
}

/// Sparse rank-1 updates: rows given as (indices, values) pairs.
/// `S[i, j] += a_d v_i v_j` for every nonzero pair with `j <= i`.
pub fn rank_update_sparse(s: &mut Mat, idx: &[u32], val: &[f32], a_d: f32) {
    debug_assert_eq!(idx.len(), val.len());
    if a_d == 0.0 {
        return;
    }
    let k = s.cols;
    let sd = &mut s.data;
    for (p, &ip) in idx.iter().enumerate() {
        let w = a_d * val[p];
        let base = ip as usize * k;
        // CSR indices are sorted, so idx[..=p] are all <= ip
        for q in 0..=p {
            sd[base + idx[q] as usize] += w * val[q];
        }
    }
}

/// Mirror the lower triangle into the upper.
pub fn symmetrize_from_lower(s: &mut Mat) {
    assert_eq!(s.rows, s.cols);
    let k = s.rows;
    for i in 0..k {
        for j in (i + 1)..k {
            s.data[i * k + j] = s.data[j * k + i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn naive(x: &[f32], n: usize, k: usize, a: &[f32]) -> Mat {
        let mut s = Mat::zeros(k, k);
        for d in 0..n {
            for i in 0..k {
                for j in 0..k {
                    s[(i, j)] += a[d] * x[d * k + i] * x[d * k + j];
                }
            }
        }
        s
    }

    #[test]
    fn dense_matches_naive() {
        let (n, k) = (37, 13);
        let mut g = Pcg64::new(5);
        let x: Vec<f32> = (0..n * k).map(|_| g.next_f32() - 0.5).collect();
        let a: Vec<f32> = (0..n).map(|_| g.next_f32() * 3.0).collect();
        let mut s = Mat::zeros(k, k);
        rank_update_dense(&mut s, &x, n, k, &a);
        symmetrize_from_lower(&mut s);
        let want = naive(&x, n, k, &a);
        assert!(s.max_abs_diff(&want) < 1e-4, "{}", s.max_abs_diff(&want));
    }

    #[test]
    fn sparse_matches_dense() {
        let k = 10;
        // one sparse row: indices sorted
        let idx = [1u32, 4, 7];
        let val = [0.5f32, -2.0, 1.5];
        let a_d = 0.7;
        let mut dense_row = vec![0.0f32; k];
        for (i, v) in idx.iter().zip(&val) {
            dense_row[*i as usize] = *v;
        }
        let mut s1 = Mat::zeros(k, k);
        rank_update_sparse(&mut s1, &idx, &val, a_d);
        symmetrize_from_lower(&mut s1);
        let mut s2 = Mat::zeros(k, k);
        rank_update_dense(&mut s2, &dense_row, 1, k, &[a_d]);
        symmetrize_from_lower(&mut s2);
        assert!(s1.max_abs_diff(&s2) < 1e-6);
    }

    #[test]
    fn zero_weight_rows_skipped() {
        let k = 4;
        let x = vec![1.0f32; 2 * k];
        let mut s = Mat::zeros(k, k);
        rank_update_dense(&mut s, &x, 2, k, &[0.0, 0.0]);
        assert!(s.data.iter().all(|&v| v == 0.0));
    }
}
