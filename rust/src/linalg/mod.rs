//! Dense linear-algebra substrate (no external crates offline).
//!
//! Everything the master step and the baselines need: a column-dense
//! row-major matrix, Cholesky factor/solve, triangular solves, and the
//! symmetric weighted rank-update `S += sum_d a_d x_d x_d^T` that is the
//! paper's hot spot on the native (CPU/MPI-like) backend.

mod cholesky;
mod mat;
mod rank_update;

pub use cholesky::{cholesky_in_place, solve_cholesky, solve_lower, solve_upper, CholeskyError};
pub use mat::Mat;
pub use rank_update::{rank_update_dense, rank_update_sparse, symmetrize_from_lower};

/// y = A x for row-major `a` of shape [m, n].
pub fn matvec(a: &[f32], m: usize, n: usize, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(x.len(), n);
    debug_assert_eq!(y.len(), m);
    for (i, yi) in y.iter_mut().enumerate() {
        let row = &a[i * n..(i + 1) * n];
        *yi = dot(row, x);
    }
}

/// Dot product with 4-way unrolling (the compiler autovectorizes this
/// shape reliably; see EXPERIMENTS.md §Perf).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    let chunks = n / 4;
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for j in chunks * 4..n {
        s += a[j] * b[j];
    }
    s
}

/// a += alpha * b (axpy).
#[inline]
pub fn axpy(alpha: f32, b: &[f32], a: &mut [f32]) {
    for (ai, bi) in a.iter_mut().zip(b) {
        *ai += alpha * bi;
    }
}

/// Euclidean norm squared.
#[inline]
pub fn norm2_sq(a: &[f32]) -> f32 {
    dot(a, a)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..103).map(|i| (i as f32) * 0.1).collect();
        let b: Vec<f32> = (0..103).map(|i| 1.0 - (i as f32) * 0.01).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-2);
    }

    #[test]
    fn matvec_identity() {
        let n = 5;
        let mut a = vec![0f32; n * n];
        for i in 0..n {
            a[i * n + i] = 1.0;
        }
        let x: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let mut y = vec![0f32; n];
        matvec(&a, n, n, &x, &mut y);
        assert_eq!(x, y);
    }

    #[test]
    fn axpy_works() {
        let mut a = vec![1f32, 2.0, 3.0];
        axpy(2.0, &[1.0, 1.0, 1.0], &mut a);
        assert_eq!(a, vec![3.0, 4.0, 5.0]);
    }
}
