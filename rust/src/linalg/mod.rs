//! Dense linear-algebra substrate (no external crates offline).
//!
//! Everything the master step and the baselines need: a column-dense
//! row-major matrix, its lower-packed symmetric sibling
//! ([`SymPacked`]), Cholesky factor/solve, triangular solves, and the
//! symmetric weighted rank-update `S += sum_d a_d x_d x_d^T` that is
//! the paper's hot spot on the native (CPU/MPI-like) backend. The hot
//! kernels (`dot`, `axpy`, `rank_update_dense`) dispatch once per
//! process to the widest ISA the CPU supports — see [`active_isa`].

mod cholesky;
mod mat;
mod packed;
mod rank_update;
mod simd;

pub use cholesky::{cholesky_in_place, solve_cholesky, solve_lower, solve_upper, CholeskyError};
pub use mat::Mat;
pub use packed::SymPacked;
pub use rank_update::{
    rank_update_dense, rank_update_dense_scalar, rank_update_sparse, symmetrize_from_lower,
};
pub use simd::{active_isa, axpy, axpy_scalar, dot, dot_scalar, KernelIsa};

/// y = A x for row-major `a` of shape [m, n].
pub fn matvec(a: &[f32], m: usize, n: usize, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(x.len(), n);
    debug_assert_eq!(y.len(), m);
    for (i, yi) in y.iter_mut().enumerate() {
        let row = &a[i * n..(i + 1) * n];
        *yi = dot(row, x);
    }
}

/// Euclidean norm squared.
#[inline]
pub fn norm2_sq(a: &[f32]) -> f32 {
    dot(a, a)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..103).map(|i| (i as f32) * 0.1).collect();
        let b: Vec<f32> = (0..103).map(|i| 1.0 - (i as f32) * 0.01).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-2);
    }

    #[test]
    fn matvec_identity() {
        let n = 5;
        let mut a = vec![0f32; n * n];
        for i in 0..n {
            a[i * n + i] = 1.0;
        }
        let x: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let mut y = vec![0f32; n];
        matvec(&a, n, n, &x, &mut y);
        assert_eq!(x, y);
    }

    #[test]
    fn axpy_works() {
        let mut a = vec![1f32, 2.0, 3.0];
        axpy(2.0, &[1.0, 1.0, 1.0], &mut a);
        assert_eq!(a, vec![3.0, 4.0, 5.0]);
    }
}
