//! Row-major dense matrix with the handful of ops the solver needs.

use std::fmt;
use std::ops::{Index, IndexMut};

/// Row-major `rows x cols` f32 matrix.
#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[&[f32]]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// self += other (elementwise); shapes must match.
    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// self += alpha * I (n x n only).
    pub fn add_scaled_eye(&mut self, alpha: f32) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            self.data[i * self.cols + i] += alpha;
        }
    }

    /// self = alpha * R + self (used for lam * Gram in KRN).
    pub fn add_scaled(&mut self, alpha: f32, r: &Mat) {
        assert_eq!((self.rows, self.cols), (r.rows, r.cols));
        for (a, b) in self.data.iter_mut().zip(&r.data) {
            *a += alpha * b;
        }
    }

    pub fn fill(&mut self, v: f32) {
        self.data.fill(v);
    }

    /// The transposed matrix ([cols, rows]). The serving scorer keeps
    /// per-class weights `[m, k]` transposed to `[k, m]` so a sparse
    /// row's nonzero `(j, v)` touches one contiguous row slice.
    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// Max |a_ij - b_ij|.
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f32;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            writeln!(f, "  {:?}", &self.row(i)[..self.cols.min(8)])?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eye_and_index() {
        let m = Mat::eye(3);
        assert_eq!(m[(0, 0)], 1.0);
        assert_eq!(m[(0, 1)], 0.0);
    }

    #[test]
    fn add_assign_and_scaled() {
        let mut a = Mat::eye(2);
        let b = Mat::eye(2);
        a.add_assign(&b);
        a.add_scaled_eye(0.5);
        assert_eq!(a[(0, 0)], 2.5);
        assert_eq!(a[(1, 1)], 2.5);
        assert_eq!(a[(0, 1)], 0.0);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = Mat::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        let t = m.transpose();
        assert_eq!((t.rows, t.cols), (3, 2));
        assert_eq!(t[(0, 1)], 4.0);
        assert_eq!(t[(2, 0)], 3.0);
        assert_eq!(t.transpose(), m);
    }

    #[test]
    #[should_panic]
    fn ragged_from_rows_panics() {
        Mat::from_rows(&[&[1.0, 2.0], &[3.0]]);
    }
}
