//! Cholesky factorization + solves for the master step
//! `w = (lam R + sum_p Sigma^p)^{-1} b` and the MC posterior sample
//! `w = mu + L^{-T} z`.
//!
//! f64 accumulation inside the factorization: the Sigma sums are built in
//! f32 across shards, but the K x K solve is tiny relative to the stats
//! pass, so we can afford the extra precision where it matters most.

use super::Mat;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CholeskyError {
    /// Pivot index that went non-positive.
    pub pivot: usize,
}

impl std::fmt::Display for CholeskyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "matrix not positive definite at pivot {}", self.pivot)
    }
}

impl std::error::Error for CholeskyError {}

/// 4-way unrolled f64 dot over two f32 row prefixes (the Cholesky
/// inner product); ~3x the scalar loop on this box (§Perf).
#[inline]
fn dot_f64(a: &[f32], b: &[f32]) -> f64 {
    let n = a.len();
    let (mut s0, mut s1, mut s2, mut s3) = (0f64, 0f64, 0f64, 0f64);
    let chunks = n / 4;
    for c in 0..chunks {
        let j = c * 4;
        s0 += a[j] as f64 * b[j] as f64;
        s1 += a[j + 1] as f64 * b[j + 1] as f64;
        s2 += a[j + 2] as f64 * b[j + 2] as f64;
        s3 += a[j + 3] as f64 * b[j + 3] as f64;
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for j in chunks * 4..n {
        s += a[j] as f64 * b[j] as f64;
    }
    s
}

/// In-place lower Cholesky: on success, the lower triangle (incl.
/// diagonal) of `a` holds L with A = L L^T; the upper triangle is left
/// untouched (callers must not read it). f64 accumulation throughout.
pub fn cholesky_in_place(a: &mut Mat) -> Result<(), CholeskyError> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let k_stride = a.cols;
    for j in 0..n {
        let row_j = &a.data[j * k_stride..j * k_stride + j];
        let d = a.data[j * k_stride + j] as f64 - dot_f64(row_j, row_j);
        if d <= 0.0 || !d.is_finite() {
            return Err(CholeskyError { pivot: j });
        }
        let d = d.sqrt();
        a.data[j * k_stride + j] = d as f32;
        let inv_d = 1.0 / d;
        for i in (j + 1)..n {
            // split_at_mut-free: rows i and j never alias (i > j)
            let (head, tail) = a.data.split_at_mut(i * k_stride);
            let row_j = &head[j * k_stride..j * k_stride + j];
            let row_i = &tail[..j];
            let s = tail[j] as f64 - dot_f64(row_i, row_j);
            tail[j] = (s * inv_d) as f32;
        }
    }
    Ok(())
}

/// Solve L y = b (lower triangular, from `cholesky_in_place` output).
pub fn solve_lower(l: &Mat, b: &[f32], y: &mut [f32]) {
    let n = l.rows;
    for i in 0..n {
        let mut s = b[i] as f64;
        for k in 0..i {
            s -= l[(i, k)] as f64 * y[k] as f64;
        }
        y[i] = (s / l[(i, i)] as f64) as f32;
    }
}

/// Solve L^T x = y (using the lower factor transposed).
pub fn solve_upper(l: &Mat, y: &[f32], x: &mut [f32]) {
    let n = l.rows;
    for i in (0..n).rev() {
        let mut s = y[i] as f64;
        for k in (i + 1)..n {
            s -= l[(k, i)] as f64 * x[k] as f64;
        }
        x[i] = (s / l[(i, i)] as f64) as f32;
    }
}

/// Factor (destroying `a`) and solve A x = b.
pub fn solve_cholesky(a: &mut Mat, b: &[f32]) -> Result<Vec<f32>, CholeskyError> {
    cholesky_in_place(a)?;
    let n = a.rows;
    let mut y = vec![0.0; n];
    let mut x = vec![0.0; n];
    solve_lower(a, b, &mut y);
    solve_upper(a, &y, &mut x);
    Ok(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn random_spd(n: usize, seed: u64) -> Mat {
        let mut g = Pcg64::new(seed);
        let mut b = Mat::zeros(n, 2 * n);
        for v in b.data.iter_mut() {
            *v = g.next_f32() - 0.5;
        }
        // A = B B^T + 0.1 I
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                a[(i, j)] = crate::linalg::dot(b.row(i), b.row(j));
            }
        }
        a.add_scaled_eye(0.1);
        a
    }

    #[test]
    fn factor_reconstructs() {
        let a = random_spd(12, 1);
        let mut l = a.clone();
        cholesky_in_place(&mut l).unwrap();
        for i in 0..12 {
            for j in 0..12 {
                let mut s = 0.0f64;
                for k in 0..=i.min(j) {
                    s += l[(i, k)] as f64 * l[(j, k)] as f64;
                }
                assert!((s as f32 - a[(i, j)]).abs() < 1e-3, "({i},{j})");
            }
        }
    }

    #[test]
    fn solve_matches_direct_residual() {
        let a = random_spd(20, 2);
        let b: Vec<f32> = (0..20).map(|i| (i as f32).sin()).collect();
        let x = solve_cholesky(&mut a.clone(), &b).unwrap();
        // residual || A x - b ||
        let mut r = vec![0.0f32; 20];
        crate::linalg::matvec(&a.data, 20, 20, &x, &mut r);
        for i in 0..20 {
            assert!((r[i] - b[i]).abs() < 1e-3, "res[{i}] = {}", r[i] - b[i]);
        }
    }

    #[test]
    fn non_spd_rejected() {
        let mut a = Mat::eye(3);
        a[(2, 2)] = -1.0;
        assert!(cholesky_in_place(&mut a).is_err());
    }

    #[test]
    fn triangular_solves_roundtrip() {
        let a = random_spd(8, 3);
        let mut l = a.clone();
        cholesky_in_place(&mut l).unwrap();
        let z: Vec<f32> = (0..8).map(|i| 0.3 * i as f32 - 1.0).collect();
        let mut y = vec![0.0; 8];
        let mut x = vec![0.0; 8];
        solve_lower(&l, &z, &mut y);
        // L y = z?
        for i in 0..8 {
            let mut s = 0.0;
            for k in 0..=i {
                s += l[(i, k)] * y[k];
            }
            assert!((s - z[i]).abs() < 1e-4);
        }
        solve_upper(&l, &z, &mut x);
        for i in 0..8 {
            let mut s = 0.0;
            for k in i..8 {
                s += l[(k, i)] * x[k];
            }
            assert!((s - z[i]).abs() < 1e-4);
        }
    }
}
