//! Helpers shared by the `harness = false` bench binaries (`criterion`
//! is not in the offline registry; each bench prints the corresponding
//! paper table/figure directly).

use std::time::Instant;

/// `--quick` on the bench command line (`cargo bench --bench X --
/// --quick`): CI smoke mode. Shrinks the default workload scale so the
/// bench finishes in seconds while still emitting its JSON snapshot.
pub fn quick() -> bool {
    std::env::args().any(|a| a == "--quick")
}

/// Global workload scale: the `SCALE` env var wins when set (`SCALE=0.2
/// cargo bench` shrinks every bench's N by 5x), else 0.05 under
/// `--quick`, else 1.0.
pub fn scale() -> f64 {
    std::env::var("SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if quick() { 0.05 } else { 1.0 })
}

/// `n` scaled by `SCALE`, at least `min`.
pub fn scaled(n: usize, min: usize) -> usize {
    ((n as f64 * scale()) as usize).max(min)
}

/// Wall-clock a closure.
pub fn time<T>(f: impl FnOnce() -> T) -> (f64, T) {
    let t0 = Instant::now();
    let out = f();
    (t0.elapsed().as_secs_f64(), out)
}

/// Least-squares slope of log(y) vs log(x) — the scaling exponent the
/// itertime/fig3/fig4 benches compare against the paper's asymptotics.
pub fn loglog_slope(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let lx: Vec<f64> = xs.iter().map(|x| x.ln()).collect();
    let ly: Vec<f64> = ys.iter().map(|y| y.ln()).collect();
    let n = lx.len() as f64;
    let mx = lx.iter().sum::<f64>() / n;
    let my = ly.iter().sum::<f64>() / n;
    let cov: f64 = lx.iter().zip(&ly).map(|(x, y)| (x - mx) * (y - my)).sum();
    let var: f64 = lx.iter().map(|x| (x - mx) * (x - mx)).sum();
    cov / var
}

/// Measured cost of merging one pair of K x K partial statistics (the
/// unit of a tree-reduce round).
pub fn pair_merge_secs(k: usize) -> f64 {
    use crate::solver::PartialStats;
    let mut a = PartialStats::zeros(k);
    let b = PartialStats::zeros(k);
    let reps = (50_000_000 / (k * k).max(1)).clamp(3, 200);
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        a.merge(&b);
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

/// Cluster cost model for a `Topology::Simulate` run: per-iteration
/// max-worker stats time + solve + bookkeeping, with the serial
/// measured reduce replaced by the paper's tree reduce
/// (ceil(log2 P) pair-merge rounds per collect; §4.1 / Table 1 —
/// on one box the merges of a round cannot actually overlap, so the
/// measured serial reduce would charge O(P) instead of O(log P)).
pub fn modeled_sim_secs(out: &crate::coordinator::TrainOutput, p: usize, k: usize) -> f64 {
    use crate::metrics::Phase;
    let m = &out.metrics;
    let serial = m.total(Phase::LocalStats)
        + m.total(Phase::DrawMu)
        + m.total(Phase::Broadcast)
        + m.total(Phase::Other);
    let rounds = (p.max(2) as f64).log2().ceil();
    serial.as_secs_f64() + m.reduces as f64 * rounds * pair_merge_secs(k)
}

/// Write a bench's JSON snapshot to `BENCH_<name>.json` at the repo
/// root (one self-contained object per bench; later runs overwrite it —
/// the git history / CI artifacts are the trajectory). Both bench
/// binaries route through here so the filenames stay uniform and CI can
/// `test -s` + parse them.
pub fn write_bench_json(name: &str, json: &str) {
    let path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(format!("../BENCH_{name}.json"));
    match std::fs::write(&path, json) {
        Ok(()) => println!("  wrote {}", path.display()),
        Err(e) => println!("  could not write {}: {e}", path.display()),
    }
}

/// Print a bench header in a common format.
pub fn header(id: &str, title: &str) {
    println!("\n================================================================");
    println!("{id}: {title}");
    println!("  (SCALE={}; see EXPERIMENTS.md for paper-vs-measured)", scale());
    println!("================================================================");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slope_of_quadratic_is_two() {
        let xs = [1.0, 2.0, 4.0, 8.0];
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x * x).collect();
        assert!((loglog_slope(&xs, &ys) - 2.0).abs() < 1e-9);
    }
}
