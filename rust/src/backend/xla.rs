//! XLA backend: worker/master steps run the AOT HLO artifacts through
//! PJRT — the re-targeted version of the paper's GPU implementation.
//!
//! Shapes are static, so shards are cut into CHUNK-row pieces (mask = 0
//! padding on the tail) and features are zero-padded to the artifact
//! family's next K. Statistics are kept at the padded width `pk` all the
//! way through the solve (padding solves to w_pad = 0 exactly); the
//! coordinator truncates the final weights.
//!
//! Each worker uploads its chunk literals once at construction — the
//! analogue of the paper loading partitions into GPU memory — and per
//! step only the weight vector (plus MC randomness) moves.

use std::ops::Range;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::config::{Algo, TaskKind, TrainConfig};
use crate::data::Dataset;
use crate::linalg::Mat;
use crate::rng::{worker_stream, NormalSource, Pcg64};
use crate::runtime::{literal_f32, to_vec_f32, Manifest, Runtime};
use crate::solver::PartialStats;

use super::{variant_str, MasterBackend, StepInput, WorkerBackend};

/// Per-chunk uploaded data.
struct ChunkLits {
    x: xla::Literal,
    /// y for CLS/SVR; one-hot for MLT
    y: xla::Literal,
    mask: xla::Literal,
}

// SAFETY: literals are only touched from the owning worker's thread;
// actual device calls go through the runtime mutex.
unsafe impl Send for ChunkLits {}

pub struct XlaWorker {
    rt: &'static Runtime,
    chunks: Vec<ChunkLits>,
    task: TaskKind,
    algo: Algo,
    eps: f32,
    use_pallas: bool,
    /// padded feature width
    pk: usize,
    chunk: usize,
    m: usize,
    rng: Pcg64,
    normals: NormalSource,
}

impl XlaWorker {
    pub fn new(cfg: &TrainConfig, ds: &Arc<Dataset>, range: Range<usize>, wid: u64) -> Result<Self> {
        let rt = crate::runtime::global(std::path::Path::new(&cfg.artifacts_dir))?;
        let pk = rt.pad_k(ds.k)?;
        let chunk = rt.chunk();
        let m = rt.manifest.m_classes;
        if cfg.task == TaskKind::Mlt && cfg.num_classes > m {
            bail!("artifacts built for M={m} classes, need {}", cfg.num_classes);
        }

        let mut chunks = Vec::new();
        let mut x = vec![0f32; chunk * pk];
        let mut y = vec![0f32; chunk];
        let mut yhot = vec![0f32; chunk * m];
        let mut mask = vec![0f32; chunk];
        let mut start = range.start;
        while start < range.end {
            let rows = (range.end - start).min(chunk);
            x.fill(0.0);
            y.fill(0.0);
            yhot.fill(0.0);
            mask.fill(0.0);
            for r in 0..rows {
                let d = start + r;
                ds.for_nonzero(d, |j, v| x[r * pk + j as usize] = v);
                y[r] = ds.labels[d];
                if cfg.task == TaskKind::Mlt {
                    yhot[r * m + ds.labels[d] as usize] = 1.0;
                }
                mask[r] = 1.0;
            }
            let y_lit = if cfg.task == TaskKind::Mlt {
                literal_f32(&yhot, &[chunk as i64, m as i64])?
            } else {
                literal_f32(&y, &[chunk as i64])?
            };
            chunks.push(ChunkLits {
                x: literal_f32(&x, &[chunk as i64, pk as i64])?,
                y: y_lit,
                mask: literal_f32(&mask, &[chunk as i64])?,
            });
            start += rows;
        }

        Ok(XlaWorker {
            rt,
            chunks,
            task: cfg.task,
            algo: cfg.algo,
            eps: cfg.eps_clamp,
            use_pallas: cfg.xla_use_pallas,
            pk,
            chunk,
            m,
            rng: worker_stream(cfg.seed, wid),
            normals: NormalSource::new(),
        })
    }

    fn rand_pair(&mut self) -> Result<(xla::Literal, xla::Literal)> {
        let mut u = vec![0f32; self.chunk];
        let mut z = vec![0f32; self.chunk];
        for v in u.iter_mut() {
            *v = self.rng.next_f32();
        }
        self.normals.fill_f32(&mut self.rng, &mut z);
        Ok((literal_f32(&u, &[self.chunk as i64])?, literal_f32(&z, &[self.chunk as i64])?))
    }

    fn pad_w(&self, w: &[f32]) -> Vec<f32> {
        let mut wp = vec![0f32; self.pk];
        let n = w.len().min(self.pk);
        wp[..n].copy_from_slice(&w[..n]);
        wp
    }
}

impl WorkerBackend for XlaWorker {
    fn step(&mut self, input: &StepInput) -> Result<PartialStats> {
        let pk = self.pk;
        let variant = variant_str(self.algo);
        let eps_lit = literal_f32(&[self.eps], &[1])?;
        let is_mc = self.algo == Algo::Mc;

        // step-invariant literals
        let (name, w_lit, yidx_lit, eps_ins_lit) = match input {
            StepInput::Binary { w } => (
                // the jnp ablation twin exists for the EM variant only
                if !self.use_pallas && self.algo == Algo::Em {
                    Manifest::step_name("lin_step_jnp", variant, pk, 0)
                } else {
                    Manifest::step_name("lin_step", variant, pk, 0)
                },
                literal_f32(&self.pad_w(w), &[pk as i64])?,
                None,
                None,
            ),
            StepInput::Svr { w, eps_ins } => (
                Manifest::step_name("svr_step", variant, pk, 0),
                literal_f32(&self.pad_w(w), &[pk as i64])?,
                None,
                Some(literal_f32(&[*eps_ins], &[1])?),
            ),
            StepInput::Mlt { w_all, yidx } => {
                let m = self.m;
                let mut wp = vec![0f32; m * pk];
                for c in 0..w_all.rows.min(m) {
                    let row = w_all.row(c);
                    let n = row.len().min(pk);
                    wp[c * pk..c * pk + n].copy_from_slice(&row[..n]);
                }
                (
                    Manifest::step_name("mlt_step", variant, pk, m),
                    literal_f32(&wp, &[m as i64, pk as i64])?,
                    Some(xla::Literal::vec1(&[*yidx as i32])),
                    None,
                )
            }
        };

        let mut out = PartialStats::zeros(pk);
        for ci in 0..self.chunks.len() {
            // MC randomness is drawn before borrowing the chunk
            let rand: Vec<xla::Literal> = if is_mc {
                let n_pairs = if self.task == TaskKind::Svr { 2 } else { 1 };
                let mut v = Vec::with_capacity(2 * n_pairs);
                for _ in 0..n_pairs {
                    let (u, z) = self.rand_pair()?;
                    v.push(u);
                    v.push(z);
                }
                v
            } else {
                Vec::new()
            };

            let c = &self.chunks[ci];
            // artifact input order (see python/compile/aot.py)
            let mut args: Vec<&xla::Literal> = vec![&c.x, &c.y, &c.mask, &w_lit];
            if let Some(yi) = &yidx_lit {
                args.push(yi); // mlt: (x, yhot, mask, w_all, yidx, eps)
            }
            args.push(&eps_lit);
            if let Some(ei) = &eps_ins_lit {
                args.push(ei); // svr: (x, y, mask, w, eps, eps_ins)
            }
            for r in &rand {
                args.push(r);
            }

            let outs = self.rt.execute(&name, &args)?;
            let sigma = to_vec_f32(&outs[0])?;
            let mu = to_vec_f32(&outs[1])?;
            let obj = to_vec_f32(&outs[2])?;
            let aux = to_vec_f32(&outs[3])?;
            // the device returns full [pk, pk] sigma; keep only the
            // lower triangle in the packed accumulator
            let pk = self.pk;
            for i in 0..pk {
                let off = crate::linalg::SymPacked::row_offset(i);
                for j in 0..=i {
                    out.sigma.data[off + j] += sigma[i * pk + j];
                }
            }
            for (acc, v) in out.mu.iter_mut().zip(&mu) {
                *acc += v;
            }
            out.obj += obj[0] as f64;
            out.aux += aux[0] as f64;
        }
        Ok(out)
    }

    fn stat_dim(&self) -> usize {
        self.pk
    }
}

/// XLA master: the `solve_{em,mc}_k{pk}` artifact (Cholesky inside HLO).
pub struct XlaMaster {
    rt: &'static Runtime,
    pk: usize,
    lam: xla::Literal,
    reg: xla::Literal,
    algo: Algo,
}

// SAFETY: leader-thread-owned; device calls behind the runtime mutex.
unsafe impl Send for XlaMaster {}

impl XlaMaster {
    /// `dim` is the (already padded) statistic width the workers report.
    pub fn new(cfg: &TrainConfig, dim: usize, gram: Option<Arc<Mat>>) -> Result<Self> {
        let rt = crate::runtime::global(std::path::Path::new(&cfg.artifacts_dir))?;
        let pk = rt.pad_k(dim)?;
        // regularizer, padded: Gram block + identity tail (keeps the
        // padded solve SPD with w_pad = 0)
        let mut reg = vec![0f32; pk * pk];
        match &gram {
            Some(g) => {
                for i in 0..g.rows {
                    for j in 0..g.cols {
                        reg[i * pk + j] = g[(i, j)];
                    }
                }
                for i in g.rows..pk {
                    reg[i * pk + i] = 1.0;
                }
            }
            None => {
                for i in 0..pk {
                    reg[i * pk + i] = 1.0;
                }
            }
        }
        Ok(XlaMaster {
            rt,
            pk,
            lam: literal_f32(&[cfg.lambda], &[1])?,
            reg: literal_f32(&reg, &[pk as i64, pk as i64])?,
            algo: cfg.algo,
        })
    }
}

impl MasterBackend for XlaMaster {
    fn solve(
        &mut self,
        stats: &mut PartialStats,
        mc_noise: Option<&[f32]>,
    ) -> Result<Vec<f32>> {
        let pk = self.pk;
        if stats.mu.len() != pk {
            bail!("XlaMaster: stats dim {} != padded {}", stats.mu.len(), pk);
        }
        // stats carry only the packed lower triangle; the solve artifact
        // wants the full symmetric matrix — unpack exactly once here.
        let full = stats.sigma.unpack();
        let s_lit = literal_f32(&full.data, &[pk as i64, pk as i64])?;
        let m_lit = literal_f32(&stats.mu, &[pk as i64])?;
        let outs = match (self.algo, mc_noise) {
            (Algo::Mc, Some(z)) => {
                let z_lit = literal_f32(z, &[pk as i64])?;
                let args: Vec<&xla::Literal> = vec![&s_lit, &m_lit, &self.reg, &self.lam, &z_lit];
                self.rt.execute(&format!("solve_mc_k{pk}"), &args)?
            }
            _ => {
                let args: Vec<&xla::Literal> = vec![&s_lit, &m_lit, &self.reg, &self.lam];
                self.rt.execute(&format!("solve_em_k{pk}"), &args)?
            }
        };
        let w = to_vec_f32(&outs[0])?;
        if w.len() != pk {
            bail!("solve: expected {pk} weights, got {}", w.len());
        }
        Ok(w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::{NativeMaster, NativeWorker};
    use crate::data::synth;

    fn have_artifacts() -> bool {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.json")
            .exists()
    }

    fn cfg() -> TrainConfig {
        let mut c = TrainConfig::default();
        c.artifacts_dir =
            format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
        c
    }

    /// The XLA worker step must agree with the native step on the same
    /// shard (truncated from the padded width), EM mode.
    #[test]
    fn xla_step_matches_native_em() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let ds = Arc::new(synth::alpha_like(700, 12, 3));
        let w = Arc::new(vec![0.07f32; 12]);
        let cfg = cfg();
        let mut xw = XlaWorker::new(&cfg, &ds, 100..650, 0).unwrap();
        let mut nw = NativeWorker::new(ds.clone(), 100..650, Algo::Em, cfg.eps_clamp, 0, 0);
        let sx = xw.step(&StepInput::Binary { w: w.clone() }).unwrap();
        let sn = nw.step(&StepInput::Binary { w: w.clone() }).unwrap();
        // packed sigma indexes symmetrically; no mirroring needed
        let pk = xw.stat_dim();
        assert_eq!(pk, 16);
        let mut max_diff = 0f32;
        for i in 0..12 {
            for j in 0..12 {
                max_diff = max_diff.max((sx.sigma[(i, j)] - sn.sigma[(i, j)]).abs());
            }
            // padded region exactly zero
            for j in 12..pk {
                assert_eq!(sx.sigma[(i, j)], 0.0);
            }
        }
        let scale = sn.sigma.data.iter().fold(0f32, |a, &b| a.max(b.abs()));
        assert!(max_diff < 1e-4 * scale.max(1.0), "sigma diff {max_diff} scale {scale}");
        for j in 0..12 {
            assert!((sx.mu[j] - sn.mu[j]).abs() < 1e-3 * scale.max(1.0));
        }
        assert!((sx.obj - sn.obj).abs() < 1e-3 * sn.obj.abs().max(1.0));
        assert_eq!(sx.aux, sn.aux);
    }

    /// XLA master solve == native master solve on the same stats.
    #[test]
    fn xla_solve_matches_native() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let ds = Arc::new(synth::alpha_like(600, 16, 4));
        let w = Arc::new(vec![0f32; 16]);
        let cfg = cfg();
        let mut xw = XlaWorker::new(&cfg, &ds, 0..600, 0).unwrap();
        let mut stats = xw.step(&StepInput::Binary { w }).unwrap();
        let mut stats2 = stats.clone();

        let mut xm = XlaMaster::new(&cfg, 16, None).unwrap();
        let wx = xm.solve(&mut stats, None).unwrap();
        let mut nm = NativeMaster::new(cfg.lambda, None);
        let wn = nm.solve(&mut stats2, None).unwrap();
        for j in 0..16 {
            assert!(
                (wx[j] - wn[j]).abs() < 1e-3 * (1.0 + wn[j].abs()),
                "w[{j}] {} vs {}",
                wx[j],
                wn[j]
            );
        }
    }
}
