//! Compute backends: where the per-iteration flops run.
//!
//! * [`native`] — pure Rust, sparse-aware; the stand-in for the paper's
//!   MPI CPU implementation (§5.7.1).
//! * [`xla`] — executes the AOT-compiled HLO artifacts (Pallas kernel
//!   inside) through PJRT; the stand-in for the paper's GPU
//!   implementation (§5.7.2). Gated behind the `xla` cargo feature so
//!   the default native build compiles offline without the PJRT
//!   bindings.
//!
//! Both expose the same two traits so the engine is backend-blind.

pub mod native;
#[cfg(feature = "xla")]
pub mod xla;

use std::ops::Range;
use std::sync::Arc;

use anyhow::Result;

use crate::config::{BackendKind, TrainConfig};
use crate::data::stream::ParsedChunk;
use crate::data::{Dataset, Task};
use crate::linalg::Mat;
use crate::solver::PartialStats;

/// What a worker should compute this step.
#[derive(Clone, Debug)]
pub enum StepInput {
    /// binary hinge (also KRN: `w` = omega over gram-row features)
    Binary { w: Arc<Vec<f32>> },
    /// epsilon-insensitive SVR
    Svr { w: Arc<Vec<f32>>, eps_ins: f32 },
    /// Crammer-Singer block update for class `yidx`
    Mlt { w_all: Arc<Mat>, yidx: usize },
}

/// A worker's sampler-RNG state, captured for checkpointing: the raw
/// PCG64 register pair plus the normal source's cached polar spare.
/// Restoring it resumes the worker's draw sequence bit-exactly
/// (DESIGN.md §13).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RngState {
    pub state: u128,
    pub inc: u128,
    pub spare: Option<f64>,
}

/// A worker's compute engine over its shard.
pub trait WorkerBackend: Send {
    /// Full pass over the shard at the given weights: gamma update +
    /// local statistics (Eq. 40) + local objective.
    fn step(&mut self, input: &StepInput) -> Result<PartialStats>;

    /// [`step`](WorkerBackend::step), additionally accumulating the
    /// given **global** row ranges into the same statistics — how a
    /// survivor adopts an evicted worker's rows mid-session (DESIGN.md
    /// §13). The default supports only the empty adoption set; backends
    /// whose workers hold the full dataset override it.
    fn step_ranges(&mut self, input: &StepInput, extra: &[Range<usize>]) -> Result<PartialStats> {
        if extra.is_empty() {
            self.step(input)
        } else {
            anyhow::bail!("this backend cannot adopt re-sharded rows")
        }
    }

    /// Feature dimensionality of the returned statistics.
    fn stat_dim(&self) -> usize;

    /// Capture the worker's sampler-RNG state for a checkpoint. `None`
    /// means the backend has no restorable RNG (checkpoints then record
    /// the gap and `--resume` rejects the file).
    fn rng_state(&self) -> Option<RngState> {
        None
    }

    /// Restore a state captured by [`rng_state`](WorkerBackend::rng_state).
    fn set_rng_state(&mut self, _state: RngState) -> Result<()> {
        anyhow::bail!("this backend does not support RNG checkpointing")
    }

    /// Streaming ingestion (DESIGN.md §10): append the rows of `chunk`
    /// that fall inside this worker's shard window. Only workers built
    /// by [`make_stream_workers`] accept chunks.
    fn ingest(&mut self, _chunk: &ParsedChunk) -> Result<()> {
        anyhow::bail!("this backend does not support streaming ingestion")
    }

    /// Finalize streaming ingestion (validate that the shard window is
    /// complete). A no-op for eagerly built workers.
    fn seal(&mut self) -> Result<()> {
        Ok(())
    }
}

/// The master solve (Eq. 6): `w = (lam R + Sigma)^-1 b`, or the MC
/// posterior draw when `mc_noise` is given.
pub trait MasterBackend: Send {
    fn solve(
        &mut self,
        stats: &mut PartialStats,
        mc_noise: Option<&[f32]>,
    ) -> Result<Vec<f32>>;
}

/// Build one worker backend per shard.
pub fn make_workers(
    cfg: &TrainConfig,
    ds: &Arc<Dataset>,
    shards: &[Range<usize>],
) -> Result<Vec<Box<dyn WorkerBackend>>> {
    let mut out: Vec<Box<dyn WorkerBackend>> = Vec::with_capacity(shards.len());
    for (wid, r) in shards.iter().enumerate() {
        match cfg.backend {
            BackendKind::Native => out.push(Box::new(native::NativeWorker::new(
                ds.clone(),
                r.clone(),
                cfg.algo,
                cfg.eps_clamp,
                cfg.seed,
                wid as u64,
            ))),
            BackendKind::Xla => {
                #[cfg(feature = "xla")]
                out.push(Box::new(xla::XlaWorker::new(cfg, ds, r.clone(), wid as u64)?));
                #[cfg(not(feature = "xla"))]
                anyhow::bail!(
                    "built without the `xla` feature; rebuild with `--features xla` \
                     for the PJRT backend"
                );
            }
        }
    }
    Ok(out)
}

/// Build one *streaming* worker per shard window: each starts empty and
/// fills via [`WorkerBackend::ingest`] as chunks arrive, so no full
/// dataset is ever materialized. Native backend only — the XLA path
/// uploads whole chunk literals at construction and stays eager.
pub fn make_stream_workers(
    cfg: &TrainConfig,
    k: usize,
    task: Task,
    shards: &[Range<usize>],
) -> Result<Vec<Box<dyn WorkerBackend>>> {
    match cfg.backend {
        BackendKind::Native => Ok(shards
            .iter()
            .enumerate()
            .map(|(wid, r)| {
                Box::new(native::NativeWorker::new_streaming(
                    r.clone(),
                    k,
                    task,
                    cfg.algo,
                    cfg.eps_clamp,
                    cfg.seed,
                    wid as u64,
                )) as Box<dyn WorkerBackend>
            })
            .collect()),
        BackendKind::Xla => anyhow::bail!(
            "streamed ingestion is implemented for the native backend; load eagerly for \
             --backend xla"
        ),
    }
}

/// Build the master backend. `gram` supplies the KRN regularizer.
pub fn make_master(
    cfg: &TrainConfig,
    k: usize,
    gram: Option<Arc<Mat>>,
) -> Result<Box<dyn MasterBackend>> {
    match cfg.backend {
        BackendKind::Native => Ok(Box::new(native::NativeMaster::new(cfg.lambda, gram))),
        BackendKind::Xla => {
            #[cfg(feature = "xla")]
            {
                Ok(Box::new(xla::XlaMaster::new(cfg, k, gram)?))
            }
            #[cfg(not(feature = "xla"))]
            {
                let _ = k;
                anyhow::bail!(
                    "built without the `xla` feature; rebuild with `--features xla` \
                     for the PJRT backend"
                );
            }
        }
    }
}

/// Algo tag for artifact names.
#[cfg(feature = "xla")]
pub(crate) fn variant_str(algo: crate::config::Algo) -> &'static str {
    use crate::config::Algo;
    match algo {
        Algo::Em => "em",
        Algo::Mc => "mc",
    }
}
