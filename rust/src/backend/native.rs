//! Pure-Rust backend — the paper's MPI CPU implementation, one worker
//! per shard, sparse-aware rank updates, f64 master solve.

use std::ops::Range;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::config::Algo;
use crate::data::stream::{ParsedChunk, ShardBuilder};
use crate::data::{Dataset, Task};
use crate::linalg::Mat;
use crate::rng::{worker_stream, NormalSource, Pcg64};
use crate::solver::local;
use crate::solver::master::{solve_native, Regularizer};
use crate::solver::{GammaMode, PartialStats};

use super::{MasterBackend, RngState, StepInput, WorkerBackend};

/// One worker's native compute state.
///
/// Built either eagerly ([`NativeWorker::new`]: a shared `Arc<Dataset>`
/// plus this worker's row range) or empty for streaming ingestion
/// ([`NativeWorker::new_streaming`]: a [`ShardBuilder`] accumulates the
/// shard chunk by chunk until `seal` swaps the finished shard in).
/// Either way the worker steps over the same rows in the same order, so
/// the two construction paths produce bit-identical statistics.
pub struct NativeWorker {
    ds: Arc<Dataset>,
    range: Range<usize>,
    /// `Some` while streaming ingestion is in flight; `None` once sealed
    /// (and always for eagerly built workers)
    builder: Option<ShardBuilder>,
    algo: Algo,
    eps: f32,
    rng: Pcg64,
    normals: NormalSource,
    stats: PartialStats,
    /// reusable step scratch + MLT score cache (allocated once per
    /// worker, not once per step call)
    ws: local::StepWorkspace,
}

impl NativeWorker {
    pub fn new(
        ds: Arc<Dataset>,
        range: Range<usize>,
        algo: Algo,
        eps: f32,
        seed: u64,
        worker_id: u64,
    ) -> Self {
        let k = ds.k;
        NativeWorker {
            ds,
            range,
            builder: None,
            algo,
            eps,
            rng: worker_stream(seed, worker_id),
            normals: NormalSource::new(),
            stats: PartialStats::zeros(k),
            ws: local::StepWorkspace::new(),
        }
    }

    /// An empty worker owning the global row window `window` of an
    /// `N x k` corpus; rows arrive through `ingest` and `seal` makes the
    /// worker steppable (DESIGN.md §10).
    pub fn new_streaming(
        window: Range<usize>,
        k: usize,
        task: Task,
        algo: Algo,
        eps: f32,
        seed: u64,
        worker_id: u64,
    ) -> Self {
        NativeWorker {
            ds: Arc::new(Dataset::sparse(vec![0], Vec::new(), Vec::new(), Vec::new(), k, task)),
            range: 0..window.len(),
            builder: Some(ShardBuilder::new(window, k, task)),
            algo,
            eps,
            rng: worker_stream(seed, worker_id),
            normals: NormalSource::new(),
            stats: PartialStats::zeros(k),
            ws: local::StepWorkspace::new(),
        }
    }

    /// One pass over `range`, **accumulating** into `out` (the local
    /// step kernels add; the caller owns the reset). Factored out so
    /// [`WorkerBackend::step_ranges`] can run the worker's own shard
    /// plus any adopted ranges into a single partial.
    fn run_into(
        &mut self,
        input: &StepInput,
        range: Range<usize>,
        out: &mut PartialStats,
    ) -> Result<()> {
        let ds = self.ds.clone();
        let eps = self.eps;
        // build the mode from disjoint fields so `ws` can borrow too
        let ws = &mut self.ws;
        let mut mode = match self.algo {
            Algo::Em => GammaMode::Em,
            Algo::Mc => GammaMode::Mc { rng: &mut self.rng, normals: &mut self.normals },
        };
        match input {
            StepInput::Binary { w } => local::lin_step(&ds, range, w, eps, &mut mode, ws, out),
            StepInput::Svr { w, eps_ins } => {
                local::svr_step(&ds, range, w, eps, *eps_ins, &mut mode, ws, out)
            }
            StepInput::Mlt { w_all, yidx } => {
                local::mlt_step(&ds, range, w_all, *yidx, eps, &mut mode, ws, out)
            }
        }
        Ok(())
    }
}

impl WorkerBackend for NativeWorker {
    fn step(&mut self, input: &StepInput) -> Result<PartialStats> {
        self.step_ranges(input, &[])
    }

    fn step_ranges(&mut self, input: &StepInput, extra: &[Range<usize>]) -> Result<PartialStats> {
        if self.builder.is_some() {
            bail!("streamed worker stepped before seal");
        }
        // eager workers hold the full dataset, so global adopted ranges
        // index it directly; a sealed streamed worker holds only its own
        // shard and cannot adopt (the pool guards this, belt + braces)
        for r in extra {
            if r.end > self.ds.n {
                bail!(
                    "adopted range {}..{} outside this worker's dataset view (n = {})",
                    r.start,
                    r.end,
                    self.ds.n
                );
            }
        }
        self.stats.reset();
        // split borrows: move stats out, run, move back
        let mut stats = std::mem::replace(&mut self.stats, PartialStats::zeros(0));
        let mut res = self.run_into(input, self.range.clone(), &mut stats);
        if res.is_ok() {
            for r in extra {
                res = self.run_into(input, r.clone(), &mut stats);
                if res.is_err() {
                    break;
                }
            }
        }
        let out = stats.clone();
        self.stats = stats;
        res.map(|()| out)
    }

    fn stat_dim(&self) -> usize {
        self.ds.k
    }

    fn rng_state(&self) -> Option<RngState> {
        let (state, inc) = self.rng.to_raw();
        Some(RngState { state, inc, spare: self.normals.spare() })
    }

    fn set_rng_state(&mut self, s: RngState) -> Result<()> {
        self.rng = Pcg64::from_raw(s.state, s.inc);
        self.normals = NormalSource::with_spare(s.spare);
        Ok(())
    }

    fn ingest(&mut self, chunk: &ParsedChunk) -> Result<()> {
        match self.builder.as_mut() {
            Some(b) => b.ingest(chunk),
            None => bail!("worker is sealed; streaming ingestion is over"),
        }
    }

    fn seal(&mut self) -> Result<()> {
        if let Some(b) = self.builder.take() {
            let ds = b.build()?;
            self.range = 0..ds.n;
            self.ds = Arc::new(ds);
        }
        Ok(())
    }
}

/// Native master: Cholesky solve with optional Gram regularizer.
pub struct NativeMaster {
    lambda: f32,
    gram: Option<Arc<Mat>>,
}

impl NativeMaster {
    pub fn new(lambda: f32, gram: Option<Arc<Mat>>) -> Self {
        NativeMaster { lambda, gram }
    }
}

impl MasterBackend for NativeMaster {
    fn solve(
        &mut self,
        stats: &mut PartialStats,
        mc_noise: Option<&[f32]>,
    ) -> Result<Vec<f32>> {
        let reg = match &self.gram {
            Some(g) => Regularizer::Gram { lambda: self.lambda, gram: g },
            None => Regularizer::Eye(self.lambda),
        };
        solve_native(stats, &reg, mc_noise)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn worker_step_reusable_and_deterministic() {
        let ds = Arc::new(synth::alpha_like(200, 8, 1));
        let w = Arc::new(vec![0.1f32; 8]);
        let mut a = NativeWorker::new(ds.clone(), 0..200, Algo::Em, 1e-5, 7, 0);
        let s1 = a.step(&StepInput::Binary { w: w.clone() }).unwrap();
        let s2 = a.step(&StepInput::Binary { w: w.clone() }).unwrap();
        assert_eq!(s1.sigma.data, s2.sigma.data);
        assert_eq!(s1.obj, s2.obj);

        // MC: same seed, new worker -> same stats
        let mut m1 = NativeWorker::new(ds.clone(), 0..200, Algo::Mc, 1e-5, 7, 0);
        let mut m2 = NativeWorker::new(ds.clone(), 0..200, Algo::Mc, 1e-5, 7, 0);
        let t1 = m1.step(&StepInput::Binary { w: w.clone() }).unwrap();
        let t2 = m2.step(&StepInput::Binary { w: w.clone() }).unwrap();
        assert_eq!(t1.sigma.data, t2.sigma.data);
        // and different from EM
        assert_ne!(t1.sigma.data, s1.sigma.data);
    }

    #[test]
    fn step_ranges_accumulates_adopted_rows() {
        // a worker stepping its own shard plus an adopted range produces
        // the same statistics as a worker owning the union outright
        let ds = Arc::new(synth::alpha_like(300, 8, 3));
        let w = Arc::new(vec![0.05f32; 8]);
        let mut split = NativeWorker::new(ds.clone(), 0..150, Algo::Em, 1e-5, 7, 0);
        let got = split.step_ranges(&StepInput::Binary { w: w.clone() }, &[150..300]).unwrap();
        let mut whole = NativeWorker::new(ds.clone(), 0..300, Algo::Em, 1e-5, 7, 0);
        let want = whole.step(&StepInput::Binary { w: w.clone() }).unwrap();
        assert_eq!(got.sigma.data, want.sigma.data);
        assert_eq!(got.mu, want.mu);
        assert_eq!(got.obj, want.obj);
        // an out-of-bounds adopted range is rejected, not a panic
        assert!(split.step_ranges(&StepInput::Binary { w }, &[290..301]).is_err());
    }

    #[test]
    fn rng_state_roundtrip_is_bit_exact() {
        let ds = Arc::new(synth::alpha_like(100, 6, 5));
        let w = Arc::new(vec![0.1f32; 6]);
        let mut a = NativeWorker::new(ds.clone(), 0..100, Algo::Mc, 1e-5, 11, 2);
        // advance the stream, snapshot, advance again
        a.step(&StepInput::Binary { w: w.clone() }).unwrap();
        let snap = a.rng_state().unwrap();
        let s1 = a.step(&StepInput::Binary { w: w.clone() }).unwrap();
        // restore and re-run: the draw sequence must replay exactly
        a.set_rng_state(snap).unwrap();
        assert_eq!(a.rng_state().unwrap(), snap);
        let s2 = a.step(&StepInput::Binary { w }).unwrap();
        assert_eq!(s1.sigma.data, s2.sigma.data);
        assert_eq!(s1.mu, s2.mu);
    }

    #[test]
    fn master_solve_end_to_end() {
        let ds = Arc::new(synth::alpha_like(500, 6, 2));
        let w0 = Arc::new(vec![0f32; 6]);
        let mut wk = NativeWorker::new(ds.clone(), 0..500, Algo::Em, 1e-5, 0, 0);
        let mut stats = wk.step(&StepInput::Binary { w: w0 }).unwrap();
        let mut master = NativeMaster::new(1.0, None);
        let w1 = master.solve(&mut stats, None).unwrap();
        assert!(crate::model::accuracy_cls(&ds, &w1) > 0.7);
    }
}
