//! Pure-Rust backend — the paper's MPI CPU implementation, one worker
//! per shard, sparse-aware rank updates, f64 master solve.

use std::ops::Range;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::config::Algo;
use crate::data::stream::{ParsedChunk, ShardBuilder};
use crate::data::{Dataset, Task};
use crate::linalg::Mat;
use crate::rng::{worker_stream, NormalSource, Pcg64};
use crate::solver::local;
use crate::solver::master::{solve_native, Regularizer};
use crate::solver::{GammaMode, PartialStats};

use super::{MasterBackend, StepInput, WorkerBackend};

/// One worker's native compute state.
///
/// Built either eagerly ([`NativeWorker::new`]: a shared `Arc<Dataset>`
/// plus this worker's row range) or empty for streaming ingestion
/// ([`NativeWorker::new_streaming`]: a [`ShardBuilder`] accumulates the
/// shard chunk by chunk until `seal` swaps the finished shard in).
/// Either way the worker steps over the same rows in the same order, so
/// the two construction paths produce bit-identical statistics.
pub struct NativeWorker {
    ds: Arc<Dataset>,
    range: Range<usize>,
    /// `Some` while streaming ingestion is in flight; `None` once sealed
    /// (and always for eagerly built workers)
    builder: Option<ShardBuilder>,
    algo: Algo,
    eps: f32,
    rng: Pcg64,
    normals: NormalSource,
    stats: PartialStats,
    /// reusable step scratch + MLT score cache (allocated once per
    /// worker, not once per step call)
    ws: local::StepWorkspace,
}

impl NativeWorker {
    pub fn new(
        ds: Arc<Dataset>,
        range: Range<usize>,
        algo: Algo,
        eps: f32,
        seed: u64,
        worker_id: u64,
    ) -> Self {
        let k = ds.k;
        NativeWorker {
            ds,
            range,
            builder: None,
            algo,
            eps,
            rng: worker_stream(seed, worker_id),
            normals: NormalSource::new(),
            stats: PartialStats::zeros(k),
            ws: local::StepWorkspace::new(),
        }
    }

    /// An empty worker owning the global row window `window` of an
    /// `N x k` corpus; rows arrive through `ingest` and `seal` makes the
    /// worker steppable (DESIGN.md §10).
    pub fn new_streaming(
        window: Range<usize>,
        k: usize,
        task: Task,
        algo: Algo,
        eps: f32,
        seed: u64,
        worker_id: u64,
    ) -> Self {
        NativeWorker {
            ds: Arc::new(Dataset::sparse(vec![0], Vec::new(), Vec::new(), Vec::new(), k, task)),
            range: 0..window.len(),
            builder: Some(ShardBuilder::new(window, k, task)),
            algo,
            eps,
            rng: worker_stream(seed, worker_id),
            normals: NormalSource::new(),
            stats: PartialStats::zeros(k),
            ws: local::StepWorkspace::new(),
        }
    }

}

impl WorkerBackend for NativeWorker {
    fn step(&mut self, input: &StepInput) -> Result<PartialStats> {
        if self.builder.is_some() {
            bail!("streamed worker stepped before seal");
        }
        self.stats.reset();
        // split borrows: move stats out, run, move back
        let mut stats = std::mem::replace(&mut self.stats, PartialStats::zeros(0));
        {
            let ds = self.ds.clone();
            let range = self.range.clone();
            let eps = self.eps;
            // build the mode from disjoint fields so `ws` can borrow too
            let ws = &mut self.ws;
            let mut mode = match self.algo {
                Algo::Em => GammaMode::Em,
                Algo::Mc => {
                    GammaMode::Mc { rng: &mut self.rng, normals: &mut self.normals }
                }
            };
            match input {
                StepInput::Binary { w } => {
                    local::lin_step(&ds, range, w, eps, &mut mode, ws, &mut stats)
                }
                StepInput::Svr { w, eps_ins } => {
                    local::svr_step(&ds, range, w, eps, *eps_ins, &mut mode, ws, &mut stats)
                }
                StepInput::Mlt { w_all, yidx } => {
                    local::mlt_step(&ds, range, w_all, *yidx, eps, &mut mode, ws, &mut stats)
                }
            }
        }
        let out = stats.clone();
        self.stats = stats;
        Ok(out)
    }

    fn stat_dim(&self) -> usize {
        self.ds.k
    }

    fn ingest(&mut self, chunk: &ParsedChunk) -> Result<()> {
        match self.builder.as_mut() {
            Some(b) => b.ingest(chunk),
            None => bail!("worker is sealed; streaming ingestion is over"),
        }
    }

    fn seal(&mut self) -> Result<()> {
        if let Some(b) = self.builder.take() {
            let ds = b.build()?;
            self.range = 0..ds.n;
            self.ds = Arc::new(ds);
        }
        Ok(())
    }
}

/// Native master: Cholesky solve with optional Gram regularizer.
pub struct NativeMaster {
    lambda: f32,
    gram: Option<Arc<Mat>>,
}

impl NativeMaster {
    pub fn new(lambda: f32, gram: Option<Arc<Mat>>) -> Self {
        NativeMaster { lambda, gram }
    }
}

impl MasterBackend for NativeMaster {
    fn solve(
        &mut self,
        stats: &mut PartialStats,
        mc_noise: Option<&[f32]>,
    ) -> Result<Vec<f32>> {
        let reg = match &self.gram {
            Some(g) => Regularizer::Gram { lambda: self.lambda, gram: g },
            None => Regularizer::Eye(self.lambda),
        };
        solve_native(stats, &reg, mc_noise)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn worker_step_reusable_and_deterministic() {
        let ds = Arc::new(synth::alpha_like(200, 8, 1));
        let w = Arc::new(vec![0.1f32; 8]);
        let mut a = NativeWorker::new(ds.clone(), 0..200, Algo::Em, 1e-5, 7, 0);
        let s1 = a.step(&StepInput::Binary { w: w.clone() }).unwrap();
        let s2 = a.step(&StepInput::Binary { w: w.clone() }).unwrap();
        assert_eq!(s1.sigma.data, s2.sigma.data);
        assert_eq!(s1.obj, s2.obj);

        // MC: same seed, new worker -> same stats
        let mut m1 = NativeWorker::new(ds.clone(), 0..200, Algo::Mc, 1e-5, 7, 0);
        let mut m2 = NativeWorker::new(ds.clone(), 0..200, Algo::Mc, 1e-5, 7, 0);
        let t1 = m1.step(&StepInput::Binary { w: w.clone() }).unwrap();
        let t2 = m2.step(&StepInput::Binary { w: w.clone() }).unwrap();
        assert_eq!(t1.sigma.data, t2.sigma.data);
        // and different from EM
        assert_ne!(t1.sigma.data, s1.sigma.data);
    }

    #[test]
    fn master_solve_end_to_end() {
        let ds = Arc::new(synth::alpha_like(500, 6, 2));
        let w0 = Arc::new(vec![0f32; 6]);
        let mut wk = NativeWorker::new(ds.clone(), 0..500, Algo::Em, 1e-5, 0, 0);
        let mut stats = wk.step(&StepInput::Binary { w: w0 }).unwrap();
        let mut master = NativeMaster::new(1.0, None);
        let w1 = master.solve(&mut stats, None).unwrap();
        assert!(crate::model::accuracy_cls(&ds, &w1) > 0.7);
    }
}
