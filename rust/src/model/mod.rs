//! Model-level definitions: discriminant functions, losses, objectives,
//! prediction, and evaluation metrics for the three tasks.
//!
//! [`Weights`] is the learned-parameter representation shared by the
//! whole stack — a single vector for CLS/SVR (and the dual omega for
//! KRN), a `[m, k]` matrix for the Crammer-Singer multiclass model.
//! The objective functions here are the reference definitions the
//! engine's per-iteration history reports against (Eq. 1 of the paper
//! and its SVR/MLT analogues); [`evaluate`] dispatches to accuracy or
//! RMSE on the dataset's task and is the single metric entrypoint used
//! by training, sweeps, and the serve path.

use crate::data::{Dataset, Task};
use crate::linalg::Mat;

/// The learned parameters: one weight vector for CLS/SVR, M of them for
/// the Crammer-Singer model, or dual coefficients omega for KRN (same
/// representation, interpreted against the Gram matrix).
#[derive(Clone, Debug)]
pub enum Weights {
    Single(Vec<f32>),
    /// row-major [m, k]
    PerClass(Mat),
}

impl Weights {
    pub fn single(&self) -> &[f32] {
        match self {
            Weights::Single(w) => w,
            _ => panic!("expected single weight vector"),
        }
    }

    pub fn per_class(&self) -> &Mat {
        match self {
            Weights::PerClass(w) => w,
            _ => panic!("expected per-class weights"),
        }
    }

    pub fn norm_sq(&self) -> f32 {
        match self {
            Weights::Single(w) => crate::linalg::norm2_sq(w),
            Weights::PerClass(w) => crate::linalg::norm2_sq(&w.data),
        }
    }
}

/// hinge(z) = max(0, 1 - z)
#[inline]
pub fn hinge(margin: f32) -> f32 {
    (1.0 - margin).max(0.0)
}

/// epsilon-insensitive loss |r|_eps = max(0, |r| - eps)
#[inline]
pub fn eps_insensitive(r: f32, eps: f32) -> f32 {
    (r.abs() - eps).max(0.0)
}

/// Full primal objective for binary CLS (Eq. 1):
/// J = lambda/2 ||w||^2 + 2 sum_d hinge(y_d w.x_d)
pub fn objective_cls(ds: &Dataset, w: &[f32], lambda: f32) -> f64 {
    let mut loss = 0f64;
    for d in 0..ds.n {
        loss += hinge(ds.labels[d] * ds.dot_row(d, w)) as f64;
    }
    0.5 * lambda as f64 * crate::linalg::norm2_sq(w) as f64 + 2.0 * loss
}

/// SVR objective (Eq. 20).
pub fn objective_svr(ds: &Dataset, w: &[f32], lambda: f32, eps: f32) -> f64 {
    let mut loss = 0f64;
    for d in 0..ds.n {
        loss += eps_insensitive(ds.labels[d] - ds.dot_row(d, w), eps) as f64;
    }
    0.5 * lambda as f64 * crate::linalg::norm2_sq(w) as f64 + 2.0 * loss
}

/// Crammer-Singer objective (Eq. 30) with 0/1 cost Delta.
pub fn objective_mlt(ds: &Dataset, w: &Mat, lambda: f32) -> f64 {
    let m = w.rows;
    let mut loss = 0f64;
    let mut scores = vec![0f32; m];
    for d in 0..ds.n {
        class_scores(ds, d, w, &mut scores);
        let yd = ds.labels[d] as usize;
        let mut best = f32::NEG_INFINITY;
        for (c, &s) in scores.iter().enumerate() {
            let delta = if c == yd { 0.0 } else { 1.0 };
            best = best.max(delta + s - scores[yd]);
        }
        loss += best.max(0.0) as f64;
    }
    0.5 * lambda as f64 * crate::linalg::norm2_sq(&w.data) as f64 + 2.0 * loss
}

/// scores[c] = w_c . x_d
pub fn class_scores(ds: &Dataset, d: usize, w: &Mat, out: &mut [f32]) {
    debug_assert_eq!(out.len(), w.rows);
    out.fill(0.0);
    ds.for_nonzero(d, |j, v| {
        for (c, o) in out.iter_mut().enumerate() {
            *o += v * w[(c, j as usize)];
        }
    });
}

/// Blockwise [`class_scores`] for the serving scorer: fills the
/// `[rows.len(), m]` block `out` with `out[(r, c)] = w_c . x_{rows[r]}`
/// against the *transposed* weights `wt` (`[k, m]`, see
/// [`Mat::transpose`]). Each nonzero `(j, v)` of a row becomes one
/// contiguous axpy over `wt.row(j)` instead of `m` strided loads — the
/// `[rows x K]` block hits row-major multiplies rather than the
/// per-row per-class scalar loop. Feature indices `>= wt.rows` (rows
/// wider than the model) contribute zero weight and are skipped.
///
/// Per class the additions run in the same nonzero order as
/// [`class_scores`], so the two produce bit-identical f32 scores.
pub fn class_scores_block(ds: &Dataset, rows: std::ops::Range<usize>, wt: &Mat, out: &mut Mat) {
    debug_assert_eq!(out.rows, rows.len());
    debug_assert_eq!(out.cols, wt.cols);
    out.fill(0.0);
    for (r, d) in rows.enumerate() {
        let row = out.row_mut(r);
        ds.for_nonzero(d, |j, v| {
            if (j as usize) < wt.rows {
                crate::linalg::axpy(v, wt.row(j as usize), row);
            }
        });
    }
}

/// Argmax over a score slice with [`accuracy_mlt`]'s tie-breaking
/// (ties go to the highest class index, matching `Iterator::max_by`).
pub fn argmax(scores: &[f32]) -> usize {
    let mut best = f32::NEG_INFINITY;
    let mut idx = 0;
    for (c, &s) in scores.iter().enumerate() {
        if s >= best {
            best = s;
            idx = c;
        }
    }
    idx
}

/// Binary accuracy of w on ds.
pub fn accuracy_cls(ds: &Dataset, w: &[f32]) -> f64 {
    let correct = (0..ds.n)
        .filter(|&d| ds.labels[d] * ds.dot_row(d, w) > 0.0)
        .count();
    correct as f64 / ds.n.max(1) as f64
}

/// Multiclass accuracy.
pub fn accuracy_mlt(ds: &Dataset, w: &Mat) -> f64 {
    let mut scores = vec![0f32; w.rows];
    let correct = (0..ds.n)
        .filter(|&d| {
            class_scores(ds, d, w, &mut scores);
            let pred = scores
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(c, _)| c)
                .unwrap();
            pred == ds.labels[d] as usize
        })
        .count();
    correct as f64 / ds.n.max(1) as f64
}

/// Root-mean-square error for SVR.
pub fn rmse(ds: &Dataset, w: &[f32]) -> f64 {
    let mut s = 0f64;
    for d in 0..ds.n {
        let r = (ds.labels[d] - ds.dot_row(d, w)) as f64;
        s += r * r;
    }
    (s / ds.n.max(1) as f64).sqrt()
}

/// Accuracy/RMSE dispatch on the dataset's task.
pub fn evaluate(ds: &Dataset, w: &Weights) -> f64 {
    match (ds.task, w) {
        (Task::Binary, Weights::Single(w)) => accuracy_cls(ds, w),
        (Task::Regression, Weights::Single(w)) => rmse(ds, w),
        (Task::Multiclass(_), Weights::PerClass(w)) => accuracy_mlt(ds, w),
        _ => panic!("weights/task mismatch"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn hinge_and_eps_loss() {
        assert_eq!(hinge(2.0), 0.0);
        assert_eq!(hinge(0.0), 1.0);
        assert_eq!(hinge(-1.0), 2.0);
        assert_eq!(eps_insensitive(0.2, 0.3), 0.0);
        assert!((eps_insensitive(-0.5, 0.3) - 0.2).abs() < 1e-7);
    }

    #[test]
    fn perfect_separator_has_low_objective() {
        let ds = synth::gaussian_margin(500, 8, 1, 3.0, 0.0);
        // w along the planted direction should classify well; estimate it
        // as the class-mean difference
        let mut w = vec![0f32; 8];
        let mut buf = vec![0f32; 8];
        for d in 0..ds.n {
            ds.densify_row(d, &mut buf);
            for j in 0..8 {
                w[j] += ds.labels[d] * buf[j] / ds.n as f32;
            }
        }
        // scale up to get margins > 1
        w.iter_mut().for_each(|v| *v *= 10.0);
        assert!(accuracy_cls(&ds, &w) > 0.95);
        let j_sep = objective_cls(&ds, &w, 1e-6);
        let j_zero = objective_cls(&ds, &vec![0.0; 8], 1e-6);
        assert!(j_sep < j_zero);
    }

    #[test]
    fn mlt_scores_and_accuracy() {
        let ds = synth::mnist_like(300, 12, 4, 3);
        // prototype classifier: mean of each class
        let mut w = Mat::zeros(4, 12);
        let mut counts = [0f32; 4];
        let mut buf = vec![0f32; 12];
        for d in 0..ds.n {
            let c = ds.labels[d] as usize;
            counts[c] += 1.0;
            ds.densify_row(d, &mut buf);
            for j in 0..12 {
                w[(c, j)] += buf[j];
            }
        }
        for c in 0..4 {
            for j in 0..12 {
                w[(c, j)] /= counts[c].max(1.0);
            }
        }
        assert!(accuracy_mlt(&ds, &w) > 0.7);
    }

    #[test]
    fn block_scores_match_per_row_exactly() {
        let ds = synth::mnist_like(120, 17, 5, 9);
        let mut w = Mat::zeros(5, 17);
        let mut g = crate::rng::Pcg64::new(11);
        for x in w.data.iter_mut() {
            *x = g.next_f32() - 0.5;
        }
        let wt = w.transpose();
        let mut block = Mat::zeros(40, 5);
        class_scores_block(&ds, 30..70, &wt, &mut block);
        let mut per_row = vec![0f32; 5];
        for d in 30..70 {
            class_scores(&ds, d, &w, &mut per_row);
            assert_eq!(block.row(d - 30), &per_row[..], "row {d}");
            assert_eq!(argmax(block.row(d - 30)), {
                per_row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(c, _)| c)
                    .unwrap()
            });
        }
    }

    #[test]
    fn rmse_of_true_weights_small() {
        let ds = synth::year_like(2000, 10, 4);
        // least squares fit via normal equations as a sanity reference
        let mut packed = crate::linalg::SymPacked::zeros(10);
        let mut b = vec![0f32; 10];
        let mut buf = vec![0f32; 10];
        for d in 0..ds.n {
            ds.densify_row(d, &mut buf);
            crate::linalg::rank_update_dense(&mut packed, &buf, 1, 10, &[1.0]);
            crate::linalg::axpy(ds.labels[d], &buf, &mut b);
        }
        let mut a = packed.unpack();
        a.add_scaled_eye(1.0);
        let w = crate::linalg::solve_cholesky(&mut a, &b).unwrap();
        assert!(rmse(&ds, &w) < 0.6);
        assert!(rmse(&ds, &vec![0.0; 10]) > rmse(&ds, &w));
    }
}
