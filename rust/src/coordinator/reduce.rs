//! The reduce step: sum the P workers' partial statistics.
//!
//! `flat` folds at the leader (O(P K^2) sequential); `tree` merges pairs
//! in log2(P) parallel rounds — the topology behind the `K^2 log(P)`
//! term in the paper's Table 1.

use crate::config::ReduceKind;
use crate::solver::PartialStats;

/// Reduce in worker-id order (deterministic for a fixed P).
pub fn reduce(kind: ReduceKind, mut partials: Vec<PartialStats>) -> PartialStats {
    assert!(!partials.is_empty());
    match kind {
        ReduceKind::Flat => {
            let mut acc = partials.remove(0);
            for p in &partials {
                acc.merge(p);
            }
            acc
        }
        ReduceKind::Tree => tree_reduce(partials),
    }
}

fn tree_reduce(mut partials: Vec<PartialStats>) -> PartialStats {
    let mut stride = 1usize;
    while stride < partials.len() {
        // each round's merges run in parallel, like simultaneous
        // pairwise exchanges on a cluster
        std::thread::scope(|scope| {
            for chunk in partials.chunks_mut(2 * stride) {
                if chunk.len() > stride {
                    let (a, b) = chunk.split_at_mut(stride);
                    let dst = &mut a[0];
                    let src = &b[0];
                    scope.spawn(move || dst.merge(src));
                }
            }
        });
        stride *= 2;
    }
    partials.swap_remove(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn random_partials(p: usize, k: usize, seed: u64) -> Vec<PartialStats> {
        let mut g = Pcg64::new(seed);
        (0..p)
            .map(|_| {
                let mut st = PartialStats::zeros(k);
                for v in st.sigma.data.iter_mut() {
                    *v = g.next_f32() - 0.5;
                }
                for v in st.mu.iter_mut() {
                    *v = g.next_f32() - 0.5;
                }
                st.obj = g.next_f64();
                st.aux = g.next_f64();
                st
            })
            .collect()
    }

    /// Property: tree == flat == serial sum for every P (up to f32
    /// association error, which for these magnitudes is ~1e-5).
    #[test]
    fn tree_equals_flat_for_all_p() {
        for p in [1usize, 2, 3, 4, 5, 7, 8, 16, 33] {
            let parts = random_partials(p, 6, p as u64);
            let a = reduce(ReduceKind::Flat, parts.clone());
            let b = reduce(ReduceKind::Tree, parts);
            assert!(a.sigma.max_abs_diff(&b.sigma) < 1e-4, "P={p}");
            for (x, y) in a.mu.iter().zip(&b.mu) {
                assert!((x - y).abs() < 1e-4, "P={p}");
            }
            assert!((a.obj - b.obj).abs() < 1e-9, "P={p}");
        }
    }
}
