//! The reduce step: sum the P workers' partial statistics.
//!
//! `flat` folds at the leader (O(P K^2) sequential); `tree` merges pairs
//! in log2(P) rounds — the topology behind the `K^2 log(P)` term in the
//! paper's Table 1.
//!
//! Both run on the calling thread: in the threaded topology the engine's
//! pool dispatches the tree's pair merges onto its own worker threads
//! (`engine::pool`) rather than spawning fresh OS threads per round, and
//! the sequential simulator uses this serial tree directly. The pairing
//! order here (slot `i` absorbs slot `i + stride`) is identical to the
//! in-pool version, so the two produce bit-identical f32 sums.

use crate::config::ReduceKind;
use crate::solver::PartialStats;

/// Reduce in worker-id order (deterministic for a fixed P).
pub fn reduce(kind: ReduceKind, mut partials: Vec<PartialStats>) -> PartialStats {
    assert!(!partials.is_empty());
    match kind {
        ReduceKind::Flat => {
            let mut acc = partials.remove(0);
            for p in &partials {
                acc.merge(p);
            }
            acc
        }
        ReduceKind::Tree => tree_reduce(partials),
    }
}

fn tree_reduce(mut partials: Vec<PartialStats>) -> PartialStats {
    let mut stride = 1usize;
    while stride < partials.len() {
        let mut i = 0usize;
        while i + stride < partials.len() {
            let (a, b) = partials.split_at_mut(i + stride);
            a[i].merge(&b[0]);
            i += 2 * stride;
        }
        stride *= 2;
    }
    partials.swap_remove(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn random_partials(p: usize, k: usize, seed: u64) -> Vec<PartialStats> {
        let mut g = Pcg64::new(seed);
        (0..p)
            .map(|_| {
                let mut st = PartialStats::zeros(k);
                for v in st.sigma.data.iter_mut() {
                    *v = g.next_f32() - 0.5;
                }
                for v in st.mu.iter_mut() {
                    *v = g.next_f32() - 0.5;
                }
                st.obj = g.next_f64();
                st.aux = g.next_f64();
                st
            })
            .collect()
    }

    /// Property: tree == flat == serial sum for every P (up to f32
    /// association error, which for these magnitudes is ~1e-5).
    #[test]
    fn tree_equals_flat_for_all_p() {
        for p in [1usize, 2, 3, 4, 5, 7, 8, 16, 33] {
            let parts = random_partials(p, 6, p as u64);
            let a = reduce(ReduceKind::Flat, parts.clone());
            let b = reduce(ReduceKind::Tree, parts);
            assert!(a.sigma.max_abs_diff(&b.sigma) < 1e-4, "P={p}");
            for (x, y) in a.mu.iter().zip(&b.mu) {
                assert!((x - y).abs() < 1e-4, "P={p}");
            }
            assert!((a.obj - b.obj).abs() < 1e-9, "P={p}");
        }
    }
}
