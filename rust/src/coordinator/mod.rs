//! One-shot training entrypoints — thin wrappers over the persistent
//! [`crate::engine`] runtime.
//!
//! The leader/worker topology, the iteration loop and the reduce step
//! all live in `engine::{Cluster, Pool, IterDriver}` now; `train` /
//! `train_full` build a single-use [`Cluster`] and run one session on
//! it. Long-lived callers (the `sweep` subcommand, serving paths)
//! should hold a `Cluster` directly and amortize the setup across
//! sessions.

pub mod reduce;

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::config::{ModelKind, TaskKind, TrainConfig};
use crate::data::{Dataset, Task};
use crate::engine::{CheckpointCfg, Cluster, WarmStart};
use crate::solver::{gram_dataset, KernelModel};
use crate::telemetry::TraceWriter;

pub use crate::engine::{IterRecord, TrainOutput};

/// Train with the configured topology/backend. Convenience wrapper
/// without a held-out set.
pub fn train(ds: &Dataset, cfg: &TrainConfig) -> Result<TrainOutput> {
    train_full(ds, None, cfg)
}

/// Train; when `test` is given, the per-iteration history carries the
/// held-out metric (accuracy for CLS/MLT, RMSE for SVR).
pub fn train_full(ds: &Dataset, test: Option<&Dataset>, cfg: &TrainConfig) -> Result<TrainOutput> {
    train_full_traced(ds, test, cfg, None)
}

/// [`train_full`] with optional iteration span tracing (DESIGN.md §12):
/// one JSONL record per iteration through the [`TraceWriter`].
pub fn train_full_traced(
    ds: &Dataset,
    test: Option<&Dataset>,
    cfg: &TrainConfig,
    trace: Option<&mut TraceWriter>,
) -> Result<TrainOutput> {
    train_full_checkpointed(ds, test, cfg, trace, None)
}

/// [`train_full_traced`] with checkpoint/resume (DESIGN.md §13): with
/// `ck`, the session state is written every `ck.every` iterations and
/// `ck.resume` continues a killed run bit-exactly.
pub fn train_full_checkpointed(
    ds: &Dataset,
    test: Option<&Dataset>,
    cfg: &TrainConfig,
    trace: Option<&mut TraceWriter>,
    ck: Option<&CheckpointCfg>,
) -> Result<TrainOutput> {
    // reject a task/dataset mismatch before any work — for KRN the
    // engine's own check would only fire after the O(N^2 K) Gram pass
    match (cfg.task, ds.task) {
        (TaskKind::Cls, Task::Binary)
        | (TaskKind::Svr, Task::Regression)
        | (TaskKind::Mlt, Task::Multiclass(_)) => {}
        (t, d) => bail!("config task {t:?} does not match dataset task {d:?}"),
    }
    if cfg.model == ModelKind::Kernel {
        if cfg.task != TaskKind::Cls {
            bail!("KRN is implemented for CLS (the paper evaluates KRN-EM-CLS)");
        }
        if ck.is_some() {
            bail!("checkpoint/resume is implemented for linear models (LIN)");
        }
        return train_kernel(ds, test, cfg, trace);
    }
    let mut cluster = Cluster::new(ds, cfg)?;
    cluster.run_session_checkpointed(cfg, test, WarmStart::Cold, trace, ck)
}

/// KRN: swap in the Gram-row dataset and the Gram regularizer (§3.1),
/// then reuse the LIN machinery verbatim.
fn train_kernel(
    ds: &Dataset,
    test: Option<&Dataset>,
    cfg: &TrainConfig,
    trace: Option<&mut TraceWriter>,
) -> Result<TrainOutput> {
    let (kds, gram) = gram_dataset(ds, &cfg.kernel);
    let mut cluster = Cluster::with_gram(&kds, cfg, Some(Arc::new(gram)))?;
    let mut out = cluster.run_session_traced(cfg, None, WarmStart::Cold, trace)?;
    let omega = out.weights.single().to_vec();
    let model = KernelModel { train: ds.clone(), omega, cfg: cfg.kernel };
    if let Some(te) = test {
        let acc = model.accuracy(te);
        if let Some(last) = out.history.last_mut() {
            last.test_metric = Some(acc);
        }
    }
    out.kernel_model = Some(model);
    Ok(out)
}
