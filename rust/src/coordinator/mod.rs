//! The parallel coordinator — the paper's §4 contribution.
//!
//! Topology: one leader (this thread) + P worker threads (the MPI ranks
//! of §5.7.1). Each iteration:
//!
//! 1. leader broadcasts the current weights (Cmd::Step),
//! 2. workers run their shard's gamma update + local statistics on
//!    their backend (native CPU or XLA/PJRT),
//! 3. partials are reduced (flat or binary tree),
//! 4. leader solves / samples the posterior for the new weights,
//! 5. stopping rule: |J_m - J_{m-1}| <= tol * N (§5.5).
//!
//! MC mode additionally averages post-burn-in samples (§5.13). The
//! Crammer-Singer task wraps steps 1-4 in a loop over classes (§3.3's
//! blockwise scheme).

pub mod reduce;

use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::backend::{self, StepInput, WorkerBackend};
use crate::config::{Algo, ModelKind, TaskKind, TrainConfig};
use crate::data::{shard_ranges, Dataset, Task};
use crate::linalg::Mat;
use crate::metrics::{Metrics, Phase};
use crate::model::Weights;
use crate::rng::{NormalSource, Pcg64};
use crate::solver::{gram_dataset, KernelModel, PartialStats};

/// Per-iteration record (drives Figures 5 and 6).
#[derive(Clone, Debug)]
pub struct IterRecord {
    pub iter: usize,
    /// primal objective J at the weights the step was computed from
    pub objective: f64,
    /// training loss sum (hinge / eps-insensitive / CS)
    pub train_loss: f64,
    /// training error fraction (CLS/MLT) or mean squared residual (SVR)
    pub train_err: f64,
    /// held-out metric (accuracy or RMSE) if a test set was supplied
    pub test_metric: Option<f64>,
}

/// Everything a training run returns.
pub struct TrainOutput {
    pub weights: Weights,
    pub objective: f64,
    pub iterations: usize,
    pub metrics: Metrics,
    pub history: Vec<IterRecord>,
    /// populated for KRN runs: the dual model for prediction
    pub kernel_model: Option<KernelModel>,
}

enum Cmd {
    Step(StepInput),
    Stop,
}

/// Train with the configured topology/backend. Convenience wrapper
/// without a held-out set.
pub fn train(ds: &Dataset, cfg: &TrainConfig) -> Result<TrainOutput> {
    train_full(ds, None, cfg)
}

/// Train; when `test` is given, the per-iteration history carries the
/// held-out metric (accuracy for CLS/MLT, RMSE for SVR).
pub fn train_full(ds: &Dataset, test: Option<&Dataset>, cfg: &TrainConfig) -> Result<TrainOutput> {
    match (cfg.task, ds.task) {
        (TaskKind::Cls, Task::Binary)
        | (TaskKind::Svr, Task::Regression)
        | (TaskKind::Mlt, Task::Multiclass(_)) => {}
        (t, d) => bail!("config task {t:?} does not match dataset task {d:?}"),
    }
    if cfg.model == ModelKind::Kernel {
        if cfg.task != TaskKind::Cls {
            bail!("KRN is implemented for CLS (the paper evaluates KRN-EM-CLS)");
        }
        return train_kernel(ds, test, cfg);
    }
    train_inner(ds, test, cfg, None, ds)
}

/// KRN: swap in the Gram-row dataset and the Gram regularizer (§3.1),
/// then reuse the LIN machinery verbatim.
fn train_kernel(ds: &Dataset, test: Option<&Dataset>, cfg: &TrainConfig) -> Result<TrainOutput> {
    let (kds, gram) = gram_dataset(ds, &cfg.kernel);
    let gram = Arc::new(gram);
    let mut out = train_inner(&kds, None, cfg, Some(gram), ds)?;
    let omega = out.weights.single().to_vec();
    let model = KernelModel { train: ds.clone(), omega, cfg: cfg.kernel };
    if let Some(te) = test {
        let acc = model.accuracy(te);
        if let Some(last) = out.history.last_mut() {
            last.test_metric = Some(acc);
        }
    }
    out.kernel_model = Some(model);
    Ok(out)
}

fn train_inner(
    ds: &Dataset,
    test: Option<&Dataset>,
    cfg: &TrainConfig,
    gram: Option<Arc<Mat>>,
    orig: &Dataset,
) -> Result<TrainOutput> {
    let n = ds.n;
    let p = cfg.workers.max(1);
    let ds_arc = Arc::new(ds.clone());
    let shards: Vec<_> = shard_ranges(n, p).into_iter().map(|s| s.range).collect();
    let workers = backend::make_workers(cfg, &ds_arc, &shards)?;
    let dim = workers.iter().map(|w| w.stat_dim()).max().unwrap_or(ds.k);
    let mut master = backend::make_master(cfg, dim, gram.clone())?;

    let mut metrics = Metrics::new();
    let mut history: Vec<IterRecord> = Vec::new();
    let mut leader_rng = Pcg64::new_stream(cfg.seed, 0x1ead);
    let mut leader_normals = NormalSource::new();

    // MC running average (post burn-in)
    let mut avg: Option<Vec<f32>> = None;
    let mut avg_count = 0usize;

    let m_classes = match ds.task {
        Task::Multiclass(m) => m,
        _ => 1,
    };
    let mut w_all = Mat::zeros(m_classes.max(1), dim);
    let mut w = Arc::new(vec![0f32; dim]);

    let result: Result<()> = std::thread::scope(|scope| {
        // Worker pool: real threads (the default; MPI-rank analogue) or
        // the sequential cluster simulator. In simulate mode each worker
        // runs serially on this thread and the "parallel" iteration time
        // recorded in metrics is max(worker durations) — the cost model
        // of the paper's homogeneous cluster (§4.1), which lets the
        // scaling benches sweep P far beyond this box's physical cores.
        let mut seq_workers: Vec<Box<dyn WorkerBackend>> = Vec::new();
        let (res_tx, res_rx) = mpsc::channel::<(usize, Result<PartialStats>, Duration)>();
        let mut cmd_txs = Vec::new();
        if cfg.simulate_cluster {
            seq_workers = workers;
        } else {
            for (wid, mut wk) in workers.into_iter().enumerate() {
                let (tx, rx) = mpsc::channel::<Cmd>();
                cmd_txs.push(tx);
                let res_tx = res_tx.clone();
                scope.spawn(move || {
                    while let Ok(cmd) = rx.recv() {
                        match cmd {
                            Cmd::Stop => break,
                            Cmd::Step(input) => {
                                let t0 = Instant::now();
                                let r = wk.step(&input);
                                let _ = res_tx.send((wid, r, t0.elapsed()));
                            }
                        }
                    }
                });
            }
        }
        drop(res_tx);

        // one broadcast+collect+reduce round; returns reduced stats
        let mut collect = |input: StepInput, metrics: &mut Metrics| -> Result<PartialStats> {
            let partials: Vec<PartialStats> = if cfg.simulate_cluster {
                let mut max_step = Duration::ZERO;
                let mut out = Vec::with_capacity(p);
                for wk in seq_workers.iter_mut() {
                    let t0 = Instant::now();
                    out.push(wk.step(&input)?);
                    max_step = max_step.max(t0.elapsed());
                }
                metrics.add(Phase::LocalStats, max_step);
                out
            } else {
                let t0 = Instant::now();
                for tx in &cmd_txs {
                    tx.send(Cmd::Step(input.clone()))
                        .map_err(|_| anyhow!("worker hung up"))?;
                }
                metrics.add(Phase::Broadcast, t0.elapsed());
                let mut slots: Vec<Option<PartialStats>> = (0..p).map(|_| None).collect();
                let mut max_step = Duration::ZERO;
                for _ in 0..p {
                    let (wid, r, dur) = res_rx.recv().context("worker died")?;
                    slots[wid] = Some(r?);
                    max_step = max_step.max(dur);
                }
                metrics.add(Phase::LocalStats, max_step);
                slots.into_iter().map(Option::unwrap).collect()
            };
            metrics.reduces += 1;
            Ok(metrics.time(Phase::Reduce, || reduce::reduce(cfg.reduce, partials)))
        };

        let mut j_prev = f64::INFINITY;
        let mut smooth: Vec<f64> = Vec::new();
        for iter in 0..cfg.max_iters {
            let (loss_sum, err_sum, j) = match cfg.task {
                TaskKind::Mlt => {
                    let mut loss_sum = 0f64;
                    let mut err_sum = 0f64;
                    for y in 0..m_classes {
                        // Gauss-Seidel over class blocks: each class sees
                        // the already-updated weights of earlier classes
                        let w_arc = Arc::new(w_all.clone());
                        let mut stats = collect(
                            StepInput::Mlt { w_all: w_arc, yidx: y },
                            &mut metrics,
                        )?;
                        if y == 0 {
                            loss_sum = stats.obj;
                            err_sum = stats.aux;
                        }
                        let noise = mc_noise(cfg, dim, &mut leader_rng, &mut leader_normals);
                        let wy = metrics
                            .time(Phase::DrawMu, || master.solve(&mut stats, noise.as_deref()))?;
                        w_all.row_mut(y).copy_from_slice(&wy);
                    }
                    let j = 0.5 * cfg.lambda as f64
                        * crate::linalg::norm2_sq(&w_all.data) as f64
                        + 2.0 * loss_sum;
                    (loss_sum, err_sum, j)
                }
                _ => {
                    let input = match cfg.task {
                        TaskKind::Cls => StepInput::Binary { w: w.clone() },
                        TaskKind::Svr => {
                            StepInput::Svr { w: w.clone(), eps_ins: cfg.eps_insensitive }
                        }
                        TaskKind::Mlt => unreachable!(),
                    };
                    let mut stats = collect(input, &mut metrics)?;
                    let loss_sum = stats.obj;
                    let err_sum = stats.aux;
                    let j = reg_quad(cfg, &gram, &w) + 2.0 * loss_sum;
                    let noise = mc_noise(cfg, dim, &mut leader_rng, &mut leader_normals);
                    let w_new = metrics
                        .time(Phase::DrawMu, || master.solve(&mut stats, noise.as_deref()))?;
                    w = Arc::new(w_new);
                    (loss_sum, err_sum, j)
                }
            };

            // MC running average (post burn-in)
            if cfg.algo == Algo::Mc && iter >= cfg.burn_in {
                let cur: &[f32] = match cfg.task {
                    TaskKind::Mlt => &w_all.data,
                    _ => &w,
                };
                match &mut avg {
                    None => {
                        avg = Some(cur.to_vec());
                        avg_count = 1;
                    }
                    Some(a) => {
                        avg_count += 1;
                        let alpha = 1.0 / avg_count as f32;
                        for (ai, ci) in a.iter_mut().zip(cur) {
                            *ai += alpha * (ci - *ai);
                        }
                    }
                }
            }

            // held-out metric for the history (Figure 6)
            let test_metric = metrics.time(Phase::Other, || {
                test.filter(|_| cfg.model == ModelKind::Linear).map(|te| {
                    let weights = snapshot_weights(cfg, ds, &w, &w_all, &avg, m_classes);
                    crate::model::evaluate(te, &weights)
                })
            });

            history.push(IterRecord {
                iter,
                objective: j,
                train_loss: loss_sum,
                train_err: match cfg.task {
                    TaskKind::Svr => err_sum / n as f64, // mean squared residual
                    _ => err_sum / n as f64,             // error fraction
                },
                test_metric,
            });
            metrics.iterations = iter + 1;

            // stopping rule (§5.5): change of (smoothed, for MC) J
            let j_s = if cfg.algo == Algo::Mc {
                smooth.push(j);
                let lo = smooth.len().saturating_sub(5);
                smooth[lo..].iter().sum::<f64>() / (smooth.len() - lo) as f64
            } else {
                j
            };
            let min_iters = if cfg.algo == Algo::Mc { cfg.burn_in + 5 } else { 2 };
            if iter >= min_iters && (j_prev - j_s).abs() <= cfg.tol as f64 * n as f64 {
                break;
            }
            j_prev = j_s;
        }

        for tx in &cmd_txs {
            let _ = tx.send(Cmd::Stop);
        }
        Ok(())
    });
    result?;

    let weights = snapshot_weights(cfg, ds, &w, &w_all, &avg, m_classes);
    let objective = history.last().map(|h| h.objective).unwrap_or(f64::INFINITY);
    let iterations = history.len();
    let _ = orig; // kernel caller re-wraps; kept for API symmetry
    Ok(TrainOutput { weights, objective, iterations, metrics, history, kernel_model: None })
}

/// lam/2 * w^T R w (R = I for LIN, Gram for KRN).
fn reg_quad(cfg: &TrainConfig, gram: &Option<Arc<Mat>>, w: &[f32]) -> f64 {
    match gram {
        None => 0.5 * cfg.lambda as f64 * crate::linalg::norm2_sq(w) as f64,
        Some(g) => {
            let k = g.rows.min(w.len());
            let mut q = 0f64;
            for i in 0..k {
                q += w[i] as f64 * crate::linalg::dot(&g.row(i)[..k], &w[..k]) as f64;
            }
            0.5 * cfg.lambda as f64 * q
        }
    }
}

/// MC posterior noise for the master draw.
fn mc_noise(
    cfg: &TrainConfig,
    dim: usize,
    rng: &mut Pcg64,
    normals: &mut NormalSource,
) -> Option<Vec<f32>> {
    (cfg.algo == Algo::Mc).then(|| {
        let mut z = vec![0f32; dim];
        normals.fill_f32(rng, &mut z);
        z
    })
}

/// Current model snapshot: EM takes the latest weights, MC the running
/// post-burn-in average (§5.13); always truncated back to the dataset's
/// true feature width (XLA pads).
fn snapshot_weights(
    cfg: &TrainConfig,
    ds: &Dataset,
    w: &Arc<Vec<f32>>,
    w_all: &Mat,
    avg: &Option<Vec<f32>>,
    m_classes: usize,
) -> Weights {
    let k = ds.k;
    match cfg.task {
        TaskKind::Mlt => {
            let dim = w_all.cols;
            let src: &[f32] = match (cfg.algo, avg) {
                (Algo::Mc, Some(a)) => a,
                _ => &w_all.data,
            };
            let mut out = Mat::zeros(m_classes, k);
            for c in 0..m_classes {
                out.row_mut(c).copy_from_slice(&src[c * dim..c * dim + k]);
            }
            Weights::PerClass(out)
        }
        _ => {
            let src: &[f32] = match (cfg.algo, avg) {
                (Algo::Mc, Some(a)) => a,
                _ => w,
            };
            Weights::Single(src[..k].to_vec())
        }
    }
}
