//! Configuration: a TOML-subset file format + the typed [`TrainConfig`]
//! every entrypoint (CLI, examples, benches) builds on. `serde`/`toml`
//! are not in the offline registry, so the parser is ours (sections,
//! `key = value`, strings / numbers / bools / flat arrays, comments).

pub mod json;
pub mod toml;

use anyhow::{bail, Result};

pub use json::Json;
pub use toml::TomlDoc;

/// LIN vs KRN (paper §4.2 options, first axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    Linear,
    Kernel,
}

/// EM vs MC (second axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    Em,
    Mc,
}

/// CLS vs SVR vs MLT (third axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    Cls,
    Svr,
    Mlt,
}

/// Which compute backend executes the worker/master steps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// pure Rust, sparse-aware — the paper's MPI CPU implementation
    Native,
    /// PJRT-compiled HLO artifacts (Pallas kernel inside) — the paper's
    /// GPU implementation, re-targeted
    Xla,
}

/// Reduction topology for the partial statistics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceKind {
    /// leader sums all P partials (O(P) at the leader)
    Flat,
    /// binary tree (the paper's log(P) term): pair merges run on the
    /// engine's worker threads in the threaded topology, serially (in
    /// the same pairing order) in the simulated one
    Tree,
}

/// How the worker "cluster" executes (see `engine::Cluster`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Topology {
    /// one persistent OS thread per worker — the MPI-rank analogue
    Threads,
    /// workers run serially on the leader thread and the metrics record
    /// max(worker durations) per iteration — the homogeneous-cluster
    /// cost model (§4.1), for sweeping P beyond this box's cores
    Simulate,
    /// one `pemsvm worker` daemon per host:port — solver steps execute
    /// in remote processes over the `net` wire protocol (DESIGN.md §15);
    /// bit-identical to `Threads` for a fixed seed
    Remote(Vec<String>),
}

impl Topology {
    /// Host-independent topology tag, used as the checkpoint
    /// fingerprint: a `Remote` checkpoint resumes onto a `Remote`
    /// cluster with *any* host list (the workers are interchangeable —
    /// shard assignment follows worker id, not address).
    pub fn name(&self) -> &'static str {
        match self {
            Topology::Threads => "Threads",
            Topology::Simulate => "Simulate",
            Topology::Remote(_) => "Remote",
        }
    }
}

/// Kernel function for KRN runs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KernelCfg {
    /// k(x, z) = exp(-||x - z||^2 / (2 sigma^2))
    Gaussian { sigma: f32 },
    /// k(x, z) = x . z
    LinearK,
}

/// Everything a training run needs. Defaults follow the paper's §5
/// settings (eps clamp 1e-5, tol 0.001 * N, burn-in 10).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub model: ModelKind,
    pub algo: Algo,
    pub task: TaskKind,
    /// l2 regularization weight lambda (liblinear's C maps to 1/(2C N)
    /// up to the paper's factor-2 loss scaling; benches set it directly)
    pub lambda: f32,
    /// gamma clamp epsilon (§5.7.3)
    pub eps_clamp: f32,
    /// SVR insensitivity epsilon (§3.2)
    pub eps_insensitive: f32,
    pub max_iters: usize,
    /// stop when |J_m - J_{m-1}| <= tol * N (§5.5)
    pub tol: f32,
    pub workers: usize,
    pub seed: u64,
    /// MC burn-in iterations before averaging (§5.13)
    pub burn_in: usize,
    pub backend: BackendKind,
    pub reduce: ReduceKind,
    pub num_classes: usize,
    pub kernel: KernelCfg,
    pub artifacts_dir: String,
    /// print per-iteration progress
    pub verbose: bool,
    /// worker-pool execution mode: real threads or the sequential
    /// cluster cost model (DESIGN.md §6)
    pub topology: Topology,
    /// multi-session runs (the `sweep` subcommand): start each session
    /// from the previous session's weights instead of zero
    pub warm_start: bool,
    /// XLA backend: route the Sigma/mu statistics through the Pallas
    /// kernel artifact (true, default) or the XLA-native-dot ablation
    /// twin (false; EM/CLS only)
    pub xla_use_pallas: bool,
    /// fault tolerance (DESIGN.md §13): how long the leader waits for a
    /// worker's step reply before retrying it (threaded topology)
    pub step_timeout_ms: u64,
    /// retries per worker per round before the worker is evicted and its
    /// rows re-sharded onto the survivors
    pub step_retries: usize,
    /// convergence diagnostics cadence (DESIGN.md §14): feed the
    /// `ChainDiag` accumulator every N iterations; 0 (default) disables
    /// diagnostics entirely and keeps train output byte-identical
    pub diag_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: ModelKind::Linear,
            algo: Algo::Em,
            task: TaskKind::Cls,
            lambda: 1.0,
            eps_clamp: 1e-5,
            eps_insensitive: 1e-3,
            max_iters: 200,
            tol: 1e-3,
            workers: 4,
            seed: 0,
            burn_in: 10,
            backend: BackendKind::Native,
            reduce: ReduceKind::Flat,
            num_classes: 2,
            kernel: KernelCfg::Gaussian { sigma: 1.0 },
            artifacts_dir: "artifacts".into(),
            verbose: false,
            topology: Topology::Threads,
            warm_start: false,
            xla_use_pallas: true,
            step_timeout_ms: 30_000,
            step_retries: 2,
            diag_every: 0,
        }
    }
}

impl TrainConfig {
    /// Parse the paper's option string, e.g. "LIN-EM-CLS" / "KRN-MC-SVR".
    pub fn with_options(mut self, opts: &str) -> Result<Self> {
        for part in opts.split('-') {
            match part.to_ascii_uppercase().as_str() {
                "LIN" => self.model = ModelKind::Linear,
                "KRN" => self.model = ModelKind::Kernel,
                "EM" => self.algo = Algo::Em,
                "MC" => self.algo = Algo::Mc,
                "CLS" => self.task = TaskKind::Cls,
                "SVR" => self.task = TaskKind::Svr,
                "MLT" => self.task = TaskKind::Mlt,
                other => bail!("unknown option `{other}` in `{opts}`"),
            }
        }
        Ok(self)
    }

    /// The paper's option-string for this config ("LIN-EM-CLS").
    pub fn options_string(&self) -> String {
        format!(
            "{}-{}-{}",
            match self.model {
                ModelKind::Linear => "LIN",
                ModelKind::Kernel => "KRN",
            },
            match self.algo {
                Algo::Em => "EM",
                Algo::Mc => "MC",
            },
            match self.task {
                TaskKind::Cls => "CLS",
                TaskKind::Svr => "SVR",
                TaskKind::Mlt => "MLT",
            }
        )
    }

    /// Apply `key = value` overrides from a parsed TOML doc (flat keys or
    /// under a `[train]` section).
    pub fn apply_toml(&mut self, doc: &TomlDoc) -> Result<()> {
        for (key, val) in doc.entries() {
            let k = key.strip_prefix("train.").unwrap_or(key);
            self.set(k, &val.to_string())?;
        }
        Ok(())
    }

    /// Set a single field by name (shared by TOML and CLI paths).
    pub fn set(&mut self, key: &str, val: &str) -> Result<()> {
        let v = val.trim().trim_matches('"');
        match key {
            "options" => *self = self.clone().with_options(v)?,
            "model" => {
                self.model = match v.to_ascii_lowercase().as_str() {
                    "lin" | "linear" => ModelKind::Linear,
                    "krn" | "kernel" => ModelKind::Kernel,
                    _ => bail!("bad model `{v}`"),
                }
            }
            "algo" => {
                self.algo = match v.to_ascii_lowercase().as_str() {
                    "em" => Algo::Em,
                    "mc" => Algo::Mc,
                    _ => bail!("bad algo `{v}`"),
                }
            }
            "task" => {
                self.task = match v.to_ascii_lowercase().as_str() {
                    "cls" => TaskKind::Cls,
                    "svr" => TaskKind::Svr,
                    "mlt" => TaskKind::Mlt,
                    _ => bail!("bad task `{v}`"),
                }
            }
            "lambda" => self.lambda = v.parse()?,
            "eps_clamp" => self.eps_clamp = v.parse()?,
            "eps_insensitive" => self.eps_insensitive = v.parse()?,
            "max_iters" => self.max_iters = v.parse()?,
            "tol" => self.tol = v.parse()?,
            "workers" => self.workers = v.parse()?,
            "seed" => self.seed = v.parse()?,
            "burn_in" => self.burn_in = v.parse()?,
            "num_classes" => self.num_classes = v.parse()?,
            "artifacts_dir" => self.artifacts_dir = v.to_string(),
            "verbose" => self.verbose = v.parse()?,
            "topology" => {
                self.topology = match v.to_ascii_lowercase().as_str() {
                    "threads" | "threaded" => Topology::Threads,
                    "simulate" | "simulated" => Topology::Simulate,
                    "remote" => bail!(
                        "the remote topology is selected by its host list: pass \
                         --hosts a:port,b:port instead of --topology remote"
                    ),
                    _ => bail!("bad topology `{v}`"),
                }
            }
            // `--hosts a:p,b:p` selects the remote topology and pins the
            // worker count to the host count (one daemon per worker)
            "hosts" => {
                let hosts: Vec<String> = v
                    .split(',')
                    .map(|h| h.trim().to_string())
                    .filter(|h| !h.is_empty())
                    .collect();
                if hosts.is_empty() {
                    bail!("--hosts needs a comma-separated host:port list");
                }
                for h in &hosts {
                    if !h.contains(':') {
                        bail!("bad host `{h}` in --hosts (want host:port)");
                    }
                }
                self.workers = hosts.len();
                self.topology = Topology::Remote(hosts);
            }
            // back-compat alias for the pre-engine boolean flag
            "simulate_cluster" => {
                self.topology =
                    if v.parse()? { Topology::Simulate } else { Topology::Threads }
            }
            "warm_start" => self.warm_start = v.parse()?,
            "xla_use_pallas" => self.xla_use_pallas = v.parse()?,
            "step_timeout_ms" => self.step_timeout_ms = v.parse()?,
            "step_retries" => self.step_retries = v.parse()?,
            "diag_every" => self.diag_every = v.parse()?,
            "backend" => {
                self.backend = match v.to_ascii_lowercase().as_str() {
                    "native" => BackendKind::Native,
                    "xla" => BackendKind::Xla,
                    _ => bail!("bad backend `{v}`"),
                }
            }
            "reduce" => {
                self.reduce = match v.to_ascii_lowercase().as_str() {
                    "flat" => ReduceKind::Flat,
                    "tree" => ReduceKind::Tree,
                    _ => bail!("bad reduce `{v}`"),
                }
            }
            "kernel" => {
                self.kernel = match v.to_ascii_lowercase().as_str() {
                    "linear" => KernelCfg::LinearK,
                    "gaussian" => KernelCfg::Gaussian { sigma: 1.0 },
                    _ => bail!("bad kernel `{v}`"),
                }
            }
            "kernel_sigma" => {
                self.kernel = KernelCfg::Gaussian { sigma: v.parse()? };
            }
            other => bail!("unknown config key `{other}`"),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn options_roundtrip() {
        for s in ["LIN-EM-CLS", "KRN-MC-SVR", "LIN-MC-MLT"] {
            let c = TrainConfig::default().with_options(s).unwrap();
            assert_eq!(c.options_string(), s);
        }
        assert!(TrainConfig::default().with_options("LIN-XX").is_err());
    }

    #[test]
    fn set_fields() {
        let mut c = TrainConfig::default();
        c.set("lambda", "0.25").unwrap();
        c.set("workers", "48").unwrap();
        c.set("backend", "xla").unwrap();
        c.set("reduce", "tree").unwrap();
        assert_eq!(c.lambda, 0.25);
        assert_eq!(c.workers, 48);
        assert_eq!(c.backend, BackendKind::Xla);
        assert_eq!(c.reduce, ReduceKind::Tree);
        assert!(c.set("nope", "1").is_err());
    }

    #[test]
    fn topology_and_warm_start_keys() {
        let mut c = TrainConfig::default();
        c.set("topology", "simulate").unwrap();
        assert_eq!(c.topology, Topology::Simulate);
        // back-compat boolean alias
        c.set("simulate_cluster", "false").unwrap();
        assert_eq!(c.topology, Topology::Threads);
        c.set("warm_start", "true").unwrap();
        assert!(c.warm_start);
        assert!(c.set("topology", "mesh").is_err());
    }

    #[test]
    fn hosts_key_selects_remote_topology() {
        let mut c = TrainConfig::default();
        c.set("hosts", "127.0.0.1:7979, 127.0.0.1:7980").unwrap();
        assert_eq!(
            c.topology,
            Topology::Remote(vec!["127.0.0.1:7979".into(), "127.0.0.1:7980".into()])
        );
        // worker count follows the host list (one daemon per worker)
        assert_eq!(c.workers, 2);
        assert_eq!(c.topology.name(), "Remote");
        assert!(c.set("hosts", "").is_err());
        assert!(c.set("hosts", "no-port").is_err());
        // --topology remote directs users at --hosts
        assert!(c.set("topology", "remote").is_err());
    }

    #[test]
    fn fault_tolerance_keys() {
        let mut c = TrainConfig::default();
        assert_eq!(c.step_timeout_ms, 30_000);
        assert_eq!(c.step_retries, 2);
        c.set("step_timeout_ms", "250").unwrap();
        c.set("step_retries", "5").unwrap();
        assert_eq!(c.step_timeout_ms, 250);
        assert_eq!(c.step_retries, 5);
        assert!(c.set("step_timeout_ms", "fast").is_err());
    }

    #[test]
    fn toml_apply() {
        let doc = TomlDoc::parse(
            "[train]\nlambda = 0.5\nworkers = 8\noptions = \"KRN-MC-CLS\"\n",
        )
        .unwrap();
        let mut c = TrainConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.lambda, 0.5);
        assert_eq!(c.workers, 8);
        assert_eq!(c.model, ModelKind::Kernel);
    }
}
