//! TOML-subset parser: `[section]` headers, `key = value` lines,
//! `#` comments. Values: strings, numbers, bools, flat arrays. Keys are
//! flattened to `section.key`. This covers every config file the repo
//! ships; nested tables / multiline strings are deliberately out of
//! scope.

use anyhow::{bail, Result};

/// A scalar-ish TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Num(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl std::fmt::Display for TomlValue {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TomlValue::Str(s) => write!(f, "{s}"),
            TomlValue::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            TomlValue::Bool(b) => write!(f, "{b}"),
            TomlValue::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
        }
    }
}

/// Parsed document: ordered `(flattened_key, value)` pairs.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    entries: Vec<(String, TomlValue)>,
}

fn parse_value(raw: &str) -> Result<TomlValue> {
    let raw = raw.trim();
    if raw.is_empty() {
        bail!("empty value");
    }
    if let Some(stripped) = raw.strip_prefix('"') {
        let Some(inner) = stripped.strip_suffix('"') else {
            bail!("unterminated string `{raw}`");
        };
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if raw == "true" || raw == "false" {
        return Ok(TomlValue::Bool(raw == "true"));
    }
    if let Some(inner) = raw.strip_prefix('[') {
        let Some(inner) = inner.strip_suffix(']') else {
            bail!("unterminated array `{raw}`");
        };
        let mut out = Vec::new();
        let inner = inner.trim();
        if !inner.is_empty() {
            for part in inner.split(',') {
                out.push(parse_value(part)?);
            }
        }
        return Ok(TomlValue::Arr(out));
    }
    match raw.parse::<f64>() {
        Ok(n) => Ok(TomlValue::Num(n)),
        Err(_) => bail!("cannot parse value `{raw}`"),
    }
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut section = String::new();
        let mut entries = Vec::new();
        for (lineno, raw_line) in text.lines().enumerate() {
            // strip comments outside strings (good enough: we disallow #
            // inside string values in our configs)
            let line = match raw_line.find('#') {
                Some(i) if !raw_line[..i].contains('"') || raw_line[..i].matches('"').count() % 2 == 0 => &raw_line[..i],
                _ => raw_line,
            };
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            if let Some(inner) = line.strip_prefix('[') {
                let Some(name) = inner.strip_suffix(']') else {
                    bail!("line {}: bad section header", lineno + 1);
                };
                section = name.trim().to_string();
                continue;
            }
            let Some((key, val)) = line.split_once('=') else {
                bail!("line {}: expected key = value", lineno + 1);
            };
            let key = key.trim();
            let flat = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            entries.push((
                flat,
                parse_value(val).map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?,
            ));
        }
        Ok(TomlDoc { entries })
    }

    pub fn load(path: &std::path::Path) -> Result<TomlDoc> {
        TomlDoc::parse(&std::fs::read_to_string(path)?)
    }

    pub fn entries(&self) -> impl Iterator<Item = (&str, &TomlValue)> {
        self.entries.iter().map(|(k, v)| (k.as_str(), v))
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.entries.iter().rev().find(|(k, _)| k == key).map(|(_, v)| v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(
            "# comment\ntitle = \"exp\"\n[train]\nlambda = 0.5 # inline\nworkers = 8\nverbose = true\nks = [16, 64]\n",
        )
        .unwrap();
        assert_eq!(doc.get("title"), Some(&TomlValue::Str("exp".into())));
        assert_eq!(doc.get("train.lambda"), Some(&TomlValue::Num(0.5)));
        assert_eq!(doc.get("train.verbose"), Some(&TomlValue::Bool(true)));
        assert_eq!(
            doc.get("train.ks"),
            Some(&TomlValue::Arr(vec![TomlValue::Num(16.0), TomlValue::Num(64.0)]))
        );
    }

    #[test]
    fn display_roundtrips_for_config_use() {
        assert_eq!(TomlValue::Num(8.0).to_string(), "8");
        assert_eq!(TomlValue::Num(0.5).to_string(), "0.5");
        assert_eq!(TomlValue::Str("xla".into()).to_string(), "xla");
    }

    #[test]
    fn errors() {
        assert!(TomlDoc::parse("[oops\n").is_err());
        assert!(TomlDoc::parse("justakey\n").is_err());
        assert!(TomlDoc::parse("a = \"unterminated\n").is_err());
    }
}
