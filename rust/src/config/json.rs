//! Minimal JSON parser — enough for `artifacts/manifest.json` (objects,
//! arrays, strings, numbers, bools, null). No serde offline; ~200 lines
//! beats hand-maintaining a line format the Python side must mirror.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected `{}` at byte {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse()?))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        c => bail!("bad escape {c:?}"),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => bail!("expected , or ] at byte {}", self.i),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => bail!("expected , or }} at byte {}", self.i),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{"chunk": 512, "k_family": [16, 64], "artifacts": [
            {"name": "lin_em_step_k16", "file": "lin_em_step_k16.hlo.txt",
             "k": 16, "num_outputs": 4, "inputs": [{"shape": [512, 16], "dtype": "float32"}]}
        ]}"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("chunk").unwrap().as_usize(), Some(512));
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("lin_em_step_k16"));
        let shape = arts[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize(), Some(512));
    }

    #[test]
    fn strings_escapes_numbers() {
        let j = Json::parse(r#"{"a": "x\n\"yA", "b": -1.5e2, "c": [true, false, null]}"#)
            .unwrap();
        assert_eq!(j.get("a").unwrap().as_str(), Some("x\n\"yA"));
        assert_eq!(j.get("b").unwrap().as_f64(), Some(-150.0));
        assert_eq!(j.get("c").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} x").is_err());
    }
}
