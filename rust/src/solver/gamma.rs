//! The latent-scale update: EM's argmax (Eq. 9) or the Gibbs draw of
//! `gamma_d^{-1} ~ IG(|margin|^{-1}, 1)` (Eq. 5), both with the paper's
//! §5.7.3 clamp.

use crate::rng::{sample_inv_gauss, NormalSource, Pcg64};

/// EM point-update vs MC draw. MC carries the worker's RNG state.
pub enum GammaMode<'a> {
    Em,
    Mc { rng: &'a mut Pcg64, normals: &'a mut NormalSource },
}

impl GammaMode<'_> {
    /// Returns `1/gamma_d` given the residual magnitude `|margin|`.
    ///
    /// EM:  1 / max(|margin|, eps)
    /// MC:  draw IG(1/max(|margin|, eps), 1), then clamp to <= 1/eps
    ///      (equivalently gamma >= eps)
    #[inline]
    pub fn inv_gamma(&mut self, abs_margin: f32, eps: f32) -> f32 {
        let mu = 1.0 / abs_margin.max(eps) as f64;
        match self {
            GammaMode::Em => mu as f32,
            GammaMode::Mc { rng, normals } => {
                let u = rng.next_f64();
                let z = normals.next(rng);
                sample_inv_gauss(mu, u, z).min(1.0 / eps as f64) as f32
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn em_is_reciprocal_clamped() {
        let mut m = GammaMode::Em;
        assert_eq!(m.inv_gamma(0.5, 1e-5), 2.0);
        assert_eq!(m.inv_gamma(0.0, 1e-5), 1e5);
        assert_eq!(m.inv_gamma(1e-9, 1e-5), 1e5);
    }

    #[test]
    fn mc_is_clamped_and_unbiasedish() {
        let mut rng = Pcg64::new(3);
        let mut ns = NormalSource::new();
        let n = 100_000;
        let mut sum = 0f64;
        for _ in 0..n {
            let mut m = GammaMode::Mc { rng: &mut rng, normals: &mut ns };
            let v = m.inv_gamma(0.5, 1e-5);
            assert!(v > 0.0 && v <= 1e5);
            sum += v as f64;
        }
        // IG(mean=2) clamp rarely binds; sample mean ~ 2
        let mean = sum / n as f64;
        assert!((mean - 2.0).abs() < 0.05, "mean {mean}");
    }
}
