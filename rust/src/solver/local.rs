//! Native worker steps: gamma update + local statistics over a shard
//! range (the paper's MPI per-process computation, §4.1). These mirror
//! the L2 jax graphs in `python/compile/model.py` — the cross-backend
//! integration tests assert they produce the same statistics.
//!
//! All three steps take a [`StepWorkspace`] so the per-iteration scratch
//! (per-row weights, densify buffer, class scores) is allocated once per
//! worker instead of once per call — the engine loop calls a step every
//! iteration (MLT: every class of every iteration), so the old `vec!`s
//! were resized-and-freed thousands of times per training run.

use std::ops::Range;

use crate::data::Dataset;
use crate::linalg::{rank_update_dense, rank_update_sparse};
use crate::model::hinge;

use super::gamma::GammaMode;
use super::PartialStats;

/// Reusable scratch for the worker steps, owned by the worker that
/// drives them (one per shard). Buffers grow to the largest shape seen
/// and are never shrunk; a fresh (or [`Default`]) workspace is always
/// valid for any step.
///
/// It also carries the MLT score cache: the `[rows, m]` block of class
/// scores `w_c . x_d` computed on the `yidx == 0` call of an outer
/// iteration and patched incrementally on the following per-class
/// calls — see [`mlt_step`] for the reuse contract.
#[derive(Debug, Default)]
pub struct StepWorkspace {
    /// per-row rank-update weights a_d for the dense fast path
    aw: Vec<f32>,
    /// per-row mu weights b_d for the dense fast path
    bw: Vec<f32>,
    /// densify buffer for sparse rows (k floats)
    buf: Vec<f32>,
    /// MLT class-score cache, row-major `[cache_rows, cache_m]`
    score_cache: Vec<f32>,
    cache_start: usize,
    cache_rows: usize,
    cache_m: usize,
    /// the `yidx` the cache is primed for (Gauss-Seidel order)
    next_class: usize,
    cache_valid: bool,
}

impl StepWorkspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Drop the MLT score cache. Callers that mutate class weights in
    /// any pattern other than the engine's Gauss-Seidel sweep must call
    /// this before the next [`mlt_step`] (a full recompute also happens
    /// automatically on every `yidx == 0` call, so drivers that restart
    /// each outer iteration at class 0 never need to).
    pub fn invalidate_scores(&mut self) {
        self.cache_valid = false;
    }

    fn ensure(&mut self, nn: usize, k: usize) {
        if self.aw.len() < nn {
            self.aw.resize(nn, 0.0);
            self.bw.resize(nn, 0.0);
        }
        if self.buf.len() < k {
            self.buf.resize(k, 0.0);
        }
    }
}

/// Accumulate one datum into the partials (dispatching on sparsity).
#[inline]
fn accumulate(ds: &Dataset, d: usize, a_d: f32, b_d: f32, out: &mut PartialStats, buf: &mut [f32]) {
    if let Some((idx, val)) = ds.sparse_row(d) {
        rank_update_sparse(&mut out.sigma, idx, val, a_d);
        if b_d != 0.0 {
            for (p, &i) in idx.iter().enumerate() {
                out.mu[i as usize] += b_d * val[p];
            }
        }
    } else {
        ds.densify_row(d, buf);
        rank_update_dense(&mut out.sigma, buf, 1, ds.k, &[a_d]);
        if b_d != 0.0 {
            crate::linalg::axpy(b_d, buf, &mut out.mu);
        }
    }
}

/// Dense fast path shared by the three steps: given per-row weights
/// (a_d, b_d) already computed for `range`, do the Sigma^p rank update
/// in one blocked call (the dispatched SYRK micro-kernel;
/// EXPERIMENTS.md §Perf) and the mu^p accumulation as a second
/// streaming pass.
fn accumulate_dense_block(
    data: &[f32],
    k: usize,
    range: &Range<usize>,
    aw: &[f32],
    bw: &[f32],
    out: &mut PartialStats,
) {
    let rows = &data[range.start * k..range.end * k];
    rank_update_dense(&mut out.sigma, rows, range.len(), k, aw);
    for (r, &b_d) in bw.iter().enumerate() {
        if b_d != 0.0 {
            crate::linalg::axpy(b_d, &rows[r * k..(r + 1) * k], &mut out.mu);
        }
    }
}

/// Binary-classification step (Eqs. 5/9 + 40) over `range`.
///
/// `out` must be zeroed (`reset`) by the caller; `obj` gets the hinge
/// sum and `aux` the training-error count at the current `w`.
pub fn lin_step(
    ds: &Dataset,
    range: Range<usize>,
    w: &[f32],
    eps: f32,
    mode: &mut GammaMode,
    ws: &mut StepWorkspace,
    out: &mut PartialStats,
) {
    let nn = range.len();
    ws.ensure(nn, ds.k);
    if let crate::data::Features::Dense { data } = &ds.features {
        // dense fast path: weights first, then one blocked rank update
        let k = ds.k;
        for (r, d) in range.clone().enumerate() {
            let y = ds.labels[d];
            let score = crate::linalg::dot(&data[d * k..(d + 1) * k], w);
            let margin = 1.0 - y * score;
            out.obj += hinge(y * score) as f64;
            out.aux += f64::from(y * score <= 0.0);
            let inv_g = mode.inv_gamma(margin.abs(), eps);
            ws.aw[r] = inv_g;
            ws.bw[r] = y * (1.0 + inv_g);
        }
        accumulate_dense_block(data, k, &range, &ws.aw[..nn], &ws.bw[..nn], out);
        return;
    }
    for d in range {
        let y = ds.labels[d];
        let score = ds.dot_row(d, w);
        let margin = 1.0 - y * score;
        out.obj += hinge(y * score) as f64;
        out.aux += f64::from(y * score <= 0.0);
        let inv_g = mode.inv_gamma(margin.abs(), eps);
        let a_d = inv_g;
        let b_d = y * (1.0 + inv_g);
        accumulate(ds, d, a_d, b_d, out, &mut ws.buf);
    }
}

/// SVR step (Lemma 3 + Eqs. 25-28). `obj` gets the eps-insensitive loss
/// sum, `aux` the squared-residual sum (for RMSE reporting).
#[allow(clippy::too_many_arguments)]
pub fn svr_step(
    ds: &Dataset,
    range: Range<usize>,
    w: &[f32],
    eps: f32,
    eps_ins: f32,
    mode: &mut GammaMode,
    ws: &mut StepWorkspace,
    out: &mut PartialStats,
) {
    let nn = range.len();
    ws.ensure(nn, ds.k);
    if let crate::data::Features::Dense { data } = &ds.features {
        let k = ds.k;
        for (ri, d) in range.clone().enumerate() {
            let y = ds.labels[d];
            let r = y - crate::linalg::dot(&data[d * k..(d + 1) * k], w);
            out.obj += crate::model::eps_insensitive(r, eps_ins) as f64;
            out.aux += (r * r) as f64;
            let inv_g = mode.inv_gamma((r - eps_ins).abs(), eps);
            let inv_o = mode.inv_gamma((r + eps_ins).abs(), eps);
            ws.aw[ri] = inv_g + inv_o;
            ws.bw[ri] = (y - eps_ins) * inv_g + (y + eps_ins) * inv_o;
        }
        accumulate_dense_block(data, k, &range, &ws.aw[..nn], &ws.bw[..nn], out);
        return;
    }
    for d in range {
        let y = ds.labels[d];
        let r = y - ds.dot_row(d, w);
        out.obj += crate::model::eps_insensitive(r, eps_ins) as f64;
        out.aux += (r * r) as f64;
        let inv_g = mode.inv_gamma((r - eps_ins).abs(), eps);
        let inv_o = mode.inv_gamma((r + eps_ins).abs(), eps);
        let a_d = inv_g + inv_o;
        let b_d = (y - eps_ins) * inv_g + (y + eps_ins) * inv_o;
        accumulate(ds, d, a_d, b_d, out, &mut ws.buf);
    }
}

/// Crammer-Singer per-class step (§3.3, Eqs. 36-39) for target class
/// `yidx` given all current class weights `w_all` ([m, k] row-major).
///
/// `obj` gets the CS loss sum and `aux` the error count — only
/// meaningful once per datum, so the driver reads them from the
/// `yidx == 0` call.
///
/// ## Score-cache contract
///
/// The class scores `w_c . x_d` are computed for all m classes on the
/// `yidx == 0` call and cached in `ws`. A follow-up call with
/// `yidx == previous + 1` over the same `range` assumes the engine's
/// Gauss-Seidel sweep (`engine::driver::CsBlockDriver`): between the
/// two calls only class row `yidx - 1` of `w_all` changed, so only that
/// score column is recomputed — cutting score work per outer iteration
/// from O(m^2 k n) to O(m k n). The recomputation runs in the same
/// f32 order as [`class_scores`](crate::model::class_scores), so cached
/// and fresh scores are bit-identical. Any other call pattern (range
/// change, class-count change, out-of-order `yidx`) falls back to a
/// full recompute; callers that mutate *other* rows of `w_all` between
/// in-order calls must invoke
/// [`invalidate_scores`](StepWorkspace::invalidate_scores).
#[allow(clippy::too_many_arguments)]
pub fn mlt_step(
    ds: &Dataset,
    range: Range<usize>,
    w_all: &crate::linalg::Mat,
    yidx: usize,
    eps: f32,
    mode: &mut GammaMode,
    ws: &mut StepWorkspace,
    out: &mut PartialStats,
) {
    let m = w_all.rows;
    let nn = range.len();
    ws.ensure(nn, ds.k);
    if ws.score_cache.len() < nn * m {
        ws.score_cache.resize(nn * m, 0.0);
    }
    let reuse = yidx != 0
        && ws.cache_valid
        && ws.cache_start == range.start
        && ws.cache_rows == nn
        && ws.cache_m == m
        && ws.next_class == yidx;
    if reuse {
        // Gauss-Seidel: only class row yidx-1 changed since last call;
        // refresh that one column in class_scores' accumulation order.
        let c = yidx - 1;
        for (r, d) in range.clone().enumerate() {
            let mut s = 0f32;
            ds.for_nonzero(d, |j, v| {
                s += v * w_all[(c, j as usize)];
            });
            ws.score_cache[r * m + c] = s;
        }
    } else {
        for (r, d) in range.clone().enumerate() {
            crate::model::class_scores(ds, d, w_all, &mut ws.score_cache[r * m..(r + 1) * m]);
        }
    }
    ws.cache_start = range.start;
    ws.cache_rows = nn;
    ws.cache_m = m;
    ws.next_class = if m > 0 { (yidx + 1) % m } else { 0 };
    ws.cache_valid = true;

    let dense_data = match &ds.features {
        crate::data::Features::Dense { data } => Some(data),
        _ => None,
    };
    for (r, d) in range.clone().enumerate() {
        let yd = ds.labels[d] as usize;
        let scores = &ws.score_cache[r * m..(r + 1) * m];

        // zeta_d(yidx) = max_{y' != yidx} (score[y'] + Delta_d(y'))
        let mut zeta = f32::NEG_INFINITY;
        let mut best_aug = f32::NEG_INFINITY;
        let mut argmax = 0usize;
        let mut best_score = f32::NEG_INFINITY;
        for (c, &s) in scores.iter().enumerate() {
            let aug = s + if c == yd { 0.0 } else { 1.0 };
            if aug > best_aug {
                best_aug = aug;
            }
            if s > best_score {
                best_score = s;
                argmax = c;
            }
            if c != yidx && aug > zeta {
                zeta = aug;
            }
        }
        if yidx == 0 {
            out.obj += (best_aug - scores[yd]).max(0.0) as f64;
            out.aux += f64::from(argmax != yd);
        }

        let delta_y = if yidx == yd { 0.0 } else { 1.0 };
        let rho = zeta - delta_y;
        let beta = if yidx == yd { 1.0 } else { -1.0 };
        let margin = rho - scores[yidx];
        let inv_g = mode.inv_gamma(margin.abs(), eps);
        let a_d = inv_g;
        let b_d = rho * inv_g + beta;
        if dense_data.is_some() {
            ws.aw[r] = a_d;
            ws.bw[r] = b_d;
        } else {
            accumulate(ds, d, a_d, b_d, out, &mut ws.buf);
        }
    }
    if let Some(data) = dense_data {
        accumulate_dense_block(data, ds.k, &range, &ws.aw[..nn], &ws.bw[..nn], out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::linalg::Mat;

    /// Dense vs sparse representations of the same data produce the same
    /// statistics, entry by entry. The accumulation orders differ
    /// (blocked SYRK vs per-datum sparse rank-1), so the bound is
    /// relative, scaled per entry by sqrt(sigma_ii sigma_jj) — a valid
    /// magnitude bound because every a_d >= 0 makes sigma PSD
    /// (Cauchy-Schwarz on the weighted feature vectors).
    #[test]
    fn sparse_dense_agree() {
        let ds = synth::dna_like(200, 50, 1);
        let dd = ds.to_dense();
        let w: Vec<f32> = (0..50).map(|j| 0.01 * j as f32).collect();
        let mut a = PartialStats::zeros(50);
        let mut b = PartialStats::zeros(50);
        let mut wsa = StepWorkspace::new();
        let mut wsb = StepWorkspace::new();
        lin_step(&ds, 0..200, &w, 1e-5, &mut GammaMode::Em, &mut wsa, &mut a);
        lin_step(&dd, 0..200, &w, 1e-5, &mut GammaMode::Em, &mut wsb, &mut b);
        for i in 0..50 {
            for j in 0..=i {
                let scale = (b.sigma[(i, i)] * b.sigma[(j, j)]).sqrt().max(1e-6);
                let diff = (a.sigma[(i, j)] - b.sigma[(i, j)]).abs();
                assert!(
                    diff <= 1e-4 * scale,
                    "sigma[{i},{j}]: |{} - {}| = {diff} > 1e-4 * {scale}",
                    a.sigma[(i, j)],
                    b.sigma[(i, j)]
                );
            }
        }
        let mu_scale = b.mu.iter().fold(1f32, |s, &v| s.max(v.abs()));
        for (j, (x, y)) in a.mu.iter().zip(&b.mu).enumerate() {
            assert!((x - y).abs() <= 1e-4 * mu_scale, "mu[{j}]: {x} vs {y}");
        }
        assert!((a.obj - b.obj).abs() < 1e-4 * a.obj.abs().max(1.0));
        assert_eq!(a.aux, b.aux);
    }

    /// Two half-range steps merged == one full-range step (the reduce
    /// operator really is the sum the paper claims).
    #[test]
    fn split_merge_equals_whole() {
        let ds = synth::alpha_like(300, 12, 2);
        let w = vec![0.05f32; 12];
        let mut ws = StepWorkspace::new();
        let mut whole = PartialStats::zeros(12);
        lin_step(&ds, 0..300, &w, 1e-5, &mut GammaMode::Em, &mut ws, &mut whole);
        let mut h1 = PartialStats::zeros(12);
        let mut h2 = PartialStats::zeros(12);
        lin_step(&ds, 0..150, &w, 1e-5, &mut GammaMode::Em, &mut ws, &mut h1);
        lin_step(&ds, 150..300, &w, 1e-5, &mut GammaMode::Em, &mut ws, &mut h2);
        h1.merge(&h2);
        assert!(whole.sigma.max_abs_diff(&h1.sigma) < 1e-1);
        assert!((whole.obj - h1.obj).abs() < 1e-6);
    }

    /// SVR statistics hand-checked on a single datum.
    #[test]
    fn svr_single_datum() {
        let ds = crate::data::Dataset::dense(
            vec![2.0, 0.0],
            vec![1.0],
            2,
            crate::data::Task::Regression,
        );
        let w = vec![0.0f32, 0.0];
        let (eps, eps_ins) = (1e-5f32, 0.25f32);
        let mut out = PartialStats::zeros(2);
        let mut ws = StepWorkspace::new();
        svr_step(&ds, 0..1, &w, eps, eps_ins, &mut GammaMode::Em, &mut ws, &mut out);
        // r = 1; gamma = |1 - .25| = .75, omega = |1 + .25| = 1.25
        let (ig, io) = (1.0 / 0.75, 1.0 / 1.25);
        let a_d = ig + io;
        let b_d = 0.75 * ig + 1.25 * io;
        assert!((out.sigma[(0, 0)] - 4.0 * a_d).abs() < 1e-5);
        assert!((out.mu[0] - 2.0 * b_d).abs() < 1e-5);
        assert!((out.obj - 0.75).abs() < 1e-6);
    }

    /// MLT: for m = 2 the CS update must reduce to the binary hinge
    /// geometry (rho = score of other class +/- 1).
    #[test]
    fn mlt_two_class_consistency() {
        let ds = crate::data::Dataset::dense(
            vec![1.0, 0.5],
            vec![0.0],
            2,
            crate::data::Task::Multiclass(2),
        );
        let mut w = Mat::zeros(2, 2);
        w[(0, 0)] = 0.3;
        w[(1, 1)] = -0.2;
        let mut out = PartialStats::zeros(2);
        let mut ws = StepWorkspace::new();
        mlt_step(&ds, 0..1, &w, 0, 1e-5, &mut GammaMode::Em, &mut ws, &mut out);
        // scores: s0 = .3, s1 = -.1; yd = 0, yidx = 0:
        // zeta = s1 + 1 = 0.9; rho = 0.9 - 0 = 0.9; beta = +1
        // margin = 0.9 - 0.3 = 0.6 => inv_g = 1/0.6
        let inv_g = 1.0f32 / 0.6;
        let b_d = 0.9 * inv_g + 1.0;
        assert!((out.mu[0] - b_d).abs() < 1e-4);
        // obj: best_aug = max(.3, .9) = .9 minus s_yd (.3) = .6
        assert!((out.obj - 0.6).abs() < 1e-6);
    }

    /// The MLT score cache must be invisible: a Gauss-Seidel sweep with
    /// one reused workspace gives bit-identical statistics to fresh
    /// workspaces per call (full recompute every time).
    #[test]
    fn mlt_score_cache_is_bit_exact() {
        let m = 3;
        let ds = synth::mnist_like(90, 7, m, 13);
        let mut w = Mat::zeros(m, 7);
        let mut g = crate::rng::Pcg64::new(4);
        for x in w.data.iter_mut() {
            *x = g.next_f32() - 0.5;
        }
        let mut ws_cached = StepWorkspace::new();
        for y in 0..m {
            let mut cached = PartialStats::zeros(7);
            let mut fresh = PartialStats::zeros(7);
            mlt_step(&ds, 0..90, &w, y, 1e-5, &mut GammaMode::Em, &mut ws_cached, &mut cached);
            let mut ws_fresh = StepWorkspace::new();
            mlt_step(&ds, 0..90, &w, y, 1e-5, &mut GammaMode::Em, &mut ws_fresh, &mut fresh);
            assert_eq!(cached.sigma.data, fresh.sigma.data, "class {y}");
            assert_eq!(cached.mu, fresh.mu, "class {y}");
            assert_eq!(cached.obj, fresh.obj, "class {y}");
            // Gauss-Seidel: the driver rewrites row y after the class-y
            // solve; mimic that so the column refresh path is exercised.
            let wy: Vec<f32> = w.row(y).iter().map(|v| v * 0.9 + 0.01).collect();
            w.row_mut(y).copy_from_slice(&wy);
        }
    }

    /// EM objective decreases over full iterations (uses master::solve).
    #[test]
    fn em_iteration_decreases_objective() {
        let ds = synth::alpha_like(400, 6, 5);
        let lambda = 1.0f32;
        let mut w = vec![0f32; 6];
        let mut ws = StepWorkspace::new();
        let mut prev = f64::INFINITY;
        for _ in 0..10 {
            let mut st = PartialStats::zeros(6);
            lin_step(&ds, 0..ds.n, &w, 1e-5, &mut GammaMode::Em, &mut ws, &mut st);
            let j = 0.5 * lambda as f64 * crate::linalg::norm2_sq(&w) as f64 + 2.0 * st.obj;
            assert!(j <= prev + 1e-3 * ds.n as f64, "{j} > {prev}");
            prev = j;
            w = crate::solver::master::solve_native(
                &mut st,
                &crate::solver::master::Regularizer::Eye(lambda),
                None,
            )
            .unwrap();
        }
        assert!(crate::model::accuracy_cls(&ds, &w) > 0.85);
    }
}
