//! Low-rank kernel SVM — the paper's own §4.3 suggestion, implemented:
//!
//! > "PSVM approximates the N by N kernel matrix with an N by sqrt(N)
//! >  matrix, and gets very good accuracy. Maybe there is a way to do
//! >  something similar with the sampling kernel SVM formulation?"
//!
//! There is. With a pivoted incomplete Cholesky `K ~= H H^T`
//! (H: [N, r]), substitute `v = H^T omega` in problem (15):
//!
//!   lam/2 omega^T K omega + 2 sum hinge(y_d omega.K_d)
//!     ~=  lam/2 ||v||^2  + 2 sum hinge(y_d v.H_d)
//!
//! — *exactly* the linear problem (1) over the r-dimensional ICF
//! features H, so the whole parallel LIN machinery (EM and MC, any
//! backend, any P) applies unchanged. Iteration cost drops from O(N^3/P)
//! to O(N r^2 / P) with r = sqrt(N) reproducing PSVM's budget, and the
//! learned model predicts via k(x, pivots) projections.

use anyhow::Result;

use crate::config::{KernelCfg, TrainConfig};
use crate::data::{Dataset, Task};

/// Kernel-space ICF: pivoted incomplete Cholesky of the *kernel* Gram
/// matrix (generalizes `baselines::psvm_lite::icf`, which is
/// linear-kernel only). Returns (H [n, r_eff], pivot rows).
pub fn kernel_icf(ds: &Dataset, cfg: &KernelCfg, r: usize) -> (Vec<f32>, Vec<usize>) {
    let n = ds.n;
    let r = r.clamp(1, n);
    let mut h = vec![0f32; n * r];
    let (mut bi, mut bj) = (vec![0f32; ds.k], vec![0f32; ds.k]);
    let mut diag: Vec<f32> = (0..n)
        .map(|d| super::kernel::kval(ds, d, ds, d, cfg, &mut bi, &mut bj))
        .collect();
    let mut used = vec![false; n];
    let mut pivots = Vec::with_capacity(r);
    for col in 0..r {
        let Some((piv, &dmax)) = diag
            .iter()
            .enumerate()
            .filter(|(i, _)| !used[*i])
            .max_by(|a, b| a.1.total_cmp(b.1))
        else {
            break;
        };
        if dmax <= 1e-9 {
            break;
        }
        used[piv] = true;
        pivots.push(piv);
        let droot = dmax.sqrt();
        h[piv * r + col] = droot;
        for i in 0..n {
            if used[i] || diag[i] <= 0.0 {
                continue;
            }
            let kip = super::kernel::kval(ds, i, ds, piv, cfg, &mut bi, &mut bj);
            let mut proj = 0f32;
            for c in 0..col {
                proj += h[i * r + c] * h[piv * r + c];
            }
            let v = (kip - proj) / droot;
            h[i * r + col] = v;
            diag[i] -= v * v;
        }
    }
    (h, pivots)
}

/// A trained low-rank kernel model: predicts by projecting a test point
/// onto the pivot columns: h(x)_c = (k(x, piv_c) - proj) / L_cc, then
/// score = v . h(x). Equivalent to the Nystrom feature map.
pub struct LowRankKernelModel {
    pub train_pivots: Dataset,
    /// r x r lower-triangular factor restricted to pivot rows
    pub l_piv: Vec<f32>,
    pub v: Vec<f32>,
    pub cfg: KernelCfg,
    pub rank: usize,
}

impl LowRankKernelModel {
    pub fn decision(&self, test: &Dataset, j: usize) -> f32 {
        let r = self.rank;
        let (mut bi, mut bj) = (vec![0f32; self.train_pivots.k], vec![0f32; self.train_pivots.k]);
        // forward-substitute h(x): L_piv h = k(x, pivots)
        let mut hx = vec![0f32; r];
        for c in 0..r {
            let kxc = super::kernel::kval(&self.train_pivots, c, test, j, &self.cfg, &mut bi, &mut bj);
            let mut s = kxc;
            for p in 0..c {
                s -= self.l_piv[c * r + p] * hx[p];
            }
            let d = self.l_piv[c * r + c];
            hx[c] = if d.abs() > 1e-12 { s / d } else { 0.0 };
        }
        crate::linalg::dot(&self.v, &hx)
    }

    pub fn accuracy(&self, test: &Dataset) -> f64 {
        let correct = (0..test.n)
            .filter(|&j| test.labels[j] * self.decision(test, j) > 0.0)
            .count();
        correct as f64 / test.n.max(1) as f64
    }
}

/// Train the low-rank sampling kernel SVM: kernel ICF, then the
/// parallel LIN solver (EM or MC, any backend/worker count from `cfg`)
/// on the ICF features.
pub fn train_lowrank_krn(
    ds: &Dataset,
    cfg: &TrainConfig,
    rank: Option<usize>,
) -> Result<(LowRankKernelModel, crate::coordinator::TrainOutput)> {
    let r = rank.unwrap_or_else(|| (ds.n as f64).sqrt().ceil() as usize).clamp(1, ds.n);
    let (h, pivots) = kernel_icf(ds, &cfg.kernel, r);
    let r_eff = r; // columns beyond the effective rank are zero — harmless
    let feat = Dataset::dense(h, ds.labels.clone(), r_eff, Task::Binary);

    // reuse the LIN coordinator verbatim (the paper's point)
    let mut lin_cfg = cfg.clone();
    lin_cfg.model = crate::config::ModelKind::Linear;
    let out = crate::coordinator::train(&feat, &lin_cfg)?;
    let v = out.weights.single().to_vec();

    // pivot-restricted factor for prediction
    let mut l_piv = vec![0f32; r_eff * r_eff];
    let mut piv_rows = Vec::new();
    for (c, &p) in pivots.iter().enumerate() {
        if let crate::data::Features::Dense { data } = &feat.features {
            l_piv[c * r_eff..c * r_eff + r_eff]
                .copy_from_slice(&data[p * r_eff..(p + 1) * r_eff]);
        }
        piv_rows.push(p);
    }
    // pivot dataset (rows of the original data at pivot positions)
    let mut pdata = vec![0f32; piv_rows.len() * ds.k];
    let mut buf = vec![0f32; ds.k];
    for (c, &p) in piv_rows.iter().enumerate() {
        ds.densify_row(p, &mut buf);
        pdata[c * ds.k..(c + 1) * ds.k].copy_from_slice(&buf);
    }
    let train_pivots = Dataset::dense(pdata, vec![0.0; piv_rows.len()], ds.k, Task::Binary);
    Ok((
        LowRankKernelModel { train_pivots, l_piv, v, cfg: cfg.kernel, rank: r_eff },
        out,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;

    fn rings(n: usize, seed: u64) -> Dataset {
        let mut g = crate::rng::Pcg64::new(seed);
        let mut data = Vec::with_capacity(n * 2);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            let y: f32 = if g.next_f64() < 0.5 { 1.0 } else { -1.0 };
            let r = if y > 0.0 { 0.5 } else { 1.6 };
            let th = g.next_f64() * std::f64::consts::TAU;
            data.push(r * th.cos() as f32 + 0.05 * (g.next_f32() - 0.5));
            data.push(r * th.sin() as f32 + 0.05 * (g.next_f32() - 0.5));
            labels.push(y);
        }
        Dataset::dense(data, labels, 2, Task::Binary)
    }

    #[test]
    fn kernel_icf_approximates_gram() {
        let ds = rings(60, 1);
        let cfg = KernelCfg::Gaussian { sigma: 0.8 };
        let (h, _) = kernel_icf(&ds, &cfg, 40);
        let gram = crate::solver::gram_matrix(&ds, &cfg);
        let mut worst = 0f32;
        for i in 0..60 {
            for j in 0..60 {
                let approx = crate::linalg::dot(&h[i * 40..(i + 1) * 40], &h[j * 40..(j + 1) * 40]);
                worst = worst.max((gram[(i, j)] - approx).abs());
            }
        }
        assert!(worst < 0.05, "ICF error {worst}");
    }

    #[test]
    fn lowrank_krn_solves_rings() {
        let train = rings(300, 2);
        let test = rings(120, 3);
        let mut cfg = TrainConfig::default().with_options("KRN-EM-CLS").unwrap();
        cfg.lambda = 1e-2;
        cfg.kernel = KernelCfg::Gaussian { sigma: 0.5 };
        cfg.workers = 2;
        cfg.max_iters = 30;
        let (model, out) = train_lowrank_krn(&train, &cfg, Some(40)).unwrap();
        assert!(out.iterations > 0);
        let acc = model.accuracy(&test);
        assert!(acc > 0.95, "low-rank kernel accuracy {acc}");
    }

    #[test]
    fn lowrank_close_to_exact_krn() {
        let train = rings(240, 4);
        let mut cfg = TrainConfig::default().with_options("KRN-EM-CLS").unwrap();
        cfg.lambda = 1e-2;
        cfg.kernel = KernelCfg::Gaussian { sigma: 0.5 };
        cfg.workers = 2;
        cfg.max_iters = 25;
        let exact = crate::coordinator::train(&train, &cfg).unwrap();
        let acc_exact = exact.kernel_model.as_ref().unwrap().accuracy(&train);
        let (model, _) = train_lowrank_krn(&train, &cfg, Some(60)).unwrap();
        let acc_lr = model.accuracy(&train);
        assert!(
            acc_lr >= acc_exact - 0.03,
            "low-rank {acc_lr} vs exact {acc_exact}"
        );
    }
}
