//! Kernel-SVM support (§3.1): Gram matrix construction and the
//! KernelModel wrapper that interprets the learned dual vector omega.
//!
//! The KRN solver is the LIN solver run on "features" = rows of K, with
//! the Gram matrix as the quadratic regularizer — exactly the
//! similarity between problems (15) and (1) the paper exploits.

use crate::config::KernelCfg;
use crate::data::Dataset;
use crate::linalg::Mat;

/// k(x_i, x_j) for the rows i of `a` and j of `b`. The scratch buffers
/// must satisfy `bi.len() >= a.k` and `bj.len() >= b.k`; mismatched
/// widths zero-pad the shorter side (when `a.k == b.k` the computation
/// and summation order are unchanged).
pub(crate) fn kval(a: &Dataset, i: usize, b: &Dataset, j: usize, cfg: &KernelCfg, bi: &mut [f32], bj: &mut [f32]) -> f32 {
    match cfg {
        KernelCfg::LinearK => {
            a.densify_row(i, &mut bi[..a.k]);
            if b.k <= a.k {
                b.dot_row(j, &bi[..a.k])
            } else {
                // features beyond a's width carry zero weight
                let mut s = 0f32;
                b.for_nonzero(j, |t, v| {
                    if (t as usize) < a.k {
                        s += v * bi[t as usize];
                    }
                });
                s
            }
        }
        KernelCfg::Gaussian { sigma } => {
            a.densify_row(i, &mut bi[..a.k]);
            b.densify_row(j, &mut bj[..b.k]);
            let k0 = a.k.min(b.k);
            let mut d2 = 0f32;
            for (x, z) in bi[..k0].iter().zip(&bj[..k0]) {
                let d = x - z;
                d2 += d * d;
            }
            for &x in &bi[k0..a.k] {
                d2 += x * x;
            }
            for &z in &bj[k0..b.k] {
                d2 += z * z;
            }
            (-d2 / (2.0 * sigma * sigma)).exp()
        }
    }
}

/// Dense N x N Gram matrix (the paper accepts the O(N^2) memory /
/// O(N^3) iteration cost for KRN and keeps N small, §4.3).
pub fn gram_matrix(ds: &Dataset, cfg: &KernelCfg) -> Mat {
    let n = ds.n;
    let mut g = Mat::zeros(n, n);
    let (mut bi, mut bj) = (vec![0f32; ds.k], vec![0f32; ds.k]);
    for i in 0..n {
        for j in 0..=i {
            let v = kval(ds, i, ds, j, cfg, &mut bi, &mut bj);
            g[(i, j)] = v;
            g[(j, i)] = v;
        }
    }
    g
}

/// The "kernelized dataset": row d of the Gram matrix becomes the
/// feature vector of datum d (problem 15's K_d), so the LIN machinery
/// applies unchanged.
pub fn gram_dataset(ds: &Dataset, cfg: &KernelCfg) -> (Dataset, Mat) {
    let gram = gram_matrix(ds, cfg);
    let data = gram.data.clone();
    (
        Dataset::dense(data, ds.labels.clone(), ds.n, ds.task),
        gram,
    )
}

/// A trained kernel SVM: support data + dual coefficients omega.
#[derive(Clone, Debug)]
pub struct KernelModel {
    pub train: Dataset,
    pub omega: Vec<f32>,
    pub cfg: KernelCfg,
}

impl KernelModel {
    /// Scratch buffers for [`decision_with`](Self::decision_with),
    /// sized for this model against `test_k`-wide rows.
    pub fn scratch(&self, test_k: usize) -> (Vec<f32>, Vec<f32>) {
        (vec![0f32; self.train.k], vec![0f32; self.train.k.max(test_k)])
    }

    /// [`decision`](Self::decision) with caller-owned scratch buffers
    /// (from [`scratch`](Self::scratch)) — the batched scorer calls
    /// this per row without reallocating. The f32 summation order is
    /// identical to `decision`, so the two agree bit-for-bit.
    pub fn decision_with(&self, test: &Dataset, j: usize, bi: &mut [f32], bj: &mut [f32]) -> f32 {
        let mut s = 0f32;
        for d in 0..self.train.n {
            if self.omega[d] != 0.0 {
                s += self.omega[d] * kval(&self.train, d, test, j, &self.cfg, bi, bj);
            }
        }
        s
    }

    /// f(x_j of `test`) = sum_d omega_d k(x_d, x_j)
    pub fn decision(&self, test: &Dataset, j: usize) -> f32 {
        let (mut bi, mut bj) = self.scratch(test.k);
        self.decision_with(test, j, &mut bi, &mut bj)
    }

    pub fn accuracy(&self, test: &Dataset) -> f64 {
        let correct = (0..test.n)
            .filter(|&j| test.labels[j] * self.decision(test, j) > 0.0)
            .count();
        correct as f64 / test.n.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth, Task};

    #[test]
    fn gram_is_symmetric_unit_diag_gaussian() {
        let ds = synth::news20_like(50, 30, 1);
        let g = gram_matrix(&ds, &KernelCfg::Gaussian { sigma: 1.0 });
        for i in 0..50 {
            assert!((g[(i, i)] - 1.0).abs() < 1e-6);
            for j in 0..i {
                assert_eq!(g[(i, j)], g[(j, i)]);
                assert!(g[(i, j)] >= 0.0 && g[(i, j)] <= 1.0);
            }
        }
    }

    #[test]
    fn linear_kernel_matches_dots() {
        let ds = crate::data::Dataset::dense(
            vec![1.0, 0.0, 0.0, 2.0, 1.0, 1.0],
            vec![1.0, -1.0, 1.0],
            2,
            Task::Binary,
        );
        let g = gram_matrix(&ds, &KernelCfg::LinearK);
        assert_eq!(g[(0, 1)], 0.0);
        assert_eq!(g[(0, 2)], 1.0);
        assert_eq!(g[(1, 2)], 2.0);
        assert_eq!(g[(1, 1)], 4.0);
    }

    #[test]
    fn kernel_model_separates_xor() {
        // XOR is not linearly separable but a Gaussian kernel handles it
        let x = vec![
            0.0, 0.0, //
            1.0, 1.0, //
            0.0, 1.0, //
            1.0, 0.0,
        ];
        let y = vec![1.0, 1.0, -1.0, -1.0];
        let train = Dataset::dense(x, y, 2, Task::Binary);
        let cfg = KernelCfg::Gaussian { sigma: 0.6 };
        let (kds, gram) = gram_dataset(&train, &cfg);
        // one EM pass chain to fit omega
        let mut omega = vec![0f32; 4];
        let mut ws = crate::solver::local::StepWorkspace::new();
        for _ in 0..30 {
            let mut st = crate::solver::PartialStats::zeros(4);
            crate::solver::local::lin_step(
                &kds,
                0..4,
                &omega,
                1e-5,
                &mut crate::solver::GammaMode::Em,
                &mut ws,
                &mut st,
            );
            omega = crate::solver::master::solve_native(
                &mut st,
                &crate::solver::master::Regularizer::Gram { lambda: 1e-3, gram: &gram },
                None,
            )
            .unwrap();
        }
        let model = KernelModel { train, omega, cfg };
        let test = Dataset::dense(
            vec![0.1, 0.1, 0.9, 0.9, 0.1, 0.9, 0.9, 0.1],
            vec![1.0, 1.0, -1.0, -1.0],
            2,
            Task::Binary,
        );
        assert_eq!(model.accuracy(&test), 1.0);
    }
}
