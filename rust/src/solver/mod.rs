//! Solver core: the data-augmentation updates shared by both backends.
//!
//! A training iteration is `worker step -> reduce -> master solve`
//! (paper §4.1); this module owns the numeric pieces, `coordinator/`
//! owns the topology, `backend/` owns where the flops run.

pub mod gamma;
pub mod kernel;
pub mod local;
pub mod lowrank;
pub mod master;

pub use gamma::GammaMode;
pub use kernel::{gram_dataset, gram_matrix, KernelModel};
pub use local::StepWorkspace;
pub use master::{solve_native, Regularizer};

use crate::linalg::SymPacked;

/// A worker's partial statistics for one iteration (Eq. 40):
/// `sigma` holds only the lower triangle, packed (`k(k+1)/2` floats) —
/// that is all a worker ever fills and all the reduce ever ships; the
/// master unpacks it exactly once per solve.
#[derive(Clone, Debug)]
pub struct PartialStats {
    pub sigma: SymPacked,
    pub mu: Vec<f32>,
    /// sum of the per-datum loss at the *current* weights
    pub obj: f64,
    /// task-dependent second statistic: error count (CLS/MLT) or
    /// squared-residual sum (SVR)
    pub aux: f64,
}

impl PartialStats {
    pub fn zeros(k: usize) -> Self {
        PartialStats { sigma: SymPacked::zeros(k), mu: vec![0.0; k], obj: 0.0, aux: 0.0 }
    }

    pub fn reset(&mut self) {
        self.sigma.fill(0.0);
        self.mu.fill(0.0);
        self.obj = 0.0;
        self.aux = 0.0;
    }

    /// Merge another partial into this one (the reduce operator; it is
    /// associative and commutative up to f32 rounding, which the
    /// coordinator tests exercise).
    pub fn merge(&mut self, other: &PartialStats) {
        self.sigma.add_assign(&other.sigma);
        for (a, b) in self.mu.iter_mut().zip(&other.mu) {
            *a += b;
        }
        self.obj += other.obj;
        self.aux += other.aux;
    }

    /// Every entry finite? The fault-tolerant pool validates each
    /// worker reply with this before accepting it — a corrupted partial
    /// (NaN/inf from a faulted worker or a numeric blow-up) is retried
    /// instead of silently poisoning the reduce and every later
    /// iteration.
    pub fn is_finite(&self) -> bool {
        self.obj.is_finite()
            && self.aux.is_finite()
            && self.mu.iter().all(|v| v.is_finite())
            && self.sigma.data.iter().all(|v| v.is_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_is_additive() {
        let mut a = PartialStats::zeros(3);
        a.sigma[(1, 0)] = 2.0;
        a.mu[2] = 1.0;
        a.obj = 0.5;
        let mut b = PartialStats::zeros(3);
        b.sigma[(1, 0)] = 3.0;
        b.mu[2] = -0.5;
        b.aux = 2.0;
        a.merge(&b);
        assert_eq!(a.sigma[(1, 0)], 5.0);
        assert_eq!(a.mu[2], 0.5);
        assert_eq!(a.obj, 0.5);
        assert_eq!(a.aux, 2.0);
        a.reset();
        assert_eq!(a.sigma[(1, 0)], 0.0);
        assert_eq!(a.obj, 0.0);
    }
}
