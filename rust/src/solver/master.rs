//! The master step: `Sigma^{-1} = lam R + sum_p Sigma^p`, then the EM
//! mode takes `w = Sigma (sum_p mu^p)` (Eq. 6) and the MC mode draws
//! `w ~ N(Sigma b, Sigma)` via `w = mu + L^{-T} z`.

use std::sync::{Arc, OnceLock};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::linalg::{cholesky_in_place, solve_lower, solve_upper, Mat};
use crate::telemetry::{self, Counter, Histogram};

use super::PartialStats;

/// Master-step series in the global telemetry registry: solve latency
/// and how often the jitter escalation had to retry the factorization.
struct MasterMetrics {
    solve_nanos: Arc<Histogram>,
    jitter_retries: Arc<Counter>,
    nonfinite_stats: Arc<Counter>,
}

fn master_metrics() -> &'static MasterMetrics {
    static M: OnceLock<MasterMetrics> = OnceLock::new();
    M.get_or_init(|| MasterMetrics {
        solve_nanos: telemetry::global()
            .histogram("master_solve_nanos", "Master solve (Eq. 6) wall-clock in nanoseconds."),
        jitter_retries: telemetry::global().counter(
            "master_jitter_retries_total",
            "Cholesky retries with escalated diagonal jitter.",
        ),
        nonfinite_stats: telemetry::global().counter(
            "master_nonfinite_stats_total",
            "Master solves rejected because the reduced statistics held NaN/inf.",
        ),
    })
}

/// The quadratic regularizer R: identity for LIN (Eq. 6), the Gram
/// matrix for KRN (§3.1).
pub enum Regularizer<'a> {
    Eye(f32),
    Gram { lambda: f32, gram: &'a Mat },
}

/// Solve the master step. The packed `stats.sigma` is unpacked into a
/// full working matrix exactly once here (the only place the full
/// `k x k` form ever materializes); `stats` itself is left intact.
/// `mc_noise` is a pre-drawn N(0, I) vector for the MC posterior
/// sample; None = EM.
pub fn solve_native(
    stats: &mut PartialStats,
    reg: &Regularizer,
    mc_noise: Option<&[f32]>,
) -> Result<Vec<f32>> {
    let t_solve = Instant::now();
    // A NaN anywhere in the reduced statistics would silently survive
    // the Cholesky (NaN comparisons are all-false) and poison every
    // later iteration; reject it here, where the failure is attributable.
    if !stats.is_finite() {
        master_metrics().nonfinite_stats.inc();
        bail!("master solve: reduced statistics contain NaN/inf (corrupt worker reply?)");
    }
    let k = stats.mu.len();
    let mut a = stats.sigma.unpack();
    match reg {
        Regularizer::Eye(lam) => a.add_scaled_eye(*lam),
        Regularizer::Gram { lambda, gram } => a.add_scaled(*lambda, gram),
    }
    // The gamma clamp lets Sigma^-1 reach condition numbers ~1/eps^2; in
    // f32 that can round a (mathematically SPD) matrix indefinite,
    // especially for KRN grams. Retry with escalating diagonal jitter —
    // statistically this only smooths the near-zero-margin directions.
    let mean_diag = (0..k).map(|i| a[(i, i)] as f64).sum::<f64>() / k.max(1) as f64;
    let pristine = a.clone();
    let mut jitter = 0f64;
    loop {
        match cholesky_in_place(&mut a) {
            Ok(()) => break,
            Err(e) => {
                master_metrics().jitter_retries.inc();
                jitter = if jitter == 0.0 { mean_diag * 1e-6 } else { jitter * 100.0 };
                if jitter > mean_diag * 1e-2 {
                    return Err(e).context(
                        "master solve: Sigma^-1 not positive definite (lambda too small?)",
                    );
                }
                a = pristine.clone();
                a.add_scaled_eye(jitter as f32);
            }
        }
    }
    let l = &a;
    let mut y = vec![0f32; k];
    let mut w = vec![0f32; k];
    solve_lower(l, &stats.mu, &mut y);
    solve_upper(l, &y, &mut w);
    if let Some(z) = mc_noise {
        // w += L^{-T} z  adds the N(0, Sigma) fluctuation
        let mut fluct = vec![0f32; k];
        solve_upper(l, z, &mut fluct);
        for (wi, fi) in w.iter_mut().zip(&fluct) {
            *wi += fi;
        }
    }
    master_metrics().solve_nanos.observe_duration(t_solve.elapsed());
    Ok(w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{NormalSource, Pcg64};

    fn stats_from(sigma_lower: Mat, mu: Vec<f32>) -> PartialStats {
        PartialStats {
            sigma: crate::linalg::SymPacked::from_mat_lower(&sigma_lower),
            mu,
            obj: 0.0,
            aux: 0.0,
        }
    }

    #[test]
    fn em_solves_normal_equations() {
        // Sigma^-1 = I + S with S = diag(1, 2); b = [3, 8]
        let mut s = Mat::zeros(2, 2);
        s[(0, 0)] = 1.0;
        s[(1, 1)] = 2.0;
        let mut st = stats_from(s, vec![3.0, 8.0]);
        let w = solve_native(&mut st, &Regularizer::Eye(1.0), None).unwrap();
        assert!((w[0] - 1.5).abs() < 1e-5);
        assert!((w[1] - 8.0 / 3.0).abs() < 1e-5);
    }

    #[test]
    fn gram_regularizer_used() {
        // R = 2 I as a "gram"; lam = 0.5 -> A = I + S
        let mut gram = Mat::eye(2);
        gram[(0, 0)] = 2.0;
        gram[(1, 1)] = 2.0;
        let mut s = Mat::zeros(2, 2);
        s[(0, 0)] = 1.0;
        s[(1, 1)] = 2.0;
        let mut st = stats_from(s, vec![3.0, 8.0]);
        let w = solve_native(&mut st, &Regularizer::Gram { lambda: 0.5, gram: &gram }, None)
            .unwrap();
        assert!((w[0] - 1.5).abs() < 1e-5);
        assert!((w[1] - 8.0 / 3.0).abs() < 1e-5);
    }

    #[test]
    fn mc_sample_has_posterior_moments() {
        let k = 3;
        let mut rng = Pcg64::new(2);
        let mut ns = NormalSource::new();
        // A = diag(4, 1, 0.25) + lam(=0) handled via Eye(0) forbidden ->
        // use lam = tiny and fold into diag
        let diag = [4.0f32, 1.0, 0.25];
        let b = [1.0f32, 2.0, 3.0];
        let n_draws = 20_000;
        let mut mean = [0f64; 3];
        let mut var = [0f64; 3];
        let mut draws = Vec::with_capacity(n_draws);
        for _ in 0..n_draws {
            let mut s = Mat::zeros(k, k);
            for i in 0..k {
                s[(i, i)] = diag[i] - 1e-6;
            }
            let mut st = stats_from(s, b.to_vec());
            let z: Vec<f32> = (0..k).map(|_| ns.next(&mut rng) as f32).collect();
            let w = solve_native(&mut st, &Regularizer::Eye(1e-6), Some(&z)).unwrap();
            draws.push(w);
        }
        for w in &draws {
            for i in 0..k {
                mean[i] += w[i] as f64 / n_draws as f64;
            }
        }
        for w in &draws {
            for i in 0..k {
                var[i] += (w[i] as f64 - mean[i]).powi(2) / n_draws as f64;
            }
        }
        for i in 0..k {
            let want_mean = b[i] as f64 / diag[i] as f64;
            let want_var = 1.0 / diag[i] as f64;
            assert!((mean[i] - want_mean).abs() < 0.05 * (1.0 + want_mean.abs()), "mean[{i}]");
            assert!((var[i] - want_var).abs() / want_var < 0.1, "var[{i}] {} vs {want_var}", var[i]);
        }
    }

    #[test]
    fn indefinite_rejected() {
        let mut s = Mat::zeros(2, 2);
        s[(0, 0)] = -5.0;
        let mut st = stats_from(s, vec![1.0, 1.0]);
        assert!(solve_native(&mut st, &Regularizer::Eye(1.0), None).is_err());
    }
}
