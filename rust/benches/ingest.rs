//! Out-of-core ingestion bench (DESIGN.md §10): eager load vs streamed
//! `Cluster::from_stream` across chunk sizes.
//!
//! Prints wall-clock to a trained cluster and the loader-overhead
//! proxy — the high-water mark of parsed rows resident in *ingestion
//! buffers* at once (the sharded training data itself is ~N rows in
//! both modes; eager additionally materializes the whole file text and
//! a second full dataset copy). Eager's loader holds all N parsed rows;
//! the streamed path is bounded by 2 x chunk regardless of N (the
//! double-buffering contract, asserted below before timings are
//! reported). Each streamed run also checks its objective is
//! bit-identical to the eager one.
//!
//! Usage: `cargo bench --bench ingest` (`SCALE=0.2` shrinks N).

use pemsvm::benchutil::{header, scaled, time};
use pemsvm::config::TrainConfig;
use pemsvm::data::stream::{StreamOpts, StreamReader};
use pemsvm::data::{libsvm, synth, Task};
use pemsvm::engine::{Cluster, WarmStart};

fn main() {
    header("Ingest", "eager load vs streamed out-of-core ingestion");
    let n = scaled(150_000, 5_000);
    let k = 64usize;
    let path = std::env::temp_dir().join("pemsvm_ingest_bench.svm");
    let (gen_secs, _) = time(|| synth::write_libsvm_streaming(&path, n, k, 42).unwrap());
    let bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
    let mb = bytes as f64 / 1e6;
    println!("corpus: N={n} K={k} ({mb:.1} MB on disk, generated in {gen_secs:.2}s)");

    let mut cfg = TrainConfig::default().with_options("LIN-EM-CLS").unwrap();
    cfg.workers = 4;
    cfg.max_iters = 5;
    cfg.tol = 0.0;

    println!("   {:>10} {:>12} {:>12} {:>16}", "mode", "chunk", "build_secs", "peak_rows");

    // eager: whole file parsed up front, all N rows resident
    let (eager_secs, eager_out) = time(|| {
        let ds = libsvm::load(&path, Task::Binary, cfg.workers).unwrap();
        let mut cluster = Cluster::new(&ds, &cfg).unwrap();
        cluster.run_session(&cfg, None, WarmStart::Cold).unwrap()
    });
    println!("   {:>10} {:>12} {:>12.3} {:>16}", "eager", "-", eager_secs, n);

    for chunk in [2_048usize, 8_192, 32_768] {
        let opts = StreamOpts::rows(chunk);
        let (secs, gauge) = time(|| {
            let reader = StreamReader::open(&path, Task::Binary, &opts).unwrap();
            let gauge = reader.gauge();
            let mut cluster = Cluster::from_stream(reader, &cfg).unwrap();
            let out = cluster.run_session(&cfg, None, WarmStart::Cold).unwrap();
            assert_eq!(
                out.objective.to_bits(),
                eager_out.objective.to_bits(),
                "streamed trajectory diverged from eager"
            );
            gauge
        });
        let peak = gauge.peak();
        assert!(peak <= 2 * chunk, "peak resident rows {peak} > 2 x chunk {chunk}");
        println!("   {:>10} {:>12} {:>12.3} {:>16}", "streamed", chunk, secs, peak);
    }
    println!("(build_secs = ingest + the same 5-iteration session in every row; peak_rows");
    println!(" is loader-buffer rows resident at once — eager grows with N, streamed with");
    println!(" chunk; the sharded training data itself is ~N rows in both modes)");
    let _ = std::fs::remove_file(&path);
}
