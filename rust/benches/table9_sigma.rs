//! Table 9: accelerating the Sigma evaluation
//! `sum_d (1/gamma_d) x_d x_d^T` — the paper's GPU kernel experiment
//! (N = 250k, K = 500; 512 GPU cores 23x, 2048 cores 50x vs 1 CPU core).
//!
//! Our accelerator is the XLA/PJRT graph (padded to K = 512): one row
//! with the Pallas MXU-tiled kernel, one with XLA's native fused dot
//! (the ablation twin). On this CPU-only box the comparison shows the
//! *offload structure* — real-TPU speedups are estimated analytically
//! in DESIGN.md §Hardware-Adaptation.

#[cfg(not(feature = "xla"))]
fn main() {
    println!("table9_sigma compares against the PJRT graphs; rebuild with `--features xla`");
}

#[cfg(feature = "xla")]
fn main() {
    use pemsvm::benchutil::{header, scaled, time};
    use pemsvm::data::synth;
    use pemsvm::linalg::SymPacked;
    use pemsvm::runtime::{global, literal_f32};

    header("Table 9", "using accelerator graphs to evaluate Sigma (N=250k, K=500)");
    let n = scaled(250_000, 20_000);
    let k = 500usize;
    let ds = synth::alpha_like(n, k, 0);
    // simulated gamma weights (paper uses simulated x, gamma too)
    let mut g = pemsvm::rng::Pcg64::new(1);
    let a: Vec<f32> = (0..n).map(|_| g.next_f32() * 2.0).collect();

    // 1 CPU core, native rank update (the paper's baseline row);
    // unpack included so the row charges the full Sigma materialization
    let (t_cpu, _s) = time(|| {
        let mut s = SymPacked::zeros(k);
        if let pemsvm::data::Features::Dense { data } = &ds.features {
            pemsvm::linalg::rank_update_dense(&mut s, data, n, k, &a);
        }
        s.unpack()
    });

    println!("   {:<28} {:>9} {:>15}", "Implementation", "Time", "Relative speed");
    println!("   {:<28} {:>8.2}s {:>15.2}", "1 CPU core (native)", t_cpu, 1.0);

    // XLA rows need artifacts
    let Ok(rt) = global(std::path::Path::new("artifacts")) else {
        println!("   (artifacts missing -- run `make artifacts` for the XLA rows)");
        return;
    };
    let chunk = rt.chunk();
    let pk = rt.pad_k(k).unwrap();
    // upload chunks once (like loading GPU global memory), then time the
    // pure execution pass; weights w=0 makes gamma=1/max(1,eps)=1 --
    // we reuse the lin_em_step artifact as the Sigma evaluator.
    let mut chunks = Vec::new();
    let mut xbuf = vec![0f32; chunk * pk];
    let mut ybuf = vec![0f32; chunk];
    let mut mbuf = vec![0f32; chunk];
    if let pemsvm::data::Features::Dense { data } = &ds.features {
        let mut start = 0usize;
        while start < n {
            let rows = (n - start).min(chunk);
            xbuf.fill(0.0);
            ybuf.fill(0.0);
            mbuf.fill(0.0);
            for r in 0..rows {
                xbuf[r * pk..r * pk + k].copy_from_slice(&data[(start + r) * k..(start + r + 1) * k]);
                ybuf[r] = 1.0;
                mbuf[r] = 1.0;
            }
            chunks.push((
                literal_f32(&xbuf, &[chunk as i64, pk as i64]).unwrap(),
                literal_f32(&ybuf, &[chunk as i64]).unwrap(),
                literal_f32(&mbuf, &[chunk as i64]).unwrap(),
            ));
            start += rows;
        }
    }
    let w = literal_f32(&vec![0f32; pk], &[pk as i64]).unwrap();
    let eps = literal_f32(&[1e-5f32], &[1]).unwrap();

    for (label, name) in [
        ("XLA graph (Pallas kernel)", format!("lin_em_step_k{pk}")),
        ("XLA graph (native dot)", format!("lin_em_step_jnp_k{pk}")),
    ] {
        // warm up / compile
        let (x0, y0, m0) = &chunks[0];
        rt.execute(&name, &[x0, y0, m0, &w, &eps]).unwrap();
        let (t, _) = time(|| {
            let mut acc = vec![0f32; pk * pk];
            for (x, y, m) in &chunks {
                let outs = rt.execute(&name, &[x, y, m, &w, &eps]).unwrap();
                let s = pemsvm::runtime::to_vec_f32(&outs[0]).unwrap();
                for (a, b) in acc.iter_mut().zip(&s) {
                    *a += b;
                }
            }
            acc
        });
        println!("   {:<28} {:>8.2}s {:>15.2}", label, t, t_cpu / t);
    }
    println!("\n   paper: 512 GPU cores 23x, 2048 GPU cores 50x (GTX590);");
    println!("   TPU estimate for the Pallas schedule: DESIGN.md §Hardware-Adaptation");
}
