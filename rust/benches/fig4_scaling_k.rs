//! Figure 4: effect of K on training time (alpha dataset), all solvers
//! single-threaded. Paper: LIN-CLS quadratic in K (dense K x K stats),
//! liblinear/Pegasos linear in K; PSVM hit hard by the high N.

use pemsvm::baselines::{dcd, pegasos, psvm_lite};
use pemsvm::benchutil::{header, loglog_slope, scaled, time};
use pemsvm::config::TrainConfig;
use pemsvm::data::synth;

fn main() {
    header("Figure 4", "training time vs K, alpha dataset (single-threaded)");
    let n = scaled(20_000, 4_000);
    let ks = [25usize, 50, 100, 200, 400];
    println!("N={n}; fixed 10 EM iterations / capped baseline epochs");
    println!("   {:>6} {:>11} {:>11} {:>11} {:>11}", "K", "LIN-EM-CLS", "PSVM", "LL-Dual", "Pegasos");

    let mut t_lin = Vec::new();
    let mut t_psvm = Vec::new();
    let mut t_dcd = Vec::new();
    let mut t_peg = Vec::new();
    for &k in &ks {
        let ds = synth::alpha_like(n, k, 0);
        let mut cfg = TrainConfig::default().with_options("LIN-EM-CLS").unwrap();
        cfg.workers = 1;
        cfg.max_iters = 10;
        cfg.tol = 0.0;
        let (a, _) = time(|| pemsvm::coordinator::train(&ds, &cfg).unwrap());
        let (b, _) = time(|| psvm_lite::train(&ds, &psvm_lite::PsvmLiteCfg { pg_iters: 50, ..Default::default() }));
        let (c, _) = time(|| dcd::train(&ds, &dcd::DcdCfg { max_epochs: 20, ..Default::default() }));
        let (d, _) = time(|| pegasos::train(&ds, &pegasos::PegasosCfg { epochs: 10, ..Default::default() }));
        println!("   {:>6} {:>10.2}s {:>10.2}s {:>10.2}s {:>10.2}s", k, a, b, c, d);
        t_lin.push(a);
        t_psvm.push(b);
        t_dcd.push(c);
        t_peg.push(d);
    }
    let ksf: Vec<f64> = ks.iter().map(|&k| k as f64).collect();
    println!("\n   scaling exponents (log-log slope vs K; paper: LIN ~2, LL/Pegasos ~1):");
    println!(
        "   LIN-EM-CLS {:.2}   PSVM {:.2}   LL-Dual {:.2}   Pegasos {:.2}",
        loglog_slope(&ksf, &t_lin),
        loglog_slope(&ksf, &t_psvm),
        loglog_slope(&ksf, &t_dcd),
        loglog_slope(&ksf, &t_peg)
    );
}
