//! Tables 1 & 2: empirical per-phase iteration times vs the paper's
//! asymptotic analysis.
//!
//! LIN (Table 1): local stats O(N K^2 / P), reduce O(K^2 log P),
//! draw mu O(K^3) [the paper writes K^2 log K for its solver; ours is a
//! Cholesky], broadcast O(K^2 log P).
//! KRN (Table 2): same with K := N.
//!
//! We sweep one variable at a time and report the measured log-log
//! exponent of each phase next to the asymptotic prediction.

use pemsvm::benchutil::{header, loglog_slope, scaled};
use pemsvm::config::{Topology, TrainConfig};
use pemsvm::data::synth;
use pemsvm::metrics::Phase;

fn phases_for(ds: &pemsvm::data::Dataset, p: usize, iters: usize) -> (f64, f64, f64) {
    let mut cfg = TrainConfig::default().with_options("LIN-EM-CLS").unwrap();
    cfg.workers = p;
    cfg.topology = Topology::Simulate;
    cfg.max_iters = iters;
    cfg.tol = 0.0;
    let out = pemsvm::coordinator::train(ds, &cfg).unwrap();
    let m = &out.metrics;
    (
        m.total(Phase::LocalStats).as_secs_f64() / iters as f64,
        m.total(Phase::Reduce).as_secs_f64() / iters as f64,
        m.total(Phase::DrawMu).as_secs_f64() / iters as f64,
    )
}

fn main() {
    header("Tables 1+2", "empirical per-phase iteration time vs asymptotics");
    let iters = 5;

    // --- sweep N (LIN: stats ~ N, others flat) -------------------------
    println!("\n-- sweep N (K=100, P=4)");
    println!("   {:>8} {:>12} {:>12} {:>12}", "N", "stats/iter", "reduce/iter", "solve/iter");
    let ns: Vec<usize> = [10_000, 20_000, 40_000, 80_000].iter().map(|&n| scaled(n, 2_000)).collect();
    let mut stats_t = Vec::new();
    for &n in &ns {
        let ds = synth::alpha_like(n, 100, 0);
        let (s, r, m) = phases_for(&ds, 4, iters);
        println!("   {:>8} {:>11.4}s {:>11.4}s {:>11.4}s", n, s, r, m);
        stats_t.push(s);
    }
    let nsf: Vec<f64> = ns.iter().map(|&x| x as f64).collect();
    println!("   stats exponent vs N: {:.2} (paper: 1.0)", loglog_slope(&nsf, &stats_t));

    // --- sweep K (stats ~ K^2, solve ~ K^3) ----------------------------
    println!("\n-- sweep K (N={}, P=4)", scaled(20_000, 4_000));
    println!("   {:>8} {:>12} {:>12} {:>12}", "K", "stats/iter", "reduce/iter", "solve/iter");
    let ks = [50usize, 100, 200, 400];
    let n = scaled(20_000, 4_000);
    let (mut st, mut rt, mut mt) = (Vec::new(), Vec::new(), Vec::new());
    for &k in &ks {
        let ds = synth::alpha_like(n, k, 0);
        let (s, r, m) = phases_for(&ds, 4, iters);
        println!("   {:>8} {:>11.4}s {:>11.4}s {:>11.4}s", k, s, r, m);
        st.push(s);
        rt.push(r);
        mt.push(m);
    }
    let ksf: Vec<f64> = ks.iter().map(|&x| x as f64).collect();
    println!(
        "   exponents vs K: stats {:.2} (paper 2.0), reduce {:.2} (paper 2.0), solve {:.2} (Cholesky 3.0)",
        loglog_slope(&ksf, &st),
        loglog_slope(&ksf, &rt),
        loglog_slope(&ksf, &mt)
    );

    // --- sweep P (stats ~ 1/P) -----------------------------------------
    println!("\n-- sweep P (N={}, K=100)", scaled(40_000, 8_000));
    println!("   {:>8} {:>12} {:>12}", "P", "stats/iter", "reduce/iter");
    let ps = [1usize, 2, 4, 8, 16, 32];
    let n = scaled(40_000, 8_000);
    let ds = synth::alpha_like(n, 100, 0);
    let mut pst = Vec::new();
    for &p in &ps {
        let (s, r, _) = phases_for(&ds, p, iters);
        println!("   {:>8} {:>11.4}s {:>11.4}s", p, s, r);
        pst.push(s);
    }
    let psf: Vec<f64> = ps.iter().map(|&x| x as f64).collect();
    println!("   stats exponent vs P: {:.2} (paper: -1.0)", loglog_slope(&psf, &pst));

    // --- KRN: iteration time independent of K, cubic-ish in N ----------
    println!("\n-- KRN sweep N (Table 2; gram features, solve dominates)");
    println!("   {:>8} {:>12} {:>12}", "N", "stats/iter", "solve/iter");
    let kns = [200usize, 400, 800];
    let mut k_solve = Vec::new();
    for &kn in &kns {
        let ds = synth::news20_like(kn, 300, 0);
        let (kds, _gram) = pemsvm::solver::gram_dataset(&ds, &pemsvm::config::KernelCfg::Gaussian { sigma: 1.0 });
        let (s, _, m) = phases_for(&kds, 4, iters);
        println!("   {:>8} {:>11.4}s {:>11.4}s", kn, s, m);
        k_solve.push(m);
    }
    let knf: Vec<f64> = kns.iter().map(|&x| x as f64).collect();
    println!("   KRN solve exponent vs N: {:.2} (paper: ~3)", loglog_slope(&knf, &k_solve));
}
