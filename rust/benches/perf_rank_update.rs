//! §Perf microbench: the native Sigma^p accumulation
//! (rank_update_dense), the single hottest loop of the native backend.
//! Prints GFLOP/s at several K for the runtime-dispatched kernel AND
//! the scalar fallback side by side, so EXPERIMENTS.md §Perf has both
//! the absolute number and the SIMD speedup to track across
//! optimization iterations.

use pemsvm::benchutil::time;
use pemsvm::linalg::{active_isa, rank_update_dense, rank_update_dense_scalar, SymPacked};
use pemsvm::rng::Pcg64;

fn main() {
    println!(
        "rank_update_dense GFLOP/s (lower-triangle FLOPs = N*K*(K+1)/2 mul-adds x2); \
         dispatched isa = {}",
        active_isa().name()
    );
    println!(
        "  {:<5} {:<8} {:>10} {:>10} {:>8}",
        "K", "N", "scalar", "simd", "speedup"
    );
    for k in [64usize, 128, 256, 512, 800] {
        let n = (40_000_000 / (k * k)).max(64); // ~40 MFLOP-ish per rep
        let mut g = Pcg64::new(1);
        let x: Vec<f32> = (0..n * k).map(|_| g.next_f32() - 0.5).collect();
        let a: Vec<f32> = (0..n).map(|_| g.next_f32() + 0.1).collect();
        let mut s = SymPacked::zeros(k);
        let reps = 5;
        let flops = reps as f64 * n as f64 * (k * (k + 1)) as f64; // x2 mul-add /2 triangle

        // warm, then time the scalar fallback
        rank_update_dense_scalar(&mut s, &x, n, k, &a);
        let (t_scalar, _) = time(|| {
            for _ in 0..reps {
                rank_update_dense_scalar(&mut s, &x, n, k, &a);
            }
        });

        // warm, then time the dispatched kernel
        rank_update_dense(&mut s, &x, n, k, &a);
        let (t_simd, _) = time(|| {
            for _ in 0..reps {
                rank_update_dense(&mut s, &x, n, k, &a);
            }
        });

        println!(
            "  {:<5} {:<8} {:>10.2} {:>10.2} {:>7.2}x",
            k,
            n,
            flops / t_scalar / 1e9,
            flops / t_simd / 1e9,
            t_scalar / t_simd
        );
    }
}
