//! §Perf microbench: the native Sigma^p accumulation
//! (rank_update_dense), the single hottest loop of the native backend.
//! Prints GFLOP/s at several K so the EXPERIMENTS.md §Perf log has a
//! stable number to track across optimization iterations.

use pemsvm::benchutil::time;
use pemsvm::linalg::{rank_update_dense, Mat};
use pemsvm::rng::Pcg64;

fn main() {
    println!("rank_update_dense GFLOP/s (lower-triangle FLOPs = N*K*(K+1)/2 mul-adds x2)");
    for k in [64usize, 128, 256, 512, 800] {
        let n = (40_000_000 / (k * k)).max(64); // ~40 MFLOP-ish per rep
        let mut g = Pcg64::new(1);
        let x: Vec<f32> = (0..n * k).map(|_| g.next_f32() - 0.5).collect();
        let a: Vec<f32> = (0..n).map(|_| g.next_f32() + 0.1).collect();
        let mut s = Mat::zeros(k, k);
        // warm
        rank_update_dense(&mut s, &x, n, k, &a);
        let reps = 5;
        let (t, _) = time(|| {
            for _ in 0..reps {
                rank_update_dense(&mut s, &x, n, k, &a);
            }
        });
        let flops = reps as f64 * n as f64 * (k * (k + 1)) as f64; // x2 mul-add /2 triangle
        println!("  K={k:<4} N={n:<7} {:>7.2} GFLOP/s   ({:.3}s)", flops / t / 1e9, t);
    }
}
