//! Table 7: kernel SVM on an N=1800 news20-like subset.
//! Paper: LL-Dual 7.1s / LL-Primal 1.67s / KRN-EM-CLS (48 cores) 27.2s,
//! all ~90% accuracy — the kernel solver is *slower* but matches
//! accuracy and its time is independent of K (checked here with two K).

use pemsvm::baselines::{dcd, primal_newton};
use pemsvm::benchutil::{header, modeled_sim_secs, time};
use pemsvm::config::{KernelCfg, Topology, TrainConfig};
use pemsvm::data::synth;
use pemsvm::model::accuracy_cls;

fn krn_row(tr: &pemsvm::data::Dataset, te: &pemsvm::data::Dataset) -> (f64, f64) {
    let mut cfg = TrainConfig::default().with_options("KRN-EM-CLS").unwrap();
    cfg.lambda = 1e-2;
    cfg.kernel = KernelCfg::Gaussian { sigma: 1.0 };
    cfg.workers = 48;
    cfg.topology = Topology::Simulate;
    cfg.max_iters = 40;
    let (t_gram_plus_train, out) = time(|| pemsvm::coordinator::train_full(tr, Some(te), &cfg).unwrap());
    let _ = t_gram_plus_train;
    let t = modeled_sim_secs(&out, cfg.workers, tr.n);
    let km = out.kernel_model.unwrap();
    (t, km.accuracy(te) * 100.0)
}

fn main() {
    header("Table 7", "KRN on N=1800 subset of news20");
    for k in [600usize, 2400] {
        let ds = synth::news20_like(2160, k, 0);
        let (tr, te) = synth::split(&ds, 6);
        println!("\nN={} K={k}", tr.n);
        println!("   {:<16} {:>5} {:>10} {:>8}", "Solver", "Cores", "Train", "Acc.%");

        let (t, out) = time(|| dcd::train(&tr, &dcd::DcdCfg { lambda: 1e-2, ..Default::default() }));
        println!("   {:<16} {:>5} {:>9.2}s {:>8.2}", "LL-Dual", 1, t, accuracy_cls(&te, &out.w) * 100.0);

        let (t, w) = time(|| primal_newton::train(&tr, &primal_newton::PrimalNewtonCfg { lambda: 1e-2, ..Default::default() }));
        println!("   {:<16} {:>5} {:>9.2}s {:>8.2}", "LL-Primal", 1, t, accuracy_cls(&te, &w) * 100.0);

        let (t, acc) = krn_row(&tr, &te);
        println!("   {:<16} {:>5} {:>9.2}s {:>8.2}  (cluster cost model; K-independent iteration)", "KRN-EM-CLS", 48, t, acc);
    }
}
