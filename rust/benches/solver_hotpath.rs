//! §Perf bench: the solver hot path end to end.
//!
//! Three sections: (1) the Sigma^p rank-update kernel in GFLOP/s,
//! dispatched-SIMD vs the scalar fallback (the PR-over-PR perf
//! trajectory number); (2) per-iteration worker-step wall-clock for the
//! three tasks (CLS / SVR / MLT) at a representative shape, using one
//! reused [`StepWorkspace`] exactly like the engine loop does; (3) the
//! cost of the telemetry layer's per-iteration instrumentation bundle —
//! now including a `--diag-every 1` [`ChainDiag`] observation —
//! asserted < 1% of one CLS iteration (ISSUE acceptance). The budget
//! denominator is measured at a **fixed** N=20,000 reference shape so
//! the assert means the same thing under `--quick` / `SCALE` smoke
//! runs as at full scale.
//!
//! Results are printed AND written as a snapshot to `BENCH_solver.json`
//! at the repo root via [`benchutil::write_bench_json`] (one
//! self-contained JSON object; later runs overwrite it — the git
//! history / CI artifacts are the trajectory).

use pemsvm::benchutil::{header, scaled, time, write_bench_json};
use pemsvm::data::synth;
use pemsvm::linalg::{active_isa, rank_update_dense, rank_update_dense_scalar, Mat, SymPacked};
use pemsvm::rng::Pcg64;
use pemsvm::solver::{local, GammaMode, PartialStats, StepWorkspace};

fn gflops_pair(k: usize) -> (usize, f64, f64) {
    let n = (40_000_000 / (k * k)).max(64);
    let mut g = Pcg64::new(1);
    let x: Vec<f32> = (0..n * k).map(|_| g.next_f32() - 0.5).collect();
    let a: Vec<f32> = (0..n).map(|_| g.next_f32() + 0.1).collect();
    let mut s = SymPacked::zeros(k);
    let reps = 5;
    let flops = reps as f64 * n as f64 * (k * (k + 1)) as f64;
    rank_update_dense_scalar(&mut s, &x, n, k, &a); // warm
    let (t_scalar, _) = time(|| {
        for _ in 0..reps {
            rank_update_dense_scalar(&mut s, &x, n, k, &a);
        }
    });
    rank_update_dense(&mut s, &x, n, k, &a); // warm
    let (t_simd, _) = time(|| {
        for _ in 0..reps {
            rank_update_dense(&mut s, &x, n, k, &a);
        }
    });
    (n, flops / t_scalar / 1e9, flops / t_simd / 1e9)
}

fn main() {
    header("solver_hotpath", "SIMD kernel GFLOP/s + per-iteration step time (CLS/SVR/MLT)");
    let isa = active_isa().name();
    println!("  dispatched isa: {isa}");

    // --- section 1: rank-update kernel ---
    let mut kernel_rows = Vec::new();
    println!("  {:<5} {:<8} {:>10} {:>10} {:>8}", "K", "N", "scalar", "simd", "speedup");
    for k in [128usize, 256, 512] {
        let (n, gf_scalar, gf_simd) = gflops_pair(k);
        println!(
            "  {:<5} {:<8} {:>10.2} {:>10.2} {:>7.2}x",
            k,
            n,
            gf_scalar,
            gf_simd,
            gf_simd / gf_scalar
        );
        kernel_rows.push((k, n, gf_scalar, gf_simd));
    }

    // --- section 2: per-iteration worker-step wall-clock ---
    let (n, k) = (scaled(20_000, 2_000), 128usize);
    let eps = 1e-5f32;
    let reps = 5;
    let mut ws = StepWorkspace::new();

    let cls = synth::alpha_like(n, k, 2);
    let w = vec![0.01f32; k];
    let mut st = PartialStats::zeros(k);
    local::lin_step(&cls, 0..n, &w, eps, &mut GammaMode::Em, &mut ws, &mut st); // warm
    let (t_cls, _) = time(|| {
        for _ in 0..reps {
            st.reset();
            local::lin_step(&cls, 0..n, &w, eps, &mut GammaMode::Em, &mut ws, &mut st);
        }
    });

    let svr = synth::year_like(n, k, 3);
    local::svr_step(&svr, 0..n, &w, eps, 0.1, &mut GammaMode::Em, &mut ws, &mut st); // warm
    let (t_svr, _) = time(|| {
        for _ in 0..reps {
            st.reset();
            local::svr_step(&svr, 0..n, &w, eps, 0.1, &mut GammaMode::Em, &mut ws, &mut st);
        }
    });

    // MLT: one outer iteration = m per-class calls in Gauss-Seidel
    // order (class 0 fills the score cache, classes 1..m reuse it)
    let m = 10usize;
    let mlt = synth::mnist_like(n, k, m, 4);
    let mut w_all = Mat::zeros(m, k);
    let mut g = Pcg64::new(7);
    for x in w_all.data.iter_mut() {
        *x = 0.01 * (g.next_f32() - 0.5);
    }
    for y in 0..m {
        st.reset();
        local::mlt_step(&mlt, 0..n, &w_all, y, eps, &mut GammaMode::Em, &mut ws, &mut st);
    } // warm
    let (t_mlt, _) = time(|| {
        for _ in 0..reps {
            for y in 0..m {
                st.reset();
                local::mlt_step(&mlt, 0..n, &w_all, y, eps, &mut GammaMode::Em, &mut ws, &mut st);
            }
        }
    });

    let (cls_it, svr_it, mlt_it) =
        (t_cls / reps as f64, t_svr / reps as f64, t_mlt / reps as f64);
    println!("  per-iteration step time at N={n} K={k} (MLT: m={m}, all classes):");
    println!("    CLS {:>9.2} ms", cls_it * 1e3);
    println!("    SVR {:>9.2} ms", svr_it * 1e3);
    println!("    MLT {:>9.2} ms", mlt_it * 1e3);

    // --- section 3: telemetry overhead per iteration ---
    // The budget denominator: one CLS iteration at the FIXED reference
    // shape (N=20,000, K=128), re-measured here so --quick/SCALE runs
    // assert against the same baseline as full-scale runs.
    let ref_cls_it = {
        let (rn, rk) = (20_000usize, 128usize);
        let ds = synth::alpha_like(rn, rk, 2);
        let w = vec![0.01f32; rk];
        let mut st = PartialStats::zeros(rk);
        local::lin_step(&ds, 0..rn, &w, eps, &mut GammaMode::Em, &mut ws, &mut st); // warm
        let (t, _) = time(|| {
            for _ in 0..3 {
                st.reset();
                local::lin_step(&ds, 0..rn, &w, eps, &mut GammaMode::Em, &mut ws, &mut st);
            }
        });
        t / 3.0
    };

    // Replays exactly what `run_session_traced` adds around one
    // iteration: two Instant reads, a phase_totals diff, the
    // weight-delta norm over K weights, a counter inc, six counter
    // adds, a histogram observe — all against live registry series —
    // plus one full `--diag-every 1` ChainDiag observation (Welford
    // over K coords, projection dot, three scalar-chain pushes,
    // verdict checks).
    let (tel_per_iter, overhead_pct) = {
        use pemsvm::metrics::{Metrics, Phase, NPHASES};
        use pemsvm::telemetry::{self, ChainDiag, Counter, Histogram, IterObs};
        use std::sync::Arc;

        let reg = telemetry::global();
        let iters: Arc<Counter> = reg.counter("bench_iterations_total", "");
        let hist: Arc<Histogram> = reg.histogram("bench_iteration_nanos", "");
        let phases: Vec<Arc<Counter>> = (0..NPHASES)
            .map(|i| {
                reg.counter_labeled(
                    "bench_phase_nanos_total",
                    &telemetry::label("phase", ["a", "b", "c", "d", "e", "f"][i]),
                    "",
                )
            })
            .collect();
        let mut metrics = Metrics::new();
        metrics.add(Phase::LocalStats, std::time::Duration::from_micros(3));
        let w_prev = vec![0.01f32; k];
        let w_cur = vec![0.02f32; k];
        // detached: same arithmetic as the engine's diag path, no
        // global-gauge writes from a bench binary
        let mut diag = ChainDiag::new_detached(true, 0, k, 42);

        let tel_reps = 100_000u32;
        let mut sink = 0f64;
        let (t_tel, _) = time(|| {
            for it in 0..tel_reps {
                let t0 = std::time::Instant::now();
                let before = metrics.phase_totals();
                let cur = std::hint::black_box(&w_cur);
                let mut acc = 0f64;
                for (i, &c) in cur.iter().enumerate() {
                    let d = (c - w_prev[i]) as f64;
                    acc += d * d;
                }
                sink += acc.sqrt();
                let after = metrics.phase_totals();
                iters.inc();
                for (i, c) in phases.iter().enumerate() {
                    c.add(after[i].saturating_sub(before[i]).as_nanos() as u64);
                }
                diag.observe(&IterObs {
                    iter: it as usize,
                    objective: 100.0 + acc,
                    weights: cur,
                    weight_delta: acc.sqrt(),
                    step_max: 1.1e-3,
                    step_mean: 1.0e-3,
                });
                hist.observe_duration(t0.elapsed());
            }
        });
        std::hint::black_box(diag.samples());
        std::hint::black_box(sink);
        let per_iter = t_tel / tel_reps as f64;
        (per_iter, 100.0 * per_iter / ref_cls_it)
    };
    println!(
        "  telemetry+diag bundle: {:.0} ns/iter = {overhead_pct:.4}% of one reference CLS \
         iteration (N=20000)",
        tel_per_iter * 1e9
    );
    assert!(
        overhead_pct < 1.0,
        "telemetry+diag instrumentation costs {overhead_pct:.3}% of a CLS iteration (budget: 1%)"
    );

    // --- JSON snapshot ---
    let mut rows = String::new();
    for (i, (k, n, gs, gv)) in kernel_rows.iter().enumerate() {
        if i > 0 {
            rows.push(',');
        }
        rows.push_str(&format!(
            "{{\"k\":{k},\"n\":{n},\"scalar_gflops\":{gs:.3},\"simd_gflops\":{gv:.3},\
             \"speedup\":{:.3}}}",
            gv / gs
        ));
    }
    let json = format!(
        "{{\n  \"bench\": \"solver_hotpath\",\n  \"isa\": \"{isa}\",\n  \
         \"scale\": {},\n  \"rank_update\": [{rows}],\n  \
         \"iteration_secs\": {{\"n\": {n}, \"k\": {k}, \"m\": {m}, \
         \"cls\": {cls_it:.6}, \"svr\": {svr_it:.6}, \"mlt\": {mlt_it:.6}}},\n  \
         \"telemetry\": {{\"per_iter_nanos\": {:.1}, \"overhead_pct_cls\": {overhead_pct:.5}, \
         \"ref_cls_iter_secs\": {ref_cls_it:.6}}}\n}}\n",
        pemsvm::benchutil::scale(),
        tel_per_iter * 1e9
    );
    write_bench_json("solver", &json);
}
