//! Distributed communication overhead (DESIGN.md §15): the same
//! 2-worker training loop through the in-process threaded pool vs over
//! loopback TCP to `pemsvm worker` daemons, at K = 128 and K = 1024.
//!
//! Reported per iteration: total wall-clock, the broadcast + reduce
//! phase times, and the wire bytes moved (from the
//! `net_bytes_{tx,rx}_total` counters — both endpoints run in this
//! process and share the telemetry registry, so the deltas cover both
//! directions of the conversation). The one-time dataset ship is
//! reported separately from the steady-state per-iteration traffic.
//!
//! `--quick` is the CI smoke preset; a `BENCH_net.json` snapshot lands
//! at the repo root via [`benchutil::write_bench_json`].

use std::net::TcpListener;

use pemsvm::benchutil::{header, quick, scaled, time, write_bench_json};
use pemsvm::config::{Topology, TrainConfig};
use pemsvm::data::{synth, Dataset};
use pemsvm::engine::{Cluster, WarmStart};
use pemsvm::metrics::Phase;
use pemsvm::net::net_metrics;

fn spawn_workers(n: usize) -> Vec<String> {
    let mut hosts = Vec::new();
    for _ in 0..n {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        hosts.push(listener.local_addr().unwrap().to_string());
        std::thread::spawn(move || {
            let _ = pemsvm::net::worker::run(listener, false);
        });
    }
    hosts
}

struct Point {
    k: usize,
    iters: usize,
    /// (wall, broadcast, reduce) seconds per iteration
    threads: (f64, f64, f64),
    remote: (f64, f64, f64),
    /// one-time dataset ship, wire bytes (both directions)
    ship_bytes: u64,
    /// steady-state wire bytes per iteration (both directions)
    iter_bytes: f64,
}

fn session(ds: &Dataset, cfg: &TrainConfig) -> (f64, f64, f64) {
    let mut cl = Cluster::new(ds, cfg).unwrap();
    let (wall, out) = time(|| cl.run_session(cfg, None, WarmStart::Cold).unwrap());
    let per = |p: Phase| out.metrics.total(p).as_secs_f64() / cfg.max_iters as f64;
    (wall / cfg.max_iters as f64, per(Phase::Broadcast), per(Phase::Reduce))
}

fn bench_k(k: usize, iters: usize) -> Point {
    // N is deliberately modest: the point is the communication term,
    // which is O(K^2) per round and independent of N
    let ds = synth::alpha_like(scaled(3000, 300), k, 0);
    let mut cfg = TrainConfig::default().with_options("LIN-EM-CLS").unwrap();
    cfg.workers = 2;
    cfg.max_iters = iters;
    cfg.tol = -1.0;

    let threads = session(&ds, &cfg);

    let m = net_metrics();
    let wire = |b0: u64| (m.bytes_tx.get() + m.bytes_rx.get()) - b0;
    let mut rcfg = cfg.clone();
    rcfg.topology = Topology::Remote(spawn_workers(cfg.workers));
    let b0 = m.bytes_tx.get() + m.bytes_rx.get();
    // Cluster::new connects, configures, and ships the full dataset
    let mut cl = Cluster::new(&ds, &rcfg).unwrap();
    let ship_bytes = wire(b0);
    let b1 = b0 + ship_bytes;
    let (rwall, out) = time(|| cl.run_session(&rcfg, None, WarmStart::Cold).unwrap());
    let iter_bytes = wire(b1) as f64 / iters as f64;
    let per = |p: Phase| out.metrics.total(p).as_secs_f64() / iters as f64;
    let remote = (rwall / iters as f64, per(Phase::Broadcast), per(Phase::Reduce));
    drop(cl);

    Point { k, iters, threads, remote, ship_bytes, iter_bytes }
}

fn main() {
    header("net", "distributed comm overhead: loopback TCP daemons vs in-process threads (P=2)");
    let iters = if quick() { 3 } else { 6 };
    println!(
        "   {:>6} {:>13} {:>13} {:>13} {:>13} {:>12} {:>12}",
        "K", "thr wall/it", "net wall/it", "bcast/it", "reduce/it", "bytes/it", "ship bytes"
    );
    let mut points = Vec::new();
    for &k in &[128usize, 1024] {
        let p = bench_k(k, iters);
        println!(
            "   {:>6} {:>12.4}s {:>12.4}s {:>12.4}s {:>12.4}s {:>12.0} {:>12}",
            p.k, p.threads.0, p.remote.0, p.remote.1, p.remote.2, p.iter_bytes, p.ship_bytes
        );
        println!(
            "   {:>6} {:>12} overhead {:.2}x wall  (threads bcast/it {:.4}s reduce/it {:.4}s)",
            "",
            "",
            p.remote.0 / p.threads.0.max(1e-12),
            p.threads.1,
            p.threads.2
        );
        points.push(p);
    }

    let rows: Vec<String> = points
        .iter()
        .map(|p| {
            format!(
                "{{\"k\": {}, \"iters\": {}, \"threads_wall_per_iter\": {:.6}, \
                 \"remote_wall_per_iter\": {:.6}, \"remote_broadcast_per_iter\": {:.6}, \
                 \"remote_reduce_per_iter\": {:.6}, \"wire_bytes_per_iter\": {:.0}, \
                 \"ship_bytes\": {}}}",
                p.k, p.iters, p.threads.0, p.remote.0, p.remote.1, p.remote.2, p.iter_bytes,
                p.ship_bytes
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"net_overhead\",\n  \"scale\": {},\n  \"workers\": 2,\n  \
         \"points\": [\n    {}\n  ]\n}}\n",
        pemsvm::benchutil::scale(),
        rows.join(",\n    ")
    );
    write_bench_json("net", &json);
}
