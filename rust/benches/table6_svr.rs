//! Table 6: SVR on the year dataset (year-like synthetic, normalized).
//! Paper: LL-Primal 15.0s / LL-Dual 114.9s / LIN-EM-SVR (48 cores) 2.5s,
//! RMS errors 0.88-0.90. LL-Primal SVR is substituted by the same dual
//! coordinate solver at a looser tolerance (DESIGN.md §6).

use pemsvm::baselines::svr_dcd;
use pemsvm::benchutil::{header, modeled_sim_secs, scaled, time};
use pemsvm::config::{Topology, TrainConfig};
use pemsvm::data::synth;
use pemsvm::model::rmse;

fn main() {
    header("Table 6", "SVR on year dataset");
    let (n, k) = (scaled(250_000, 10_000), 90);
    let ds = synth::year_like(n, k, 0);
    let (tr, te) = synth::split(&ds, 6);
    println!("N={} K={} (paper: 250k x 90), epsilon=0.3", tr.n, tr.k);
    println!("   {:<16} {:>5} {:>10} {:>10}", "Solver", "Cores", "Train", "RMS error");

    let (lam, eps) = (0.01f32, 0.3f32);
    let (t, w) = time(|| {
        svr_dcd::train(&tr, &svr_dcd::SvrDcdCfg {
            lambda: lam,
            eps_insensitive: eps,
            tol: 1e-2,
            max_epochs: 30,
            ..Default::default()
        })
    });
    println!("   {:<16} {:>5} {:>9.2}s {:>10.3}", "LL-Primal*", 1, t, rmse(&te, &w));

    let (t, w) = time(|| {
        svr_dcd::train(&tr, &svr_dcd::SvrDcdCfg { lambda: lam, eps_insensitive: eps, ..Default::default() })
    });
    println!("   {:<16} {:>5} {:>9.2}s {:>10.3}", "LL-Dual", 1, t, rmse(&te, &w));

    let mut cfg = TrainConfig::default().with_options("LIN-EM-SVR").unwrap();
    cfg.lambda = lam;
    cfg.eps_insensitive = eps;
    cfg.workers = 48;
    cfg.topology = Topology::Simulate;
    cfg.max_iters = 60;
    let out = pemsvm::coordinator::train(&tr, &cfg).unwrap();
    println!(
        "   {:<16} {:>5} {:>9.2}s {:>10.3}  (cluster cost model)",
        "LIN-EM-SVR",
        cfg.workers,
        modeled_sim_secs(&out, cfg.workers, tr.k),
        rmse(&te, out.weights.single())
    );
}
