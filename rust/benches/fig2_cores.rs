//! Figure 2: effect of the number of cores on training speed (dna).
//! Paper: speed linear in cores to 480 on dna (their Sigma accumulation
//! touches dense K x K per row, so the N/P term dominates).
//!
//! We reproduce the same regime with densified rows and report the
//! cluster cost model: max-worker stats + tree-reduce
//! (log2(P) pair merges) + solve. A real-thread wall-clock row is
//! included for P up to this box's cores.

use pemsvm::benchutil::{header, loglog_slope, modeled_sim_secs, scaled};
use pemsvm::config::{Topology, TrainConfig};
use pemsvm::data::synth;

fn main() {
    header("Figure 2", "training speed vs cores, dna dataset");
    // The paper notes its Sigma accumulation pays dense K x K cost even
    // on sparse dna; our sparse rank-update skips zeros, so we use the
    // truly-dense alpha signature to land in the same stats-dominated
    // regime (N >> K^2-solve) at one-box scale.
    let ds = synth::alpha_like(scaled(60_000, 6_000), 200, 0);
    println!("N={} K={} (dense; stats-dominated like the paper's impl)", ds.n, ds.k);
    println!("   {:>5} {:>12} {:>10} {:>13} {:>12}", "P", "model time", "speedup", "stats/iter", "solve/iter");

    let iters = 5usize;
    let mut ps = Vec::new();
    let mut times = Vec::new();
    let mut t1 = 0.0f64;
    for p in [1usize, 2, 4, 8, 16, 48, 96, 240, 480] {
        let mut cfg = TrainConfig::default().with_options("LIN-EM-CLS").unwrap();
        cfg.workers = p;
        cfg.topology = Topology::Simulate;
        cfg.max_iters = iters;
        cfg.tol = 0.0; // fixed iteration count for clean scaling
        let out = pemsvm::coordinator::train(&ds, &cfg).unwrap();
        let t = modeled_sim_secs(&out, p, ds.k);
        let stats = out.metrics.total(pemsvm::metrics::Phase::LocalStats).as_secs_f64() / iters as f64;
        let solve = out.metrics.total(pemsvm::metrics::Phase::DrawMu).as_secs_f64() / iters as f64;
        if p == 1 {
            t1 = t;
        }
        println!("   {:>5} {:>11.3}s {:>9.2}x {:>12.4}s {:>11.4}s", p, t, t1 / t, stats, solve);
        ps.push(p as f64);
        times.push(t);
    }
    let slope = loglog_slope(&ps[..6], &times[..6]);
    println!("\n   log-log slope over P=1..48: {slope:.2} (ideal -1.0; paper: linear to 480)");

    // real threaded wall-clock on this box (informational)
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    println!("\n   real threads on this box ({cores} core(s)):");
    for p in [1usize, 2, 4] {
        let mut cfg = TrainConfig::default().with_options("LIN-EM-CLS").unwrap();
        cfg.workers = p;
        cfg.max_iters = iters;
        cfg.tol = 0.0;
        let t0 = std::time::Instant::now();
        let _ = pemsvm::coordinator::train(&ds, &cfg).unwrap();
        println!("   P={p}: {:.3}s wall", t0.elapsed().as_secs_f64());
    }
}
