//! Figure 3: effect of N on training time (alpha dataset), all solvers
//! single-threaded. Paper: LIN-CLS linear in N and much better than
//! PSVM (whose sqrt(N)-rank factorization makes it ~N^2); liblinear and
//! Pegasos also linear.

use pemsvm::baselines::{dcd, pegasos, psvm_lite};
use pemsvm::benchutil::{header, loglog_slope, scaled, time};
use pemsvm::config::TrainConfig;
use pemsvm::data::synth;

fn main() {
    header("Figure 3", "training time vs N, alpha dataset (single-threaded)");
    let k = 100usize;
    let ns: Vec<usize> = [5_000, 10_000, 20_000, 40_000, 80_000]
        .iter()
        .map(|&n| scaled(n, 1_000))
        .collect();
    println!("K={k}; fixed 10 EM iterations / solver-native stopping");
    println!("   {:>8} {:>11} {:>11} {:>11} {:>11}", "N", "LIN-EM-CLS", "PSVM", "LL-Dual", "Pegasos");

    let mut t_lin = Vec::new();
    let mut t_psvm = Vec::new();
    let mut t_dcd = Vec::new();
    let mut t_peg = Vec::new();
    for &n in &ns {
        let ds = synth::alpha_like(n, k, 0);
        let mut cfg = TrainConfig::default().with_options("LIN-EM-CLS").unwrap();
        cfg.workers = 1;
        cfg.max_iters = 10;
        cfg.tol = 0.0;
        let (a, _) = time(|| pemsvm::coordinator::train(&ds, &cfg).unwrap());
        let (b, _) = time(|| psvm_lite::train(&ds, &psvm_lite::PsvmLiteCfg { pg_iters: 50, ..Default::default() }));
        let (c, _) = time(|| dcd::train(&ds, &dcd::DcdCfg { max_epochs: 20, ..Default::default() }));
        let (d, _) = time(|| pegasos::train(&ds, &pegasos::PegasosCfg { epochs: 10, ..Default::default() }));
        println!("   {:>8} {:>10.2}s {:>10.2}s {:>10.2}s {:>10.2}s", n, a, b, c, d);
        t_lin.push(a);
        t_psvm.push(b);
        t_dcd.push(c);
        t_peg.push(d);
    }
    let nsf: Vec<f64> = ns.iter().map(|&n| n as f64).collect();
    println!("\n   scaling exponents (log-log slope vs N; paper: LIN/LL/Pegasos ~1, PSVM >1):");
    println!(
        "   LIN-EM-CLS {:.2}   PSVM {:.2}   LL-Dual {:.2}   Pegasos {:.2}",
        loglog_slope(&nsf, &t_lin),
        loglog_slope(&nsf, &t_psvm),
        loglog_slope(&nsf, &t_dcd),
        loglog_slope(&nsf, &t_peg)
    );
}
