//! Serving throughput: the batched parallel scorer (`serve::Scorer`)
//! vs the per-row `model::evaluate` loop, on an mnist-like MLT batch
//! and a CLS margin batch. The acceptance bar for the serving PR is
//! >= 2x at 4 workers on the mnist-like batch; results are recorded in
//! EXPERIMENTS.md (§Serving).
//!
//! `SCALE=0.2` shrinks the workload like the other benches (`--quick`
//! is the CI smoke preset). A `BENCH_serve.json` snapshot lands at the
//! repo root via [`benchutil::write_bench_json`].

use std::sync::Arc;

use pemsvm::benchutil::{header, scaled, time, write_bench_json};
use pemsvm::config::TaskKind;
use pemsvm::data::synth;
use pemsvm::linalg::Mat;
use pemsvm::model::Weights;
use pemsvm::rng::Pcg64;
use pemsvm::serve::{metric_of, ModelBody, ModelMeta, SavedModel, Scorer};

fn saved(task: TaskKind, body: Weights, k: usize, m: usize) -> Arc<SavedModel> {
    Arc::new(SavedModel::new(
        ModelMeta { task, k, m, lambda: 1.0, options: String::new(), verdict: None, legacy: false },
        ModelBody::Linear(body),
    ))
}

/// Run the worker sweep and return `(workers, rows_per_sec, speedup)`
/// per point for the JSON snapshot.
fn bench_rows(
    label: &str,
    n: usize,
    per_row_secs: f64,
    model: &Arc<SavedModel>,
    batch: &Arc<pemsvm::data::Dataset>,
) -> Vec<(usize, f64, f64)> {
    println!(
        "   {:<22} {:>9} {:>12.0} {:>10}",
        label,
        format!("{:.3}s", per_row_secs),
        n as f64 / per_row_secs,
        "1.00x"
    );
    let mut points = Vec::new();
    for workers in [1usize, 2, 4, 8] {
        let mut scorer = Scorer::new(workers);
        // one warmup dispatch so thread startup is off the clock
        scorer.score_batch(model, batch).unwrap();
        let (secs, out) = time(|| scorer.score_batch(model, batch).unwrap());
        println!(
            "   {:<22} {:>9} {:>12.0} {:>9.2}x",
            format!("scorer workers={workers}"),
            format!("{secs:.3}s"),
            n as f64 / secs,
            per_row_secs / secs
        );
        points.push((workers, n as f64 / secs, per_row_secs / secs));
        drop(out);
    }
    points
}

/// One section of the JSON snapshot.
fn section_json(n: usize, k: usize, per_row_secs: f64, points: &[(usize, f64, f64)]) -> String {
    let rows: Vec<String> = points
        .iter()
        .map(|(w, rps, sp)| {
            format!("{{\"workers\":{w},\"rows_per_sec\":{rps:.0},\"speedup\":{sp:.3}}}")
        })
        .collect();
    format!(
        "{{\"n\": {n}, \"k\": {k}, \"per_row_rows_per_sec\": {:.0}, \"scorer\": [{}]}}",
        n as f64 / per_row_secs,
        rows.join(",")
    )
}

fn main() {
    header("serve_throughput", "batched scorer vs per-row evaluate loop");

    // MLT: the paper's mnist-like shape — where the blockwise
    // [rows x K] multiply replaces the per-row per-class scalar loop
    let n = scaled(30_000, 2_000);
    let (k, m) = (256usize, 10usize);
    let ds = Arc::new(synth::mnist_like(n, k, m, 0));
    let mut g = Pcg64::new(1);
    let mut w = Mat::zeros(m, k);
    for x in w.data.iter_mut() {
        *x = g.next_f32() - 0.5;
    }
    let weights = Weights::PerClass(w);
    let (t_row, acc_row) = time(|| pemsvm::model::evaluate(&ds, &weights));
    let model = saved(TaskKind::Mlt, weights, k, m);
    println!("\nMLT mnist-like N={n} K={k} M={m}");
    println!("   {:<22} {:>9} {:>12} {:>10}", "path", "secs", "rows/s", "speedup");
    let mlt_points = bench_rows("per-row evaluate", n, t_row, &model, &ds);
    let mlt_json = section_json(n, k, t_row, &mlt_points);
    // the batched path must agree with the per-row loop bit-for-bit
    let scores = Scorer::new(4).score_batch(&model, &ds).unwrap().scores;
    assert_eq!(metric_of(TaskKind::Mlt, &ds.labels, &scores), acc_row);

    // CLS: one weight vector, sparse-dot bound
    let n = scaled(200_000, 10_000);
    let k = 128usize;
    let ds = Arc::new(synth::alpha_like(n, k, 2));
    let w: Vec<f32> = (0..k).map(|_| g.next_f32() - 0.5).collect();
    let weights = Weights::Single(w);
    let (t_row, acc_row) = time(|| pemsvm::model::evaluate(&ds, &weights));
    let model = saved(TaskKind::Cls, weights, k, 1);
    println!("\nCLS alpha-like N={n} K={k}");
    println!("   {:<22} {:>9} {:>12} {:>10}", "path", "secs", "rows/s", "speedup");
    let cls_points = bench_rows("per-row evaluate", n, t_row, &model, &ds);
    let cls_json = section_json(n, k, t_row, &cls_points);
    let scores = Scorer::new(4).score_batch(&model, &ds).unwrap().scores;
    assert_eq!(metric_of(TaskKind::Cls, &ds.labels, &scores), acc_row);

    let json = format!(
        "{{\n  \"bench\": \"serve_throughput\",\n  \"scale\": {},\n  \
         \"mlt\": {mlt_json},\n  \"cls\": {cls_json}\n}}\n",
        pemsvm::benchutil::scale()
    );
    write_bench_json("serve", &json);
}
