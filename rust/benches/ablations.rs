//! Ablations over the design choices DESIGN.md calls out:
//!   A. reduce topology: flat vs tree (Table 1's log P term)
//!   B. gamma clamp epsilon (§5.7.3 "treatment of singular gamma")
//!   C. MC burn-in (§5.13)
//!   D. low-rank KRN rank sweep (the paper's §4.3 open question,
//!      implemented in solver::lowrank)

use pemsvm::benchutil::{header, pair_merge_secs, scaled, time};
use pemsvm::config::{KernelCfg, TrainConfig};
use pemsvm::data::{synth, Dataset, Task};

fn main() {
    header("Ablations", "reduce topology / gamma clamp / burn-in / low-rank KRN");

    // A. reduce topology -------------------------------------------------
    println!("\nA. reduce: measured pair-merge and modeled round counts, K=512");
    let pm = pair_merge_secs(512);
    println!("   pair-merge(512) = {:.3} ms", pm * 1e3);
    for p in [8usize, 48, 480] {
        let flat = (p - 1) as f64 * pm;
        let tree = (p as f64).log2().ceil() * pm;
        println!("   P={p:>4}: flat {:.2} ms  tree {:.2} ms  ({:.1}x)", flat * 1e3, tree * 1e3, flat / tree);
    }

    // B. gamma clamp ------------------------------------------------------
    println!("\nB. gamma clamp eps (LIN-EM-CLS, alpha N=20k K=64): accuracy & iters");
    let ds = synth::alpha_like(scaled(20_000, 4_000), 64, 0);
    let (tr, te) = synth::split(&ds, 5);
    for eps in [1e-2f32, 1e-3, 1e-5, 1e-8] {
        let mut cfg = TrainConfig::default().with_options("LIN-EM-CLS").unwrap();
        cfg.eps_clamp = eps;
        cfg.workers = 4;
        cfg.max_iters = 80;
        let (t, out) = time(|| pemsvm::coordinator::train(&tr, &cfg).unwrap());
        let acc = pemsvm::model::evaluate(&te, &out.weights);
        println!(
            "   eps={eps:<8.0e} iters={:<3} J={:<12.1} test-acc={acc:.4}  ({t:.2}s)",
            out.iterations, out.objective
        );
    }

    // C. MC burn-in --------------------------------------------------------
    println!("\nC. MC burn-in (LIN-MC-CLS, 60 iters): final test accuracy");
    for burn in [0usize, 5, 10, 20] {
        let mut cfg = TrainConfig::default().with_options("LIN-MC-CLS").unwrap();
        cfg.burn_in = burn;
        cfg.workers = 4;
        cfg.max_iters = 60;
        cfg.tol = 0.0;
        let out = pemsvm::coordinator::train(&tr, &cfg).unwrap();
        let acc = pemsvm::model::evaluate(&te, &out.weights);
        println!("   burn-in={burn:<3} test-acc={acc:.4}");
    }

    // D. low-rank KRN -------------------------------------------------------
    println!("\nD. low-rank sampling KRN (paper §4.3 open question): rank sweep, rings N=600");
    let mut g = pemsvm::rng::Pcg64::new(7);
    let n = 600;
    let mut data = Vec::new();
    let mut labels = Vec::new();
    for _ in 0..n {
        let y: f32 = if g.next_f64() < 0.5 { 1.0 } else { -1.0 };
        let r = if y > 0.0 { 0.5 } else { 1.6 };
        let th = g.next_f64() * std::f64::consts::TAU;
        data.push(r * th.cos() as f32 + 0.05 * (g.next_f32() - 0.5));
        data.push(r * th.sin() as f32 + 0.05 * (g.next_f32() - 0.5));
        labels.push(y);
    }
    let rings = Dataset::dense(data, labels, 2, Task::Binary);
    let mut cfg = TrainConfig::default().with_options("KRN-EM-CLS").unwrap();
    cfg.lambda = 1e-2;
    cfg.kernel = KernelCfg::Gaussian { sigma: 0.5 };
    cfg.workers = 4;
    cfg.max_iters = 30;

    let (t_exact, out) = time(|| pemsvm::coordinator::train(&rings, &cfg).unwrap());
    let acc_exact = out.kernel_model.as_ref().unwrap().accuracy(&rings);
    println!("   exact KRN (N x N): acc={acc_exact:.4}  ({t_exact:.2}s)");
    for rank in [10usize, 25, 50, 100] {
        let (t, (model, _)) =
            time(|| pemsvm::solver::lowrank::train_lowrank_krn(&rings, &cfg, Some(rank)).unwrap());
        println!("   rank={rank:<4} acc={:.4}  ({t:.2}s)", model.accuracy(&rings));
    }
    println!("   (sqrt(N) = {:.0}; PSVM's budget)", (n as f64).sqrt());
}
