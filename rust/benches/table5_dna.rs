//! Table 5: LIN-EM-CLS vs the solver roster on the dna dataset.
//!
//! Paper: dna N = 2.5M / 25M rows, K = 800, sparse. Scaled for one box:
//! N = 100k ("subset") and 400k ("full") by default (SCALE multiplies).
//! PEMSVM rows use the cluster cost model for P = 48 / 480 (§DESIGN 6).

use pemsvm::baselines::{cutting_plane, dcd, pegasos, primal_newton, stream_dcd};
use pemsvm::benchutil::{header, modeled_sim_secs, scaled, time};
use pemsvm::config::{Topology, TrainConfig};
use pemsvm::data::synth;
use pemsvm::model::accuracy_cls;

fn pem_row(tr: &pemsvm::data::Dataset, te: &pemsvm::data::Dataset, p: usize) -> (f64, f64) {
    let mut cfg = TrainConfig::default().with_options("LIN-EM-CLS").unwrap();
    cfg.workers = p;
    cfg.topology = Topology::Simulate;
    cfg.max_iters = 60;
    let out = pemsvm::coordinator::train(tr, &cfg).unwrap();
    (modeled_sim_secs(&out, p, tr.k), accuracy_cls(te, out.weights.single()) * 100.0)
}

fn run_subset(n: usize, k: usize, full: bool) {
    let ds = synth::dna_like(n + n / 5, k, 0);
    let (tr, te) = synth::split(&ds, 6);
    println!(
        "\n-- {} training subset: N={} K={} density={:.4}",
        if full { "full" } else { "N-subset" },
        tr.n,
        tr.k,
        tr.density()
    );
    println!("   {:<16} {:>5} {:>10} {:>8}", "Solver", "P", "Train", "Acc.%");

    let lam = 1.0;
    if !full {
        // single-thread roster only on the subset (paper: they crash or
        // take hours on the full set)
        let (t, w) = time(|| {
            pegasos::train(&tr, &pegasos::PegasosCfg { lambda: lam, epochs: 15, ..Default::default() })
        });
        println!("   {:<16} {:>5} {:>9.2}s {:>8.2}", "Pegasos", 1, t, accuracy_cls(&te, &w) * 100.0);

        let (t, w) = time(|| {
            stream_dcd::train(&tr, &stream_dcd::StreamDcdCfg { lambda: lam, selective: true, ..Default::default() })
                .unwrap()
        });
        println!("   {:<16} {:>5} {:>9.2}s {:>8.2}", "SDB", 1, t, accuracy_cls(&te, &w) * 100.0);

        let (t, w) = time(|| {
            stream_dcd::train(&tr, &stream_dcd::StreamDcdCfg { lambda: lam, ..Default::default() }).unwrap()
        });
        println!("   {:<16} {:>5} {:>9.2}s {:>8.2}", "StreamSVM", 2, t, accuracy_cls(&te, &w) * 100.0);

        let (t, w) = time(|| cutting_plane::train(&tr, &cutting_plane::CuttingPlaneCfg { lambda: lam, ..Default::default() }));
        println!("   {:<16} {:>5} {:>9.2}s {:>8.2}", "SVMPerf", 1, t, accuracy_cls(&te, &w) * 100.0);

        let (t, w) = time(|| primal_newton::train(&tr, &primal_newton::PrimalNewtonCfg { lambda: lam, ..Default::default() }));
        println!("   {:<16} {:>5} {:>9.2}s {:>8.2}", "LL-Primal", 1, t, accuracy_cls(&te, &w) * 100.0);

        let (t, out) = time(|| dcd::train(&tr, &dcd::DcdCfg { lambda: lam, ..Default::default() }));
        println!("   {:<16} {:>5} {:>9.2}s {:>8.2}", "LL-Dual", 1, t, accuracy_cls(&te, &out.w) * 100.0);
    } else {
        let (t, out) = time(|| dcd::train(&tr, &dcd::DcdCfg { lambda: lam, ..Default::default() }));
        println!("   {:<16} {:>5} {:>9.2}s {:>8.2}", "LL-Dual", 1, t, accuracy_cls(&te, &out.w) * 100.0);
        let (t, w) = time(|| {
            stream_dcd::train(&tr, &stream_dcd::StreamDcdCfg { lambda: lam, ..Default::default() }).unwrap()
        });
        println!("   {:<16} {:>5} {:>9.2}s {:>8.2}", "StreamSVM", 2, t, accuracy_cls(&te, &w) * 100.0);
    }

    for p in [48usize, 480] {
        let (t, acc) = pem_row(&tr, &te, p);
        println!("   {:<16} {:>5} {:>9.2}s {:>8.2}  (cluster cost model)", "LIN-EM-CLS", p, t, acc);
    }
}

fn main() {
    header("Table 5", "performance on dna dataset (dna-like synthetic)");
    let k = 800;
    run_subset(scaled(100_000, 5_000), k, false);
    run_subset(scaled(400_000, 20_000), k, true);
}
