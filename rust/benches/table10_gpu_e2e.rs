//! Table 10: accelerator end-to-end on the alpha dataset (C = 1).
//! Paper: LL-Dual 44.8s/78.16%; LIN-EM-CLS 1 core 78.9s (+30.4s load);
//! LIN-EM-CLS 2048 GPU cores 6.1s learn (+29.2s load) — data load
//! dominates the accelerated run.

use pemsvm::baselines::dcd;
use pemsvm::benchutil::{header, scaled, time};
use pemsvm::config::{BackendKind, TrainConfig};
use pemsvm::data::{libsvm, synth, Task};
use pemsvm::model::accuracy_cls;

fn main() {
    header("Table 10", "accelerator end-to-end on alpha dataset, C=1");
    let (n, k) = (scaled(100_000, 10_000), 500usize);
    let dir = std::env::temp_dir().join("pemsvm_t10");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("alpha.svm");
    let ds0 = synth::alpha_like(n + n / 5, k, 0);
    let (tr0, te) = synth::split(&ds0, 6);
    libsvm::save(&tr0, &path).unwrap();
    drop((ds0, tr0));
    println!("N={} K={k} on disk: {}", n, path.display());
    println!("   {:<16} {:<22} {:>9} {:>9} {:>8}", "Solver", "Hardware", "Load", "Learn", "Acc.%");

    let lam = 2.0; // C = 2/lam = 1

    let (t_load, tr) = time(|| libsvm::load(&path, Task::Binary, 1).unwrap());
    let (t_dcd, out) = time(|| dcd::train(&tr, &dcd::DcdCfg { lambda: lam, ..Default::default() }));
    println!(
        "   {:<16} {:<22} {:>8.2}s {:>8.2}s {:>8.2}",
        "LL-Dual", "1 CPU core", t_load, t_dcd, accuracy_cls(&te, &out.w) * 100.0
    );

    let mut cfg = TrainConfig::default().with_options("LIN-EM-CLS").unwrap();
    cfg.lambda = lam;
    cfg.workers = 1;
    cfg.max_iters = 40;
    let (t_pem, out) = time(|| pemsvm::coordinator::train(&tr, &cfg).unwrap());
    println!(
        "   {:<16} {:<22} {:>8.2}s {:>8.2}s {:>8.2}",
        "LIN-EM-CLS",
        "1 CPU core",
        t_load,
        t_pem,
        pemsvm::model::evaluate(&te, &out.weights) * 100.0
    );

    if std::path::Path::new("artifacts/manifest.json").exists() {
        for (label, pallas) in [("XLA graph (Pallas)", true), ("XLA graph (dot)", false)] {
            let mut cfg = cfg.clone();
            cfg.backend = BackendKind::Xla;
            cfg.xla_use_pallas = pallas;
            let (t_x, out) = time(|| pemsvm::coordinator::train(&tr, &cfg).unwrap());
            println!(
                "   {:<16} {:<22} {:>8.2}s {:>8.2}s {:>8.2}",
                "LIN-EM-CLS",
                label,
                t_load,
                t_x,
                pemsvm::model::evaluate(&te, &out.weights) * 100.0
            );
        }
    } else {
        println!("   (artifacts missing -- run `make artifacts` for the XLA rows)");
    }
    println!("\n   paper shape: accelerated learn time falls well under the");
    println!("   1-core learn time and data-load begins to dominate.");
}
