//! Table 8: Crammer-Singer multiclass on mnist8m (mnist-like synthetic).
//! Paper: N = 200k subset and 4M full, K = 784, M = 10. LL-CS wins at
//! small core counts; LIN-MC-MLT scales 48 -> 480 cores by ~7.6x.
//! SVMMulticlass is substituted by LL-CS at a tight tolerance (the
//! cutting-plane CS solver is not implemented; DESIGN.md §6).

use pemsvm::baselines::cs_dcd;
use pemsvm::benchutil::{header, modeled_sim_secs, scaled, time};
use pemsvm::config::{Topology, TrainConfig};
use pemsvm::data::synth;
use pemsvm::model::accuracy_mlt;

fn pem_row(tr: &pemsvm::data::Dataset, te: &pemsvm::data::Dataset, m: usize, p: usize) -> (f64, f64) {
    let mut cfg = TrainConfig::default().with_options("LIN-MC-MLT").unwrap();
    cfg.num_classes = m;
    cfg.workers = p;
    cfg.topology = Topology::Simulate;
    cfg.burn_in = 5;
    cfg.max_iters = 8;
    let out = pemsvm::coordinator::train(tr, &cfg).unwrap();
    (modeled_sim_secs(&out, p, tr.k), pemsvm::model::evaluate(te, &out.weights) * 100.0)
}

fn run(n: usize, label: &str) {
    let (k, m) = (128usize, 10usize);
    let ds = synth::mnist_like(n + n / 5, k, m, 0);
    let (tr, te) = synth::split(&ds, 6);
    println!("\n-- {label}: N={} K={k} M={m}", tr.n);
    println!("   {:<16} {:>5} {:>10} {:>8}", "Solver", "Cores", "Train", "Acc.%");

    let (t, w) = time(|| cs_dcd::train(&tr, m, &cs_dcd::CsDcdCfg { lambda: 1.0, ..Default::default() }));
    println!("   {:<16} {:>5} {:>9.2}s {:>8.2}", "LL-CS", 1, t, accuracy_mlt(&te, &w) * 100.0);

    let (t, w) = time(|| {
        cs_dcd::train(&tr, m, &cs_dcd::CsDcdCfg { lambda: 1.0, tol: 1e-4, max_epochs: 150, ..Default::default() })
    });
    println!("   {:<16} {:>5} {:>9.2}s {:>8.2}  (LL-CS tight-tol substitute)", "SVMMult*", 1, t, accuracy_mlt(&te, &w) * 100.0);

    for p in [48usize, 480] {
        let (t, acc) = pem_row(&tr, &te, m, p);
        println!("   {:<16} {:>5} {:>9.2}s {:>8.2}  (cluster cost model)", "LIN-MC-MLT", p, t, acc);
    }
}

fn main() {
    header("Table 8", "Crammer-Singer on mnist8m dataset");
    run(scaled(30_000, 4_000), "N-subset");
    run(scaled(100_000, 12_000), "full");
}
