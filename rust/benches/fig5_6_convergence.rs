//! Figures 5 & 6: convergence of the objective and the test accuracy
//! for EM vs MC on the dna N-subset (C = 1e-5 in the paper; we use the
//! equivalent lambda). MC is reported both raw (burn-in 0) and with the
//! §5.13 burn-in-10 running average.

use pemsvm::benchutil::{header, scaled};
use pemsvm::config::TrainConfig;
use pemsvm::data::synth;

fn run(options: &str, burn_in: usize, iters: usize, tr: &pemsvm::data::Dataset, te: &pemsvm::data::Dataset) -> Vec<(f64, f64)> {
    let mut cfg = TrainConfig::default().with_options(options).unwrap();
    cfg.workers = 4;
    cfg.burn_in = burn_in;
    cfg.max_iters = iters;
    cfg.tol = 0.0; // run the full horizon for the curves
    let out = pemsvm::coordinator::train_full(tr, Some(te), &cfg).unwrap();
    out.history.iter().map(|h| (h.objective, h.test_metric.unwrap_or(f64::NAN))).collect()
}

fn main() {
    header("Figures 5+6", "convergence of objective / accuracy, dna subset, EM vs MC");
    let ds = synth::dna_like(scaled(50_000, 8_000), 800, 0);
    let (tr, te) = synth::split(&ds, 6);
    println!("N={} K={}", tr.n, tr.k);

    let iters = 100;
    let em = run("LIN-EM-CLS", 0, iters, &tr, &te);
    let mc0 = run("LIN-MC-CLS", 0, iters, &tr, &te);
    let mc10 = run("LIN-MC-CLS", 10, iters, &tr, &te);

    println!("\n   iter   J(EM)        J(MC)        acc(EM)  acc(MC,b0)  acc(MC,b10)");
    for i in (0..iters).step_by(5) {
        let je = em.get(i).map(|x| x.0).unwrap_or(f64::NAN);
        let jm = mc0.get(i).map(|x| x.0).unwrap_or(f64::NAN);
        let ae = em.get(i).map(|x| x.1).unwrap_or(f64::NAN);
        let a0 = mc0.get(i).map(|x| x.1).unwrap_or(f64::NAN);
        let a10 = mc10.get(i).map(|x| x.1).unwrap_or(f64::NAN);
        println!("   {i:>4}   {je:<12.1} {jm:<12.1} {ae:<8.4} {a0:<11.4} {a10:<8.4}");
    }

    // paper claims: EM converges in 40-60 iters; MC objective converges
    // more slowly; late-horizon MC accuracy can edge out EM
    let em_converged_at = em
        .windows(2)
        .position(|w| (w[0].0 - w[1].0).abs() < 1e-3 * tr.n as f64)
        .map(|i| i + 1)
        .unwrap_or(iters);
    println!("\n   EM objective converged (|dJ| < 0.001N) at iter {em_converged_at} (paper: 40-60)");
    let last_em = em.last().unwrap();
    let last_mc = mc10.last().unwrap();
    println!(
        "   final test acc: EM {:.4} vs MC(avg) {:.4} (paper: MC slightly higher after 100 iters)",
        last_em.1, last_mc.1
    );
}
