"""L2: the per-iteration compute graphs of the data-augmentation SVM.

Every function here is a pure jax function over fixed-shape f32 arrays,
AOT-lowered by `aot.py` to one HLO-text artifact per shape family and
executed from Rust through PJRT. Together they implement the paper's
Eqs. (4)-(10) (linear binary), (24)-(28) (SVR), (36)-(39)
(Crammer-Singer), and the map-reduce split of §4.1:

  worker step  : gamma update (EM argmax / MC inverse-Gaussian draw)
                 + local statistics (Sigma^p, mu^p)  + local objective
  master solve : Sigma^-1 = lam*R + sum_p Sigma^p ;  EM w = Sigma b,
                 MC w ~ N(Sigma b, Sigma)

Conventions shared with the Rust side (runtime/ and backend/xla.rs):
  * CHUNK rows per call; `mask` is 1.0 for real rows, 0.0 for padding.
  * scalars travel as shape-[1] f32 (or i32) arrays — the `xla` crate's
    `Literal::vec1` covers those without a scalar-literal code path.
  * MC randomness (uniforms/normals) is *injected* by the Rust PCG64
    streams so runs are deterministic per (seed, worker) for both
    backends.
  * all functions return tuples; aot lowers with return_tuple=True.

The kernel SVM (KRN) variant reuses the linear step graphs verbatim
with x := rows of the Gram matrix and w := the dual vector omega
(problem (15) has the same hinge structure), and the master solve with
R := Gram instead of I.
"""

import jax
import jax.numpy as jnp

from .kernels.ref import inv_gauss_ref
from .kernels.weighted_gram import weighted_stats


def _margin_stats(x, y, mask, w):
    """Shared pieces of the binary hinge steps."""
    scores = x @ w
    margin = 1.0 - y * scores  # 1 - y w.x  (paper's 1 - y_d w^T x_d)
    hinge = jnp.maximum(margin, 0.0)
    obj = jnp.sum(hinge * mask, keepdims=True)
    err = jnp.sum(mask * (y * scores <= 0.0), keepdims=True)
    return margin, obj, err


def lin_step_em(x, y, mask, w, eps):
    """EM E-step + local stats, linear binary SVM (Eqs. 9, 40).

    gamma_d = max(|1 - y_d w.x_d|, eps)   (§5.7.3 clamping)
    a_d = 1/gamma_d, b_d = y_d (1 + 1/gamma_d)
    """
    margin, obj, err = _margin_stats(x, y, mask, w)
    inv_g = mask / jnp.maximum(jnp.abs(margin), eps[0])
    a = inv_g
    b = y * (mask + inv_g)
    s, m = weighted_stats(x, a, b)
    return s, m, obj, err


def lin_step_em_jnp(x, y, mask, w, eps):
    """Ablation variant of `lin_step_em`: identical math but the local
    statistics go through XLA's own fused dot (`weighted_stats_ref`)
    instead of the Pallas kernel. Used by the Table-9 bench to separate
    "offload to an accelerator graph" from "the Pallas MXU tiling" —
    on the CPU PJRT backend the interpret-mode Pallas grid becomes a
    while-loop, so this is the fair CPU baseline for it.
    """
    from .kernels.ref import weighted_stats_ref

    margin, obj, err = _margin_stats(x, y, mask, w)
    inv_g = mask / jnp.maximum(jnp.abs(margin), eps[0])
    a = inv_g
    b = y * (mask + inv_g)
    s, m = weighted_stats_ref(x, a, b)
    return s, m, obj, err


def lin_step_mc(x, y, mask, w, eps, u, z):
    """Gibbs draw of gamma^-1 ~ IG(|1 - y w.x|^-1, 1) + local stats (Eq. 5)."""
    margin, obj, err = _margin_stats(x, y, mask, w)
    mu_ig = 1.0 / jnp.maximum(jnp.abs(margin), eps[0])
    inv_g = inv_gauss_ref(mu_ig, u, z)
    inv_g = jnp.minimum(inv_g, 1.0 / eps[0])  # clamp gamma >= eps
    a = mask * inv_g
    b = y * (mask + a)
    s, m = weighted_stats(x, a, b)
    return s, m, obj, err


def svr_step_em(x, y, mask, w, eps, eps_ins):
    """EM step for epsilon-insensitive SVR (Eqs. 25-28).

    gamma_d = |y - w.x - eps_ins|, omega_d = |y - w.x + eps_ins|
    a_d = 1/gamma + 1/omega, b_d = (y - eps_ins)/gamma + (y + eps_ins)/omega
    """
    r = y - x @ w
    loss = jnp.sum(mask * jnp.maximum(jnp.abs(r) - eps_ins[0], 0.0), keepdims=True)
    sq = jnp.sum(mask * r * r, keepdims=True)  # for RMSE reporting
    inv_g = mask / jnp.maximum(jnp.abs(r - eps_ins[0]), eps[0])
    inv_o = mask / jnp.maximum(jnp.abs(r + eps_ins[0]), eps[0])
    a = inv_g + inv_o
    b = (y - eps_ins[0]) * inv_g + (y + eps_ins[0]) * inv_o
    s, m = weighted_stats(x, a, b)
    return s, m, loss, sq


def svr_step_mc(x, y, mask, w, eps, eps_ins, u1, z1, u2, z2):
    """Gibbs draws for the double scale mixture (Lemma 3, Eqs. 25-26)."""
    r = y - x @ w
    loss = jnp.sum(mask * jnp.maximum(jnp.abs(r) - eps_ins[0], 0.0), keepdims=True)
    sq = jnp.sum(mask * r * r, keepdims=True)
    cap = 1.0 / eps[0]
    mu_g = 1.0 / jnp.maximum(jnp.abs(r - eps_ins[0]), eps[0])
    mu_o = 1.0 / jnp.maximum(jnp.abs(r + eps_ins[0]), eps[0])
    inv_g = mask * jnp.minimum(inv_gauss_ref(mu_g, u1, z1), cap)
    inv_o = mask * jnp.minimum(inv_gauss_ref(mu_o, u2, z2), cap)
    a = inv_g + inv_o
    b = (y - eps_ins[0]) * inv_g + (y + eps_ins[0]) * inv_o
    s, m = weighted_stats(x, a, b)
    return s, m, loss, sq


def _mlt_common(x, yhot, mask, w_all, yidx):
    """Shared pieces of the Crammer-Singer per-class step (§3.3).

    scores[d, y'] = w_y'.x_d ; aug = scores + Delta (0/1 cost);
    zeta_d(y)  = max_{y' != y} aug[d, y']
    rho_d^y    = zeta_d(y) - Delta_d(y)
    beta_d^y   = +1 if y == y_d else -1
    """
    m_cls = w_all.shape[0]
    scores = x @ w_all.T  # [CHUNK, M]
    delta = 1.0 - yhot  # Delta_d(y') with 0/1 cost
    aug = scores + delta
    is_y = (jnp.arange(m_cls) == yidx[0]).astype(x.dtype)  # one-hot of target class
    neg_inf = jnp.float32(-1e30)
    zeta = jnp.max(jnp.where(is_y[None, :] > 0, neg_inf, aug), axis=1)
    delta_y = 1.0 - (yhot @ is_y)  # Delta_d(y) for the target class
    rho = zeta - delta_y
    beta = 2.0 * (yhot @ is_y) - 1.0
    w_y = is_y @ w_all  # row yidx of W without gather
    margin = rho - x @ w_y
    # CS loss / errors at the current W (identical for every target class;
    # the driver reads them from the class-0 call only).
    loss = jnp.sum(mask * (jnp.max(aug, axis=1) - jnp.sum(yhot * scores, axis=1)), keepdims=True)
    err = jnp.sum(
        mask * (jnp.argmax(scores, axis=1) != jnp.argmax(yhot, axis=1)), keepdims=True
    )
    return rho, beta, margin, loss, err


def mlt_step_em(x, yhot, mask, w_all, yidx, eps):
    """EM step for class block w_y of the Crammer-Singer model (Eqs. 38-39)."""
    rho, beta, margin, loss, err = _mlt_common(x, yhot, mask, w_all, yidx)
    inv_g = mask / jnp.maximum(jnp.abs(margin), eps[0])
    a = inv_g
    b = mask * (rho * inv_g + beta)
    s, m = weighted_stats(x, a, b)
    return s, m, loss, err


def mlt_step_mc(x, yhot, mask, w_all, yidx, eps, u, z):
    """Gibbs draw of gamma_{yd}^-1 ~ IG(|rho - w_y.x|^-1, 1) (Eq. 36)."""
    rho, beta, margin, loss, err = _mlt_common(x, yhot, mask, w_all, yidx)
    mu_ig = 1.0 / jnp.maximum(jnp.abs(margin), eps[0])
    inv_g = mask * jnp.minimum(inv_gauss_ref(mu_ig, u, z), 1.0 / eps[0])
    a = inv_g
    b = mask * (rho * inv_g + beta)
    s, m = weighted_stats(x, a, b)
    return s, m, loss, err


# --- pure-HLO dense solves -------------------------------------------------
#
# jnp.linalg.cholesky / scipy cho_solve lower to LAPACK *custom-calls* with
# the typed-FFI API, which the xla_extension 0.5.1 the rust `xla` crate
# links cannot compile ("Unknown custom-call API version ... TYPED_FFI").
# The master solve therefore carries its own loop-based factorization that
# lowers to plain HLO (while/dynamic-slice/dot), same O(K^3)/O(K^2) costs.


def cholesky_hlo(a):
    """Lower Cholesky factor of SPD `a` via a fori_loop of rank-1 column
    updates — emits only core HLO ops."""
    k = a.shape[0]
    idx = jnp.arange(k)

    def body(j, l):
        row_j = jnp.take(l, j, axis=0)  # row j of the partial factor
        col = jnp.take(a, j, axis=1) - l @ row_j
        d = jnp.sqrt(jnp.maximum(jnp.take(col, j), 1e-30))
        newcol = jnp.where(idx == j, d, jnp.where(idx > j, col / d, 0.0))
        return l.at[:, j].set(newcol)

    return jax.lax.fori_loop(0, k, body, jnp.zeros_like(a))


def solve_lower_hlo(l, b):
    """y with L y = b (forward substitution, masked-dot loop)."""
    k = l.shape[0]
    idx = jnp.arange(k)

    def body(i, y):
        row = jnp.take(l, i, axis=0)
        s = jnp.sum(jnp.where(idx < i, row * y, 0.0))
        return y.at[i].set((jnp.take(b, i) - s) / jnp.take(row, i))

    return jax.lax.fori_loop(0, k, body, jnp.zeros_like(b))


def solve_upper_hlo(l, b):
    """x with L^T x = b (back substitution over columns of L)."""
    k = l.shape[0]
    idx = jnp.arange(k)

    def body(t, x):
        i = k - 1 - t
        col = jnp.take(l, i, axis=1)
        s = jnp.sum(jnp.where(idx > i, col * x, 0.0))
        return x.at[i].set((jnp.take(b, i) - s) / jnp.take(col, i))

    return jax.lax.fori_loop(0, k, body, jnp.zeros_like(b))


def master_solve_em(s_sum, m_sum, reg, lam):
    """w = (lam*R + sum_p Sigma^p)^-1 (sum_p mu^p)  — Eq. (6) M-step."""
    a = lam[0] * reg + s_sum
    a = 0.5 * (a + a.T)  # symmetrize fp drift from the tree reduce
    l_fac = cholesky_hlo(a)
    w = solve_upper_hlo(l_fac, solve_lower_hlo(l_fac, m_sum))
    return (w,)


def master_solve_mc(s_sum, m_sum, reg, lam, z):
    """Posterior draw w ~ N(mu, Sigma), Sigma^-1 = lam*R + sum Sigma^p = L L^T.

    mu = Sigma b via Cholesky; the sample adds L^-T z with z ~ N(0, I),
    since Cov[L^-T z] = L^-T L^-1 = (L L^T)^-1 = Sigma.
    """
    a = lam[0] * reg + s_sum
    a = 0.5 * (a + a.T)
    l_fac = cholesky_hlo(a)
    mu = solve_upper_hlo(l_fac, solve_lower_hlo(l_fac, m_sum))
    w = mu + solve_upper_hlo(l_fac, z)
    return (w,)


def predict(x, w):
    """Binary / SVR scores for a chunk."""
    return (x @ w,)


def predict_mlt(x, w_all):
    """Crammer-Singer class scores for a chunk."""
    return (x @ w_all.T,)
