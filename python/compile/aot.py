"""AOT-lower every L2 graph to an HLO-text artifact + manifest.

Interchange format is HLO *text*, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (what the `xla` 0.1.6 rust crate links) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Shape families (DESIGN.md §3): features padded to K in {16, 64, 256,
1024}, CHUNK = 512 rows per worker-step call, M = 10 classes for the
Crammer-Singer steps.  KRN reuses the lin_step artifacts with K := N.

Usage:  python -m compile.aot [--out-dir ../artifacts] [--only lin_em]
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

K_FAMILY = (16, 64, 256, 1024)
CHUNK = 512
M_CLASSES = 10


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def to_hlo_text(fn, specs):
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def artifact_specs():
    """Yield (name, fn, arg_specs, meta) for every artifact."""
    for k in K_FAMILY:
        x, y, mask, w, eps = f32(CHUNK, k), f32(CHUNK), f32(CHUNK), f32(k), f32(1)
        u, z = f32(CHUNK), f32(CHUNK)
        meta = {"k": k, "chunk": CHUNK, "m": 0}

        yield (
            f"lin_em_step_k{k}",
            model.lin_step_em,
            (x, y, mask, w, eps),
            {**meta, "kind": "lin_step", "variant": "em", "num_outputs": 4},
        )
        yield (
            f"lin_mc_step_k{k}",
            model.lin_step_mc,
            (x, y, mask, w, eps, u, z),
            {**meta, "kind": "lin_step", "variant": "mc", "num_outputs": 4},
        )
        # ablation twin of lin_em_step: XLA-native dot instead of the
        # Pallas kernel (DESIGN.md ablations; Table 9 bench)
        yield (
            f"lin_em_step_jnp_k{k}",
            model.lin_step_em_jnp,
            (x, y, mask, w, eps),
            {**meta, "kind": "lin_step_jnp", "variant": "em", "num_outputs": 4},
        )
        yield (
            f"svr_em_step_k{k}",
            model.svr_step_em,
            (x, y, mask, w, eps, f32(1)),
            {**meta, "kind": "svr_step", "variant": "em", "num_outputs": 4},
        )
        yield (
            f"svr_mc_step_k{k}",
            model.svr_step_mc,
            (x, y, mask, w, eps, f32(1), u, z, u, z),
            {**meta, "kind": "svr_step", "variant": "mc", "num_outputs": 4},
        )

        m = M_CLASSES
        yhot, w_all, yidx = f32(CHUNK, m), f32(m, k), i32(1)
        mmeta = {**meta, "m": m}
        yield (
            f"mlt_em_step_k{k}_m{m}",
            model.mlt_step_em,
            (x, yhot, mask, w_all, yidx, eps),
            {**mmeta, "kind": "mlt_step", "variant": "em", "num_outputs": 4},
        )
        yield (
            f"mlt_mc_step_k{k}_m{m}",
            model.mlt_step_mc,
            (x, yhot, mask, w_all, yidx, eps, u, z),
            {**mmeta, "kind": "mlt_step", "variant": "mc", "num_outputs": 4},
        )

        s_sum, m_sum, reg, lam, zk = f32(k, k), f32(k), f32(k, k), f32(1), f32(k)
        yield (
            f"solve_em_k{k}",
            model.master_solve_em,
            (s_sum, m_sum, reg, lam),
            {**meta, "kind": "solve", "variant": "em", "num_outputs": 1},
        )
        yield (
            f"solve_mc_k{k}",
            model.master_solve_mc,
            (s_sum, m_sum, reg, lam, zk),
            {**meta, "kind": "solve", "variant": "mc", "num_outputs": 1},
        )

        yield (
            f"predict_k{k}",
            model.predict,
            (x, w),
            {**meta, "kind": "predict", "variant": "em", "num_outputs": 1},
        )
        yield (
            f"predict_mlt_k{k}_m{m}",
            model.predict_mlt,
            (x, w_all),
            {**mmeta, "kind": "predict_mlt", "variant": "em", "num_outputs": 1},
        )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--only", default=None, help="substring filter on artifact names")
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)

    manifest = {"chunk": CHUNK, "k_family": list(K_FAMILY), "m_classes": M_CLASSES, "artifacts": []}
    for name, fn, specs, meta in artifact_specs():
        if args.only and args.only not in name:
            continue
        text = to_hlo_text(fn, specs)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entry = {
            "name": name,
            "file": fname,
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
            "inputs": [
                {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
            ],
            **meta,
        }
        manifest["artifacts"].append(entry)
        print(f"  {name:28s} {len(text):>9d} chars")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(manifest['artifacts'])} artifacts to {out_dir}")


if __name__ == "__main__":
    main()
