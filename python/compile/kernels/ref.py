"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this
package has a reference here, and pytest asserts allclose between the
two over a hypothesis-driven sweep of shapes.
"""

import jax.numpy as jnp


def weighted_gram_ref(x, a):
    """S = X^T diag(a) X.

    x: [N, K] float, a: [N] float (per-row weights, 0 for masked rows).
    Returns [K, K].
    """
    return (x * a[:, None]).T @ x


def weighted_stats_ref(x, a, b):
    """Fused local statistics of the paper's Eq. (40).

    S = X^T diag(a) X     (the Sigma^p partial)
    m = X^T b             (the mu^p partial)
    """
    return (x * a[:, None]).T @ x, x.T @ b


def inv_gauss_ref(mu, u, z):
    """Michael-Schucany-Haas inverse-Gaussian sampler, IG(mu, lam=1).

    mu: [N] mean, u: [N] uniforms in (0,1), z: [N] standard normals.
    Returns [N] samples. Vectorized transformation method; the Rust
    `rng::invgauss` implements the same math so the native and XLA
    backends agree per seed (to f32 tolerance).
    """
    y = z * z
    x = mu + 0.5 * mu * mu * y - 0.5 * mu * jnp.sqrt(4.0 * mu * y + (mu * y) ** 2)
    x = jnp.maximum(x, 1e-30)  # guard fp cancellation for tiny mu*y
    return jnp.where(u <= mu / (mu + x), x, mu * mu / x)
