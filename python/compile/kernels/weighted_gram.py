"""L1 Pallas kernel: fused weighted-Gram local statistics.

The paper's rate-limiting step (§5.14) is

    Sigma^p = sum_d (1/gamma_d) x_d x_d^T  =  X^T diag(a) X
    mu^p    = sum_d b_d x_d                =  X^T b

Its GPU implementation partitions rows over OpenCL compute units with
per-unit local-memory accumulators and a second reduce kernel.  On TPU
the outer-product sum *is* a matmul, so we tile it for the MXU instead
(DESIGN.md §Hardware-Adaptation):

  grid = (K/bk, K/bk, N/bn); step (i, j, n) contracts the row-block n of
  (diag(a) X) restricted to feature-block i against the row-block n of X
  restricted to feature-block j, accumulating into the (i, j) output
  tile resident in VMEM.  The n-axis is the innermost grid dimension, so
  each output tile is initialized once (@pl.when n == 0) and revisited —
  Pallas's analogue of the paper's two-stage GPU reduction, minus the
  second kernel.

`mu^p` is fused: the j == 0 column of the grid additionally contracts
x-block-i against b, amortizing the X reload the paper's separate
matvec pass would pay.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; real-TPU numbers are estimated analytically in DESIGN.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 256
DEFAULT_BLOCK_K = 128


def _stats_kernel(x_i_ref, x_j_ref, a_ref, b_ref, s_ref, m_ref):
    """One (i, j, n) grid step. See module docstring for the schedule."""
    n = pl.program_id(2)
    j = pl.program_id(1)

    x_i = x_i_ref[...]  # [bn, bk] rows of X, feature block i
    x_j = x_j_ref[...]  # [bn, bk] rows of X, feature block j
    a = a_ref[...]  # [bn]    per-row weights (0 => masked row)

    @pl.when(n == 0)
    def _init_s():
        s_ref[...] = jnp.zeros_like(s_ref)

    # (a * x_i)^T @ x_j : contraction over the bn row axis feeds the MXU
    # with a [bk, bn] x [bn, bk] tile product (f32 accumulate).
    s_ref[...] += jnp.dot(
        (x_i * a[:, None]).T, x_j, preferred_element_type=jnp.float32
    )

    @pl.when(jnp.logical_and(n == 0, j == 0))
    def _init_m():
        m_ref[...] = jnp.zeros_like(m_ref)

    @pl.when(j == 0)
    def _acc_m():
        m_ref[...] += x_i.T @ b_ref[...]


@functools.partial(jax.jit, static_argnames=("block_n", "block_k"))
def weighted_stats(x, a, b, *, block_n=DEFAULT_BLOCK_N, block_k=DEFAULT_BLOCK_K):
    """Fused (Sigma^p, mu^p) = (X^T diag(a) X, X^T b).

    x: [N, K] f32, a: [N] f32, b: [N] f32 with N % bn == 0, K % bk == 0
    (the AOT artifact family guarantees this; callers pad).
    Returns ([K, K], [K]).
    """
    n_rows, k = x.shape
    bn = min(block_n, n_rows)
    bk = min(block_k, k)
    if n_rows % bn or k % bk:
        raise ValueError(f"shape ({n_rows},{k}) not divisible by blocks ({bn},{bk})")
    grid = (k // bk, k // bk, n_rows // bn)
    return pl.pallas_call(
        _stats_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j, n: (n, i)),  # x_i
            pl.BlockSpec((bn, bk), lambda i, j, n: (n, j)),  # x_j
            pl.BlockSpec((bn,), lambda i, j, n: (n,)),  # a
            pl.BlockSpec((bn,), lambda i, j, n: (n,)),  # b
        ],
        out_specs=[
            pl.BlockSpec((bk, bk), lambda i, j, n: (i, j)),  # S
            pl.BlockSpec((bk,), lambda i, j, n: (i,)),  # m
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, k), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
        ],
        interpret=True,
    )(x, x, a, b)  # x passed twice: once per feature-block view (i and j)


def weighted_gram(x, a, **kw):
    """S = X^T diag(a) X via the fused kernel (b = 0)."""
    s, _ = weighted_stats(x, a, jnp.zeros(x.shape[0], x.dtype), **kw)
    return s
