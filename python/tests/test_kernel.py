"""L1 correctness: the Pallas weighted-stats kernel vs the jnp oracle.

hypothesis sweeps shapes (and block shapes) under the divisibility
contract the AOT shape family guarantees; assert_allclose at f32
tolerances scaled by the contraction length.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import weighted_gram_ref, weighted_stats_ref
from compile.kernels.weighted_gram import weighted_gram, weighted_stats

# shapes satisfying N % min(block_n, N) == 0, K % min(block_k, K) == 0
NS = [32, 64, 128, 256, 512, 768]
KS = [1, 3, 8, 16, 33, 64, 100, 128, 256, 384]


def _rand(rng, n, k):
    x = rng.standard_normal((n, k)).astype(np.float32)
    a = rng.uniform(0.0, 5.0, n).astype(np.float32)
    b = rng.standard_normal(n).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(a), jnp.asarray(b)


def _tol(n):
    # f32 accumulation error grows ~sqrt(N) * eps * |summand|
    return dict(rtol=3e-4, atol=3e-3 * np.sqrt(n / 256.0))


@settings(max_examples=40, deadline=None)
@given(n=st.sampled_from(NS), k=st.sampled_from(KS), seed=st.integers(0, 2**31 - 1))
def test_weighted_stats_matches_ref(n, k, seed):
    x, a, b = _rand(np.random.default_rng(seed), n, k)
    s, m = weighted_stats(x, a, b)
    sr, mr = weighted_stats_ref(x, a, b)
    np.testing.assert_allclose(s, sr, **_tol(n))
    np.testing.assert_allclose(m, mr, **_tol(n))


@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([256, 512]),
    k=st.sampled_from([64, 128, 256]),
    bn=st.sampled_from([64, 128, 256]),
    bk=st.sampled_from([32, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_block_shape_invariance(n, k, bn, bk, seed):
    """Any legal (bn, bk) tiling computes the same statistics."""
    x, a, b = _rand(np.random.default_rng(seed), n, k)
    s, m = weighted_stats(x, a, b, block_n=bn, block_k=bk)
    sr, mr = weighted_stats_ref(x, a, b)
    np.testing.assert_allclose(s, sr, **_tol(n))
    np.testing.assert_allclose(m, mr, **_tol(n))


def test_masked_rows_contribute_nothing():
    rng = np.random.default_rng(7)
    x, a, b = _rand(rng, 512, 64)
    mask = np.ones(512, np.float32)
    mask[300:] = 0.0
    s, m = weighted_stats(x, jnp.asarray(a * mask), jnp.asarray(b * np.asarray(mask)))
    sr, mr = weighted_stats_ref(x[:300], a[:300], b[:300])
    np.testing.assert_allclose(s, sr, **_tol(512))
    np.testing.assert_allclose(m, mr, **_tol(512))


def test_gram_is_symmetric_psd():
    rng = np.random.default_rng(11)
    x, a, _ = _rand(rng, 256, 32)
    s = np.asarray(weighted_gram(x, a))
    np.testing.assert_allclose(s, s.T, rtol=1e-5, atol=1e-5)
    w = np.linalg.eigvalsh(s.astype(np.float64))
    assert w.min() > -1e-3


def test_indivisible_shape_rejected():
    with pytest.raises(ValueError):
        weighted_stats(
            jnp.zeros((300, 64)), jnp.zeros(300), jnp.zeros(300), block_n=256
        )


def test_zero_weights_give_zero():
    x = jnp.asarray(np.random.default_rng(3).standard_normal((256, 16)), jnp.float32)
    s, m = weighted_stats(x, jnp.zeros(256), jnp.zeros(256))
    assert float(jnp.abs(s).max()) == 0.0
    assert float(jnp.abs(m).max()) == 0.0
