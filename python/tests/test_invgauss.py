"""The inverse-Gaussian transform sampler: distributional correctness.

gamma^-1 ~ IG(mu, lam=1) has mean mu and variance mu^3 (Eq. 5 uses
lam = 1). We drive the transform with numpy randomness and check
moments, plus the scale-free sanity identities of the MSH method.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import inv_gauss_ref


def _sample(mu, n, seed):
    rng = np.random.default_rng(seed)
    u = rng.uniform(0.0, 1.0, n).astype(np.float32)
    z = rng.standard_normal(n).astype(np.float32)
    return np.asarray(inv_gauss_ref(jnp.full(n, mu, jnp.float32), jnp.asarray(u), jnp.asarray(z)))


@settings(max_examples=10, deadline=None)
@given(mu=st.sampled_from([0.1, 0.5, 1.0, 2.0]), seed=st.integers(0, 2**31 - 1))
def test_moments(mu, seed):
    n = 200_000
    s = _sample(mu, n, seed)
    assert s.min() > 0.0
    # mean = mu, var = mu^3 / lam with lam = 1
    se_mean = np.sqrt(mu**3 / n)
    assert abs(s.mean() - mu) < 6.0 * se_mean + 1e-3
    # variance check is loose: 4th moment of IG is heavy-tailed
    assert abs(s.var() - mu**3) / mu**3 < 0.25


def test_matches_scipy_closed_form_cdf():
    """Kolmogorov-Smirnov against the analytic IG cdf (no scipy: own cdf)."""

    def ig_cdf(x, mu, lam=1.0):
        from math import erf, exp, sqrt

        def phi(t):
            return 0.5 * (1.0 + erf(t / sqrt(2.0)))

        return np.array(
            [
                phi(sqrt(lam / xi) * (xi / mu - 1.0))
                + exp(2.0 * lam / mu) * phi(-sqrt(lam / xi) * (xi / mu + 1.0))
                for xi in x
            ]
        )

    mu = 0.7
    s = np.sort(_sample(mu, 50_000, 123).astype(np.float64))
    cdf = ig_cdf(s, mu)
    emp = np.arange(1, len(s) + 1) / len(s)
    ks = np.abs(cdf - emp).max()
    assert ks < 0.02, f"KS distance {ks}"


def test_extreme_mu_finite():
    for mu in (1e-6, 1e6):
        s = _sample(mu, 1000, 5)
        assert np.isfinite(s).all()
        assert (s > 0).all()
