"""Artifact/manifest integrity: what aot.py wrote is what runtime/ expects."""

import json
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def test_family_complete(manifest):
    names = {a["name"] for a in manifest["artifacts"]}
    for k in manifest["k_family"]:
        for base in ("lin_em_step", "lin_mc_step", "svr_em_step", "svr_mc_step",
                     "solve_em", "solve_mc", "predict"):
            assert f"{base}_k{k}" in names, f"missing {base}_k{k}"
        m = manifest["m_classes"]
        for base in ("mlt_em_step", "mlt_mc_step", "predict_mlt"):
            assert f"{base}_k{k}_m{m}" in names


def test_files_exist_and_are_hlo(manifest):
    for a in manifest["artifacts"]:
        p = os.path.join(ART, a["file"])
        assert os.path.exists(p), a["file"]
        head = open(p).read(200)
        assert "HloModule" in head, f"{a['file']} is not HLO text"


def test_step_shapes_consistent(manifest):
    for a in manifest["artifacts"]:
        k, chunk = a["k"], a["chunk"]
        shapes = [tuple(i["shape"]) for i in a["inputs"]]
        if a["kind"] in ("lin_step", "svr_step"):
            assert shapes[0] == (chunk, k)  # x
            assert shapes[1] == (chunk,)  # y
            assert shapes[2] == (chunk,)  # mask
            assert shapes[3] == (k,)  # w
        if a["kind"] == "mlt_step":
            assert shapes[0] == (chunk, k)
            assert shapes[1] == (chunk, a["m"])
            assert shapes[3] == (a["m"], k)
        if a["kind"] == "solve":
            assert shapes[0] == (k, k) and shapes[2] == (k, k)


def test_mc_variants_take_randomness(manifest):
    for a in manifest["artifacts"]:
        if a["kind"] == "lin_step":
            n_in = len(a["inputs"])
            assert n_in == (7 if a["variant"] == "mc" else 5)
        if a["kind"] == "svr_step":
            assert len(a["inputs"]) == (10 if a["variant"] == "mc" else 6)
