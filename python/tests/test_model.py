"""L2 correctness: every worker step / master solve vs an independent
numpy re-derivation of the paper's equations, plus end-to-end EM
convergence on a tiny separable problem.
"""

import jax
import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model

CHUNK, EPS = 512, 1e-5


def _lin_data(seed, k=16, frac_pad=0.25):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((CHUNK, k)).astype(np.float32)
    y = np.sign(rng.standard_normal(CHUNK)).astype(np.float32)
    mask = (rng.uniform(size=CHUNK) > frac_pad).astype(np.float32)
    w = rng.standard_normal(k).astype(np.float32) * 0.3
    return x, y, mask, w


def _close(actual, desired, rtol=2e-3):
    """Scale-aware comparison: gamma clamps at eps=1e-5 make the weights
    span ~5 orders of magnitude, so f32 accumulation-order differences
    are proportional to the matrix scale, not elementwise values."""
    desired = np.asarray(desired)
    atol = 1e-4 * max(np.abs(desired).max(), 1.0)
    np.testing.assert_allclose(actual, desired, rtol=rtol, atol=atol)


def _np_lin_em(x, y, mask, w, eps):
    x, y, w = x.astype(np.float64), y.astype(np.float64), w.astype(np.float64)
    margin = 1.0 - y * (x @ w)
    gamma = np.maximum(np.abs(margin), eps)
    a = mask / gamma
    b = y * (mask + a)
    s = (x * a[:, None]).T @ x
    m = x.T @ b
    obj = np.sum(np.maximum(margin, 0.0) * mask)
    err = np.sum(mask * (y * (x @ w) <= 0.0))
    return s, m, obj, err


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), k=st.sampled_from([16, 64]))
def test_lin_step_em(seed, k):
    x, y, mask, w = _lin_data(seed, k)
    s, m, obj, err = model.lin_step_em(
        jnp.asarray(x), jnp.asarray(y), jnp.asarray(mask), jnp.asarray(w), jnp.float32([EPS])
    )
    sr, mr, objr, errr = _np_lin_em(x, y, mask, w, EPS)
    _close(s, sr)
    _close(m, mr)
    np.testing.assert_allclose(float(obj[0]), objr, rtol=1e-4)
    assert float(err[0]) == errr


def test_lin_step_mc_uses_injected_randomness():
    """Same (u, z) -> identical draw; stats match a numpy replay of the
    MSH transform with the same randomness."""
    x, y, mask, w = _lin_data(3)
    rng = np.random.default_rng(0)
    u = rng.uniform(size=CHUNK).astype(np.float32)
    z = rng.standard_normal(CHUNK).astype(np.float32)
    args = [jnp.asarray(v) for v in (x, y, mask, w)] + [jnp.float32([EPS]), jnp.asarray(u), jnp.asarray(z)]
    s1, m1, *_ = model.lin_step_mc(*args)
    s2, m2, *_ = model.lin_step_mc(*args)
    np.testing.assert_array_equal(s1, s2)

    # numpy replay
    margin = 1.0 - y * (x @ w)
    mu = 1.0 / np.maximum(np.abs(margin), EPS)
    yv = z * z
    xr = mu + 0.5 * mu * mu * yv - 0.5 * mu * np.sqrt(4 * mu * yv + (mu * yv) ** 2)
    xr = np.maximum(xr, 1e-30)
    ig = np.where(u <= mu / (mu + xr), xr, mu * mu / xr)
    inv_g = mask * np.minimum(ig, 1.0 / EPS)
    sr = (x * inv_g[:, None]).T @ x
    mr = x.T @ (y * (mask + inv_g))
    np.testing.assert_allclose(s1, sr, rtol=2e-3, atol=2e-2)
    np.testing.assert_allclose(m1, mr, rtol=2e-3, atol=2e-2)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_svr_step_em(seed):
    rng = np.random.default_rng(seed)
    k, eps_ins = 16, 0.3
    x = rng.standard_normal((CHUNK, k)).astype(np.float32)
    y = (x @ rng.standard_normal(k) + 0.1 * rng.standard_normal(CHUNK)).astype(np.float32)
    mask = np.ones(CHUNK, np.float32)
    w = rng.standard_normal(k).astype(np.float32) * 0.2
    s, m, loss, sq = model.svr_step_em(
        *[jnp.asarray(v) for v in (x, y, mask, w)], jnp.float32([EPS]), jnp.float32([eps_ins])
    )
    r = y - x @ w
    g = np.maximum(np.abs(r - eps_ins), EPS)
    o = np.maximum(np.abs(r + eps_ins), EPS)
    a = 1.0 / g + 1.0 / o
    b = (y - eps_ins) / g + (y + eps_ins) / o
    _close(s, (x.astype(np.float64) * a[:, None]).T @ x)
    _close(m, x.T.astype(np.float64) @ b)
    np.testing.assert_allclose(float(loss[0]), np.maximum(np.abs(r) - eps_ins, 0).sum(), rtol=1e-4)
    np.testing.assert_allclose(float(sq[0]), (r * r).sum(), rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), yidx=st.integers(0, 9))
def test_mlt_step_em(seed, yidx):
    rng = np.random.default_rng(seed)
    k, m_cls = 16, 10
    x = rng.standard_normal((CHUNK, k)).astype(np.float32)
    labels = rng.integers(0, m_cls, CHUNK)
    yhot = np.eye(m_cls, dtype=np.float32)[labels]
    mask = np.ones(CHUNK, np.float32)
    w_all = (rng.standard_normal((m_cls, k)) * 0.2).astype(np.float32)

    s, m, loss, err = model.mlt_step_em(
        *[jnp.asarray(v) for v in (x, yhot, mask, w_all)],
        jnp.int32([yidx]),
        jnp.float32([EPS]),
    )

    # independent numpy re-derivation of §3.3
    scores = x @ w_all.T
    delta = 1.0 - yhot
    aug = scores + delta
    aug_m = aug.copy()
    aug_m[:, yidx] = -np.inf
    zeta = aug_m.max(axis=1)
    rho = zeta - delta[:, yidx]
    beta = np.where(labels == yidx, 1.0, -1.0).astype(np.float32)
    margin = rho - x @ w_all[yidx]
    a = 1.0 / np.maximum(np.abs(margin), EPS)
    b = rho * a + beta
    _close(s, (x.astype(np.float64) * a[:, None]).T @ x)
    _close(m, x.T.astype(np.float64) @ b)
    np.testing.assert_allclose(
        float(loss[0]), (aug.max(axis=1) - scores[np.arange(CHUNK), labels]).sum(), rtol=1e-4
    )
    assert float(err[0]) == (scores.argmax(axis=1) != labels).sum()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), k=st.sampled_from([16, 64]))
def test_master_solve_em(seed, k):
    rng = np.random.default_rng(seed)
    g = rng.standard_normal((k, 2 * k)).astype(np.float32)
    s_sum = (g @ g.T).astype(np.float32)
    m_sum = rng.standard_normal(k).astype(np.float32)
    lam = 0.7
    (w,) = model.master_solve_em(
        jnp.asarray(s_sum), jnp.asarray(m_sum), jnp.eye(k, dtype=jnp.float32), jnp.float32([lam])
    )
    wr = np.linalg.solve(lam * np.eye(k) + s_sum.astype(np.float64), m_sum)
    np.testing.assert_allclose(w, wr, rtol=2e-3, atol=2e-3)


def test_master_solve_mc_distribution():
    """With z ~ N(0, I), solve_mc draws from N(mu, Sigma): check the
    sample mean and covariance over many draws on a tiny K."""
    k, lam, n_draws = 4, 1.0, 3000
    solve_mc = jax.jit(model.master_solve_mc)  # loop-based solve is slow eagerly
    rng = np.random.default_rng(0)
    g = rng.standard_normal((k, 3 * k)).astype(np.float32)
    s_sum = g @ g.T
    m_sum = rng.standard_normal(k).astype(np.float32)
    a = lam * np.eye(k) + s_sum
    mu = np.linalg.solve(a, m_sum)
    cov = np.linalg.inv(a)

    draws = []
    for i in range(n_draws):
        z = rng.standard_normal(k).astype(np.float32)
        (w,) = solve_mc(
            jnp.asarray(s_sum), jnp.asarray(m_sum), jnp.eye(k, dtype=jnp.float32),
            jnp.float32([lam]), jnp.asarray(z),
        )
        draws.append(np.asarray(w))
    d = np.stack(draws)
    np.testing.assert_allclose(d.mean(0), mu, atol=4.0 * np.sqrt(cov.max() / n_draws) + 1e-3)
    np.testing.assert_allclose(np.cov(d.T), cov, atol=0.05 * np.abs(cov).max() + 1e-4)


def test_em_loop_converges_to_svm_solution():
    """Full EM on a tiny separable 2-D problem reaches a w with zero
    training error and monotone objective (paper §2.4: concave posterior
    => global optimum)."""
    rng = np.random.default_rng(42)
    n, k, lam = 512, 2, 1.0
    y = np.sign(rng.standard_normal(n)).astype(np.float32)
    x = (rng.standard_normal((n, k)) + 2.5 * y[:, None] * np.array([1.0, 0.5])).astype(np.float32)
    mask = np.ones(n, np.float32)
    w = np.zeros(k, np.float32)
    objs = []
    for _ in range(50):
        s, m, obj, err = model.lin_step_em(
            *[jnp.asarray(v) for v in (x, y, mask, w)], jnp.float32([1e-5])
        )
        objs.append(0.5 * lam * float(w @ w) + 2.0 * float(obj[0]))
        (w,) = model.master_solve_em(s, m, jnp.eye(k, dtype=jnp.float32), jnp.float32([lam]))
        w = np.asarray(w)
    assert objs[-1] < objs[0]
    # tail is monotone non-increasing (early iterations may oscillate in f32)
    tail = objs[20:]
    assert all(b <= a + 1e-2 for a, b in zip(tail, tail[1:]))
    margin = y * (x @ w)
    assert (margin > 0).mean() > 0.98
