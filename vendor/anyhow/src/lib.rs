//! Offline stand-in for the `anyhow` crate, implementing exactly the
//! subset this workspace uses: [`Error`], [`Result`], the [`anyhow!`] /
//! [`bail!`] / [`ensure!`] macros, the [`Context`] extension trait for
//! `Result` and `Option`, and typed recovery via [`Error::new`] +
//! [`Error::downcast_ref`].
//!
//! The offline registry cannot be assumed to carry the real `anyhow`,
//! and the crate's API surface used here is small, so a path dependency
//! keeps the default build hermetic. Semantics match the real crate for
//! this subset: `{e}` prints the outermost message, `{e:#}` prints the
//! whole context chain joined by `": "`, any
//! `std::error::Error + Send + Sync + 'static` converts via `?` keeping
//! its concrete type recoverable through `downcast_ref`, and context
//! wrapping preserves that payload.

use std::any::Any;
use std::fmt::{self, Debug, Display};

/// An error with a context chain (outermost first) and, when built from
/// a concrete `std::error::Error` value, that value as a recoverable
/// payload.
pub struct Error {
    chain: Vec<String>,
    payload: Option<Box<dyn Any + Send + Sync>>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()], payload: None }
    }

    /// Create an error from a concrete error value, keeping the value
    /// itself recoverable via [`downcast_ref`](Error::downcast_ref).
    pub fn new<E: std::error::Error + Send + Sync + 'static>(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain, payload: Some(Box::new(e)) }
    }

    /// The underlying concrete error, if this `Error` was built from a
    /// value of type `E` (via [`Error::new`] or the `?` conversion).
    /// Context wrapping does not erase it.
    pub fn downcast_ref<E: 'static>(&self) -> Option<&E> {
        self.payload.as_ref()?.downcast_ref::<E>()
    }

    /// Wrap with an outer context message.
    pub fn context<C: Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        for cause in &self.chain[1..] {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

// `Error` deliberately does not implement `std::error::Error`: that is
// what keeps this blanket conversion coherent with `From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::new(e)
    }
}

/// `Result` defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to the error/`None` arm of a fallible value.
pub trait Context<T> {
    fn context<C: Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = Err::<(), _>(io_err()).context("opening data").unwrap_err();
        assert_eq!(format!("{e}"), "opening data");
        assert_eq!(format!("{e:#}"), "opening data: gone");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn option_context_and_macros() {
        let v: Result<i32> = None.context("missing");
        assert_eq!(format!("{}", v.unwrap_err()), "missing");
        let e = anyhow!("bad value {}", 7);
        assert_eq!(format!("{e}"), "bad value 7");
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 10 {
                bail!("too big");
            }
            Ok(x)
        }
        assert!(f(-1).is_err());
        assert!(f(11).is_err());
        assert_eq!(f(3).unwrap(), 3);
    }

    #[test]
    fn with_context_lazy() {
        let r: Result<(), Error> = Err(io_err()).with_context(|| format!("attempt {}", 2));
        assert_eq!(format!("{:#}", r.unwrap_err()), "attempt 2: gone");
    }

    #[test]
    fn downcast_ref_recovers_concrete_type() {
        let e = Error::new(io_err());
        assert_eq!(e.downcast_ref::<std::io::Error>().unwrap().kind(), std::io::ErrorKind::NotFound);
        assert!(e.downcast_ref::<std::fmt::Error>().is_none());
        // context wrapping keeps the payload; plain messages have none
        let wrapped = Error::new(io_err()).context("outer");
        assert!(wrapped.downcast_ref::<std::io::Error>().is_some());
        assert!(Error::msg("plain").downcast_ref::<std::io::Error>().is_none());
        // the ? conversion goes through Error::new, so it downcasts too
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(f().unwrap_err().downcast_ref::<std::io::Error>().is_some());
    }
}
